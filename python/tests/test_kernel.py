"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, seeds and parameter ranges; every case
asserts exact agreement (the kernels are elementwise compare/affine
ops — no tolerance needed beyond float equality of identical formulas).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import physics
from compile.kernels import frac as frac_k
from compile.kernels import ref
from compile.kernels import simra as simra_k

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- simra

@settings(max_examples=12, deadline=None)
@given(
    s=st.sampled_from([1, 3, 8, 16]),
    n=st.sampled_from([4, 512, 1024, 640]),
    seed=st.integers(0, 2**31 - 1),
)
def test_charge_sense_matches_ref(s, n, seed):
    k1, k2, k3 = (seed % 1000, seed % 997, seed % 991)
    ksum = rand(k1, s, n) * 8.0
    thr = 0.4 + 0.2 * rand(k2, n)
    noise = 0.01 * (rand(k3, s, n) - 0.5)
    got = simra_k.charge_sense(ksum, thr, noise)
    want = ref.charge_sense_ref(ksum, thr, noise)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_charge_sense_blocked_equals_single_tile():
    # The BlockSpec grid path and the single-tile path must agree.
    ksum = rand(1, 16, 1024) * 8.0
    thr = 0.45 + 0.1 * rand(2, 1024)
    noise = 0.002 * (rand(3, 16, 1024) - 0.5)
    tiled = simra_k.charge_sense(ksum, thr, noise)  # divisible -> grid
    old = simra_k.SINGLE_TILE
    try:
        simra_k.SINGLE_TILE = True
        single = simra_k.charge_sense(ksum, thr, noise)
    finally:
        simra_k.SINGLE_TILE = old
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(single))


def test_charge_sense_paper_voltages():
    # MAJ5(1,1,1,0,0) + neutral 1.5 must sit at 0.529 V_DD: above a
    # 0.5 threshold, below a 0.535 threshold.
    ksum = jnp.full((1, 2), 3.0 + 1.5)
    thr = jnp.array([0.5, 0.535], jnp.float32)
    noise = jnp.zeros((1, 2), jnp.float32)
    out = np.asarray(simra_k.charge_sense(ksum, thr, noise))
    assert out.tolist() == [[1.0, 0.0]]


def test_charge_sense_threshold_is_strict():
    # Exactly at threshold -> 0 (strict compare, matches Rust `>`).
    ksum = jnp.full((1, 1), 1.5)  # V = 0.5 under 8-row SiMRA... compute
    v = physics.bitline_voltage(1.5)
    thr = jnp.array([v], jnp.float32)
    noise = jnp.zeros((1, 1), jnp.float32)
    out = np.asarray(simra_k.charge_sense(ksum, thr, noise))
    assert out[0, 0] == 0.0


# ----------------------------------------------------------------- frac

@settings(max_examples=12, deadline=None)
@given(
    n=st.sampled_from([8, 512, 1000]),
    fx=st.integers(0, 6),
    fy=st.integers(0, 6),
    fz=st.integers(0, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_frac_rows_matches_ref(n, fx, fy, fz, seed):
    bits = (rand(seed % 4093, 3, n) > 0.5).astype(jnp.float32)
    fracs = jnp.array([fx, fy, fz], jnp.float32)
    got = frac_k.frac_rows(bits, fracs)
    want = ref.frac_rows_ref(bits, fracs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_frac_rows_known_values():
    bits = jnp.array([[1.0, 0.0], [1.0, 0.0], [1.0, 0.0]], jnp.float32)
    fracs = jnp.array([0.0, 1.0, 2.0], jnp.float32)
    out = np.asarray(frac_k.frac_rows(bits, fracs))
    r = physics.FRAC_R
    np.testing.assert_allclose(
        out,
        [[1.0, 0.0],
         [0.5 + 0.5 * r, 0.5 - 0.5 * r],
         [0.5 + 0.5 * r * r, 0.5 - 0.5 * r * r]],
        rtol=1e-6,
    )


def test_frac_converges_to_neutral():
    bits = jnp.ones((3, 4), jnp.float32)
    fracs = jnp.array([10.0, 10.0, 10.0], jnp.float32)
    out = np.asarray(frac_k.frac_rows(bits, fracs))
    assert np.all(np.abs(out - 0.5) < 0.01)


# ----------------------------------------------------------------- majx

@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([3, 5]),
    s=st.sampled_from([4, 16]),
    n=st.sampled_from([64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_majx_ref_majority_semantics(m, s, n, seed):
    # With ideal thresholds, zero noise and neutral calibration the
    # reference MAJX is exactly the boolean majority.
    key = jax.random.PRNGKey(seed % 65521)
    bits = jax.random.bernoulli(key, 0.5, (s, m, n)).astype(jnp.float32)
    const_q = {5: 0.0, 3: 1.0}[m]
    calib_q = jnp.full((n,), 1.5 + const_q, jnp.float32)
    thr = jnp.full((n,), 0.5, jnp.float32)
    noise = jnp.zeros((s, n), jnp.float32)
    out = np.asarray(ref.majx_ref(bits, calib_q, thr, noise))
    want = (np.asarray(bits).sum(axis=1) >= (m + 1) // 2).astype(np.float32)
    np.testing.assert_array_equal(out, want)
