"""L2 graph semantics: calibration step, ECR scan, GEMV."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model, physics

jax.config.update("jax_platform_name", "cpu")

N = 256
S = 64


def lattice_t210():
    """Mirror calib::lattice::OffsetLattice::build for T_{2,1,0}."""
    r = physics.FRAC_R
    fracs = [2, 1, 0]
    combos = []
    for c in range(8):
        bits = [(c >> i) & 1 for i in range(3)]
        q = sum(0.5 + (b - 0.5) * r ** f for b, f in zip(bits, fracs))
        combos.append((q, bits))
    combos.sort(key=lambda x: x[0])
    table = jnp.array([b for _, b in combos], jnp.float32)
    qs = [q for q, _ in combos]
    return table, jnp.array([2.0, 1.0, 0.0], jnp.float32), qs


def run_step(levels, thr, seed=7, sigma_n=0.0, tau=0.02, update=1.0, m=5):
    table, fracs, _ = lattice_t210()
    fn = model.make_majx_step(m, S, N)
    return fn(
        jnp.uint32(seed),
        levels,
        table,
        fracs,
        jnp.float32(physics.FRAC_R),
        jnp.float32(0.0 if m == 5 else 1.0),
        thr,
        jnp.float32(sigma_n),
        jnp.float32(tau),
        jnp.float32(update),
    )


def test_ideal_columns_have_no_errors_and_keep_levels():
    table, fracs, qs = lattice_t210()
    neutral = int(np.argmin([abs(q - 1.5) for q in qs]))
    levels = jnp.full((N,), neutral, jnp.int32)
    thr = jnp.full((N,), 0.5, jnp.float32)
    new_levels, bias, err = run_step(levels, thr)
    assert np.all(np.asarray(err) == 0)
    assert np.all(np.abs(np.asarray(bias)) < 1e-6)
    np.testing.assert_array_equal(np.asarray(new_levels), np.asarray(levels))


def test_biased_columns_step_toward_compensation():
    table, fracs, qs = lattice_t210()
    neutral = int(np.argmin([abs(q - 1.5) for q in qs]))
    levels = jnp.full((N,), neutral, jnp.int32)
    # First half: threshold far too low (outputs 1 too often) ->
    # decrement; second half: too high -> increment.
    thr = jnp.concatenate([
        jnp.full((N // 2,), 0.40, jnp.float32),
        jnp.full((N // 2,), 0.60, jnp.float32),
    ])
    new_levels, bias, err = run_step(levels, thr)
    nl = np.asarray(new_levels)
    b = np.asarray(bias)
    assert np.all(b[: N // 2] > 0.2)
    assert np.all(b[N // 2:] < -0.2)
    assert np.all(nl[: N // 2] == neutral - 1)
    assert np.all(nl[N // 2:] == neutral + 1)
    assert np.all(np.asarray(err) > 0)


def test_update_flag_freezes_levels():
    _, _, qs = lattice_t210()
    levels = jnp.zeros((N,), jnp.int32)
    thr = jnp.full((N,), 0.65, jnp.float32)
    new_levels, _, _ = run_step(levels, thr, update=0.0)
    np.testing.assert_array_equal(np.asarray(new_levels), 0)


def test_levels_clamp_to_lattice():
    levels = jnp.full((N,), 7, jnp.int32)
    thr = jnp.full((N,), 0.9, jnp.float32)  # always under-reads -> inc
    new_levels, _, _ = run_step(levels, thr)
    assert np.all(np.asarray(new_levels) == 7)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_step_is_deterministic_in_seed(seed):
    levels = jnp.full((N,), 3, jnp.int32)
    thr = jnp.full((N,), 0.5, jnp.float32)
    a = run_step(levels, thr, seed=seed % 99991, sigma_n=0.01)
    b = run_step(levels, thr, seed=seed % 99991, sigma_n=0.01)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_ecr_scan_counts_match_step_scale():
    table, fracs, qs = lattice_t210()
    neutral = int(np.argmin([abs(q - 1.5) for q in qs]))
    levels = jnp.full((N,), neutral, jnp.int32)
    # Mildly offset thresholds: some columns err.
    key = jax.random.PRNGKey(5)
    thr = 0.5 + 0.03 * jax.random.normal(key, (N,), jnp.float32)
    fn = model.make_ecr_scan(5, 4, S, N)
    (err_total,) = fn(
        jnp.uint32(3),
        levels,
        table,
        fracs,
        jnp.float32(physics.FRAC_R),
        jnp.float32(0.0),
        thr,
        jnp.float32(0.002),
    )
    e = np.asarray(err_total)
    assert e.shape == (N,)
    assert e.min() >= 0 and e.max() <= 4 * S
    # Columns beyond the margin must err heavily; centred ones not.
    margin = 0.5 * physics.CC_FF / (8 * physics.CC_FF + physics.CB_FF)
    t = np.asarray(thr) - 0.5
    heavy = e[np.abs(t) > 2.5 * margin]
    clean = e[np.abs(t) < 0.2 * margin]
    assert heavy.min() > 0
    assert np.median(clean) == 0


def test_maj3_uses_const_rows():
    # With const_q = 1.0 and neutral calibration, MAJ3 behaves as a
    # majority: heavily-low thresholds output 1 always.
    _, _, qs = lattice_t210()
    neutral = int(np.argmin([abs(q - 1.5) for q in qs]))
    levels = jnp.full((N,), neutral, jnp.int32)
    thr = jnp.full((N,), 0.5, jnp.float32)
    new_levels, bias, err = run_step(levels, thr, m=3)
    assert np.all(np.asarray(err) == 0)


def test_pud_gemv_ideal_and_faulty():
    fn = model.make_pud_gemv(8, 16)
    key = jax.random.PRNGKey(0)
    w = jax.random.randint(key, (8, 16), -128, 127).astype(jnp.float32)
    x = jax.random.randint(key, (16,), -128, 127).astype(jnp.float32)
    flip_none = jnp.zeros((8,), jnp.float32)
    flip_all = jnp.ones((8,), jnp.float32)
    y, y_clean = fn(w, x, flip_none, jnp.uint32(1))
    np.testing.assert_allclose(np.asarray(y), np.asarray(w) @ np.asarray(x))
    np.testing.assert_allclose(np.asarray(y_clean), np.asarray(y))
    _, y_bad = fn(w, x, flip_all, jnp.uint32(1))
    assert np.any(np.asarray(y_bad) != np.asarray(y))


def test_physics_constants_match_paper():
    # §II-C anchors.
    assert abs(physics.bitline_voltage(1.0, rows=1) - 0.55) < 1e-9
    assert abs(physics.bitline_voltage(4.5) - 0.52941) < 1e-4
    assert abs(physics.frac_charge(1.0, 8) - 0.5) < 0.05
