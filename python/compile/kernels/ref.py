"""Pure-jnp oracle for the L1 Pallas kernels.

Used by pytest/hypothesis to validate every kernel against a
straight-line jax.numpy implementation of the same analog model, and by
the L2 graphs' own unit tests.
"""

import jax.numpy as jnp

from .. import physics


def charge_sense_ref(ksum, thr, noise, rows=physics.SIMRA_ROWS):
    """Reference SA decision: voltage divider + noisy compare."""
    denom = rows * physics.CC_FF + physics.CB_FF
    v = (physics.CC_FF * ksum + physics.CB_FF * physics.V_PRE) / denom
    return (v + noise > thr[None, :]).astype(jnp.float32)


def frac_rows_ref(bits, fracs, r=physics.FRAC_R):
    """Reference multi-level Frac charge."""
    decay = jnp.power(jnp.float32(r), fracs.astype(jnp.float32))
    return 0.5 + (bits - 0.5) * decay[:, None]


def majx_ref(input_bits, calib_q, thr, noise, rows=physics.SIMRA_ROWS):
    """Reference MAJX: explicit operand bits -> SA decisions.

    input_bits: f32[S, M, N] operand bits; calib_q: f32[N] total
    non-operand charge; returns f32[S, N].
    """
    ksum = input_bits.sum(axis=1) + calib_q[None, :]
    return charge_sense_ref(ksum, thr, noise, rows=rows)
