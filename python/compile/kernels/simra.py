"""L1 Pallas kernel: SiMRA charge-sharing + sense-amplifier decision.

This is the compute hot-spot of the whole reproduction: for every
(sample, column) pair, share charge across the 8 opened cells of the
column, add the per-operation noise, and compare against that column's
sense-amplifier threshold.

The kernel is written tile-wise with a BlockSpec grid over (samples,
columns). On a real TPU the natural tiling is (8, 128)-multiples resident
in VMEM with the whole pass fused (one HBM read of the operand count, one
write of the output bits) — see DESIGN.md §Hardware-Adaptation. Here it
is lowered with ``interpret=True`` so the resulting HLO runs on any PJRT
backend, including the Rust CPU client.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import physics

# Tile sizes for the (samples, columns) grid. 8 x 512 f32 tiles keep the
# working set tiny (~16 KiB/tile) and map onto TPU-native (8, 128) lanes.
BLOCK_S = 8
BLOCK_N = 512

# When True, lower with a single full-array tile instead of the BlockSpec
# grid. The grid expresses the HBM<->VMEM schedule for a real TPU; under
# interpret=True on the CPU PJRT backend the grid only adds loop overhead,
# so `aot.py` flips this for production artifacts (see DESIGN.md §7).
SINGLE_TILE = False


def _sense_kernel(ksum_ref, thr_ref, noise_ref, out_ref, *, rows):
    """One (BLOCK_S, BLOCK_N) tile: voltage divider + noisy compare.

    ksum_ref:  summed cell charge per (sample, column), cell-equivalents.
    thr_ref:   per-column SA threshold (broadcast over samples).
    noise_ref: per-(sample, column) operation noise.
    out_ref:   0.0/1.0 SA decisions.
    """
    denom = rows * physics.CC_FF + physics.CB_FF
    v = (physics.CC_FF * ksum_ref[...] + physics.CB_FF * physics.V_PRE) / denom
    out_ref[...] = (v + noise_ref[...] > thr_ref[...]).astype(jnp.float32)


def charge_sense(ksum, thr, noise, rows=physics.SIMRA_ROWS):
    """SA output bits for an (S, N) batch of SiMRA operations.

    Args:
      ksum:  f32[S, N] — total cell charge on each column per sample
             (operand ones count + calibration charge).
      thr:   f32[N]    — per-column effective SA thresholds.
      noise: f32[S, N] — per-operation noise realisations.
      rows:  number of rows opened by the SiMRA (denominator of the
             charge-sharing divider).

    Returns:
      f32[S, N] of {0.0, 1.0} sense decisions.
    """
    s, n = ksum.shape
    if SINGLE_TILE or s % BLOCK_S != 0 or n % BLOCK_N != 0:
        # One full-array tile: for CPU-targeted artifacts and odd test
        # shapes (see SINGLE_TILE above).
        bs, bn = s, n
    else:
        bs, bn = BLOCK_S, BLOCK_N
    grid = (s // bs, n // bn)
    thr2d = jnp.broadcast_to(thr[None, :], (1, n))
    return pl.pallas_call(
        lambda a, b, c, o: _sense_kernel(a, b, c, o, rows=rows),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bs, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bs, bn), lambda i, j: (i, j)),
        interpret=True,
    )(ksum, thr2d, noise)
