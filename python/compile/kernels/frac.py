"""L1 Pallas kernel: multi-level Frac charging of calibration rows.

PUDTune's key insight (§III-C): applying f Frac operations to a cell that
initially stores bit b leaves it at the intermediate charge

    q_f(b) = 0.5 + (b - 0.5) * r**f,

so different per-row Frac counts T_{x,y,z} turn 3 stored bits per column
into one of 2^3 = 8 analog offsets. This kernel evaluates that charge for
a (CALIB_ROWS, N) tile of stored bits given per-row Frac counts.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import physics

BLOCK_N = 512

# See kernels/simra.py: production CPU artifacts lower with one tile.
SINGLE_TILE = False


def _frac_kernel(bits_ref, decay_ref, out_ref):
    """q = 0.5 + (b - 0.5) * r^f, with r^f precomputed per row."""
    out_ref[...] = 0.5 + (bits_ref[...] - 0.5) * decay_ref[...]


def frac_rows(bits, fracs, r=physics.FRAC_R):
    """Charge of calibration rows after per-row Frac sequences.

    Args:
      bits:  f32[R, N] — stored calibration bits (0.0 or 1.0).
      fracs: f32[R]    — Frac count applied to each row (the x, y, z of
             a T_{x,y,z} configuration).
      r:     Frac convergence ratio.

    Returns:
      f32[R, N] cell charges in [0, 1].
    """
    rrows, n = bits.shape
    decay = jnp.power(jnp.float32(r), fracs.astype(jnp.float32))
    decay2d = jnp.broadcast_to(decay[:, None], (rrows, n))
    if SINGLE_TILE or n % BLOCK_N != 0:
        grid = (1,)
        bn = n
    else:
        grid = (n // BLOCK_N,)
        bn = BLOCK_N
    return pl.pallas_call(
        _frac_kernel,
        out_shape=jax.ShapeDtypeStruct((rrows, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rrows, bn), lambda j: (0, j)),
            pl.BlockSpec((rrows, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((rrows, bn), lambda j: (0, j)),
        interpret=True,
    )(bits, decay2d)
