"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts for Rust.

Run once at build time (``make artifacts``). Emits, for every graph in
`model.py` and every configured batch geometry, an ``artifacts/*.hlo.txt``
file plus a ``manifest.json`` describing each artifact's exact input and
output signature, and ``physics.json`` with the shared model constants.

HLO **text** — not ``HloModuleProto.serialize()`` — is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids that the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Everything is lowered with
``return_tuple=True`` and unwrapped with ``to_tuple*()`` on the Rust side.

Usage: cd python && python -m compile.aot --out ../artifacts [--full]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

# PRNG implementation for the sampling graphs. threefry2x32 is jax's
# default but costs ~30 scalar ops per 32 random bits; 'rbg' lowers to
# the native rng-bit-generator HLO (Philox) which the CPU PJRT backend
# executes ~an order of magnitude faster. Quality is ample for random
# test patterns + noise (EXPERIMENTS.md §Perf, L2 iteration log).
jax.config.update("jax_default_prng_impl", "rbg")

from . import model, physics
from .kernels import frac as frac_k
from .kernels import simra as simra_k


def to_hlo_text(lowered):
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


F32, I32, U32 = jnp.float32, jnp.int32, jnp.uint32


def majx_step_specs(n):
    """Input signature of model.make_majx_step graphs."""
    return [
        ("seed", (), U32),
        ("levels", (n,), I32),
        ("bits_table", (physics.LATTICE_LEVELS, physics.CALIB_ROWS), F32),
        ("fracs", (physics.CALIB_ROWS,), F32),
        ("r", (), F32),
        ("const_q", (), F32),
        ("thr", (n,), F32),
        ("sigma_n", (), F32),
        ("tau", (), F32),
        ("update", (), F32),
    ]


def ecr_scan_specs(n):
    """Input signature of model.make_ecr_scan graphs."""
    return [
        ("seed", (), U32),
        ("levels", (n,), I32),
        ("bits_table", (physics.LATTICE_LEVELS, physics.CALIB_ROWS), F32),
        ("fracs", (physics.CALIB_ROWS,), F32),
        ("r", (), F32),
        ("const_q", (), F32),
        ("thr", (n,), F32),
        ("sigma_n", (), F32),
    ]


def majx_eval_specs(s, m, n):
    return [
        ("input_bits", (s, m, n), F32),
        ("calib_q", (n,), F32),
        ("thr", (n,), F32),
        ("noise", (s, n), F32),
    ]


def gemv_specs(m_rows, k_cols):
    return [
        ("w", (m_rows, k_cols), F32),
        ("x", (k_cols,), F32),
        ("flip_p", (m_rows,), F32),
        ("seed", (), U32),
    ]


def build_catalog(full):
    """(name, fn, input_specs, output_names, meta) for every artifact.

    Geometry tiers:
      small — pytest / cargo-test cross-validation shapes;
      std   — default experiment shapes (single-core friendly);
      full  — the paper's 65,536-column subarray (--full only).
    """
    cat = []
    col_tiers = [("small", 1024, 128, 8), ("std", 16384, 512, 16)]
    if full:
        col_tiers.append(("full", 65536, 512, 16))
    for m in (3, 5):
        for tier, n, s, chunks in col_tiers:
            cat.append((
                f"maj{m}_step_{tier}",
                model.make_majx_step(m, s, n),
                majx_step_specs(n),
                ["new_levels", "bias", "err"],
                {"m": m, "samples": s, "cols": n},
            ))
            cat.append((
                f"maj{m}_ecr_{tier}",
                model.make_ecr_scan(m, chunks, s, n),
                ecr_scan_specs(n),
                ["err_total"],
                {"m": m, "samples": s, "cols": n, "chunks": chunks,
                 "total_samples": s * chunks},
            ))
    # Cross-validation graph: explicit inputs, no RNG, small only.
    cat.append((
        "maj5_eval_small",
        model.majx_eval,
        majx_eval_specs(32, 5, 256),
        ["bits"],
        {"m": 5, "samples": 32, "cols": 256},
    ))
    cat.append((
        "maj3_eval_small",
        model.majx_eval,
        majx_eval_specs(32, 3, 256),
        ["bits"],
        {"m": 3, "samples": 32, "cols": 256},
    ))
    cat.append((
        "pud_gemv_64x256",
        model.make_pud_gemv(64, 256),
        gemv_specs(64, 256),
        ["y_ideal", "y_faulty"],
        {"rows": 64, "cols": 256},
    ))
    return cat


def physics_dict():
    return {
        "cc_ff": physics.CC_FF,
        "cb_ff": physics.CB_FF,
        "v_pre": physics.V_PRE,
        "simra_rows": physics.SIMRA_ROWS,
        "frac_r": physics.FRAC_R,
        "calib_rows": physics.CALIB_ROWS,
        "lattice_levels": physics.LATTICE_LEVELS,
        "sigma_sa": physics.SIGMA_SA,
        "tail_weight": physics.TAIL_WEIGHT,
        "tail_ratio": physics.TAIL_RATIO,
        "sigma_noise": physics.SIGMA_NOISE,
        "bias_tau": physics.BIAS_TAU,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also emit the 65,536-column paper-scale artifacts")
    ap.add_argument("--tiled", action="store_true",
                    help="keep the TPU BlockSpec grid in the lowered HLO "
                         "(default: single-tile for the CPU PJRT backend)")
    args = ap.parse_args()

    # Production artifacts run on the CPU PJRT backend where the BlockSpec
    # grid is pure loop overhead; keep kernels single-tile unless asked.
    simra_k.SINGLE_TILE = not args.tiled
    frac_k.SINGLE_TILE = not args.tiled

    os.makedirs(args.out, exist_ok=True)
    manifest = {"artifacts": {}, "tiled": bool(args.tiled)}
    for name, fn, in_specs, out_names, meta in build_catalog(args.full):
        example = [spec(shape, dt) for _, shape, dt in in_specs]
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"name": nm, "shape": list(shape), "dtype": dt.__name__}
                for nm, shape, dt in in_specs
            ],
            "outputs": out_names,
            "meta": meta,
        }
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    with open(os.path.join(args.out, "physics.json"), "w") as f:
        json.dump(physics_dict(), f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
