"""L2: JAX compute graphs for PUDTune calibration and ECR measurement.

Each public function here is a pure jax function that `aot.py` lowers
once to HLO text; the Rust coordinator loads and executes the compiled
artifacts on its PJRT CPU client — Python is never on the request path.

All graphs call the L1 Pallas kernels (`kernels.simra.charge_sense`,
`kernels.frac.frac_rows`) so the kernels lower into the same HLO.

Graph inventory (see DESIGN.md §5):

  majx_eval    — explicit-input MAJX evaluation (no RNG). Used by the
                 Rust<->Python cross-validation test: the native Rust
                 simulator must produce bit-identical outputs.
  majx_step    — one Algorithm-1 iteration, fused: draw S random input
                 patterns per column, apply the column's calibration
                 offsets (bits -> Frac multi-level charges), sense,
                 compute the per-column bias, and step the calibration
                 level indices. One PJRT call per iteration.
  ecr_scan     — mass error measurement: C chunks of S random patterns,
                 accumulated error counts per column (lax.scan keeps the
                 HLO small and the working set bounded).
  pud_gemv     — int8-quantised GEMV with per-column error injection,
                 used by the end-to-end example to translate column error
                 rates into end-task accuracy.

Conventions:
  * the per-column *state* is a level index into an offset lattice of
    2^3 = 8 bit-triples (``bits_table`` f32[8, 3], rows sorted by total
    calibration charge, computed by the Rust side — calib::lattice);
  * thresholds ``thr`` arrive already shifted for temperature/aging
    (the Rust dram model owns the variation field);
  * the majority operand count m (3 or 5) and the batch geometry are
    baked into each artifact at lowering time.
"""

import jax
import jax.numpy as jnp

from . import physics
from .kernels import frac as frac_k
from .kernels import simra as simra_k


def _majority_threshold(m):
    return (m + 1) // 2


def _draw_counts(key, m, s, n):
    """Per-(sample, column) count of '1' operand bits, k ~ Binomial(m, 1/2).

    Drawn as an m-bit random word per element + popcount so no [m, S, N]
    intermediate is materialised.
    """
    word = jax.random.randint(key, (s, n), 0, 2 ** m, dtype=jnp.uint32)
    k = jnp.zeros((s, n), jnp.uint32)
    for b in range(m):
        k = k + ((word >> b) & 1)
    return k.astype(jnp.float32)


def _calib_charge(levels, bits_table, fracs, r):
    """Total calibration charge per column from level indices.

    levels: i32[N] in [0, 8); bits_table: f32[8, 3]; fracs: f32[3].
    Returns f32[N].
    """
    bits = bits_table[levels]                    # [N, 3] gather
    q_rows = frac_k.frac_rows(bits.T, fracs, r)  # [3, N] pallas kernel
    return q_rows.sum(axis=0)


def majx_eval(input_bits, calib_q, thr, noise):
    """Explicit MAJX evaluation (cross-validation path, no RNG).

    input_bits: f32[S, M, N]; calib_q: f32[N] total non-operand charge;
    thr: f32[N]; noise: f32[S, N]. Returns (bits f32[S, N],).
    """
    ksum = input_bits.sum(axis=1) + calib_q[None, :]
    return (simra_k.charge_sense(ksum, thr, noise),)


def make_majx_step(m, s, n):
    """Build the fused Algorithm-1 iteration graph for MAJ-m at (S, N)."""

    maj_t = float(_majority_threshold(m))

    def majx_step(seed, levels, bits_table, fracs, r, const_q, thr,
                  sigma_n, tau, update):
        """One calibration iteration (paper Algorithm 1, lines 3-12).

        seed u32[]: RNG seed for this iteration's random input patterns.
        levels i32[N]: per-column lattice level indices (state).
        bits_table f32[8,3], fracs f32[3], r f32[]: offset lattice spec.
        const_q f32[]: charge of constant non-operand rows (0.0 for MAJ5,
            1.0 for MAJ3 whose 8-row SiMRA also opens a 0-row and 1-row).
        thr f32[N]: effective per-column SA thresholds.
        sigma_n f32[]: per-operation noise std-dev.
        tau f32[]: bias threshold of Algorithm 1.
        update f32[]: 1.0 -> step the levels, 0.0 -> measure only.

        Returns (new_levels i32[N], bias f32[N], err i32[N]).
        """
        key = jax.random.PRNGKey(seed)
        kk, kn = jax.random.split(key)
        k = _draw_counts(kk, m, s, n)
        noise = sigma_n * jax.random.normal(kn, (s, n), jnp.float32)
        q_extra = _calib_charge(levels, bits_table, fracs, r) + const_q
        bits = simra_k.charge_sense(k + q_extra[None, :], thr, noise)
        maj = (k >= maj_t).astype(jnp.float32)
        err = jnp.sum((bits != maj).astype(jnp.int32), axis=0)
        bias = jnp.mean(bits - maj, axis=0)
        # bias > tau: the column outputs too many 1s -> its SA threshold
        # sits low -> reduce the calibration charge (decrement level),
        # and vice versa (paper Algorithm 1 lines 6-11). Columns still
        # showing any errors are additionally nudged along the bias
        # direction: at 512 samples a sub-threshold bias is still a
        # reliable direction signal, and without the nudge columns stall
        # on "just inside the margin" levels that the 8,192-sample ECR
        # test catches (mirrors calib::algorithm on the Rust side).
        # Levels clamp to the lattice bounds.
        has_err = err > 0
        dec = (bias > tau) | (has_err & (bias > 0.0))
        inc = (bias < -tau) | (has_err & (bias < 0.0))
        step = inc.astype(jnp.int32) - dec.astype(jnp.int32)
        stepped = jnp.clip(levels + step, 0, physics.LATTICE_LEVELS - 1)
        new_levels = jnp.where(update > 0, stepped, levels)
        return new_levels, bias, err

    return majx_step


def make_ecr_scan(m, chunks, s, n):
    """Build the mass-ECR graph: chunks x S random patterns per column."""

    maj_t = float(_majority_threshold(m))

    def ecr_scan(seed, levels, bits_table, fracs, r, const_q, thr, sigma_n):
        """Total per-column error counts over ``chunks * s`` patterns.

        Returns (err_total i32[N],).
        """
        q_extra = _calib_charge(levels, bits_table, fracs, r) + const_q

        def body(carry, i):
            key = jax.random.PRNGKey(seed + i)
            kk, kn = jax.random.split(key)
            k = _draw_counts(kk, m, s, n)
            noise = sigma_n * jax.random.normal(kn, (s, n), jnp.float32)
            bits = simra_k.charge_sense(k + q_extra[None, :], thr, noise)
            maj = (k >= maj_t).astype(jnp.float32)
            err = jnp.sum((bits != maj).astype(jnp.int32), axis=0)
            return carry + err, None

        init = jnp.zeros((n,), jnp.int32)
        total, _ = jax.lax.scan(body, init, jnp.arange(chunks, dtype=jnp.uint32))
        return (total,)

    return ecr_scan


def make_pud_gemv(m_rows, k_cols):
    """Build the e2e GEMV graph: ideal int8 GEMV + error injection.

    The end-to-end example maps an MVDRAM-style bit-serial GEMV onto the
    calibrated device: each output element is computed by majority
    circuits on a group of columns, so a column's residual error rate
    translates into bit flips of the accumulated partial sums. The graph
    returns both the ideal product (MXU path on TPU) and an
    error-injected product given per-output flip probabilities, letting
    the driver report end-task accuracy for calibrated vs uncalibrated
    devices.
    """

    def pud_gemv(w, x, flip_p, seed):
        """w: f32[M, K] int8-valued; x: f32[K] int8-valued;
        flip_p: f32[M] probability a given output suffers a bit flip;
        Returns (y_ideal f32[M], y_faulty f32[M])."""
        y = jnp.dot(w, x)
        key = jax.random.PRNGKey(seed)
        kf, kb = jax.random.split(key)
        # Accumulators are 2*8 + log2(K) bits wide; model one flip at a
        # uniformly-drawn bit position of the magnitude.
        flips = jax.random.uniform(kf, (m_rows,)) < flip_p
        bitpos = jax.random.randint(kb, (m_rows,), 0, 16, dtype=jnp.int32)
        delta = jnp.where(flips, jnp.exp2(bitpos.astype(jnp.float32)), 0.0)
        sign = jnp.where(y >= 0, 1.0, -1.0)
        return y, y + sign * delta

    return pud_gemv
