"""Shared analog-physics constants for the PUD charge-sharing model.

Single source of truth for the build-time (JAX/Pallas) side. `aot.py`
exports these to ``artifacts/physics.json`` so the Rust side can assert it
was built against the same model (see ``rust/src/config/device.rs``).

All voltages are expressed in units of V_DD.

The constants are pinned by the paper (PUDTune, §II-C):
  * a cell capacitor of 30 fF and a bitline of 270 fF give a single-cell
    read voltage of (30·1 + 270·0.5)/300 = 0.55 V_DD;
  * MAJ5(1,1,1,0,0) with an ideally-neutral calibration charge of 1.5
    cell-equivalents under 8-row SiMRA gives
    (30·4.5 + 270·0.5)/(8·30 + 270) = 0.529 V_DD.
Both checks are asserted in ``python/tests/test_physics.py`` and in the
Rust unit tests.
"""

# Cell capacitor, femtofarads (paper §II-C).
CC_FF = 30.0
# Bitline capacitance, femtofarads (paper §II-C).
CB_FF = 270.0
# Bitline precharge voltage, in V_DD units.
V_PRE = 0.5
# Rows opened by one SiMRA. MAJ5 = 5 operands + 3 calibration rows;
# MAJ3 = 3 operands + 3 calibration rows + 2 constant rows (0 and 1).
SIMRA_ROWS = 8

# Frac convergence ratio: one Frac pulls a cell charge toward neutral,
#   q <- 0.5 + (q - 0.5) * FRAC_R.
# FracDRAM (cited in §III-C) reports 6-10 Fracs to reach the neutral
# state; r = 0.65 gives 0.65**8 ~= 0.032 of the initial deviation left
# after 8 Fracs, consistent with that observation.
FRAC_R = 0.65

# Number of calibration rows reserved per subarray (paper §III-D: three
# rows, 0.6% of a 512-row subarray).
CALIB_ROWS = 3

# Offset lattice size: 2**CALIB_ROWS bit combinations per column.
LATTICE_LEVELS = 2 ** CALIB_ROWS


def bitline_voltage(total_charge, rows=SIMRA_ROWS):
    """Charge-sharing voltage (V_DD units) for `rows`-row SiMRA.

    ``total_charge`` is the summed per-cell charge (cell-equivalents,
    each in [0, 1]) over the opened rows of one column.
    """
    return (CC_FF * total_charge + CB_FF * V_PRE) / (rows * CC_FF + CB_FF)


def frac_charge(initial, n_fracs, r=FRAC_R):
    """Cell charge after ``n_fracs`` Frac operations from ``initial``."""
    return 0.5 + (initial - 0.5) * (r ** n_fracs)


# Default variation-model parameters (fitted once against Table I's
# baseline by `pudtune fit-model`; see EXPERIMENTS.md §Model-Fit).
# These are *runtime inputs* to the AOT graphs, not baked into HLO —
# they live here so both sides share the same defaults.
SIGMA_SA = 0.0284       # per-column SA threshold std-dev (core component)
TAIL_WEIGHT = 0.10      # heavy-tail mixture weight of the variation field
TAIL_RATIO = 2.5        # tail component std-dev ratio vs core
SIGMA_NOISE = 0.0020    # per-operation bitline/SA noise std-dev
BIAS_TAU = 0.02         # Algorithm-1 bias threshold (|bias| > tau -> step)
