//! 8-bit vector arithmetic *inside* the simulated DRAM.
//!
//! Runs real bit-serial majority circuits (MVDRAM full adders) through
//! the full RowCopy/Frac/SiMRA command flow on baseline and calibrated
//! subarrays, reporting end-result correctness and the command-level
//! cost — Table I's ADD/MUL workloads at functional fidelity.
//!
//! ```bash
//! cargo run --release --example arithmetic_workload
//! ```

use pudtune::config::system::Ddr4Timing;
use pudtune::dram::geometry::RowMap;
use pudtune::prelude::*;
use pudtune::pud::adder::ripple_adder;
use pudtune::pud::exec::run_circuit;
use pudtune::pud::multiplier::array_multiplier;
use pudtune::util::rng::Rng;

fn encode(vals: &[u64], bit: usize) -> Vec<u8> {
    vals.iter().map(|&v| ((v >> bit) & 1) as u8).collect()
}

fn decode(outputs: &[Vec<u8>], col: usize) -> u64 {
    outputs
        .iter()
        .enumerate()
        .fold(0u64, |acc, (bit, out)| acc | ((out[col] as u64) << bit))
}

fn main() {
    let cfg = DeviceConfig::default();
    let cols = 256;
    let seed = 0xA51u64;
    let grade = Ddr4Timing::ddr4_2133();
    // Identification + measurement go through the `CalibEngine` trait
    // (native backend: the 256-column demo geometry has no artifact);
    // the circuit runs below exercise the golden-model subarray itself.
    let engine = AnyEngine::native(cfg.clone());
    let mut sub = Subarray::with_geometry(&cfg, 128, cols, seed);
    let map = RowMap::standard(sub.rows);
    let mut rng = Rng::new(42);

    let a: Vec<u64> = (0..cols).map(|_| rng.below(256)).collect();
    let b: Vec<u64> = (0..cols).map(|_| rng.below(256)).collect();

    let tune = FracConfig::pudtune([2, 1, 0]);
    let base = FracConfig::baseline(3);
    let calib = engine
        .calibrate_one(&CalibRequest::from_subarray(&sub, seed, tune, CalibParams::paper()))
        .expect("running Algorithm 1");
    let base_cal = base.uncalibrated(&cfg, cols);

    // ---- 8-bit vector ADD (one add per column, SIMD across columns).
    let add = ripple_adder(8);
    let mut inputs = Vec::new();
    for bit in 0..8 {
        inputs.push(encode(&a, bit));
    }
    for bit in 0..8 {
        inputs.push(encode(&b, bit));
    }
    println!("8-bit vector ADD over {cols} columns:");
    for (label, fc, cal) in [("baseline", &base, &base_cal), ("PUDTune ", &tune, &calib)] {
        let run = run_circuit(&mut sub, &map, cal, fc, &grade, &add, &inputs);
        let ok = (0..cols)
            .filter(|&c| decode(&run.outputs, c) == a[c] + b[c])
            .count();
        println!(
            "  {label}: {ok}/{cols} columns correct ({:.1}%), {:.1} us of DRAM commands, {} peak scratch rows",
            100.0 * ok as f64 / cols as f64,
            run.elapsed_ns / 1000.0,
            run.peak_rows
        );
    }

    // ---- 4-bit vector MUL (array multiplier; 8-bit products).
    let mul = array_multiplier(4);
    let a4: Vec<u64> = a.iter().map(|&x| x & 15).collect();
    let b4: Vec<u64> = b.iter().map(|&x| x & 15).collect();
    let mut inputs = Vec::new();
    for bit in 0..4 {
        inputs.push(encode(&a4, bit));
    }
    for bit in 0..4 {
        inputs.push(encode(&b4, bit));
    }
    println!("\n4-bit vector MUL over {cols} columns:");
    for (label, fc, cal) in [("baseline", &base, &base_cal), ("PUDTune ", &tune, &calib)] {
        let run = run_circuit(&mut sub, &map, cal, fc, &grade, &mul, &inputs);
        let ok = (0..cols)
            .filter(|&c| decode(&run.outputs, c) == a4[c] * b4[c])
            .count();
        println!(
            "  {label}: {ok}/{cols} columns correct ({:.1}%), {:.1} us of DRAM commands",
            100.0 * ok as f64 / cols as f64,
            run.elapsed_ns / 1000.0
        );
    }

    // ---- Projected system throughput for the paper's geometry: four
    // batteries as one batched ECR call.
    let tput = ThroughputModel::new(&SystemConfig::paper());
    let reqs = vec![
        EcrRequest::from_subarray(&sub, seed, calib.clone(), 5, 8192),
        EcrRequest::from_subarray(&sub, seed, calib.clone(), 3, 8192),
        EcrRequest::from_subarray(&sub, seed, base_cal.clone(), 5, 8192),
        EcrRequest::from_subarray(&sub, seed, base_cal.clone(), 3, 8192),
    ];
    let mut reports = engine.measure_ecr_batch(&reqs).expect("ECR batch");
    let e3b = reports.pop().unwrap();
    let e5b = reports.pop().unwrap();
    let e3t = reports.pop().unwrap();
    let e5t = reports.pop().unwrap();
    let addc = pudtune::pud::adder::add8_cost();
    let mulc = pudtune::pud::multiplier::mul8_cost();
    let rb = tput.report(&base, e5b.ecr(), e5b.intersect(&e3b).ecr(), &addc, &mulc);
    let rt = tput.report(&tune, e5t.ecr(), e5t.intersect(&e3t).ecr(), &addc, &mulc);
    println!("\nprojected 4ch x 16-bank x 65,536-col throughput (Eq. 1):");
    println!(
        "  ADD: {} -> {} ({:.2}x; paper 1.88x)",
        pudtune::util::table::fmt_ops(rb.add8_ops),
        pudtune::util::table::fmt_ops(rt.add8_ops),
        rt.add8_ops / rb.add8_ops
    );
    println!(
        "  MUL: {} -> {} ({:.2}x; paper 1.89x)",
        pudtune::util::table::fmt_ops(rb.mul8_ops),
        pudtune::util::table::fmt_ops(rt.mul8_ops),
        rt.mul8_ops / rb.mul8_ops
    );
}
