//! 8-bit vector arithmetic *inside* the simulated DRAM, served through
//! the unified workload API.
//!
//! Compiles real workloads (`PudOp::Add`/`PudOp::Mul` →
//! `WorkloadPlan`) once and executes them through the batch-first
//! `ComputeEngine` trait on baseline and calibrated subarrays, with
//! each configuration's arithmetic-usable (MAJ5 ∧ MAJ3 error-free)
//! column mask restricting which outputs are trusted — Table I's
//! ADD/MUL workloads at functional fidelity, plus the Eq. 1 effective
//! throughput both masks project.
//!
//! ```bash
//! cargo run --release --example arithmetic_workload
//! ```

use pudtune::calib::engine::measure_arith_batteries;
use pudtune::prelude::*;
use std::sync::Arc;

#[path = "common.rs"]
mod common;

fn main() -> anyhow::Result<()> {
    let cfg = DeviceConfig::default();
    let cols = 256;
    let seed = 0xA51u64;
    // Identification + measurement + execution all go through the
    // engine traits (native backend: the 256-column demo geometry has
    // no AOT artifact).
    let engine = AnyEngine::native(cfg.clone());
    let sub = Subarray::with_geometry(&cfg, 128, cols, seed);
    let bank = ColumnBank::from_subarray(&sub, seed);
    let setup = common::calibrated_setup(&engine, &cfg, &bank)?;
    let mut rng = Rng::new(42);

    // One batched ECR phase: (base, tune) x (MAJ5, MAJ3) batteries.
    let batteries =
        measure_arith_batteries(&engine, &sub, seed, &[&setup.base_cal, &setup.calib], 8192)?;
    let base_arith = batteries[0].arith();
    let tune_arith = batteries[1].arith();
    let tput = ThroughputModel::new(&SystemConfig::paper());

    for (title, op) in [
        ("8-bit vector ADD", PudOp::Add { width: 8 }),
        ("4-bit vector MUL", PudOp::Mul { width: 4 }),
    ] {
        let plan = Arc::new(WorkloadPlan::compile(op).map_err(anyhow::Error::from)?);
        let width = plan.op.operand_width();
        let a: Vec<u64> = (0..cols).map(|_| rng.below(1 << width)).collect();
        let b: Vec<u64> = (0..cols).map(|_| rng.below(1 << width)).collect();
        println!("{title} over {cols} columns ({}):", plan.op.label());
        for (label, fc, cal, battery) in [
            ("baseline", &setup.base, &setup.base_cal, &base_arith),
            ("PUDTune ", &setup.tune, &setup.calib, &tune_arith),
        ] {
            let req = ComputeRequest::from_subarray(
                &sub,
                seed,
                plan.clone(),
                cal.clone(),
                vec![a.clone(), b.clone()],
            )
            .with_mask(battery.error_free_mask());
            let golden = req.golden_outputs().map_err(anyhow::Error::from)?;
            let res = engine.execute_one(&req)?;
            let all_ok = res.outputs.iter().zip(&golden).filter(|(o, g)| o == g).count();
            let masked_ok = res.golden_correct(&golden);
            println!(
                "  {label}: {all_ok}/{cols} columns correct ({:.1}%), \
                 {masked_ok}/{} on the error-free mask, {:.1} us of DRAM commands, \
                 effective {}",
                100.0 * all_ok as f64 / cols as f64,
                res.active_cols(),
                res.elapsed_ns / 1000.0,
                pudtune::util::table::fmt_ops(tput.workload_ops(
                    &plan.cost,
                    fc,
                    res.active_cols() as f64 / cols as f64
                ))
            );
        }
        println!();
    }

    // ---- Projected system throughput for the paper's geometry
    // (Eq. 1 over the full 4ch x 16-bank x 65,536-col system).
    let addc = pudtune::pud::adder::add8_cost();
    let mulc = pudtune::pud::multiplier::mul8_cost();
    let rb = tput.report(
        &setup.base,
        batteries[0].maj5.ecr(),
        base_arith.ecr(),
        &addc,
        &mulc,
    );
    let rt = tput.report(
        &setup.tune,
        batteries[1].maj5.ecr(),
        tune_arith.ecr(),
        &addc,
        &mulc,
    );
    println!("projected 4ch x 16-bank x 65,536-col throughput (Eq. 1):");
    println!(
        "  ADD: {} -> {} ({:.2}x; paper 1.88x)",
        pudtune::util::table::fmt_ops(rb.add8_ops),
        pudtune::util::table::fmt_ops(rt.add8_ops),
        rt.add8_ops / rb.add8_ops
    );
    println!(
        "  MUL: {} -> {} ({:.2}x; paper 1.89x)",
        pudtune::util::table::fmt_ops(rb.mul8_ops),
        pudtune::util::table::fmt_ops(rt.mul8_ops),
        rt.mul8_ops / rb.mul8_ops
    );
    Ok(())
}
