//! Thermal + aging reliability study (the Fig. 6 campaign).
//!
//! Calibrates at nominal temperature, then sweeps the die from 40 °C
//! to 100 °C and ages the device for a simulated week, counting *new*
//! error-prone columns relative to calibration time.
//!
//! ```bash
//! cargo run --release --example thermal_study
//! ```

use pudtune::prelude::*;

fn main() {
    let cfg = DeviceConfig::default();
    let mut sys = SystemConfig::small();
    sys.cols = 8192;
    let mut engine = NativeEngine::new(cfg.clone());
    let mut sub = Subarray::new(&cfg, &sys, 0x7E3);
    let tune = FracConfig::pudtune([2, 1, 0]);

    println!("calibrating at {:.0} C...", cfg.t_cal);
    let calib = engine.calibrate(&mut sub, &tune, &CalibParams::paper());
    let reference = engine.measure_ecr(&mut sub, &calib, 5, 32768); // burn-in depth
    println!(
        "reference ECR: {:.2}% ({} columns)\n",
        reference.ecr() * 100.0,
        reference.cols()
    );

    println!("temperature sweep (paper Fig. 6a: new ECR stays below 0.14%):");
    println!("  {:>6}  {:>8}  {:>8}", "T (C)", "ECR", "new ECR");
    for t in [40.0, 50.0, 60.0, 70.0, 80.0, 90.0, 100.0] {
        sub.set_temperature(t);
        let rep = engine.measure_ecr(&mut sub, &calib, 5, 8192);
        println!(
            "  {:>6.0}  {:>7.2}%  {:>7.3}%",
            t,
            rep.ecr() * 100.0,
            rep.new_ecr_vs(&reference) * 100.0
        );
    }
    sub.set_temperature(cfg.t_cal);

    println!("\naging sweep (paper Fig. 6b: new ECR stays below 0.27% over a week):");
    println!("  {:>6}  {:>8}  {:>8}", "day", "ECR", "new ECR");
    for day in 0..=7 {
        if day > 0 {
            sub.advance_time(24.0);
        }
        let rep = engine.measure_ecr(&mut sub, &calib, 5, 8192);
        println!(
            "  {:>6}  {:>7.2}%  {:>7.3}%",
            day,
            rep.ecr() * 100.0,
            rep.new_ecr_vs(&reference) * 100.0
        );
    }

    println!("\nre-calibration after the campaign restores the reference ECR:");
    let recal = engine.calibrate(&mut sub, &tune, &CalibParams::paper());
    let rep = engine.measure_ecr(&mut sub, &recal, 5, 8192);
    println!("  post-recalibration ECR: {:.2}%", rep.ecr() * 100.0);
}
