//! Thermal + aging reliability study (the Fig. 6 campaign).
//!
//! Calibrates at nominal temperature, then sweeps the die from 40 °C
//! to 100 °C and ages the device for a simulated week, counting *new*
//! error-prone columns relative to calibration time. The sweeps are
//! expressed as `EcrRequest` batches — each request snapshots the
//! sense-amp state under its own environment — and submitted to the
//! engine in single batched calls.
//!
//! ```bash
//! cargo run --release --example thermal_study
//! ```

use pudtune::prelude::*;

fn main() {
    let cfg = DeviceConfig::default();
    let mut sys = SystemConfig::small();
    sys.cols = 8192;
    let seed = 0x7E3u64;
    // Native backend: the campaign needs arbitrary geometry and a
    // caller-chosen burn-in depth, which AOT artifacts fix at build
    // time. The call sites stay backend-agnostic via the trait.
    let engine = AnyEngine::native(cfg.clone());
    let mut sub = Subarray::new(&cfg, &sys, seed);
    let tune = FracConfig::pudtune([2, 1, 0]);

    println!("calibrating at {:.0} C...", cfg.t_cal);
    let calib = engine
        .calibrate_one(&CalibRequest::from_subarray(&sub, seed, tune, CalibParams::paper()))
        .expect("running Algorithm 1");
    let reference = engine
        .measure_ecr_one(&EcrRequest::from_subarray(&sub, seed, calib.clone(), 5, 32768))
        .expect("burn-in reference battery");
    println!(
        "reference ECR: {:.2}% ({} columns)\n",
        reference.ecr() * 100.0,
        reference.cols()
    );

    // Temperature sweep: seven independent measurements of one device,
    // one batched call.
    let temps = [40.0, 50.0, 60.0, 70.0, 80.0, 90.0, 100.0];
    let temp_reqs: Vec<EcrRequest> = temps
        .iter()
        .map(|&t| {
            let mut bank = ColumnBank::from_subarray(&sub, seed);
            bank.env.temp_c = t;
            EcrRequest::new(bank, calib.clone(), 5, 8192)
        })
        .collect();
    let temp_reports = engine.measure_ecr_batch(&temp_reqs).expect("temperature batch");
    println!("temperature sweep (paper Fig. 6a: new ECR stays below 0.14%):");
    println!("  {:>6}  {:>8}  {:>8}", "T (C)", "ECR", "new ECR");
    for (&t, rep) in temps.iter().zip(&temp_reports) {
        println!(
            "  {:>6.0}  {:>7.2}%  {:>7.3}%",
            t,
            rep.ecr() * 100.0,
            rep.new_ecr_vs(&reference) * 100.0
        );
    }

    // Aging sweep: the drift random walk is cumulative, so the device
    // advances sequentially — each checkpoint's sense-amp state is
    // snapshotted into a request and the battery runs as one batch.
    let mut age_reqs = Vec::new();
    for day in 0..=7 {
        if day > 0 {
            sub.advance_time(24.0);
        }
        age_reqs.push(EcrRequest::from_subarray(&sub, seed, calib.clone(), 5, 8192));
    }
    let age_reports = engine.measure_ecr_batch(&age_reqs).expect("aging batch");
    println!("\naging sweep (paper Fig. 6b: new ECR stays below 0.27% over a week):");
    println!("  {:>6}  {:>8}  {:>8}", "day", "ECR", "new ECR");
    for (day, rep) in age_reports.iter().enumerate() {
        println!(
            "  {:>6}  {:>7.2}%  {:>7.3}%",
            day,
            rep.ecr() * 100.0,
            rep.new_ecr_vs(&reference) * 100.0
        );
    }

    println!("\nre-calibration after the campaign restores the reference ECR:");
    let recal = engine
        .calibrate_one(&CalibRequest::from_subarray(&sub, seed, tune, CalibParams::paper()))
        .expect("re-calibration");
    let rep = engine
        .measure_ecr_one(&EcrRequest::from_subarray(&sub, seed, recal, 5, 8192))
        .expect("post-recalibration battery");
    println!("  post-recalibration ECR: {:.2}%", rep.ecr() * 100.0);
}
