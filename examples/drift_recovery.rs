//! Drift-aware serving: a temperature-excursion scenario end to end.
//!
//! Replays the full calibration lifecycle the recalibration service
//! closes: calibrate a small device and persist the store ("first
//! boot"), rehydrate it into a fresh service ("reboot") where a cheap
//! spot check accepts every entry, serve workload batches, then hit
//! the die with a temperature excursion — serving degrades but never
//! stalls, the drift monitor schedules background recalibration, and
//! the repaired calibrations restore the error-free column count at
//! the hot operating point. Finally, the recalibration command traffic
//! is interleaved into the serving trace under a deadline, showing the
//! bank-level cost of the repair is hidden in serving slack.
//!
//! ```bash
//! cargo run --release --example drift_recovery
//! ```

use pudtune::controller::command;
use pudtune::controller::scheduler::{Scheduler, TraceClass};
use pudtune::prelude::*;

fn mean_ecr(outcomes: &[ServeOutcome]) -> f64 {
    let ecrs: Vec<f64> = outcomes
        .iter()
        .filter_map(|o| o.report.as_ref().ok().map(|r| r.ecr()))
        .collect();
    ecrs.iter().sum::<f64>() / ecrs.len().max(1) as f64
}

fn main() {
    // Exaggerated common-mode tempco so the excursion visibly breaks
    // the nominal calibration (the fitted differential-SA model keeps
    // excursions benign, which is exactly Fig. 6a's point).
    let cfg = DeviceConfig { tempco: 5.0e-4, tempco_jitter: 2.0e-5, ..DeviceConfig::default() };
    let (banks, cols, device_seed) = (4usize, 2048usize, 0xD21F7u64);
    let svc_cfg = ServiceConfig { serve_samples: 4096, ..ServiceConfig::default() };
    let make_service = || {
        let s =
            RecalibService::new(cfg.clone(), svc_cfg, NativeEngine::new(cfg.clone())).unwrap();
        for b in 0..banks {
            s.register(SubarrayId::new(0, b, 0), 32, cols, device_seed);
        }
        s
    };

    // ---- First boot: calibrate from scratch and persist. ----
    println!("first boot: calibrating {banks} banks x {cols} columns...");
    let first = make_service();
    first.run_pending(usize::MAX);
    let nominal = mean_ecr(&first.serve());
    println!("  nominal serving ECR {:.2}%", nominal * 100.0);
    let path = std::env::temp_dir().join("pudtune_drift_recovery_store.json");
    first.snapshot_store().save_file(&path).unwrap();
    println!("  store persisted to {}", path.display());

    // ---- Reboot: rehydrate + spot-check instead of recalibrating. ----
    println!("\nreboot: rehydrating from the store...");
    let store = CalibStore::load_file(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let svc = make_service();
    for (id, outcome) in svc.load_store(&store) {
        match outcome {
            LoadOutcome::Accepted { spot_ecr } => {
                println!("  bank {}: accepted (spot ECR {:.2}%)", id.bank, spot_ecr * 100.0)
            }
            other => println!("  bank {}: {other:?}", id.bank),
        }
    }

    // ---- Steady serving at nominal temperature. ----
    let accepted = mean_ecr(&svc.serve());
    println!("\nserving at nominal: mean ECR {:.2}%", accepted * 100.0);

    // ---- Temperature excursion. ----
    println!("\ntemperature excursion: 45 C -> 85 C on every bank");
    for id in svc.ids() {
        svc.set_temperature(id, 85.0);
    }
    let stale = mean_ecr(&svc.serve());
    println!("  stale serving ECR {:.2}% (still serving, no stall)", stale * 100.0);
    for (id, signal) in svc.poll_drift() {
        println!("  drift detected on bank {}: {signal}", id.bank);
    }

    // ---- Background repair. ----
    let repaired_n = svc.run_pending(usize::MAX).len();
    let repaired = mean_ecr(&svc.serve());
    println!(
        "  recalibrated {repaired_n} banks in the background: ECR {:.2}% -> {:.2}%",
        stale * 100.0,
        repaired * 100.0
    );
    assert!(repaired < stale / 2.0, "repair must restore the error-free columns");

    // ---- Interleave the repair traffic under serving deadlines. ----
    // One bank's recalibration rewrites its three calibration rows and
    // re-fracs them; issue that command traffic only into the slack
    // between serving batches (here: MAJ5 primitives every ~500 ns).
    println!("\ninterleaving recalibration commands into serving slack:");
    let sys = SystemConfig::small();
    let mut sched = Scheduler::new(sys.timing.clone());
    let close = sys.timing.t_ras + sys.timing.t_rp;
    let mut recalib_cmds: Vec<(Vec<_>, f64)> = Vec::new();
    for row in [8usize, 9, 10] {
        recalib_cmds.push((command::row_copy_seq(16 + row, row), close));
        for _ in 0..2 {
            recalib_cmds.push((command::frac_seq(row), sys.timing.t_rp));
        }
    }
    let mut pending = recalib_cmds.into_iter();
    let mut queued = pending.next();
    let serve_gap = sys.timing.to_clocks(500.0);
    let mut serve_end = 0;
    for _ in 0..8 {
        serve_end = sched.issue(&command::simra_seq(0, 7), close);
        let deadline = serve_end + serve_gap;
        while let Some((seq, cl)) = queued.take() {
            if sched.try_issue_background(&seq, cl, deadline).is_none() {
                // Would push past the next serving slot: defer it.
                queued = Some((seq, cl));
                break;
            }
            queued = pending.next();
        }
        if queued.is_none() {
            break;
        }
    }
    println!(
        "  serve busy {} cycles, recalib busy {} cycles, {} deferrals, makespan {:.0} ns",
        sched.class_cycles(TraceClass::Serve),
        sched.class_cycles(TraceClass::Recalib),
        sched.deferred_background(),
        sched.elapsed_ns()
    );
    assert!(serve_end > 0);
    println!("\nlifecycle closed: persist -> load -> validate -> drift -> recalibrate.");
    println!("\nservice metrics:\n{}", svc.metrics.render());
}
