//! Device-scale calibration with non-volatile persistence.
//!
//! Calibrates every bank of a (reduced-geometry) device, stores the
//! identified bit patterns to a JSON calibration store, reloads the
//! store as a fresh process would after reboot, and verifies the
//! reloaded data still fixes the columns (paper §III-A).
//!
//! ```bash
//! cargo run --release --example calibrate_device
//! ```

use pudtune::calib::store::CalibStore;
use pudtune::dram::geometry::SubarrayId;
use pudtune::prelude::*;
use pudtune::util::rng::derive_seed;
use std::time::Instant;

fn main() {
    let cfg = DeviceConfig::default();
    let mut sys = SystemConfig::default();
    sys.channels = 1;
    sys.banks = 8;
    sys.cols = 2048;
    let device_seed = 0xD31C3;
    let tune = FracConfig::pudtune([2, 1, 0]);
    let params = CalibParams::paper();
    let mut engine = NativeEngine::new(cfg.clone());
    let mut store = CalibStore::default();

    println!(
        "calibrating {} banks x {} columns ({} iterations x {} samples each)...",
        sys.banks, sys.cols, params.iterations, params.samples
    );
    let t0 = Instant::now();
    let mut before = Vec::new();
    for b in 0..sys.banks {
        let id = SubarrayId::new(0, b, 0);
        let seed = derive_seed(device_seed, &id.seed_path());
        let mut sub = Subarray::new(&cfg, &sys, seed);
        let base = FracConfig::baseline(3).uncalibrated(&cfg, sub.cols);
        let ecr0 = engine.measure_ecr(&mut sub, &base, 5, 4096).ecr();
        let calib = engine.calibrate(&mut sub, &tune, &params);
        let ecr1 = engine.measure_ecr(&mut sub, &calib, 5, 4096).ecr();
        println!("  bank {b}: ECR {:5.1}% -> {:4.1}%", ecr0 * 100.0, ecr1 * 100.0);
        store.insert(id, &calib);
        before.push(ecr1);
    }
    let per_sub = t0.elapsed().as_secs_f64() / sys.banks as f64;
    println!(
        "calibration took {:.2}s/subarray (paper: ~60s/subarray on real DRAM Bender hardware)",
        per_sub
    );

    // Persist, reload, verify — the reboot story.
    let path = std::env::temp_dir().join("pudtune_device_store.json");
    store.save_file(&path).unwrap();
    let bytes = std::fs::metadata(&path).unwrap().len();
    println!(
        "\nstore written: {} ({} banks, {} bytes, RLE-compressed levels)",
        path.display(),
        sys.banks,
        bytes
    );

    let reloaded = CalibStore::load_file(&path).unwrap();
    println!("reloaded; verifying against a fresh device instance...");
    for b in 0..sys.banks {
        let id = SubarrayId::new(0, b, 0);
        let seed = derive_seed(device_seed, &id.seed_path());
        // Fresh subarray = same manufactured device after a reboot.
        let mut sub = Subarray::new(&cfg, &sys, seed);
        let calib = reloaded.load(id, &cfg).expect("bank in store");
        let ecr = engine.measure_ecr(&mut sub, &calib, 5, 4096).ecr();
        assert!(
            (ecr - before[b]).abs() < 0.02,
            "bank {b}: reloaded ECR {ecr} deviates from {}",
            before[b]
        );
        println!("  bank {b}: reloaded ECR {:4.1}% (matches)", ecr * 100.0);
    }
    println!("\nreboot persistence verified: stored bit patterns reproduce the calibration.");
    let _ = std::fs::remove_file(&path);
}
