//! Device-scale calibration with non-volatile persistence.
//!
//! Calibrates every bank of a (reduced-geometry) device in ONE batched
//! `CalibEngine` call — the engine fans the banks across the worker
//! pool (native) or stacks them into fused executable calls (PJRT) —
//! stores the identified bit patterns to a JSON calibration store,
//! reloads the store as a fresh process would after reboot, and
//! verifies the reloaded data still fixes the columns (paper §III-A).
//!
//! ```bash
//! cargo run --release --example calibrate_device
//! ```

use pudtune::dram::geometry::SubarrayId;
use pudtune::prelude::*;
use pudtune::util::rng::derive_seed;
use std::time::Instant;

fn main() {
    let cfg = DeviceConfig::default();
    let sys = SystemConfig { channels: 1, banks: 8, cols: 2048, ..SystemConfig::default() };
    let device_seed = 0xD31C3;
    let tune = FracConfig::pudtune([2, 1, 0]);
    let params = CalibParams::paper();
    // 8 banks x 2,048 columns stack to exactly the standard
    // 16,384-column artifact shape, so with `make artifacts` present
    // the whole device fuses into one executable call per step; the
    // native fallback fans the same batch across the worker pool.
    let engine = AnyEngine::auto(cfg.clone());
    let mut store = CalibStore::default();

    // One request per bank; per-bank seeds follow the device geometry.
    let ids: Vec<SubarrayId> = (0..sys.banks).map(|b| SubarrayId::new(0, b, 0)).collect();
    let seeds: Vec<u64> = ids
        .iter()
        .map(|id| derive_seed(device_seed, &id.seed_path()))
        .collect();
    let batch = BankBatch::with_seeds(cfg.clone(), sys.cols, seeds);

    println!(
        "calibrating {} banks x {} columns ({} iterations x {} samples each) in one batched call...",
        sys.banks, sys.cols, params.iterations, params.samples
    );
    let t0 = Instant::now();
    // Materialise the variation fields once; every request snapshots
    // from this one set of banks.
    let banks = batch.banks();
    let base_cal = FracConfig::baseline(3).uncalibrated(&cfg, sys.cols);
    let base_reqs: Vec<EcrRequest> = banks
        .iter()
        .map(|bank| EcrRequest::new(bank.clone(), base_cal.clone(), 5, 4096))
        .collect();
    let before_reports = engine.measure_ecr_batch(&base_reqs).expect("baseline ECR batch");
    let calibs = engine
        .calibrate_batch(&BankBatch::calib_requests_for(&banks, tune, params))
        .expect("batched Algorithm 1");
    let after_reports = engine
        .measure_ecr_batch(&BankBatch::ecr_requests_for(&banks, &calibs, 5, 4096))
        .expect("calibrated ECR batch");
    let mut before = Vec::new();
    for (b, (id, calib)) in ids.iter().zip(&calibs).enumerate() {
        let (ecr0, ecr1) = (before_reports[b].ecr(), after_reports[b].ecr());
        println!("  bank {b}: ECR {:5.1}% -> {:4.1}%", ecr0 * 100.0, ecr1 * 100.0);
        store.insert(*id, calib);
        before.push(ecr1);
    }
    let per_sub = t0.elapsed().as_secs_f64() / sys.banks as f64;
    println!(
        "batched calibration took {:.2}s/subarray amortised (paper: ~60s/subarray on real DRAM Bender hardware)",
        per_sub
    );

    // Persist, reload, verify — the reboot story.
    let path = std::env::temp_dir().join("pudtune_device_store.json");
    store.save_file(&path).unwrap();
    let bytes = std::fs::metadata(&path).unwrap().len();
    println!(
        "\nstore written: {} ({} banks, {} bytes, RLE-compressed levels)",
        path.display(),
        sys.banks,
        bytes
    );

    let reloaded = CalibStore::load_file(&path).unwrap();
    println!("reloaded; verifying against a fresh device instance...");
    // Fresh banks = the same manufactured device after a reboot; one
    // more batched measurement under the reloaded calibration data.
    let verify_reqs: Vec<EcrRequest> = ids
        .iter()
        .zip(&banks)
        .map(|(&id, bank)| {
            let calib = reloaded
                .load_expecting(id, &cfg, sys.cols)
                .expect("compatible store")
                .expect("bank in store");
            EcrRequest::new(bank.clone(), calib, 5, 4096)
        })
        .collect();
    let verify_reports = engine.measure_ecr_batch(&verify_reqs).expect("verification ECR batch");
    for (b, rep) in verify_reports.iter().enumerate() {
        let ecr = rep.ecr();
        assert!(
            (ecr - before[b]).abs() < 0.02,
            "bank {b}: reloaded ECR {ecr} deviates from {}",
            before[b]
        );
        println!("  bank {b}: reloaded ECR {:4.1}% (matches)", ecr * 100.0);
    }
    println!("\nreboot persistence verified: stored bit patterns reproduce the calibration.");
    let _ = std::fs::remove_file(&path);
}
