//! END-TO-END driver: the full three-layer stack serving an
//! MVDRAM-style int8 GEMV workload.
//!
//! Everything on the request path is Rust + PJRT — Python authored the
//! graphs once at build time:
//!
//! 1. the L3 coordinator calibrates a bank through the AOT
//!    `maj5_step_*` graphs (L2 JAX embedding the L1 Pallas kernels),
//!    one executable call per Algorithm-1 iteration;
//! 2. mass ECR measurement runs through the scanned `maj*_ecr_*`
//!    graphs (the paper's 8,192-random-input battery);
//! 3. a stream of GEMV requests is dynamically batched
//!    (`coordinator::batcher`) and evaluated through the `pud_gemv`
//!    graph with per-output bit-flip probabilities derived from the
//!    measured residual column error rates — translating ECR into
//!    end-task accuracy, calibrated vs uncalibrated;
//! 4. Eq. 1 projects the DRAM-side GEMV throughput for both configs.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-End.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_gemv
//! ```

use anyhow::Result;
use pudtune::calib::engine::{AnyEngine, CalibEngine, EcrRequest};
use pudtune::config::device::DeviceConfig;
use pudtune::config::system::SystemConfig;
use pudtune::coordinator::batcher::Batcher;
use pudtune::coordinator::engine::ColumnBank;
use pudtune::prelude::ThroughputModel;
use pudtune::runtime::{buffers, Runtime};
use pudtune::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

#[path = "common.rs"]
mod common;

const M: usize = 64; // GEMV output rows
const K: usize = 256; // GEMV inner dimension
const COLS: usize = 1024; // calibrated bank columns
const REQUESTS: usize = 64;
const BATCH: usize = 8;

fn main() -> Result<()> {
    let rt = Arc::new(Runtime::open_default()?);
    println!("PJRT platform: {}", rt.platform());
    let cfg = DeviceConfig::default();
    let engine = AnyEngine::pjrt(rt.clone(), cfg.clone());
    let bank = ColumnBank::new(&cfg, COLS, 0x6E37);

    // ---- 1. Calibrate through the AOT stack (L3 -> L2 -> L1), via
    // the shared workload bring-up over the backend-agnostic
    // `CalibEngine` trait.
    let t0 = Instant::now();
    let setup = common::calibrated_setup(&engine, &cfg, &bank)?;
    let (base, tune) = (setup.base, setup.tune);
    println!(
        "calibrated {COLS} columns in {:.2}s ({} PJRT step calls)",
        t0.elapsed().as_secs_f64(),
        engine.metrics().expect("pjrt backend").counter("pjrt.step.calls")
    );

    // ---- 2. Mass ECR via the scanned graphs (one batched call).
    let mut reports = engine.measure_ecr_batch(&[
        EcrRequest::new(bank.clone(), setup.base_cal, 5, 8192).with_seed(0xE),
        EcrRequest::new(bank.clone(), setup.calib, 5, 8192).with_seed(0xE),
    ])?;
    let ecr_tune = reports.pop().unwrap();
    let ecr_base = reports.pop().unwrap();
    println!(
        "MAJ5 ECR: baseline {:.1}% -> PUDTune {:.1}%",
        ecr_base.ecr() * 100.0,
        ecr_tune.ecr() * 100.0
    );

    // Per-output flip probability: an output is wrong if any of the
    // K/COLS... map each GEMV output lane onto a column group; a lane
    // inherits the error rate of its columns (residual error count /
    // samples, aggregated).
    let flip_p = |rep: &pudtune::analysis::ecr::EcrReport| -> Vec<f32> {
        let per_lane = COLS / M;
        (0..M)
            .map(|lane| {
                let errs: u32 = (0..per_lane)
                    .map(|i| rep.error_counts[lane * per_lane + i])
                    .sum();
                (errs as f64 / (rep.samples as f64 * per_lane as f64)).min(1.0) as f32
            })
            .collect()
    };
    let flips_base = flip_p(&ecr_base);
    let flips_tune = flip_p(&ecr_tune);

    // ---- 3. Serve batched GEMV requests through the pud_gemv graph.
    let gemv = rt.load("pud_gemv_64x256")?;
    let mut rng = Rng::new(0x9E37);
    let w: Vec<f32> = (0..M * K).map(|_| rng.range(-128, 128) as f32).collect();
    let w_lit = buffers::f32_array(&w, &[M as i64, K as i64])?;

    let mut batcher: Batcher<Vec<f32>> = Batcher::new(BATCH);
    let mut latencies = Vec::new();
    let mut exact = [0usize; 2];
    let mut served = 0usize;
    let mut l2err = [0f64; 2];
    let t_serve = Instant::now();
    let mut process = |batch: Vec<Vec<f32>>,
                       latencies: &mut Vec<f64>,
                       exact: &mut [usize; 2],
                       l2err: &mut [f64; 2],
                       served: &mut usize|
     -> Result<()> {
        let tb = Instant::now();
        for x in batch {
            let x_lit = buffers::f32_vec(&x);
            for (which, flips) in [(0usize, &flips_base), (1usize, &flips_tune)] {
                let out = gemv.run(&[
                    w_lit.clone(),
                    x_lit.clone(),
                    buffers::f32_vec(flips),
                    buffers::u32_scalar(*served as u32),
                ])?;
                let ideal = buffers::to_f32_vec(&out[0])?;
                let faulty = buffers::to_f32_vec(&out[1])?;
                if ideal == faulty {
                    exact[which] += 1;
                }
                l2err[which] += ideal
                    .iter()
                    .zip(&faulty)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
            }
            *served += 1;
        }
        latencies.push(tb.elapsed().as_secs_f64());
        Ok(())
    };
    for _ in 0..REQUESTS {
        let x: Vec<f32> = (0..K).map(|_| rng.range(-128, 128) as f32).collect();
        if let Some(batch) = batcher.push(x) {
            process(batch, &mut latencies, &mut exact, &mut l2err, &mut served)?;
        }
    }
    if let Some(batch) = batcher.flush() {
        process(batch, &mut latencies, &mut exact, &mut l2err, &mut served)?;
    }
    let wall = t_serve.elapsed().as_secs_f64();
    println!(
        "\nserved {served} GEMV requests in {:.2}s ({:.1} req/s, {} batches, occupancy {:.0}%)",
        wall,
        served as f64 / wall,
        batcher.batches_emitted,
        batcher.mean_occupancy() * 100.0
    );
    println!(
        "end-task accuracy (exact outputs): baseline {}/{} | PUDTune {}/{}",
        exact[0], served, exact[1], served
    );
    println!(
        "mean L2 output error:              baseline {:8.1} | PUDTune {:8.1}",
        l2err[0] / served as f64,
        l2err[1] / served as f64
    );

    // ---- 4. Eq. 1 projection of DRAM-side GEMV throughput.
    let tput = ThroughputModel::new(&SystemConfig::paper());
    let mulc = pudtune::pud::multiplier::mul8_cost();
    let addc = pudtune::pud::adder::add8_cost();
    // One int8 GEMV row = K MACs; a MAC = 8-bit MUL + 16-bit ADD (~2x).
    let mac = pudtune::pud::graph::CircuitCost {
        maj3: mulc.maj3 + 2 * addc.maj3,
        maj5: mulc.maj5 + 2 * addc.maj5,
        not_ops: mulc.not_ops + 2 * addc.not_ops,
    };
    for (label, fc, rep) in [("baseline", &base, &ecr_base), ("PUDTune ", &tune, &ecr_tune)] {
        let cost = tput.circuit_cost_ns(&mac, fc);
        let macs = tput.ops_per_sec(&cost, 1.0 - rep.ecr());
        println!(
            "  {label}: {:.1} M MAC/s -> {:.0} GEMV(64x256)/s system-wide",
            macs / 1e6,
            macs / (M * K) as f64
        );
    }
    println!("\n{}", engine.metrics().expect("pjrt backend").render());
    Ok(())
}
