//! Shared workload bring-up for the examples: one calibration setup
//! (baseline + PUDTune configurations, Algorithm-1 identification
//! through the backend-agnostic `CalibEngine` trait) that
//! `quickstart`, `arithmetic_workload` and `e2e_gemv` all reuse
//! instead of duplicating subarray/calibration plumbing inline.
//!
//! Included via `#[path = "common.rs"] mod common;` — this file is not
//! itself a registered example.

use pudtune::prelude::*;

/// The calibration states a workload demo compares: the conventional
/// (baseline) configuration serving uniform neutral levels, and the
/// PUDTune configuration with per-column identified levels.
pub struct WorkloadSetup {
    /// Conventional MAJX configuration (paper Fig. 1a, B_{3,0,0}).
    pub base: FracConfig,
    /// PUDTune configuration (paper T_{2,1,0}).
    pub tune: FracConfig,
    /// Uniform neutral calibration for the baseline.
    pub base_cal: Calibration,
    /// Algorithm-1 identified per-column calibration.
    pub calib: Calibration,
}

/// Calibrate one bank for the standard baseline-vs-PUDTune comparison
/// (Algorithm 1 at the paper's §IV-A settings, via any backend).
pub fn calibrated_setup<E: CalibEngine>(
    engine: &E,
    cfg: &DeviceConfig,
    bank: &ColumnBank,
) -> anyhow::Result<WorkloadSetup> {
    let tune = FracConfig::pudtune([2, 1, 0]);
    let base = FracConfig::baseline(3);
    let calib =
        engine.calibrate_one(&CalibRequest::new(bank.clone(), tune, CalibParams::paper()))?;
    let base_cal = base.uncalibrated(cfg, bank.cols());
    Ok(WorkloadSetup { base, tune, base_cal, calib })
}
