//! Quickstart: calibrate one subarray and watch the error-prone
//! columns disappear — all through the backend-agnostic `CalibEngine`
//! trait.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pudtune::prelude::*;

fn main() {
    // A simulated DDR4 subarray: 1,024 columns with seeded
    // process-variation in the sense amplifiers.
    let cfg = DeviceConfig::default();
    let mut sys = SystemConfig::small();
    sys.cols = 1024;
    let seed = 7u64;
    let sub = Subarray::new(&cfg, &sys, seed);

    // Everything below is written against the `CalibEngine` trait; the
    // native backend is pinned here because this demo's 1,024-column
    // geometry has no AOT artifact (swap in `AnyEngine::auto` plus an
    // artifact-shaped geometry to run the same code on PJRT).
    let engine = AnyEngine::native(cfg.clone());
    println!("engine backend: {}\n", engine.backend());

    // The conventional MAJ5 implementation: one Frac'd neutral row plus
    // constant 0/1 rows (paper Fig. 1a, B_{3,0,0}).
    let baseline = FracConfig::baseline(3);
    let base_cal = baseline.uncalibrated(&cfg, sub.cols);
    let ecr_base = engine
        .measure_ecr_one(&EcrRequest::from_subarray(&sub, seed, base_cal, 5, 8192))
        .expect("measuring baseline ECR");
    println!(
        "baseline  {}: ECR {:5.1}%  ({} of {} columns error-prone)",
        baseline.label(),
        ecr_base.ecr() * 100.0,
        ecr_base.error_prone(),
        ecr_base.cols()
    );

    // PUDTune: identify per-column calibration data with Algorithm 1
    // (20 iterations x 512 random samples, the paper's settings), then
    // measure again.
    let tune = FracConfig::pudtune([2, 1, 0]);
    let calib = engine
        .calibrate_one(&CalibRequest::from_subarray(&sub, seed, tune, CalibParams::paper()))
        .expect("running Algorithm 1");
    let ecr_tune = engine
        .measure_ecr_one(&EcrRequest::from_subarray(&sub, seed, calib, 5, 8192))
        .expect("measuring calibrated ECR");
    println!(
        "PUDTune   {}: ECR {:5.1}%  ({} of {} columns error-prone)",
        tune.label(),
        ecr_tune.ecr() * 100.0,
        ecr_tune.error_prone(),
        ecr_tune.cols()
    );

    // Eq. 1: error-free columns / MAJ5 latency = throughput.
    let tput = ThroughputModel::new(&SystemConfig::paper());
    let ops_base = tput.ops_per_sec(&tput.majx(5, &baseline), 1.0 - ecr_base.ecr());
    let ops_tune = tput.ops_per_sec(&tput.majx(5, &tune), 1.0 - ecr_tune.ecr());
    println!(
        "\nprojected full-system MAJ5 throughput (4ch x 16 banks x 65,536 cols):"
    );
    println!("  baseline: {}", pudtune::util::table::fmt_ops(ops_base));
    println!("  PUDTune:  {}", pudtune::util::table::fmt_ops(ops_tune));
    println!("  gain:     {:.2}x (paper: 1.81x)", ops_tune / ops_base);
}
