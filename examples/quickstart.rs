//! Quickstart: calibrate one subarray, watch the error-prone columns
//! disappear, then serve a real workload through the compute path —
//! all through the backend-agnostic `CalibEngine`/`ComputeEngine`
//! traits.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pudtune::calib::engine::measure_arith_batteries;
use pudtune::prelude::*;
use std::sync::Arc;

#[path = "common.rs"]
mod common;

fn main() -> anyhow::Result<()> {
    // A simulated DDR4 subarray: 1,024 columns with seeded
    // process-variation in the sense amplifiers.
    let cfg = DeviceConfig::default();
    let mut sys = SystemConfig::small();
    sys.cols = 1024;
    let seed = 7u64;
    let sub = Subarray::new(&cfg, &sys, seed);

    // Everything below is written against the engine traits; the
    // native backend is pinned here because this demo's 1,024-column
    // geometry has no AOT artifact (swap in `AnyEngine::auto` plus an
    // artifact-shaped geometry to run the same code on PJRT).
    let engine = AnyEngine::native(cfg.clone());
    println!("engine backend: {}\n", engine.backend());

    // Identify PUDTune calibration data with Algorithm 1 (20
    // iterations x 512 random samples, the paper's settings); the
    // baseline keeps its uniform neutral levels.
    let bank = ColumnBank::from_subarray(&sub, seed);
    let setup = common::calibrated_setup(&engine, &cfg, &bank)?;

    // Measure both configurations' MAJ5 + MAJ3 batteries (paper
    // §IV-A: 8,192 random patterns) in one batched call; the MAJ5
    // report carries the headline ECR, the intersection is the
    // arithmetic-usable column mask.
    let batteries =
        measure_arith_batteries(&engine, &sub, seed, &[&setup.base_cal, &setup.calib], 8192)?;
    let (ecr_base, ecr_tune) = (&batteries[0].maj5, &batteries[1].maj5);
    for (label, fc, rep) in [
        ("baseline ", &setup.base, ecr_base),
        ("PUDTune  ", &setup.tune, ecr_tune),
    ] {
        println!(
            "{label}{}: ECR {:5.1}%  ({} of {} columns error-prone)",
            fc.label(),
            rep.ecr() * 100.0,
            rep.error_prone(),
            rep.cols()
        );
    }

    // Eq. 1: error-free columns / MAJ5 latency = throughput.
    let tput = ThroughputModel::new(&SystemConfig::paper());
    let ops_base = tput.ops_per_sec(&tput.majx(5, &setup.base), 1.0 - ecr_base.ecr());
    let ops_tune = tput.ops_per_sec(&tput.majx(5, &setup.tune), 1.0 - ecr_tune.ecr());
    println!("\nprojected full-system MAJ5 throughput (4ch x 16 banks x 65,536 cols):");
    println!("  baseline: {}", pudtune::util::table::fmt_ops(ops_base));
    println!("  PUDTune:  {}", pudtune::util::table::fmt_ops(ops_tune));
    println!("  gain:     {:.2}x (paper: 1.81x)", ops_tune / ops_base);

    // Serve an actual workload through the compute path: compile the
    // op once, execute it under the calibrated levels on the columns
    // the batteries proved arithmetic-usable (an add circuit chains
    // MAJ5 *and* MAJ3 gates, so the mask intersects both arities),
    // and check the golden model.
    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 8 })?);
    let mut rng = Rng::new(1);
    let a: Vec<u64> = (0..sub.cols).map(|_| rng.below(256)).collect();
    let b: Vec<u64> = (0..sub.cols).map(|_| rng.below(256)).collect();
    let req = ComputeRequest::from_subarray(
        &sub,
        seed,
        plan.clone(),
        setup.calib.clone(),
        vec![a, b],
    )
    .with_mask(batteries[1].arith().error_free_mask());
    let golden = req.golden_outputs()?;
    let res = engine.execute_one(&req)?;
    let correct = res.golden_correct(&golden);
    println!(
        "\nserved one {} batch via {}: {correct}/{} masked columns golden-correct, \
         effective {}",
        plan.op.label(),
        engine.compute_backend(),
        res.active_cols(),
        pudtune::util::table::fmt_ops(tput.workload_ops(
            &plan.cost,
            &setup.tune,
            res.active_cols() as f64 / sub.cols as f64
        ))
    );
    Ok(())
}
