//! Integration suite for the bit-level range analysis
//! (`pud::ranges`): exhaustive in-range equivalence for every
//! vocabulary op up to width 6, randomized add8/mul8 property tests,
//! the clean full-width vocabulary the CI `analyze-vocabulary` step
//! pins, and the transparent narrowed-variant substitution on both the
//! engine batch path and `RecalibService::serve_workload`.

use pudtune::calib::algorithm::{CalibParams, Calibration, NativeEngine};
use pudtune::calib::engine::{ComputeEngine, ComputeRequest};
use pudtune::calib::lattice::{FracConfig, OffsetLattice};
use pudtune::config::device::DeviceConfig;
use pudtune::coordinator::service::{RecalibService, ServiceConfig};
use pudtune::dram::geometry::SubarrayId;
use pudtune::pud::plan::{PudOp, WorkloadPlan};
use pudtune::pud::ranges::{analyze_plan, soundness_check, OperandRange};
use pudtune::util::rng::Rng;
use std::sync::Arc;

fn compiled(op: PudOp) -> WorkloadPlan {
    WorkloadPlan::compile(op).unwrap()
}

fn quiet_cfg() -> DeviceConfig {
    DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 1e-6,
        ..DeviceConfig::default()
    }
}

fn random_range(rng: &mut Rng, width: usize) -> OperandRange {
    let hi = OperandRange::full(width).hi;
    OperandRange::new(rng.below(hi + 1), rng.below(hi + 1))
}

#[test]
fn the_full_width_vocabulary_analyzes_clean() {
    // Full ranges fold nothing: no constant bits, no stranded gates,
    // no narrowing — every compiled gate earns its place. This is the
    // contract the CI `analyze-vocabulary` step asserts over JSON.
    for op in PudOp::vocabulary(6) {
        let plan = compiled(op);
        let full: Vec<OperandRange> = (0..plan.op.n_operands())
            .map(|_| OperandRange::full(plan.op.operand_width()))
            .collect();
        let report = analyze_plan(&plan, &full).unwrap();
        assert!(
            report.is_clean(),
            "{}: full-width analysis must be clean, got {:?}",
            plan.op.label(),
            report.diagnostics
        );
        assert_eq!(
            report.narrowed_gates(),
            report.gates,
            "{}: nothing to narrow at full width",
            plan.op.label()
        );
        assert!(
            soundness_check(&plan, &report, 1024, 0x50E).is_empty(),
            "{}: the (vacuous) full-width claims must be sound",
            plan.op.label()
        );
    }
}

#[test]
fn narrowing_is_exhaustively_sound_up_to_width_6() {
    // Every vocabulary op up to width 6, random declared ranges, and
    // an exhaustive walk of every in-range operand tuple: the narrowed
    // circuit and every claimed-constant bit must agree with the
    // original circuit on all of them.
    let mut rng = Rng::new(0x6A11);
    for op in PudOp::vocabulary(6) {
        let plan = compiled(op);
        let w = plan.op.operand_width();
        for _ in 0..4 {
            let ranges: Vec<OperandRange> =
                (0..plan.op.n_operands()).map(|_| random_range(&mut rng, w)).collect();
            let report = analyze_plan(&plan, &ranges).unwrap();
            let findings = soundness_check(&plan, &report, usize::MAX, 0);
            assert!(
                findings.is_empty(),
                "{} under {ranges:?}: {findings:?}",
                plan.op.label()
            );
            // The narrowed artifact re-verifies as a full plan.
            let narrowed = plan.narrowed(&ranges).expect("narrowing re-verifies");
            assert!(narrowed.is_verified());
            assert!(narrowed.circuit.gates.len() <= plan.circuit.gates.len());
        }
    }
}

#[test]
fn add8_and_mul8_hold_on_random_ranges_and_hit_the_known_gate_counts() {
    // Randomized property test at a width too wide to enumerate.
    let mut rng = Rng::new(0x8A8);
    for op in [PudOp::Add { width: 8 }, PudOp::Mul { width: 8 }] {
        let plan = compiled(op);
        for round in 0..5 {
            let ranges = vec![random_range(&mut rng, 8), random_range(&mut rng, 8)];
            let report = analyze_plan(&plan, &ranges).unwrap();
            let findings = soundness_check(&plan, &report, 2048, 0xF00 + round);
            assert!(
                findings.is_empty(),
                "{} under {ranges:?}: {findings:?}",
                plan.op.label()
            );
        }
    }
    // The canonical skewed class: nibble-valued operands in 8-bit
    // plans. The gate counts are part of the bench uplift story.
    let nibble = [OperandRange::new(0, 15); 2];
    let add = analyze_plan(&compiled(PudOp::Add { width: 8 }), &nibble).unwrap();
    assert_eq!((add.gates, add.narrowed_gates()), (16, 8), "add8 halves");
    let mul = analyze_plan(&compiled(PudOp::Mul { width: 8 }), &nibble).unwrap();
    assert_eq!((mul.gates, mul.narrowed_gates()), (176, 40), "mul8 drops 4.4x");
}

#[test]
fn declared_ranges_substitute_narrowed_plans_transparently() {
    // Two identical requests, one carrying declared ranges: the engine
    // must substitute the narrowed variant (fewer gates, same
    // interface) and produce bit-identical outputs.
    let cfg = quiet_cfg();
    let eng = NativeEngine::new(cfg.clone());
    let cols = 16;
    let plan = Arc::new(compiled(PudOp::Add { width: 8 }));
    let mut rng = Rng::new(0xE2E);
    let operands: Vec<Vec<u64>> =
        (0..2).map(|_| (0..cols).map(|_| rng.below(16)).collect()).collect();
    let fc = FracConfig::pudtune([2, 1, 0]);
    let calib = Calibration::uniform(OffsetLattice::build(&cfg, &fc), cols);
    let wide = ComputeRequest::new(plan, 128, cols, 0x5EED, calib, operands.clone());
    let narrow = wide.clone().with_ranges(vec![OperandRange::new(0, 15); 2]);
    let a = eng.execute_one(&wide).unwrap();
    let b = eng.execute_one(&narrow).unwrap();
    assert_eq!(a.outputs, b.outputs, "narrowed substitution must be bit-identical");
    for (col, &out) in a.outputs.iter().enumerate() {
        assert_eq!(out, operands[0][col] + operands[1][col], "col {col}");
    }
}

#[test]
fn serve_workload_picks_narrowed_variants_and_counts_them() {
    let cfg = quiet_cfg();
    let svc = ServiceConfig {
        serve_samples: 256,
        params: CalibParams::quick(),
        ..ServiceConfig::default()
    };
    let s = RecalibService::new(cfg.clone(), svc, NativeEngine::new(cfg)).unwrap();
    let cols = 16;
    s.register(SubarrayId::new(0, 0, 0), 64, cols, 0x5EED);
    s.run_pending(usize::MAX);

    // Nibble-valued operands through an 8-bit op: the serve derives
    // the range class from the values and picks the narrowed variant.
    let op = PudOp::Add { width: 8 };
    let operands: Vec<Vec<u64>> = (0..2u64)
        .map(|i| (0..cols as u64).map(|c| (c * (i + 3)) % 16).collect())
        .collect();
    let outs = s.serve_workload(op.clone(), &operands).unwrap();
    assert_eq!(s.metrics.counter("plan.narrow.served"), 1);
    for o in &outs {
        assert!(o.result.is_ok(), "bank must serve: {:?}", o.result);
        assert_eq!(
            o.golden_correct, o.active_cols,
            "narrowed serving must stay golden-correct"
        );
    }

    // Full-width operands do not narrow: the counter stays put.
    let full: Vec<Vec<u64>> =
        (0..2).map(|_| (0..cols as u64).map(|c| 128 + c).collect()).collect();
    s.serve_workload(op, &full).unwrap();
    assert_eq!(s.metrics.counter("plan.narrow.served"), 1);
}
