//! Mutation testing for the static plan verifier: corrupt known-good
//! compiled plans (and their lowered charge scripts) one class at a
//! time and assert every diagnostic class P001–P008 is caught, then
//! prove the admission layers (`ComputeEngine`, `RecalibService`)
//! reject the corrupted plans before touching any subarray.
//!
//! Plan-level mutants go through `WorkloadPlan::assemble`, which never
//! marks its result verified — exactly the hole a hand-crafted or
//! bit-rotted plan would arrive through.
//!
//! Width-narrowed variants (`pud::ranges`) get the same treatment: a
//! narrowed plan with a corrupted death list is rejected like any
//! other mutant, a narrowing pipeline that skips the dead-gate strip
//! re-reports P009/P010/P012 on re-analysis, and a lying range class
//! is caught both by the concrete soundness cross-check and by the
//! typed range validation on the serving path.

use pudtune::calib::algorithm::{CalibParams, Calibration, NativeEngine};
use pudtune::calib::engine::{ComputeEngine, ComputeRequest};
use pudtune::calib::lattice::{FracConfig, OffsetLattice};
use pudtune::config::device::DeviceConfig;
use pudtune::coordinator::service::{RecalibService, ServiceConfig};
use pudtune::dram::geometry::SubarrayId;
use pudtune::pud::graph::{Gate, MajCircuit, Signal};
use pudtune::pud::plan::{BitwiseOp, PudError, PudOp, WorkloadPlan};
use pudtune::pud::verify::{
    self, check_script, lower_plan, ChargeOp, DiagCode, Severity, DATA_BASE,
};
use pudtune::util::rng::Rng;
use std::sync::Arc;

fn compiled(op: PudOp) -> WorkloadPlan {
    WorkloadPlan::compile(op).unwrap()
}

/// Re-assemble a plan with mutated parts; the result is unverified.
fn reassemble(plan: &WorkloadPlan, deaths: Vec<Vec<Signal>>, peak: usize) -> WorkloadPlan {
    WorkloadPlan::assemble(plan.op.clone(), plan.circuit.clone(), deaths, peak)
}

/// The canonical P001 mutant: move one death entry to an earlier gate,
/// so the signal's true last consumer reads a released row.
fn early_death_mutant(plan: &WorkloadPlan, rng: &mut Rng) -> WorkloadPlan {
    let mut deaths = plan.death_lists().to_vec();
    let candidates: Vec<(usize, usize)> = deaths
        .iter()
        .enumerate()
        .filter(|(gi, _)| *gi > 0)
        .flat_map(|(gi, list)| (0..list.len()).map(move |k| (gi, k)))
        .collect();
    assert!(!candidates.is_empty(), "{}: no movable death entry", plan.op.label());
    let (gi, k) = candidates[rng.below(candidates.len() as u64) as usize];
    let sig = deaths[gi].remove(k);
    let earlier = rng.below(gi as u64) as usize;
    deaths[earlier].push(sig);
    reassemble(plan, deaths, plan.peak_rows)
}

#[test]
fn p001_moved_death_entry_is_use_after_death() {
    let mut rng = Rng::new(0x001);
    for op in [
        PudOp::Add { width: 2 },
        PudOp::Add { width: 5 },
        PudOp::Mul { width: 2 },
        PudOp::Mul { width: 3 },
    ] {
        let plan = compiled(op);
        for _ in 0..4 {
            let mutant = early_death_mutant(&plan, &mut rng);
            assert!(!mutant.is_verified());
            let report = verify::verify_plan(&mutant);
            assert!(
                report.has(DiagCode::UseAfterDeath),
                "{}: moving a death entry earlier must be P001\n{report}",
                plan.op.label()
            );
            assert!(
                report.has(DiagCode::DeathListMismatch),
                "{}: the edited lists must also disagree with liveness\n{report}",
                plan.op.label()
            );
            assert!(verify::admit(&mutant).is_err());
        }
    }
}

#[test]
fn p002_duplicated_frac_and_dropped_restore_are_caught() {
    let plan = compiled(PudOp::Add { width: 2 });
    let script = lower_plan(&plan).unwrap();
    assert!(check_script(&script).is_empty(), "baseline script must be clean");

    // Mutation: replay one Frac burst (a double-charge without an
    // intervening restore).
    let frac_at = script
        .ops
        .iter()
        .position(|op| matches!(op, ChargeOp::Frac { .. }))
        .expect("every MAJX flow fracs");
    let mut doubled = script.clone();
    doubled.ops.insert(frac_at + 1, doubled.ops[frac_at].clone());
    let diags = check_script(&doubled);
    assert!(
        diags.iter().any(|d| d.code == DiagCode::DoubleFrac),
        "duplicated Frac must be P002: {diags:?}"
    );

    // Mutation: truncate the first SiMRA's restore phase — the group's
    // analog rows leak into the next gate's staging copies (P002)
    // and/or survive to exit (P006).
    let simra_at = script
        .ops
        .iter()
        .position(|op| matches!(op, ChargeOp::Simra { .. }))
        .expect("every MAJX flow simras");
    let mut truncated = script.clone();
    if let ChargeOp::Simra { restore, .. } = &mut truncated.ops[simra_at] {
        *restore = false;
    }
    let diags = check_script(&truncated);
    assert!(
        diags
            .iter()
            .any(|d| d.code == DiagCode::DoubleFrac || d.code == DiagCode::UnrestoredExit),
        "dropped restore must surface as P002/P006: {diags:?}"
    );
}

#[test]
fn p003_dropped_write_is_read_of_never_written_row() {
    let plan = compiled(PudOp::Bitwise(BitwiseOp::And));
    let script = lower_plan(&plan).unwrap();
    // Drop the first scratch-region write (an input materialisation);
    // the gate's staging copy then reads an uninitialised row.
    let w = script
        .ops
        .iter()
        .position(|op| matches!(op, ChargeOp::Write { row, .. } if *row >= DATA_BASE))
        .expect("inputs are written into the data region");
    let mut mutant = script.clone();
    mutant.ops.remove(w);
    let diags = check_script(&mutant);
    assert!(
        diags.iter().any(|d| d.code == DiagCode::ReadUninitialized),
        "dropped input write must be P003: {diags:?}"
    );
}

#[test]
fn p004_peak_lies_and_budget_overflows_are_caught() {
    let plan = compiled(PudOp::Add { width: 3 });
    assert!(plan.peak_rows > 1, "add3 needs scratch rows");

    // Mutation: bump the declared peak — the replay disagrees.
    let deaths = plan.death_lists().to_vec();
    let bumped = reassemble(&plan, deaths.clone(), plan.peak_rows + 1);
    let report = verify::verify_plan(&bumped);
    assert!(report.has(DiagCode::RowBudgetOverflow), "{report}");
    assert!(verify::admit(&bumped).is_err());

    // An honest plan against a too-small subarray budget.
    let report = verify::verify_plan_with_budget(&plan, Some(plan.peak_rows - 1));
    assert!(report.has(DiagCode::RowBudgetOverflow), "{report}");
    // ...and against exactly its own peak: clean.
    assert!(verify::verify_plan_with_budget(&plan, Some(plan.peak_rows)).is_clean());
}

#[test]
fn p005_dead_gate_warns_but_does_not_block_admission() {
    let mut c = MajCircuit::new(2);
    let used = c.push(Gate::maj3(Signal::Input(0), Signal::Input(1), Signal::Const(false)));
    c.push(Gate::maj3(Signal::Input(0), Signal::Input(1), Signal::Const(true)));
    c.output(used);
    let report = verify::verify_circuit(&c);
    assert!(report.has(DiagCode::DeadGate), "{report}");
    assert!(
        report
            .diagnostics
            .iter()
            .all(|d| d.code != DiagCode::DeadGate || d.severity() == Severity::Warning)
    );
    assert_eq!(report.errors().count(), 0, "a dead gate alone is warning-only\n{report}");
    // Warnings fail lint but not compilation/admission.
    let plan = WorkloadPlan::from_circuit(c).expect("warnings must not block compile");
    assert!(plan.is_verified());
    assert!(verify::admit(&plan).is_ok());
}

#[test]
fn p006_analog_rows_at_exit_are_caught() {
    let plan = compiled(PudOp::Bitwise(BitwiseOp::Or));
    let mut script = lower_plan(&plan).unwrap();
    // Mutation: a stray trailing Frac leaves a calibration row analog
    // with no restore before exit.
    script.ops.push(ChargeOp::Frac { row: verify::CALIB_STORE[0], gate: None });
    let diags = check_script(&script);
    assert!(
        diags.iter().any(|d| d.code == DiagCode::UnrestoredExit),
        "analog row at exit must be P006: {diags:?}"
    );
}

#[test]
fn p007_swapped_death_lists_disagree_with_liveness() {
    let plan = compiled(PudOp::Add { width: 3 });
    let mut deaths = plan.death_lists().to_vec();
    let (a, b) = {
        let nonempty: Vec<usize> =
            (0..deaths.len()).filter(|&g| !deaths[g].is_empty()).collect();
        let (a, b) = (nonempty[0], *nonempty.last().unwrap());
        assert!(a < b, "add3 must have two distinct death sites");
        assert_ne!(deaths[a], deaths[b]);
        (a, b)
    };
    deaths.swap(a, b);
    let mutant = reassemble(&plan, deaths, plan.peak_rows);
    let report = verify::verify_plan(&mutant);
    assert!(report.has(DiagCode::DeathListMismatch), "{report}");
    match verify::admit(&mutant) {
        Err(PudError::Verification { code, .. }) => {
            assert!(code.starts_with('P'), "typed admission error, got {code}")
        }
        other => panic!("swapped death lists must be rejected, got {other:?}"),
    }
}

#[test]
fn p008_shape_mutations_are_caught() {
    // Mutation: bump one gate input past the circuit's input count.
    let plan = compiled(PudOp::Bitwise(BitwiseOp::And));
    let mut circuit = plan.circuit.clone();
    circuit.gates[0].args[0] = Signal::Input(circuit.n_inputs + 7);
    let mutant = WorkloadPlan::assemble(
        plan.op.clone(),
        circuit,
        plan.death_lists().to_vec(),
        plan.peak_rows,
    );
    let report = verify::verify_plan(&mutant);
    assert!(report.has(DiagCode::ShapeMismatch), "{report}");
    assert!(verify::admit(&mutant).is_err());

    // A 4-ary gate and a forward gate reference, via the lint path.
    let mut c = MajCircuit::new(2);
    c.gates.push(Gate {
        args: vec![Signal::Input(0), Signal::Input(1), Signal::Input(0), Signal::Input(1)],
    });
    c.gates.push(Gate::maj3(Signal::Gate(5), Signal::Input(0), Signal::Const(true)));
    c.outputs.push(Signal::Gate(1));
    let report = verify::verify_circuit(&c);
    assert!(report.has(DiagCode::ShapeMismatch), "{report}");
    assert!(report.errors().count() >= 2, "both shape mutations must surface\n{report}");
}

#[test]
fn corrupted_narrowed_plans_are_rejected_like_any_other_mutant() {
    use pudtune::pud::ranges::OperandRange;
    let base = compiled(PudOp::Add { width: 8 });
    let narrow =
        base.narrowed(&[OperandRange::new(0, 15); 2]).expect("nibble ranges narrow add8");
    assert!(narrow.is_verified());
    assert!(narrow.circuit.gates.len() < base.circuit.gates.len());

    // Mutation: widen a death list — release one signal a second time
    // in a later gate's list. The replay reads/releases a dead row
    // (P001) and the lists disagree with liveness (P007).
    let mut deaths = narrow.death_lists().to_vec();
    let first = (0..deaths.len())
        .find(|&g| !deaths[g].is_empty())
        .expect("a narrowed adder still releases rows");
    let last = (0..deaths.len()).rev().find(|&g| g > first).expect("multiple gates");
    let sig = deaths[first][0];
    deaths[last].push(sig);
    let mutant = reassemble(&narrow, deaths, narrow.peak_rows);
    assert!(!mutant.is_verified(), "assemble never marks its result verified");
    let report = verify::verify_plan(&mutant);
    assert!(
        report.has(DiagCode::UseAfterDeath) || report.has(DiagCode::DeathListMismatch),
        "widened death list must be P001/P007\n{report}"
    );
    match verify::admit(&mutant) {
        Err(PudError::Verification { code, .. }) => assert!(code.starts_with('P'), "{code}"),
        other => panic!("corrupted narrowed plan must be rejected, got {other:?}"),
    }
}

#[test]
fn dropped_dead_gate_strip_is_recaught_on_reanalysis() {
    use pudtune::pud::ranges::{analyze_plan, OperandRange};
    // A corrupt narrowing pipeline that "forgot" the strip would ship
    // the original circuit as the narrowed variant. Re-analysis under
    // the same ranges immediately re-reports the stranded gates
    // (P010), the constant output bits (P009) and the missed strip
    // (P012) — while an honestly narrowed plan re-analyzes clean and
    // idempotent.
    let base = compiled(PudOp::Add { width: 8 });
    let nibble = [OperandRange::new(0, 15); 2];
    let skipped = analyze_plan(&base, &nibble).unwrap();
    assert!(skipped.has(DiagCode::ConstantOutputBit), "high bits are provably zero");
    assert!(skipped.has(DiagCode::DeadGateByDataflow), "the high carry chain is stranded");
    assert!(skipped.has(DiagCode::NarrowingOpportunity), "the strip was skipped");
    assert!(skipped.narrowed_gates() < skipped.gates);

    let honest = base.narrowed(&nibble).unwrap();
    let again = analyze_plan(&honest, &nibble).unwrap();
    assert!(again.is_clean(), "honest narrowing leaves nothing to report\n{again:?}");
    assert_eq!(again.narrowed_gates(), again.gates, "narrowing is idempotent");
}

#[test]
fn lying_ranges_are_caught_concretely_and_typed() {
    use pudtune::pud::ranges::{analyze_plan, soundness_check, OperandRange};
    let base = compiled(PudOp::Add { width: 8 });
    let nibble = [OperandRange::new(0, 15); 2];
    let report = analyze_plan(&base, &nibble).unwrap();
    // The honest report survives an exhaustive in-range cross-check.
    assert!(
        soundness_check(&base, &report, 512, 0x11E).is_empty(),
        "honest nibble analysis must be sound"
    );
    // Forge the declared ranges wider than the analysis ran under —
    // the concrete cross-check contradicts the claimed-constant bits
    // on the first out-of-nibble operand pair it draws.
    let mut lying = report.clone();
    lying.ranges = vec![OperandRange::new(0, 255); 2];
    let findings = soundness_check(&base, &lying, 512, 0x11E);
    assert!(!findings.is_empty(), "a lying range class must be caught as unsound");

    // On the serving path the lie is typed: operands outside the
    // declared ranges are rejected before any narrowed substitution.
    let cfg = DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 1e-6,
        ..DeviceConfig::default()
    };
    let eng = NativeEngine::new(cfg.clone());
    let cols = 8;
    let fc = FracConfig::pudtune([2, 1, 0]);
    let calib = Calibration::uniform(OffsetLattice::build(&cfg, &fc), cols);
    let mut operands: Vec<Vec<u64>> =
        (0..2).map(|_| (0..cols as u64).map(|c| c % 16).collect()).collect();
    operands[1][3] = 200; // outside the declared [0, 15]
    let req = ComputeRequest::new(Arc::new(base), 128, cols, 0x5EED, calib, operands)
        .with_ranges(vec![OperandRange::new(0, 15); 2]);
    let err = eng.execute_one(&req).unwrap_err();
    let rendered = format!("{err:#}");
    assert!(
        rendered.contains("violates the declared range"),
        "out-of-range operand must be a typed rejection: {rendered}"
    );
}

#[test]
fn engines_reject_corrupted_plans_at_admission() {
    let cfg = DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 1e-6,
        ..DeviceConfig::default()
    };
    let eng = NativeEngine::new(cfg.clone());
    let mut rng = Rng::new(0xADA17);
    let good = Arc::new(compiled(PudOp::Add { width: 2 }));
    let mutant = Arc::new(early_death_mutant(&good, &mut rng));

    let cols = 16;
    let operands: Vec<Vec<u64>> = (0..2).map(|_| (0..cols as u64).map(|c| c % 4).collect()).collect();
    let fc = FracConfig::pudtune([2, 1, 0]);
    let calib = Calibration::uniform(OffsetLattice::build(&cfg, &fc), cols);
    let req = |plan: Arc<WorkloadPlan>| {
        ComputeRequest::new(plan, 128, cols, 0x5EED, calib.clone(), operands.clone())
    };

    // The compiled plan executes; the byte-identical-but-corrupted
    // assembly is rejected before any subarray is touched.
    eng.execute_one(&req(good.clone())).expect("verified plan must execute");
    let err = eng.execute_one(&req(mutant.clone())).unwrap_err();
    let rendered = format!("{err:#}");
    assert!(
        rendered.contains("plan rejected by verifier (P"),
        "admission must return the typed verifier error: {rendered}"
    );

    // Batch admission: one bad request fails the whole batch, typed.
    let err = eng.execute_batch(&[req(good.clone()), req(mutant)]).unwrap_err();
    assert!(format!("{err:#}").contains("plan rejected by verifier (P"));
}

#[test]
fn serving_layer_rejects_corrupted_plans_at_admission() {
    let cfg = DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 1e-6,
        ..DeviceConfig::default()
    };
    let svc = ServiceConfig {
        serve_samples: 256,
        params: CalibParams::quick(),
        ..ServiceConfig::default()
    };
    let s = RecalibService::new(cfg.clone(), svc, NativeEngine::new(cfg)).unwrap();
    let cols = 16;
    s.register(SubarrayId::new(0, 0, 0), 64, cols, 0x5EED);
    s.run_pending(usize::MAX);

    let good = Arc::new(compiled(PudOp::Add { width: 2 }));
    let mut rng = Rng::new(0xADA18);
    let mutant = Arc::new(early_death_mutant(&good, &mut rng));
    let operands: Vec<Vec<u64>> =
        (0..2).map(|_| (0..cols as u64).map(|c| c % 4).collect()).collect();

    s.serve_plan(&good, &operands).expect("verified plan must serve");
    match s.serve_plan(&mutant, &operands) {
        Err(PudError::Verification { code, message }) => {
            assert!(code.starts_with('P'), "{code}");
            assert!(message.contains("hint:"), "diagnostics carry fix hints: {message}");
        }
        other => panic!("corrupted plan must be rejected before serving, got {other:?}"),
    }
    // Nothing was served for the rejected plan: the verifier runs
    // before any bank executes.
    assert_eq!(s.metrics.counter("compute.bank_failures"), 0);
}
