//! Fused-execution parity suite: batch-fused multi-bank execution must
//! be **bit-identical** to the per-bank request loop — same decoded
//! outputs, same f64 latency bits, same peak scratch rows, same fault
//! flips, same RNG fingerprints — across backends, batch sizes and the
//! whole built-in vocabulary. Also pins the [`PlanCache`] hit/miss/
//! eviction contract the serving path and CLI rely on.

use pudtune::calib::algorithm::Calibration;
use pudtune::calib::engine::{AnyEngine, ComputeEngine, ComputeRequest, ComputeResult};
use pudtune::calib::lattice::{FracConfig, OffsetLattice};
use pudtune::config::device::DeviceConfig;
use pudtune::config::system::Ddr4Timing;
use pudtune::coordinator::metrics::Metrics;
use pudtune::coordinator::plancache::{CacheStats, PlanCache};
use pudtune::dram::geometry::RowMap;
use pudtune::dram::subarray::Subarray;
use pudtune::prelude::NativeEngine;
use pudtune::pud::exec::{run_plan, StepRunner};
use pudtune::pud::majx::setup_subarray;
use pudtune::pud::plan::{PudError, PudOp, WorkloadPlan};
use pudtune::util::rng::Rng;
use std::sync::Arc;

const ROWS: usize = 128;

fn quiet_cfg() -> DeviceConfig {
    DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 1e-6,
        ..DeviceConfig::default()
    }
}

fn calib_for(cfg: &DeviceConfig, cols: usize) -> Calibration {
    let fc = FracConfig::pudtune([2, 1, 0]);
    Calibration::uniform(OffsetLattice::build(cfg, &fc), cols)
}

fn request_for(
    plan: &Arc<WorkloadPlan>,
    cfg: &DeviceConfig,
    cols: usize,
    seed: u64,
    rng: &mut Rng,
) -> ComputeRequest {
    let width = plan.op.operand_width();
    let operands: Vec<Vec<u64>> = (0..plan.op.n_operands())
        .map(|_| (0..cols).map(|_| rng.below(1u64 << width)).collect())
        .collect();
    ComputeRequest::new(plan.clone(), ROWS, cols, seed, calib_for(cfg, cols), operands)
}

/// Bit-exact result comparison: `elapsed_ns` must match to the bit, not
/// approximately — the fused path promises the *same* f64 additions in
/// the same order as the per-bank loop.
fn assert_result_eq(a: &ComputeResult, b: &ComputeResult, ctx: &str) {
    assert_eq!(a.outputs, b.outputs, "{ctx}: outputs diverged");
    assert_eq!(a.mask, b.mask, "{ctx}: masks diverged");
    assert_eq!(
        a.elapsed_ns.to_bits(),
        b.elapsed_ns.to_bits(),
        "{ctx}: elapsed_ns not bit-identical ({} vs {})",
        a.elapsed_ns,
        b.elapsed_ns
    );
    assert_eq!(a.peak_rows, b.peak_rows, "{ctx}: peak_rows diverged");
    assert_eq!(a.fault_flips, b.fault_flips, "{ctx}: fault_flips diverged");
}

/// A mixed batch: several ops, two geometries, a mask here and there, a
/// replicated request, an env-carrying request — fused execution must
/// reproduce the per-request loop exactly at every batch size.
#[test]
fn fused_batches_match_the_per_bank_loop_bit_for_bit() {
    let cfg = DeviceConfig::default();
    let eng = NativeEngine::new(cfg.clone());
    let ops = [
        Arc::new(WorkloadPlan::compile(PudOp::Add { width: 4 }).unwrap()),
        Arc::new(WorkloadPlan::compile(PudOp::Mul { width: 3 }).unwrap()),
        Arc::new(WorkloadPlan::compile(PudOp::Add { width: 2 }).unwrap()),
    ];
    let mut rng = Rng::new(0xF05E);
    for batch in [1usize, 3, 16] {
        let mut reqs = Vec::with_capacity(batch);
        for i in 0..batch {
            let plan = &ops[i % ops.len()];
            let cols = if i % 2 == 0 { 8 } else { 16 };
            let mut req = request_for(plan, &cfg, cols, 0x5EED + i as u64, &mut rng);
            if i % 4 == 1 {
                req = req.with_mask((0..cols).map(|c| c % 3 != 0).collect());
            }
            if i % 5 == 2 {
                req = req.with_replicas(3);
            }
            if i % 6 == 3 {
                // Environment override, as serving requests carry.
                let sub = Subarray::with_geometry(&cfg, ROWS, cols, req.seed);
                req.env = Some(sub.env);
            }
            reqs.push(req);
        }
        let fused = eng.execute_batch(&reqs).unwrap();
        assert_eq!(fused.len(), reqs.len());
        for (i, (req, got)) in reqs.iter().zip(&fused).enumerate() {
            let single = eng.execute_one(req).unwrap();
            assert_result_eq(got, &single, &format!("batch {batch}, request {i}"));
        }
    }
}

/// Every built-in op: a fused batch of three differently-seeded banks
/// equals three single executions, and on a quiet device all of them
/// equal the software golden model.
#[test]
fn vocabulary_fuses_to_golden_outputs() {
    let cfg = quiet_cfg();
    let eng = NativeEngine::new(cfg.clone());
    let mut rng = Rng::new(0x70CA);
    for op in PudOp::vocabulary(4) {
        let plan = Arc::new(WorkloadPlan::compile(op).unwrap());
        let reqs: Vec<ComputeRequest> = (0..3)
            .map(|i| request_for(&plan, &cfg, 8, 0xBA5E + i, &mut rng))
            .collect();
        let fused = eng.execute_batch(&reqs).unwrap();
        for (i, (req, got)) in reqs.iter().zip(&fused).enumerate() {
            let single = eng.execute_one(req).unwrap();
            let label = plan.op.label();
            assert_result_eq(got, &single, &format!("{label}, bank {i}"));
            let golden = req.golden_outputs().unwrap();
            assert_eq!(got.outputs, golden, "{label}, bank {i}: diverged from golden");
        }
    }
}

/// The fused path's request-order error semantics match the loop: the
/// first malformed request fails the whole batch with the same typed
/// error `execute_one` would surface.
#[test]
fn fused_batches_surface_the_first_request_error() {
    let cfg = quiet_cfg();
    let eng = NativeEngine::new(cfg.clone());
    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 2 }).unwrap());
    let mut rng = Rng::new(0xE44);
    let good = request_for(&plan, &cfg, 8, 1, &mut rng);
    let mut bad = request_for(&plan, &cfg, 8, 2, &mut rng);
    bad.operands.pop();
    let err = eng.execute_batch(&[good.clone(), bad, good]).unwrap_err();
    assert!(err.to_string().contains("arity"), "unexpected error: {err}");
}

/// `run_plan` is an interpreter of the canonical lowering: driving a
/// [`StepRunner`] by hand over `plan.lowered()` on an identically
/// seeded subarray reproduces it exactly — outputs, latency bits, op
/// counts and the RNG fingerprint.
#[test]
fn step_runner_replays_run_plan_exactly() {
    let cfg = quiet_cfg();
    let plan = WorkloadPlan::compile(PudOp::Add { width: 4 }).unwrap();
    let cols = 8;
    let fc = FracConfig::pudtune([2, 1, 0]);
    let calib = calib_for(&cfg, cols);
    let grade = Ddr4Timing::ddr4_2133();
    let mut rng = Rng::new(0x51E9);
    let operands: Vec<Vec<u64>> =
        (0..2).map(|_| (0..cols).map(|_| rng.below(16)).collect()).collect();
    let inputs = plan.encode_operands(&operands).unwrap();

    let mut sub_a = Subarray::with_geometry(&cfg, ROWS, cols, 11);
    let map = RowMap::standard(ROWS);
    let run_a = run_plan(&mut sub_a, &map, &calib, &fc, &grade, &plan, &inputs).unwrap();

    let mut sub_b = Subarray::with_geometry(&cfg, ROWS, cols, 11);
    let lowered = plan.lowered().unwrap();
    setup_subarray(&mut sub_b, &map, &calib);
    let mut runner = StepRunner::new(cols);
    for step in &lowered.steps {
        runner.apply(&mut sub_b, &map, &fc, &grade, &inputs, step);
    }
    let run_b = runner.finish(&sub_b, lowered.peak_rows());

    assert_eq!(run_a.outputs, run_b.outputs);
    assert_eq!(run_a.elapsed_ns.to_bits(), run_b.elapsed_ns.to_bits());
    assert_eq!(run_a.peak_rows, run_b.peak_rows);
    assert_eq!(sub_a.counts, sub_b.counts, "op counts diverged");
    assert_eq!(sub_a.rng_fingerprint(), sub_b.rng_fingerprint(), "RNG streams diverged");
}

/// Cross-backend parity: whatever backend `AnyEngine::auto` lands on
/// (PJRT with its resident native fallback, or plain native) must
/// produce results bit-identical to the native engine — and a built-in
/// vocabulary batch must report **zero** per-step fallbacks.
#[test]
fn backends_agree_and_builtin_vocabulary_reports_zero_fallbacks() {
    let cfg = DeviceConfig::default();
    let native = AnyEngine::native(cfg.clone());
    let auto = AnyEngine::auto(cfg.clone());
    let mut rng = Rng::new(0xACC0);
    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 4 }).unwrap());
    let reqs: Vec<ComputeRequest> =
        (0..3).map(|i| request_for(&plan, &cfg, 16, 0xD1CE + i, &mut rng)).collect();
    let a = native.execute_batch(&reqs).unwrap();
    let b = auto.execute_batch(&reqs).unwrap();
    for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
        assert_result_eq(ra, rb, &format!("native vs {}, request {i}", auto.compute_backend()));
    }
    if let Some(m) = auto.metrics() {
        assert_eq!(
            m.counter("pjrt.compute.fallback"),
            0,
            "built-in ops must lower without per-step fallbacks"
        );
    }
}

/// The compiled-plan cache contract: hits share one `Arc`, misses
/// compile + insert, LRU eviction honours recency, stats and the
/// `plan.cache.*` metrics agree, and errors are never cached.
#[test]
fn plan_cache_hit_miss_eviction_properties() {
    let m = Metrics::new();
    let cache = PlanCache::new(2);
    let add1 = PudOp::Add { width: 1 };
    let add2 = PudOp::Add { width: 2 };
    let add3 = PudOp::Add { width: 3 };

    let a = cache.get_or_compile(&add1, 0, Some(&m)).unwrap();
    let a2 = cache.get_or_compile(&add1, 0, Some(&m)).unwrap();
    assert!(Arc::ptr_eq(&a, &a2), "a hit must return the cached Arc");
    assert!(Arc::ptr_eq(&a.lowered, &a2.lowered));
    assert!(a.plan.is_verified());
    cache.get_or_compile(&add2, 0, Some(&m)).unwrap();
    assert_eq!(cache.len(), 2);

    // Third distinct key on capacity 2: the LRU entry (add1) goes.
    cache.get_or_compile(&add3, 0, Some(&m)).unwrap();
    assert_eq!(cache.len(), 2);
    assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 3, evicted: 1 });

    // add2 is still resident (hit); re-resolving add1 recompiles and
    // evicts the now-least-recent add3.
    cache.get_or_compile(&add2, 0, Some(&m)).unwrap();
    let a3 = cache.get_or_compile(&add1, 0, Some(&m)).unwrap();
    assert!(!Arc::ptr_eq(&a, &a3), "evicted entries recompile to a fresh Arc");
    assert_eq!(cache.stats(), CacheStats { hits: 2, misses: 4, evicted: 2 });
    assert_eq!(m.counter("plan.cache.hit"), 2);
    assert_eq!(m.counter("plan.cache.miss"), 4);
    assert_eq!(m.counter("plan.cache.evicted"), 2);

    // Geometry-pinned keys are distinct entries; impossible geometry is
    // a typed error and never cached.
    let pinned = cache.get_or_compile(&add1, 96, Some(&m)).unwrap();
    assert!(!Arc::ptr_eq(&a3, &pinned), "geometry is part of the key");
    let err = cache.get_or_compile(&add1, 16, Some(&m)).unwrap_err();
    assert_eq!(err, PudError::RowBudgetExceeded { needed: 32, available: 16 });
    assert_eq!(cache.len(), 2, "errors must not occupy cache slots");
}
