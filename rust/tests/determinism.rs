//! Determinism suite for the column-tiled sampling kernel.
//!
//! The kernel's contract (see `calib::algorithm` module docs): every
//! column draws from a stream derived from its logical address, so
//! calibration levels and ECR error counts are **bit-identical** for
//! any tile size and any worker count — and the per-tile streams must
//! still reproduce the paper-anchored statistics.

use pudtune::calib::algorithm::{CalibParams, NativeEngine};
use pudtune::calib::lattice::FracConfig;
use pudtune::config::device::DeviceConfig;
use pudtune::config::system::SystemConfig;
use pudtune::coordinator::worker;
use pudtune::dram::subarray::Subarray;

const COLS: usize = 1024;

fn device() -> (DeviceConfig, Subarray) {
    let cfg = DeviceConfig::default();
    let mut sys = SystemConfig::small();
    sys.cols = COLS;
    let sub = Subarray::new(&cfg, &sys, 0xD37);
    (cfg, sub)
}

/// Calibration levels + ECR error counts under an explicit kernel
/// geometry.
fn run(tile_cols: usize, threads: usize) -> (Vec<u8>, Vec<u32>) {
    let (cfg, sub) = device();
    let mut eng = NativeEngine::with_parallelism(cfg, tile_cols, threads);
    let calib = eng.calibrate(&sub, &FracConfig::pudtune([2, 1, 0]), &CalibParams::quick());
    let rep = eng.measure_ecr(&sub, &calib, 5, 2048);
    (calib.levels, rep.error_counts)
}

#[test]
fn kernel_is_tile_size_invariant() {
    // Tile widths 1, 64, and full-width on one worker must agree bit
    // for bit.
    let golden = run(COLS, 1);
    for tile in [1, 64, 37] {
        assert_eq!(run(tile, 1), golden, "tile_cols={tile}");
    }
}

#[test]
fn kernel_is_thread_count_invariant() {
    // One worker vs many (at several tile widths) must agree bit for
    // bit — per-(batch, column) streams make draw order irrelevant.
    let golden = run(64, 1);
    let n = worker::default_threads().max(2);
    for (tile, threads) in [(64, 2), (64, n), (1, n), (COLS, n), (37, 3)] {
        assert_eq!(run(tile, threads), golden, "tile={tile} threads={threads}");
    }
}

#[test]
fn engine_state_does_not_leak_across_calls() {
    // A fresh engine and a reused engine (scratch warm from other
    // work) must produce identical results.
    let (cfg, sub) = device();
    let p = CalibParams::quick();
    let fc = FracConfig::pudtune([2, 1, 0]);
    let mut fresh = NativeEngine::new(cfg.clone());
    let a = fresh.calibrate(&sub, &fc, &p);

    let mut reused = NativeEngine::new(cfg.clone());
    // Warm the scratch on a different geometry + config first.
    let mut sys2 = SystemConfig::small();
    sys2.cols = 333;
    let other = Subarray::new(&cfg, &sys2, 1);
    let _ = reused.calibrate(&other, &FracConfig::pudtune([1, 1, 0]), &p);
    let b = reused.calibrate(&sub, &fc, &p);
    assert_eq!(a.levels, b.levels);
}

#[test]
fn paper_anchor_baseline_ecr_is_high() {
    // §II-C anchor under the per-tile streams: the uncalibrated MAJ5
    // baseline degrades to roughly half the columns being error-prone.
    let cfg = DeviceConfig::default();
    let mut sys = SystemConfig::small();
    sys.cols = 4096;
    let sub = Subarray::new(&cfg, &sys, 3);
    let mut eng = NativeEngine::new(cfg.clone());
    let base = FracConfig::baseline(3).uncalibrated(&cfg, sub.cols);
    let ecr = eng.measure_ecr(&sub, &base, 5, 2048).ecr();
    assert!((0.30..0.65).contains(&ecr), "ecr={ecr}");
}

#[test]
fn paper_anchor_calibration_reduces_errors() {
    // Algorithm-1 anchor under the per-tile streams, and statistical
    // equivalence across kernel geometries: every geometry reports the
    // *same* ECRs (bit-stability), and those ECRs show the paper's
    // >3x error reduction.
    let (cfg, sub) = device();
    let base = FracConfig::baseline(3).uncalibrated(&cfg, sub.cols);
    let mut ecrs = Vec::new();
    for threads in [1, worker::default_threads().max(2)] {
        let mut eng = NativeEngine::with_parallelism(cfg.clone(), 64, threads);
        let tuned = eng.calibrate(&sub, &FracConfig::pudtune([2, 1, 0]), &CalibParams::paper());
        let ecr_b = eng.measure_ecr(&sub, &base, 5, 2048).ecr();
        let ecr_t = eng.measure_ecr(&sub, &tuned, 5, 2048).ecr();
        assert!(
            ecr_t < ecr_b / 3.0,
            "threads={threads}: base={ecr_b:.3} tuned={ecr_t:.3}"
        );
        ecrs.push((ecr_b.to_bits(), ecr_t.to_bits()));
    }
    assert_eq!(ecrs[0], ecrs[1]);
}
