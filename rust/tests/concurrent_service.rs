//! Concurrency suite for the threaded `RecalibService` server (runs
//! under ThreadSanitizer in CI): multi-client serving interleaved with
//! drift-triggered background recalibration, scrub passes, injected
//! worker panics, admission-control backpressure and graceful drain.
//!
//! Device/geometry are kept deliberately small — TSan runs these tests
//! with every memory access instrumented — and the *quiet* device
//! (vanishing analog noise, zero tempco) makes every served column
//! golden-model-correct at every lifecycle stage, so correctness
//! assertions are exact, not statistical.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use pudtune::analysis::ecr::EcrReport;
use pudtune::calib::algorithm::{CalibParams, Calibration, NativeEngine};
use pudtune::calib::engine::{
    CalibEngine, CalibRequest, ComputeEngine, ComputeRequest, ComputeResult, EcrRequest,
};
use pudtune::config::device::DeviceConfig;
use pudtune::coordinator::service::{
    EntryState, RecalibService, ServiceConfig, ServiceServer,
};
use pudtune::dram::geometry::SubarrayId;
use pudtune::pud::plan::{PudError, PudOp, WorkloadPlan};
use pudtune::util::rng::{derive_seed, Rng};

/// Vanishing analog noise AND zero tempco: a temperature excursion
/// still trips the drift *policy* (the monitor compares environments),
/// but the device itself stays perfect, so serving must stay golden
/// straight through the stale window and the background repair.
fn quiet_cfg() -> DeviceConfig {
    DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 1e-6,
        tempco: 0.0,
        tempco_jitter: 0.0,
        ..DeviceConfig::default()
    }
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        serve_samples: 128,
        params: CalibParams::quick(),
        maintain_every_ms: 5,
        ..ServiceConfig::default()
    }
}

fn register_banks<E: CalibEngine + Sync>(
    s: &RecalibService<E>,
    channels: usize,
    banks_per_channel: usize,
    rows: usize,
    cols: usize,
) -> Vec<SubarrayId> {
    let mut ids = Vec::new();
    for ch in 0..channels {
        for b in 0..banks_per_channel {
            let id = SubarrayId::new(ch, b, 0);
            s.register(id, rows, cols, 0x5EED);
            ids.push(id);
        }
    }
    ids
}

/// Spin until `cond` holds, failing the test after `secs` seconds.
fn wait_for(secs: u64, what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn concurrent_serves_stay_golden_during_background_recalibration() {
    let cols = 32;
    let svc_cfg = ServiceConfig { scrub_every: 3, ..service_cfg() };
    let cfg = quiet_cfg();
    let s = Arc::new(RecalibService::new(cfg.clone(), svc_cfg, NativeEngine::new(cfg)).unwrap());
    // Two channels: the serve path and the recalibration write-backs
    // exercise distinct shards concurrently.
    let ids = register_banks(&s, 2, 2, 32, cols);
    s.run_pending(usize::MAX);
    for o in s.serve() {
        o.report.as_ref().expect("mask battery");
    }

    let server = ServiceServer::start(s.clone(), 2);
    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 2 }).unwrap());
    let a: Vec<u64> = (0..cols as u64).map(|c| c % 4).collect();
    let b: Vec<u64> = (0..cols as u64).map(|c| (c * 5 + 2) % 4).collect();

    // Three client threads serve workloads while the main thread
    // injects a temperature excursion: the drift policy fires, the
    // background workers recalibrate, and every in-between serve must
    // still be golden on every active column.
    let served = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for t in 0..3 {
            let (s, plan, a, b) = (&s, &plan, &a, &b);
            let served = &served;
            scope.spawn(move || {
                let mut rng = Rng::new(0xC0FFEE + t as u64);
                for _ in 0..15 {
                    let outs = s.serve_plan(plan, &[a.clone(), b.clone()]).unwrap();
                    assert_eq!(outs.len(), 4);
                    for o in &outs {
                        assert!(o.result.is_ok(), "{:?}: {:?}", o.id, o.result);
                        assert!(o.active_cols > 0, "{:?} served no columns", o.id);
                        assert_eq!(
                            o.golden_correct, o.active_cols,
                            "{:?} diverged from the golden model mid-lifecycle",
                            o.id
                        );
                    }
                    served.fetch_add(1, Ordering::Relaxed);
                    if rng.next_u64() % 3 == 0 {
                        std::thread::yield_now();
                    }
                }
            });
        }
        // Let some serves land before the excursion so both the
        // accepted and the stale windows are exercised.
        wait_for(10, "first concurrent serves", || served.load(Ordering::Relaxed) >= 3);
        for &id in &ids {
            assert!(s.set_temperature(id, 85.0));
        }
        // The maintenance ticker turns the excursion into queued
        // background repairs; the workers complete all of them.
        wait_for(30, "background recalibration of every bank", || {
            s.metrics.counter("recalib.completed") >= 2 * ids.len() as u64
                && ids.iter().all(|&id| s.state(id) == Some(EntryState::Accepted))
        });
        // The scrub cadence fires on the background ticker too.
        wait_for(30, "a background scrub pass", || s.metrics.counter("scrub.passes") >= 1);
    });

    assert_eq!(s.metrics.counter("recalib.scheduled"), ids.len() as u64);
    assert_eq!(s.metrics.counter("compute.golden_mismatch"), 0);
    assert_eq!(s.metrics.counter("compute.bank_failures"), 0);

    // Graceful drain: queued work finishes, the store persists every
    // bank, and the service stops admitting.
    let store = server.drain();
    assert_eq!(store.entries.len(), ids.len());
    assert_eq!(s.pending(), 0);
    assert!(!s.is_accepting());
    assert!(s.metrics.counter("drain.persisted_entries") >= ids.len() as u64);
}

/// Counts calibration jobs per bank seed, so duplicated (or lost)
/// background recalibrations are directly observable (the count map is
/// shared with the test through the `Arc`).
struct CountingEngine {
    inner: NativeEngine,
    calibrations: Arc<Mutex<std::collections::BTreeMap<u64, u32>>>,
}

impl CalibEngine for CountingEngine {
    fn backend(&self) -> &'static str {
        "counting"
    }

    fn calibrate_batch(&self, reqs: &[CalibRequest]) -> anyhow::Result<Vec<Calibration>> {
        {
            let mut counts = self.calibrations.lock().unwrap();
            for r in reqs {
                *counts.entry(r.bank.seed).or_insert(0) += 1;
            }
        }
        self.inner.calibrate_batch(reqs)
    }

    fn measure_ecr_batch(&self, reqs: &[EcrRequest]) -> anyhow::Result<Vec<EcrReport>> {
        self.inner.measure_ecr_batch(reqs)
    }
}

impl ComputeEngine for CountingEngine {
    fn compute_backend(&self) -> &'static str {
        "counting"
    }

    fn execute_batch(&self, reqs: &[ComputeRequest]) -> anyhow::Result<Vec<ComputeResult>> {
        self.inner.execute_batch(reqs)
    }
}

#[test]
fn background_recalibrations_are_exactly_once() {
    let cfg = quiet_cfg();
    let counts = Arc::new(Mutex::new(std::collections::BTreeMap::new()));
    let engine = CountingEngine {
        inner: NativeEngine::new(cfg.clone()),
        calibrations: counts.clone(),
    };
    let s = Arc::new(RecalibService::new(cfg, service_cfg(), engine).unwrap());
    let ids = register_banks(&s, 1, 3, 32, 32);
    // Synchronous cold start: exactly one calibration per bank.
    s.run_pending(usize::MAX);

    let server = ServiceServer::start(s.clone(), 2);
    // Each round flips every bank past the temperature threshold; one
    // drift signal per bank per round must mean exactly one background
    // recalibration per bank per round — the maintenance ticker keeps
    // polling (fast) while the repair is in flight, and neither the
    // queued flag nor the running window may let it double-schedule.
    let rounds: &[f64] = &[85.0, 45.0, 85.0];
    for (round, &temp) in rounds.iter().enumerate() {
        for &id in &ids {
            s.set_temperature(id, temp);
        }
        let want = ((round + 1) * ids.len()) as u64;
        wait_for(30, "the round's background recalibrations", || {
            s.metrics.counter("recalib.completed") >= want
                && ids.iter().all(|&id| s.state(id) == Some(EntryState::Accepted))
        });
    }
    let store = server.drain();
    assert_eq!(store.entries.len(), ids.len());

    assert_eq!(s.metrics.counter("recalib.failed"), 0);
    assert_eq!(s.metrics.counter("recalib.scheduled"), (rounds.len() * ids.len()) as u64);
    assert_eq!(
        s.metrics.counter("recalib.completed"),
        ((rounds.len() + 1) * ids.len()) as u64,
        "cold start + one repair per bank per round, nothing lost or duplicated"
    );
    // The engine-level ground truth: every bank was calibrated exactly
    // once per round plus its cold start — a duplicate (same drift
    // signal recalibrated twice) or a loss (signal never repaired)
    // would show directly in the per-seed counts.
    let counts = counts.lock().unwrap();
    for &id in &ids {
        let seed = derive_seed(0x5EED, &id.seed_path());
        assert_eq!(
            counts.get(&seed).copied(),
            Some(1 + rounds.len() as u32),
            "{id:?} calibration count"
        );
    }
}

#[test]
fn drain_finishes_every_queued_cold_start_job() {
    let cfg = quiet_cfg();
    let s = Arc::new(
        RecalibService::new(cfg.clone(), service_cfg(), NativeEngine::new(cfg)).unwrap(),
    );
    let ids = register_banks(&s, 2, 2, 32, 32);
    assert_eq!(s.pending(), ids.len());
    // Start and immediately drain: the graceful path must still finish
    // every queued cold-start calibration before persisting.
    let server = ServiceServer::start(s.clone(), 3);
    let store = server.drain();
    assert_eq!(store.entries.len(), ids.len(), "drain abandons no queued job");
    assert_eq!(s.pending(), 0);
    for &id in &ids {
        assert_eq!(s.state(id), Some(EntryState::Accepted));
    }
    assert_eq!(s.metrics.counter("recalib.completed"), ids.len() as u64);
    assert!(s.metrics.counter("drain.pending_jobs") > 0);
    assert_eq!(s.metrics.counter("drain.abandoned_jobs"), 0);
}

/// Panics whenever a calibration batch touches the poisoned bank —
/// a hard backend fault injected on the *threaded* recalibration path.
struct PanickingEngine {
    inner: NativeEngine,
    poison_seed: u64,
}

impl CalibEngine for PanickingEngine {
    fn backend(&self) -> &'static str {
        "panicking"
    }

    fn calibrate_batch(&self, reqs: &[CalibRequest]) -> anyhow::Result<Vec<Calibration>> {
        for r in reqs {
            assert_ne!(r.bank.seed, self.poison_seed, "injected backend fault");
        }
        self.inner.calibrate_batch(reqs)
    }

    fn measure_ecr_batch(&self, reqs: &[EcrRequest]) -> anyhow::Result<Vec<EcrReport>> {
        self.inner.measure_ecr_batch(reqs)
    }
}

impl ComputeEngine for PanickingEngine {
    fn compute_backend(&self) -> &'static str {
        "panicking"
    }

    fn execute_batch(&self, reqs: &[ComputeRequest]) -> anyhow::Result<Vec<ComputeResult>> {
        self.inner.execute_batch(reqs)
    }
}

#[test]
fn worker_panic_mid_recalibration_degrades_one_bank_not_the_server() {
    let cfg = quiet_cfg();
    let device_seed = 0xBAD5EED;
    let poison = SubarrayId::new(0, 1, 0);
    let engine = PanickingEngine {
        inner: NativeEngine::new(cfg.clone()),
        poison_seed: derive_seed(device_seed, &poison.seed_path()),
    };
    // A slower ticker keeps the failed bank's retry churn bounded
    // while the test asserts on the sharded map.
    let svc_cfg = ServiceConfig { maintain_every_ms: 50, ..service_cfg() };
    let s = Arc::new(RecalibService::new(cfg, svc_cfg, engine).unwrap());
    let mut ids = Vec::new();
    for b in 0..3 {
        let id = SubarrayId::new(0, b, 0);
        s.register(id, 32, 32, device_seed);
        ids.push(id);
    }

    // Cold start runs ON the worker threads: bank 1's job panics
    // inside a background worker, and must degrade to exactly that
    // bank — no poisoned shard, no dead worker, no aborted process.
    let server = ServiceServer::start(s.clone(), 2);
    wait_for(30, "background cold start around the poisoned bank", || {
        s.metrics.counter("recalib.completed") >= 2 && s.metrics.counter("recalib.failed") >= 1
    });
    assert_eq!(s.state(SubarrayId::new(0, 0, 0)), Some(EntryState::Accepted));
    assert_eq!(s.state(poison), Some(EntryState::Uncalibrated));
    assert_eq!(s.state(SubarrayId::new(0, 2, 0)), Some(EntryState::Accepted));

    // The sharded map stays fully usable from concurrent clients: the
    // quiet device serves golden even on the uncalibrated bank's
    // neutral levels.
    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 2 }).unwrap());
    let a: Vec<u64> = (0..32u64).map(|c| c % 4).collect();
    std::thread::scope(|scope| {
        for _ in 0..2 {
            let (s, plan, a) = (&s, &plan, &a);
            scope.spawn(move || {
                for _ in 0..5 {
                    let outs = s.serve_plan(plan, &[a.clone(), a.clone()]).unwrap();
                    assert_eq!(outs.len(), 3);
                    for o in &outs {
                        assert!(o.result.is_ok(), "{:?}: {:?}", o.id, o.result);
                        assert_eq!(o.golden_correct, o.active_cols);
                    }
                }
            });
        }
    });
    assert!(s.serve().iter().all(|o| o.report.is_ok()));
    assert_eq!(s.quarantine(poison).map(|q| q.quarantined_cols()), Some(0));

    // Drain still terminates: the maintenance ticker stops
    // rescheduling once admission closes, the workers fail the last
    // queued retry and exit cleanly.
    let store = server.drain();
    assert_eq!(store.entries.len(), 2, "only the calibrated banks persist");
    assert_eq!(s.state(poison), Some(EntryState::Uncalibrated));
}

#[test]
fn admission_backpressure_is_bounded_and_exactly_once() {
    let cols = 64;
    let cfg = quiet_cfg();
    let svc_cfg = ServiceConfig { max_inflight_serves: 2, ..service_cfg() };
    let s = Arc::new(RecalibService::new(cfg.clone(), svc_cfg, NativeEngine::new(cfg)).unwrap());
    register_banks(&s, 1, 2, 96, cols);
    s.run_pending(usize::MAX);
    let server = ServiceServer::start(s.clone(), 1);

    // Randomized burst: 8 clients, 25 calls each, random pauses. Every
    // call must resolve to exactly one of {served, typed rejection
    // carrying the configured bound} — nothing lost, nothing blocked.
    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 4 }).unwrap());
    let a: Vec<u64> = (0..cols as u64).map(|c| c % 16).collect();
    let b: Vec<u64> = (0..cols as u64).map(|c| (c * 7 + 3) % 16).collect();
    let served = AtomicUsize::new(0);
    let rejected = AtomicUsize::new(0);
    let threads = 8;
    let calls_per_thread = 25;
    std::thread::scope(|scope| {
        for t in 0..threads {
            let (s, plan, a, b) = (&s, &plan, &a, &b);
            let (served, rejected) = (&served, &rejected);
            scope.spawn(move || {
                let mut rng = Rng::new(0xAD417 + t as u64);
                for _ in 0..calls_per_thread {
                    match s.serve_plan(plan, &[a.clone(), b.clone()]) {
                        Ok(outs) => {
                            assert_eq!(outs.len(), 2);
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(PudError::Overloaded { inflight, limit }) => {
                            assert_eq!(limit, 2);
                            assert!(inflight >= limit, "rejection below the bound");
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("unexpected serve error: {e}"),
                    }
                    if rng.next_u64() % 2 == 0 {
                        std::thread::yield_now();
                    }
                }
            });
        }
    });
    let total = (threads * calls_per_thread) as u64;
    let (served, rejected) =
        (served.load(Ordering::Relaxed) as u64, rejected.load(Ordering::Relaxed) as u64);
    assert_eq!(served + rejected, total, "every call served-or-rejected exactly once");
    assert_eq!(s.metrics.counter("admission.accepted"), served);
    assert_eq!(s.metrics.counter("admission.rejected"), rejected);
    assert!(
        s.metrics.counter("serve.concurrent") <= 2,
        "in-flight serves exceeded the admission bound: {}",
        s.metrics.counter("serve.concurrent")
    );
    assert!(served > 0, "the burst must serve something");
    assert!(rejected > 0, "8 clients against a bound of 2 must hit backpressure");

    // drain() always terminates, even right after a burst — run it on
    // a helper thread and hold it to a deadline.
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        let store = server.drain();
        tx.send(store.entries.len()).unwrap();
    });
    let persisted = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("drain must terminate promptly after a serve burst");
    handle.join().unwrap();
    assert_eq!(persisted, 2);
    // Post-drain serves are rejected with the draining error, not
    // queued forever.
    assert_eq!(
        s.serve_plan(&plan, &[a, b]).unwrap_err(),
        PudError::Draining
    );
    assert!(s.metrics.counter("admission.rejected_draining") >= 1);
}
