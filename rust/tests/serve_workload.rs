//! Integration: arithmetic serving through `RecalibService::serve_workload`
//! interleaved with drift-triggered background recalibration — outputs
//! must stay golden-model-correct on the error-free masks throughout
//! the whole lifecycle (accepted → stale → recalibrated), and a
//! geometry-mismatched bank must degrade alone.

use pudtune::calib::algorithm::{CalibParams, NativeEngine};
use pudtune::calib::drift::{DriftPolicy, DriftSignal};
use pudtune::config::device::DeviceConfig;
use pudtune::coordinator::service::{EntryState, RecalibService, ServiceConfig};
use pudtune::dram::geometry::SubarrayId;
use pudtune::pud::plan::{PudOp, WorkloadPlan};
use pudtune::util::rng::Rng;
use std::sync::Arc;

fn quiet_cfg() -> DeviceConfig {
    DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 1e-6,
        ..DeviceConfig::default()
    }
}

fn quiet_service(policy: DriftPolicy, banks: usize, cols: usize) -> RecalibService<NativeEngine> {
    let cfg = quiet_cfg();
    let svc = ServiceConfig {
        policy,
        serve_samples: 512,
        params: CalibParams::quick(),
        ..ServiceConfig::default()
    };
    let s = RecalibService::new(cfg.clone(), svc, NativeEngine::new(cfg)).unwrap();
    for b in 0..banks {
        s.register(SubarrayId::new(0, b, 0), 96, cols, 0x5EED);
    }
    s
}

#[test]
fn serving_stays_golden_through_drift_and_recalibration() {
    // Age-based drift: every 1.5 simulated hours the calibrations age;
    // past 2 hours the policy schedules background recalibration. The
    // quiet device keeps every column error-free, so every served
    // output must equal the golden model at every lifecycle stage.
    let policy = DriftPolicy { max_age_hours: 2.0, ..DriftPolicy::default() };
    let cols = 64;
    let s = quiet_service(policy, 2, cols);
    s.run_pending(usize::MAX);
    // One measurement battery establishes the per-bank masks.
    for o in s.serve() {
        assert!(o.report.is_ok());
    }

    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 4 }).unwrap());
    let mut rng = Rng::new(7);
    let mut saw_stale_serving = false;
    let mut recalibrations = 0usize;
    for tick in 0..5 {
        let signals = s.poll_drift();
        for (_, sig) in &signals {
            assert!(matches!(sig, DriftSignal::RetentionAge { .. }), "{sig}");
        }
        // Serve arithmetic *while possibly stale* — serving never
        // waits on the recalibration queue.
        let a: Vec<u64> = (0..cols).map(|_| rng.below(16)).collect();
        let b: Vec<u64> = (0..cols).map(|_| rng.below(16)).collect();
        let out = s
            .serve_workload(PudOp::Add { width: 4 }, &[a.clone(), b.clone()])
            .unwrap();
        assert_eq!(out.len(), 2);
        for o in &out {
            let res = o.result.as_ref().expect("bank served");
            if o.state == EntryState::Stale {
                saw_stale_serving = true;
            }
            assert_eq!(
                o.golden_correct, o.active_cols,
                "tick {tick} {:?}: served output diverged from the golden model",
                o.id
            );
            assert!(o.active_cols > 0, "tick {tick}: empty mask");
            for c in 0..cols {
                if let Some(v) = res.output(c) {
                    assert_eq!(v, a[c] + b[c], "tick {tick} col {c}");
                }
            }
        }
        // The precompiled-plan path serves identically.
        let replay = s.serve_plan(&plan, &[a.clone(), b.clone()]).expect("compiled plan serves");
        for (o, r) in out.iter().zip(&replay) {
            assert_eq!(
                o.result.as_ref().unwrap().outputs,
                r.result.as_ref().unwrap().outputs
            );
        }
        // Background repair of whatever drift scheduled.
        if !signals.is_empty() {
            let done = s.run_pending(usize::MAX);
            assert_eq!(done.len(), signals.len());
            assert!(done.iter().all(|(_, r)| r.is_ok()));
            recalibrations += done.len();
            // A fresh battery re-establishes the masks the next
            // workload serves under.
            s.serve();
        }
        s.advance_time(1.5);
    }
    assert!(recalibrations >= 2, "age drift never fired ({recalibrations})");
    assert!(saw_stale_serving, "stale entries must keep serving");
    assert!(s.metrics.counter("recalib.scheduled") >= 1);
    assert_eq!(s.metrics.counter("compute.golden_mismatch"), 0);
    assert_eq!(s.metrics.counter("compute.bank_failures"), 0);
    assert!(s.metrics.counter("compute.batches") >= 20);
}

#[test]
fn geometry_mismatched_bank_degrades_alone() {
    let cols = 64;
    let s = quiet_service(DriftPolicy::default(), 1, cols);
    // A second bank with a different geometry cannot serve 64-column
    // operands: it must fail alone, typed, without poisoning the pool.
    s.register(SubarrayId::new(0, 9, 0), 96, cols / 2, 0x5EED);
    s.run_pending(usize::MAX);
    let a: Vec<u64> = (0..cols).map(|c| c as u64 % 16).collect();
    let b: Vec<u64> = (0..cols).map(|c| (c as u64 * 3) % 16).collect();
    let out = s.serve_workload(PudOp::Add { width: 4 }, &[a, b]).unwrap();
    assert_eq!(out.len(), 2);
    let healthy = &out[0];
    let mismatched = &out[1];
    assert_eq!(healthy.id, SubarrayId::new(0, 0, 0));
    assert!(healthy.result.is_ok());
    assert_eq!(healthy.golden_correct, healthy.active_cols);
    let err = mismatched.result.as_ref().unwrap_err();
    assert!(err.contains("width mismatch"), "{err}");
    assert_eq!(s.metrics.counter("compute.bank_failures"), 1);
    assert_eq!(s.metrics.counter("compute.batches"), 1);
}
