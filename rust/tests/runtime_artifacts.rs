//! Runtime integration: every artifact in the manifest loads, compiles
//! and executes on the PJRT CPU client with manifest-shaped inputs.

use pudtune::config::device::DeviceConfig;
use pudtune::runtime::{buffers, Runtime};

mod common;

fn rt() -> Option<Runtime> {
    common::open_runtime()
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = rt() else { return };
    let names = rt.artifact_names();
    for required in [
        "maj5_step_small",
        "maj5_ecr_small",
        "maj3_step_small",
        "maj3_ecr_small",
        "maj5_eval_small",
        "pud_gemv_64x256",
    ] {
        assert!(names.iter().any(|n| n == required), "missing {required}");
    }
}

#[test]
fn physics_json_matches_rust_defaults() {
    // The Python build step and the Rust model must agree on the
    // physics constants (single-source check, DESIGN.md §3).
    let Some(rt) = rt() else { return };
    let j = rt.physics_json().unwrap();
    let from_py = DeviceConfig::from_physics_json(&j).unwrap();
    let rust = DeviceConfig::default();
    assert_eq!(from_py.cc_ff, rust.cc_ff);
    assert_eq!(from_py.cb_ff, rust.cb_ff);
    assert_eq!(from_py.simra_rows, rust.simra_rows);
    assert!((from_py.frac_r - rust.frac_r).abs() < 1e-9);
    assert!(
        (from_py.sigma_sa - rust.sigma_sa).abs() < 1e-9,
        "sigma_sa drifted: py={} rust={}",
        from_py.sigma_sa,
        rust.sigma_sa
    );
}

#[test]
fn every_artifact_executes() {
    let Some(rt) = rt() else { return };
    for name in rt.artifact_names() {
        let exe = rt.load(&name).unwrap();
        // Build zero-ish inputs per the manifest signature.
        let mut args = Vec::new();
        for spec in &exe.inputs {
            let count: usize = spec.shape.iter().product::<usize>().max(1);
            let lit = match spec.dtype.as_str() {
                "float32" => {
                    let data = vec![0.25f32; count];
                    if spec.shape.is_empty() {
                        buffers::f32_scalar(0.25)
                    } else {
                        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                        buffers::f32_array(&data, &dims).unwrap()
                    }
                }
                "int32" => buffers::i32_vec(&vec![0i32; count]),
                "uint32" => buffers::u32_scalar(7),
                other => panic!("{name}: unhandled dtype {other}"),
            };
            args.push(lit);
        }
        let out = exe.run(&args).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(out.len(), exe.outputs.len(), "{name}");
    }
}

#[test]
fn unknown_artifact_errors_cleanly() {
    let Some(rt) = rt() else { return };
    let err = match rt.load("nonexistent_graph") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("not in manifest"), "{err}");
}

#[test]
fn executable_rejects_wrong_arity() {
    let Some(rt) = rt() else { return };
    let exe = rt.load("maj5_eval_small").unwrap();
    let err = match exe.run(&[buffers::f32_scalar(1.0)]) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected arity error"),
    };
    assert!(err.contains("expected"), "{err}");
}
