//! Integration: 8-bit arithmetic executed bit-serially *in* the
//! simulated DRAM, baseline vs calibrated — the paper's Table I
//! workloads at functional level.

use pudtune::calib::algorithm::{CalibParams, NativeEngine};
use pudtune::calib::lattice::FracConfig;
use pudtune::config::device::DeviceConfig;
use pudtune::config::system::Ddr4Timing;
use pudtune::dram::geometry::RowMap;
use pudtune::dram::subarray::Subarray;
use pudtune::pud::adder::ripple_adder;
use pudtune::pud::exec::run_circuit;
use pudtune::pud::graph::MajCircuit;
use pudtune::pud::multiplier::array_multiplier;
use pudtune::util::rng::Rng;

fn encode(vals: &[u64], bit: usize) -> Vec<u8> {
    vals.iter().map(|&v| ((v >> bit) & 1) as u8).collect()
}

fn decode(outputs: &[Vec<u8>], col: usize) -> u64 {
    let mut v = 0u64;
    for (bit, out) in outputs.iter().enumerate() {
        v |= (out[col] as u64) << bit;
    }
    v
}

/// Run a circuit on a calibrated subarray over random operands and
/// return the fraction of columns computing perfectly.
fn correct_fraction(
    circuit: &MajCircuit,
    width: usize,
    sub: &mut Subarray,
    fc: &FracConfig,
    calib: &pudtune::calib::algorithm::Calibration,
    expect: impl Fn(u64, u64) -> u64,
    seed: u64,
) -> f64 {
    let grade = Ddr4Timing::ddr4_2133();
    let map = RowMap::standard(sub.rows);
    let mut rng = Rng::new(seed);
    let cols = sub.cols;
    let a: Vec<u64> = (0..cols).map(|_| rng.below(256)).collect();
    let b: Vec<u64> = (0..cols).map(|_| rng.below(256)).collect();
    let mut inputs = Vec::new();
    for bit in 0..width {
        inputs.push(encode(&a, bit));
    }
    for bit in 0..width {
        inputs.push(encode(&b, bit));
    }
    let run = run_circuit(sub, &map, calib, fc, &grade, circuit, &inputs)
        .expect("well-formed request");
    let mut ok = 0;
    for c in 0..cols {
        if decode(&run.outputs, c) == expect(a[c], b[c]) {
            ok += 1;
        }
    }
    ok as f64 / cols as f64
}

#[test]
fn calibration_rescues_in_dram_addition() {
    let cfg = DeviceConfig::default();
    let cols = 128;
    let width = 8;
    let circuit = ripple_adder(width);
    let mut sub = Subarray::with_geometry(&cfg, 96, cols, 0xADD1);
    let mut eng = NativeEngine::new(cfg.clone());

    let tune = FracConfig::pudtune([2, 1, 0]);
    let calib = eng.calibrate(&mut sub, &tune, &CalibParams::paper());
    let ok_tuned = correct_fraction(&circuit, width, &mut sub, &tune, &calib, |a, b| a + b, 1);

    let base = FracConfig::baseline(3);
    let base_cal = base.uncalibrated(&cfg, cols);
    let ok_base = correct_fraction(&circuit, width, &mut sub, &base, &base_cal, |a, b| a + b, 1);

    // An 8-bit add chains 16 majority ops per column: with ~47% of
    // columns MAJ5-error-prone the baseline mostly fails, while the
    // calibrated device computes correctly on the large majority.
    assert!(ok_tuned > 0.7, "tuned correct fraction {ok_tuned}");
    assert!(ok_tuned > ok_base + 0.15, "tuned {ok_tuned} vs base {ok_base}");
}

#[test]
fn calibrated_multiplication_works_on_clean_columns() {
    // 4-bit multiply (manageable gate count) on a calibrated subarray.
    let cfg = DeviceConfig::default();
    let cols = 64;
    let width = 4;
    let circuit = array_multiplier(width);
    let mut sub = Subarray::with_geometry(&cfg, 128, cols, 0x3A15);
    let mut eng = NativeEngine::new(cfg.clone());
    let tune = FracConfig::pudtune([2, 1, 0]);
    let calib = eng.calibrate(&mut sub, &tune, &CalibParams::paper());
    let grade = Ddr4Timing::ddr4_2133();
    let map = RowMap::standard(sub.rows);
    let mut rng = Rng::new(9);
    let a: Vec<u64> = (0..cols).map(|_| rng.below(16)).collect();
    let b: Vec<u64> = (0..cols).map(|_| rng.below(16)).collect();
    let mut inputs = Vec::new();
    for bit in 0..width {
        inputs.push(encode(&a, bit));
    }
    for bit in 0..width {
        inputs.push(encode(&b, bit));
    }
    let run = run_circuit(&mut sub, &map, &calib, &tune, &grade, &circuit, &inputs)
        .expect("well-formed request");
    let mut ok = 0;
    for c in 0..cols {
        if decode(&run.outputs, c) == a[c] * b[c] {
            ok += 1;
        }
    }
    // The multiplier chains ~40 majority ops; every column must be
    // error-free across all of them, so expect most-but-not-all.
    assert!(ok as f64 / cols as f64 > 0.6, "ok={ok}/{cols}");
    assert!(run.elapsed_ns > 0.0);
}
