//! Integration: the drift-aware recalibration service's full
//! lifecycle — calibrate → persist → reboot → load + spot-check →
//! temperature excursion → drift detection → background recalibration
//! — plus the fault-isolation guarantee (an injected engine panic
//! degrades exactly one bank, never the process).

use pudtune::calib::engine::{CalibEngine, CalibRequest, EcrRequest};
use pudtune::prelude::*;

/// Device model with an exaggerated common-mode tempco: the stock
/// fitted value models the paper's differential sense amp, whose
/// excursions stay benign (Fig. 6a) — here we *want* a 40 °C excursion
/// to visibly break a nominal calibration so the repair is measurable.
fn drifty_cfg() -> DeviceConfig {
    DeviceConfig { tempco: 5.0e-4, tempco_jitter: 2.0e-5, ..DeviceConfig::default() }
}

fn service_over(cfg: &DeviceConfig, banks: usize, cols: usize) -> RecalibService<NativeEngine> {
    let svc = ServiceConfig { serve_samples: 2048, ..ServiceConfig::default() };
    let s = RecalibService::new(cfg.clone(), svc, NativeEngine::new(cfg.clone())).unwrap();
    for b in 0..banks {
        s.register(SubarrayId::new(0, b, 0), 32, cols, 0xD21F7);
    }
    s
}

fn mean_ecr(outcomes: &[ServeOutcome]) -> f64 {
    let ecrs: Vec<f64> = outcomes
        .iter()
        .map(|o| o.report.as_ref().expect("serve must not fail").ecr())
        .collect();
    ecrs.iter().sum::<f64>() / ecrs.len() as f64
}

fn total_error_free(outcomes: &[ServeOutcome]) -> usize {
    outcomes
        .iter()
        .map(|o| o.report.as_ref().expect("serve must not fail").error_free())
        .sum()
}

#[test]
fn full_lifecycle_detects_and_repairs_drift() {
    let cfg = drifty_cfg();
    let (banks, cols) = (2, 1024);

    // ---- Calibrate and persist (first boot). ----
    let mut first = service_over(&cfg, banks, cols);
    let done = first.run_pending(usize::MAX);
    assert_eq!(done.len(), banks);
    assert!(done.iter().all(|(_, r)| r.is_ok()));
    let nominal = first.serve();
    let nominal_ecr = mean_ecr(&nominal);
    assert!(nominal_ecr < 0.10, "calibrated nominal ECR {nominal_ecr}");
    let path = std::env::temp_dir().join("pudtune_drift_service_store.json");
    first.snapshot_store().save_file(&path).unwrap();

    // ---- Reboot: fresh device state, rehydrate from the store. ----
    let store = CalibStore::load_file(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let svc = service_over(&cfg, banks, cols);
    let outcomes = svc.load_store(&store);
    assert_eq!(outcomes.len(), banks);
    for (id, o) in &outcomes {
        assert!(matches!(o, LoadOutcome::Accepted { .. }), "{id:?}: {o:?}");
    }
    assert_eq!(svc.metrics.counter("recalib.accepted_on_load"), banks as u64);
    // Rehydration is bit-identical to the identified data.
    for &id in &svc.ids() {
        assert_eq!(
            svc.calibration(id).unwrap().levels,
            first.calibration(id).unwrap().levels
        );
    }
    // The cold-start queue entries were satisfied by the load.
    assert!(svc.run_pending(usize::MAX).is_empty());

    let accepted = svc.serve();
    let accepted_ecr = mean_ecr(&accepted);
    assert!(accepted_ecr < 0.10, "accepted ECR {accepted_ecr}");

    // ---- Temperature excursion: serving degrades but never stalls. ----
    for id in svc.ids() {
        assert!(svc.set_temperature(id, 85.0));
    }
    let stale = svc.serve();
    let stale_ecr = mean_ecr(&stale);
    let stale_free = total_error_free(&stale);
    assert!(
        stale_ecr > 3.0 * accepted_ecr && stale_ecr > 0.15,
        "excursion should visibly degrade ECR: {accepted_ecr} -> {stale_ecr}"
    );

    // ---- Drift detection schedules background recalibration. ----
    let signals = svc.poll_drift();
    assert_eq!(signals.len(), banks);
    for (_, sig) in &signals {
        assert!(matches!(sig, DriftSignal::TemperatureExcursion { delta_c } if *delta_c > 20.0));
    }
    assert_eq!(svc.metrics.counter("recalib.scheduled"), banks as u64);
    assert_eq!(svc.pending(), banks);
    // Stale entries keep serving from the old calibration meanwhile —
    // the serving path never stalls or panics on drifted entries.
    let while_stale = svc.serve();
    for o in &while_stale {
        assert_eq!(o.state, EntryState::Stale);
        assert!(o.report.is_ok());
    }

    // ---- Background recalibration restores the error-free count. ----
    let repairs = svc.run_pending(usize::MAX);
    assert_eq!(repairs.len(), banks);
    assert!(repairs.iter().all(|(_, r)| r.is_ok()));
    let repaired = svc.serve();
    let repaired_ecr = mean_ecr(&repaired);
    let repaired_free = total_error_free(&repaired);
    assert!(
        repaired_ecr < stale_ecr / 2.0 && repaired_ecr < 0.15,
        "recalibration should repair the excursion: {stale_ecr} -> {repaired_ecr}"
    );
    assert!(
        repaired_free > stale_free,
        "error-free columns must recover: {stale_free} -> {repaired_free}"
    );
    // Re-anchored at the hot point: the drift signal clears.
    assert!(svc.poll_drift().is_empty());
    // The refreshed calibrations persist for the next boot.
    assert_eq!(svc.snapshot_store().entries.len(), banks);
}

/// Engine wrapper that panics whenever a batch touches the poisoned
/// bank — simulating a hard backend fault on one bank.
struct PanickingEngine {
    inner: NativeEngine,
    poison_seed: u64,
}

impl CalibEngine for PanickingEngine {
    fn backend(&self) -> &'static str {
        "panicking"
    }

    fn calibrate_batch(&self, reqs: &[CalibRequest]) -> anyhow::Result<Vec<Calibration>> {
        for r in reqs {
            assert_ne!(r.bank.seed, self.poison_seed, "injected backend fault");
        }
        self.inner.calibrate_batch(reqs)
    }

    fn measure_ecr_batch(&self, reqs: &[EcrRequest]) -> anyhow::Result<Vec<EcrReport>> {
        self.inner.measure_ecr_batch(reqs)
    }
}

#[test]
fn injected_worker_panic_degrades_exactly_one_bank() {
    let cfg = DeviceConfig::default();
    let (banks, cols, device_seed) = (3usize, 512usize, 0xBAD5EEDu64);
    // The service derives per-subarray seeds along the address path;
    // poison bank 1's.
    let poison_seed =
        pudtune::util::rng::derive_seed(device_seed, &SubarrayId::new(0, 1, 0).seed_path());
    let engine = PanickingEngine { inner: NativeEngine::new(cfg.clone()), poison_seed };
    let svc_cfg = ServiceConfig {
        params: CalibParams::quick(),
        serve_samples: 512,
        ..ServiceConfig::default()
    };
    let svc = RecalibService::new(cfg, svc_cfg, engine).unwrap();
    for b in 0..banks {
        svc.register(SubarrayId::new(0, b, 0), 32, cols, device_seed);
    }

    let outcomes = svc.run_pending(usize::MAX);
    assert_eq!(outcomes.len(), banks);
    let failures: Vec<_> = outcomes.iter().filter(|(_, r)| r.is_err()).collect();
    assert_eq!(failures.len(), 1, "exactly one bank must fail: {outcomes:?}");
    assert_eq!(failures[0].0, SubarrayId::new(0, 1, 0));
    assert!(
        failures[0].1.as_ref().unwrap_err().contains("injected backend fault"),
        "the panic payload surfaces in the error"
    );
    assert_eq!(svc.metrics.counter("recalib.completed"), 2);
    assert_eq!(svc.metrics.counter("recalib.failed"), 1);
    assert_eq!(svc.state(SubarrayId::new(0, 0, 0)), Some(EntryState::Accepted));
    assert_eq!(svc.state(SubarrayId::new(0, 1, 0)), Some(EntryState::Uncalibrated));
    assert_eq!(svc.state(SubarrayId::new(0, 2, 0)), Some(EntryState::Accepted));

    // The coordinator keeps serving every bank — the failed one on its
    // neutral levels — with no process abort anywhere.
    let served = svc.serve();
    assert_eq!(served.len(), banks);
    assert!(served.iter().all(|o| o.report.is_ok()));

    // The failed bank is rescheduled on the next maintenance poll.
    assert_eq!(svc.pending(), 0);
    let signals = svc.poll_drift();
    assert!(signals.is_empty(), "a fault retry is not a drift signal");
    assert_eq!(svc.metrics.counter("recalib.rescheduled"), 1);
    assert_eq!(svc.pending(), 1);
}
