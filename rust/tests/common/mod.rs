//! Shared test support.

use pudtune::runtime::Runtime;

/// Open the PJRT runtime, or skip the calling test when the AOT
/// artifacts (an optional build product) are absent — offline checkouts
/// stay green. Artifact-enabled CI must export
/// `PUDTUNE_REQUIRE_ARTIFACTS=1` so a loading regression fails loudly
/// instead of silently skipping.
pub fn open_runtime() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) if std::env::var_os("PUDTUNE_REQUIRE_ARTIFACTS").is_some() => {
            panic!("PUDTUNE_REQUIRE_ARTIFACTS set but artifacts unavailable: {e}")
        }
        Err(e) => {
            eprintln!("skipping: PJRT artifacts unavailable ({e})");
            None
        }
    }
}
