//! Property-based invariants across modules (own harness — see
//! `util::proptest`).

use pudtune::calib::algorithm::{const_q, Calibration};
use pudtune::calib::lattice::{FracConfig, OffsetLattice};
use pudtune::config::device::DeviceConfig;
use pudtune::config::system::Ddr4Timing;
use pudtune::controller::power::ActPowerModel;
use pudtune::controller::timing::{majx_cost, PrimitiveTiming};
use pudtune::pud::adder::{eval_add, ripple_adder};
use pudtune::pud::graph::{Gate, MajCircuit, Signal};
use pudtune::pud::multiplier::{array_multiplier, eval_mul};
use pudtune::util::json;
use pudtune::util::proptest::{check, check_res};
use pudtune::util::rng::Rng;

#[test]
fn lattice_offsets_are_monotone_and_symmetric() {
    let cfg = DeviceConfig::default();
    check_res(
        "lattice-monotone-symmetric",
        1,
        128,
        |r: &mut Rng| {
            [
                r.below(7) as u32,
                r.below(7) as u32,
                r.below(7) as u32,
            ]
        },
        |&fracs| {
            let lat = OffsetLattice::build(&cfg, &FracConfig::pudtune(fracs));
            // Monotone by construction.
            for w in lat.levels.windows(2) {
                if w[1].q_total < w[0].q_total - 1e-12 {
                    return Err("not sorted".into());
                }
            }
            // Bit-complement symmetry: Q(b) + Q(!b) = 3.0.
            for lv in &lat.levels {
                let inv = [1 - lv.bits[0], 1 - lv.bits[1], 1 - lv.bits[2]];
                let q_inv: f64 = (0..3)
                    .map(|i| cfg.frac_charge(inv[i] as f64, fracs[i]))
                    .sum();
                if (lv.q_total + q_inv - 3.0).abs() > 1e-9 {
                    return Err(format!("asymmetric at {:?}", lv.bits));
                }
            }
            // Offsets bounded by the zero-frac full swing.
            let bound = 1.5 * cfg.cc_ff / (8.0 * cfg.cc_ff + cfg.cb_ff) + 1e-12;
            if lat.range().0 < -bound || lat.range().1 > bound {
                return Err("range exceeds physical bound".into());
            }
            Ok(())
        },
    );
}

#[test]
fn calibration_row_bits_roundtrip_levels() {
    let cfg = DeviceConfig::default();
    check(
        "row-bits-roundtrip",
        2,
        64,
        |r: &mut Rng| {
            let fracs = [r.below(5) as u32, r.below(5) as u32, r.below(5) as u32];
            let levels: Vec<u8> = (0..64).map(|_| r.below(8) as u8).collect();
            (fracs, levels)
        },
        |(fracs, levels)| {
            let lat = OffsetLattice::build(&cfg, &FracConfig::pudtune(*fracs));
            let mut c = Calibration::uniform(lat, levels.len());
            c.levels = levels.clone();
            // Rebuild each column's total charge from the 3 row-bit
            // patterns and per-row Frac counts; must equal q_extra.
            let rows: Vec<Vec<u8>> = (0..3).map(|r| c.row_bits(r)).collect();
            (0..levels.len()).all(|col| {
                let q: f64 = (0..3)
                    .map(|r| cfg.frac_charge(rows[r][col] as f64, fracs[r]))
                    .sum();
                (q - c.q_extra(col)).abs() < 1e-9
            })
        },
    );
}

#[test]
fn majority_circuits_match_integer_arithmetic() {
    check(
        "adder-and-multiplier-match",
        3,
        48,
        |r: &mut Rng| {
            let w = 2 + r.below(5) as usize; // widths 2..=6
            (w, r.below(1 << 6), r.below(1 << 6))
        },
        |&(w, a0, b0)| {
            let mask = (1u64 << w) - 1;
            let (a, b) = (a0 & mask, b0 & mask);
            let add = ripple_adder(w);
            let mul = array_multiplier(w);
            eval_add(&add, w, a, b) == a + b && eval_mul(&mul, w, a, b) == a * b
        },
    );
}

#[test]
fn majority_gate_is_monotone() {
    // Flipping any input 0->1 never flips the output 1->0.
    check_res(
        "maj-monotone",
        4,
        96,
        |r: &mut Rng| {
            let arity = if r.bool(0.5) { 3 } else { 5 };
            let bits: Vec<bool> = (0..arity).map(|_| r.bool(0.5)).collect();
            bits
        },
        |bits| {
            let arity = bits.len();
            let mut c = MajCircuit::new(arity);
            let args: Vec<Signal> = (0..arity).map(Signal::Input).collect();
            let g = if arity == 3 {
                c.push(Gate::maj3(args[0], args[1], args[2]))
            } else {
                c.push(Gate::maj5(args[0], args[1], args[2], args[3], args[4]))
            };
            c.output(g);
            let base = c.eval(bits)[0];
            for i in 0..arity {
                if !bits[i] {
                    let mut up = bits.clone();
                    up[i] = true;
                    if base && !c.eval(&up)[0] {
                        return Err(format!("non-monotone at input {i}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn majx_cost_is_affine_in_fracs() {
    let pt = PrimitiveTiming::from_grade(&Ddr4Timing::ddr4_2133());
    check(
        "cost-affine",
        5,
        32,
        |r: &mut Rng| (r.below(10) as u32, r.below(10) as u32),
        |&(f1, f2)| {
            let a = majx_cost(&pt, 5, f1);
            let b = majx_cost(&pt, 5, f2);
            let d_lat = b.latency_ns - a.latency_ns;
            let expect = (f2 as f64 - f1 as f64) * pt.frac_ns;
            (d_lat - expect).abs() < 1e-9 && (b.acts as i64 - a.acts as i64)
                == (f2 as i64 - f1 as i64) * pt.frac_acts as i64
        },
    );
}

#[test]
fn act_power_period_is_monotone_in_load() {
    let pm = ActPowerModel::from_grade(&Ddr4Timing::ddr4_2133());
    check(
        "power-monotone",
        6,
        64,
        |r: &mut Rng| {
            (
                10.0 + r.f64() * 1000.0,
                1 + r.below(64) as u32,
                1 + r.below(32) as usize,
            )
        },
        |&(lat, acts, banks)| {
            let p = pm.op_period_ns(lat, acts, banks);
            p >= lat
                && pm.op_period_ns(lat, acts + 1, banks) >= p
                && pm.op_period_ns(lat + 1.0, acts, banks) >= p
                && pm.op_period_ns(lat, acts, banks + 1) >= p
        },
    );
}

#[test]
fn json_roundtrips_arbitrary_trees() {
    check_res(
        "json-roundtrip",
        7,
        64,
        |r: &mut Rng| gen_json(r, 0),
        |j| {
            let text = j.to_string();
            let back = json::parse(&text).map_err(|e| e.to_string())?;
            if &back != j {
                return Err("mismatch after roundtrip".into());
            }
            let pretty = json::parse(&j.to_pretty()).map_err(|e| e.to_string())?;
            if &pretty != j {
                return Err("mismatch after pretty roundtrip".into());
            }
            Ok(())
        },
    );
}

fn gen_json(r: &mut Rng, depth: usize) -> json::Json {
    use json::Json;
    match if depth > 2 { r.below(4) } else { r.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(r.bool(0.5)),
        2 => Json::Num((r.range(-1_000_000, 1_000_000) as f64) / 64.0),
        3 => Json::Str(
            (0..r.below(12))
                .map(|_| char::from_u32(32 + r.below(90) as u32).unwrap())
                .collect(),
        ),
        4 => Json::Arr((0..r.below(4)).map(|_| gen_json(r, depth + 1)).collect()),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for i in 0..r.below(4) {
                m.insert(format!("k{i}"), gen_json(r, depth + 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn const_q_definition() {
    assert_eq!(const_q(5), 0.0);
    assert_eq!(const_q(3), 1.0);
}
