//! Property-based invariants across modules (own harness — see
//! `util::proptest`).

use pudtune::calib::algorithm::{const_q, Calibration};
use pudtune::calib::lattice::{FracConfig, OffsetLattice};
use pudtune::config::device::DeviceConfig;
use pudtune::config::system::Ddr4Timing;
use pudtune::controller::power::ActPowerModel;
use pudtune::controller::timing::{majx_cost, PrimitiveTiming};
use pudtune::dram::subarray::Subarray;
use pudtune::pud::adder::{eval_add, ripple_adder};
use pudtune::pud::graph::{Gate, MajCircuit, Signal};
use pudtune::pud::multiplier::{array_multiplier, eval_mul};
use pudtune::util::json;
use pudtune::util::proptest::{check, check_res};
use pudtune::util::rng::Rng;

#[test]
fn lattice_offsets_are_monotone_and_symmetric() {
    let cfg = DeviceConfig::default();
    check_res(
        "lattice-monotone-symmetric",
        1,
        128,
        |r: &mut Rng| {
            [
                r.below(7) as u32,
                r.below(7) as u32,
                r.below(7) as u32,
            ]
        },
        |&fracs| {
            let lat = OffsetLattice::build(&cfg, &FracConfig::pudtune(fracs));
            // Monotone by construction.
            for w in lat.levels.windows(2) {
                if w[1].q_total < w[0].q_total - 1e-12 {
                    return Err("not sorted".into());
                }
            }
            // Bit-complement symmetry: Q(b) + Q(!b) = 3.0.
            for lv in &lat.levels {
                let inv = [1 - lv.bits[0], 1 - lv.bits[1], 1 - lv.bits[2]];
                let q_inv: f64 = (0..3)
                    .map(|i| cfg.frac_charge(inv[i] as f64, fracs[i]))
                    .sum();
                if (lv.q_total + q_inv - 3.0).abs() > 1e-9 {
                    return Err(format!("asymmetric at {:?}", lv.bits));
                }
            }
            // Offsets bounded by the zero-frac full swing.
            let bound = 1.5 * cfg.cc_ff / (8.0 * cfg.cc_ff + cfg.cb_ff) + 1e-12;
            if lat.range().0 < -bound || lat.range().1 > bound {
                return Err("range exceeds physical bound".into());
            }
            Ok(())
        },
    );
}

#[test]
fn calibration_row_bits_roundtrip_levels() {
    let cfg = DeviceConfig::default();
    check(
        "row-bits-roundtrip",
        2,
        64,
        |r: &mut Rng| {
            let fracs = [r.below(5) as u32, r.below(5) as u32, r.below(5) as u32];
            let levels: Vec<u8> = (0..64).map(|_| r.below(8) as u8).collect();
            (fracs, levels)
        },
        |(fracs, levels)| {
            let lat = OffsetLattice::build(&cfg, &FracConfig::pudtune(*fracs));
            let mut c = Calibration::uniform(lat, levels.len());
            c.levels = levels.clone();
            // Rebuild each column's total charge from the 3 row-bit
            // patterns and per-row Frac counts; must equal q_extra.
            let rows: Vec<Vec<u8>> = (0..3).map(|r| c.row_bits(r)).collect();
            (0..levels.len()).all(|col| {
                let q: f64 = (0..3)
                    .map(|r| cfg.frac_charge(rows[r][col] as f64, fracs[r]))
                    .sum();
                (q - c.q_extra(col)).abs() < 1e-9
            })
        },
    );
}

#[test]
fn majority_circuits_match_integer_arithmetic() {
    check(
        "adder-and-multiplier-match",
        3,
        48,
        |r: &mut Rng| {
            let w = 2 + r.below(5) as usize; // widths 2..=6
            (w, r.below(1 << 6), r.below(1 << 6))
        },
        |&(w, a0, b0)| {
            let mask = (1u64 << w) - 1;
            let (a, b) = (a0 & mask, b0 & mask);
            let add = ripple_adder(w);
            let mul = array_multiplier(w);
            eval_add(&add, w, a, b) == a + b && eval_mul(&mul, w, a, b) == a * b
        },
    );
}

#[test]
fn majority_gate_is_monotone() {
    // Flipping any input 0->1 never flips the output 1->0.
    check_res(
        "maj-monotone",
        4,
        96,
        |r: &mut Rng| {
            let arity = if r.bool(0.5) { 3 } else { 5 };
            let bits: Vec<bool> = (0..arity).map(|_| r.bool(0.5)).collect();
            bits
        },
        |bits| {
            let arity = bits.len();
            let mut c = MajCircuit::new(arity);
            let args: Vec<Signal> = (0..arity).map(Signal::Input).collect();
            let g = if arity == 3 {
                c.push(Gate::maj3(args[0], args[1], args[2]))
            } else {
                c.push(Gate::maj5(args[0], args[1], args[2], args[3], args[4]))
            };
            c.output(g);
            let base = c.eval(bits)[0];
            for i in 0..arity {
                if !bits[i] {
                    let mut up = bits.clone();
                    up[i] = true;
                    if base && !c.eval(&up)[0] {
                        return Err(format!("non-monotone at input {i}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn majx_cost_is_affine_in_fracs() {
    let pt = PrimitiveTiming::from_grade(&Ddr4Timing::ddr4_2133());
    check(
        "cost-affine",
        5,
        32,
        |r: &mut Rng| (r.below(10) as u32, r.below(10) as u32),
        |&(f1, f2)| {
            let a = majx_cost(&pt, 5, f1);
            let b = majx_cost(&pt, 5, f2);
            let d_lat = b.latency_ns - a.latency_ns;
            let expect = (f2 as f64 - f1 as f64) * pt.frac_ns;
            (d_lat - expect).abs() < 1e-9 && (b.acts as i64 - a.acts as i64)
                == (f2 as i64 - f1 as i64) * pt.frac_acts as i64
        },
    );
}

#[test]
fn act_power_period_is_monotone_in_load() {
    let pm = ActPowerModel::from_grade(&Ddr4Timing::ddr4_2133());
    check(
        "power-monotone",
        6,
        64,
        |r: &mut Rng| {
            (
                10.0 + r.f64() * 1000.0,
                1 + r.below(64) as u32,
                1 + r.below(32) as usize,
            )
        },
        |&(lat, acts, banks)| {
            let p = pm.op_period_ns(lat, acts, banks);
            p >= lat
                && pm.op_period_ns(lat, acts + 1, banks) >= p
                && pm.op_period_ns(lat + 1.0, acts, banks) >= p
                && pm.op_period_ns(lat, acts, banks + 1) >= p
        },
    );
}

#[test]
fn json_roundtrips_arbitrary_trees() {
    check_res(
        "json-roundtrip",
        7,
        64,
        |r: &mut Rng| gen_json(r, 0),
        |j| {
            let text = j.to_string();
            let back = json::parse(&text).map_err(|e| e.to_string())?;
            if &back != j {
                return Err("mismatch after roundtrip".into());
            }
            let pretty = json::parse(&j.to_pretty()).map_err(|e| e.to_string())?;
            if &pretty != j {
                return Err("mismatch after pretty roundtrip".into());
            }
            Ok(())
        },
    );
}

fn gen_json(r: &mut Rng, depth: usize) -> json::Json {
    use json::Json;
    match if depth > 2 { r.below(4) } else { r.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(r.bool(0.5)),
        2 => Json::Num((r.range(-1_000_000, 1_000_000) as f64) / 64.0),
        3 => Json::Str(
            (0..r.below(12))
                .map(|_| char::from_u32(32 + r.below(90) as u32).unwrap())
                .collect(),
        ),
        4 => Json::Arr((0..r.below(4)).map(|_| gen_json(r, depth + 1)).collect()),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for i in 0..r.below(4) {
                m.insert(format!("k{i}"), gen_json(r, depth + 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn const_q_definition() {
    assert_eq!(const_q(5), 0.0);
    assert_eq!(const_q(3), 1.0);
}

/// A near-ideal device: packed-row reads must be error-free for any
/// in-spec temperature.
fn quiet_cfg() -> DeviceConfig {
    DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 0.0,
        ..DeviceConfig::default()
    }
}

#[test]
fn packed_rows_read_back_stored_bits_at_any_temperature() {
    // Invariant: a full-swing (packed) row reads back exactly its
    // stored bits on near-ideal columns regardless of die temperature
    // within spec — the 0.05 V_DD single-cell margin dwarfs the
    // temperature response of the thresholds.
    let cfg = quiet_cfg();
    check_res(
        "packed-roundtrip-any-temp",
        11,
        64,
        |r: &mut Rng| {
            let bits: Vec<u8> = (0..100).map(|_| r.bit()).collect();
            let temp_c = r.f64() * 85.0; // 0..85 C operating range
            let seed = r.next_u64();
            (bits, temp_c, seed)
        },
        |(bits, temp_c, seed)| {
            let mut s = Subarray::with_geometry(&cfg, 16, bits.len(), *seed);
            s.write_row(3, bits);
            s.set_temperature(*temp_c);
            if !s.row_is_packed(3) {
                return Err("written row must be packed".into());
            }
            let got = s.read_row(3);
            if &got != bits {
                return Err(format!("read-back differs at {temp_c:.1} C"));
            }
            if !s.row_is_packed(3) {
                return Err("restored row must stay packed".into());
            }
            Ok(())
        },
    );
}

#[test]
fn storage_state_machine_transitions() {
    // Invariants of the hybrid representation: frac always enters the
    // analog state, every restore (read / SiMRA / RowCopy) always
    // returns the touched rows to packed, and write/fill are packed by
    // construction.
    let cfg = DeviceConfig::default();
    check_res(
        "storage-state-machine",
        12,
        64,
        |r: &mut Rng| {
            let row = r.below(16) as usize;
            let fracs = 1 + r.below(4) as u32;
            let seed = r.next_u64();
            (row, fracs, seed)
        },
        |&(row, fracs, seed)| {
            let mut s = Subarray::with_geometry(&cfg, 16, 64, seed);
            s.fill_row(row, 1);
            for _ in 0..fracs {
                s.frac(row);
                if s.row_is_packed(row) {
                    return Err("frac must enter the analog state".into());
                }
            }
            s.read_row(row);
            if !s.row_is_packed(row) {
                return Err("read restore must exit to packed".into());
            }
            s.frac(row);
            let dst = (row + 1) % 16;
            s.row_copy(row, dst);
            if !s.row_is_packed(row) || !s.row_is_packed(dst) {
                return Err("row copy must leave both rows packed".into());
            }
            s.frac(row.min(7));
            let group: Vec<usize> = (0..8).collect();
            s.simra(&group);
            if s.analog_rows() != 0 {
                return Err("SiMRA must restore every opened row".into());
            }
            Ok(())
        },
    );
}

#[cfg(feature = "reference-model")]
#[test]
fn op_counts_are_representation_independent() {
    // The same command trace must produce identical OpCounts on the
    // hybrid and dense models: counting is defined by the command
    // stream, never by the storage representation (full bit-level
    // parity lives in rust/tests/storage_parity.rs).
    use pudtune::dram::dense::DenseSubarray;
    let cfg = DeviceConfig::default();
    check_res(
        "op-counts-representation-independent",
        13,
        48,
        |r: &mut Rng| {
            let seed = r.next_u64();
            let ops: Vec<u64> = (0..16).map(|_| r.below(64)).collect();
            (seed, ops)
        },
        |(seed, ops)| {
            let mut h = Subarray::with_geometry(&cfg, 16, 64, *seed);
            let mut d = DenseSubarray::with_geometry(&cfg, 16, 64, *seed);
            let group: Vec<usize> = (0..8).collect();
            for &op in ops {
                let row = (op >> 3) as usize % 16;
                match op & 7 {
                    0 => {
                        h.fill_row(row, 1);
                        d.fill_row(row, 1);
                    }
                    1 => {
                        let bits = vec![1u8; 64];
                        h.write_row(row, &bits);
                        d.write_row(row, &bits);
                    }
                    2 => {
                        h.read_row(row);
                        d.read_row(row);
                    }
                    3 | 4 => {
                        h.frac(row);
                        d.frac(row);
                    }
                    5 => {
                        h.row_copy(row, (row + 3) % 16);
                        d.row_copy(row, (row + 3) % 16);
                    }
                    _ => {
                        h.simra(&group);
                        d.simra(&group);
                    }
                }
                if h.counts != d.counts {
                    return Err(format!("counts diverge: {:?} vs {:?}", h.counts, d.counts));
                }
            }
            Ok(())
        },
    );
}
