//! Verifier ↔ compiler agreement properties: every compile-able op in
//! the vocabulary (all widths up to 16) verifies clean, and the
//! verifier's abstract replay reproduces the compiler's dry-run
//! `peak_rows` exactly. Also pins the error-composition contract
//! (`PudError` / `JobError` / `Diagnostic` all compose with `?` into
//! `anyhow::Result`) and the machine-readable diagnostic renderings.

use pudtune::coordinator::worker::JobError;
use pudtune::pud::graph::{Gate, MajCircuit, Signal};
use pudtune::pud::logic::not;
use pudtune::pud::plan::{PudError, PudOp, WorkloadPlan};
use pudtune::pud::verify::{self, DiagCode, Diagnostic, Severity};
use pudtune::util::json;
use pudtune::util::rng::Rng;

#[test]
fn whole_vocabulary_verifies_clean_and_peaks_agree() {
    let vocab = PudOp::vocabulary(16);
    assert!(vocab.len() > 30, "vocabulary(16) should sweep widths: {}", vocab.len());
    for op in vocab {
        let label = op.label();
        let plan = WorkloadPlan::compile(op).unwrap_or_else(|e| panic!("{label}: {e}"));
        assert!(plan.is_verified(), "{label}: compile must self-verify");
        let report = verify::verify_plan(&plan);
        assert!(report.is_clean(), "{label}:\n{report}");
        assert_eq!(
            report.peak_rows, plan.peak_rows,
            "{label}: abstract replay peak must equal the compiler dry-run"
        );
        // The budget the plan itself declares is, by construction,
        // exactly enough.
        let budgeted = verify::verify_plan_with_budget(&plan, Some(plan.peak_rows));
        assert!(budgeted.is_clean(), "{label}: own peak must fit its own budget\n{budgeted}");
    }
}

/// A random well-formed majority DAG (mirrors the compute_plan suite's
/// generator): negated signals sprinkled in, sometimes a negated
/// output, and — because only the last gate is guaranteed a consumer —
/// possibly dead gates, which must surface as P005 warnings and
/// nothing worse.
fn random_circuit(rng: &mut Rng) -> MajCircuit {
    let n_inputs = 2 + rng.below(3) as usize;
    let mut c = MajCircuit::new(n_inputs);
    let gates = 1 + rng.below(6) as usize;
    for gi in 0..gates {
        let mut sig = |rng: &mut Rng| -> Signal {
            let pool = n_inputs + gi;
            let k = rng.below(pool as u64 + 1) as usize;
            let base = if k < n_inputs {
                Signal::Input(k)
            } else if k < pool {
                Signal::Gate(k - n_inputs)
            } else {
                Signal::Const(rng.below(2) == 1)
            };
            if rng.below(4) == 0 {
                not(base)
            } else {
                base
            }
        };
        if rng.below(2) == 0 {
            c.push(Gate::maj3(sig(rng), sig(rng), sig(rng)));
        } else {
            c.push(Gate::maj5(sig(rng), sig(rng), sig(rng), sig(rng), sig(rng)));
        }
    }
    c.output(Signal::Gate(gates - 1));
    if rng.below(2) == 0 {
        c.output(Signal::NotInput(0));
    }
    c
}

#[test]
fn random_custom_plans_verify_without_errors_and_peaks_agree() {
    let mut rng = Rng::new(0x7E51F);
    for trial in 0..60 {
        let circuit = random_circuit(&mut rng);
        let plan = WorkloadPlan::from_circuit(circuit)
            .unwrap_or_else(|e| panic!("trial {trial}: well-formed circuit must compile: {e}"));
        let report = verify::verify_plan(&plan);
        assert_eq!(
            report.errors().count(),
            0,
            "trial {trial}: compiled plan must have no error diagnostics\n{report}"
        );
        assert!(
            report.diagnostics.iter().all(|d| d.code == DiagCode::DeadGate),
            "trial {trial}: only dead-gate warnings may survive compile\n{report}"
        );
        assert_eq!(report.peak_rows, plan.peak_rows, "trial {trial}");
    }
}

#[test]
fn dead_gate_fixture_is_known_bad() {
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/dead_gate.pud"
    ))
    .expect("committed fixture");
    let circuit = verify::parse_circuit(&text).expect("fixture parses");
    let report = verify::verify_circuit(&circuit);
    assert!(!report.is_clean(), "the fixture must stay known-bad (CI pins the lint exit)");
    assert!(report.has(DiagCode::DeadGate), "{report}");
    assert_eq!(report.errors().count(), 0, "fixture is warning-only\n{report}");
    assert!(
        report.diagnostics.iter().all(|d| d.severity() == Severity::Warning),
        "{report}"
    );
}

#[test]
fn errors_compose_with_anyhow_and_question_mark() {
    fn plan_err() -> anyhow::Result<()> {
        Err(PudError::WidthMismatch { expected: 4, got: 2 })?;
        Ok(())
    }
    let e = plan_err().unwrap_err();
    assert!(e.to_string().contains("width mismatch"), "{e}");
    assert!(e.downcast_ref::<PudError>().is_some());

    fn job_err() -> anyhow::Result<()> {
        Err(JobError::Panicked("boom".into()))?;
        Ok(())
    }
    let e = job_err().unwrap_err();
    assert!(e.downcast_ref::<JobError>().is_some());

    // A Diagnostic is itself a std::error::Error...
    let diag = Diagnostic {
        code: DiagCode::UseAfterDeath,
        gate: Some(3),
        row: Some(17),
        message: "Gate(1) read after its death at gate 2".into(),
    };
    fn diag_err(d: Diagnostic) -> anyhow::Result<()> {
        Err(d)?;
        Ok(())
    }
    let e = diag_err(diag.clone()).unwrap_err();
    assert!(e.to_string().contains("error[P001]"), "{e}");

    // ...and converts into the typed PudError the admission layers
    // return, keeping the stable code and the rendered hint.
    let pe = PudError::from(diag);
    match &pe {
        PudError::Verification { code, message } => {
            assert_eq!(*code, "P001");
            assert!(message.contains("gate 3"), "{message}");
            assert!(message.contains("hint:"), "{message}");
        }
        other => panic!("expected Verification, got {other:?}"),
    }
    assert!(pe.to_string().contains("plan rejected by verifier (P001)"), "{pe}");
}

#[test]
fn reports_and_diagnostics_render_well_formed_json() {
    let plan = WorkloadPlan::compile(PudOp::Add { width: 3 }).unwrap();
    let clean = json::parse(&verify::verify_plan(&plan).to_json()).expect("clean report JSON");
    assert_eq!(clean.get("clean").as_bool(), Some(true));
    assert_eq!(clean.get("peak_rows").as_usize(), Some(plan.peak_rows));
    assert_eq!(clean.get("diagnostics").as_arr().map(|a| a.len()), Some(0));

    let diag = Diagnostic {
        code: DiagCode::DoubleFrac,
        gate: None,
        row: Some(8),
        message: "row 8 \"quoted\"\nmultiline".into(),
    };
    let parsed = json::parse(&diag.to_json()).expect("diagnostic JSON survives escaping");
    assert_eq!(parsed.get("code").as_str(), Some("P002"));
    assert_eq!(parsed.get("severity").as_str(), Some("error"));
    assert_eq!(parsed.get("gate"), &json::Json::Null);
    assert_eq!(parsed.get("row").as_usize(), Some(8));
    assert_eq!(parsed.get("message").as_str(), Some("row 8 \"quoted\"\nmultiline"));
    assert_eq!(parsed.get("hint").as_str(), Some(DiagCode::DoubleFrac.hint()));

    // Every code renders a distinct, stable identifier with docs.
    let codes: Vec<&str> = DiagCode::ALL.iter().map(|c| c.code()).collect();
    assert_eq!(
        codes,
        ["P001", "P002", "P003", "P004", "P005", "P006", "P007", "P008", "P009", "P010", "P011",
         "P012"]
    );
    for c in DiagCode::ALL {
        assert!(!c.meaning().is_empty() && !c.hint().is_empty());
    }
}
