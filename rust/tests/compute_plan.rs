//! Property suite for the unified workload API: every `PudOp` driven
//! through `WorkloadPlan` → `ComputeEngine` must reproduce the
//! software golden model (`MajCircuit::eval`) on the error-free column
//! mask, for random widths/inputs/seeds — on the hybrid storage model
//! via the engine, and (feature `reference-model`) on the dense
//! reference model via a minimal gate executor over the same plan.

use pudtune::calib::algorithm::Calibration;
use pudtune::calib::engine::{ComputeEngine, ComputeRequest};
use pudtune::calib::lattice::{FracConfig, OffsetLattice};
use pudtune::config::device::DeviceConfig;
use pudtune::prelude::NativeEngine;
use pudtune::pud::graph::{Gate, MajCircuit, Signal};
use pudtune::pud::logic::not;
use pudtune::pud::plan::{BitwiseOp, PudOp, WorkloadPlan};
use pudtune::util::rng::Rng;
use std::sync::Arc;

const ROWS: usize = 128;

fn quiet_cfg() -> DeviceConfig {
    DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 1e-6,
        ..DeviceConfig::default()
    }
}

/// A random op spanning the whole vocabulary.
fn random_op(rng: &mut Rng) -> PudOp {
    match rng.below(6) {
        0 => PudOp::Add { width: 1 + rng.below(5) as usize },
        1 => PudOp::Mul { width: 1 + rng.below(3) as usize },
        2 => PudOp::Bitwise(match rng.below(3) {
            0 => BitwiseOp::And,
            1 => BitwiseOp::Or,
            _ => BitwiseOp::Not,
        }),
        3 => PudOp::MajReduce { m: 3 },
        4 => PudOp::MajReduce { m: 5 },
        _ => PudOp::Custom(random_circuit(rng)),
    }
}

/// A random well-formed majority DAG, with negated signals sprinkled
/// in and (sometimes) a negated output.
fn random_circuit(rng: &mut Rng) -> MajCircuit {
    let n_inputs = 2 + rng.below(3) as usize;
    let mut c = MajCircuit::new(n_inputs);
    let gates = 1 + rng.below(6) as usize;
    for gi in 0..gates {
        let mut sig = |rng: &mut Rng| -> Signal {
            let pool = n_inputs + gi;
            let k = rng.below(pool as u64 + 1) as usize;
            let base = if k < n_inputs {
                Signal::Input(k)
            } else if k < pool {
                Signal::Gate(k - n_inputs)
            } else {
                Signal::Const(rng.below(2) == 1)
            };
            if rng.below(4) == 0 {
                not(base)
            } else {
                base
            }
        };
        if rng.below(2) == 0 {
            c.push(Gate::maj3(sig(rng), sig(rng), sig(rng)));
        } else {
            c.push(Gate::maj5(sig(rng), sig(rng), sig(rng), sig(rng), sig(rng)));
        }
    }
    c.output(Signal::Gate(gates - 1));
    if rng.below(2) == 0 {
        c.output(Signal::NotInput(0));
    }
    c
}

fn random_request(plan: Arc<WorkloadPlan>, cfg: &DeviceConfig, rng: &mut Rng) -> ComputeRequest {
    let cols = [8usize, 16, 24][rng.below(3) as usize];
    let width = plan.op.operand_width();
    let operands: Vec<Vec<u64>> = (0..plan.op.n_operands())
        .map(|_| (0..cols).map(|_| rng.below(1u64 << width)).collect())
        .collect();
    let fc = FracConfig::pudtune([2, 1, 0]);
    let calib = Calibration::uniform(OffsetLattice::build(cfg, &fc), cols);
    let seed = rng.below(1 << 30);
    ComputeRequest::new(plan, ROWS, cols, seed, calib, operands)
}

#[test]
fn every_op_matches_the_golden_model_on_a_quiet_device() {
    let cfg = quiet_cfg();
    let eng = NativeEngine::new(cfg.clone());
    let mut rng = Rng::new(0x97A);
    for trial in 0..24u64 {
        let op = random_op(&mut rng);
        let plan = Arc::new(
            WorkloadPlan::compile(op.clone())
                .unwrap_or_else(|e| panic!("trial {trial}: {op:?} failed to compile: {e}")),
        );
        let req = random_request(plan, &cfg, &mut rng);
        let golden = req.golden_outputs().unwrap();
        let res = eng.execute_one(&req).unwrap();
        assert_eq!(
            res.outputs,
            golden,
            "trial {trial}: {} diverged from MajCircuit::eval",
            req.plan.op.label()
        );
        // No mask supplied: every column is trusted on a quiet device.
        assert_eq!(res.active_cols(), req.cols);
        assert_eq!(res.peak_rows, req.plan.peak_rows);

        // The dense reference model executes the same plan to the same
        // outputs (the representation-independence contract).
        #[cfg(feature = "reference-model")]
        assert_eq!(
            run_on_dense(&cfg, &req),
            golden,
            "trial {trial}: dense model diverged for {}",
            req.plan.op.label()
        );
    }
}

#[test]
fn masks_rescue_noisy_columns() {
    // On a default (noisy) device with the *baseline* uniform levels,
    // roughly half the columns are arithmetic-unusable. Restricting to
    // the battery-proven error-free mask must never lower the
    // golden-correct rate.
    use pudtune::calib::engine::measure_arith_batteries;
    use pudtune::dram::subarray::Subarray;
    let cfg = DeviceConfig::default();
    let eng = NativeEngine::new(cfg.clone());
    let cols = 128;
    let seed = 0xA5C;
    let base_cal = FracConfig::baseline(3).uncalibrated(&cfg, cols);
    let sub = Subarray::with_geometry(&cfg, ROWS, cols, seed);
    let batteries = measure_arith_batteries(&eng, &sub, seed, &[&base_cal], 2048).unwrap();
    let mask = batteries[0].arith().error_free_mask();
    let masked_cols = mask.iter().filter(|&&m| m).count();
    assert!(masked_cols < cols, "a noisy baseline must lose some columns");
    assert!(masked_cols > 0, "some columns must survive the battery");

    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 4 }).unwrap());
    let mut rng = Rng::new(3);
    let a: Vec<u64> = (0..cols).map(|_| rng.below(16)).collect();
    let b: Vec<u64> = (0..cols).map(|_| rng.below(16)).collect();
    let req = ComputeRequest::new(plan, ROWS, cols, seed, base_cal, vec![a, b])
        .with_mask(mask.clone());
    let golden = req.golden_outputs().unwrap();
    let res = eng.execute_one(&req).unwrap();
    let all_rate = res.outputs.iter().zip(&golden).filter(|(o, g)| o == g).count() as f64
        / cols as f64;
    let masked_rate = res.golden_correct(&golden) as f64 / masked_cols as f64;
    assert!(
        masked_rate >= all_rate,
        "mask must not hurt: masked {masked_rate:.3} vs all {all_rate:.3}"
    );
    assert!(masked_rate > 0.8, "error-free columns mostly compute: {masked_rate:.3}");
}

/// Minimal gate executor on the dense reference model: the same MAJX
/// flow as `exec::run_plan` (RowCopy-in, Frac, SiMRA, copy-out)
/// without timing or row recycling — on a quiet device the outputs
/// must equal the golden model, and hence the hybrid engine's.
#[cfg(feature = "reference-model")]
fn run_on_dense(cfg: &DeviceConfig, req: &ComputeRequest) -> Vec<u64> {
    use pudtune::dram::dense::DenseSubarray;
    use pudtune::dram::geometry::RowMap;
    use std::collections::HashMap;

    let mut d = DenseSubarray::with_geometry(cfg, req.rows, req.cols, req.seed);
    let map = RowMap::standard(req.rows);
    let calib = &req.calib;
    let fc = calib.lattice.config;
    for (i, &row) in map.calib_store.iter().enumerate() {
        d.write_row(row, &calib.row_bits(i));
    }
    d.fill_row(map.const0, 0);
    d.fill_row(map.const1, 1);
    let inputs = req.plan.encode_operands(&req.operands).unwrap();
    let mut next = map.data_base;
    let mut alloc = || {
        let r = next;
        next += 1;
        r
    };
    let mut input_rows = Vec::new();
    for bits in &inputs {
        let r = alloc();
        d.write_row(r, bits);
        input_rows.push(r);
    }
    let mut gate_rows: Vec<usize> = Vec::new();
    let mut not_rows: HashMap<Signal, usize> = HashMap::new();
    macro_rules! row_of {
        ($sig:expr) => {{
            let sig: Signal = $sig;
            match sig {
                Signal::Input(i) => input_rows[i],
                Signal::Gate(g) => gate_rows[g],
                Signal::Const(false) => map.const0,
                Signal::Const(true) => map.const1,
                Signal::NotInput(_) | Signal::NotGate(_) => {
                    if let Some(&r) = not_rows.get(&sig) {
                        r
                    } else {
                        let src = match sig {
                            Signal::NotInput(i) => input_rows[i],
                            Signal::NotGate(g) => gate_rows[g],
                            _ => unreachable!(),
                        };
                        let mut bits = d.read_row(src);
                        for b in &mut bits {
                            *b = 1 - *b;
                        }
                        let r = alloc();
                        d.write_row(r, &bits);
                        not_rows.insert(sig, r);
                        r
                    }
                }
            }
        }};
    }
    for gate in &req.plan.circuit.gates {
        let arity = gate.arity();
        let op_rows: Vec<usize> = gate.args.iter().map(|&s| row_of!(s)).collect();
        let base = map.simra_base;
        for (i, &r) in op_rows.iter().enumerate() {
            d.row_copy(r, base + i);
        }
        for (i, &store) in map.calib_store.iter().enumerate() {
            d.row_copy(store, base + arity + i);
        }
        if arity + 3 < 8 {
            d.row_copy(map.const0, base + arity + 3);
            d.row_copy(map.const1, base + arity + 4);
        }
        for (i, &n) in fc.fracs.iter().enumerate() {
            for _ in 0..n {
                d.frac(base + arity + i);
            }
        }
        let group: Vec<usize> = (base..base + 8).collect();
        let bits = d.simra(&group);
        let r = alloc();
        d.write_row(r, &bits);
        gate_rows.push(r);
    }
    let outputs: Vec<Vec<u8>> = req
        .plan
        .circuit
        .outputs
        .clone()
        .into_iter()
        .map(|s| {
            let r = row_of!(s);
            d.read_row(r)
        })
        .collect();
    (0..req.cols)
        .map(|c| req.plan.decode_output(&outputs, c))
        .collect()
}
