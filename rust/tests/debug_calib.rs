// Scratch diagnostic (ignored by default): where do calibrated columns
// still err?
use pudtune::calib::algorithm::{CalibParams, Calibration, NativeEngine};
use pudtune::calib::lattice::{FracConfig, OffsetLattice};
use pudtune::config::device::DeviceConfig;
use pudtune::dram::subarray::Subarray;

#[test]
#[ignore]
fn probe_residuals() {
    let cfg = DeviceConfig::default();
    let cols = 8192;
    let sub = Subarray::with_geometry(&cfg, 32, cols, 7);
    let mut eng = NativeEngine::new(cfg.clone());
    let fc = FracConfig::pudtune([2, 1, 0]);
    let calib = eng.calibrate(&sub, &fc, &CalibParams::paper());
    let rep = eng.measure_ecr(&sub, &calib, 5, 8192);
    // Oracle: best level per column.
    let lat = OffsetLattice::build(&cfg, &fc);
    let mut oracle = Calibration::uniform(lat.clone(), cols);
    for c in 0..cols {
        let d = sub.sa.variation.sa_offset[c] as f64;
        let (mut bi, mut bd) = (0usize, f64::INFINITY);
        for (i, lv) in lat.levels.iter().enumerate() {
            let r = (d - lv.offset_v).abs();
            if r < bd {
                bd = r;
                bi = i;
            }
        }
        oracle.levels[c] = bi as u8;
    }
    let orep = eng.measure_ecr(&sub, &oracle, 5, 8192);
    let margin = cfg.majority_margin();
    let mut big_resid = 0;
    let mut out_of_range = 0;
    let mut moved_wrong = 0;
    for c in 0..cols {
        if rep.error_counts[c] == 0 {
            continue;
        }
        let d = sub.sa.variation.sa_offset[c] as f64;
        let got = lat.levels[calib.levels[c] as usize].offset_v;
        let resid = (d - got).abs();
        if d.abs() > lat.range().1 + margin {
            out_of_range += 1;
        } else if resid > margin {
            big_resid += 1;
        }
        if calib.levels[c] != oracle.levels[c] {
            moved_wrong += 1;
        }
    }
    println!("algo ECR {:.4}  oracle ECR {:.4}", rep.ecr(), orep.ecr());
    println!(
        "errors: {} (out-of-range {}, resid>margin {}, level!=oracle {})",
        rep.error_prone(),
        out_of_range,
        big_resid,
        moved_wrong
    );
}
