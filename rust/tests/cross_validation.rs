//! Cross-validation: the native Rust golden model and the AOT-compiled
//! JAX/Pallas graphs must implement the *same* analog arithmetic.
//!
//! `maj5_eval_small` / `maj3_eval_small` take explicit operand bits,
//! calibration charges, thresholds and noise (no RNG), so the outputs
//! must match the golden model's `simra_eval` **bit-exactly**.
//! Requires `make artifacts`.

use pudtune::config::device::DeviceConfig;
use pudtune::dram::subarray::Subarray;
use pudtune::runtime::buffers;
use pudtune::util::rng::Rng;

mod common;
use common::open_runtime;

const S: usize = 32;
const N: usize = 256;

fn eval_case(m: usize, seed: u64) {
    let Some(rt) = open_runtime() else { return };
    let exe = rt.load(&format!("maj{m}_eval_small")).unwrap();

    let cfg = DeviceConfig::default();
    let mut rng = Rng::new(seed);

    let mut input_bits = vec![0f32; S * m * N];
    for v in input_bits.iter_mut() {
        *v = rng.bit() as f32;
    }
    // Per-column total non-operand charge (calibration rows + the MAJ3
    // constant rows): neutral-ish with jitter.
    let const_q = if m == 3 { 1.0f32 } else { 0.0 };
    let calib_q: Vec<f32> = (0..N)
        .map(|_| 1.5 + (rng.f32() - 0.5) * 0.8 + const_q)
        .collect();
    let thr: Vec<f32> = (0..N).map(|_| 0.5 + (rng.f32() - 0.5) * 0.1).collect();
    let mut noise = vec![0f32; S * N];
    rng.fill_normal(&mut noise, 0.002);

    // PJRT path.
    let out = exe
        .run(&[
            buffers::f32_array(&input_bits, &[S as i64, m as i64, N as i64]).unwrap(),
            buffers::f32_vec(&calib_q),
            buffers::f32_vec(&thr),
            buffers::f32_array(&noise, &[S as i64, N as i64]).unwrap(),
        ])
        .unwrap();
    let pjrt_bits = buffers::to_f32_vec(&out[0]).unwrap();
    assert_eq!(pjrt_bits.len(), S * N);

    // Native golden model. Only the column charge SUM matters for the
    // divider, so fold the non-operand charge into an equivalent
    // threshold shift: V(k + q) > thr  <=>  V(k) > thr - Cc*q/denom.
    let mut sub = Subarray::with_geometry(&cfg, 16, N, 1);
    let denom = cfg.simra_rows as f64 * cfg.cc_ff + cfg.cb_ff;
    for c in 0..N {
        sub.sa.variation.sa_offset[c] =
            (thr[c] as f64 - 0.5 - cfg.cc_ff * calib_q[c] as f64 / denom) as f32;
        sub.sa.variation.tempco_jitter[c] = 0.0;
        sub.sa.drift.drift[c] = 0.0;
    }
    for r in m..8 {
        sub.fill_row(r, 0); // non-operand rows folded into thresholds
    }
    let rows: Vec<usize> = (0..8).collect();
    let mut mismatches = 0usize;
    for s in 0..S {
        for r in 0..m {
            let bits: Vec<u8> = (0..N)
                .map(|c| input_bits[s * m * N + r * N + c] as u8)
                .collect();
            sub.write_row(r, &bits);
        }
        let noise_row: Vec<f32> = (0..N).map(|c| noise[s * N + c]).collect();
        let native = sub.simra_eval(&rows, &noise_row);
        for c in 0..N {
            if (pjrt_bits[s * N + c] != 0.0) != (native[c] != 0) {
                mismatches += 1;
            }
        }
    }
    // f32-vs-f64 rounding could only differ exactly at a decision
    // boundary, which random draws never hit; the tolerance is a guard
    // against that measure-zero case, not a fudge factor.
    assert!(
        mismatches <= 1,
        "maj{m}: {mismatches}/{} bits disagree between native and PJRT",
        S * N
    );
}

#[test]
fn maj5_eval_bit_exact() {
    eval_case(5, 0xBEEF);
}

#[test]
fn maj3_eval_bit_exact() {
    eval_case(3, 0xF00D);
}

/// Statistical agreement of the RNG paths: the PJRT ECR graph and the
/// native engine measure the same device through different random
/// streams; the measured ECRs must agree closely.
#[test]
fn ecr_statistical_agreement() {
    use pudtune::experiments;
    let Some(rt) = open_runtime() else { return };
    let rt = std::sync::Arc::new(rt);
    let cfg = DeviceConfig::default();
    let (pjrt, native) = experiments::cross_check(&cfg, &rt, 1024).unwrap();
    assert!(
        (pjrt - native).abs() < 0.05,
        "pjrt={pjrt:.3} native={native:.3}"
    );
}

/// Calibration on the PJRT path reaches the same quality as native.
#[test]
fn pjrt_calibration_quality_matches_native() {
    use pudtune::calib::algorithm::{CalibParams, NativeEngine};
    use pudtune::calib::lattice::FracConfig;
    use pudtune::coordinator::engine::{ColumnBank, PjrtEngine};
    let Some(rt) = open_runtime() else { return };
    let rt = std::sync::Arc::new(rt);
    let cfg = DeviceConfig::default();
    let fc = FracConfig::pudtune([2, 1, 0]);
    let params = CalibParams::paper();

    let eng = PjrtEngine::new(rt, cfg.clone());
    let bank = ColumnBank::new(&cfg, 1024, 77);
    let cal_p = eng.calibrate(&bank, &fc, &params).unwrap();
    let ecr_p = eng.measure_ecr(&bank, &cal_p, 5, 0xAB).unwrap().ecr();

    let mut neng = NativeEngine::new(cfg.clone());
    let sub = Subarray::with_geometry(&cfg, 16, 1024, 77);
    let cal_n = neng.calibrate(&sub, &fc, &params);
    let ecr_n = neng.measure_ecr(&sub, &cal_n, 5, 8192).ecr();

    assert!(
        (ecr_p - ecr_n).abs() < 0.05,
        "pjrt={ecr_p:.3} native={ecr_n:.3}"
    );
    // Both must be far below the uncalibrated baseline.
    let base = FracConfig::baseline(3).uncalibrated(&cfg, 1024);
    let ecr_base = neng.measure_ecr(&sub, &base, 5, 8192).ecr();
    assert!(ecr_p < ecr_base / 3.0 && ecr_n < ecr_base / 3.0);
}
