//! Randomised round-trip coverage for the calibration store: for many
//! random level vectors — including the degenerate shapes RLE is most
//! likely to mangle (all-neutral, empty, single-column, long constant
//! runs, alternating values) — `to_json → text → parse → from_json →
//! load` must reproduce every `Calibration` bit for bit.

use pudtune::calib::lattice::OffsetLattice;
use pudtune::prelude::*;
use pudtune::util::json;

fn lattice_calib(cfg: &DeviceConfig, fc: FracConfig, levels: Vec<u8>) -> Calibration {
    Calibration { lattice: OffsetLattice::build(cfg, &fc), levels }
}

/// Random level vector with run-heavy structure: random runs of random
/// lengths (1..=max_run), biased toward the neutral level the way real
/// post-calibration data is.
fn random_levels(rng: &mut Rng, cols: usize, max_run: usize, neutral: u8) -> Vec<u8> {
    let mut out = Vec::with_capacity(cols);
    while out.len() < cols {
        let v = if rng.next_u64() % 4 == 0 {
            (rng.next_u64() % 8) as u8
        } else {
            neutral
        };
        let run = 1 + (rng.next_u64() as usize) % max_run;
        let run = run.min(cols - out.len());
        out.extend(std::iter::repeat(v).take(run));
    }
    out
}

#[test]
fn fuzz_roundtrip_reproduces_bit_identical_calibrations() {
    let cfg = DeviceConfig::default();
    let fc = FracConfig::pudtune([2, 1, 0]);
    let neutral = OffsetLattice::build(&cfg, &fc).neutral_level() as u8;
    let mut rng = Rng::new(0xF022);

    for trial in 0..64 {
        let cols = match trial % 8 {
            // Degenerate shapes every cycle: empty, single column,
            // exactly one RLE pair boundary, then random widths.
            0 => 0,
            1 => 1,
            2 => 255,
            3 => 256,
            _ => 1 + (rng.next_u64() as usize) % 4096,
        };
        let max_run = 1 + (rng.next_u64() as usize) % 255;
        let mut store = CalibStore::default();
        let mut originals = Vec::new();
        for b in 0..3usize {
            let levels = match (trial + b) % 5 {
                // All-neutral (the common real-world case: one RLE pair).
                0 => vec![neutral; cols],
                // Constant non-neutral, including 255-long runs.
                1 => vec![7u8; cols],
                // Worst case for RLE: alternating values, runs of 1.
                2 => (0..cols).map(|c| (c % 2) as u8 * 5).collect(),
                _ => random_levels(&mut rng, cols, max_run, neutral),
            };
            let id = SubarrayId::new(trial % 4, b, trial);
            let calib = lattice_calib(&cfg, fc, levels);
            store.insert(id, &calib);
            originals.push((id, calib));
        }

        // to_json → text → parse → from_json: entries survive exactly.
        let text = store.to_json().to_string();
        let back = CalibStore::from_json(&json::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("trial {trial}: decode failed: {e}"));
        assert_eq!(back.entries, store.entries, "trial {trial}");
        // Pretty output parses to the same store.
        let pretty = CalibStore::from_json(&json::parse(&store.to_json().to_pretty()).unwrap())
            .unwrap();
        assert_eq!(pretty.entries, store.entries, "trial {trial} (pretty)");

        // load() rehydrates bit-identical calibrations.
        for (id, original) in &originals {
            let loaded = back
                .load(*id, &cfg)
                .unwrap_or_else(|e| panic!("trial {trial}: load failed: {e}"))
                .expect("entry must exist");
            assert_eq!(loaded.levels, original.levels, "trial {trial} {id:?}");
            assert_eq!(loaded.lattice.config, original.lattice.config);
            for c in 0..original.cols() {
                assert!((loaded.q_extra(c) - original.q_extra(c)).abs() < 1e-12);
            }
        }
    }
}

#[test]
fn fuzz_roundtrip_covers_all_frac_configs() {
    // Mixed configurations (including the baseline) in one store.
    let cfg = DeviceConfig::default();
    let mut rng = Rng::new(0xF023);
    let configs = [
        FracConfig::baseline(3),
        FracConfig::pudtune([0, 0, 0]),
        FracConfig::pudtune([2, 1, 0]),
        FracConfig::pudtune([2, 2, 2]),
    ];
    let mut store = CalibStore::default();
    let mut originals = Vec::new();
    for (i, fc) in configs.into_iter().enumerate() {
        let neutral = OffsetLattice::build(&cfg, &fc).neutral_level() as u8;
        let levels = random_levels(&mut rng, 777, 255, neutral);
        let id = SubarrayId::new(1, i, 0);
        let calib = lattice_calib(&cfg, fc, levels);
        store.insert(id, &calib);
        originals.push((id, calib));
    }
    let back = CalibStore::from_json(&json::parse(&store.to_json().to_string()).unwrap()).unwrap();
    assert_eq!(back.entries, store.entries);
    for (id, original) in &originals {
        let loaded = back.load(*id, &cfg).unwrap().unwrap();
        assert_eq!(loaded.levels, original.levels);
        assert_eq!(loaded.lattice.config, original.lattice.config);
    }
}
