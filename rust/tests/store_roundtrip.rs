//! Randomised round-trip coverage for the calibration store: for many
//! random level vectors — including the degenerate shapes RLE is most
//! likely to mangle (all-neutral, empty, single-column, long constant
//! runs, alternating values) — `to_json → text → parse → from_json →
//! load` must reproduce every `Calibration` bit for bit.

use pudtune::calib::lattice::OffsetLattice;
use pudtune::dram::temperature::Environment;
use pudtune::prelude::*;
use pudtune::util::json;

fn lattice_calib(cfg: &DeviceConfig, fc: FracConfig, levels: Vec<u8>) -> Calibration {
    Calibration { lattice: OffsetLattice::build(cfg, &fc), levels }
}

/// Random level vector with run-heavy structure: random runs of random
/// lengths (1..=max_run), biased toward the neutral level the way real
/// post-calibration data is.
fn random_levels(rng: &mut Rng, cols: usize, max_run: usize, neutral: u8) -> Vec<u8> {
    let mut out = Vec::with_capacity(cols);
    while out.len() < cols {
        let v = if rng.next_u64() % 4 == 0 {
            (rng.next_u64() % 8) as u8
        } else {
            neutral
        };
        let run = 1 + (rng.next_u64() as usize) % max_run;
        let run = run.min(cols - out.len());
        out.extend(std::iter::repeat(v).take(run));
    }
    out
}

#[test]
fn fuzz_roundtrip_reproduces_bit_identical_calibrations() {
    let cfg = DeviceConfig::default();
    let fc = FracConfig::pudtune([2, 1, 0]);
    let neutral = OffsetLattice::build(&cfg, &fc).neutral_level() as u8;
    let mut rng = Rng::new(0xF022);

    for trial in 0..64 {
        let cols = match trial % 8 {
            // Degenerate shapes every cycle: empty, single column,
            // exactly one RLE pair boundary, then random widths.
            0 => 0,
            1 => 1,
            2 => 255,
            3 => 256,
            _ => 1 + (rng.next_u64() as usize) % 4096,
        };
        let max_run = 1 + (rng.next_u64() as usize) % 255;
        let mut store = CalibStore::default();
        let mut originals = Vec::new();
        for b in 0..3usize {
            let levels = match (trial + b) % 5 {
                // All-neutral (the common real-world case: one RLE pair).
                0 => vec![neutral; cols],
                // Constant non-neutral, including 255-long runs.
                1 => vec![7u8; cols],
                // Worst case for RLE: alternating values, runs of 1.
                2 => (0..cols).map(|c| (c % 2) as u8 * 5).collect(),
                _ => random_levels(&mut rng, cols, max_run, neutral),
            };
            let id = SubarrayId::new(trial % 4, b, trial);
            let calib = lattice_calib(&cfg, fc, levels);
            store.insert(id, &calib);
            originals.push((id, calib));
        }

        // to_json → text → parse → from_json: entries survive exactly.
        let text = store.to_json().to_string();
        let back = CalibStore::from_json(&json::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("trial {trial}: decode failed: {e}"));
        assert_eq!(back.entries, store.entries, "trial {trial}");
        // Pretty output parses to the same store.
        let pretty = CalibStore::from_json(&json::parse(&store.to_json().to_pretty()).unwrap())
            .unwrap();
        assert_eq!(pretty.entries, store.entries, "trial {trial} (pretty)");

        // load() rehydrates bit-identical calibrations.
        for (id, original) in &originals {
            let loaded = back
                .load(*id, &cfg)
                .unwrap_or_else(|e| panic!("trial {trial}: load failed: {e}"))
                .expect("entry must exist");
            assert_eq!(loaded.levels, original.levels, "trial {trial} {id:?}");
            assert_eq!(loaded.lattice.config, original.lattice.config);
            for c in 0..original.cols() {
                assert!((loaded.q_extra(c) - original.q_extra(c)).abs() < 1e-12);
            }
        }
    }
}

#[test]
fn fuzz_roundtrip_covers_all_frac_configs() {
    // Mixed configurations (including the baseline) in one store.
    let cfg = DeviceConfig::default();
    let mut rng = Rng::new(0xF023);
    let configs = [
        FracConfig::baseline(3),
        FracConfig::pudtune([0, 0, 0]),
        FracConfig::pudtune([2, 1, 0]),
        FracConfig::pudtune([2, 2, 2]),
    ];
    let mut store = CalibStore::default();
    let mut originals = Vec::new();
    for (i, fc) in configs.into_iter().enumerate() {
        let neutral = OffsetLattice::build(&cfg, &fc).neutral_level() as u8;
        let levels = random_levels(&mut rng, 777, 255, neutral);
        let id = SubarrayId::new(1, i, 0);
        let calib = lattice_calib(&cfg, fc, levels);
        store.insert(id, &calib);
        originals.push((id, calib));
    }
    let back = CalibStore::from_json(&json::parse(&store.to_json().to_string()).unwrap()).unwrap();
    assert_eq!(back.entries, store.entries);
    for (id, original) in &originals {
        let loaded = back.load(*id, &cfg).unwrap().unwrap();
        assert_eq!(loaded.levels, original.levels);
        assert_eq!(loaded.lattice.config, original.lattice.config);
    }
}

#[test]
fn fuzz_roundtrip_preserves_v2_env_metadata() {
    // Random calibration environments — including awkward non-integral
    // floats — survive `insert_with_env → to_json → parse → from_json
    // → stored_env` exactly, and entries inserted without telemetry
    // stay env-free rather than inventing metadata.
    let cfg = DeviceConfig::default();
    let fc = FracConfig::pudtune([2, 1, 0]);
    let neutral = OffsetLattice::build(&cfg, &fc).neutral_level() as u8;
    let mut rng = Rng::new(0xE27);

    for trial in 0..32 {
        let cols = 1 + (rng.next_u64() as usize) % 1024;
        let mut store = CalibStore::default();
        let mut expected: Vec<(SubarrayId, Option<Environment>)> = Vec::new();
        for b in 0..4usize {
            let id = SubarrayId::new(0, b, trial);
            let calib = lattice_calib(&cfg, fc, random_levels(&mut rng, cols, 64, neutral));
            if b % 2 == 0 {
                let env = Environment {
                    temp_c: 20.0 + rng.f64() * 80.0,
                    hours: rng.f64() * 500.0,
                };
                store.insert_with_env(id, &calib, env);
                expected.push((id, Some(env)));
            } else {
                store.insert(id, &calib);
                expected.push((id, None));
            }
        }
        let back = CalibStore::from_json(&json::parse(&store.to_json().to_string()).unwrap())
            .unwrap_or_else(|e| panic!("trial {trial}: decode failed: {e}"));
        assert_eq!(back.entries, store.entries, "trial {trial}");
        for (id, env) in expected {
            assert_eq!(back.stored_env(id), env, "trial {trial} {id:?}");
        }
    }
}

#[test]
fn service_snapshot_env_metadata_gates_rehydration() {
    // The full service loop around the v2 metadata: `snapshot_store`
    // records the calibration environment, rehydration at the same die
    // temperature accepts, v1-style entries (no env) still accept
    // purely on the spot check, and a temperature excursion beyond
    // `DriftPolicy::max_temp_delta_c` rejects the stored entry before
    // any spot check is spent on it.
    let cfg = DeviceConfig::default();
    let (banks, cols) = (2usize, 256);
    let fresh = |cfg: &DeviceConfig| {
        let svc = ServiceConfig { serve_samples: 512, ..ServiceConfig::default() };
        let s = RecalibService::new(cfg.clone(), svc, NativeEngine::new(cfg.clone())).unwrap();
        for b in 0..banks {
            s.register(SubarrayId::new(0, b, 0), 32, cols, 0xE27E);
        }
        s
    };

    let mut first = fresh(&cfg);
    assert!(first.run_pending(usize::MAX).iter().all(|(_, r)| r.is_ok()));
    let store = first.snapshot_store();
    for id in first.ids() {
        assert!(store.stored_env(id).is_some(), "snapshot must carry v2 env metadata");
    }

    // Same temperature: the env gate passes and the spot check accepts.
    let mut warm = fresh(&cfg);
    for (id, o) in warm.load_store(&store) {
        assert!(matches!(o, LoadOutcome::Accepted { .. }), "{id:?}: {o:?}");
    }
    assert!(warm.run_pending(usize::MAX).is_empty(), "accepted loads satisfy cold-start jobs");

    // v1-style store (no env metadata): accepted on the spot check alone.
    let mut v1 = CalibStore::default();
    for id in first.ids() {
        assert!(v1.stored_env(id).is_none());
        v1.insert(id, first.calibration(id).unwrap());
    }
    let mut legacy = fresh(&cfg);
    for (id, o) in legacy.load_store(&v1) {
        assert!(matches!(o, LoadOutcome::Accepted { .. }), "{id:?}: {o:?}");
    }

    // Excursion beyond the policy bound (20 C default): the stored env
    // no longer matches the die, so the entry is rejected up front and
    // stays queued for recalibration.
    let mut hot = fresh(&cfg);
    for id in hot.ids() {
        assert!(hot.set_temperature(id, 85.0));
    }
    for (id, o) in hot.load_store(&store) {
        assert!(
            matches!(&o, LoadOutcome::Incompatible(e) if e.contains("die temperature")),
            "{id:?}: {o:?}"
        );
    }
    assert_eq!(hot.metrics.counter("recalib.rejected_on_load"), banks as u64);
    assert_eq!(hot.run_pending(usize::MAX).len(), banks);
}
