//! End-to-end calibration: identify on one engine, persist to the NV
//! store, reload, and verify on the *full command-level flow* (the
//! golden model executing actual RowCopy/Frac/SiMRA programs).

use pudtune::calib::algorithm::{CalibParams, NativeEngine};
use pudtune::calib::lattice::FracConfig;
use pudtune::calib::store::CalibStore;
use pudtune::config::device::DeviceConfig;
use pudtune::config::system::Ddr4Timing;
use pudtune::dram::geometry::{RowMap, SubarrayId};
use pudtune::dram::subarray::Subarray;
use pudtune::pud::majx::{execute_majx, setup_subarray, MajX};
use pudtune::util::rng::Rng;

/// Full-flow ECR: run MAJ5 through RowCopy/Frac/SiMRA programs and
/// count per-column errors (slow; used at small scale to validate the
/// fast sampling path end to end).
fn full_flow_error_counts(
    sub: &mut Subarray,
    map: &RowMap,
    fc: &FracConfig,
    samples: u32,
    seed: u64,
) -> Vec<u32> {
    let grade = Ddr4Timing::ddr4_2133();
    let mut rng = Rng::new(seed);
    let mut errs = vec![0u32; sub.cols];
    let operand_rows: Vec<usize> = (0..5).map(|i| map.data_base + i).collect();
    for _ in 0..samples {
        // Random per-column operand bits.
        let mut expected = vec![0u8; sub.cols];
        let mut cols_bits: Vec<Vec<u8>> = vec![vec![0u8; sub.cols]; 5];
        for c in 0..sub.cols {
            let word = rng.next_u64();
            let mut ones = 0;
            for (r, row) in cols_bits.iter_mut().enumerate() {
                let b = ((word >> r) & 1) as u8;
                row[c] = b;
                ones += b;
            }
            expected[c] = (ones >= 3) as u8;
        }
        for (r, bits) in operand_rows.iter().zip(&cols_bits) {
            sub.write_row(*r, bits);
        }
        let (got, _) = execute_majx(sub, map, MajX::Maj5, &operand_rows, fc, &grade);
        for c in 0..sub.cols {
            errs[c] += (got[c] != expected[c]) as u32;
        }
    }
    errs
}

#[test]
fn calibrate_store_reload_verify_full_flow() {
    let cfg = DeviceConfig::default();
    let cols = 512;
    let fc = FracConfig::pudtune([2, 1, 0]);
    let mut sub = Subarray::with_geometry(&cfg, 64, cols, 0xE2E);
    let mut eng = NativeEngine::new(cfg.clone());

    // 1. Identify calibration data (Algorithm 1, fast sampling path).
    let calib = eng.calibrate(&mut sub, &fc, &CalibParams::paper());

    // 2. Persist to the NV store and reload (paper §III-A).
    let mut store = CalibStore::default();
    let id = SubarrayId::new(0, 0, 0);
    store.insert(id, &calib);
    let json = store.to_json().to_string();
    let reloaded = CalibStore::from_json(&pudtune::util::json::parse(&json).unwrap())
        .unwrap()
        .load(id, &cfg)
        .expect("compatible store")
        .expect("bank in store");
    assert_eq!(reloaded.levels, calib.levels);

    // 3. Verify through the FULL command-level flow: write the reloaded
    //    calibration bits into the reserved rows and execute real
    //    MAJ5 programs.
    let map = RowMap::standard(sub.rows);
    setup_subarray(&mut sub, &map, &reloaded);
    let errs_tuned = full_flow_error_counts(&mut sub, &map, &fc, 96, 0x5EED);
    let ecr_tuned =
        errs_tuned.iter().filter(|&&e| e > 0).count() as f64 / cols as f64;

    // Baseline through the same full flow.
    let base = FracConfig::baseline(3);
    let base_cal = base.uncalibrated(&cfg, cols);
    setup_subarray(&mut sub, &map, &base_cal);
    let errs_base = full_flow_error_counts(&mut sub, &map, &base, 96, 0x5EED);
    let ecr_base =
        errs_base.iter().filter(|&&e| e > 0).count() as f64 / cols as f64;

    assert!(
        ecr_tuned < ecr_base / 2.5,
        "full-flow ECR: tuned {ecr_tuned:.3} vs base {ecr_base:.3}"
    );
    assert!(ecr_base > 0.25, "baseline should be visibly error-prone: {ecr_base}");
}

#[test]
fn calibration_survives_moderate_environment_change() {
    // Calibrate at nominal, verify at 70C and after 3 days: new errors
    // must be rare (Fig. 6 mechanism, end to end).
    let cfg = DeviceConfig::default();
    let cols = 4096;
    let fc = FracConfig::pudtune([2, 1, 0]);
    let mut sub = Subarray::with_geometry(&cfg, 32, cols, 0x716);
    let mut eng = NativeEngine::new(cfg.clone());
    let calib = eng.calibrate(&mut sub, &fc, &CalibParams::paper());
    let before = eng.measure_ecr(&mut sub, &calib, 5, 4096);
    sub.set_temperature(70.0);
    sub.advance_time(72.0);
    let after = eng.measure_ecr(&mut sub, &calib, 5, 4096);
    let new_ecr = after.new_ecr_vs(&before);
    assert!(new_ecr < 0.01, "new ECR {new_ecr}");
}
