//! End-to-end fault campaign: the standard corruption campaign
//! (`dram::faults::standard_campaign` — pattern-dependent flips,
//! aggressor/victim coupling, and duty-cycled intermittent columns,
//! all at p = 1 over a quiet analog substrate) against
//! `RecalibService`, with and without countermeasures.
//!
//! The injected faults are invisible to the calibration/ECR sampling
//! path (which runs on `ColumnBank`, not the cell-array golden model),
//! so every service here calibrates cleanly and then corrupts real
//! workloads — exactly the failure mode quarantine + scrub exist for.
//! Because faults are seeded per column address and every serve
//! rebuilds the subarray from the same (plan, operands, seed), the
//! corrupting column set is identical every epoch: an unprotected
//! service mismatches forever, a protected one converges to zero
//! steady-state golden mismatches.

use std::sync::Arc;

use pudtune::dram::faults::standard_campaign;
use pudtune::prelude::*;

const BANKS: usize = 2;
const COLS: usize = 256;
const SEED: u64 = 0xFA57;

fn campaign_service(cfg: &DeviceConfig, svc: ServiceConfig) -> RecalibService<NativeEngine> {
    let s = RecalibService::new(cfg.clone(), svc, NativeEngine::new(cfg.clone())).unwrap();
    for b in 0..BANKS {
        s.register(SubarrayId::new(0, b, 0), 32, COLS, SEED);
    }
    let done = s.run_pending(usize::MAX);
    assert!(done.iter().all(|(_, r)| r.is_ok()), "campaign device must calibrate cleanly");
    s
}

/// One fixed workload, reused every epoch: per-column random 2-bit
/// additions. Identical requests draw identical faults.
fn workload() -> (Arc<WorkloadPlan>, Vec<Vec<u64>>) {
    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 2 }).unwrap());
    let mut rng = Rng::new(0xCA3);
    let operands: Vec<Vec<u64>> = (0..plan.op.n_operands())
        .map(|_| (0..COLS).map(|_| rng.below(4)).collect())
        .collect();
    (plan, operands)
}

fn mismatches(outs: &[WorkloadOutcome]) -> usize {
    outs.iter()
        .map(|o| {
            assert!(o.result.is_ok(), "{:?}: {:?}", o.id, o.result);
            o.active_cols - o.golden_correct
        })
        .sum()
}

fn active(outs: &[WorkloadOutcome]) -> usize {
    outs.iter().map(|o| o.active_cols).sum()
}

#[test]
fn unprotected_service_keeps_serving_corrupted_outputs() {
    let cfg = standard_campaign(&DeviceConfig::default());
    let svc = ServiceConfig { serve_samples: 512, ..ServiceConfig::default() };
    let service = campaign_service(&cfg, svc);
    let (plan, operands) = workload();

    let mut per_epoch = Vec::new();
    for _ in 0..4 {
        per_epoch.push(mismatches(&service.serve_plan(&plan, &operands).unwrap()));
        // Countermeasures are off by default: maintain() polls drift
        // but never scrubs, and no quarantine state exists to change.
        let (_, scrubs) = service.maintain();
        assert!(scrubs.is_empty());
    }
    assert!(per_epoch[0] > 0, "campaign must corrupt the unprotected serve: {per_epoch:?}");
    assert!(
        per_epoch.windows(2).all(|w| w[0] == w[1]),
        "deterministic faults repeat identically every epoch: {per_epoch:?}"
    );
    assert_eq!(
        service.metrics.counter("compute.golden_mismatch"),
        per_epoch.iter().sum::<usize>() as u64
    );
    assert!(service.metrics.counter("fault.flips") > 0);
    assert_eq!(service.metrics.counter("quarantine.entered"), 0);
    assert_eq!(service.metrics.counter("scrub.passes"), 0);
}

#[test]
fn quarantine_and_scrub_drive_steady_state_mismatches_to_zero() {
    let cfg = standard_campaign(&DeviceConfig::default());
    let svc = ServiceConfig {
        serve_samples: 512,
        quarantine_strikes: 2,
        quarantine_clean_passes: 2,
        scrub_every: 1,
        ..ServiceConfig::default()
    };
    let service = campaign_service(&cfg, svc);
    let (plan, operands) = workload();

    let epochs = 6;
    let mut bad = Vec::new();
    let mut served = Vec::new();
    for _ in 0..epochs {
        let outs = service.serve_plan(&plan, &operands).unwrap();
        bad.push(mismatches(&outs));
        served.push(active(&outs));
        let (_, scrubs) = service.maintain();
        assert_eq!(scrubs.len(), BANKS);
        assert!(scrubs.iter().all(|s| s.result.is_ok()), "{scrubs:?}");
    }

    // Epoch 0 serves corrupted outputs (the faults pass calibration),
    // but each corrupting column collects a serve strike plus a scrub
    // strike that same epoch — reaching `quarantine_strikes` — so from
    // epoch 1 on the service masks them out and serves zero mismatches.
    assert!(bad[0] > 0, "campaign must corrupt the first serve: {bad:?}");
    for (e, &b) in bad.iter().enumerate().skip(1) {
        assert_eq!(b, 0, "epoch {e} must serve clean: {bad:?}");
    }

    let quarantined: usize = service
        .ids()
        .iter()
        .map(|id| service.quarantine(*id).unwrap().quarantined_cols())
        .sum();
    assert!(quarantined > 0, "clean steady state must come from quarantine, not luck");
    // The throughput cost of protection: quarantined columns stop
    // serving, so the steady-state active width shrinks but stays
    // well above zero.
    assert!(served[epochs - 1] < served[0], "{served:?}");
    assert!(served[epochs - 1] > 0, "{served:?}");

    assert_eq!(service.metrics.counter("scrub.passes"), epochs as u64);
    assert!(service.metrics.counter("quarantine.entered") >= quarantined as u64);
    assert!(service.metrics.counter("quarantine.observed_mismatches") > 0);
    assert!(service.metrics.counter("fault.flips") > 0);
    assert!(service.metrics.counter("scrub.dirty_cols") > 0);
    // Persistent (deterministic, p = 1) faults never replay clean, so
    // hysteresis must never release a quarantined column.
    assert_eq!(service.metrics.counter("quarantine.released"), 0);
}

#[test]
fn redundant_execution_outvotes_most_corruption() {
    let cfg = standard_campaign(&DeviceConfig::default());
    let mut plain =
        campaign_service(&cfg, ServiceConfig { serve_samples: 512, ..ServiceConfig::default() });
    let mut voted = campaign_service(
        &cfg,
        ServiceConfig { serve_samples: 512, redundancy: 3, ..ServiceConfig::default() },
    );
    let (plan, operands) = workload();

    let single = mismatches(&plain.serve_plan(&plan, &operands).unwrap());
    let majority = mismatches(&voted.serve_plan(&plan, &operands).unwrap());
    assert!(single > 0, "campaign must corrupt the single-shot serve");
    // Replicas draw independent fault fields from derived seeds, so a
    // column corrupted in the primary is overwhelmingly likely to be
    // clean in both replicas and the per-column majority vote repairs
    // it — without any quarantine state or scrub passes.
    assert!(
        majority < single,
        "majority vote must outvote independent per-replica faults: {majority} vs {single}"
    );
    assert!(voted.metrics.counter("fault.flips") >= plain.metrics.counter("fault.flips"));
    assert_eq!(voted.metrics.counter("scrub.passes"), 0);
}
