//! Engine-API suite: the `CalibEngine` trait must behave identically
//! across backends and batch shapes.
//!
//! * **Backend parity** — the same `CalibRequest` through a concrete
//!   `NativeEngine` and through `AnyEngine::auto`'s stub-fallback path
//!   (the vendored `xla` stub fails cleanly at runtime, so `auto`
//!   resolves to native) must produce identical `Calibration` and ECR
//!   outputs.
//! * **Batch-shape invariance** — batched calls equal one-at-a-time
//!   calls bit for bit, in any request order.
//! * **Coordinator over native** — `DeviceCoordinator<NativeEngine>`
//!   (first made possible by the generic redesign) reproduces the
//!   paper's error-reduction shape.
//! * **CalibStore round-trip** — identified calibration data survives
//!   `to_json`/`from_json` and `save_file`/`load_file` unchanged.

use pudtune::calib::algorithm::{CalibParams, NativeEngine};
use pudtune::calib::engine::{AnyEngine, BankBatch, CalibEngine, CalibRequest, EcrRequest};
use pudtune::calib::lattice::FracConfig;
use pudtune::calib::store::CalibStore;
use pudtune::config::device::DeviceConfig;
use pudtune::config::system::SystemConfig;
use pudtune::coordinator::engine::{BankSummary, ColumnBank, DeviceCoordinator};
use pudtune::dram::geometry::SubarrayId;
use pudtune::util::json;

#[test]
fn native_and_stub_fallback_paths_agree() {
    let cfg = DeviceConfig::default();
    let auto = AnyEngine::auto(cfg.clone());
    if auto.backend() != "native" {
        // A real artifact build is present; cross-backend agreement is
        // statistical and covered by rust/tests/cross_validation.rs.
        eprintln!("skipping: AnyEngine::auto resolved to '{}'", auto.backend());
        return;
    }
    let native = NativeEngine::new(cfg.clone());
    let bank = ColumnBank::new(&cfg, 512, 0xA11CE);
    let req =
        CalibRequest::new(bank.clone(), FracConfig::pudtune([2, 1, 0]), CalibParams::quick());
    let a = native.calibrate_one(&req).unwrap();
    let b = auto.calibrate_one(&req).unwrap();
    assert_eq!(a.levels, b.levels);

    let ereq = EcrRequest::new(bank, a.clone(), 5, 2048);
    let ra = native.measure_ecr_one(&ereq).unwrap();
    let rb = auto.measure_ecr_one(&ereq).unwrap();
    assert_eq!(ra.error_counts, rb.error_counts);
    assert_eq!(ra.samples, rb.samples);
}

#[test]
fn batched_calls_are_order_and_shape_invariant() {
    let cfg = DeviceConfig::default();
    let eng = NativeEngine::new(cfg.clone());
    let batch = BankBatch::from_device_seed(cfg, 384, 0xD1CE, 4);
    let reqs = batch.calib_requests(FracConfig::pudtune([2, 1, 0]), CalibParams::quick());

    let forward = eng.calibrate_batch(&reqs).unwrap();
    let mut rev: Vec<CalibRequest> = reqs.clone();
    rev.reverse();
    let mut backward = eng.calibrate_batch(&rev).unwrap();
    backward.reverse();
    for (f, b) in forward.iter().zip(&backward) {
        assert_eq!(f.levels, b.levels);
    }
    for (r, f) in reqs.iter().zip(&forward) {
        assert_eq!(eng.calibrate_one(r).unwrap().levels, f.levels);
    }

    let ereqs = batch.ecr_requests(&forward, 5, 1024);
    let reports = eng.measure_ecr_batch(&ereqs).unwrap();
    for (r, rep) in ereqs.iter().zip(&reports) {
        assert_eq!(eng.measure_ecr_one(r).unwrap().error_counts, rep.error_counts);
    }
}

#[test]
fn device_coordinator_runs_on_the_native_engine() {
    let cfg = DeviceConfig::default();
    let mut sys = SystemConfig::small();
    sys.cols = 1024;
    let coord = DeviceCoordinator::new(cfg.clone(), sys, NativeEngine::new(cfg));
    let outcomes = coord
        .run_banks(
            0xD00D,
            2,
            &FracConfig::baseline(3),
            &FracConfig::pudtune([2, 1, 0]),
            &CalibParams::quick(),
            1024,
        )
        .unwrap();
    assert_eq!(outcomes.len(), 2);
    let s = BankSummary::from_outcomes(&outcomes);
    assert_eq!(s.banks, 2);
    assert!(s.ecr5_base > 0.25, "baseline {}", s.ecr5_base);
    assert!(s.ecr5_tune < s.ecr5_base / 3.0, "{s}");
    assert!(s.ecr_arith_base >= s.ecr5_base, "{s}");
}

#[test]
fn calib_store_roundtrips_identified_data() {
    let cfg = DeviceConfig::default();
    let eng = NativeEngine::new(cfg.clone());
    let batch = BankBatch::from_device_seed(cfg.clone(), 256, 0x57013, 2);
    let calibs = batch
        .calib_requests(FracConfig::pudtune([2, 1, 0]), CalibParams::quick())
        .iter()
        .map(|r| eng.calibrate_one(r).unwrap())
        .collect::<Vec<_>>();
    let mut store = CalibStore::default();
    for (b, calib) in calibs.iter().enumerate() {
        store.insert(SubarrayId::new(0, b, 0), calib);
    }

    // to_json -> text -> from_json.
    let text = store.to_json().to_string();
    let back = CalibStore::from_json(&json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.entries, store.entries);

    // save_file -> load_file, and rehydration against the device config.
    let path = std::env::temp_dir().join("pudtune_engine_api_store.json");
    store.save_file(&path).unwrap();
    let reloaded = CalibStore::load_file(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(reloaded.entries, store.entries);
    for (b, calib) in calibs.iter().enumerate() {
        let re = reloaded.load(SubarrayId::new(0, b, 0), &cfg).unwrap().unwrap();
        assert_eq!(re.levels, calib.levels);
        assert_eq!(re.lattice.config, calib.lattice.config);
    }
}
