//! Storage parity: the hybrid bit-packed/analog `Subarray` must be
//! observably identical to the dense-`f32` reference model.
//!
//! Every test drives `Subarray` (hybrid) and `dram::dense::
//! DenseSubarray` (the pre-hybrid implementation, kept as the
//! executable specification) through the *same* command trace and
//! asserts after **every** command:
//!
//! * identical read-outs (read / SiMRA results),
//! * identical `OpCounts`,
//! * identical noise-stream positions (`rng_fingerprint`),
//! * bit-identical cell charges and identical packed/analog row state.
//!
//! Traces cover the regimes the hybrid representation special-cases:
//! Frac ladders, frac -> copy -> re-frac ordering, SiMRA with 0/1/many
//! analog rows open, retention decay crossing the packed/analog
//! boundary, Algorithm-1 calibration runs, and full adder/multiplier
//! workloads — plus seeded randomized traces that report a minimal
//! failing prefix on divergence.

#![cfg(feature = "reference-model")]

use std::collections::HashMap;

use pudtune::calib::algorithm::{CalibParams, Calibration, NativeEngine};
use pudtune::calib::lattice::{FracConfig, OffsetLattice};
use pudtune::config::device::DeviceConfig;
use pudtune::config::system::SystemConfig;
use pudtune::dram::dense::DenseSubarray;
use pudtune::dram::geometry::RowMap;
use pudtune::dram::subarray::Subarray;
use pudtune::pud::adder::{eval_add, ripple_adder};
use pudtune::pud::graph::{MajCircuit, Signal};
use pudtune::pud::multiplier::{array_multiplier, eval_mul};
use pudtune::util::proptest::check_res;
use pudtune::util::rng::Rng;

/// The command surface shared by both golden models.
trait GoldenModel {
    fn write_row(&mut self, row: usize, bits: &[u8]);
    fn fill_row(&mut self, row: usize, bit: u8);
    fn read_row(&mut self, row: usize) -> Vec<u8>;
    fn row_copy(&mut self, src: usize, dst: usize);
    fn frac(&mut self, row: usize);
    fn simra(&mut self, rows: &[usize]) -> Vec<u8>;
    fn set_temperature(&mut self, temp_c: f64);
    fn advance_time(&mut self, dt_hours: f64);
}

macro_rules! impl_model {
    ($t:ty) => {
        impl GoldenModel for $t {
            fn write_row(&mut self, row: usize, bits: &[u8]) {
                <$t>::write_row(self, row, bits)
            }
            fn fill_row(&mut self, row: usize, bit: u8) {
                <$t>::fill_row(self, row, bit)
            }
            fn read_row(&mut self, row: usize) -> Vec<u8> {
                <$t>::read_row(self, row)
            }
            fn row_copy(&mut self, src: usize, dst: usize) {
                <$t>::row_copy(self, src, dst)
            }
            fn frac(&mut self, row: usize) {
                <$t>::frac(self, row)
            }
            fn simra(&mut self, rows: &[usize]) -> Vec<u8> {
                <$t>::simra(self, rows)
            }
            fn set_temperature(&mut self, temp_c: f64) {
                <$t>::set_temperature(self, temp_c)
            }
            fn advance_time(&mut self, dt_hours: f64) {
                <$t>::advance_time(self, dt_hours)
            }
        }
    };
}
impl_model!(Subarray);
impl_model!(DenseSubarray);

/// One traced command.
#[derive(Clone, Debug)]
enum Op {
    Write { row: usize, bits: Vec<u8> },
    Fill { row: usize, bit: u8 },
    Read { row: usize },
    Copy { src: usize, dst: usize },
    Frac { row: usize },
    Simra { base: usize },
    SetTemp { temp_c: f64 },
    Advance { dt_hours: f64 },
}

fn apply<M: GoldenModel>(m: &mut M, op: &Op) -> Option<Vec<u8>> {
    match op {
        Op::Write { row, bits } => {
            m.write_row(*row, bits);
            None
        }
        Op::Fill { row, bit } => {
            m.fill_row(*row, *bit);
            None
        }
        Op::Read { row } => Some(m.read_row(*row)),
        Op::Copy { src, dst } => {
            m.row_copy(*src, *dst);
            None
        }
        Op::Frac { row } => {
            m.frac(*row);
            None
        }
        Op::Simra { base } => {
            let group: Vec<usize> = (*base..*base + 8).collect();
            Some(m.simra(&group))
        }
        Op::SetTemp { temp_c } => {
            m.set_temperature(*temp_c);
            None
        }
        Op::Advance { dt_hours } => {
            m.advance_time(*dt_hours);
            None
        }
    }
}

/// Full-state comparison: counts, noise-stream position, per-row
/// representation state and bit-exact charges.
fn parity(h: &Subarray, d: &DenseSubarray) -> Result<(), String> {
    if h.counts != d.counts {
        return Err(format!("OpCounts diverge: {:?} vs {:?}", h.counts, d.counts));
    }
    if h.rng_fingerprint() != d.rng_fingerprint() {
        return Err("noise-stream positions diverge".into());
    }
    if h.fault_flips() != d.fault_flips() || h.fault_fingerprint() != d.fault_fingerprint() {
        return Err(format!(
            "fault state diverges: {} flips (fp {:#018x}) vs {} flips (fp {:#018x})",
            h.fault_flips(),
            h.fault_fingerprint(),
            d.fault_flips(),
            d.fault_fingerprint()
        ));
    }
    if h.env.temp_c != d.env.temp_c || h.env.hours != d.env.hours {
        return Err("environments diverge".into());
    }
    for r in 0..h.rows {
        if h.row_is_packed(r) != d.row_is_packed(r) {
            return Err(format!(
                "row {r} storage state diverges: hybrid packed={}, dense full-swing={}",
                h.row_is_packed(r),
                d.row_is_packed(r)
            ));
        }
        for c in 0..h.cols {
            let (a, b) = (h.charge(r, c), d.charge(r, c));
            if a.to_bits() != b.to_bits() {
                return Err(format!("charge ({r},{c}) diverges: {a} vs {b}"));
            }
        }
    }
    Ok(())
}

const TRACE_ROWS: usize = 24;

/// Run one trace through both models with per-command comparison.
fn run_trace(cols: usize, tau_hours: f64, seed: u64, ops: &[Op]) -> Result<(), String> {
    let cfg = DeviceConfig {
        tau_retention_hours: tau_hours,
        retention_swing_min: 0.9,
        ..DeviceConfig::default()
    };
    let mut h = Subarray::with_geometry(&cfg, TRACE_ROWS, cols, seed);
    let mut d = DenseSubarray::with_geometry(&cfg, TRACE_ROWS, cols, seed);
    parity(&h, &d).map_err(|e| format!("fresh state: {e}"))?;
    for (i, op) in ops.iter().enumerate() {
        let oh = apply(&mut h, op);
        let od = apply(&mut d, op);
        if oh != od {
            return Err(format!("op {i} {op:?}: read-outs diverge"));
        }
        parity(&h, &d).map_err(|e| format!("op {i} {op:?}: {e}"))?;
    }
    Ok(())
}

fn expect_parity(name: &str, cols: usize, tau_hours: f64, seed: u64, ops: &[Op]) {
    if let Err(e) = run_trace(cols, tau_hours, seed, ops) {
        panic!("{name}: {e}");
    }
}

#[test]
fn frac_ladder_parity() {
    // Deep Frac ladders interleaved with reads: the row oscillates
    // between analog (frac) and packed (restore) representations.
    let mut ops = vec![Op::Fill { row: 0, bit: 1 }, Op::Fill { row: 1, bit: 0 }];
    for _ in 0..3 {
        for _ in 0..4 {
            ops.push(Op::Frac { row: 0 });
            ops.push(Op::Frac { row: 1 });
        }
        ops.push(Op::Read { row: 0 });
        ops.push(Op::Read { row: 1 });
    }
    // Columns 100 leaves a partial tail word in the packed words.
    expect_parity("frac-ladder", 100, f64::INFINITY, 0xA1, &ops);
}

#[test]
fn frac_copy_refrac_ordering_parity() {
    // PUDTune's central ordering constraint: RowCopy destroys
    // intermediate charge, so calibration rows are re-Frac'd after
    // every copy-in. The trace exercises frac -> copy -> re-frac on
    // both the source and destination sides.
    let bits: Vec<u8> = (0..96).map(|c| (c % 3 != 0) as u8).collect();
    let ops = vec![
        Op::Write { row: 8, bits: bits.clone() },
        Op::Frac { row: 8 },             // analog source
        Op::Copy { src: 8, dst: 3 },     // copy restores src, drives dst
        Op::Frac { row: 3 },             // re-frac the copied-in row
        Op::Frac { row: 3 },
        Op::Copy { src: 3, dst: 9 },     // analog src again
        Op::Frac { row: 9 },
        Op::Copy { src: 10, dst: 3 },    // packed src over a packed dst
        Op::Simra { base: 3 },           // group 3..11 with row 9 analog
        Op::Read { row: 3 },
    ];
    expect_parity("frac-copy-refrac", 96, f64::INFINITY, 0xB2, &ops);
}

#[test]
fn simra_with_zero_one_many_analog_rows_parity() {
    for (label, fracd) in [
        ("zero", vec![]),
        ("one", vec![4usize]),
        ("many", vec![1, 2, 5, 6, 7]),
        ("all", (0..8).collect()),
    ] {
        let mut ops = Vec::new();
        for r in 0..8 {
            ops.push(Op::Fill { row: r, bit: (r % 2) as u8 });
        }
        for &r in &fracd {
            ops.push(Op::Frac { row: r });
        }
        ops.push(Op::Simra { base: 0 });
        ops.push(Op::Simra { base: 0 }); // second SiMRA on the restored group
        for r in 0..8 {
            ops.push(Op::Read { row: r });
        }
        if let Err(e) = run_trace(129, f64::INFINITY, 0xC3, &ops) {
            panic!("simra-analog-{label}: {e}");
        }
    }
}

#[test]
fn retention_boundary_parity() {
    // Finite retention: small intervals keep full-swing rows packed
    // (refresh holds), long intervals push them over the threshold
    // into analog decay; Frac'd rows decay under every interval.
    // Temperature excursions ride along (they shift thresholds, so
    // read-outs depend on them).
    let ops = vec![
        Op::Fill { row: 0, bit: 1 },
        Op::Fill { row: 1, bit: 0 },
        Op::Fill { row: 2, bit: 1 },
        Op::Frac { row: 2 },
        Op::Advance { dt_hours: 0.05 }, // factor ~0.992: packed rows hold
        Op::Read { row: 0 },
        Op::SetTemp { temp_c: 75.0 },
        Op::Advance { dt_hours: 3.0 },  // factor ~0.61: crosses the boundary
        Op::Read { row: 0 },            // restore re-packs the decayed row
        Op::Frac { row: 1 },
        Op::Advance { dt_hours: 0.05 },
        Op::SetTemp { temp_c: 30.0 },
        Op::Simra { base: 0 },
        Op::Advance { dt_hours: 8.0 },  // deep decay of everything
        Op::Read { row: 2 },
    ];
    expect_parity("retention-boundary", 80, 6.0, 0xD4, &ops);
}

#[test]
fn randomized_trace_parity() {
    // Seeded randomized traces over both retention regimes; on
    // divergence the property re-runs prefixes to report the shortest
    // failing trace for replay.
    check_res(
        "storage-parity-random-traces",
        0x57AB1E,
        48,
        |r: &mut Rng| {
            let cols = [64usize, 96, 100, 129][r.below(4) as usize];
            let tau = if r.bool(0.5) { 6.0 } else { f64::INFINITY };
            let seed = r.next_u64();
            let n_ops = 24 + r.below(24) as usize;
            let rows = TRACE_ROWS as u64;
            let ops: Vec<Op> = (0..n_ops)
                .map(|_| match r.below(10) {
                    0 => Op::Write {
                        row: r.below(rows) as usize,
                        bits: (0..cols).map(|_| r.bit()).collect(),
                    },
                    1 => Op::Fill { row: r.below(rows) as usize, bit: r.bit() },
                    2 => Op::Read { row: r.below(rows) as usize },
                    3 => Op::Copy {
                        src: r.below(rows) as usize,
                        dst: r.below(rows) as usize,
                    },
                    4 | 5 | 6 => Op::Frac { row: r.below(rows) as usize },
                    7 => Op::Simra { base: r.below(rows - 7) as usize },
                    8 => Op::SetTemp { temp_c: 20.0 + r.f64() * 60.0 },
                    _ => Op::Advance {
                        dt_hours: if r.bool(0.4) { 1.0 + r.f64() * 3.0 } else { r.f64() * 0.2 },
                    },
                })
                .collect();
            (cols, tau, seed, ops)
        },
        |(cols, tau, seed, ops)| match run_trace(*cols, *tau, *seed, ops) {
            Ok(()) => Ok(()),
            Err(full) => {
                for n in 1..=ops.len() {
                    if let Err(e) = run_trace(*cols, *tau, *seed, &ops[..n]) {
                        return Err(format!(
                            "minimal failing prefix of {n} ops: {e}\n  prefix = {:?}",
                            &ops[..n]
                        ));
                    }
                }
                Err(full)
            }
        },
    );
}

#[test]
fn calibration_algorithm1_parity() {
    // Algorithm 1 + the ECR battery read only sense amps + environment,
    // and both models share those exactly; the identified levels then
    // flow back into the arrays as calibration row bits via the same
    // trace. End state must be identical.
    let cfg = DeviceConfig::default();
    let cols = 256;
    let mut h = Subarray::with_geometry(&cfg, TRACE_ROWS, cols, 0xE5);
    let mut d = DenseSubarray::with_geometry(&cfg, TRACE_ROWS, cols, 0xE5);
    let fc = FracConfig::pudtune([2, 1, 0]);
    let params = CalibParams::quick();
    let mut eng = NativeEngine::new(cfg.clone());
    let ch = eng.calibrate(&h, &fc, &params);
    let cd = eng.calibrate_columns(&d.sa, &d.env, &fc, &params);
    assert_eq!(ch.levels, cd.levels, "Algorithm 1 diverges across models");
    let map = RowMap::standard(64); // index arithmetic only
    for (i, &row) in map.calib_store.iter().enumerate() {
        let bits = ch.row_bits(i);
        h.write_row(row, &bits);
        d.write_row(row, &bits);
    }
    for (i, &n) in fc.fracs.iter().enumerate() {
        for _ in 0..n {
            h.frac(map.calib_store[i]);
            d.frac(map.calib_store[i]);
        }
    }
    assert_eq!(h.simra(&(8..16).collect::<Vec<_>>()), d.simra(&(8..16).collect::<Vec<_>>()));
    parity(&h, &d).unwrap();
}

/// Minimal deterministic gate executor over the shared model surface —
/// the MAJX flow of `pud::majx::execute_majx` (RowCopy-in, Frac,
/// SiMRA) without timing, so full circuits run identically on both
/// models.
struct Exec<'a, M: GoldenModel> {
    m: &'a mut M,
    map: &'a RowMap,
    input_rows: Vec<usize>,
    gate_rows: Vec<usize>,
    not_rows: HashMap<Signal, usize>,
    next_row: usize,
}

impl<M: GoldenModel> Exec<'_, M> {
    fn resolve(&mut self, sig: Signal) -> usize {
        match sig {
            Signal::Input(i) => self.input_rows[i],
            Signal::Gate(g) => self.gate_rows[g],
            Signal::Const(false) => self.map.const0,
            Signal::Const(true) => self.map.const1,
            Signal::NotInput(_) | Signal::NotGate(_) => {
                if let Some(&r) = self.not_rows.get(&sig) {
                    return r;
                }
                let src = match sig {
                    Signal::NotInput(i) => self.input_rows[i],
                    Signal::NotGate(g) => self.gate_rows[g],
                    _ => unreachable!(),
                };
                let mut bits = self.m.read_row(src);
                for b in &mut bits {
                    *b = 1 - *b;
                }
                let r = self.next_row;
                self.next_row += 1;
                self.m.write_row(r, &bits);
                self.not_rows.insert(sig, r);
                r
            }
        }
    }
}

fn run_circuit_on<M: GoldenModel>(
    m: &mut M,
    map: &RowMap,
    calib: &Calibration,
    fc: &FracConfig,
    circuit: &MajCircuit,
    inputs: &[Vec<u8>],
) -> Vec<Vec<u8>> {
    for (i, &row) in map.calib_store.iter().enumerate() {
        m.write_row(row, &calib.row_bits(i));
    }
    m.fill_row(map.const0, 0);
    m.fill_row(map.const1, 1);
    let mut ex = Exec {
        m,
        map,
        input_rows: Vec::new(),
        gate_rows: Vec::new(),
        not_rows: HashMap::new(),
        next_row: map.data_base,
    };
    for bits in inputs {
        let r = ex.next_row;
        ex.next_row += 1;
        ex.m.write_row(r, bits);
        ex.input_rows.push(r);
    }
    for gate in &circuit.gates {
        let arity = gate.arity();
        let op_rows: Vec<usize> = gate.args.iter().map(|&s| ex.resolve(s)).collect();
        let base = ex.map.simra_base;
        for (i, &r) in op_rows.iter().enumerate() {
            ex.m.row_copy(r, base + i);
        }
        for (i, &store) in ex.map.calib_store.iter().enumerate() {
            ex.m.row_copy(store, base + arity + i);
        }
        if arity + 3 < 8 {
            ex.m.row_copy(ex.map.const0, base + arity + 3);
            ex.m.row_copy(ex.map.const1, base + arity + 4);
        }
        for (i, &n) in fc.fracs.iter().enumerate() {
            for _ in 0..n {
                ex.m.frac(base + arity + i);
            }
        }
        let group: Vec<usize> = (base..base + 8).collect();
        let bits = ex.m.simra(&group);
        let r = ex.next_row;
        ex.next_row += 1;
        ex.m.write_row(r, &bits);
        ex.gate_rows.push(r);
    }
    let out_rows: Vec<usize> = circuit.outputs.iter().map(|&s| ex.resolve(s)).collect();
    out_rows.into_iter().map(|r| ex.m.read_row(r)).collect()
}

fn workload_parity(circuit: &MajCircuit, width: usize, cfg: &DeviceConfig, seed: u64) {
    let rows = 128;
    let cols = 16;
    let mut h = Subarray::with_geometry(cfg, rows, cols, seed);
    let mut d = DenseSubarray::with_geometry(cfg, rows, cols, seed);
    let map = RowMap::standard(rows);
    let fc = FracConfig::pudtune([2, 1, 0]);
    let calib = Calibration::uniform(OffsetLattice::build(cfg, &fc), cols);
    let mut rng = Rng::new(seed ^ 0xDA7A);
    let a: Vec<u64> = (0..cols).map(|_| rng.below(1 << width)).collect();
    let b: Vec<u64> = (0..cols).map(|_| rng.below(1 << width)).collect();
    let mut inputs = Vec::new();
    for bit in 0..width {
        inputs.push(a.iter().map(|&v| ((v >> bit) & 1) as u8).collect());
    }
    for bit in 0..width {
        inputs.push(b.iter().map(|&v| ((v >> bit) & 1) as u8).collect());
    }
    let oh = run_circuit_on(&mut h, &map, &calib, &fc, circuit, &inputs);
    let od = run_circuit_on(&mut d, &map, &calib, &fc, circuit, &inputs);
    assert_eq!(oh, od, "workload outputs diverge");
    parity(&h, &d).unwrap();
}

#[test]
fn adder_workload_parity_and_correctness() {
    let width = 3;
    let add = ripple_adder(width);
    // Noisy device: outputs may contain errors, but both models must
    // make *the same* errors.
    workload_parity(&add, width, &DeviceConfig::default(), 0xF6);
    // Quiet device: the in-DRAM run must also be functionally correct.
    let quiet = DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 1e-6,
        ..DeviceConfig::default()
    };
    let cols = 16;
    let mut h = Subarray::with_geometry(&quiet, 128, cols, 0xF7);
    let map = RowMap::standard(128);
    let fc = FracConfig::pudtune([2, 1, 0]);
    let calib = Calibration::uniform(OffsetLattice::build(&quiet, &fc), cols);
    let mut rng = Rng::new(5);
    let a: Vec<u64> = (0..cols).map(|_| rng.below(1 << width)).collect();
    let b: Vec<u64> = (0..cols).map(|_| rng.below(1 << width)).collect();
    let mut inputs = Vec::new();
    for bit in 0..width {
        inputs.push(a.iter().map(|&v| ((v >> bit) & 1) as u8).collect());
    }
    for bit in 0..width {
        inputs.push(b.iter().map(|&v| ((v >> bit) & 1) as u8).collect());
    }
    let outs = run_circuit_on(&mut h, &map, &calib, &fc, &add, &inputs);
    for c in 0..cols {
        let mut got = 0u64;
        for (bit, out) in outs.iter().enumerate() {
            got |= (out[c] as u64) << bit;
        }
        assert_eq!(got, a[c] + b[c], "col {c}");
        assert_eq!(got, eval_add(&add, width, a[c], b[c]), "col {c} (logic ref)");
    }
}

#[test]
fn multiplier_workload_parity() {
    let width = 2;
    let mul = array_multiplier(width);
    workload_parity(&mul, width, &DeviceConfig::default(), 0x3A);
    // eval_mul sanity on the same circuit (logic-level reference).
    assert_eq!(eval_mul(&mul, width, 3, 2), 6);
}

#[test]
fn fault_campaign_trace_parity() {
    // The standard corruption campaign on both models: the fault-field
    // draw, every injected flip (count and order digest, via
    // `parity`), and the corrupted read-outs must be bit-identical.
    // The trace is SiMRA-heavy — contested 4-of-8 patterns inside the
    // pattern window, full-swing aggressor rows on alternating rounds
    // for the coupling class, and enough op clock to sweep the
    // intermittent duty cycle (period 32).
    use pudtune::dram::faults::standard_campaign;
    let cfg = standard_campaign(&DeviceConfig::default());
    for seed in [1u64, 0x6057, 0xFA57] {
        let mut ops = Vec::new();
        for round in 0..40usize {
            for r in 0..8 {
                ops.push(Op::Fill { row: r, bit: ((r + round) % 2) as u8 });
            }
            ops.push(Op::Simra { base: 0 });
        }
        let mut h = Subarray::with_geometry(&cfg, TRACE_ROWS, 128, seed);
        let mut d = DenseSubarray::with_geometry(&cfg, TRACE_ROWS, 128, seed);
        assert!(h.fault_field().is_enabled());
        assert!(h.fault_field().faulty_cols() > 0, "seed {seed:#x} drew no faults");
        for (i, op) in ops.iter().enumerate() {
            let oh = apply(&mut h, op);
            let od = apply(&mut d, op);
            assert_eq!(oh, od, "seed {seed:#x} op {i} {op:?}: read-outs diverge");
            parity(&h, &d).unwrap_or_else(|e| panic!("seed {seed:#x} op {i} {op:?}: {e}"));
        }
        assert!(h.fault_flips() > 0, "seed {seed:#x}: campaign trace must inject flips");
    }
}

#[test]
fn hybrid_footprint_is_at_least_10x_smaller() {
    // Default geometry (512 x 16,384), <= 8 analog rows: the headline
    // memory claim, pinned by CI rather than by prose.
    let cfg = DeviceConfig::default();
    let sys = SystemConfig::default();
    let mut hyb = Subarray::new(&cfg, &sys, 1);
    let den = DenseSubarray::new(&cfg, &sys, 1);
    for r in 0..8 {
        hyb.frac(r);
    }
    assert_eq!(hyb.analog_rows(), 8);
    let ratio = den.approx_bytes() as f64 / hyb.approx_bytes() as f64;
    assert!(ratio >= 10.0, "dense/hybrid byte ratio {ratio:.1} < 10x");
    // Fully packed (the steady state between MAJX groups) is ~30x.
    let packed = Subarray::new(&cfg, &sys, 1);
    let ratio_packed = den.approx_bytes() as f64 / packed.approx_bytes() as f64;
    assert!(ratio_packed >= 20.0, "packed ratio {ratio_packed:.1}");
}
