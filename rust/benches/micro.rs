//! Micro-benchmarks of the hot paths (the §Perf iteration targets):
//! native sampling batch, golden-model SiMRA, PJRT step/ECR calls,
//! circuit evaluation, and the PRNG.

use pudtune::calib::algorithm::{CalibParams, NativeEngine};
use pudtune::calib::lattice::FracConfig;
use pudtune::config::device::DeviceConfig;
use pudtune::dram::subarray::Subarray;
use pudtune::pud::adder::{eval_add, ripple_adder};
use pudtune::runtime::Runtime;
use pudtune::util::benchkit;
use pudtune::util::rng::Rng;

fn main() {
    let cfg = DeviceConfig::default();

    // PRNG throughput (the native engine's inner dependency).
    let mut rng = Rng::new(1);
    benchkit::bench("micro/rng-normal-1M", 1, 10, || {
        let mut acc = 0.0;
        for _ in 0..1_000_000 {
            acc += rng.normal();
        }
        std::hint::black_box(acc);
    });

    // Native sampling batch: 512 samples x 8,192 columns (one
    // Algorithm-1 iteration's work).
    let eng = NativeEngine::new(cfg.clone());
    let sub = Subarray::with_geometry(&cfg, 32, 8192, 3);
    let fc = FracConfig::pudtune([2, 1, 0]);
    let calib = fc.uncalibrated(&cfg, 8192);
    let mut r2 = Rng::new(9);
    benchkit::bench("micro/native-sample-batch-512x8192", 1, 10, || {
        let acc = eng.sample_batch(&sub, &calib, 5, 512, &mut r2);
        std::hint::black_box(acc.samples());
    });

    // Golden-model SiMRA (command-level fidelity).
    let mut gsub = Subarray::with_geometry(&cfg, 32, 8192, 4);
    let rows: Vec<usize> = (0..8).collect();
    benchkit::bench("micro/golden-simra-8192cols", 2, 20, || {
        let out = gsub.simra(&rows);
        std::hint::black_box(out[0]);
    });

    // Full native calibration of one 8,192-column subarray.
    let mut eng2 = NativeEngine::new(cfg.clone());
    let mut sub2 = Subarray::with_geometry(&cfg, 32, 8192, 5);
    benchkit::bench("micro/native-calibrate-8192cols", 0, 3, || {
        let c = eng2.calibrate(&mut sub2, &fc, &CalibParams::paper());
        std::hint::black_box(c.levels[0]);
    });

    // Circuit evaluation (logic-level reference).
    let add8 = ripple_adder(8);
    benchkit::bench("micro/adder8-logic-eval-1k", 2, 20, || {
        let mut acc = 0u64;
        for a in 0..32u64 {
            for b in 0..32u64 {
                acc = acc.wrapping_add(eval_add(&add8, 8, a, b));
            }
        }
        std::hint::black_box(acc);
    });

    // PJRT calls (when artifacts are present).
    if let Ok(rt) = Runtime::open_default() {
        let rt = std::sync::Arc::new(rt);
        use pudtune::coordinator::engine::{ColumnBank, PjrtEngine};
        let peng = PjrtEngine::new(rt, cfg.clone());
        let bank = ColumnBank::new(&cfg, 16384, 6);
        let cal = fc.uncalibrated(&cfg, 16384);
        benchkit::bench("micro/pjrt-ecr-8192x16384", 1, 5, || {
            let rep = peng.measure_ecr(&bank, &cal, 5, 0xB).unwrap();
            std::hint::black_box(rep.error_free());
        });
        let params = CalibParams::paper();
        benchkit::bench("micro/pjrt-calibrate-16384", 0, 2, || {
            let c = peng.calibrate(&bank, &fc, &params).unwrap();
            std::hint::black_box(c.levels[0]);
        });
    } else {
        println!("(artifacts missing; skipping PJRT micro-benches)");
    }
}
