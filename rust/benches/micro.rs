//! Micro-benchmarks of the hot paths (the §Perf iteration targets):
//! native sampling batch, calibration sweep, batched `CalibEngine`
//! calls (native fan-out and fused multi-bank PJRT execution),
//! golden-model SiMRA, hybrid row storage (packed vs dense-reference
//! RowCopy/SiMRA and an end-to-end `calibrate_columns` case), PJRT
//! step/ECR calls, circuit evaluation, and the PRNG.
//!
//! Every case is recorded into `BENCH_calib.json` (written to the
//! working directory) so the repo's perf trajectory is machine
//! readable. The `/before` cases run the seed's scalar shared-stream
//! kernel (`NativeEngine::sample_batch_reference`); the `/after` cases
//! run the column-tiled kernel, so the recorded `*_speedup` deriveds
//! capture both the algorithmic win (uniform-space decisions, scratch
//! reuse) and the parallel win (config fan-out).

use pudtune::analysis::ecr::EcrReport;
use pudtune::calib::algorithm::{CalibParams, Calibration, NativeEngine};
use pudtune::calib::engine::{BankBatch, CalibEngine};
use pudtune::calib::lattice::{ConfigKind, FracConfig, OffsetLattice};
use pudtune::calib::sweep;
use pudtune::config::device::DeviceConfig;
use pudtune::config::system::SystemConfig;
use pudtune::coordinator::worker;
use pudtune::dram::subarray::Subarray;
use pudtune::pud::adder::{eval_add, ripple_adder};
use pudtune::runtime::Runtime;
use pudtune::util::benchkit::BenchSuite;
use pudtune::util::rng::Rng;

/// The seed's sweep implementation: sequential configs, scalar
/// shared-stream sampling, thresholds re-derived per column per batch.
/// Kept here as the honest "before" for the sweep speedup record.
fn sweep_reference(
    cfg: &DeviceConfig,
    sub: &Subarray,
    params: &CalibParams,
    ecr_samples: u32,
    configs: &[FracConfig],
) -> Vec<f64> {
    let eng = NativeEngine::serial(cfg.clone());
    configs
        .iter()
        .map(|fc| {
            let lattice = OffsetLattice::build(cfg, fc);
            let mut calib = Calibration::uniform(lattice, sub.cols);
            if fc.kind != ConfigKind::Baseline {
                let max_lv = (calib.lattice.len() - 1) as u8;
                let mut rng = Rng::new(params.seed);
                for _ in 0..params.iterations {
                    let acc =
                        eng.sample_batch_reference(sub, &calib, 5, params.samples, &mut rng);
                    for c in 0..sub.cols {
                        let bias = acc.bias(c);
                        if bias > params.tau || (acc.errors(c) > 0 && bias > 0.0) {
                            calib.levels[c] = calib.levels[c].saturating_sub(1);
                        } else if bias < -params.tau || (acc.errors(c) > 0 && bias < 0.0) {
                            calib.levels[c] = (calib.levels[c] + 1).min(max_lv);
                        }
                    }
                }
            }
            let mut rng =
                Rng::new(0xECC ^ sub.env.temp_c.to_bits() ^ sub.env.hours.to_bits());
            let acc = eng.sample_batch_reference(sub, &calib, 5, ecr_samples, &mut rng);
            EcrReport::from_error_counts(acc.error_counts().to_vec(), ecr_samples).ecr()
        })
        .collect()
}

/// Workload-serving cases (written to `BENCH_workload.json`): add8 and
/// mul8 compiled once (`WorkloadPlan`) and executed through the
/// batch-first `ComputeEngine` under the conventional vs PUDTune
/// arithmetic-usable (MAJ5 ∧ MAJ3 error-free) column masks. The
/// derived values record each op's Eq. 1 *effective* throughput per
/// mask and the PUDTune uplift — the Table I 1.88x/1.89x story as a
/// machine-readable trajectory — plus the batch-fusion win
/// (`workload_fused_speedup_batch8`: one step-major dispatch for 8
/// banks vs 8 per-request calls), the width-narrowing win on skewed
/// operands (`workload_narrowed_uplift`: Eq. 1 throughput of the
/// range-narrowed add8/mul8 variants over the wide plans, must stay
/// > 1) and the per-step fallback count over the built-in vocabulary
/// (`workload_pjrt_fallback_steps`, must stay 0). `PUDTUNE_FAST_BENCH=1`
/// shrinks the geometry/batteries for the CI smoke job.
fn workload_suite(cfg: &DeviceConfig, fast: bool) -> BenchSuite {
    use pudtune::analysis::throughput::ThroughputModel;
    use pudtune::calib::engine::{
        measure_arith_batteries, CalibRequest, ComputeEngine, ComputeRequest,
    };
    use pudtune::pud::plan::{PudOp, WorkloadPlan};
    use std::sync::Arc;

    let mut suite = BenchSuite::new();
    let cols = if fast { 256 } else { 1024 };
    let samples: u32 = if fast { 2048 } else { 8192 };
    let params = if fast { CalibParams::quick() } else { CalibParams::paper() };
    let seed = 0xB0B;
    let sub = Subarray::with_geometry(cfg, 192, cols, seed);
    let eng = NativeEngine::new(cfg.clone());
    let tune = FracConfig::pudtune([2, 1, 0]);
    let base = FracConfig::baseline(3);
    let calib = eng
        .calibrate_one(&CalibRequest::from_subarray(&sub, seed, tune, params))
        .unwrap();
    let base_cal = base.uncalibrated(cfg, cols);
    let batteries =
        measure_arith_batteries(&eng, &sub, seed, &[&base_cal, &calib], samples).unwrap();
    let base_mask = batteries[0].arith().error_free_mask();
    let tune_mask = batteries[1].arith().error_free_mask();
    let tput = ThroughputModel::new(&SystemConfig::paper());
    let mut rng = Rng::new(0x3AD);

    for (op, iters) in [
        (PudOp::Add { width: 8 }, if fast { 2 } else { 3 }),
        (PudOp::Mul { width: 8 }, if fast { 1 } else { 2 }),
    ] {
        let plan = Arc::new(WorkloadPlan::compile(op).unwrap());
        let opname = plan.op.label();
        let operands: Vec<Vec<u64>> = (0..plan.op.n_operands())
            .map(|_| (0..cols).map(|_| rng.below(256)).collect())
            .collect();
        let mut effective = Vec::with_capacity(2);
        for (label, fc, cal, mask) in [
            ("conventional", &base, &base_cal, &base_mask),
            ("pudtune", &tune, &calib, &tune_mask),
        ] {
            let req = ComputeRequest::from_subarray(
                &sub,
                seed,
                plan.clone(),
                cal.clone(),
                operands.clone(),
            )
            .with_mask(mask.clone());
            suite.bench(&format!("workload/{opname}-{label}-{cols}cols"), 0, iters, || {
                let res = eng.execute_one(&req).unwrap();
                std::hint::black_box(res.outputs[0]);
            });
            let free = mask.iter().filter(|&&m| m).count() as f64 / cols as f64;
            effective.push(tput.workload_ops(&plan.cost, fc, free));
        }
        suite.derive(&format!("{opname}_effective_ops_conventional"), effective[0]);
        suite.derive(&format!("{opname}_effective_ops_pudtune"), effective[1]);
        suite.derive(&format!("{opname}_effective_uplift"), effective[1] / effective[0]);
    }

    // Width-narrowed serving: nibble-valued operands declared as such
    // (`pud::ranges`), the wide 8-bit plans vs their
    // `WorkloadPlan::narrowed` variants on the same inputs. The timing
    // cases record the measured win; the `*_narrowed_uplift` deriveds
    // record the Eq. 1 uplift from the narrowed plans' smaller gate
    // cost (add8 16 -> 8 gates, mul8 176 -> 40), which the CI smoke
    // asserts stays > 1 via `workload_narrowed_uplift`.
    {
        use pudtune::pud::ranges::OperandRange;
        let nibble = vec![OperandRange::new(0, 15), OperandRange::new(0, 15)];
        let free = tune_mask.iter().filter(|&&m| m).count() as f64 / cols as f64;
        let mut narrowed_uplift = f64::INFINITY;
        for (op, iters) in [
            (PudOp::Add { width: 8 }, if fast { 2 } else { 3 }),
            (PudOp::Mul { width: 8 }, if fast { 1 } else { 2 }),
        ] {
            let wide = Arc::new(WorkloadPlan::compile(op).unwrap());
            let opname = wide.op.label();
            let narrow = Arc::new(wide.narrowed(&nibble).unwrap());
            let operands: Vec<Vec<u64>> = (0..wide.op.n_operands())
                .map(|_| (0..cols).map(|_| rng.below(16)).collect())
                .collect();
            for (label, plan) in [("wide", &wide), ("narrowed", &narrow)] {
                let req = ComputeRequest::from_subarray(
                    &sub,
                    seed,
                    plan.clone(),
                    calib.clone(),
                    operands.clone(),
                )
                .with_mask(tune_mask.clone());
                suite.bench(
                    &format!("workload/{opname}-nibble-{label}-{cols}cols"),
                    0,
                    iters,
                    || {
                        let res = eng.execute_one(&req).unwrap();
                        std::hint::black_box(res.outputs[0]);
                    },
                );
            }
            let op_uplift = tput.workload_ops(&narrow.cost, &tune, free)
                / tput.workload_ops(&wide.cost, &tune, free);
            suite.derive(&format!("{opname}_narrowed_uplift"), op_uplift);
            narrowed_uplift = narrowed_uplift.min(op_uplift);
        }
        suite.derive("workload_narrowed_uplift", narrowed_uplift);
    }

    // Fused vs looped dispatch: eight equal-geometry banks serving one
    // plan as a single step-major worker-pool dispatch vs eight
    // per-request calls. `workload_fused_speedup_batch8` records the
    // batching win (bounded by the worker-pool width; must stay > 1).
    let fused_plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 8 }).unwrap());
    let batch: Vec<ComputeRequest> = (0..8u64)
        .map(|i| {
            let operands: Vec<Vec<u64>> = (0..fused_plan.op.n_operands())
                .map(|_| (0..cols).map(|_| rng.below(256)).collect())
                .collect();
            ComputeRequest::from_subarray(
                &sub,
                seed ^ (i + 1),
                fused_plan.clone(),
                calib.clone(),
                operands,
            )
            .with_mask(tune_mask.clone())
        })
        .collect();
    let iters = if fast { 2 } else { 3 };
    let looped = suite.bench(&format!("workload/add8-looped-batch8-{cols}cols"), 0, iters, || {
        for req in &batch {
            let res = eng.execute_one(req).unwrap();
            std::hint::black_box(res.outputs[0]);
        }
    });
    let fused = suite.bench(&format!("workload/add8-fused-batch8-{cols}cols"), 0, iters, || {
        let res = eng.execute_batch(&batch).unwrap();
        std::hint::black_box(res.len());
    });
    suite.derive("workload_fused_speedup_batch8", looped.min_s / fused.min_s);

    // Per-step fallback classification over the whole built-in
    // vocabulary: every op must lower with zero unfusable steps (the
    // CI smoke asserts this stays 0).
    let fallback_steps: usize = PudOp::vocabulary(8)
        .into_iter()
        .map(|op| {
            let plan = WorkloadPlan::compile(op).unwrap();
            pudtune::coordinator::engine::unfusable_steps(&plan.lowered().unwrap())
        })
        .sum();
    suite.derive("workload_pjrt_fallback_steps", fallback_steps as f64);
    suite
}

/// Reliability record (written to `BENCH_reliability.json`): the
/// standard corruption campaign (`dram::faults::standard_campaign` —
/// every fault class at p = 1 over a quiet analog substrate) served
/// through `RecalibService` three ways — unprotected, quarantine +
/// scrub, and 3x redundant execution with majority vote. Deriveds
/// record each stack's masked golden correctness (the protected stack
/// must reach 1.0 once quarantine converges), the quarantined column
/// count, and the Eq. 1 effective-throughput retention the
/// countermeasures cost. `PUDTUNE_FAST_BENCH=1` shrinks the geometry
/// for the CI campaign-smoke job.
fn reliability_suite(cfg: &DeviceConfig, fast: bool) -> BenchSuite {
    use pudtune::analysis::throughput::ThroughputModel;
    use pudtune::coordinator::service::{RecalibService, ServiceConfig, WorkloadOutcome};
    use pudtune::dram::faults::standard_campaign;
    use pudtune::dram::geometry::SubarrayId;
    use pudtune::pud::plan::{PudOp, WorkloadPlan};
    use std::sync::Arc;

    /// Masked golden correctness and total served width over one
    /// epoch's outcomes.
    fn correctness(outs: &[WorkloadOutcome]) -> (f64, usize) {
        let (mut ok, mut active) = (0usize, 0usize);
        for o in outs {
            ok += o.golden_correct;
            active += o.active_cols;
        }
        let frac = if active == 0 { 1.0 } else { ok as f64 / active as f64 };
        (frac, active)
    }

    let mut suite = BenchSuite::new();
    let cols = if fast { 256 } else { 1024 };
    let banks = if fast { 2 } else { 4 };
    let epochs = if fast { 3 } else { 6 };
    let campaign = standard_campaign(cfg);
    let svc_base = ServiceConfig {
        serve_samples: if fast { 512 } else { 2048 },
        ..ServiceConfig::default()
    };
    let build = |svc: ServiceConfig| {
        let s =
            RecalibService::new(campaign.clone(), svc, NativeEngine::new(campaign.clone()))
                .unwrap();
        for b in 0..banks {
            s.register(SubarrayId::new(0, b, 0), 32, cols, 0xBE5E);
        }
        s.run_pending(usize::MAX);
        s
    };
    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 2 }).unwrap());
    let mut rng = Rng::new(0xBE11);
    let operands: Vec<Vec<u64>> = (0..plan.op.n_operands())
        .map(|_| (0..cols).map(|_| rng.below(4)).collect())
        .collect();

    // Unprotected: the corruption the campaign inflicts every epoch.
    let unprot = build(svc_base);
    let mut raw = (1.0, 0usize);
    for _ in 0..epochs {
        raw = correctness(&unprot.serve_plan(&plan, &operands).expect("compiled plan serves"));
    }
    suite.derive("reliability_masked_correctness_unprotected", raw.0);

    // Quarantine + scrub: converge, then time a steady-state epoch.
    let prot = build(ServiceConfig {
        quarantine_strikes: 2,
        quarantine_clean_passes: 2,
        scrub_every: 1,
        ..svc_base
    });
    for _ in 0..epochs {
        prot.serve_plan(&plan, &operands).expect("compiled plan serves");
        prot.maintain();
    }
    suite.bench(
        &format!("reliability/protected-epoch-{banks}x{cols}"),
        0,
        if fast { 2 } else { 3 },
        || {
            let outs = prot.serve_plan(&plan, &operands).expect("compiled plan serves");
            std::hint::black_box(outs.len());
            let (_, scrubs) = prot.maintain();
            std::hint::black_box(scrubs.len());
        },
    );
    let steady = correctness(&prot.serve_plan(&plan, &operands).expect("compiled plan serves"));
    suite.derive("reliability_masked_correctness_protected", steady.0);
    let quarantined: usize = prot
        .ids()
        .iter()
        .map(|id| prot.quarantine(*id).map_or(0, |q| q.quarantined_cols()))
        .sum();
    suite.derive("reliability_quarantined_cols", quarantined as f64);
    // Eq. 1 accounting for the protection cost: quarantined columns
    // stop serving, shrinking effective throughput against the clean
    // full-width device.
    let tput = ThroughputModel::new(&SystemConfig::paper());
    let fc = FracConfig::pudtune([2, 1, 0]);
    let full = tput.workload_ops(&plan.cost, &fc, 1.0);
    let retained =
        tput.workload_ops(&plan.cost, &fc, steady.1 as f64 / (banks * cols) as f64);
    suite.derive("reliability_throughput_retention", retained / full);

    // 3x redundant execution: majority vote over independent replica
    // fault fields, no quarantine state needed.
    let red = build(ServiceConfig { redundancy: 3, ..svc_base });
    let voted = correctness(&red.serve_plan(&plan, &operands).expect("compiled plan serves"));
    suite.derive("reliability_masked_correctness_redundant3", voted.0);
    suite
}

/// Concurrent-serving record (written to `BENCH_serve.json`): workload
/// throughput through the admission-controlled `serve_plan` path with
/// zero vs continuous background recalibration pressure (a
/// `ServiceServer`'s worker threads repairing operator-requested
/// recalibrations the whole time), plus the graceful-drain latency.
/// Deriveds record the concurrent/idle throughput ratio — how much
/// serving capacity background repair traffic costs — and
/// `serve_drain_latency_s`. `PUDTUNE_FAST_BENCH=1` shrinks the
/// geometry for the CI smoke job.
fn serve_suite(cfg: &DeviceConfig, fast: bool) -> BenchSuite {
    use pudtune::coordinator::service::{RecalibService, ServiceConfig, ServiceServer};
    use pudtune::dram::geometry::SubarrayId;
    use pudtune::pud::plan::{PudOp, WorkloadPlan};
    use std::sync::Arc;
    use std::time::Instant;

    let mut suite = BenchSuite::new();
    let cols = if fast { 256 } else { 1024 };
    let banks = if fast { 2 } else { 4 };
    let iters = if fast { 3 } else { 5 };
    let svc_cfg = ServiceConfig {
        serve_samples: if fast { 512 } else { 2048 },
        params: CalibParams::quick(),
        maintain_every_ms: 5,
        ..ServiceConfig::default()
    };
    let s = Arc::new(
        RecalibService::new(cfg.clone(), svc_cfg, NativeEngine::new(cfg.clone())).unwrap(),
    );
    let ids: Vec<SubarrayId> = (0..banks)
        .map(|b| {
            let id = SubarrayId::new(b % 2, b, 0);
            s.register(id, 32, cols, 0x5E7E);
            id
        })
        .collect();
    s.run_pending(usize::MAX);
    for o in s.serve() {
        o.report.as_ref().expect("mask battery");
    }
    let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 2 }).unwrap());
    let mut rng = Rng::new(0x5E7E);
    let operands: Vec<Vec<u64>> = (0..plan.op.n_operands())
        .map(|_| (0..cols).map(|_| rng.below(4)).collect())
        .collect();

    // Baseline: serving with no background work at all.
    let idle = suite.bench(&format!("serve/idle-{banks}x{cols}"), 1, iters, || {
        let outs = s.serve_plan(&plan, &operands).expect("compiled plan serves");
        std::hint::black_box(outs.len());
    });

    // Concurrent: every iteration forces a fresh recalibration of all
    // banks, so the worker threads repair continuously while the
    // measured thread serves against the same shards.
    let server = ServiceServer::start(s.clone(), 2);
    let under = suite.bench(
        &format!("serve/under-recalib-{banks}x{cols}"),
        1,
        iters,
        || {
            for &id in &ids {
                s.request_recalibration(id);
            }
            let outs = s.serve_plan(&plan, &operands).expect("compiled plan serves");
            std::hint::black_box(outs.len());
        },
    );
    let served_cols = (banks * cols) as f64;
    suite.derive("serve_idle_cols_per_s", served_cols / idle.min_s);
    suite.derive("serve_under_recalib_cols_per_s", served_cols / under.min_s);
    suite.derive("serve_concurrent_throughput_ratio", idle.min_s / under.min_s);

    // Graceful drain with the recalibration queue still warm: finish
    // every queued repair, join the workers, persist the store.
    let t = Instant::now();
    let store = server.drain();
    let drain_s = t.elapsed().as_secs_f64();
    assert_eq!(store.entries.len(), banks, "drain persists every bank");
    suite.derive("serve_drain_latency_s", drain_s);
    suite
}

fn main() {
    let cfg = DeviceConfig::default();
    let mut suite = BenchSuite::new();

    // Workload serving + reliability + concurrent-serving records
    // (fast mode + the option to run one suite keep the CI smoke jobs
    // cheap).
    let fast = std::env::var_os("PUDTUNE_FAST_BENCH").is_some();
    let only = std::env::var("PUDTUNE_BENCH_ONLY").ok();
    let want = |name: &str| only.as_deref().is_none_or(|o| o == name);
    if want("workload") {
        let wsuite = workload_suite(&cfg, fast);
        let wout = std::path::Path::new("BENCH_workload.json");
        wsuite.write_json(wout).expect("writing BENCH_workload.json");
        println!("wrote {}", wout.display());
    }
    if want("reliability") {
        let rsuite = reliability_suite(&cfg, fast);
        let rout = std::path::Path::new("BENCH_reliability.json");
        rsuite.write_json(rout).expect("writing BENCH_reliability.json");
        println!("wrote {}", rout.display());
    }
    if want("serve") {
        let ssuite = serve_suite(&cfg, fast);
        let sout = std::path::Path::new("BENCH_serve.json");
        ssuite.write_json(sout).expect("writing BENCH_serve.json");
        println!("wrote {}", sout.display());
    }
    if only.is_some() {
        return;
    }

    // PRNG throughput (the native engine's inner dependency).
    let mut rng = Rng::new(1);
    suite.bench("micro/rng-normal-1M", 1, 10, || {
        let mut acc = 0.0;
        for _ in 0..1_000_000 {
            acc += rng.normal();
        }
        std::hint::black_box(acc);
    });

    // Static verification: the admission-path cost of re-verifying an
    // unverified plan (compiled plans skip this in O(1)), on the
    // cheapest and costliest common arithmetic plans.
    {
        use pudtune::pud::plan::{PudOp, WorkloadPlan};
        use pudtune::pud::verify::verify_plan;
        let add8 = WorkloadPlan::compile(PudOp::Add { width: 8 }).unwrap();
        let mul8 = WorkloadPlan::compile(PudOp::Mul { width: 8 }).unwrap();
        suite.bench("micro/verify-add8", 2, 20, || {
            let report = verify_plan(&add8);
            assert!(report.is_clean());
            std::hint::black_box(report.peak_rows);
        });
        suite.bench("micro/verify-mul8", 2, 20, || {
            let report = verify_plan(&mul8);
            assert!(report.is_clean());
            std::hint::black_box(report.peak_rows);
        });

        // Bit-level range analysis + width narrowing: the cost of
        // proving the nibble range class and rewriting the plan to its
        // minimal safe width — paid once per (op, geometry, range
        // class) in production thanks to the plan cache.
        use pudtune::pud::ranges::{analyze_plan, OperandRange};
        let nibble = [OperandRange::new(0, 15), OperandRange::new(0, 15)];
        suite.bench("micro/analyze-add8", 2, 20, || {
            let report = analyze_plan(&add8, &nibble).unwrap();
            let narrowed = add8.narrowed(&nibble).unwrap();
            assert_eq!(narrowed.circuit.gates.len(), report.narrowed_gates());
            std::hint::black_box(narrowed.peak_rows);
        });
        suite.bench("micro/analyze-mul8", 2, 20, || {
            let report = analyze_plan(&mul8, &nibble).unwrap();
            let narrowed = mul8.narrowed(&nibble).unwrap();
            assert_eq!(narrowed.circuit.gates.len(), report.narrowed_gates());
            std::hint::black_box(narrowed.peak_rows);
        });
    }

    // Native sampling batch: 512 samples x 8,192 columns (one
    // Algorithm-1 iteration's work), seed kernel vs tiled kernel.
    let mut eng = NativeEngine::new(cfg.clone());
    let sub = Subarray::with_geometry(&cfg, 32, 8192, 3);
    let fc = FracConfig::pudtune([2, 1, 0]);
    let calib = fc.uncalibrated(&cfg, 8192);
    let mut r2 = Rng::new(9);
    let batch_before = suite.bench("micro/sample-batch-512x8192/before", 1, 5, || {
        let acc = eng.sample_batch_reference(&sub, &calib, 5, 512, &mut r2);
        std::hint::black_box(acc.samples());
    });
    let mut batch_seed = 0u64;
    let batch_after = suite.bench("micro/sample-batch-512x8192/after", 1, 10, || {
        batch_seed += 1;
        let acc = eng.sample_batch(&sub, &calib, 5, 512, batch_seed);
        std::hint::black_box(acc.samples());
    });
    suite.derive("sample_batch_speedup", batch_before.min_s / batch_after.min_s);

    // ECR measurement: 2,048 samples x 2,048 columns.
    let esub = Subarray::with_geometry(&cfg, 32, 2048, 7);
    let ecal = FracConfig::pudtune([2, 1, 0]).uncalibrated(&cfg, 2048);
    let ecr_before = suite.bench("micro/measure-ecr-2048x2048/before", 1, 5, || {
        let mut rng =
            Rng::new(0xECC ^ esub.env.temp_c.to_bits() ^ esub.env.hours.to_bits());
        let acc = eng.sample_batch_reference(&esub, &ecal, 5, 2048, &mut rng);
        let rep = EcrReport::from_error_counts(acc.error_counts().to_vec(), 2048);
        std::hint::black_box(rep.ecr());
    });
    let ecr_after = suite.bench("micro/measure-ecr-2048x2048/after", 1, 10, || {
        let rep = eng.measure_ecr(&esub, &ecal, 5, 2048);
        std::hint::black_box(rep.ecr());
    });
    suite.derive("measure_ecr_speedup", ecr_before.min_s / ecr_after.min_s);

    // Calibration sweep over the Fig. 5 config list at 2,048 columns —
    // the headline before/after of this optimisation round.
    let mut sys = SystemConfig::small();
    sys.cols = 2048;
    let ssub = Subarray::new(&cfg, &sys, 21);
    let params = CalibParams::quick();
    let configs = sweep::fig5_configs();
    let sweep_before = suite.bench("micro/sweep-fig5-2048cols/before", 0, 2, || {
        let ecrs = sweep_reference(&cfg, &ssub, &params, 2048, &configs);
        std::hint::black_box(ecrs.len());
    });
    suite.bench("micro/sweep-fig5-2048cols/after-serial", 0, 3, || {
        let pts = sweep::sweep_configs_threads(&cfg, &sys, &ssub, &params, 2048, &configs, 1);
        std::hint::black_box(pts.len());
    });
    let threads = worker::default_threads();
    let sweep_after = suite.bench("micro/sweep-fig5-2048cols/after-parallel", 0, 3, || {
        let pts =
            sweep::sweep_configs_threads(&cfg, &sys, &ssub, &params, 2048, &configs, threads);
        std::hint::black_box(pts.len());
    });
    suite.derive("sweep_fig5_2048cols_speedup", sweep_before.min_s / sweep_after.min_s);

    // Batch-first CalibEngine API: whole-device calibration as one
    // trait call (the engine fans banks across the worker pool) vs the
    // same requests issued one at a time.
    let beng = NativeEngine::new(cfg.clone());
    let bbatch = BankBatch::from_device_seed(cfg.clone(), 2048, 0xBA7C4, 8);
    let breqs = bbatch.calib_requests(fc, CalibParams::quick());
    let batch_seq = suite.bench("micro/calibrate-8x2048/one-at-a-time", 0, 2, || {
        for r in &breqs {
            std::hint::black_box(beng.calibrate_one(r).unwrap().levels[0]);
        }
    });
    let batch_par = suite.bench("micro/calibrate-8x2048/batched", 0, 3, || {
        let calibs = beng.calibrate_batch(&breqs).unwrap();
        std::hint::black_box(calibs.len());
    });
    suite.derive("calibrate_batch_speedup", batch_seq.min_s / batch_par.min_s);

    // Golden-model SiMRA (command-level fidelity).
    let mut gsub = Subarray::with_geometry(&cfg, 32, 8192, 4);
    let rows: Vec<usize> = (0..8).collect();
    let mut simra_out = vec![0u8; 8192];
    suite.bench("micro/golden-simra-8192cols", 2, 20, || {
        gsub.simra_into(&rows, &mut simra_out);
        std::hint::black_box(simra_out[0]);
    });

    // Hybrid row storage: packed RowCopy / SiMRA vs the dense-f32
    // reference model (the seed's per-cell implementation), plus an
    // end-to-end calibrate_columns case so the perf trajectory records
    // this path.
    let mut hsub = Subarray::with_geometry(&cfg, 64, 8192, 12);
    let copy_packed = suite.bench("storage/rowcopy-packed-8192", 3, 50, || {
        hsub.row_copy(0, 1);
        std::hint::black_box(hsub.charge(1, 0));
    });
    let mut hout = vec![0u8; 8192];
    let simra_packed = suite.bench("storage/simra-packed-8192", 2, 20, || {
        hsub.simra_into(&rows, &mut hout);
        std::hint::black_box(hout[0]);
    });
    let mut ceng = NativeEngine::serial(cfg.clone());
    suite.bench("storage/calibrate-columns-2048", 0, 3, || {
        let c = ceng.calibrate_columns(&esub.sa, &esub.env, &fc, &CalibParams::quick());
        std::hint::black_box(c.levels[0]);
    });
    #[cfg(feature = "reference-model")]
    {
        use pudtune::dram::dense::DenseSubarray;
        let mut dsub = DenseSubarray::with_geometry(&cfg, 64, 8192, 12);
        let copy_dense = suite.bench("storage/rowcopy-dense-8192", 3, 50, || {
            dsub.row_copy(0, 1);
            std::hint::black_box(dsub.charge(1, 0));
        });
        suite.derive("storage_rowcopy_speedup", copy_dense.min_s / copy_packed.min_s);
        let mut dout = vec![0u8; 8192];
        let simra_dense = suite.bench("storage/simra-dense-8192", 2, 20, || {
            dsub.simra_into(&rows, &mut dout);
            std::hint::black_box(dout[0]);
        });
        suite.derive("storage_simra_speedup", simra_dense.min_s / simra_packed.min_s);
    }
    #[cfg(not(feature = "reference-model"))]
    {
        let _ = (copy_packed, simra_packed);
        println!("(reference-model feature off; skipping dense storage benches)");
    }

    // Full native calibration of one 8,192-column subarray.
    let mut eng2 = NativeEngine::new(cfg.clone());
    let sub2 = Subarray::with_geometry(&cfg, 32, 8192, 5);
    suite.bench("micro/native-calibrate-8192cols", 0, 3, || {
        let c = eng2.calibrate(&sub2, &fc, &CalibParams::paper());
        std::hint::black_box(c.levels[0]);
    });

    // Circuit evaluation (logic-level reference).
    let add8 = ripple_adder(8);
    suite.bench("micro/adder8-logic-eval-1k", 2, 20, || {
        let mut acc = 0u64;
        for a in 0..32u64 {
            for b in 0..32u64 {
                acc = acc.wrapping_add(eval_add(&add8, 8, a, b));
            }
        }
        std::hint::black_box(acc);
    });

    // PJRT calls (when artifacts are present).
    if let Ok(rt) = Runtime::open_default() {
        let rt = std::sync::Arc::new(rt);
        use pudtune::coordinator::engine::{ColumnBank, PjrtEngine};
        let peng = PjrtEngine::new(rt, cfg.clone());
        let bank = ColumnBank::new(&cfg, 16384, 6);
        let cal = fc.uncalibrated(&cfg, 16384);
        suite.bench("micro/pjrt-ecr-8192x16384", 1, 5, || {
            let rep = peng.measure_ecr(&bank, &cal, 5, 0xB).unwrap();
            std::hint::black_box(rep.error_free());
        });
        let pparams = CalibParams::paper();
        suite.bench("micro/pjrt-calibrate-16384", 0, 2, || {
            let c = peng.calibrate(&bank, &fc, &pparams).unwrap();
            std::hint::black_box(c.levels[0]);
        });

        // Batched multi-bank PJRT calibration: 4 x 4096-column banks
        // stacked into the 16,384-column step artifact — one
        // executable call per Algorithm-1 iteration for the whole
        // batch. The derived `pjrt_step_calls_per_batched_run` records
        // the executable-call count per run: `iterations` when fused
        // (vs `banks * iterations` issued one bank at a time).
        let pbatch = BankBatch::from_device_seed(cfg.clone(), 4096, 0xFA7, 4);
        let preqs = pbatch.calib_requests(fc, pparams);
        let calls_before = peng.metrics.counter("pjrt.step.calls");
        const BATCH_RUNS: u32 = 2;
        suite.bench("micro/pjrt-calibrate-batch-4x4096", 0, BATCH_RUNS, || {
            let calibs = peng.calibrate_batch(&preqs).unwrap();
            std::hint::black_box(calibs.len());
        });
        let calls_per_run = (peng.metrics.counter("pjrt.step.calls") - calls_before) as f64
            / BATCH_RUNS as f64;
        suite.derive("pjrt_step_calls_per_batched_run", calls_per_run);
        suite.derive(
            "pjrt_banks_per_step_call",
            preqs.len() as f64 * pparams.iterations as f64 / calls_per_run,
        );
    } else {
        println!("(artifacts missing; skipping PJRT micro-benches)");
    }

    let out = std::path::Path::new("BENCH_calib.json");
    suite.write_json(out).expect("writing BENCH_calib.json");
    println!("wrote {}", out.display());
}
