//! Bench: regenerate Fig. 5 (MAJ5 ECR/throughput sensitivity to the
//! Frac configuration).

use pudtune::analysis::report;
use pudtune::calib::lattice::FracConfig;
use pudtune::config::device::DeviceConfig;
use pudtune::config::experiment::ExperimentConfig;
use pudtune::config::system::SystemConfig;
use pudtune::experiments;
use pudtune::util::{benchkit, table};

fn main() {
    let cfg = DeviceConfig::default();
    let sys = SystemConfig { cols: 8192, ..SystemConfig::default() };
    let exp = ExperimentConfig::default();

    let mut pts = Vec::new();
    let r = benchkit::bench("fig5/sweep-15-configs", 0, 1, || {
        pts = experiments::run_fig5(&cfg, &sys, &exp);
    });
    let rows: Vec<(FracConfig, f64, f64)> =
        pts.iter().map(|p| (p.config, p.ecr, p.maj5_ops)).collect();
    println!("\n=== Fig. 5 (MAJ5 sensitivity to Frac times) ===\n");
    println!("{}", report::render_sweep(&rows));
    let chart: Vec<(String, f64)> = pts
        .iter()
        .map(|p| (p.config.label(), p.maj5_ops / 1e12))
        .collect();
    println!("{}", table::bar_chart("MAJ5 throughput", &chart, "TOPS", 40));

    // Paper's headline comparisons.
    let find = |fr: [u32; 3]| {
        pts.iter()
            .find(|p| p.config == FracConfig::pudtune(fr))
            .map(|p| p.maj5_ops)
            .unwrap_or(f64::NAN)
    };
    let t210 = find([2, 1, 0]);
    println!(
        "T_2,1,0 vs T_0,0,0: {:.2}x (paper 1.03x) | vs T_2,2,2: {:.2}x (paper 1.48x)",
        t210 / find([0, 0, 0]),
        t210 / find([2, 2, 2])
    );
    println!("sweep wall: {}", benchkit::fmt_time(r.mean_s));
}
