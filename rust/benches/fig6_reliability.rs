//! Bench: regenerate Fig. 6 (thermal + aging reliability of the
//! identified calibration data).

use pudtune::analysis::report;
use pudtune::config::device::DeviceConfig;
use pudtune::config::experiment::ExperimentConfig;
use pudtune::config::system::SystemConfig;
use pudtune::experiments;
use pudtune::util::benchkit;

fn main() {
    let cfg = DeviceConfig::default();
    let sys = SystemConfig { cols: 8192, ..SystemConfig::default() };
    let exp = ExperimentConfig::default();

    let mut a = Vec::new();
    let ra = benchkit::bench("fig6a/temperature-sweep", 0, 1, || {
        a = experiments::run_fig6a(&cfg, &sys, &exp);
    });
    println!("\n=== Fig. 6a (temperature 40-100C; paper: new ECR < 0.14%) ===");
    let series: Vec<(f64, f64)> = a.iter().map(|p| (p.x, p.new_ecr)).collect();
    println!("{}", report::render_reliability("Temp (C)", &series));
    let worst_a = a.iter().map(|p| p.new_ecr).fold(0.0, f64::max);
    println!("worst new ECR: {:.3}% (paper bound 0.14%)\n", worst_a * 100.0);

    let mut b = Vec::new();
    let rb = benchkit::bench("fig6b/one-week-aging", 0, 1, || {
        b = experiments::run_fig6b(&cfg, &sys, &exp);
    });
    println!("\n=== Fig. 6b (one week; paper: new ECR < 0.27%) ===");
    let series: Vec<(f64, f64)> = b.iter().map(|p| (p.x, p.new_ecr)).collect();
    println!("{}", report::render_reliability("Hours", &series));
    let worst_b = b.iter().map(|p| p.new_ecr).fold(0.0, f64::max);
    println!("worst new ECR: {:.3}% (paper bound 0.27%)", worst_b * 100.0);
    println!(
        "walls: fig6a {} fig6b {}",
        benchkit::fmt_time(ra.mean_s),
        benchkit::fmt_time(rb.mean_s)
    );
}
