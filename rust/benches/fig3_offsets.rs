//! Bench: regenerate Fig. 3 (offset variety per Frac configuration)
//! plus lattice-construction micro-benchmarks.

use pudtune::calib::lattice::{FracConfig, OffsetLattice};
use pudtune::config::device::DeviceConfig;
use pudtune::experiments;
use pudtune::util::benchkit;

fn main() {
    let cfg = DeviceConfig::default();
    println!("{}", experiments::run_fig3(&cfg));
    println!("paper Fig. 3: T_0,0,0 wide/coarse; T_2,2,2 fine/narrow; T_2,1,0 fine AND wide\n");

    // Quantify the Fig. 3 claim as numbers.
    let t210 = OffsetLattice::build(&cfg, &FracConfig::pudtune([2, 1, 0]));
    let t000 = OffsetLattice::build(&cfg, &FracConfig::pudtune([0, 0, 0]));
    let t222 = OffsetLattice::build(&cfg, &FracConfig::pudtune([2, 2, 2]));
    println!(
        "range(T210)/range(T222) = {:.2}   gap(T000)/gap(T210) = {:.2}",
        t210.range().1 / t222.range().1,
        t000.max_gap() / t210.max_gap()
    );

    benchkit::bench("fig3/lattice-build", 10, 100, || {
        let l = OffsetLattice::build(&cfg, &FracConfig::pudtune([2, 1, 0]));
        std::hint::black_box(l.len());
    });
}
