//! Bench: regenerate the paper's Table I (ECR + MAJ5/ADD/MUL
//! throughput, baseline vs PUDTune) and time the pipeline phases.
//!
//! `cargo bench --bench table1` — add `-- --full` for the paper's
//! 65,536-column geometry (slow on one core).

use pudtune::calib::engine::AnyEngine;
use pudtune::calib::lattice::FracConfig;
use pudtune::config::device::DeviceConfig;
use pudtune::config::experiment::ExperimentConfig;
use pudtune::config::system::SystemConfig;
use pudtune::experiments;
use pudtune::util::benchkit;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let cfg = DeviceConfig::default();
    // Column counts must match an AOT artifact shape (16,384 std /
    // 65,536 full) for the PJRT engine.
    let sys = if full { SystemConfig::paper() } else { SystemConfig::default() };
    let mut exp = ExperimentConfig::default();
    exp.banks = if full { 16 } else { 4 };

    println!("=== Table I ({} banks x {} cols, {} ECR samples/bank) ===\n", exp.banks, sys.cols, exp.ecr_samples);
    let engine = AnyEngine::auto(cfg.clone());
    let base = FracConfig::baseline(3);
    let tune = FracConfig::pudtune([2, 1, 0]);

    let mut rendered = String::new();
    let r = benchkit::bench("table1/full-pipeline", 0, 1, || {
        let out = experiments::run_table1(&cfg, &sys, &exp, &engine, base, tune).unwrap();
        rendered = out.rendered.clone();
    });
    println!("\n{rendered}");
    println!("paper Table I:     ECR 46.6% / 3.3%; MAJ5 0.89 / 1.62 TOPS; ADD 50.2 / 94.6 GOPS; MUL 5.8 / 11.0 GOPS");
    println!("pipeline wall: {}", benchkit::fmt_time(r.mean_s));

    // Phase micro-timings on one bank.
    use pudtune::calib::algorithm::{CalibParams, NativeEngine};
    use pudtune::dram::subarray::Subarray;
    let mut eng = NativeEngine::new(cfg.clone());
    let sub = Subarray::with_geometry(&cfg, 32, sys.cols, 1);
    let params = CalibParams::paper();
    benchkit::bench_budget("table1/calibrate-one-bank", 3.0, || {
        let c = eng.calibrate(&sub, &tune, &params);
        std::hint::black_box(&c.levels);
    });
    let calib = eng.calibrate(&sub, &tune, &params);
    benchkit::bench_budget("table1/ecr-8192-samples", 3.0, || {
        let r = eng.measure_ecr(&sub, &calib, 5, 8192);
        std::hint::black_box(r.ecr());
    });
}
