//! Seeded process-variation fields.
//!
//! Every column's sense amplifier carries a static threshold offset
//! drawn once per manufactured device (paper §II-C: "threshold voltage
//! variation in sense amplifiers ... due to process variation"). The
//! offsets use a two-component Gaussian scale mixture — a core
//! population plus a heavier-tailed defect-like population — which is
//! what makes wide-range offset coverage matter (DESIGN.md §3).

use crate::config::device::DeviceConfig;
use crate::util::rng::Rng;

/// Static per-column variation of one subarray.
#[derive(Clone, Debug)]
pub struct VariationField {
    /// SA threshold offset per column, V_DD units (mean 0).
    pub sa_offset: Vec<f32>,
    /// Per-column temperature-coefficient jitter, V_DD/°C.
    pub tempco_jitter: Vec<f32>,
}

impl VariationField {
    /// Draw the field for `cols` columns from a dedicated stream.
    pub fn draw(cfg: &DeviceConfig, cols: usize, rng: &mut Rng) -> Self {
        let mut sa_offset = Vec::with_capacity(cols);
        let mut tempco_jitter = Vec::with_capacity(cols);
        for _ in 0..cols {
            sa_offset.push(
                rng.mixture_normal(cfg.sigma_sa, cfg.tail_weight, cfg.tail_ratio) as f32,
            );
            tempco_jitter.push(rng.normal_ms(0.0, cfg.tempco_jitter) as f32);
        }
        Self { sa_offset, tempco_jitter }
    }

    pub fn cols(&self) -> usize {
        self.sa_offset.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_is_deterministic_per_seed() {
        let cfg = DeviceConfig::default();
        let a = VariationField::draw(&cfg, 256, &mut Rng::new(5));
        let b = VariationField::draw(&cfg, 256, &mut Rng::new(5));
        assert_eq!(a.sa_offset, b.sa_offset);
        let c = VariationField::draw(&cfg, 256, &mut Rng::new(6));
        assert_ne!(a.sa_offset, c.sa_offset);
    }

    #[test]
    fn offsets_have_expected_scale() {
        let cfg = DeviceConfig::default();
        let f = VariationField::draw(&cfg, 50_000, &mut Rng::new(1));
        let mean: f64 = f.sa_offset.iter().map(|&x| x as f64).sum::<f64>() / 50_000.0;
        let var: f64 =
            f.sa_offset.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / 50_000.0;
        // Mixture variance = (1-w)σ² + w(σ·ratio)².
        let expect = (1.0 - cfg.tail_weight) * cfg.sigma_sa.powi(2)
            + cfg.tail_weight * (cfg.sigma_sa * cfg.tail_ratio).powi(2);
        assert!(mean.abs() < 0.002, "{mean}");
        assert!((var - expect).abs() / expect < 0.1, "var={var} expect={expect}");
    }

    #[test]
    fn tail_population_exists() {
        let cfg = DeviceConfig::default();
        let f = VariationField::draw(&cfg, 100_000, &mut Rng::new(2));
        // Beyond 4σ of the core there should be far more mass than a
        // plain Gaussian would give (~0.006%).
        let beyond = f
            .sa_offset
            .iter()
            .filter(|&&x| (x as f64).abs() > 4.0 * cfg.sigma_sa)
            .count();
        assert!(beyond > 100, "only {beyond} beyond 4 sigma");
    }
}
