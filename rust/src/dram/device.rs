//! The full device: channels x banks of simulated DRAM.
//!
//! Experiments usually materialise only the subarrays they measure (a
//! full 4x16x65,536-column device was ~17 GB of `f32` cell state before
//! the hybrid bit-packed row storage, and is still ~0.6 GB of packed
//! words plus variation fields after it); `Device` therefore builds
//! subarrays lazily on first touch while keeping the seed derivation
//! identical to eager construction.

use crate::config::device::DeviceConfig;
use crate::config::system::SystemConfig;
use crate::dram::geometry::SubarrayId;
use crate::dram::subarray::Subarray;
use crate::util::rng::derive_seed;
use std::collections::BTreeMap;

/// A lazily-materialised multi-channel DRAM device.
#[derive(Clone, Debug)]
pub struct Device {
    pub cfg: DeviceConfig,
    pub sys: SystemConfig,
    pub seed: u64,
    built: BTreeMap<SubarrayId, Subarray>,
}

impl Device {
    pub fn new(cfg: DeviceConfig, sys: SystemConfig, seed: u64) -> Self {
        Self { cfg, sys, seed, built: BTreeMap::new() }
    }

    /// Seed of a given subarray (stable whether or not it is built).
    pub fn subarray_seed(&self, id: SubarrayId) -> u64 {
        derive_seed(self.seed, &id.seed_path())
    }

    /// Materialise (if needed) and return a subarray.
    pub fn subarray_mut(&mut self, id: SubarrayId) -> &mut Subarray {
        assert!(id.channel < self.sys.channels, "channel out of range");
        assert!(id.bank < self.sys.banks, "bank out of range");
        assert!(id.subarray < self.sys.subarrays_per_bank, "subarray out of range");
        let cfg = self.cfg.clone();
        let sys = self.sys.clone();
        let seed = self.subarray_seed(id);
        self.built
            .entry(id)
            .or_insert_with(|| Subarray::new(&cfg, &sys, seed))
    }

    /// All subarray ids of the device in canonical order.
    pub fn all_subarrays(&self) -> Vec<SubarrayId> {
        let mut v = Vec::new();
        for c in 0..self.sys.channels {
            for b in 0..self.sys.banks {
                for s in 0..self.sys.subarrays_per_bank {
                    v.push(SubarrayId::new(c, b, s));
                }
            }
        }
        v
    }

    /// Number of currently materialised subarrays.
    pub fn built_count(&self) -> usize {
        self.built.len()
    }

    /// Approximate heap bytes of the materialised subarrays' cell
    /// state. With the hybrid row storage a fully materialised paper
    /// geometry device is ~0.6 GB instead of ~17 GB of `f32` cells —
    /// lazy materialisation is still kept for variation fields and
    /// sense amps.
    pub fn approx_bytes(&self) -> usize {
        self.built.values().map(|s| s.approx_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_materialisation() {
        let mut d = Device::new(DeviceConfig::default(), SystemConfig::small(), 11);
        assert_eq!(d.built_count(), 0);
        let id = SubarrayId::new(0, 1, 0);
        let off0 = d.subarray_mut(id).sa.variation.sa_offset[0];
        assert_eq!(d.built_count(), 1);
        // Same instance on re-access (state persists).
        d.subarray_mut(id).fill_row(0, 1);
        assert_eq!(d.subarray_mut(id).charge(0, 0), 1.0);
        // Rebuilding the device reproduces the same variation.
        let mut d2 = Device::new(DeviceConfig::default(), SystemConfig::small(), 11);
        assert_eq!(d2.subarray_mut(id).sa.variation.sa_offset[0], off0);
    }

    #[test]
    fn materialised_bytes_track_built_subarrays() {
        let mut d = Device::new(DeviceConfig::default(), SystemConfig::small(), 3);
        assert_eq!(d.approx_bytes(), 0);
        d.subarray_mut(SubarrayId::new(0, 0, 0));
        let one = d.approx_bytes();
        assert!(one > 0);
        d.subarray_mut(SubarrayId::new(0, 1, 0));
        assert!(d.approx_bytes() > one);
    }

    #[test]
    fn enumeration_matches_geometry() {
        let d = Device::new(DeviceConfig::default(), SystemConfig::small(), 1);
        let ids = d.all_subarrays();
        assert_eq!(ids.len(), 1 * 2 * 1);
        assert_eq!(ids[0], SubarrayId::new(0, 0, 0));
    }

    #[test]
    #[should_panic(expected = "bank out of range")]
    fn bounds_checked() {
        let mut d = Device::new(DeviceConfig::default(), SystemConfig::small(), 1);
        d.subarray_mut(SubarrayId::new(0, 99, 0));
    }
}
