//! The dense-`f32` reference golden model.
//!
//! [`DenseSubarray`] is the pre-hybrid `Subarray` implementation — one
//! `f32` charge per cell, per-cell loops on every primitive — kept as
//! the executable specification the bit-packed hybrid model
//! (`dram::subarray`) is validated against. The storage parity suite
//! (`rust/tests/storage_parity.rs`) drives both models through
//! identical command traces and asserts bit-identical read-outs, equal
//! [`OpCounts`] and equal noise-stream positions.
//!
//! Semantics shared with the hybrid model (and *only* expressible as a
//! per-row state machine, not derivable from cell values alone): the
//! `full_swing` flag mirrors the hybrid `Packed`/`Analog` split. It is
//! set by every restore (read, SiMRA, RowCopy) and by
//! `write_row`/`fill_row`, cleared by `frac`, and governs retention:
//! full-swing rows are refreshed (they hold their rails while one
//! `advance_time` interval retains at least
//! `DeviceConfig::retention_swing_min` of the swing), Frac'd rows decay
//! unconditionally — refresh would destroy their intermediate levels.
//!
//! Compiled only under `cfg(test)` or the `reference-model` feature
//! (default-on), so production builds can drop it with
//! `--no-default-features`.

use crate::config::device::DeviceConfig;
use crate::config::system::SystemConfig;
use crate::dram::faults::{FaultField, FAULT_STREAM};
use crate::dram::retention;
use crate::dram::sense_amp::SenseAmps;
use crate::dram::subarray::OpCounts;
use crate::dram::temperature::Environment;
use crate::util::rng::Rng;

/// The dense-storage reference subarray (one `f32` per cell).
#[derive(Clone, Debug)]
pub struct DenseSubarray {
    pub cfg: DeviceConfig,
    pub rows: usize,
    pub cols: usize,
    /// Row-major cell charges, `rows * cols`, V_DD units in [0, 1].
    charges: Vec<f32>,
    pub sa: SenseAmps,
    pub env: Environment,
    /// Per-operation noise stream.
    rng: Rng,
    pub counts: OpCounts,
    /// Seeded fault-injection field — drawn from the same dedicated
    /// child stream as the hybrid model, so both corrupt in lockstep.
    faults: FaultField,
    /// Per-row full-swing state (see module docs).
    full_swing: Vec<bool>,
    /// Reusable row-width scratch (RowCopy sense buffer).
    row_buf: Vec<u8>,
}

impl DenseSubarray {
    /// Build a subarray with variation drawn from `seed` — the exact
    /// seeding sequence of the hybrid model, so both see identical
    /// variation fields and noise streams.
    pub fn new(cfg: &DeviceConfig, sys: &SystemConfig, seed: u64) -> Self {
        Self::with_geometry(cfg, sys.rows_per_subarray, sys.cols, seed)
    }

    pub fn with_geometry(cfg: &DeviceConfig, rows: usize, cols: usize, seed: u64) -> Self {
        let mut field_rng = Rng::new(seed);
        let sa = SenseAmps::new(cfg, cols, &mut field_rng);
        let mut fault_rng = field_rng.child(&[FAULT_STREAM]);
        let faults = FaultField::draw(cfg, cols, &mut fault_rng);
        Self {
            cfg: cfg.clone(),
            rows,
            cols,
            charges: vec![0.0; rows * cols],
            sa,
            env: Environment::nominal(cfg.t_cal),
            rng: field_rng.child(&[0xC0FFEE]),
            counts: OpCounts::default(),
            faults,
            full_swing: vec![true; rows],
            row_buf: Vec::new(),
        }
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    /// Raw charge access.
    pub fn charge(&self, row: usize, col: usize) -> f32 {
        self.charges[self.idx(row, col)]
    }

    /// Materialised charge vector of one row (signature-compatible with
    /// the hybrid model for the parity suite).
    pub fn row_charges(&self, row: usize) -> Vec<f32> {
        self.charges[row * self.cols..(row + 1) * self.cols].to_vec()
    }

    /// Whether a row is in the full-swing state (mirrors the hybrid
    /// model's packed representation).
    pub fn row_is_packed(&self, row: usize) -> bool {
        self.full_swing[row]
    }

    /// Number of rows currently holding intermediate charge.
    pub fn analog_rows(&self) -> usize {
        self.full_swing.iter().filter(|&&p| !p).count()
    }

    /// Heap bytes held by the cell-state storage (the footprint test
    /// compares this against the hybrid model).
    pub fn approx_bytes(&self) -> usize {
        self.charges.capacity() * std::mem::size_of::<f32>()
            + self.full_swing.capacity() * std::mem::size_of::<bool>()
    }

    /// Digest of the per-operation noise-stream position.
    pub fn rng_fingerprint(&self) -> u64 {
        self.rng.fingerprint()
    }

    /// The fault field drawn for this subarray (introspection).
    pub fn fault_field(&self) -> &FaultField {
        &self.faults
    }

    /// Total fault-induced SiMRA bit flips so far.
    pub fn fault_flips(&self) -> u64 {
        self.faults.flips()
    }

    /// Order-sensitive digest of the fault field and its fired flips.
    pub fn fault_fingerprint(&self) -> u64 {
        self.faults.fingerprint()
    }

    /// Write full-swing data into a row (column-interface transfer:
    /// bumps `io_writes` only — `dram::subarray` module docs).
    pub fn write_row(&mut self, row: usize, bits: &[u8]) {
        assert_eq!(bits.len(), self.cols);
        self.counts.io_writes += 1;
        let base = row * self.cols;
        for (c, &b) in bits.iter().enumerate() {
            self.charges[base + c] = if b != 0 { 1.0 } else { 0.0 };
        }
        self.full_swing[row] = true;
    }

    pub fn fill_row(&mut self, row: usize, bit: u8) {
        self.counts.io_writes += 1;
        let v = if bit != 0 { 1.0 } else { 0.0 };
        let base = row * self.cols;
        self.charges[base..base + self.cols].fill(v);
        self.full_swing[row] = true;
    }

    /// Standard activate-and-read (per-cell reference loop).
    pub fn read_row(&mut self, row: usize) -> Vec<u8> {
        let mut out = vec![0u8; self.cols];
        self.read_row_into(row, &mut out);
        out
    }

    /// [`Self::read_row`] into a caller-owned buffer.
    pub fn read_row_into(&mut self, row: usize, out: &mut [u8]) {
        assert_eq!(out.len(), self.cols, "row buffer width must equal columns");
        self.counts.activates += 1;
        self.counts.precharges += 1;
        let base = row * self.cols;
        for c in 0..self.cols {
            let v = self.cfg.bitline_voltage(self.charges[base + c] as f64, 1);
            let bit = self.sa.sense(&self.cfg, &self.env, c, v, &mut self.rng);
            out[c] = bit as u8;
            self.charges[base + c] = if bit { 1.0 } else { 0.0 };
        }
        self.full_swing[row] = true;
    }

    /// RowCopy (ACT src - violated PRE - ACT dst), per-cell reference.
    pub fn row_copy(&mut self, src: usize, dst: usize) {
        self.counts.row_copies += 1;
        // read_row_into accounts one ACT/PRE; the second ACT opens dst.
        self.counts.activates += 1;
        let mut buf = std::mem::take(&mut self.row_buf);
        buf.resize(self.cols, 0);
        self.read_row_into(src, &mut buf);
        let base = dst * self.cols;
        for (c, &b) in buf.iter().enumerate() {
            self.charges[base + c] = if b != 0 { 1.0 } else { 0.0 };
        }
        self.full_swing[dst] = true;
        self.row_buf = buf;
    }

    /// Frac (ACT with early PRE): partial charging toward neutral.
    pub fn frac(&mut self, row: usize) {
        self.counts.fracs += 1;
        self.counts.activates += 1;
        self.counts.precharges += 1;
        let r = self.cfg.frac_r as f32;
        let base = row * self.cols;
        for q in &mut self.charges[base..base + self.cols] {
            *q = 0.5 + (*q - 0.5) * r;
        }
        self.full_swing[row] = false;
    }

    /// Simultaneous multi-row activation (per-cell reference loop).
    pub fn simra(&mut self, rows: &[usize]) -> Vec<u8> {
        let mut out = vec![0u8; self.cols];
        self.simra_into(rows, &mut out);
        out
    }

    /// [`Self::simra`] into a caller-owned buffer.
    pub fn simra_into(&mut self, rows: &[usize], out: &mut [u8]) {
        assert!(
            rows.len() == self.cfg.simra_rows,
            "SiMRA opens exactly {} rows (decoder glitch)",
            self.cfg.simra_rows
        );
        assert_eq!(out.len(), self.cols, "row buffer width must equal columns");
        self.counts.simras += 1;
        self.counts.activates += 2; // ACT-PRE-ACT decoder glitch sequence
        self.counts.precharges += 1;
        // SiMRA operation index for the fault clock (1-based; shared
        // with the hybrid model because both bump the counter first).
        let op_idx = self.counts.simras;
        let cols = self.cols;
        let Self { cfg, charges, sa, env, rng, faults, full_swing, .. } = self;
        for c in 0..cols {
            let total: f64 = rows.iter().map(|&r| charges[r * cols + c] as f64).sum();
            let v = cfg.bitline_voltage(total, rows.len());
            let mut bit = sa.sense(cfg, env, c, v, rng);
            if faults.is_enabled()
                && faults.flip_simra(c, op_idx, total, rows.len(), |pos| {
                    charges[rows[pos] * cols + c]
                })
            {
                bit = !bit;
            }
            out[c] = bit as u8;
            let q = if bit { 1.0 } else { 0.0 };
            for &r in rows {
                charges[r * cols + c] = q;
            }
        }
        for &r in rows {
            full_swing[r] = true;
        }
    }

    /// Deterministic SiMRA evaluation with explicit noise; mutates
    /// nothing.
    pub fn simra_eval(&self, rows: &[usize], noise: &[f32]) -> Vec<u8> {
        let mut out = vec![0u8; self.cols];
        for c in 0..self.cols {
            let total: f64 = rows
                .iter()
                .map(|&r| self.charges[r * self.cols + c] as f64)
                .sum();
            let v = self.cfg.bitline_voltage(total, rows.len());
            let thr = self.sa.threshold(&self.cfg, &self.env, c);
            out[c] = (v + noise[c] as f64 > thr) as u8;
        }
        out
    }

    /// Set the die temperature (Fig. 6a).
    pub fn set_temperature(&mut self, temp_c: f64) {
        self.env.temp_c = temp_c;
    }

    /// Advance simulated wall-clock time: the same retention state
    /// machine as the hybrid model, then aging drift. Degenerate
    /// intervals (zero, negative, NaN, infinite) are no-ops, mirroring
    /// `Subarray::advance_time` so the parity suite stays valid.
    pub fn advance_time(&mut self, dt_hours: f64) {
        if dt_hours.is_nan() || dt_hours.is_infinite() || dt_hours <= 0.0 {
            return;
        }
        self.env.hours += dt_hours;
        let f = retention::swing_factor(dt_hours, self.cfg.tau_retention_hours);
        if f < 1.0 {
            let fr = f as f32;
            let refreshable = f >= self.cfg.retention_swing_min;
            for r in 0..self.rows {
                if self.full_swing[r] && refreshable {
                    continue; // refresh restores the rails
                }
                self.full_swing[r] = false;
                let base = r * self.cols;
                for q in &mut self.charges[base..base + self.cols] {
                    *q = 0.5 + (*q - 0.5) * fr;
                }
            }
        }
        let drift_per_hour = self.cfg.drift_per_hour;
        let mut rng = self.rng.child(&[0xA6E, self.env.hours.to_bits()]);
        self.sa.drift.advance(dt_hours, drift_per_hour, &mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseSubarray {
        let cfg = DeviceConfig::default();
        DenseSubarray::with_geometry(&cfg, 64, 128, 42)
    }

    #[test]
    fn full_swing_flag_follows_state_machine() {
        let mut s = small();
        assert!(s.row_is_packed(3));
        s.frac(3);
        assert!(!s.row_is_packed(3));
        s.read_row(3);
        assert!(s.row_is_packed(3));
        s.frac(7);
        assert_eq!(s.analog_rows(), 1);
        let group: Vec<usize> = (0..8).collect();
        s.simra(&group);
        assert_eq!(s.analog_rows(), 0);
    }

    #[test]
    fn matches_hybrid_on_a_simple_flow() {
        // Spot parity (the full randomized suite lives in
        // rust/tests/storage_parity.rs): same seed, same commands, same
        // outputs, counts and stream position.
        use crate::dram::subarray::Subarray;
        let cfg = DeviceConfig::default();
        let mut d = DenseSubarray::with_geometry(&cfg, 32, 96, 7);
        let mut h = Subarray::with_geometry(&cfg, 32, 96, 7);
        let bits: Vec<u8> = (0..96).map(|c| (c % 5 < 2) as u8).collect();
        for s in [0usize, 1, 2, 5, 6, 7] {
            d.fill_row(s, (s % 2) as u8);
            h.fill_row(s, (s % 2) as u8);
        }
        d.write_row(3, &bits);
        h.write_row(3, &bits);
        d.frac(4);
        h.frac(4);
        d.row_copy(3, 9);
        h.row_copy(3, 9);
        let group: Vec<usize> = (0..8).collect();
        assert_eq!(d.simra(&group), h.simra(&group));
        assert_eq!(d.read_row(9), h.read_row(9));
        assert_eq!(d.counts, h.counts);
        assert_eq!(d.rng_fingerprint(), h.rng_fingerprint());
        for r in 0..32 {
            assert_eq!(d.row_charges(r), h.row_charges(r), "row {r}");
        }
    }
}
