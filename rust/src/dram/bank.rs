//! A bank: a set of subarrays sharing a bank-level command interface.

use crate::config::device::DeviceConfig;
use crate::config::system::SystemConfig;
use crate::dram::subarray::Subarray;
use crate::util::rng::derive_seed;

/// One DRAM bank.
#[derive(Clone, Debug)]
pub struct Bank {
    pub subarrays: Vec<Subarray>,
}

impl Bank {
    /// Build all subarrays of the bank, each with an independent
    /// variation field derived from (device seed, channel, bank, sa).
    pub fn new(
        cfg: &DeviceConfig,
        sys: &SystemConfig,
        device_seed: u64,
        channel: usize,
        bank: usize,
    ) -> Self {
        let subarrays = (0..sys.subarrays_per_bank)
            .map(|s| {
                let seed =
                    derive_seed(device_seed, &[channel as u64, bank as u64, s as u64]);
                Subarray::new(cfg, sys, seed)
            })
            .collect();
        Self { subarrays }
    }

    pub fn subarray(&self, i: usize) -> &Subarray {
        &self.subarrays[i]
    }

    pub fn subarray_mut(&mut self, i: usize) -> &mut Subarray {
        &mut self.subarrays[i]
    }

    /// Approximate heap bytes of all subarrays' cell-state storage
    /// (capacity reports; dominated by any analog rows, see
    /// `Subarray::approx_bytes`).
    pub fn approx_bytes(&self) -> usize {
        self.subarrays.iter().map(|s| s.approx_bytes()).sum()
    }

    /// Rows across the bank currently holding intermediate (analog)
    /// charge — the quantity that controls the memory footprint.
    pub fn analog_rows(&self) -> usize {
        self.subarrays.iter().map(|s| s.analog_rows()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subarrays_have_independent_variation() {
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.subarrays_per_bank = 2;
        let b = Bank::new(&cfg, &sys, 7, 0, 0);
        assert_eq!(b.subarrays.len(), 2);
        assert_ne!(
            b.subarray(0).sa.variation.sa_offset,
            b.subarray(1).sa.variation.sa_offset
        );
    }

    #[test]
    fn fresh_banks_are_fully_packed() {
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.subarrays_per_bank = 2;
        let mut b = Bank::new(&cfg, &sys, 7, 0, 0);
        assert_eq!(b.analog_rows(), 0);
        // Packed storage: far below one f32 per cell.
        let dense = 2 * sys.rows_per_subarray * sys.cols * 4;
        assert!(b.approx_bytes() * 4 < dense, "{} vs {dense}", b.approx_bytes());
        b.subarray_mut(0).frac(3);
        assert_eq!(b.analog_rows(), 1);
    }

    #[test]
    fn banks_are_reproducible() {
        let cfg = DeviceConfig::default();
        let sys = SystemConfig::small();
        let a = Bank::new(&cfg, &sys, 7, 0, 3);
        let b = Bank::new(&cfg, &sys, 7, 0, 3);
        assert_eq!(
            a.subarray(0).sa.variation.sa_offset,
            b.subarray(0).sa.variation.sa_offset
        );
        let c = Bank::new(&cfg, &sys, 7, 1, 3);
        assert_ne!(
            a.subarray(0).sa.variation.sa_offset,
            c.subarray(0).sa.variation.sa_offset
        );
    }
}
