//! The subarray golden model: cells, activation, SiMRA, Frac, RowCopy —
//! on a **hybrid bit-packed / analog row storage**.
//!
//! A subarray is a `rows x cols` array of cell charges (V_DD units in
//! [0, 1]) plus its sense amplifiers and environment. All PUD
//! primitives are implemented at analog fidelity:
//!
//! * **activate / read** — single-row charge sharing against the
//!   precharged bitline, noisy SA decision, full-swing restore;
//! * **SiMRA** — multi-row activation: charge sharing across the opened
//!   cells of each column, SA decision, and restore of the decision
//!   value into *all* opened rows (paper Fig. 1 step 4);
//! * **Frac** — partial charging: every cell of the row moves toward
//!   the neutral state by the factor `frac_r` (multi-level charge
//!   states, paper §III-C);
//! * **RowCopy** — ACT-PRE-ACT copy of the *sensed* source bits into
//!   the destination row (copying destroys intermediate charge states,
//!   which is why PUDTune's flow re-Fracs calibration rows after every
//!   copy-in — the model enforces the same ordering).
//!
//! ## Storage representation
//!
//! Only the handful of rows that have been `Frac`'d ever hold
//! intermediate charge; every other row is restored to full swing after
//! each ACT / SiMRA / RowCopy. [`RowStorage`] exploits that: a
//! full-swing row is a bit-packed `Packed(Vec<u64>)` (64 columns per
//! word, ~30x smaller than one `f32` per cell), and only
//! fractionally-charged rows carry a dense `Analog(Vec<f32>)` level
//! vector. Rows transition `Packed -> Analog` on [`Subarray::frac`]
//! (and on retention decay past the refresh threshold, below) and back
//! to `Packed` whenever a restore drives them to full swing (read,
//! SiMRA, RowCopy in either direction).
//!
//! The representation is an implementation detail with **no observable
//! effect**: RowCopy between packed rows is a word-wise `u64` copy, and
//! SiMRA over an all-packed group computes each column's charge count
//! with bit-sliced word-parallel counters — but both draw the same
//! per-column SA noise in the same order and compute the same bitline
//! voltages as the per-cell loop, so read-outs, [`OpCounts`] and the
//! noise-stream position are bit-identical to the dense reference
//! model (`dram::dense::DenseSubarray`, compiled under `cfg(test)` or
//! the `reference-model` feature; pinned by
//! `rust/tests/storage_parity.rs`).
//!
//! ## Retention
//!
//! [`Subarray::advance_time`] applies first-order charge decay
//! (`dram::retention::swing_factor`, time constant
//! `DeviceConfig::tau_retention_hours`, default off). Full-swing rows
//! are periodically refreshed, so they hold their rails as long as one
//! interval retains at least `DeviceConfig::retention_swing_min` of the
//! swing; past that threshold a refresh can no longer reliably restore
//! them and the row degrades to its decayed analog levels. Each
//! `advance_time` call models one refresh-window check, so callers
//! should step time at the refresh-interval granularity they intend
//! (see the `retention_swing_min` docs for the caveat).
//! Fractionally-charged rows are *never* refreshed (a refresh is an
//! ACT restore, which would destroy the intermediate levels PUDTune
//! relies on), so they decay unconditionally.
//!
//! ## Operation counting convention
//!
//! [`OpCounts`] counts **in-array command sequences**: ACT/PRE pairs,
//! RowCopy, Frac and SiMRA — the quantities the timing/power models
//! consume. [`Subarray::write_row`] and [`Subarray::fill_row`] are
//! column-interface transfers (host WRITE bursts whose timing the
//! controller accounts separately, see `controller::bender`); they bump
//! only the informational `io_writes` counter and never ACT/PRE. The
//! convention is pinned by the `io_write_counting_convention` test.
//!
//! Mass experiments run the same arithmetic on the PJRT path; this
//! model is the reference for correctness (cross-validation test) and
//! runs all command-level/integration scenarios.

use crate::config::device::DeviceConfig;
use crate::config::system::SystemConfig;
use crate::dram::faults::{FaultField, FAULT_STREAM};
use crate::dram::retention;
use crate::dram::sense_amp::SenseAmps;
use crate::dram::temperature::Environment;
use crate::util::rng::Rng;

/// Operation counters (fed to the timing model / reports).
///
/// `activates`/`precharges`/`row_copies`/`fracs`/`simras` count
/// in-array command sequences; `io_writes` counts column-interface row
/// loads (`write_row`/`fill_row`), which the timing model accounts
/// separately (module docs, "Operation counting convention").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub activates: u64,
    pub precharges: u64,
    pub row_copies: u64,
    pub fracs: u64,
    pub simras: u64,
    pub io_writes: u64,
}

/// Cell state of one row: bit-packed when the row sits at full swing,
/// dense analog levels while it holds intermediate charge.
#[derive(Clone, Debug)]
pub enum RowStorage {
    /// Full-swing row: column `c` is bit `c % 64` of word `c / 64`
    /// (bits at and above the column count are always zero).
    Packed(Vec<u64>),
    /// Fractionally-charged row: one charge level per column, V_DD
    /// units in [0, 1].
    Analog(Vec<f32>),
}

impl RowStorage {
    /// Whether the row is in the bit-packed full-swing representation.
    #[inline]
    pub fn is_packed(&self) -> bool {
        matches!(self, RowStorage::Packed(_))
    }

    /// Charge of one column. Packed bits are exactly 0.0 / 1.0, so the
    /// two representations agree bit for bit on every read-out path.
    #[inline]
    pub fn charge(&self, col: usize) -> f32 {
        match self {
            RowStorage::Packed(w) => ((w[col >> 6] >> (col & 63)) & 1) as f32,
            RowStorage::Analog(q) => q[col],
        }
    }

    /// Heap bytes held by this row's cell state.
    pub fn approx_bytes(&self) -> usize {
        match self {
            RowStorage::Packed(w) => w.capacity() * std::mem::size_of::<u64>(),
            RowStorage::Analog(q) => q.capacity() * std::mem::size_of::<f32>(),
        }
    }
}

/// Packed words needed for one row of `cols` columns.
#[inline]
fn words_for(cols: usize) -> usize {
    cols.div_ceil(64)
}

/// One simulated subarray.
#[derive(Clone, Debug)]
pub struct Subarray {
    pub cfg: DeviceConfig,
    pub rows: usize,
    pub cols: usize,
    /// Per-row hybrid cell state (see module docs).
    storage: Vec<RowStorage>,
    pub sa: SenseAmps,
    pub env: Environment,
    /// Per-operation noise stream.
    rng: Rng,
    pub counts: OpCounts,
    /// Seeded fault-injection field (`dram::faults`; empty unless the
    /// config enables fault knobs). Drawn from a dedicated child stream
    /// so disabling it leaves every other draw byte-identical.
    faults: FaultField,
    /// Reusable packed decision words (SiMRA restore buffer).
    decision_buf: Vec<u64>,
    /// Reusable charge-count -> bitline-voltage table (SiMRA fast path).
    volt_buf: Vec<f64>,
}

impl Subarray {
    /// Build a subarray with variation drawn from `seed`.
    pub fn new(cfg: &DeviceConfig, sys: &SystemConfig, seed: u64) -> Self {
        Self::with_geometry(cfg, sys.rows_per_subarray, sys.cols, seed)
    }

    pub fn with_geometry(cfg: &DeviceConfig, rows: usize, cols: usize, seed: u64) -> Self {
        let mut field_rng = Rng::new(seed);
        let sa = SenseAmps::new(cfg, cols, &mut field_rng);
        // Child stream: does not advance `field_rng`, so the op-noise
        // stream below is unchanged whether or not faults are enabled.
        let mut fault_rng = field_rng.child(&[FAULT_STREAM]);
        let faults = FaultField::draw(cfg, cols, &mut fault_rng);
        let nwords = words_for(cols);
        Self {
            cfg: cfg.clone(),
            rows,
            cols,
            storage: (0..rows).map(|_| RowStorage::Packed(vec![0u64; nwords])).collect(),
            sa,
            env: Environment::nominal(cfg.t_cal),
            rng: field_rng.child(&[0xC0FFEE]),
            counts: OpCounts::default(),
            faults,
            decision_buf: Vec::new(),
            volt_buf: Vec::new(),
        }
    }

    /// Raw charge access (tests, cross-validation).
    pub fn charge(&self, row: usize, col: usize) -> f32 {
        debug_assert!(row < self.rows && col < self.cols);
        self.storage[row].charge(col)
    }

    /// Materialised charge vector of one row (tests; the hot paths
    /// never materialise packed rows).
    pub fn row_charges(&self, row: usize) -> Vec<f32> {
        let st = &self.storage[row];
        (0..self.cols).map(|c| st.charge(c)).collect()
    }

    /// Storage representation of one row (introspection for tests,
    /// benches and capacity accounting).
    pub fn row_storage(&self, row: usize) -> &RowStorage {
        &self.storage[row]
    }

    /// Whether a row currently sits in the packed full-swing
    /// representation.
    pub fn row_is_packed(&self, row: usize) -> bool {
        self.storage[row].is_packed()
    }

    /// Number of rows currently holding intermediate (analog) charge.
    pub fn analog_rows(&self) -> usize {
        self.storage.iter().filter(|s| !s.is_packed()).count()
    }

    /// Approximate heap bytes held by the cell-state storage (the
    /// memory-footprint test pins the >=10x win over the dense model).
    pub fn approx_bytes(&self) -> usize {
        self.storage.iter().map(|s| s.approx_bytes()).sum::<usize>()
            + self.storage.capacity() * std::mem::size_of::<RowStorage>()
    }

    /// Digest of the per-operation noise-stream position (storage
    /// parity suite: dense and hybrid must consume noise in lockstep).
    pub fn rng_fingerprint(&self) -> u64 {
        self.rng.fingerprint()
    }

    /// The fault field drawn for this subarray (introspection).
    pub fn fault_field(&self) -> &FaultField {
        &self.faults
    }

    /// Total fault-induced SiMRA bit flips so far.
    pub fn fault_flips(&self) -> u64 {
        self.faults.flips()
    }

    /// Order-sensitive digest of the fault field and every flip it has
    /// fired (storage parity: hybrid and dense must corrupt in
    /// lockstep).
    pub fn fault_fingerprint(&self) -> u64 {
        self.faults.fingerprint()
    }

    /// Reset `slot` to an all-zero packed row of `nwords` words,
    /// reusing its allocation when it is already packed.
    fn packed_slot(slot: &mut RowStorage, nwords: usize) -> &mut Vec<u64> {
        if let RowStorage::Packed(w) = slot {
            w.clear();
            w.resize(nwords, 0);
        } else {
            *slot = RowStorage::Packed(vec![0u64; nwords]);
        }
        match slot {
            RowStorage::Packed(w) => w,
            RowStorage::Analog(_) => unreachable!(),
        }
    }

    /// Write full-swing data into a row (memory-controller WRITE path;
    /// timing handled by `controller` — see the counting convention in
    /// the module docs).
    pub fn write_row(&mut self, row: usize, bits: &[u8]) {
        assert_eq!(bits.len(), self.cols);
        self.counts.io_writes += 1;
        let words = Self::packed_slot(&mut self.storage[row], words_for(self.cols));
        for (c, &b) in bits.iter().enumerate() {
            if b != 0 {
                words[c >> 6] |= 1u64 << (c & 63);
            }
        }
    }

    pub fn fill_row(&mut self, row: usize, bit: u8) {
        self.counts.io_writes += 1;
        let cols = self.cols;
        let nwords = words_for(cols);
        let words = Self::packed_slot(&mut self.storage[row], nwords);
        if bit != 0 {
            for w in words.iter_mut() {
                *w = !0u64;
            }
            let tail = cols & 63;
            if tail != 0 {
                words[nwords - 1] = (1u64 << tail) - 1;
            }
        }
    }

    /// Standard activate-and-read: single-row charge share, noisy SA
    /// decision per column, full restore of the decision into the row.
    pub fn read_row(&mut self, row: usize) -> Vec<u8> {
        let mut out = vec![0u8; self.cols];
        self.read_row_into(row, &mut out);
        out
    }

    /// [`Self::read_row`] into a caller-owned buffer (the hot circuit
    /// path reuses one buffer across all row operations).
    pub fn read_row_into(&mut self, row: usize, out: &mut [u8]) {
        assert_eq!(out.len(), self.cols, "row buffer width must equal columns");
        self.counts.activates += 1;
        self.counts.precharges += 1;
        self.activate_restore(row, Some(out));
    }

    /// Core ACT + sense + full-swing restore. Leaves the row `Packed`
    /// with the sensed decision bits; draws exactly one noise value per
    /// column, in column order, regardless of representation.
    fn activate_restore(&mut self, row: usize, mut out: Option<&mut [u8]>) {
        let cols = self.cols;
        let st = std::mem::replace(&mut self.storage[row], RowStorage::Packed(Vec::new()));
        let Self { cfg, sa, env, rng, .. } = self;
        let restored = match st {
            RowStorage::Packed(mut words) => {
                // Only two possible cell voltages on a full-swing row.
                let v0 = cfg.bitline_voltage(0.0, 1);
                let v1 = cfg.bitline_voltage(1.0, 1);
                for c in 0..cols {
                    let (w, m) = (c >> 6, 1u64 << (c & 63));
                    let v = if words[w] & m != 0 { v1 } else { v0 };
                    let bit = sa.sense(cfg, env, c, v, rng);
                    if bit {
                        words[w] |= m;
                    } else {
                        words[w] &= !m;
                    }
                    if let Some(o) = out.as_mut() {
                        o[c] = bit as u8;
                    }
                }
                RowStorage::Packed(words)
            }
            RowStorage::Analog(q) => {
                let mut words = vec![0u64; words_for(cols)];
                for c in 0..cols {
                    let v = cfg.bitline_voltage(q[c] as f64, 1);
                    let bit = sa.sense(cfg, env, c, v, rng);
                    if bit {
                        words[c >> 6] |= 1u64 << (c & 63);
                    }
                    if let Some(o) = out.as_mut() {
                        o[c] = bit as u8;
                    }
                }
                RowStorage::Packed(words)
            }
        };
        self.storage[row] = restored;
    }

    /// RowCopy (ACT src - violated PRE - ACT dst): the sensed source
    /// bits are driven into the destination row; the source row is
    /// restored to full swing. Between full-swing rows the copy itself
    /// is a word-wise `u64` copy.
    pub fn row_copy(&mut self, src: usize, dst: usize) {
        self.counts.row_copies += 1;
        // One ACT/PRE senses and restores the source; the second ACT
        // opens the destination (same accounting as the dense model).
        self.counts.activates += 2;
        self.counts.precharges += 1;
        self.activate_restore(src, None);
        if src == dst {
            return;
        }
        let (lo, hi) = self.storage.split_at_mut(src.max(dst));
        let (s, d) = if src < dst {
            (&lo[src], &mut hi[0])
        } else {
            (&hi[0], &mut lo[dst])
        };
        match (s, d) {
            (RowStorage::Packed(sw), RowStorage::Packed(dw)) => dw.copy_from_slice(sw),
            (RowStorage::Packed(sw), slot) => *slot = RowStorage::Packed(sw.clone()),
            (RowStorage::Analog(_), _) => unreachable!("restored source row is packed"),
        }
    }

    /// Frac (ACT with early PRE): partial charging pulls every cell of
    /// the row toward the neutral state by the factor `frac_r`. The row
    /// enters (or stays in) the analog representation.
    pub fn frac(&mut self, row: usize) {
        self.counts.fracs += 1;
        self.counts.activates += 1;
        self.counts.precharges += 1;
        let r = self.cfg.frac_r as f32;
        let cols = self.cols;
        match &mut self.storage[row] {
            RowStorage::Analog(q) => {
                for v in q.iter_mut() {
                    *v = 0.5 + (*v - 0.5) * r;
                }
            }
            slot => {
                let q: Vec<f32> = (0..cols).map(|c| 0.5 + (slot.charge(c) - 0.5) * r).collect();
                *slot = RowStorage::Analog(q);
            }
        }
    }

    /// Simultaneous multi-row activation: charge sharing across the
    /// opened cells of every column, noisy SA decision, decision value
    /// restored into all opened rows. Returns the per-column result.
    pub fn simra(&mut self, rows: &[usize]) -> Vec<u8> {
        let mut out = vec![0u8; self.cols];
        self.simra_into(rows, &mut out);
        out
    }

    /// [`Self::simra`] into a caller-owned buffer.
    ///
    /// When every opened row is packed, the per-column charge sum is a
    /// bit-sliced popcount over the opened words and the restore is a
    /// word-wise store of the decision words — the per-cell loop runs
    /// only when an opened row holds analog charge. Both paths draw one
    /// noise value per column in column order and compute identical
    /// voltages (an integer cell-count sum is exact in either
    /// representation), so results are bit-identical.
    pub fn simra_into(&mut self, rows: &[usize], out: &mut [u8]) {
        assert!(
            rows.len() == self.cfg.simra_rows,
            "SiMRA opens exactly {} rows (decoder glitch)",
            self.cfg.simra_rows
        );
        assert_eq!(out.len(), self.cols, "row buffer width must equal columns");
        self.counts.simras += 1;
        self.counts.activates += 2; // ACT-PRE-ACT decoder glitch sequence
        self.counts.precharges += 1;
        // SiMRA operation index for the fault clock (1-based; shared
        // with the dense model because both bump the counter first).
        let op_idx = self.counts.simras;
        let cols = self.cols;
        let nwords = words_for(cols);
        let mut decision = std::mem::take(&mut self.decision_buf);
        decision.clear();
        decision.resize(nwords, 0);
        // The 4-bit sliced counters below hold up to 15 opened rows.
        let fast = rows.len() <= 15 && rows.iter().all(|&r| self.storage[r].is_packed());
        let Self { cfg, storage, sa, env, rng, faults, volt_buf, .. } = self;
        if fast {
            volt_buf.clear();
            volt_buf.extend((0..=rows.len()).map(|k| cfg.bitline_voltage(k as f64, rows.len())));
            for w in 0..nwords {
                // Bit-sliced ripple counters: plane p_i holds bit i of
                // each column's count of opened '1' cells.
                let (mut p0, mut p1, mut p2, mut p3) = (0u64, 0u64, 0u64, 0u64);
                for &r in rows {
                    let x = match &storage[r] {
                        RowStorage::Packed(ws) => ws[w],
                        RowStorage::Analog(_) => unreachable!(),
                    };
                    let c0 = p0 & x;
                    p0 ^= x;
                    let c1 = p1 & c0;
                    p1 ^= c0;
                    let c2 = p2 & c1;
                    p2 ^= c1;
                    p3 ^= c2;
                }
                let base = w * 64;
                let lim = (cols - base).min(64);
                let mut dword = 0u64;
                for i in 0..lim {
                    let c = base + i;
                    let k = (((p0 >> i) & 1)
                        | (((p1 >> i) & 1) << 1)
                        | (((p2 >> i) & 1) << 2)
                        | (((p3 >> i) & 1) << 3)) as usize;
                    let mut bit = sa.sense(cfg, env, c, volt_buf[k], rng);
                    if faults.is_enabled()
                        && faults.flip_simra(c, op_idx, k as f64, rows.len(), |pos| {
                            storage[rows[pos]].charge(c)
                        })
                    {
                        bit = !bit;
                    }
                    out[c] = bit as u8;
                    dword |= (bit as u64) << i;
                }
                decision[w] = dword;
            }
        } else {
            for c in 0..cols {
                let total: f64 = rows.iter().map(|&r| storage[r].charge(c) as f64).sum();
                let v = cfg.bitline_voltage(total, rows.len());
                let mut bit = sa.sense(cfg, env, c, v, rng);
                if faults.is_enabled()
                    && faults.flip_simra(c, op_idx, total, rows.len(), |pos| {
                        storage[rows[pos]].charge(c)
                    })
                {
                    bit = !bit;
                }
                out[c] = bit as u8;
                if bit {
                    decision[c >> 6] |= 1u64 << (c & 63);
                }
            }
        }
        // Restore the decision into all opened rows (word-wise; rows
        // holding analog charge exit to the packed representation).
        for &r in rows {
            match &mut storage[r] {
                RowStorage::Packed(ws) => ws.copy_from_slice(&decision),
                slot => *slot = RowStorage::Packed(decision.clone()),
            }
        }
        self.decision_buf = decision;
    }

    /// Deterministic SiMRA evaluation with explicit noise (the
    /// cross-validation path mirroring `artifacts/maj*_eval_small`).
    /// Does not mutate charges or counters.
    pub fn simra_eval(&self, rows: &[usize], noise: &[f32]) -> Vec<u8> {
        let mut out = vec![0u8; self.cols];
        for c in 0..self.cols {
            let total: f64 = rows.iter().map(|&r| self.storage[r].charge(c) as f64).sum();
            let v = self.cfg.bitline_voltage(total, rows.len());
            let thr = self.sa.threshold(&self.cfg, &self.env, c);
            out[c] = (v + noise[c] as f64 > thr) as u8;
        }
        out
    }

    /// Set the die temperature (Fig. 6a).
    pub fn set_temperature(&mut self, temp_c: f64) {
        self.env.temp_c = temp_c;
    }

    /// Advance simulated wall-clock time: cell-charge retention decay
    /// (module docs, "Retention") plus aging drift (Fig. 6b).
    /// Degenerate intervals (zero, negative, NaN, infinite) are no-ops
    /// so a bad caller can never corrupt the environment clock.
    pub fn advance_time(&mut self, dt_hours: f64) {
        if dt_hours.is_nan() || dt_hours.is_infinite() || dt_hours <= 0.0 {
            return;
        }
        self.env.hours += dt_hours;
        let f = retention::swing_factor(dt_hours, self.cfg.tau_retention_hours);
        if f < 1.0 {
            let fr = f as f32;
            let refreshable = f >= self.cfg.retention_swing_min;
            let cols = self.cols;
            for slot in self.storage.iter_mut() {
                match slot {
                    // Refresh restores the rails within the interval.
                    RowStorage::Packed(_) if refreshable => {}
                    RowStorage::Analog(q) => {
                        for v in q.iter_mut() {
                            *v = 0.5 + (*v - 0.5) * fr;
                        }
                    }
                    // Decayed past the refresh threshold: the data
                    // degrades to the decayed analog levels.
                    slot_packed => {
                        let q: Vec<f32> = (0..cols)
                            .map(|c| 0.5 + (slot_packed.charge(c) - 0.5) * fr)
                            .collect();
                        *slot_packed = RowStorage::Analog(q);
                    }
                }
            }
        }
        let drift_per_hour = self.cfg.drift_per_hour;
        let mut rng = self.rng.child(&[0xA6E, self.env.hours.to_bits()]);
        self.sa.drift.advance(dt_hours, drift_per_hour, &mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Subarray {
        let cfg = DeviceConfig::default();
        Subarray::with_geometry(&cfg, 64, 128, 42)
    }

    #[test]
    fn write_then_read_roundtrips() {
        let mut s = small();
        let bits: Vec<u8> = (0..s.cols).map(|c| (c % 3 == 0) as u8).collect();
        s.write_row(5, &bits);
        let got = s.read_row(5);
        // Single-cell reads have a 0.05 V_DD margin; only the
        // heavy-tail (defect-like) columns may flip — of 128 columns
        // that is a small handful.
        let diff = bits.iter().zip(&got).filter(|(a, b)| a != b).count();
        assert!(diff <= 32, "diff={diff}");
    }

    #[test]
    fn row_copy_copies() {
        let mut s = small();
        let bits: Vec<u8> = (0..s.cols).map(|c| (c % 2) as u8).collect();
        s.write_row(3, &bits);
        s.row_copy(3, 17);
        let a = s.row_charges(3);
        let b = s.row_charges(17);
        assert_eq!(a, b);
        assert_eq!(s.counts.row_copies, 1);
    }

    #[test]
    fn frac_converges_to_neutral() {
        let mut s = small();
        s.fill_row(7, 1);
        for _ in 0..8 {
            s.frac(7);
        }
        for c in 0..s.cols {
            assert!((s.charge(7, c) - 0.5).abs() < 0.05);
        }
        assert_eq!(s.counts.fracs, 8);
    }

    #[test]
    fn frac_creates_intermediate_levels() {
        // §III-C: fewer Fracs leave intermediate states between the
        // initial value and neutral.
        let mut s = small();
        s.fill_row(1, 1);
        s.frac(1);
        let q1 = s.charge(1, 0);
        s.frac(1);
        let q2 = s.charge(1, 0);
        assert!(q1 > q2 && q2 > 0.5, "q1={q1} q2={q2}");
        let r = s.cfg.frac_r as f32;
        assert!((q1 - (0.5 + 0.5 * r)).abs() < 1e-6);
        assert!((q2 - (0.5 + 0.5 * r * r)).abs() < 1e-6);
    }

    #[test]
    fn storage_transitions_follow_charge_state() {
        let mut s = small();
        assert!(s.row_is_packed(3), "rows start at full swing");
        s.frac(3);
        assert!(!s.row_is_packed(3), "frac enters the analog representation");
        s.read_row(3);
        assert!(s.row_is_packed(3), "restore exits back to packed");
        s.frac(3);
        s.row_copy(5, 3); // copy-in destroys intermediate state
        assert!(s.row_is_packed(3) && s.row_is_packed(5));
        s.frac(7);
        assert_eq!(s.analog_rows(), 1);
        let group: Vec<usize> = (0..8).collect();
        s.simra(&group); // SiMRA restores all opened rows
        assert_eq!(s.analog_rows(), 0);
    }

    #[test]
    fn io_write_counting_convention() {
        // write_row/fill_row are column-interface transfers: they bump
        // only the informational io_writes counter (the controller
        // accounts their timing), while RowCopy is an in-array
        // ACT-PRE-ACT sequence. Pinning this keeps the timing-model
        // inputs from silently drifting.
        let mut s = small();
        let bits = vec![1u8; s.cols];
        s.write_row(0, &bits);
        s.fill_row(1, 0);
        assert_eq!(s.counts, OpCounts { io_writes: 2, ..OpCounts::default() });
        s.row_copy(0, 2);
        assert_eq!(
            s.counts,
            OpCounts {
                io_writes: 2,
                row_copies: 1,
                activates: 2,
                precharges: 1,
                ..OpCounts::default()
            }
        );
    }

    #[test]
    fn packed_storage_is_compact() {
        let s = small();
        let dense_bytes = s.rows * s.cols * std::mem::size_of::<f32>();
        assert!(
            s.approx_bytes() * 4 < dense_bytes,
            "hybrid {} vs dense {dense_bytes}",
            s.approx_bytes()
        );
    }

    #[test]
    fn retention_decay_crosses_packed_boundary() {
        let mut cfg = DeviceConfig::default();
        cfg.tau_retention_hours = 10.0;
        cfg.retention_swing_min = 0.9;
        let mut s = Subarray::with_geometry(&cfg, 16, 64, 1);
        s.fill_row(0, 1);
        // Small interval: swing factor ~0.99 >= 0.9, refresh holds.
        s.advance_time(0.1);
        assert!(s.row_is_packed(0));
        assert_eq!(s.charge(0, 0), 1.0);
        // Long interval: factor e^-2.4 ~ 0.09 < 0.9, data degrades.
        s.advance_time(24.0);
        assert!(!s.row_is_packed(0));
        let q = s.charge(0, 0);
        assert!(q < 1.0 && q > 0.5, "q={q}");
        // A Frac'd (analog) row decays even under small intervals.
        s.fill_row(1, 1);
        s.frac(1);
        let q1 = s.charge(1, 0);
        s.advance_time(0.1);
        assert!(s.charge(1, 0) < q1);
    }

    #[test]
    fn simra_majority_with_ideal_columns() {
        // Columns with negligible offset must compute MAJ5 correctly:
        // build a subarray with variation scaled to ~0.
        let mut cfg = DeviceConfig::default();
        cfg.sigma_sa = 1e-6;
        cfg.tail_weight = 0.0;
        cfg.sigma_noise = 1e-6;
        let mut s = Subarray::with_geometry(&cfg, 64, 64, 1);
        // Operands: 3 ones, 2 zeros -> majority 1. Neutral rows: one
        // half-charged + const 0 + const 1 (conventional Fig. 1a).
        for r in 0..3 {
            s.fill_row(r, 1);
        }
        for r in 3..5 {
            s.fill_row(r, 0);
        }
        s.fill_row(5, 1);
        for _ in 0..10 {
            s.frac(5); // ~neutral
        }
        s.fill_row(6, 0);
        s.fill_row(7, 1);
        let out = s.simra(&[0, 1, 2, 3, 4, 5, 6, 7]);
        assert!(out.iter().all(|&b| b == 1));
        // Result restored into all 8 rows.
        for r in 0..8 {
            assert!(s.row_charges(r).iter().all(|&q| q == 1.0));
            assert!(s.row_is_packed(r));
        }
        // And the complementary case: 2 ones, 3 zeros -> majority 0.
        for r in 0..2 {
            s.fill_row(r, 1);
        }
        for r in 2..5 {
            s.fill_row(r, 0);
        }
        s.fill_row(5, 1);
        for _ in 0..10 {
            s.frac(5);
        }
        s.fill_row(6, 0);
        s.fill_row(7, 1);
        let out = s.simra(&[0, 1, 2, 3, 4, 5, 6, 7]);
        assert!(out.iter().all(|&b| b == 0));
    }

    #[test]
    fn simra_all_packed_uses_popcount_path() {
        // An all-packed group (no Frac'd row) exercises the bit-sliced
        // fast path; on ideal columns the decision is the plain charge
        // count against 0.5 V_DD.
        let mut cfg = DeviceConfig::default();
        cfg.sigma_sa = 1e-6;
        cfg.tail_weight = 0.0;
        cfg.sigma_noise = 1e-6;
        let mut s = Subarray::with_geometry(&cfg, 16, 100, 2);
        let group: Vec<usize> = (0..8).collect();
        // 5 of 8 cells charged: V = (5*30 + 135) / 510 ~ 0.559 -> 1.
        for r in 0..5 {
            s.fill_row(r, 1);
        }
        for r in 5..8 {
            s.fill_row(r, 0);
        }
        assert!(group.iter().all(|&r| s.row_is_packed(r)));
        let out = s.simra(&group);
        assert!(out.iter().all(|&b| b == 1));
        // 3 of 8: V ~ 0.441 -> 0.
        for r in 0..3 {
            s.fill_row(r, 1);
        }
        for r in 3..8 {
            s.fill_row(r, 0);
        }
        let out = s.simra(&group);
        assert!(out.iter().all(|&b| b == 0));
        for &r in &group {
            assert!(s.row_is_packed(r));
            assert!(s.row_charges(r).iter().all(|&q| q == 0.0));
        }
    }

    #[test]
    fn simra_boundary_voltage_matches_paper() {
        // The MAJ5(1,1,1,0,0) shared voltage must be ~0.529 V_DD.
        let s = small();
        let v = s.cfg.bitline_voltage(3.0 + 1.5, 8);
        assert!((v - 0.529).abs() < 5e-4);
    }

    #[test]
    #[should_panic(expected = "SiMRA opens exactly")]
    fn simra_requires_eight_rows() {
        let mut s = small();
        s.simra(&[0, 1, 2]);
    }

    #[test]
    fn into_apis_match_allocating_apis() {
        let cfg = DeviceConfig::default();
        let mk = || {
            let mut s = Subarray::with_geometry(&cfg, 32, 64, 9);
            for r in 0..8 {
                s.fill_row(r, (r % 2) as u8);
            }
            s
        };
        let mut a = mk();
        let mut b = mk();
        let ra = a.read_row(0);
        let mut rb = vec![0u8; 64];
        b.read_row_into(0, &mut rb);
        assert_eq!(ra, rb);
        assert_eq!(a.counts, b.counts);
        let rows: Vec<usize> = (0..8).collect();
        let sa = a.simra(&rows);
        let mut sb = vec![0u8; 64];
        b.simra_into(&rows, &mut sb);
        assert_eq!(sa, sb);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.rng_fingerprint(), b.rng_fingerprint());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = DeviceConfig::default();
        let mk = || {
            let mut s = Subarray::with_geometry(&cfg, 32, 64, 9);
            s.fill_row(0, 1);
            s.frac(0);
            s.read_row(0)
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn default_config_never_draws_faults() {
        let mut s = small();
        for r in 0..8 {
            s.fill_row(r, (r % 2) as u8);
        }
        let rows: Vec<usize> = (0..8).collect();
        for _ in 0..16 {
            s.simra(&rows);
        }
        assert!(!s.fault_field().is_enabled());
        assert_eq!(s.fault_flips(), 0);
    }

    #[test]
    fn campaign_config_flips_simra_decisions_deterministically() {
        let cfg = crate::dram::faults::standard_campaign(&DeviceConfig::default());
        let run = || {
            let mut s = Subarray::with_geometry(&cfg, 32, 256, 7);
            // Contested pattern (4 of 8 high) sits on the majority
            // boundary: every pattern-fault column fires each op.
            for r in 0..4 {
                s.fill_row(r, 1);
            }
            for r in 4..8 {
                s.fill_row(r, 0);
            }
            let rows: Vec<usize> = (0..8).collect();
            let out = s.simra(&rows);
            (out, s.fault_flips(), s.fault_fingerprint())
        };
        let (out_a, flips_a, fp_a) = run();
        let (out_b, flips_b, fp_b) = run();
        assert!(flips_a > 0, "campaign config must corrupt contested SiMRA");
        assert_eq!(out_a, out_b);
        assert_eq!(flips_a, flips_b);
        assert_eq!(fp_a, fp_b);
    }

    #[test]
    fn temperature_and_time_mutate_env() {
        let mut s = small();
        s.set_temperature(80.0);
        assert_eq!(s.env.temp_c, 80.0);
        s.advance_time(24.0);
        assert_eq!(s.env.hours, 24.0);
        let moved = s.sa.drift.drift.iter().filter(|&&d| d != 0.0).count();
        assert!(moved > s.cols / 2);
        // Default config has no charge decay: rows stay packed.
        assert_eq!(s.analog_rows(), 0);
    }
}
