//! The subarray golden model: cells, activation, SiMRA, Frac, RowCopy.
//!
//! A subarray is a `rows x cols` array of cell charges (f32 in [0, 1],
//! V_DD units) plus its sense amplifiers and environment. All PUD
//! primitives are implemented at analog fidelity:
//!
//! * **activate / read** — single-row charge sharing against the
//!   precharged bitline, noisy SA decision, full-swing restore;
//! * **SiMRA** — multi-row activation: charge sharing across the opened
//!   cells of each column, SA decision, and restore of the decision
//!   value into *all* opened rows (paper Fig. 1 step 4);
//! * **Frac** — partial charging: every cell of the row moves toward
//!   the neutral state by the factor `frac_r` (multi-level charge
//!   states, paper §III-C);
//! * **RowCopy** — ACT-PRE-ACT copy of the *sensed* source bits into
//!   the destination row (copying destroys intermediate charge states,
//!   which is why PUDTune's flow re-Fracs calibration rows after every
//!   copy-in — the model enforces the same ordering).
//!
//! Mass experiments run the same arithmetic on the PJRT path; this
//! model is the reference for correctness (cross-validation test) and
//! runs all command-level/integration scenarios.

use crate::config::device::DeviceConfig;
use crate::config::system::SystemConfig;
use crate::dram::sense_amp::SenseAmps;
use crate::dram::temperature::Environment;
use crate::util::rng::Rng;

/// Operation counters (fed to the timing model / reports).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub activates: u64,
    pub precharges: u64,
    pub row_copies: u64,
    pub fracs: u64,
    pub simras: u64,
}

/// One simulated subarray.
#[derive(Clone, Debug)]
pub struct Subarray {
    pub cfg: DeviceConfig,
    pub rows: usize,
    pub cols: usize,
    /// Row-major cell charges, `rows * cols`, V_DD units in [0, 1].
    charges: Vec<f32>,
    pub sa: SenseAmps,
    pub env: Environment,
    /// Per-operation noise stream.
    rng: Rng,
    pub counts: OpCounts,
    /// Reusable row-width scratch (RowCopy sense buffer).
    row_buf: Vec<u8>,
}

impl Subarray {
    /// Build a subarray with variation drawn from `seed`.
    pub fn new(cfg: &DeviceConfig, sys: &SystemConfig, seed: u64) -> Self {
        Self::with_geometry(cfg, sys.rows_per_subarray, sys.cols, seed)
    }

    pub fn with_geometry(cfg: &DeviceConfig, rows: usize, cols: usize, seed: u64) -> Self {
        let mut field_rng = Rng::new(seed);
        let sa = SenseAmps::new(cfg, cols, &mut field_rng);
        Self {
            cfg: cfg.clone(),
            rows,
            cols,
            charges: vec![0.0; rows * cols],
            sa,
            env: Environment::nominal(cfg.t_cal),
            rng: field_rng.child(&[0xC0FFEE]),
            counts: OpCounts::default(),
            row_buf: Vec::new(),
        }
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    /// Raw charge access (tests, cross-validation).
    pub fn charge(&self, row: usize, col: usize) -> f32 {
        self.charges[self.idx(row, col)]
    }

    pub fn row_charges(&self, row: usize) -> &[f32] {
        &self.charges[row * self.cols..(row + 1) * self.cols]
    }

    /// Write full-swing data into a row (memory-controller WRITE path;
    /// timing handled by `controller`).
    pub fn write_row(&mut self, row: usize, bits: &[u8]) {
        assert_eq!(bits.len(), self.cols);
        let base = row * self.cols;
        for (c, &b) in bits.iter().enumerate() {
            self.charges[base + c] = if b != 0 { 1.0 } else { 0.0 };
        }
    }

    pub fn fill_row(&mut self, row: usize, bit: u8) {
        let v = if bit != 0 { 1.0 } else { 0.0 };
        let base = row * self.cols;
        self.charges[base..base + self.cols].fill(v);
    }

    /// Standard activate-and-read: single-row charge share, noisy SA
    /// decision per column, full restore of the decision into the row.
    pub fn read_row(&mut self, row: usize) -> Vec<u8> {
        let mut out = vec![0u8; self.cols];
        self.read_row_into(row, &mut out);
        out
    }

    /// [`Self::read_row`] into a caller-owned buffer (the hot circuit
    /// path reuses one buffer across all row operations).
    pub fn read_row_into(&mut self, row: usize, out: &mut [u8]) {
        assert_eq!(out.len(), self.cols, "row buffer width must equal columns");
        self.counts.activates += 1;
        self.counts.precharges += 1;
        let base = row * self.cols;
        for c in 0..self.cols {
            let v = self.cfg.bitline_voltage(self.charges[base + c] as f64, 1);
            let bit = self.sa.sense(&self.cfg, &self.env, c, v, &mut self.rng);
            out[c] = bit as u8;
            self.charges[base + c] = if bit { 1.0 } else { 0.0 };
        }
    }

    /// RowCopy (ACT src - violated PRE - ACT dst): the sensed source
    /// bits are driven into the destination row; the source row is
    /// restored to full swing.
    pub fn row_copy(&mut self, src: usize, dst: usize) {
        self.counts.row_copies += 1;
        // read_row_into accounts one ACT/PRE; the second ACT opens dst.
        self.counts.activates += 1;
        let mut buf = std::mem::take(&mut self.row_buf);
        buf.resize(self.cols, 0);
        self.read_row_into(src, &mut buf);
        let base = dst * self.cols;
        for (c, &b) in buf.iter().enumerate() {
            self.charges[base + c] = if b != 0 { 1.0 } else { 0.0 };
        }
        self.row_buf = buf;
    }

    /// Frac (ACT with early PRE): partial charging pulls every cell of
    /// the row toward the neutral state by the factor `frac_r`.
    pub fn frac(&mut self, row: usize) {
        self.counts.fracs += 1;
        self.counts.activates += 1;
        self.counts.precharges += 1;
        let r = self.cfg.frac_r as f32;
        let base = row * self.cols;
        for q in &mut self.charges[base..base + self.cols] {
            *q = 0.5 + (*q - 0.5) * r;
        }
    }

    /// Simultaneous multi-row activation: charge sharing across the
    /// opened cells of every column, noisy SA decision, decision value
    /// restored into all opened rows. Returns the per-column result.
    pub fn simra(&mut self, rows: &[usize]) -> Vec<u8> {
        let mut out = vec![0u8; self.cols];
        self.simra_into(rows, &mut out);
        out
    }

    /// [`Self::simra`] into a caller-owned buffer.
    pub fn simra_into(&mut self, rows: &[usize], out: &mut [u8]) {
        assert!(
            rows.len() == self.cfg.simra_rows,
            "SiMRA opens exactly {} rows (decoder glitch)",
            self.cfg.simra_rows
        );
        assert_eq!(out.len(), self.cols, "row buffer width must equal columns");
        self.counts.simras += 1;
        self.counts.activates += 2; // ACT-PRE-ACT decoder glitch sequence
        self.counts.precharges += 1;
        for c in 0..self.cols {
            let total: f64 = rows
                .iter()
                .map(|&r| self.charges[self.idx(r, c)] as f64)
                .sum();
            let v = self.cfg.bitline_voltage(total, rows.len());
            let bit = self.sa.sense(&self.cfg, &self.env, c, v, &mut self.rng);
            out[c] = bit as u8;
            let q = if bit { 1.0 } else { 0.0 };
            for &r in rows {
                let i = self.idx(r, c);
                self.charges[i] = q;
            }
        }
    }

    /// Deterministic SiMRA evaluation with explicit noise (the
    /// cross-validation path mirroring `artifacts/maj*_eval_small`).
    /// Does not mutate charges or counters.
    pub fn simra_eval(&self, rows: &[usize], noise: &[f32]) -> Vec<u8> {
        let mut out = vec![0u8; self.cols];
        for c in 0..self.cols {
            let total: f64 = rows
                .iter()
                .map(|&r| self.charges[r * self.cols + c] as f64)
                .sum();
            let v = self.cfg.bitline_voltage(total, rows.len());
            let thr = self.sa.threshold(&self.cfg, &self.env, c);
            out[c] = (v + noise[c] as f64 > thr) as u8;
        }
        out
    }

    /// Set the die temperature (Fig. 6a).
    pub fn set_temperature(&mut self, temp_c: f64) {
        self.env.temp_c = temp_c;
    }

    /// Advance simulated wall-clock time, applying aging drift (Fig. 6b).
    pub fn advance_time(&mut self, dt_hours: f64) {
        self.env.hours += dt_hours;
        let drift_per_hour = self.cfg.drift_per_hour;
        let mut rng = self.rng.child(&[0xA6E, self.env.hours.to_bits()]);
        self.sa.drift.advance(dt_hours, drift_per_hour, &mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Subarray {
        let cfg = DeviceConfig::default();
        Subarray::with_geometry(&cfg, 64, 128, 42)
    }

    #[test]
    fn write_then_read_roundtrips() {
        let mut s = small();
        let bits: Vec<u8> = (0..s.cols).map(|c| (c % 3 == 0) as u8).collect();
        s.write_row(5, &bits);
        let got = s.read_row(5);
        // Single-cell reads have a 0.05 V_DD margin; only the
        // heavy-tail (defect-like) columns may flip — of 128 columns
        // that is a small handful.
        let diff = bits.iter().zip(&got).filter(|(a, b)| a != b).count();
        assert!(diff <= 32, "diff={diff}");
    }

    #[test]
    fn row_copy_copies() {
        let mut s = small();
        let bits: Vec<u8> = (0..s.cols).map(|c| (c % 2) as u8).collect();
        s.write_row(3, &bits);
        s.row_copy(3, 17);
        let a = s.row_charges(3).to_vec();
        let b = s.row_charges(17).to_vec();
        assert_eq!(a, b);
        assert_eq!(s.counts.row_copies, 1);
    }

    #[test]
    fn frac_converges_to_neutral() {
        let mut s = small();
        s.fill_row(7, 1);
        for _ in 0..8 {
            s.frac(7);
        }
        for c in 0..s.cols {
            assert!((s.charge(7, c) - 0.5).abs() < 0.05);
        }
        assert_eq!(s.counts.fracs, 8);
    }

    #[test]
    fn frac_creates_intermediate_levels() {
        // §III-C: fewer Fracs leave intermediate states between the
        // initial value and neutral.
        let mut s = small();
        s.fill_row(1, 1);
        s.frac(1);
        let q1 = s.charge(1, 0);
        s.frac(1);
        let q2 = s.charge(1, 0);
        assert!(q1 > q2 && q2 > 0.5, "q1={q1} q2={q2}");
        let r = s.cfg.frac_r as f32;
        assert!((q1 - (0.5 + 0.5 * r)).abs() < 1e-6);
        assert!((q2 - (0.5 + 0.5 * r * r)).abs() < 1e-6);
    }

    #[test]
    fn simra_majority_with_ideal_columns() {
        // Columns with negligible offset must compute MAJ5 correctly:
        // build a subarray with variation scaled to ~0.
        let mut cfg = DeviceConfig::default();
        cfg.sigma_sa = 1e-6;
        cfg.tail_weight = 0.0;
        cfg.sigma_noise = 1e-6;
        let mut s = Subarray::with_geometry(&cfg, 64, 64, 1);
        // Operands: 3 ones, 2 zeros -> majority 1. Neutral rows: one
        // half-charged + const 0 + const 1 (conventional Fig. 1a).
        for r in 0..3 {
            s.fill_row(r, 1);
        }
        for r in 3..5 {
            s.fill_row(r, 0);
        }
        s.fill_row(5, 1);
        for _ in 0..10 {
            s.frac(5); // ~neutral
        }
        s.fill_row(6, 0);
        s.fill_row(7, 1);
        let out = s.simra(&[0, 1, 2, 3, 4, 5, 6, 7]);
        assert!(out.iter().all(|&b| b == 1));
        // Result restored into all 8 rows.
        for r in 0..8 {
            assert!(s.row_charges(r).iter().all(|&q| q == 1.0));
        }
        // And the complementary case: 2 ones, 3 zeros -> majority 0.
        for r in 0..2 {
            s.fill_row(r, 1);
        }
        for r in 2..5 {
            s.fill_row(r, 0);
        }
        s.fill_row(5, 1);
        for _ in 0..10 {
            s.frac(5);
        }
        s.fill_row(6, 0);
        s.fill_row(7, 1);
        let out = s.simra(&[0, 1, 2, 3, 4, 5, 6, 7]);
        assert!(out.iter().all(|&b| b == 0));
    }

    #[test]
    fn simra_boundary_voltage_matches_paper() {
        // The MAJ5(1,1,1,0,0) shared voltage must be ~0.529 V_DD.
        let s = small();
        let v = s.cfg.bitline_voltage(3.0 + 1.5, 8);
        assert!((v - 0.529).abs() < 5e-4);
    }

    #[test]
    #[should_panic(expected = "SiMRA opens exactly")]
    fn simra_requires_eight_rows() {
        let mut s = small();
        s.simra(&[0, 1, 2]);
    }

    #[test]
    fn into_apis_match_allocating_apis() {
        let cfg = DeviceConfig::default();
        let mk = || {
            let mut s = Subarray::with_geometry(&cfg, 32, 64, 9);
            for r in 0..8 {
                s.fill_row(r, (r % 2) as u8);
            }
            s
        };
        let mut a = mk();
        let mut b = mk();
        let ra = a.read_row(0);
        let mut rb = vec![0u8; 64];
        b.read_row_into(0, &mut rb);
        assert_eq!(ra, rb);
        assert_eq!(a.counts, b.counts);
        let rows: Vec<usize> = (0..8).collect();
        let sa = a.simra(&rows);
        let mut sb = vec![0u8; 64];
        b.simra_into(&rows, &mut sb);
        assert_eq!(sa, sb);
        assert_eq!(a.counts, b.counts);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = DeviceConfig::default();
        let mk = || {
            let mut s = Subarray::with_geometry(&cfg, 32, 64, 9);
            s.fill_row(0, 1);
            s.frac(0);
            s.read_row(0)
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn temperature_and_time_mutate_env() {
        let mut s = small();
        s.set_temperature(80.0);
        assert_eq!(s.env.temp_c, 80.0);
        s.advance_time(24.0);
        assert_eq!(s.env.hours, 24.0);
        let moved = s.sa.drift.drift.iter().filter(|&&d| d != 0.0).count();
        assert!(moved > s.cols / 2);
    }
}
