//! Seeded PuDGhost-style fault injection (PAPERS.md: PuDGhost, arxiv
//! 2606.19119).
//!
//! The variation field ([`crate::dram::variation`]) and the drift model
//! cover the *smooth* error sources the paper calibrates against:
//! static per-column threshold offsets, temperature walks, retention
//! decay. Real PUD chips additionally exhibit result corruption that no
//! static calibration can cancel, because it depends on what the chip
//! is computing *right now*. [`FaultField`] models the three
//! characterized classes, all scoped to SiMRA (the many-row
//! charge-sharing step, where noise margins are a fraction of a cell
//! and the PuDGhost effects concentrate; single-row activation keeps
//! the full V_DD/2 margin and is left clean):
//!
//! * **pattern-dependent flips** ([`Fault::PatternFlip`]) — the flip
//!   chance is conditioned on the data pattern latched across the open
//!   rows: a SiMRA whose summed charge lands within
//!   [`PATTERN_WINDOW`] cells of the majority boundary (a *contested*
//!   pattern) has reduced margin and flips with probability `p`;
//!   unanimous patterns are unaffected;
//! * **aggressor/victim row coupling** ([`Fault::Coupling`]) — a
//!   victim column flips when a specific aggressor position inside the
//!   activated group is strongly driven high
//!   (≥ [`COUPLING_AGGRESSOR_MIN`] of full swing);
//! * **intermittent columns** ([`Fault::Intermittent`]) — duty-cycled
//!   misbehavior: the column corrupts results only during a periodic
//!   active window of the subarray's SiMRA clock, so a one-shot spot
//!   check (or a short probe workload) can land in the quiet phase and
//!   pass while live workloads keep hitting the active window.
//!
//! ## Determinism contract
//!
//! The field is drawn once per subarray from a dedicated child of the
//! geometry seed ([`FAULT_STREAM`]), so the hybrid [`Subarray`] and the
//! dense reference model draw bit-identical faults — the storage-parity
//! suite compares [`FaultField::fingerprint`] after every command.
//! Flip decisions draw from *address-based* streams
//! (`stream(flip_seed, &[op_index, column])`), never from the shared
//! per-operation noise stream: injecting a fault therefore does not
//! move the noise-stream position, and a fault-free column behaves
//! byte-identically whether or not its neighbours are faulty.
//!
//! Crucially for the serving stack, none of this is visible to the
//! calibration/ECR sampling kernel: ECR batteries run on
//! [`crate::coordinator::engine::ColumnBank`] (sense amps +
//! environment only, no cell array, no SiMRA), so a faulty column
//! passes every spot check and then corrupts live workloads — exactly
//! the PuDGhost failure mode the quarantine/scrub countermeasures in
//! [`crate::coordinator::service`] exist to catch.
//!
//! [`Subarray`]: crate::dram::subarray::Subarray

use crate::config::device::DeviceConfig;
use crate::util::rng::{derive_seed, stream, Rng};

/// Stream tag of the per-subarray fault-field child RNG (sibling of
/// the `0xC0FFEE` operation-noise stream).
pub const FAULT_STREAM: u64 = 0xFA17;

/// Pattern-dependent faults trigger when the summed charge across the
/// opened rows lands within this many cell-charges of the majority
/// decision boundary (`rows/2`). With the standard 8-row group and
/// near-neutral calibration, every non-unanimous MAJ3/MAJ5 operand
/// pattern sits within ~1 cell of the boundary while unanimous
/// patterns sit ≥ 1.5 cells away — contested computations corrupt,
/// data-at-rest does not.
pub const PATTERN_WINDOW: f64 = 1.25;

/// An aggressor row couples into its victim column only while driven
/// to at least this fraction of full swing.
pub const COUPLING_AGGRESSOR_MIN: f32 = 0.75;

/// Intermittent columns are active for `period / INTERMITTENT_DUTY`
/// (at least one) of every `period` SiMRA operations.
pub const INTERMITTENT_DUTY: u64 = 4;

/// One column's injected fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Flip with probability `p` whenever the latched pattern is
    /// contested (within [`PATTERN_WINDOW`] of the majority boundary).
    PatternFlip { p: f64 },
    /// Flip with probability `p` whenever the row at position
    /// `agg_pos` inside the activated group is strongly driven high.
    Coupling { agg_pos: u8, p: f64 },
    /// Flip with probability `p` while the subarray's SiMRA clock is
    /// inside the active window: `(op + phase) % period < active`.
    Intermittent { period: u64, phase: u64, active: u64, p: f64 },
}

/// Per-subarray fault assignment plus the injection bookkeeping the
/// parity suite pins. Drawn once at construction (like
/// [`crate::dram::variation::VariationField`]); disabled by default —
/// every fault knob in [`DeviceConfig`] defaults to zero, in which
/// case the field is empty and the SiMRA hot path pays one branch.
#[derive(Clone, Debug)]
pub struct FaultField {
    /// Per-column fault assignment (`None` = healthy column).
    faults: Vec<Option<Fault>>,
    /// Seed of the address-based flip-decision streams.
    flip_seed: u64,
    /// Number of flips injected so far.
    flips: u64,
    /// Order-sensitive digest over the (op, column) address of every
    /// injected flip.
    digest: u64,
    /// Fast-out for the hot path: any fault assigned at all.
    enabled: bool,
}

impl FaultField {
    /// An empty field (no faulty columns, nothing ever flips).
    pub fn none(cols: usize) -> Self {
        Self { faults: vec![None; cols], flip_seed: 0, flips: 0, digest: 0, enabled: false }
    }

    /// Draw the per-column fault assignment for one subarray. The
    /// draw sequence depends only on `cfg` and the RNG state, so both
    /// golden models (seeded identically) assign identical faults.
    pub fn draw(cfg: &DeviceConfig, cols: usize, rng: &mut Rng) -> Self {
        let mut classes: Vec<u8> = Vec::new();
        if cfg.fault_pattern_p > 0.0 {
            classes.push(0);
        }
        if cfg.fault_coupling_p > 0.0 {
            classes.push(1);
        }
        if cfg.fault_intermittent_p > 0.0 {
            classes.push(2);
        }
        if cfg.fault_col_rate <= 0.0 || classes.is_empty() {
            return Self::none(cols);
        }
        let flip_seed = rng.next_u64();
        let period = cfg.fault_intermittent_period.max(1);
        let active = (period / INTERMITTENT_DUTY).max(1);
        let mut faults = Vec::with_capacity(cols);
        for _ in 0..cols {
            if !rng.bool(cfg.fault_col_rate) {
                faults.push(None);
                continue;
            }
            let fault = match classes[rng.below(classes.len() as u64) as usize] {
                0 => Fault::PatternFlip { p: cfg.fault_pattern_p },
                1 => Fault::Coupling {
                    agg_pos: rng.below(cfg.simra_rows as u64) as u8,
                    p: cfg.fault_coupling_p,
                },
                _ => Fault::Intermittent {
                    period,
                    phase: rng.below(period),
                    active,
                    p: cfg.fault_intermittent_p,
                },
            };
            faults.push(Some(fault));
        }
        let enabled = faults.iter().any(|f| f.is_some());
        Self { faults, flip_seed, flips: 0, digest: 0, enabled }
    }

    /// Whether any column carries a fault (hot-path fast-out).
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Decide whether column `c`'s sensed SiMRA decision is corrupted.
    ///
    /// `op` is the subarray's SiMRA ordinal (its operation clock),
    /// `total_charge` the column's summed cell charge across the
    /// `rows_opened` activated rows, and `agg_charge` resolves the
    /// pre-share charge of an opened row by its position in the group
    /// (only consulted for coupling faults). The flip randomness is
    /// address-based — `(op, c)` fully determines the draw — so
    /// injection never perturbs the shared noise stream.
    #[inline]
    pub fn flip_simra(
        &mut self,
        c: usize,
        op: u64,
        total_charge: f64,
        rows_opened: usize,
        agg_charge: impl FnOnce(usize) -> f32,
    ) -> bool {
        if !self.enabled {
            return false;
        }
        let Some(fault) = self.faults.get(c).copied().flatten() else {
            return false;
        };
        let (triggered, p) = match fault {
            Fault::PatternFlip { p } => {
                ((total_charge - rows_opened as f64 * 0.5).abs() <= PATTERN_WINDOW, p)
            }
            Fault::Coupling { agg_pos, p } => {
                let pos = (agg_pos as usize).min(rows_opened.saturating_sub(1));
                (agg_charge(pos) >= COUPLING_AGGRESSOR_MIN, p)
            }
            Fault::Intermittent { period, phase, active, p } => {
                ((op.wrapping_add(phase)) % period < active, p)
            }
        };
        if !triggered {
            return false;
        }
        let fire = p >= 1.0 || stream(self.flip_seed, &[op, c as u64]).f64() < p;
        if fire {
            self.flips += 1;
            self.digest = derive_seed(self.digest, &[op, c as u64]);
        }
        fire
    }

    /// Number of flips injected so far.
    pub fn flips(&self) -> u64 {
        self.flips
    }

    /// Order-sensitive digest of the fault assignment *and* every
    /// injected flip's (op, column) address — two models with equal
    /// fingerprints drew the same faults and corrupted the same bits
    /// in the same order.
    pub fn fingerprint(&self) -> u64 {
        let mut acc = derive_seed(self.flip_seed, &[self.flips, self.digest]);
        for (c, f) in self.faults.iter().enumerate() {
            if let Some(fault) = f {
                let tag = match *fault {
                    Fault::PatternFlip { p } => derive_seed(1, &[p.to_bits()]),
                    Fault::Coupling { agg_pos, p } => {
                        derive_seed(2, &[agg_pos as u64, p.to_bits()])
                    }
                    Fault::Intermittent { period, phase, active, p } => {
                        derive_seed(3, &[period, phase, active, p.to_bits()])
                    }
                };
                acc = derive_seed(acc, &[c as u64, tag]);
            }
        }
        acc
    }

    /// Number of columns carrying a fault.
    pub fn faulty_cols(&self) -> usize {
        self.faults.iter().filter(|f| f.is_some()).count()
    }

    /// The fault assigned to column `c`, if any.
    pub fn fault_at(&self, c: usize) -> Option<Fault> {
        self.faults.get(c).copied().flatten()
    }
}

/// The standard corruption campaign used by the `fault_campaign`
/// integration test, the `BENCH_reliability.json` bench case, and
/// `pudtune campaign`: a quiet device (negligible Gaussian noise, so
/// every golden mismatch is attributable to an injected fault) with
/// all three fault classes enabled deterministically (`p = 1`) on a
/// `fault_col_rate` fraction of columns. Deterministic flip
/// probabilities make campaign outcomes a pure function of the seeds:
/// a faulty column mismatches identically on every identical request,
/// which is what lets the campaign assert *exact* convergence
/// (protected runs reach zero steady-state mismatches) instead of
/// statistical bounds.
pub fn standard_campaign(base: &DeviceConfig) -> DeviceConfig {
    DeviceConfig {
        sigma_sa: 1e-6,
        tail_weight: 0.0,
        sigma_noise: 1e-6,
        fault_col_rate: 0.08,
        fault_pattern_p: 1.0,
        fault_coupling_p: 1.0,
        fault_intermittent_p: 1.0,
        fault_intermittent_period: 32,
        ..base.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn campaign_cfg() -> DeviceConfig {
        standard_campaign(&DeviceConfig::default())
    }

    #[test]
    fn default_config_draws_nothing() {
        let cfg = DeviceConfig::default();
        let mut rng = Rng::new(7);
        let mut f = FaultField::draw(&cfg, 256, &mut rng);
        assert!(!f.is_enabled());
        assert_eq!(f.faulty_cols(), 0);
        for c in 0..256 {
            assert!(!f.flip_simra(c, 0, 4.0, 8, |_| 1.0));
        }
        assert_eq!(f.flips(), 0);
    }

    #[test]
    fn field_is_deterministic_per_seed() {
        let cfg = campaign_cfg();
        let mut a = FaultField::draw(&cfg, 512, &mut Rng::new(42));
        let mut b = FaultField::draw(&cfg, 512, &mut Rng::new(42));
        let c = FaultField::draw(&cfg, 512, &mut Rng::new(43));
        assert!(a.is_enabled(), "campaign rate over 512 cols must assign faults");
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        // Identical flip decisions, address by address.
        for col in 0..512 {
            assert_eq!(a.fault_at(col), b.fault_at(col));
            for op in 0..16u64 {
                assert_eq!(
                    a.flip_simra(col, op, 3.5, 8, |_| 1.0),
                    b.flip_simra(col, op, 3.5, 8, |_| 1.0),
                    "col {col} op {op}"
                );
            }
        }
        assert_eq!(a.flips(), b.flips());
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn pattern_fault_triggers_only_near_the_boundary() {
        let mut f = FaultField {
            faults: vec![Some(Fault::PatternFlip { p: 1.0 })],
            flip_seed: 9,
            flips: 0,
            digest: 0,
            enabled: true,
        };
        // Contested patterns (within the window of rows/2 = 4.0) flip.
        assert!(f.flip_simra(0, 0, 3.5, 8, |_| 0.0));
        assert!(f.flip_simra(0, 1, 4.5, 8, |_| 0.0));
        // Unanimous patterns keep their full margin.
        assert!(!f.flip_simra(0, 2, 1.5, 8, |_| 0.0));
        assert!(!f.flip_simra(0, 3, 6.5, 8, |_| 0.0));
        assert_eq!(f.flips(), 2);
    }

    #[test]
    fn coupling_fault_follows_the_aggressor_charge() {
        let mut f = FaultField {
            faults: vec![Some(Fault::Coupling { agg_pos: 3, p: 1.0 })],
            flip_seed: 9,
            flips: 0,
            digest: 0,
            enabled: true,
        };
        assert!(f.flip_simra(0, 0, 4.0, 8, |pos| if pos == 3 { 1.0 } else { 0.0 }));
        assert!(!f.flip_simra(0, 1, 4.0, 8, |pos| if pos == 3 { 0.2 } else { 1.0 }));
        // Partial drive below the coupling threshold stays clean.
        assert!(!f.flip_simra(0, 2, 4.0, 8, |_| 0.5));
    }

    #[test]
    fn intermittent_fault_is_duty_cycled() {
        let (period, phase, active) = (8u64, 3u64, 2u64);
        let mut f = FaultField {
            faults: vec![Some(Fault::Intermittent { period, phase, active, p: 1.0 })],
            flip_seed: 9,
            flips: 0,
            digest: 0,
            enabled: true,
        };
        let mut fired = Vec::new();
        for op in 0..24u64 {
            if f.flip_simra(0, op, 4.0, 8, |_| 0.0) {
                fired.push(op);
            }
        }
        // Active exactly when (op + phase) % period < active: ops 5, 6
        // in every period of 8 — and an op-probe outside the window
        // (e.g. a one-shot spot check at op 0) sees a healthy column.
        assert_eq!(fired, vec![5, 6, 13, 14, 21, 22]);
    }

    #[test]
    fn sub_unit_probability_is_address_deterministic() {
        let mk = || FaultField {
            faults: vec![Some(Fault::PatternFlip { p: 0.5 })],
            flip_seed: 0xABCD,
            flips: 0,
            digest: 0,
            enabled: true,
        };
        let (mut a, mut b) = (mk(), mk());
        let decisions: Vec<bool> =
            (0..64u64).map(|op| a.flip_simra(0, op, 4.0, 8, |_| 0.0)).collect();
        for (op, &d) in decisions.iter().enumerate() {
            assert_eq!(b.flip_simra(0, op as u64, 4.0, 8, |_| 0.0), d);
        }
        // p = 0.5 over 64 triggered ops: both outcomes occur.
        assert!(decisions.iter().any(|&d| d) && decisions.iter().any(|&d| !d));
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn fingerprint_tracks_flip_order() {
        let mk = || FaultField {
            faults: vec![Some(Fault::PatternFlip { p: 1.0 }); 2],
            flip_seed: 1,
            flips: 0,
            digest: 0,
            enabled: true,
        };
        let (mut a, mut b) = (mk(), mk());
        a.flip_simra(0, 0, 4.0, 8, |_| 0.0);
        a.flip_simra(1, 0, 4.0, 8, |_| 0.0);
        b.flip_simra(1, 0, 4.0, 8, |_| 0.0);
        b.flip_simra(0, 0, 4.0, 8, |_| 0.0);
        assert_eq!(a.flips(), b.flips());
        assert_ne!(a.fingerprint(), b.fingerprint(), "digest must be order-sensitive");
    }

    #[test]
    fn standard_campaign_validates_and_enables_every_class() {
        let cfg = campaign_cfg();
        cfg.validate().unwrap();
        let f = FaultField::draw(&cfg, 4096, &mut Rng::new(0xCA3));
        let mut seen = [false; 3];
        for c in 0..4096 {
            match f.fault_at(c) {
                Some(Fault::PatternFlip { .. }) => seen[0] = true,
                Some(Fault::Coupling { .. }) => seen[1] = true,
                Some(Fault::Intermittent { .. }) => seen[2] = true,
                None => {}
            }
        }
        assert_eq!(seen, [true; 3], "all three classes drawn at campaign rates");
        let frac = f.faulty_cols() as f64 / 4096.0;
        assert!((0.04..0.12).contains(&frac), "faulty fraction {frac}");
    }
}
