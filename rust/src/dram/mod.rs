//! Analog DRAM simulator — the substrate the paper's testbed provided.
//!
//! The paper runs on real SK Hynix DDR4 modules driven by DRAM Bender;
//! every effect it exploits or fights is analog: charge sharing across
//! simultaneously-activated cells, fractional charging, and per-column
//! sense-amplifier threshold variation. This module reproduces those at
//! the level the paper's results depend on (DESIGN.md §1, §3):
//!
//! * [`geometry`] — address arithmetic (channel/bank/subarray/row/col);
//! * [`variation`] — seeded per-column process-variation fields
//!   (threshold offsets with heavy tails, tempco jitter);
//! * [`sense_amp`]  — threshold evaluation under temperature and aging;
//! * [`subarray`] — the cell array: charges, activation, SiMRA charge
//!   sharing, Frac partial charging, row copy (the golden model, on a
//!   hybrid bit-packed / analog row storage);
//! * `dense` — the dense-`f32` reference implementation the hybrid
//!   storage is validated against (compiled under `cfg(test)` or the
//!   `reference-model` feature);
//! * [`bank`], [`device`] — the hierarchy above subarrays;
//! * [`temperature`], [`retention`] — environment models for Fig. 6.

pub mod bank;
#[cfg(any(test, feature = "reference-model"))]
pub mod dense;
pub mod device;
pub mod geometry;
pub mod retention;
pub mod sense_amp;
pub mod subarray;
pub mod temperature;
pub mod variation;
