//! Analog DRAM simulator — the substrate the paper's testbed provided.
//!
//! The paper runs on real SK Hynix DDR4 modules driven by DRAM Bender;
//! every effect it exploits or fights is analog: charge sharing across
//! simultaneously-activated cells, fractional charging, and per-column
//! sense-amplifier threshold variation. This module reproduces those at
//! the level the paper's results depend on (DESIGN.md §1, §3):
//!
//! * [`geometry`] — address arithmetic (channel/bank/subarray/row/col);
//! * [`variation`] — seeded per-column process-variation fields
//!   (threshold offsets with heavy tails, tempco jitter);
//! * [`sense_amp`]  — threshold evaluation under temperature and aging;
//! * [`subarray`] — the cell array: charges, activation, SiMRA charge
//!   sharing, Frac partial charging, row copy (the golden model, on a
//!   hybrid bit-packed / analog row storage);
//! * `dense` — the dense-`f32` reference implementation the hybrid
//!   storage is validated against (compiled under `cfg(test)` or the
//!   `reference-model` feature);
//! * [`faults`] — seeded PuDGhost-style fault injection, off by
//!   default (every fault knob in `DeviceConfig` defaults to zero);
//! * [`bank`], [`device`] — the hierarchy above subarrays;
//! * [`temperature`], [`retention`] — environment models for Fig. 6.
//!
//! ## Fault model
//!
//! Beyond the smooth variation/drift/retention physics, the simulator
//! injects the *discrete* corruption modes PuDGhost characterized on
//! real PUD chips ([`faults`]): pattern-dependent flips (a faulty
//! column corrupts its SiMRA decision only when the data latched
//! across the open rows is contested — near the majority boundary,
//! where margin is thinnest), aggressor/victim row coupling (a victim
//! column flips while a specific row position in the activated group
//! is driven high), and intermittent columns (duty-cycled misbehavior
//! keyed to the subarray's SiMRA operation clock, so one-shot spot
//! checks can pass while sustained workloads keep corrupting). All
//! three are scoped to SiMRA — single-row activation keeps its full
//! margin — drawn per subarray from a dedicated seed stream shared
//! bit-identically by the hybrid and dense models, and invisible to
//! the calibration/ECR sampling kernel, which is exactly why the
//! serving stack pairs them with quarantine/scrub countermeasures
//! ([`crate::coordinator::service`]).

pub mod bank;
#[cfg(any(test, feature = "reference-model"))]
pub mod dense;
pub mod device;
pub mod faults;
pub mod geometry;
pub mod retention;
pub mod sense_amp;
pub mod subarray;
pub mod temperature;
pub mod variation;
