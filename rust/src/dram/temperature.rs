//! Temperature environment (Fig. 6a substrate).
//!
//! The paper heats its modules with pads from 40 °C to 100 °C and checks
//! whether columns calibrated at nominal temperature develop new errors.
//! We model the SA threshold's temperature response as a small
//! common-mode coefficient plus per-column jitter (drawn in
//! [`super::variation`]): columns whose calibrated residual margin is
//! tiny get pushed over the edge, which is exactly the "new error-prone
//! column" population Fig. 6a counts.

/// Environment state shared by a subarray's sense amplifiers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Environment {
    /// Current die temperature, °C.
    pub temp_c: f64,
    /// Elapsed time since calibration, hours.
    pub hours: f64,
}

impl Environment {
    pub fn nominal(t_cal: f64) -> Self {
        Self { temp_c: t_cal, hours: 0.0 }
    }

    /// Common-mode threshold shift at this temperature relative to the
    /// calibration temperature.
    pub fn common_shift(&self, tempco: f64, t_cal: f64) -> f64 {
        tempco * (self.temp_c - t_cal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_has_no_shift() {
        let e = Environment::nominal(45.0);
        assert_eq!(e.common_shift(2e-5, 45.0), 0.0);
    }

    #[test]
    fn shift_scales_with_delta_t() {
        let mut e = Environment::nominal(45.0);
        e.temp_c = 100.0;
        let s = e.common_shift(2e-5, 45.0);
        assert!((s - 55.0 * 2e-5).abs() < 1e-12);
        e.temp_c = 40.0;
        assert!(e.common_shift(2e-5, 45.0) < 0.0);
    }
}
