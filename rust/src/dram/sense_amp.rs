//! Sense-amplifier bank of one subarray.
//!
//! Combines the static variation field, the temperature environment and
//! the aging drift into the *effective* per-column threshold, and makes
//! the noisy analog decision. This is the exact arithmetic the L1 Pallas
//! kernel implements on the PJRT path — `effective_thresholds()` is what
//! the Rust coordinator feeds to the AOT artifacts, which keeps the two
//! paths provably consistent (cross-validation test).

use crate::config::device::DeviceConfig;
use crate::dram::retention::DriftState;
use crate::dram::temperature::Environment;
use crate::dram::variation::VariationField;
use crate::util::rng::Rng;

/// The sense amplifiers of one subarray.
#[derive(Clone, Debug)]
pub struct SenseAmps {
    pub variation: VariationField,
    pub drift: DriftState,
}

impl SenseAmps {
    pub fn new(cfg: &DeviceConfig, cols: usize, rng: &mut Rng) -> Self {
        Self {
            variation: VariationField::draw(cfg, cols, rng),
            drift: DriftState::new(cols),
        }
    }

    pub fn cols(&self) -> usize {
        self.variation.cols()
    }

    /// Effective threshold of one column under the given environment.
    #[inline]
    pub fn threshold(&self, cfg: &DeviceConfig, env: &Environment, col: usize) -> f64 {
        let dt = env.temp_c - cfg.t_cal;
        0.5 + self.variation.sa_offset[col] as f64
            + (cfg.tempco + self.variation.tempco_jitter[col] as f64) * dt
            + self.drift.drift[col] as f64
    }

    /// Effective thresholds for every column (input to the PJRT path).
    pub fn effective_thresholds(&self, cfg: &DeviceConfig, env: &Environment) -> Vec<f32> {
        (0..self.cols())
            .map(|c| self.threshold(cfg, env, c) as f32)
            .collect()
    }

    /// One noisy sense decision on a column given the shared bitline
    /// voltage `v` (V_DD units).
    #[inline]
    pub fn sense(
        &self,
        cfg: &DeviceConfig,
        env: &Environment,
        col: usize,
        v: f64,
        rng: &mut Rng,
    ) -> bool {
        let noise = rng.normal_ms(0.0, cfg.sigma_noise);
        v + noise > self.threshold(cfg, env, col)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(cols: usize, seed: u64) -> (DeviceConfig, SenseAmps) {
        let cfg = DeviceConfig::default();
        let sa = SenseAmps::new(&cfg, cols, &mut Rng::new(seed));
        (cfg, sa)
    }

    #[test]
    fn thresholds_center_on_half_vdd() {
        let (cfg, sa) = mk(20_000, 1);
        let env = Environment::nominal(cfg.t_cal);
        let t = sa.effective_thresholds(&cfg, &env);
        let mean: f64 = t.iter().map(|&x| x as f64).sum::<f64>() / t.len() as f64;
        assert!((mean - 0.5).abs() < 0.002, "{mean}");
    }

    #[test]
    fn clean_read_is_reliable_for_most_columns() {
        // §II-C: a single-cell read at 0.55 V_DD is distinguishable even
        // with ~5% threshold deviation. The fitted variation field keeps
        // most columns inside that bound; the heavy-tail population
        // (the same defect-like columns PUD can never use) is the small
        // remainder.
        let (cfg, sa) = mk(10_000, 2);
        let env = Environment::nominal(cfg.t_cal);
        let mut rng = Rng::new(3);
        let v1 = cfg.bitline_voltage(1.0, 1); // 0.55
        let v0 = cfg.bitline_voltage(0.0, 1); // 0.45
        let mut bad = 0;
        for c in 0..10_000 {
            if !sa.sense(&cfg, &env, c, v1, &mut rng) || sa.sense(&cfg, &env, c, v0, &mut rng) {
                bad += 1;
            }
        }
        assert!(bad < 10_000 * 25 / 100, "bad={bad}"); // >75% read clean
        // And the core population alone is essentially clean: count
        // only columns inside the 5% deviation bound.
        let mut core_bad = 0;
        for c in 0..10_000 {
            if sa.variation.sa_offset[c].abs() < 0.04
                && (!sa.sense(&cfg, &env, c, v1, &mut rng)
                    || sa.sense(&cfg, &env, c, v0, &mut rng))
            {
                core_bad += 1;
            }
        }
        assert!(core_bad < 10, "core_bad={core_bad}");
    }

    #[test]
    fn temperature_moves_thresholds() {
        let (cfg, sa) = mk(64, 4);
        let hot = Environment { temp_c: 100.0, hours: 0.0 };
        let nom = Environment::nominal(cfg.t_cal);
        let th = sa.effective_thresholds(&cfg, &hot);
        let tn = sa.effective_thresholds(&cfg, &nom);
        let dmean: f64 = th
            .iter()
            .zip(&tn)
            .map(|(&a, &b)| (a - b) as f64)
            .sum::<f64>()
            / 64.0;
        let expect = cfg.tempco * (100.0 - cfg.t_cal);
        assert!((dmean - expect).abs() < 3e-4, "dmean={dmean} expect={expect}");
    }
}
