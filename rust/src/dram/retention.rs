//! Aging / long-term drift (Fig. 6b substrate) and cell-charge
//! retention.
//!
//! The paper leaves calibrated modules running for a week and counts new
//! error-prone columns. We model slow per-column threshold drift as a
//! Brownian random walk: advancing simulated time by `dt` hours adds a
//! zero-mean step with std-dev `drift_per_hour * sqrt(dt)` to each
//! column's drift state, so the accumulated drift after T hours has
//! std-dev `drift_per_hour * sqrt(T)` regardless of step granularity —
//! checked by the invariance test below.
//!
//! Cell-charge retention is a first-order leak toward the neutral
//! state: [`swing_factor`] gives the multiplicative factor applied to
//! every cell's deviation from 0.5 over one `advance_time` interval.
//! How a row *reacts* to the factor depends on its charge state (a
//! full-swing row is periodically refreshed, a fractionally-charged row
//! cannot be — refresh would destroy its intermediate levels); that
//! state machine lives in `dram::subarray` ("Retention" section of the
//! module docs) and is shared verbatim by the dense reference model.
//! Unlike drift, the full-swing branch of that state machine is
//! deliberately **per-interval** (each `advance_time` call models one
//! refresh-window check against `retention_swing_min`), so it is not
//! step-granularity invariant — see the
//! `crate::config::device::DeviceConfig::retention_swing_min` docs.

use crate::util::rng::Rng;

/// Multiplicative swing retention over one `dt_hours` interval:
/// `exp(-dt / tau)` for a finite positive `tau_hours`, `1.0` (no
/// decay) for any degenerate input — `dt <= 0`, NaN `dt`, or a
/// non-finite/non-positive/NaN `tau` — so the default
/// [`crate::config::device::DeviceConfig`] (`tau = INFINITY`)
/// reproduces the pre-retention model bit for bit, and a corrupt
/// config can never emit a NaN factor into the charge state.
/// `DeviceConfig::validate` additionally rejects `tau <= 0` and NaN at
/// parse time so misconfiguration is caught before it reaches here.
pub fn swing_factor(dt_hours: f64, tau_hours: f64) -> f64 {
    let decays = dt_hours > 0.0 && tau_hours > 0.0 && tau_hours.is_finite();
    if decays {
        (-dt_hours / tau_hours).exp()
    } else {
        1.0
    }
}

/// Per-column drift state.
#[derive(Clone, Debug)]
pub struct DriftState {
    /// Accumulated threshold drift per column, V_DD units.
    pub drift: Vec<f32>,
}

impl DriftState {
    pub fn new(cols: usize) -> Self {
        Self { drift: vec![0.0; cols] }
    }

    /// Advance the walk by `dt_hours`. Degenerate intervals (zero,
    /// negative, NaN, infinite) are no-ops — a NaN step would
    /// otherwise poison every column's accumulated drift.
    pub fn advance(&mut self, dt_hours: f64, drift_per_hour: f64, rng: &mut Rng) {
        if dt_hours.is_nan() || dt_hours.is_infinite() || dt_hours <= 0.0 {
            return;
        }
        let sd = drift_per_hour * dt_hours.sqrt();
        for d in self.drift.iter_mut() {
            *d += rng.normal_ms(0.0, sd) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rms(xs: &[f32]) -> f64 {
        (xs.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
    }

    #[test]
    fn drift_grows_like_sqrt_t() {
        let mut a = DriftState::new(20_000);
        let mut rng = Rng::new(3);
        a.advance(168.0, 1.2e-5, &mut rng); // one week, single step
        let r = rms(&a.drift);
        let expect = 1.2e-5 * 168f64.sqrt();
        assert!((r - expect).abs() / expect < 0.05, "rms={r} expect={expect}");
    }

    #[test]
    fn step_granularity_invariance() {
        let mut fine = DriftState::new(50_000);
        let mut rng = Rng::new(9);
        for _ in 0..24 {
            fine.advance(7.0, 1.2e-5, &mut rng); // 24 x 7h = 168h
        }
        let r = rms(&fine.drift);
        let expect = 1.2e-5 * 168f64.sqrt();
        assert!((r - expect).abs() / expect < 0.05, "rms={r} expect={expect}");
    }

    #[test]
    fn zero_dt_is_noop() {
        let mut d = DriftState::new(8);
        let mut rng = Rng::new(1);
        d.advance(0.0, 1.0, &mut rng);
        assert!(d.drift.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn degenerate_dt_never_poisons_drift() {
        let mut d = DriftState::new(8);
        let mut rng = Rng::new(1);
        d.advance(f64::NAN, 1.0, &mut rng);
        d.advance(-5.0, 1.0, &mut rng);
        d.advance(f64::INFINITY, 1.0, &mut rng);
        assert!(d.drift.iter().all(|&x| x == 0.0), "{:?}", d.drift);
        // A subsequent well-formed step still works.
        d.advance(1.0, 1.0, &mut rng);
        assert!(d.drift.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn swing_factor_decays_exponentially() {
        // One time constant retains e^-1 of the swing; factors
        // compound across intervals.
        let f1 = swing_factor(8.0, 8.0);
        assert!((f1 - (-1.0f64).exp()).abs() < 1e-12);
        let half = swing_factor(4.0, 8.0);
        assert!((half * half - f1).abs() < 1e-12);
        // Monotone in dt.
        assert!(swing_factor(16.0, 8.0) < f1);
    }

    #[test]
    fn swing_factor_degenerate_inputs_disable_decay() {
        assert_eq!(swing_factor(0.0, 8.0), 1.0);
        assert_eq!(swing_factor(-1.0, 8.0), 1.0);
        assert_eq!(swing_factor(f64::NAN, 8.0), 1.0);
        assert_eq!(swing_factor(24.0, f64::INFINITY), 1.0);
        assert_eq!(swing_factor(24.0, 0.0), 1.0);
        assert_eq!(swing_factor(24.0, -8.0), 1.0);
        assert_eq!(swing_factor(24.0, f64::NAN), 1.0);
    }
}
