//! Aging / long-term drift (Fig. 6b substrate).
//!
//! The paper leaves calibrated modules running for a week and counts new
//! error-prone columns. We model slow per-column threshold drift as a
//! Brownian random walk: advancing simulated time by `dt` hours adds a
//! zero-mean step with std-dev `drift_per_hour * sqrt(dt)` to each
//! column's drift state, so the accumulated drift after T hours has
//! std-dev `drift_per_hour * sqrt(T)` regardless of step granularity —
//! checked by the invariance test below.

use crate::util::rng::Rng;

/// Per-column drift state.
#[derive(Clone, Debug)]
pub struct DriftState {
    /// Accumulated threshold drift per column, V_DD units.
    pub drift: Vec<f32>,
}

impl DriftState {
    pub fn new(cols: usize) -> Self {
        Self { drift: vec![0.0; cols] }
    }

    /// Advance the walk by `dt_hours`.
    pub fn advance(&mut self, dt_hours: f64, drift_per_hour: f64, rng: &mut Rng) {
        if dt_hours <= 0.0 {
            return;
        }
        let sd = drift_per_hour * dt_hours.sqrt();
        for d in self.drift.iter_mut() {
            *d += rng.normal_ms(0.0, sd) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rms(xs: &[f32]) -> f64 {
        (xs.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
    }

    #[test]
    fn drift_grows_like_sqrt_t() {
        let mut a = DriftState::new(20_000);
        let mut rng = Rng::new(3);
        a.advance(168.0, 1.2e-5, &mut rng); // one week, single step
        let r = rms(&a.drift);
        let expect = 1.2e-5 * 168f64.sqrt();
        assert!((r - expect).abs() / expect < 0.05, "rms={r} expect={expect}");
    }

    #[test]
    fn step_granularity_invariance() {
        let mut fine = DriftState::new(50_000);
        let mut rng = Rng::new(9);
        for _ in 0..24 {
            fine.advance(7.0, 1.2e-5, &mut rng); // 24 x 7h = 168h
        }
        let r = rms(&fine.drift);
        let expect = 1.2e-5 * 168f64.sqrt();
        assert!((r - expect).abs() / expect < 0.05, "rms={r} expect={expect}");
    }

    #[test]
    fn zero_dt_is_noop() {
        let mut d = DriftState::new(8);
        let mut rng = Rng::new(1);
        d.advance(0.0, 1.0, &mut rng);
        assert!(d.drift.iter().all(|&x| x == 0.0));
    }
}
