//! Address arithmetic over the DRAM hierarchy (Fig. 2a of the paper):
//! channel -> bank -> subarray -> (row, column).

/// Fully-qualified subarray address.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SubarrayId {
    pub channel: usize,
    pub bank: usize,
    pub subarray: usize,
}

impl SubarrayId {
    pub fn new(channel: usize, bank: usize, subarray: usize) -> Self {
        Self { channel, bank, subarray }
    }

    /// Stable seed-derivation path for this subarray.
    pub fn seed_path(&self) -> [u64; 3] {
        [self.channel as u64, self.bank as u64, self.subarray as u64]
    }
}

/// A row address inside one subarray.
pub type Row = usize;

/// Reserved row layout inside a subarray used by PUD operations.
///
/// The SiMRA decoder glitch activates a naturally-aligned group of
/// 2^k rows, so the compute rows live in one aligned 8-row group
/// (`simra_base..simra_base+8`). Calibration data occupies three rows
/// just below it and the constant all-0/all-1 rows sit next to them,
/// mirroring the paper's Fig. 1 arrangement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowMap {
    /// First row of the 8-row SiMRA group.
    pub simra_base: Row,
    /// Rows storing the pre-identified calibration bits (3 rows).
    pub calib_store: [Row; 3],
    /// All-zeros constant row.
    pub const0: Row,
    /// All-ones constant row.
    pub const1: Row,
    /// First row of general data storage.
    pub data_base: Row,
}

impl RowMap {
    /// Standard layout for a subarray with `rows` rows.
    pub fn standard(rows: usize) -> Self {
        assert!(rows >= 32, "subarray too small for the PUD row layout");
        Self {
            simra_base: 0,
            calib_store: [8, 9, 10],
            const0: 11,
            const1: 12,
            data_base: 16,
        }
    }

    /// The 8 rows opened by a SiMRA on the compute group.
    pub fn simra_rows(&self) -> [Row; 8] {
        let b = self.simra_base;
        [b, b + 1, b + 2, b + 3, b + 4, b + 5, b + 6, b + 7]
    }

    /// Operand rows inside the SiMRA group for an m-input MAJX
    /// (the first m rows), and the non-operand rows (the rest).
    pub fn operand_rows(&self, m: usize) -> Vec<Row> {
        (0..m).map(|i| self.simra_base + i).collect()
    }

    pub fn non_operand_rows(&self, m: usize) -> Vec<Row> {
        (m..8).map(|i| self.simra_base + i).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_map_is_disjoint() {
        let m = RowMap::standard(512);
        let mut all: Vec<Row> = m.simra_rows().to_vec();
        all.extend_from_slice(&m.calib_store);
        all.push(m.const0);
        all.push(m.const1);
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "row roles must not overlap");
        assert!(m.data_base > *all.last().unwrap());
    }

    #[test]
    fn simra_group_is_aligned() {
        let m = RowMap::standard(512);
        assert_eq!(m.simra_base % 8, 0, "SiMRA group must be 8-aligned");
    }

    #[test]
    fn operand_split() {
        let m = RowMap::standard(512);
        assert_eq!(m.operand_rows(5).len(), 5);
        assert_eq!(m.non_operand_rows(5).len(), 3);
        assert_eq!(m.operand_rows(3).len(), 3);
        assert_eq!(m.non_operand_rows(3).len(), 5);
    }
}
