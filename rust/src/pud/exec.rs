//! Bit-serial circuit execution on the subarray — an interpreter of
//! the canonical lowering.
//!
//! [`run_plan`] no longer re-derives the setup/Frac/SiMRA/readout
//! order itself: it admits the plan, obtains its canonical
//! [`LoweredPlan`] ([`WorkloadPlan::lowered`] — the same single-pass
//! artifact the static verifier's charge-state machine checks), and
//! interprets the typed step stream against the subarray. One source
//! of truth: the program that executes is — by construction — the
//! program that was verified. The per-step interpreter
//! ([`StepRunner`]) is shared with the batch-fused engine path
//! ([`crate::calib::engine::ComputeEngine`]), which drives many banks
//! through the same stream step-major.
//!
//! Request validation is typed: arity/width/row-budget violations
//! surface as [`PudError`]s *before* the subarray is touched, so a
//! malformed request degrades one bank instead of poisoning a worker
//! pool ([`crate::calib::engine::execute_isolated`]).
//!
//! The executor is also the heaviest consumer of the subarray's hybrid
//! row storage: wire traffic is pure RowCopy/write between full-swing
//! rows (word-wise packed copies), only the calibration rows inside a
//! MAJX group ever go analog, and each gate's SiMRA restores them — so
//! a run holds at most three analog rows at any instant and ends with
//! zero ([`CircuitRun::storage_bytes`] records the resulting packed
//! footprint).

use crate::calib::algorithm::Calibration;
use crate::calib::lattice::FracConfig;
use crate::config::system::Ddr4Timing;
use crate::dram::geometry::RowMap;
use crate::dram::subarray::Subarray;
use crate::pud::graph::MajCircuit;
use crate::pud::majx::{execute_majx, setup_subarray, MajX};
use crate::pud::plan::{PudError, WorkloadPlan};
use crate::pud::verify::{LoweredPlan, LoweredStep, CALIB_STORE, CONST0, CONST1, DATA_BASE};

/// Result of a circuit run.
#[derive(Clone, Debug)]
pub struct CircuitRun {
    /// Output bit-vectors, one per circuit output, each `cols` wide.
    pub outputs: Vec<Vec<u8>>,
    pub elapsed_ns: f64,
    /// Peak simultaneous scratch rows.
    pub peak_rows: usize,
    /// Subarray cell-state heap bytes after the run. Every MAJX flow
    /// ends in a SiMRA restore, so every row the circuit touches exits
    /// at full swing and this stays at the bit-packed floor however
    /// long the circuit is.
    pub storage_bytes: usize,
}

/// Execute an ad-hoc circuit over per-column operand bit-vectors.
///
/// Compiles a throwaway [`WorkloadPlan`] and runs it — callers
/// executing the same circuit repeatedly (or across banks) should
/// compile once and use [`run_plan`].
pub fn run_circuit(
    sub: &mut Subarray,
    map: &RowMap,
    calib: &Calibration,
    fc: &FracConfig,
    grade: &Ddr4Timing,
    circuit: &MajCircuit,
    inputs: &[Vec<u8>],
) -> Result<CircuitRun, PudError> {
    let plan = WorkloadPlan::from_circuit(circuit.clone())?;
    run_plan(sub, map, calib, fc, grade, &plan, inputs)
}

/// Translate an abstract lowered-script row (the layout fixed by
/// [`crate::pud::verify`]: SiMRA group, calibration stores, constants,
/// then the data region from [`DATA_BASE`]) to the subarray's physical
/// row through its [`RowMap`]. The lowering's replay allocator mirrors
/// the executor's LIFO discipline, so abstract data row `DATA_BASE+k`
/// is always physical row `map.data_base + k`.
pub fn phys_row(map: &RowMap, row: usize) -> usize {
    match row {
        r if r >= DATA_BASE => map.data_base + (r - DATA_BASE),
        r if r == CONST0 => map.const0,
        r if r == CONST1 => map.const1,
        r if CALIB_STORE.contains(&r) => map.calib_store[r - CALIB_STORE[0]],
        // The abstract SiMRA group starts at row 0 (`SIMRA_BASE`).
        r => map.simra_base + r,
    }
}

/// Incremental interpreter for one subarray walking a [`LoweredPlan`]
/// step stream. [`run_lowered`] drives it step-by-step for a single
/// bank; the batch-fused engine path drives one runner per bank
/// through the same stream step-major. Either way each subarray sees
/// the exact same operation sequence, so results are bit-identical.
#[derive(Clone, Debug)]
pub struct StepRunner {
    elapsed_ns: f64,
    not_buf: Vec<u8>,
    outputs: Vec<Vec<u8>>,
}

impl StepRunner {
    /// A fresh runner for a subarray with `cols` columns. The subarray
    /// must already be set up ([`setup_subarray`]) and validated
    /// against the plan (see [`run_lowered`]).
    pub fn new(cols: usize) -> Self {
        Self { elapsed_ns: 0.0, not_buf: vec![0u8; cols], outputs: Vec::new() }
    }

    /// Apply one lowered step to the subarray. `inputs[i]` is the
    /// bit-vector of primary input `i` (length = cols).
    pub fn apply(
        &mut self,
        sub: &mut Subarray,
        map: &RowMap,
        fc: &FracConfig,
        grade: &Ddr4Timing,
        inputs: &[Vec<u8>],
        step: &LoweredStep,
    ) {
        match step {
            LoweredStep::WriteInput { input, row } => {
                sub.write_row(phys_row(map, *row), &inputs[*input]);
            }
            LoweredStep::Not { src, dst } => {
                sub.read_row_into(phys_row(map, *src), &mut self.not_buf);
                for b in self.not_buf.iter_mut() {
                    *b = 1 - *b;
                }
                sub.write_row(phys_row(map, *dst), &self.not_buf);
                // NOT = readout + write-back through the column
                // interface.
                self.elapsed_ns += grade.t_rcd + 8.0 * grade.t_ck + grade.t_rp;
                self.elapsed_ns += grade.t_rcd + 8.0 * grade.t_ck + grade.t_rp;
            }
            LoweredStep::Majx { m, operands, dst, .. } => {
                let x = if *m == 3 { MajX::Maj3 } else { MajX::Maj5 };
                let rows: Vec<usize> = operands.iter().map(|&r| phys_row(map, r)).collect();
                let (bits, run) = execute_majx(sub, map, x, &rows, fc, grade);
                self.elapsed_ns += run.elapsed_ns;
                // Persist the result into a scratch row (copy out of
                // the group).
                sub.write_row(phys_row(map, *dst), &bits);
            }
            // Releases are bookkeeping: the lowering's replay allocator
            // already baked the LIFO row reuse into the row ids.
            LoweredStep::Release { .. } => {}
            LoweredStep::ReadOutput { row, .. } => {
                self.outputs.push(sub.read_row(phys_row(map, *row)));
            }
        }
    }

    /// Finish the run: package outputs, elapsed model time and the
    /// lowering's replayed scratch peak into a [`CircuitRun`].
    pub fn finish(self, sub: &Subarray, peak_rows: usize) -> CircuitRun {
        CircuitRun {
            outputs: self.outputs,
            elapsed_ns: self.elapsed_ns,
            peak_rows,
            storage_bytes: sub.approx_bytes(),
        }
    }
}

/// Execute a compiled plan over per-column operand bit-vectors.
///
/// `inputs[i]` is the bit-vector of primary input `i` (length = cols).
/// The calibration rows must already be identified; `setup_subarray`
/// is invoked to (re)store them. Validation happens up front: the
/// subarray is untouched when an `Err` is returned.
pub fn run_plan(
    sub: &mut Subarray,
    map: &RowMap,
    calib: &Calibration,
    fc: &FracConfig,
    grade: &Ddr4Timing,
    plan: &WorkloadPlan,
    inputs: &[Vec<u8>],
) -> Result<CircuitRun, PudError> {
    // Admission: plans from `WorkloadPlan::compile` pass in O(1);
    // hand-assembled plans get the full charge-state verification and
    // are rejected here, before the subarray is touched.
    crate::pud::verify::admit(plan)?;
    let lowered = plan.lowered()?;
    run_lowered(sub, map, calib, fc, grade, plan, &lowered, inputs)
}

/// Execute an already-admitted plan's canonical lowering: validate the
/// request shape against this subarray, set up the calibration and
/// constant rows, then interpret the step stream. This is the single
/// execution core behind both [`run_plan`] and the batch-fused engine
/// path; callers are responsible for having [`crate::pud::verify::admit`]ted
/// the plan the lowering came from.
#[allow(clippy::too_many_arguments)]
pub fn run_lowered(
    sub: &mut Subarray,
    map: &RowMap,
    calib: &Calibration,
    fc: &FracConfig,
    grade: &Ddr4Timing,
    plan: &WorkloadPlan,
    lowered: &LoweredPlan,
    inputs: &[Vec<u8>],
) -> Result<CircuitRun, PudError> {
    let circuit = &plan.circuit;
    if inputs.len() != circuit.n_inputs {
        return Err(PudError::ArityMismatch {
            expected: circuit.n_inputs,
            got: inputs.len(),
        });
    }
    for v in inputs {
        if v.len() != sub.cols {
            return Err(PudError::WidthMismatch { expected: sub.cols, got: v.len() });
        }
    }
    if calib.cols() != sub.cols {
        return Err(PudError::WidthMismatch { expected: sub.cols, got: calib.cols() });
    }
    let available = sub.rows.saturating_sub(map.data_base);
    if available == 0 || plan.peak_rows > available {
        return Err(PudError::RowBudgetExceeded {
            needed: plan.peak_rows.max(1),
            available,
        });
    }
    setup_subarray(sub, map, calib);

    let mut runner = StepRunner::new(sub.cols);
    for step in &lowered.steps {
        runner.apply(sub, map, fc, grade, inputs, step);
    }
    // Every gate's SiMRA restored its group to full swing; only the
    // calibration rows re-Frac'd by the *next* MAJX will leave the
    // packed representation again. (Scoped to the SiMRA group: rows the
    // circuit never touched may legitimately hold analog charge, e.g.
    // after retention decay applied before the run.)
    debug_assert!(
        circuit.gates.is_empty()
            || (map.simra_base..map.simra_base + 8).all(|r| sub.row_is_packed(r)),
        "circuit must leave its SiMRA group fully restored"
    );
    Ok(runner.finish(sub, lowered.peak_rows()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::lattice::OffsetLattice;
    use crate::config::device::DeviceConfig;
    use crate::pud::adder::ripple_adder;
    use crate::pud::plan::PudOp;

    fn quiet(cols: usize) -> Subarray {
        let mut cfg = DeviceConfig::default();
        cfg.sigma_sa = 1e-6;
        cfg.tail_weight = 0.0;
        cfg.sigma_noise = 1e-6;
        Subarray::with_geometry(&cfg, 96, cols, 3)
    }

    fn encode(vals: &[u64], bit: usize) -> Vec<u8> {
        vals.iter().map(|&v| ((v >> bit) & 1) as u8).collect()
    }

    #[test]
    fn adder_circuit_runs_in_dram() {
        // 4-bit add on 8 columns simultaneously (bit-serial SIMD).
        let width = 4;
        let circuit = ripple_adder(width);
        let mut sub = quiet(8);
        let map = RowMap::standard(sub.rows);
        let fc = FracConfig::pudtune([2, 1, 0]);
        let calib =
            Calibration::uniform(OffsetLattice::build(&sub.cfg, &fc), sub.cols);
        let a: Vec<u64> = vec![3, 7, 15, 0, 9, 5, 12, 1];
        let b: Vec<u64> = vec![4, 9, 1, 0, 6, 5, 3, 14];
        let mut inputs = Vec::new();
        for bit in 0..width {
            inputs.push(encode(&a, bit));
        }
        for bit in 0..width {
            inputs.push(encode(&b, bit));
        }
        let run = run_circuit(
            &mut sub,
            &map,
            &calib,
            &fc,
            &Ddr4Timing::ddr4_2133(),
            &circuit,
            &inputs,
        )
        .expect("well-formed request");
        assert_eq!(run.outputs.len(), width + 1);
        for col in 0..8 {
            let mut got = 0u64;
            for (bit, out) in run.outputs.iter().enumerate() {
                got |= (out[col] as u64) << bit;
            }
            assert_eq!(got, a[col] + b[col], "col {col}");
        }
        assert!(run.elapsed_ns > 0.0);
        assert!(run.peak_rows < 32, "peak rows {}", run.peak_rows);
        // Long circuits never accumulate analog rows: every gate's
        // SiMRA restores its group, so the subarray stays at the
        // bit-packed storage floor (the >=10x footprint win at real
        // geometry is pinned in rust/tests/storage_parity.rs).
        assert_eq!(sub.analog_rows(), 0);
        assert_eq!(run.storage_bytes, sub.approx_bytes());
    }

    #[test]
    fn plan_peak_rows_matches_the_executed_high_water() {
        // The plan's allocation dry-run must predict the executor's
        // scratch high-water mark exactly — it is what the row-budget
        // admission check is based on.
        for op in [PudOp::Add { width: 4 }, PudOp::Mul { width: 3 }] {
            let plan = WorkloadPlan::compile(op).unwrap();
            let mut sub = quiet(8);
            let map = RowMap::standard(sub.rows);
            let fc = FracConfig::pudtune([2, 1, 0]);
            let calib =
                Calibration::uniform(OffsetLattice::build(&sub.cfg, &fc), sub.cols);
            let inputs = plan
                .encode_operands(&[vec![3; 8], vec![5; 8]])
                .unwrap();
            let run = run_plan(
                &mut sub,
                &map,
                &calib,
                &fc,
                &Ddr4Timing::ddr4_2133(),
                &plan,
                &inputs,
            )
            .unwrap();
            assert_eq!(
                run.peak_rows,
                plan.peak_rows,
                "dry-run peak diverged for {}",
                plan.op.label()
            );
        }
    }

    #[test]
    fn narrowed_plans_run_in_dram_and_match_the_wide_plan() {
        // A width-narrowed variant (pud::ranges) keeps the original
        // interface — same operand encoding, same output count — so it
        // drops into the executor unchanged and must produce identical
        // sums for in-range operands.
        use crate::pud::ranges::OperandRange;
        let wide = WorkloadPlan::compile(PudOp::Add { width: 8 }).unwrap();
        let narrow = wide
            .narrowed(&[OperandRange::new(0, 15), OperandRange::new(0, 15)])
            .unwrap();
        assert!(narrow.is_verified());
        assert!(
            narrow.circuit.gates.len() < wide.circuit.gates.len(),
            "nibble-range add8 must narrow ({} vs {})",
            narrow.circuit.gates.len(),
            wide.circuit.gates.len()
        );
        let a: Vec<u64> = vec![3, 7, 15, 0, 9, 5, 12, 1];
        let b: Vec<u64> = vec![4, 9, 1, 0, 6, 5, 3, 14];
        let mut decoded = Vec::new();
        for plan in [&wide, &narrow] {
            let mut sub = quiet(8);
            let map = RowMap::standard(sub.rows);
            let fc = FracConfig::pudtune([2, 1, 0]);
            let calib =
                Calibration::uniform(OffsetLattice::build(&sub.cfg, &fc), sub.cols);
            let inputs = plan.encode_operands(&[a.clone(), b.clone()]).unwrap();
            let run = run_plan(
                &mut sub,
                &map,
                &calib,
                &fc,
                &Ddr4Timing::ddr4_2133(),
                plan,
                &inputs,
            )
            .unwrap();
            let mut vals = vec![0u64; 8];
            for (bit, out) in run.outputs.iter().enumerate() {
                for col in 0..8 {
                    vals[col] |= (out[col] as u64) << bit;
                }
            }
            decoded.push(vals);
        }
        for col in 0..8 {
            assert_eq!(decoded[0][col], a[col] + b[col], "wide col {col}");
            assert_eq!(decoded[1][col], a[col] + b[col], "narrow col {col}");
        }
    }

    #[test]
    fn not_rows_are_recycled() {
        // A chain of identity gates each consuming the negation of the
        // previous one: MAJ3(!prev, 0, 1) = !prev. Every gate
        // materialises one NOT row; with per-gate death lists releasing
        // both polarities, the scratch high-water mark stays O(1) in
        // circuit length (the seed leaked one row per NOT).
        use crate::pud::graph::{Gate, MajCircuit, Signal};
        let mut c = MajCircuit::new(1);
        let mut prev = Signal::Input(0);
        for _ in 0..24 {
            let not_prev = match prev {
                Signal::Input(i) => Signal::NotInput(i),
                Signal::Gate(g) => Signal::NotGate(g),
                _ => unreachable!(),
            };
            prev = c.push(Gate::maj3(not_prev, Signal::Const(false), Signal::Const(true)));
        }
        c.output(prev);
        let mut sub = quiet(8);
        let map = RowMap::standard(sub.rows);
        let fc = FracConfig::pudtune([2, 1, 0]);
        let calib =
            Calibration::uniform(OffsetLattice::build(&sub.cfg, &fc), sub.cols);
        let run = run_circuit(
            &mut sub,
            &map,
            &calib,
            &fc,
            &Ddr4Timing::ddr4_2133(),
            &c,
            &[vec![0u8; 8]],
        )
        .expect("well-formed request");
        // 24 chained negations of constant-0 input -> 0 again.
        assert!(run.outputs[0].iter().all(|&b| b == 0), "{:?}", run.outputs);
        assert!(run.peak_rows < 16, "NOT rows leaked: peak={}", run.peak_rows);
    }

    #[test]
    fn malformed_requests_error_without_touching_the_subarray() {
        let circuit = ripple_adder(2);
        let mut sub = quiet(4);
        let map = RowMap::standard(sub.rows);
        let fc = FracConfig::pudtune([2, 1, 0]);
        let calib =
            Calibration::uniform(OffsetLattice::build(&sub.cfg, &fc), sub.cols);
        let grade = Ddr4Timing::ddr4_2133();
        let fingerprint = sub.rng_fingerprint();
        // Wrong input count.
        let err = run_circuit(&mut sub, &map, &calib, &fc, &grade, &circuit, &[vec![0u8; 4]])
            .unwrap_err();
        assert_eq!(err, PudError::ArityMismatch { expected: 4, got: 1 });
        // Wrong operand width.
        let err = run_circuit(
            &mut sub,
            &map,
            &calib,
            &fc,
            &grade,
            &circuit,
            &[vec![0u8; 3], vec![0; 4], vec![0; 4], vec![0; 4]],
        )
        .unwrap_err();
        assert_eq!(err, PudError::WidthMismatch { expected: 4, got: 3 });
        // Calibration for the wrong geometry.
        let wide = Calibration::uniform(OffsetLattice::build(&sub.cfg, &fc), 8);
        let err = run_circuit(&mut sub, &map, &wide, &fc, &grade, &circuit, &[vec![0u8; 4]; 4])
            .unwrap_err();
        assert_eq!(err, PudError::WidthMismatch { expected: 4, got: 8 });
        // Row budget: a subarray whose data region cannot hold the
        // plan's scratch set.
        let plan = WorkloadPlan::compile(PudOp::Mul { width: 4 }).unwrap();
        let mut tiny = quiet(4);
        let tiny_map = RowMap {
            data_base: tiny.rows - 2,
            ..RowMap::standard(tiny.rows)
        };
        let inputs = plan.encode_operands(&[vec![1; 4], vec![2; 4]]).unwrap();
        let err = run_plan(&mut tiny, &tiny_map, &calib, &fc, &grade, &plan, &inputs)
            .unwrap_err();
        assert!(
            matches!(err, PudError::RowBudgetExceeded { available: 2, .. }),
            "{err:?}"
        );
        // Validation failures never consumed subarray randomness.
        assert_eq!(sub.rng_fingerprint(), fingerprint);
    }
}
