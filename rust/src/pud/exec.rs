//! Bit-serial circuit execution on the subarray.
//!
//! Runs a [`MajCircuit`] gate by gate through the full MAJX flow
//! (RowCopy-in, Frac, SiMRA, copy-out), with wire rows recycled by
//! last-use analysis. This is the functional path the examples use to
//! run real 8-bit arithmetic *in* the simulated DRAM; throughput
//! numbers come from `analysis::throughput` which uses the same
//! command-cost model.
//!
//! The executor is also the heaviest consumer of the subarray's hybrid
//! row storage: wire traffic is pure RowCopy/write between full-swing
//! rows (word-wise packed copies), only the calibration rows inside a
//! MAJX group ever go analog, and each gate's SiMRA restores them — so
//! a run holds at most three analog rows at any instant and ends with
//! zero ([`CircuitRun::storage_bytes`] records the resulting packed
//! footprint).

use crate::calib::algorithm::Calibration;
use crate::calib::lattice::FracConfig;
use crate::config::system::Ddr4Timing;
use crate::dram::geometry::RowMap;
use crate::dram::subarray::Subarray;
use crate::pud::graph::{MajCircuit, Signal};
use crate::pud::majx::{execute_majx, setup_subarray, MajX};
use crate::pud::rowalloc::RowAlloc;
use std::collections::HashMap;

/// Result of a circuit run.
#[derive(Clone, Debug)]
pub struct CircuitRun {
    /// Output bit-vectors, one per circuit output, each `cols` wide.
    pub outputs: Vec<Vec<u8>>,
    pub elapsed_ns: f64,
    /// Peak simultaneous scratch rows.
    pub peak_rows: usize,
    /// Subarray cell-state heap bytes after the run. Every MAJX flow
    /// ends in a SiMRA restore, so every row the circuit touches exits
    /// at full swing and this stays at the bit-packed floor however
    /// long the circuit is.
    pub storage_bytes: usize,
}

/// Execute `circuit` over per-column operand bit-vectors.
///
/// `inputs[i]` is the bit-vector of primary input `i` (length = cols).
/// The calibration rows must already be identified; `setup_subarray`
/// is invoked to (re)store them.
pub fn run_circuit(
    sub: &mut Subarray,
    map: &RowMap,
    calib: &Calibration,
    fc: &FracConfig,
    grade: &Ddr4Timing,
    circuit: &MajCircuit,
    inputs: &[Vec<u8>],
) -> CircuitRun {
    assert_eq!(inputs.len(), circuit.n_inputs, "operand arity mismatch");
    for v in inputs {
        assert_eq!(v.len(), sub.cols, "operand width must equal columns");
    }
    setup_subarray(sub, map, calib);

    let mut elapsed = 0.0f64;

    // Last gate index using each signal, for row recycling.
    let mut last_use: HashMap<Signal, usize> = HashMap::new();
    for (gi, gate) in circuit.gates.iter().enumerate() {
        for &s in &gate.args {
            last_use.insert(canonical(s), gi);
        }
    }
    for &s in &circuit.outputs {
        last_use.insert(canonical(s), usize::MAX); // outputs live forever
    }
    // Per-gate death lists, built once — releasing dead rows is then
    // O(deaths) per gate instead of a scan over every live signal.
    let mut deaths: Vec<Vec<Signal>> = vec![Vec::new(); circuit.gates.len()];
    for (&sig, &lu) in &last_use {
        if lu != usize::MAX {
            deaths[lu].push(sig);
        }
    }

    let mut alloc = RowAlloc::new(map.data_base, sub.rows);

    // Materialise primary inputs.
    let mut input_rows = Vec::with_capacity(circuit.n_inputs);
    for bits in inputs {
        let r = alloc.alloc();
        sub.write_row(r, bits);
        input_rows.push(r);
    }
    let mut gate_rows: Vec<Option<usize>> = vec![None; circuit.gates.len()];
    // Cache of materialised negations.
    let mut not_rows: HashMap<Signal, usize> = HashMap::new();
    // One reusable row buffer for every NOT materialisation.
    let mut not_buf = vec![0u8; sub.cols];

    // Resolve a signal to a readable row, materialising NOTs on demand.
    // (Closures can't borrow everything mutably at once; a macro keeps
    // the call sites readable.)
    macro_rules! row_of {
        ($sig:expr) => {{
            let sig: Signal = $sig;
            match sig {
                Signal::Input(i) => input_rows[i],
                Signal::Gate(g) => gate_rows[g].expect("gate row live"),
                Signal::Const(false) => map.const0,
                Signal::Const(true) => map.const1,
                Signal::NotInput(_) | Signal::NotGate(_) => {
                    if let Some(&r) = not_rows.get(&sig) {
                        r
                    } else {
                        let src = match sig {
                            Signal::NotInput(i) => input_rows[i],
                            Signal::NotGate(g) => gate_rows[g].expect("gate row live"),
                            _ => unreachable!(),
                        };
                        sub.read_row_into(src, &mut not_buf);
                        for b in not_buf.iter_mut() {
                            *b = 1 - *b;
                        }
                        let r = alloc.alloc();
                        sub.write_row(r, &not_buf);
                        // NOT = readout + write-back through the column
                        // interface.
                        elapsed += grade.t_rcd + 8.0 * grade.t_ck + grade.t_rp;
                        elapsed += grade.t_rcd + 8.0 * grade.t_ck + grade.t_rp;
                        not_rows.insert(sig, r);
                        r
                    }
                }
            }
        }};
    }

    for (gi, gate) in circuit.gates.iter().enumerate() {
        let op_rows: Vec<usize> = gate.args.iter().map(|&s| row_of!(s)).collect();
        let x = if gate.arity() == 3 { MajX::Maj3 } else { MajX::Maj5 };
        let (bits, run) = execute_majx(sub, map, x, &op_rows, fc, grade);
        elapsed += run.elapsed_ns;
        // Persist the result into a scratch row (copy out of the group).
        let r = alloc.alloc();
        sub.write_row(r, &bits);
        gate_rows[gi] = Some(r);
        // Recycle rows whose signals die at this gate (precomputed).
        // Death lists hold canonical signals, and a canonical last-use
        // index covers *both* polarities — so a dying gate releases its
        // own row and any materialised negation of it (the seed kept
        // NOT rows alive forever, leaking scratch rows on NOT-heavy
        // circuits).
        for sig in deaths[gi].drain(..) {
            match sig {
                Signal::Gate(g) => {
                    if let Some(r) = gate_rows[g].take() {
                        alloc.release(r);
                    }
                    if let Some(r) = not_rows.remove(&Signal::NotGate(g)) {
                        alloc.release(r);
                    }
                }
                Signal::Input(i) => {
                    if let Some(r) = not_rows.remove(&Signal::NotInput(i)) {
                        alloc.release(r);
                    }
                }
                _ => {}
            }
        }
    }

    let outputs = circuit
        .outputs
        .iter()
        .map(|&s| {
            let r = row_of!(s);
            sub.read_row(r)
        })
        .collect();
    // Every gate's SiMRA restored its group to full swing; only the
    // calibration rows re-Frac'd by the *next* MAJX will leave the
    // packed representation again. (Scoped to the SiMRA group: rows the
    // circuit never touched may legitimately hold analog charge, e.g.
    // after retention decay applied before the run.)
    debug_assert!(
        circuit.gates.is_empty()
            || (map.simra_base..map.simra_base + 8).all(|r| sub.row_is_packed(r)),
        "circuit must leave its SiMRA group fully restored"
    );
    CircuitRun {
        outputs,
        elapsed_ns: elapsed,
        peak_rows: alloc.high_water,
        storage_bytes: sub.approx_bytes(),
    }
}

/// Canonical storage key: a signal and its negation share liveness.
fn canonical(s: Signal) -> Signal {
    match s {
        Signal::NotInput(i) => Signal::Input(i),
        Signal::NotGate(g) => Signal::Gate(g),
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::lattice::OffsetLattice;
    use crate::config::device::DeviceConfig;
    use crate::pud::adder::ripple_adder;

    fn quiet(cols: usize) -> Subarray {
        let mut cfg = DeviceConfig::default();
        cfg.sigma_sa = 1e-6;
        cfg.tail_weight = 0.0;
        cfg.sigma_noise = 1e-6;
        Subarray::with_geometry(&cfg, 96, cols, 3)
    }

    fn encode(vals: &[u64], bit: usize) -> Vec<u8> {
        vals.iter().map(|&v| ((v >> bit) & 1) as u8).collect()
    }

    #[test]
    fn adder_circuit_runs_in_dram() {
        // 4-bit add on 8 columns simultaneously (bit-serial SIMD).
        let width = 4;
        let circuit = ripple_adder(width);
        let mut sub = quiet(8);
        let map = RowMap::standard(sub.rows);
        let fc = FracConfig::pudtune([2, 1, 0]);
        let calib =
            Calibration::uniform(OffsetLattice::build(&sub.cfg, &fc), sub.cols);
        let a: Vec<u64> = vec![3, 7, 15, 0, 9, 5, 12, 1];
        let b: Vec<u64> = vec![4, 9, 1, 0, 6, 5, 3, 14];
        let mut inputs = Vec::new();
        for bit in 0..width {
            inputs.push(encode(&a, bit));
        }
        for bit in 0..width {
            inputs.push(encode(&b, bit));
        }
        let run = run_circuit(
            &mut sub,
            &map,
            &calib,
            &fc,
            &Ddr4Timing::ddr4_2133(),
            &circuit,
            &inputs,
        );
        assert_eq!(run.outputs.len(), width + 1);
        for col in 0..8 {
            let mut got = 0u64;
            for (bit, out) in run.outputs.iter().enumerate() {
                got |= (out[col] as u64) << bit;
            }
            assert_eq!(got, a[col] + b[col], "col {col}");
        }
        assert!(run.elapsed_ns > 0.0);
        assert!(run.peak_rows < 32, "peak rows {}", run.peak_rows);
        // Long circuits never accumulate analog rows: every gate's
        // SiMRA restores its group, so the subarray stays at the
        // bit-packed storage floor (the >=10x footprint win at real
        // geometry is pinned in rust/tests/storage_parity.rs).
        assert_eq!(sub.analog_rows(), 0);
        assert_eq!(run.storage_bytes, sub.approx_bytes());
    }

    #[test]
    fn not_rows_are_recycled() {
        // A chain of identity gates each consuming the negation of the
        // previous one: MAJ3(!prev, 0, 1) = !prev. Every gate
        // materialises one NOT row; with per-gate death lists releasing
        // both polarities, the scratch high-water mark stays O(1) in
        // circuit length (the seed leaked one row per NOT).
        use crate::pud::graph::{Gate, MajCircuit, Signal};
        let mut c = MajCircuit::new(1);
        let mut prev = Signal::Input(0);
        for _ in 0..24 {
            let not_prev = match prev {
                Signal::Input(i) => Signal::NotInput(i),
                Signal::Gate(g) => Signal::NotGate(g),
                _ => unreachable!(),
            };
            prev = c.push(Gate::maj3(not_prev, Signal::Const(false), Signal::Const(true)));
        }
        c.output(prev);
        let mut sub = quiet(8);
        let map = RowMap::standard(sub.rows);
        let fc = FracConfig::pudtune([2, 1, 0]);
        let calib =
            Calibration::uniform(OffsetLattice::build(&sub.cfg, &fc), sub.cols);
        let run = run_circuit(
            &mut sub,
            &map,
            &calib,
            &fc,
            &Ddr4Timing::ddr4_2133(),
            &c,
            &[vec![0u8; 8]],
        );
        // 24 chained negations of constant-0 input -> 0 again.
        assert!(run.outputs[0].iter().all(|&b| b == 0), "{:?}", run.outputs);
        assert!(run.peak_rows < 16, "NOT rows leaked: peak={}", run.peak_rows);
    }

    #[test]
    #[should_panic(expected = "operand arity mismatch")]
    fn wrong_input_count_panics() {
        let circuit = ripple_adder(2);
        let mut sub = quiet(4);
        let map = RowMap::standard(sub.rows);
        let fc = FracConfig::pudtune([2, 1, 0]);
        let calib =
            Calibration::uniform(OffsetLattice::build(&sub.cfg, &fc), sub.cols);
        run_circuit(
            &mut sub,
            &map,
            &calib,
            &fc,
            &Ddr4Timing::ddr4_2133(),
            &circuit,
            &[vec![0u8; 4]],
        );
    }
}
