//! Bit-level range analysis and static width narrowing.
//!
//! The PR-7 verifier proves a plan is *charge-state* safe; this module
//! is the sibling **value** analysis. Most served operands do not need
//! the full compiled width (Proteus, arxiv 2501.17466): an `add8` whose
//! operands live in `[0, 15]` wastes half its SiMRA flows computing
//! bits that are provably zero. The analysis here proves which bits
//! those are, and [`crate::pud::plan::WorkloadPlan::narrowed`] strips
//! them — so narrower variants need fewer gates and fewer steps, more
//! circuits fit under the row budget, and effective throughput (Eq. 1)
//! rises without new hardware modeling.
//!
//! ## The range lattice
//!
//! Every wire carries a ternary bit value ([`BitVal`]):
//!
//! ```text
//!        Top            (unknown: 0 or 1 depending on operands)
//!       /   \
//!    Zero    One        (provably constant under the declared ranges)
//! ```
//!
//! Input bits come from declared per-operand [`OperandRange`]s: every
//! value in `[lo, hi]` shares the bits above the highest bit where
//! `lo` and `hi` differ, so those bits are constant and the rest are
//! `Top`. The abstract transfer for a MAJ gate is strictly stronger
//! than per-bit counting — each wire's abstract value is a *resolved
//! signal* (constant, input polarity, or live-gate polarity), so the
//! interpreter folds:
//!
//! * **constant votes** — enough known ones (or zeros) decide the gate;
//! * **complement pairs** — `(x, ¬x)` contributes exactly one 1 and
//!   one 0 whatever `x` is (how `MAJ5(a,b,cin,¬cout,¬cout)` folds);
//! * **dominant roots** — when one unknown root's multiplicity alone
//!   decides the vote both ways (`MAJ3(0,1,c) = c`, `MAJ3(x,x,y) = x`),
//!   the gate folds to an *alias* of that root.
//!
//! On top of the bit lattice, `Add`/`Mul` outputs get a **value
//! interval** refinement: the output interval `[lo_a ⊕ lo_b, hi_a ⊕
//! hi_b]` (monotone ops over unsigned ranges) proves carries impossible
//! that per-bit propagation cannot — e.g. `add8` over `[0,160] +
//! [0,90]` can never set its carry-out (sum ≤ 250) even though bit 7 of
//! the first operand is unknown.
//!
//! ## Diagnostics
//!
//! Findings surface through the stable `P###` catalogue
//! ([`crate::pud::verify::DiagCode`]), all warning-severity:
//!
//! * **P009** — an output bit is provably constant under the analyzed
//!   ranges (and is not already a syntactic `Const` in the IR);
//! * **P010** — a gate is consumed syntactically but provably
//!   unobservable at any output (folded away or feeding only folded
//!   logic) — disjoint from P005, which flags *never-consumed* gates;
//! * **P011** — a carry/overflow output bit the value-interval
//!   refinement proves constant where the bit lattice cannot;
//! * **P012** — the plan admits a strictly smaller narrowed variant
//!   under the declared ranges.
//!
//! Under full-width ranges the whole built-in vocabulary analyzes
//! clean (asserted by `pudtune analyze` in CI) — nothing folds when
//! nothing is known.
//!
//! ## The narrowing contract
//!
//! [`crate::pud::plan::WorkloadPlan::narrowed`] consumes a verified
//! plan plus one [`OperandRange`] per operand and returns a plan that:
//!
//! * keeps the same op, operand count/width, and output count;
//! * produces **bit-identical outputs for every operand inside the
//!   declared ranges** (pinned by an exhaustive ≤ 6-bit suite and
//!   randomized add8/mul8 property tests) — outside the ranges the
//!   outputs are unspecified;
//! * contains only gates observable at an output, with folded
//!   constants/aliases substituted into surviving gate arguments and
//!   provably-constant output bits replaced by `Const` signals;
//! * is re-verified by the PR-7 charge-state verifier before it is
//!   returned (fresh death lists and peak via the compiler's own
//!   last-use analysis).
//!
//! Operands are validated against the declared ranges at execution
//! time ([`PudError::RangeViolation`]) — a narrowed plan is only ever
//! asked questions inside its contract.

use crate::pud::graph::{Gate, MajCircuit, Signal};
use crate::pud::logic::not;
use crate::pud::plan::{PudError, PudOp, WorkloadPlan};
use crate::pud::verify::{DiagCode, Diagnostic};
use crate::util::rng::Rng;
use std::fmt;

/// One wire bit in the ternary lattice: provably 0, provably 1, or
/// operand-dependent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BitVal {
    Zero,
    One,
    Top,
}

impl BitVal {
    /// The known constant, if any.
    pub fn known(self) -> Option<bool> {
        match self {
            BitVal::Zero => Some(false),
            BitVal::One => Some(true),
            BitVal::Top => None,
        }
    }

    fn of(b: bool) -> BitVal {
        if b {
            BitVal::One
        } else {
            BitVal::Zero
        }
    }
}

impl fmt::Display for BitVal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BitVal::Zero => write!(f, "0"),
            BitVal::One => write!(f, "1"),
            BitVal::Top => write!(f, "?"),
        }
    }
}

/// A declared inclusive value range `[lo, hi]` for one operand.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct OperandRange {
    pub lo: u64,
    pub hi: u64,
}

impl OperandRange {
    /// `[lo, hi]`, normalised so `lo <= hi`.
    pub fn new(lo: u64, hi: u64) -> Self {
        Self { lo: lo.min(hi), hi: lo.max(hi) }
    }

    /// The full range of a `width`-bit operand.
    pub fn full(width: usize) -> Self {
        let hi = if width >= 64 { u64::MAX } else { (1u64 << width) - 1 };
        Self { lo: 0, hi }
    }

    /// The singleton range `[v, v]`.
    pub fn exact(v: u64) -> Self {
        Self { lo: v, hi: v }
    }

    /// The tightest range covering every value in `vals` (empty input
    /// covers only 0).
    pub fn of_values(vals: &[u64]) -> Self {
        let lo = vals.iter().copied().min().unwrap_or(0);
        let hi = vals.iter().copied().max().unwrap_or(0);
        Self { lo, hi }
    }

    /// Whether `v` lies inside the range.
    pub fn contains(&self, v: u64) -> bool {
        self.lo <= v && v <= self.hi
    }

    /// Minimal bits covering every value in the range (`bitlen(hi)`;
    /// 0 for the singleton `[0, 0]`).
    pub fn bits(&self) -> usize {
        (64 - self.hi.leading_zeros()) as usize
    }

    /// Whether the range covers all of a `width`-bit operand.
    pub fn is_full(&self, width: usize) -> bool {
        *self == Self::full(width)
    }

    /// Lattice value of bit `i`: every value in `[lo, hi]` agrees on
    /// the bits above the most significant bit where `lo` and `hi`
    /// differ (the common prefix), so those bits are constant.
    pub fn bit(&self, i: usize) -> BitVal {
        if i >= 64 {
            return BitVal::Zero;
        }
        let diff = self.lo ^ self.hi;
        let first_unknown = 64 - diff.leading_zeros() as usize; // bits >= this are shared
        if i >= first_unknown {
            BitVal::of((self.hi >> i) & 1 == 1)
        } else {
            BitVal::Top
        }
    }

    /// Parse `"lo:hi"` (or a single `"v"` for an exact value).
    pub fn parse(s: &str) -> Result<Self, String> {
        let t = s.trim();
        let parse_u64 =
            |p: &str| p.trim().parse::<u64>().map_err(|_| format!("bad range bound '{p}'"));
        match t.split_once(':') {
            Some((lo, hi)) => Ok(Self::new(parse_u64(lo)?, parse_u64(hi)?)),
            None => Ok(Self::exact(parse_u64(t)?)),
        }
    }
}

impl fmt::Display for OperandRange {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.lo, self.hi)
    }
}

/// The cache key a set of operand ranges collapses to: the covering
/// bit-length of each operand ([`OperandRange::bits`]). Two requests
/// whose operands need the same bit-lengths share one narrowed plan —
/// the class widens each range to `[0, 2^bits - 1]`, a sound superset.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct RangeClass {
    widths: Vec<u8>,
}

impl RangeClass {
    /// The class covering `ranges`.
    pub fn of(ranges: &[OperandRange]) -> Self {
        Self { widths: ranges.iter().map(|r| r.bits().min(64) as u8).collect() }
    }

    /// The widened ranges this class stands for (`[0, 2^bits - 1]`
    /// per operand).
    pub fn ranges(&self) -> Vec<OperandRange> {
        self.widths.iter().map(|&b| OperandRange::full(b as usize)).collect()
    }

    /// Per-operand covering bit-lengths.
    pub fn widths(&self) -> &[u8] {
        &self.widths
    }

    /// Whether this class is strictly narrower than `op`'s declared
    /// operand width for at least one operand — the cheap pre-check
    /// serving paths use before paying for a narrowed compile.
    pub fn narrows(&self, op: &PudOp) -> bool {
        let w = op.operand_width();
        self.widths.len() == op.n_operands() && self.widths.iter().any(|&b| (b as usize) < w)
    }

    /// Short label for logs/bench cases (`"4x8"` for a 4-bit and an
    /// 8-bit operand).
    pub fn label(&self) -> String {
        let parts: Vec<String> = self.widths.iter().map(|b| b.to_string()).collect();
        parts.join("x")
    }
}

/// Flip a resolved signal's polarity.
fn neg(s: Signal) -> Signal {
    not(s)
}

fn gate_of(s: Signal) -> Option<usize> {
    match s {
        Signal::Gate(g) | Signal::NotGate(g) => Some(g),
        _ => None,
    }
}

/// Resolve a raw circuit signal to its abstract value: a constant, an
/// (unknown) input polarity, or a live-gate polarity. `abs` entries
/// are fully resolved by induction, so resolution is one step deep.
fn resolve(s: Signal, inputs: &[BitVal], abs: &[Signal]) -> Signal {
    match s {
        Signal::Const(_) => s,
        Signal::Input(i) => match inputs.get(i).copied().unwrap_or(BitVal::Top).known() {
            Some(b) => Signal::Const(b),
            None => s,
        },
        Signal::NotInput(i) => neg(resolve(Signal::Input(i), inputs, abs)),
        Signal::Gate(g) => abs[g],
        Signal::NotGate(g) => neg(abs[g]),
    }
}

/// Abstract MAJ transfer over resolved arguments: fold to a constant,
/// fold to an alias of a dominant root, or stay live as `Gate(gi)`.
fn fold_gate(gi: usize, args: &[Signal]) -> Signal {
    let m = args.len();
    let t = m / 2 + 1; // majority threshold (m odd)
    let mut ones = 0usize;
    let mut zeros = 0usize;
    // Positive/negative occurrence counts per canonical unknown root.
    let mut roots: Vec<(Signal, usize, usize)> = Vec::new();
    for &a in args {
        match a {
            Signal::Const(true) => ones += 1,
            Signal::Const(false) => zeros += 1,
            _ => {
                let (canon, negd) = match a {
                    Signal::NotInput(i) => (Signal::Input(i), true),
                    Signal::NotGate(g) => (Signal::Gate(g), true),
                    other => (other, false),
                };
                match roots.iter_mut().find(|(c, _, _)| *c == canon) {
                    Some((_, p, n)) => {
                        if negd {
                            *n += 1
                        } else {
                            *p += 1
                        }
                    }
                    None => roots.push((canon, usize::from(!negd), usize::from(negd))),
                }
            }
        }
    }
    // A complement pair (x, ¬x) is one guaranteed 1 and one guaranteed
    // 0 whatever x is; what survives is a signed leftover per root.
    let mut leftovers: Vec<(Signal, usize)> = Vec::new();
    for (canon, p, n) in roots {
        let pairs = p.min(n);
        ones += pairs;
        zeros += pairs;
        if p > n {
            leftovers.push((canon, p - n));
        } else if n > p {
            leftovers.push((neg(canon), n - p));
        }
    }
    let unknown: usize = leftovers.iter().map(|(_, k)| k).sum();
    if ones >= t {
        return Signal::Const(true);
    }
    if zeros >= t {
        return Signal::Const(false);
    }
    if ones + unknown < t {
        return Signal::Const(false);
    }
    if zeros + unknown < t {
        return Signal::Const(true);
    }
    // Dominant root: r's value alone decides the vote both ways —
    // r = 1 forces a majority of ones, and with r = 0 every other
    // unknown being 1 still falls short.
    for &(sig, k) in &leftovers {
        if ones + k >= t && ones + (unknown - k) < t {
            return sig;
        }
    }
    Signal::Gate(gi)
}

/// The forward pass over one circuit: per-gate abstract values,
/// resolved output signals, the semantic needed set and the syntactic
/// consumed set.
#[derive(Clone, Debug)]
pub struct CircuitAnalysis {
    /// Abstract value per gate. `Gate(g)` for gate `g` itself means
    /// "live"; anything else is the folded constant or alias.
    pub abs: Vec<Signal>,
    /// Output signals after folding (before interval refinement).
    pub outs: Vec<Signal>,
    /// Gates transitively observable at some output *through the
    /// folded dataflow*.
    pub needed: Vec<bool>,
    /// Gates syntactically consumed by a gate argument or an output
    /// (the complement of what P005 flags).
    pub consumed: Vec<bool>,
}

impl CircuitAnalysis {
    /// Lattice value of gate `g`'s output bit.
    pub fn gate_bit(&self, g: usize) -> BitVal {
        match self.abs[g] {
            Signal::Const(b) => BitVal::of(b),
            _ => BitVal::Top,
        }
    }

    /// Lattice value of output `j` (before interval refinement).
    pub fn out_bit(&self, j: usize) -> BitVal {
        match self.outs[j] {
            Signal::Const(b) => BitVal::of(b),
            _ => BitVal::Top,
        }
    }

    /// Number of gates the folded dataflow still needs.
    pub fn live_gates(&self) -> usize {
        self.needed.iter().filter(|&&n| n).count()
    }
}

/// Run the abstract interpreter over a bare circuit with the given
/// per-input bit lattice (`inputs.len()` may be short; missing bits
/// are `Top`).
pub fn analyze_circuit(circuit: &MajCircuit, inputs: &[BitVal]) -> CircuitAnalysis {
    let mut abs: Vec<Signal> = Vec::with_capacity(circuit.gates.len());
    for (gi, gate) in circuit.gates.iter().enumerate() {
        let args: Vec<Signal> =
            gate.args.iter().map(|&a| resolve(a, inputs, &abs)).collect();
        abs.push(fold_gate(gi, &args));
    }
    let outs: Vec<Signal> =
        circuit.outputs.iter().map(|&o| resolve(o, inputs, &abs)).collect();
    let mut consumed = vec![false; circuit.gates.len()];
    for gate in &circuit.gates {
        for &a in &gate.args {
            if let Some(g) = gate_of(a) {
                consumed[g] = true;
            }
        }
    }
    for &o in &circuit.outputs {
        if let Some(g) = gate_of(o) {
            consumed[g] = true;
        }
    }
    let needed = needed_gates(circuit, inputs, &abs, &outs);
    CircuitAnalysis { abs, outs, needed, consumed }
}

/// BFS from the (folded) outputs over resolved gate arguments: the
/// gates whose result can still influence an output.
fn needed_gates(
    circuit: &MajCircuit,
    inputs: &[BitVal],
    abs: &[Signal],
    outs: &[Signal],
) -> Vec<bool> {
    let mut needed = vec![false; circuit.gates.len()];
    let mut stack: Vec<usize> = outs.iter().filter_map(|&o| gate_of(o)).collect();
    while let Some(g) = stack.pop() {
        if needed[g] {
            continue;
        }
        needed[g] = true;
        for &a in &circuit.gates[g].args {
            if let Some(h) = gate_of(resolve(a, inputs, abs)) {
                if !needed[h] {
                    stack.push(h);
                }
            }
        }
    }
    needed
}

/// The per-input bit lattice an op's declared operand ranges induce
/// (operand-major, LSB first — the same layout
/// [`WorkloadPlan::encode_operands`] materialises).
pub fn input_bits(op: &PudOp, ranges: &[OperandRange]) -> Result<Vec<BitVal>, PudError> {
    let n = op.n_operands();
    if ranges.len() != n {
        return Err(PudError::ArityMismatch { expected: n, got: ranges.len() });
    }
    let w = op.operand_width();
    for (i, r) in ranges.iter().enumerate() {
        if !OperandRange::full(w).contains(r.hi) {
            return Err(PudError::RangeViolation {
                operand: i,
                value: r.hi,
                lo: 0,
                hi: OperandRange::full(w).hi,
            });
        }
    }
    let mut bits = Vec::with_capacity(n * w);
    for r in ranges {
        for b in 0..w {
            bits.push(r.bit(b));
        }
    }
    Ok(bits)
}

/// Value-interval of the op's decoded output under the declared
/// ranges, for the ops whose value semantics the analysis knows
/// (`Add`/`Mul` are monotone over unsigned ranges, so the interval
/// ends are the images of the range ends).
fn output_interval(op: &PudOp, ranges: &[OperandRange]) -> Option<OperandRange> {
    match op {
        PudOp::Add { .. } => Some(OperandRange::new(
            ranges[0].lo.saturating_add(ranges[1].lo),
            ranges[0].hi.saturating_add(ranges[1].hi),
        )),
        PudOp::Mul { .. } => Some(OperandRange::new(
            ranges[0].lo.saturating_mul(ranges[1].lo),
            ranges[0].hi.saturating_mul(ranges[1].hi),
        )),
        _ => None,
    }
}

/// Everything one plan analysis produced: per-bit verdicts, the
/// diagnostics, and the narrowed circuit (gates the folded dataflow
/// still needs, constants substituted).
#[derive(Clone, Debug)]
pub struct RangeReport {
    /// The analyzed op's label.
    pub op_label: String,
    /// The ranges the analysis ran under.
    pub ranges: Vec<OperandRange>,
    /// The forward pass (per-gate values, needed/consumed sets).
    pub analysis: CircuitAnalysis,
    /// Final per-output-bit verdicts (bit lattice ⊔ interval).
    pub out_bits: Vec<BitVal>,
    /// Per-output-bit verdicts from the bit lattice alone.
    pub lattice_out_bits: Vec<BitVal>,
    /// P009–P012 findings (all warning severity).
    pub diagnostics: Vec<Diagnostic>,
    /// Gate count of the analyzed circuit.
    pub gates: usize,
    /// The narrowed circuit: needed gates only, folded constants and
    /// aliases substituted, provably-constant output bits overridden.
    pub narrowed: MajCircuit,
}

impl RangeReport {
    /// Whether any finding carries `code`.
    pub fn has(&self, code: DiagCode) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }

    /// No findings at all (how the full-range vocabulary analyzes).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Gates the narrowed circuit retains.
    pub fn narrowed_gates(&self) -> usize {
        self.narrowed.gates.len()
    }

    /// Machine-readable rendering of the whole report.
    pub fn to_json(&self) -> String {
        let ranges: Vec<String> =
            self.ranges.iter().map(|r| format!("\"{r}\"")).collect();
        let bits: Vec<String> =
            self.out_bits.iter().map(|b| format!("\"{b}\"")).collect();
        let diags: Vec<String> = self.diagnostics.iter().map(|d| d.to_json()).collect();
        format!(
            "{{\"op\":\"{}\",\"ranges\":[{}],\"gates\":{},\"narrowed_gates\":{},\
             \"live_gates\":{},\"out_bits\":[{}],\"clean\":{},\"diagnostics\":[{}]}}",
            self.op_label,
            ranges.join(","),
            self.gates,
            self.narrowed_gates(),
            self.analysis.live_gates(),
            bits.join(","),
            self.is_clean(),
            diags.join(",")
        )
    }
}

/// Analyze a plan under declared per-operand ranges: run the forward
/// bit-lattice pass, refine the outputs with the value interval, build
/// the narrowed circuit, and emit P009–P012.
pub fn analyze_plan(
    plan: &WorkloadPlan,
    ranges: &[OperandRange],
) -> Result<RangeReport, PudError> {
    let inputs = input_bits(&plan.op, ranges)?;
    let circuit = &plan.circuit;
    let analysis = analyze_circuit(circuit, &inputs);

    // Per-output verdicts: the bit lattice, then the value-interval
    // refinement for the ops whose decoded-value semantics we know.
    let n_out = circuit.outputs.len();
    let lattice_out_bits: Vec<BitVal> = (0..n_out).map(|j| analysis.out_bit(j)).collect();
    let mut out_bits = lattice_out_bits.clone();
    let mut interval_bits: Vec<usize> = Vec::new();
    if let Some(iv) = output_interval(&plan.op, ranges) {
        for (j, slot) in out_bits.iter_mut().enumerate() {
            if slot.known().is_none() {
                if let Some(b) = iv.bit(j).known() {
                    *slot = BitVal::of(b);
                    interval_bits.push(j);
                }
            }
        }
    }

    let mut diagnostics = Vec::new();
    // P009: an output bit the lattice proves constant that is not
    // already a syntactic constant in the IR (mul1's high bit *is*
    // `Const(false)` by construction — nothing to report there).
    for (j, &bit) in lattice_out_bits.iter().enumerate() {
        if let Some(b) = bit.known() {
            if !matches!(circuit.outputs[j], Signal::Const(_)) {
                diagnostics.push(Diagnostic {
                    code: DiagCode::ConstantOutputBit,
                    gate: gate_of(circuit.outputs[j]),
                    row: None,
                    message: format!(
                        "output bit {j} of {} is provably {} for every operand in {}",
                        plan.op.label(),
                        u8::from(b),
                        render_ranges(ranges)
                    ),
                });
            }
        }
    }
    // P011: interval-only constant bits (the impossible carries).
    for &j in &interval_bits {
        diagnostics.push(Diagnostic {
            code: DiagCode::RangeOverflowImpossibleCarry,
            gate: gate_of(circuit.outputs[j]),
            row: None,
            message: format!(
                "output bit {j} of {} cannot fire: the value interval for operands in {} \
                 proves the carry impossible (bit lattice alone could not)",
                plan.op.label(),
                render_ranges(ranges)
            ),
        });
    }
    // P010: consumed but unobservable gates. Disjoint from P005 by
    // construction — P005 flags gates *nothing* consumes.
    for g in 0..circuit.gates.len() {
        if analysis.consumed[g] && !analysis.needed[g] {
            let why = match analysis.abs[g] {
                Signal::Const(b) => format!("folds to constant {}", u8::from(b)),
                Signal::Gate(h) if h == g => "feeds only folded logic".into(),
                alias => format!("folds to an alias of {alias:?}"),
            };
            diagnostics.push(Diagnostic {
                code: DiagCode::DeadGateByDataflow,
                gate: Some(g),
                row: None,
                message: format!(
                    "gate {g} is consumed but unobservable under operand ranges {}: {why}",
                    render_ranges(ranges)
                ),
            });
        }
    }

    let narrowed = narrowed_circuit(circuit, &inputs, &out_bits, &analysis);
    // P012: the narrowed variant is strictly smaller.
    if narrowed.gates.len() < circuit.gates.len() {
        diagnostics.push(Diagnostic {
            code: DiagCode::NarrowingOpportunity,
            gate: None,
            row: None,
            message: format!(
                "{} narrows from {} to {} gates under operand ranges {} \
                 (range class {})",
                plan.op.label(),
                circuit.gates.len(),
                narrowed.gates.len(),
                render_ranges(ranges),
                RangeClass::of(ranges).label()
            ),
        });
    }

    Ok(RangeReport {
        op_label: plan.op.label(),
        ranges: ranges.to_vec(),
        gates: circuit.gates.len(),
        analysis,
        out_bits,
        lattice_out_bits,
        diagnostics,
        narrowed,
    })
}

fn render_ranges(ranges: &[OperandRange]) -> String {
    let parts: Vec<String> = ranges.iter().map(|r| format!("[{},{}]", r.lo, r.hi)).collect();
    format!("({})", parts.join(", "))
}

/// Rebuild the circuit keeping only gates observable at an output:
/// folded constants/aliases substituted into surviving arguments,
/// provably-constant output bits overridden with `Const` signals.
/// Keeps `n_inputs` and the output count — only in-range behavior is
/// preserved.
fn narrowed_circuit(
    circuit: &MajCircuit,
    inputs: &[BitVal],
    out_bits: &[BitVal],
    analysis: &CircuitAnalysis,
) -> MajCircuit {
    // Outputs after overrides, then the needed set those outputs pin
    // (an interval-overridden output can strand further gates).
    let overridden: Vec<Signal> = analysis
        .outs
        .iter()
        .zip(out_bits)
        .map(|(&o, bit)| match bit.known() {
            Some(b) => Signal::Const(b),
            None => o,
        })
        .collect();
    let needed = needed_gates(circuit, inputs, &analysis.abs, &overridden);

    let mut nc = MajCircuit::new(circuit.n_inputs);
    let mut remap: Vec<Option<usize>> = vec![None; circuit.gates.len()];
    let remap_sig = |s: Signal, remap: &[Option<usize>]| -> Signal {
        match s {
            Signal::Gate(g) => Signal::Gate(remap[g].expect("needed gates emitted in order")),
            Signal::NotGate(g) => {
                Signal::NotGate(remap[g].expect("needed gates emitted in order"))
            }
            other => other,
        }
    };
    for (gi, gate) in circuit.gates.iter().enumerate() {
        if !needed[gi] {
            continue;
        }
        let args: Vec<Signal> = gate
            .args
            .iter()
            .map(|&a| remap_sig(resolve(a, inputs, &analysis.abs), &remap))
            .collect();
        let s = nc.push(Gate { args });
        let Signal::Gate(idx) = s else { unreachable!("push returns a gate signal") };
        remap[gi] = Some(idx);
    }
    for &o in &overridden {
        nc.output(remap_sig(o, &remap));
    }
    nc
}

/// Concrete cross-check of an analysis' claims: evaluate the original
/// and narrowed circuits on operand tuples inside the declared ranges
/// (exhaustively when the product of range sizes is ≤ `budget`,
/// else `budget` seeded samples) and collect every contradiction —
/// a claimed-constant output bit that varies, or a narrowed output
/// that disagrees with the original. An empty return is what the CI
/// `analyze-vocabulary` step asserts.
pub fn soundness_check(
    plan: &WorkloadPlan,
    report: &RangeReport,
    budget: usize,
    seed: u64,
) -> Vec<String> {
    let ranges = &report.ranges;
    let mut findings = Vec::new();
    let sizes: Vec<u64> = ranges.iter().map(|r| (r.hi - r.lo).saturating_add(1)).collect();
    let total: u128 = sizes.iter().map(|&s| s as u128).product();
    let exhaustive = total <= budget as u128;
    let n_cases = if exhaustive { total as usize } else { budget };
    let mut rng = Rng::new(seed);
    let w = plan.op.operand_width();
    for case in 0..n_cases {
        let vals: Vec<u64> = if exhaustive {
            let mut ix = case as u64;
            sizes
                .iter()
                .zip(ranges)
                .map(|(&s, r)| {
                    let v = r.lo + ix % s;
                    ix /= s;
                    v
                })
                .collect()
        } else {
            ranges
                .iter()
                .map(|r| r.lo + rng.below((r.hi - r.lo).saturating_add(1)))
                .collect()
        };
        let mut bits = Vec::with_capacity(plan.circuit.n_inputs);
        for &v in &vals {
            for b in 0..w {
                bits.push((v >> b) & 1 == 1);
            }
        }
        let original = plan.circuit.eval(&bits);
        let narrow = report.narrowed.eval(&bits);
        for (j, (&o, &n)) in original.iter().zip(&narrow).enumerate() {
            if o != n {
                findings.push(format!(
                    "{}: narrowed output bit {j} disagrees on operands {vals:?} \
                     (original {}, narrowed {})",
                    report.op_label,
                    u8::from(o),
                    u8::from(n)
                ));
            }
            if let Some(claimed) = report.out_bits[j].known() {
                if o != claimed {
                    findings.push(format!(
                        "{}: output bit {j} claimed constant {} but is {} on operands {vals:?}",
                        report.op_label,
                        u8::from(claimed),
                        u8::from(o)
                    ));
                }
            }
        }
        if findings.len() > 16 {
            break; // enough evidence; don't flood the report
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::plan::BitwiseOp;

    fn plan(op: PudOp) -> WorkloadPlan {
        WorkloadPlan::compile(op).unwrap()
    }

    #[test]
    fn range_bits_follow_the_common_prefix() {
        let r = OperandRange::new(8, 15); // 1xxx
        assert_eq!(r.bit(3), BitVal::One);
        assert_eq!(r.bit(2), BitVal::Top);
        assert_eq!(r.bit(4), BitVal::Zero);
        let e = OperandRange::exact(5); // 101 exactly
        assert_eq!(e.bit(0), BitVal::One);
        assert_eq!(e.bit(1), BitVal::Zero);
        assert_eq!(e.bit(2), BitVal::One);
        assert_eq!(OperandRange::full(4).bit(3), BitVal::Top);
        assert_eq!(OperandRange::full(4).bit(4), BitVal::Zero);
        assert_eq!(OperandRange::new(9, 3), OperandRange::new(3, 9), "normalised");
    }

    #[test]
    fn range_parse_and_labels() {
        assert_eq!(OperandRange::parse("0:15"), Ok(OperandRange::new(0, 15)));
        assert_eq!(OperandRange::parse(" 7 "), Ok(OperandRange::exact(7)));
        assert!(OperandRange::parse("a:b").is_err());
        assert_eq!(OperandRange::new(0, 15).to_string(), "0:15");
        let class = RangeClass::of(&[OperandRange::new(0, 15), OperandRange::new(0, 255)]);
        assert_eq!(class.label(), "4x8");
        assert_eq!(class.widths(), &[4, 8]);
        assert!(class.narrows(&PudOp::Add { width: 8 }));
        assert!(!class.narrows(&PudOp::Add { width: 4 }));
        assert!(!RangeClass::of(&[OperandRange::full(8); 2]).narrows(&PudOp::Add { width: 8 }));
    }

    #[test]
    fn fold_rules_cover_the_canonical_identities() {
        let x = Signal::Input(0);
        let y = Signal::Input(1);
        // Constant votes.
        assert_eq!(
            fold_gate(0, &[Signal::Const(true), Signal::Const(true), x]),
            Signal::Const(true)
        );
        assert_eq!(
            fold_gate(0, &[Signal::Const(false), Signal::Const(false), x]),
            Signal::Const(false)
        );
        // Dominant roots.
        assert_eq!(fold_gate(0, &[Signal::Const(false), Signal::Const(true), x]), x);
        assert_eq!(fold_gate(0, &[x, x, y]), x);
        assert_eq!(
            fold_gate(
                0,
                &[Signal::Const(false), Signal::Const(false), x, Signal::Const(true), Signal::Const(true)]
            ),
            x
        );
        // Complement pairs: MAJ3(x, ¬x, y) = y.
        assert_eq!(fold_gate(0, &[x, neg(x), y]), y);
        // MAJ5(a, b, c, ¬c, ¬c): one pair cancels, leaves MAJ-ish over
        // a, b, ¬c with one guaranteed 1 and 0 — no fold.
        let c = Signal::Input(2);
        assert_eq!(fold_gate(7, &[x, y, c, neg(c), neg(c)]), Signal::Gate(7));
        // Unknown-but-insufficient: MAJ5(0, 0, 0, x, y) = 0.
        let zero = Signal::Const(false);
        assert_eq!(fold_gate(0, &[zero, zero, zero, x, y]), Signal::Const(false));
    }

    #[test]
    fn full_ranges_fold_nothing_and_are_clean() {
        for op in PudOp::vocabulary(6) {
            let p = plan(op.clone());
            let full = vec![OperandRange::full(op.operand_width()); op.n_operands()];
            let report = analyze_plan(&p, &full).unwrap();
            assert!(report.is_clean(), "{}: {:?}", op.label(), report.diagnostics);
            assert_eq!(
                report.narrowed_gates(),
                report.gates,
                "{}: full ranges must not narrow",
                op.label()
            );
        }
    }

    #[test]
    fn skewed_add_folds_high_bits() {
        let p = plan(PudOp::Add { width: 8 });
        let ranges = [OperandRange::new(0, 15), OperandRange::new(0, 15)];
        let report = analyze_plan(&p, &ranges).unwrap();
        // Sum fits in 5 bits: bits 5..=8 are provably zero.
        for j in 5..=8 {
            assert_eq!(report.out_bits[j], BitVal::Zero, "bit {j}");
        }
        assert_eq!(report.out_bits[0], BitVal::Top);
        assert!(report.has(DiagCode::ConstantOutputBit));
        assert!(report.has(DiagCode::DeadGateByDataflow));
        assert!(report.has(DiagCode::NarrowingOpportunity));
        assert!(
            report.narrowed_gates() < report.gates,
            "{} -> {}",
            report.gates,
            report.narrowed_gates()
        );
        assert!(soundness_check(&p, &report, 4096, 7).is_empty());
    }

    #[test]
    fn interval_beats_the_bit_lattice_on_impossible_carries() {
        // add8 over [0,160] + [0,90]: bit 7 of the first operand is
        // unknown, so the lattice cannot kill the carry-out — but the
        // value interval (sum <= 250 < 256) can.
        let p = plan(PudOp::Add { width: 8 });
        let ranges = [OperandRange::new(0, 160), OperandRange::new(0, 90)];
        let report = analyze_plan(&p, &ranges).unwrap();
        assert_eq!(report.lattice_out_bits[8], BitVal::Top);
        assert_eq!(report.out_bits[8], BitVal::Zero);
        assert!(report.has(DiagCode::RangeOverflowImpossibleCarry));
        assert!(soundness_check(&p, &report, 2048, 11).is_empty());
    }

    #[test]
    fn exact_ranges_fold_to_constants() {
        let p = plan(PudOp::Add { width: 4 });
        let ranges = [OperandRange::exact(5), OperandRange::exact(9)];
        let report = analyze_plan(&p, &ranges).unwrap();
        let decoded = report
            .out_bits
            .iter()
            .enumerate()
            .fold(0u64, |acc, (j, b)| acc | (u64::from(b.known().unwrap()) << j));
        assert_eq!(decoded, 14);
        assert_eq!(report.narrowed_gates(), 0, "a constant plan needs no gates");
        assert!(soundness_check(&p, &report, 16, 3).is_empty());
    }

    #[test]
    fn bitwise_ops_fold_under_exact_single_bits() {
        let and = plan(PudOp::Bitwise(BitwiseOp::And));
        let ranges = [OperandRange::exact(0), OperandRange::full(1)];
        let report = analyze_plan(&and, &ranges).unwrap();
        assert_eq!(report.out_bits[0], BitVal::Zero);
        assert!(report.has(DiagCode::ConstantOutputBit));
        assert!(soundness_check(&and, &report, 8, 1).is_empty());
        // OR with a known 1 is constant 1.
        let or = plan(PudOp::Bitwise(BitwiseOp::Or));
        let report =
            analyze_plan(&or, &[OperandRange::exact(1), OperandRange::full(1)]).unwrap();
        assert_eq!(report.out_bits[0], BitVal::One);
    }

    #[test]
    fn syntactic_const_outputs_do_not_fire_p009() {
        // mul1's high output bit is a literal `Const(false)` in the IR;
        // P009 must only report *discovered* constants.
        let p = plan(PudOp::Mul { width: 1 });
        let full = vec![OperandRange::full(1); 2];
        let report = analyze_plan(&p, &full).unwrap();
        assert!(!report.has(DiagCode::ConstantOutputBit), "{:?}", report.diagnostics);
        assert!(report.is_clean());
    }

    #[test]
    fn bad_ranges_are_typed_errors() {
        let p = plan(PudOp::Add { width: 4 });
        let err = analyze_plan(&p, &[OperandRange::full(4)]).unwrap_err();
        assert!(matches!(err, PudError::ArityMismatch { expected: 2, got: 1 }));
        let err =
            analyze_plan(&p, &[OperandRange::new(0, 99), OperandRange::full(4)]).unwrap_err();
        assert!(matches!(err, PudError::RangeViolation { operand: 0, value: 99, .. }), "{err:?}");
    }

    #[test]
    fn report_renders_json() {
        let p = plan(PudOp::Add { width: 2 });
        let report =
            analyze_plan(&p, &[OperandRange::exact(1), OperandRange::full(2)]).unwrap();
        let j = report.to_json();
        assert!(j.contains("\"op\":\"add2\""), "{j}");
        assert!(j.contains("\"ranges\":[\"1:1\",\"0:3\"]"), "{j}");
        assert!(j.contains("\"gates\":"), "{j}");
        assert!(j.contains("\"narrowed_gates\":"), "{j}");
        assert!(j.contains("\"diagnostics\":["), "{j}");
    }
}
