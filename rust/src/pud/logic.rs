//! Boolean logic from majority gates (Ambit/ComputeDRAM construction):
//! `AND(a,b) = MAJ3(a,b,0)`, `OR(a,b) = MAJ3(a,b,1)`; NOT is an
//! inverted write-back through the column interface.

use crate::pud::graph::{Gate, MajCircuit, Signal};

/// Append `AND(a, b)` to a circuit.
pub fn and(c: &mut MajCircuit, a: Signal, b: Signal) -> Signal {
    c.push(Gate::maj3(a, b, Signal::Const(false)))
}

/// Append `OR(a, b)` to a circuit.
pub fn or(c: &mut MajCircuit, a: Signal, b: Signal) -> Signal {
    c.push(Gate::maj3(a, b, Signal::Const(true)))
}

/// Negate a signal (free at the IR level; costed as a NOT op when the
/// negation must be materialised on a row).
pub fn not(s: Signal) -> Signal {
    match s {
        Signal::Input(i) => Signal::NotInput(i),
        Signal::NotInput(i) => Signal::Input(i),
        Signal::Gate(g) => Signal::NotGate(g),
        Signal::NotGate(g) => Signal::Gate(g),
        Signal::Const(b) => Signal::Const(!b),
    }
}

/// XOR via majority gates: `a ^ b = MAJ3(AND(a,¬b), AND(¬a,b), 1)`…
/// implemented as `OR(AND(a,¬b), AND(¬a,b))` (3 MAJ3).
pub fn xor(c: &mut MajCircuit, a: Signal, b: Signal) -> Signal {
    let t0 = and(c, a, not(b));
    let t1 = and(c, not(a), b);
    or(c, t0, t1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_input(f: impl Fn(&mut MajCircuit, Signal, Signal) -> Signal) -> MajCircuit {
        let mut c = MajCircuit::new(2);
        let s = f(&mut c, Signal::Input(0), Signal::Input(1));
        c.output(s);
        c
    }

    #[test]
    fn and_table() {
        let c = two_input(and);
        for (a, b) in [(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(c.eval(&[a, b]), vec![a && b]);
        }
    }

    #[test]
    fn or_table() {
        let c = two_input(or);
        for (a, b) in [(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(c.eval(&[a, b]), vec![a || b]);
        }
    }

    #[test]
    fn xor_table() {
        let c = two_input(xor);
        for (a, b) in [(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(c.eval(&[a, b]), vec![a ^ b]);
        }
    }

    #[test]
    fn not_is_involutive() {
        let s = Signal::Input(2);
        assert_eq!(not(not(s)), s);
        assert_eq!(not(Signal::Const(true)), Signal::Const(false));
    }
}
