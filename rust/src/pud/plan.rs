//! Workload vocabulary and one-time compilation.
//!
//! The paper's headline arithmetic results (Table I: 1.88×/1.89×
//! add/multiply throughput from more error-free columns) treat PUD
//! operations as *schedulable primitives*, not ad-hoc scripts. This
//! module is the typed half of that story:
//!
//! * [`PudOp`] — the operation vocabulary a serving system accepts:
//!   ripple-carry addition, array multiplication, boolean logic,
//!   majority reduction, or an arbitrary [`MajCircuit`];
//! * [`WorkloadPlan`] — one op **compiled once**: circuit synthesis,
//!   last-use analysis (per-gate death lists), the exact peak
//!   scratch-row count the executor will reach, and the op/ACT cost
//!   summary the throughput model prices. A plan holds no subarray
//!   state, so it is reusable and cacheable across banks — build it
//!   once, wrap it in an `Arc`, and hand it to every
//!   [`crate::calib::engine::ComputeRequest`];
//! * [`PudError`] — the typed failure surface that replaces the old
//!   panicking asserts: a malformed request degrades one bank instead
//!   of poisoning the worker pool.
//!
//! Execution lives in [`crate::pud::exec::run_plan`]; batch dispatch
//! across banks/backends in [`crate::calib::engine::ComputeEngine`].

use crate::pud::adder::ripple_adder;
use crate::pud::graph::{CircuitCost, Gate, MajCircuit, Signal};
use crate::pud::multiplier::array_multiplier;
use std::collections::{HashMap, HashSet};
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, OnceLock};

/// Why a PUD workload request could not be planned or executed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PudError {
    /// Operand count does not match what the circuit consumes.
    ArityMismatch { expected: usize, got: usize },
    /// An operand / calibration / mask width disagrees with the
    /// subarray's column count (or with the other operands).
    WidthMismatch { expected: usize, got: usize },
    /// The circuit needs more simultaneous scratch rows than the
    /// subarray's data region provides.
    RowBudgetExceeded { needed: usize, available: usize },
    /// The circuit itself is invalid (bad gate arity, dangling signal
    /// reference, unsupported shape).
    MalformedCircuit(String),
    /// The static charge-state verifier ([`crate::pud::verify`])
    /// rejected the plan; `code` is the stable `P###` diagnostic code
    /// and `message` the rendered diagnostic (with fix hint).
    Verification { code: &'static str, message: String },
    /// Admission control rejected the request: the serve path already
    /// holds its configured bound of in-flight requests (backpressure
    /// — the caller should retry once in-flight work completes).
    Overloaded { inflight: usize, limit: usize },
    /// The service is draining (or shut down) and admits no new work;
    /// in-flight requests still complete.
    Draining,
    /// An operand (or a declared range bound) falls outside the range
    /// contract in force — a width-narrowed plan is only
    /// bit-identical to the original *inside* its declared ranges, so
    /// out-of-range operands are rejected rather than miscomputed.
    RangeViolation { operand: usize, value: u64, lo: u64, hi: u64 },
}

impl fmt::Display for PudError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PudError::ArityMismatch { expected, got } => {
                write!(f, "operand arity mismatch: expected {expected} inputs, got {got}")
            }
            PudError::WidthMismatch { expected, got } => {
                write!(f, "operand width mismatch: expected {expected} columns, got {got}")
            }
            PudError::RowBudgetExceeded { needed, available } => {
                write!(
                    f,
                    "row budget exceeded: circuit needs {needed} scratch rows, \
                     subarray has {available}"
                )
            }
            PudError::MalformedCircuit(msg) => write!(f, "malformed circuit: {msg}"),
            PudError::Verification { code, message } => {
                write!(f, "plan rejected by verifier ({code}): {message}")
            }
            PudError::Overloaded { inflight, limit } => {
                write!(
                    f,
                    "service overloaded: {inflight} requests in flight \
                     (admission bound {limit}); retry after in-flight work completes"
                )
            }
            PudError::Draining => {
                write!(f, "service is draining and admits no new work")
            }
            PudError::RangeViolation { operand, value, lo, hi } => {
                write!(
                    f,
                    "operand {operand} value {value} violates the declared range [{lo}, {hi}]"
                )
            }
        }
    }
}

impl std::error::Error for PudError {}

/// Bitwise boolean operations (Ambit/ComputeDRAM constructions over
/// constant-biased MAJ3 and inverted write-back).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BitwiseOp {
    And,
    Or,
    Not,
}

/// A schedulable PUD workload.
///
/// Value-level operands are per-column unsigned integers; `Add`/`Mul`
/// consume two `width`-bit operands per column, everything else
/// consumes single-bit operands.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PudOp {
    /// `width`-bit ripple-carry addition (outputs `width + 1` bits).
    Add { width: usize },
    /// `width`×`width`-bit array multiplication (outputs `2 * width`).
    Mul { width: usize },
    /// Single-bit boolean logic.
    Bitwise(BitwiseOp),
    /// One MAJ-m majority vote over m single-bit operands (m ∈ {3, 5}).
    MajReduce { m: usize },
    /// An arbitrary caller-supplied majority circuit (validated at
    /// compile time; single-bit operands, one per circuit input).
    Custom(MajCircuit),
}

impl PudOp {
    /// Parse a CLI-style op name: `add8`, `mul4`, `and`, `or`, `not`,
    /// `maj3`, `maj5`.
    pub fn parse(name: &str) -> Option<PudOp> {
        let t = name.trim().to_ascii_lowercase();
        match t.as_str() {
            "and" => Some(PudOp::Bitwise(BitwiseOp::And)),
            "or" => Some(PudOp::Bitwise(BitwiseOp::Or)),
            "not" => Some(PudOp::Bitwise(BitwiseOp::Not)),
            "maj3" => Some(PudOp::MajReduce { m: 3 }),
            "maj5" => Some(PudOp::MajReduce { m: 5 }),
            _ => {
                if let Some(w) = t.strip_prefix("add") {
                    w.parse().ok().map(|width| PudOp::Add { width })
                } else if let Some(w) = t.strip_prefix("mul") {
                    w.parse().ok().map(|width| PudOp::Mul { width })
                } else {
                    None
                }
            }
        }
    }

    /// [`PudOp::parse`] with a CLI-grade error: the failure message
    /// enumerates the full op vocabulary so callers (e.g. `pudtune run
    /// --op` and `pudtune campaign --op`) never have to maintain their
    /// own copy of the list.
    pub fn parse_or_list(name: &str) -> Result<PudOp, String> {
        PudOp::parse(name).ok_or_else(|| {
            format!(
                "unknown op '{}'; valid ops: add<W> (W in 1..=63), \
                 mul<W> (W in 1..=32), and, or, not, maj3, maj5",
                name.trim()
            )
        })
    }

    /// Short name for logs/benches (`add8`, `mul4`, `maj5`, ...).
    pub fn label(&self) -> String {
        match self {
            PudOp::Add { width } => format!("add{width}"),
            PudOp::Mul { width } => format!("mul{width}"),
            PudOp::Bitwise(BitwiseOp::And) => "and".into(),
            PudOp::Bitwise(BitwiseOp::Or) => "or".into(),
            PudOp::Bitwise(BitwiseOp::Not) => "not".into(),
            PudOp::MajReduce { m } => format!("maj{m}"),
            PudOp::Custom(_) => "custom".into(),
        }
    }

    /// Value-level operands the op consumes per column.
    pub fn n_operands(&self) -> usize {
        match self {
            PudOp::Add { .. } | PudOp::Mul { .. } => 2,
            PudOp::Bitwise(BitwiseOp::Not) => 1,
            PudOp::Bitwise(_) => 2,
            PudOp::MajReduce { m } => *m,
            PudOp::Custom(c) => c.n_inputs,
        }
    }

    /// Bits per value-level operand.
    pub fn operand_width(&self) -> usize {
        match self {
            PudOp::Add { width } | PudOp::Mul { width } => *width,
            _ => 1,
        }
    }

    /// Synthesise the majority circuit implementing the op.
    pub fn circuit(&self) -> Result<MajCircuit, PudError> {
        match self {
            PudOp::Add { width } => {
                require_width(*width, 63, "add")?;
                Ok(ripple_adder(*width))
            }
            PudOp::Mul { width } => {
                require_width(*width, 32, "mul")?;
                Ok(array_multiplier(*width))
            }
            PudOp::Bitwise(BitwiseOp::And) => {
                let mut c = MajCircuit::new(2);
                let g = c.try_push(Gate::maj3(
                    Signal::Input(0),
                    Signal::Input(1),
                    Signal::Const(false),
                ))?;
                c.try_output(g)?;
                Ok(c)
            }
            PudOp::Bitwise(BitwiseOp::Or) => {
                let mut c = MajCircuit::new(2);
                let g = c.try_push(Gate::maj3(
                    Signal::Input(0),
                    Signal::Input(1),
                    Signal::Const(true),
                ))?;
                c.try_output(g)?;
                Ok(c)
            }
            PudOp::Bitwise(BitwiseOp::Not) => {
                let mut c = MajCircuit::new(1);
                c.try_output(Signal::NotInput(0))?;
                Ok(c)
            }
            PudOp::MajReduce { m: 3 } => {
                let mut c = MajCircuit::new(3);
                let g = c.try_push(Gate::maj3(
                    Signal::Input(0),
                    Signal::Input(1),
                    Signal::Input(2),
                ))?;
                c.try_output(g)?;
                Ok(c)
            }
            PudOp::MajReduce { m: 5 } => {
                let mut c = MajCircuit::new(5);
                let g = c.try_push(Gate::maj5(
                    Signal::Input(0),
                    Signal::Input(1),
                    Signal::Input(2),
                    Signal::Input(3),
                    Signal::Input(4),
                ))?;
                c.try_output(g)?;
                Ok(c)
            }
            PudOp::MajReduce { m } => Err(PudError::MalformedCircuit(format!(
                "MAJ{m} is not reducible under 8-row SiMRA (m must be 3 or 5)"
            ))),
            PudOp::Custom(c) => {
                c.validate()?;
                Ok(c.clone())
            }
        }
    }

    /// The whole built-in op vocabulary, arithmetic widths capped at
    /// `max_width` (and at each op's own hard limit). This is the set
    /// `pudtune lint` and the verifier property tests sweep.
    pub fn vocabulary(max_width: usize) -> Vec<PudOp> {
        let mut v = vec![
            PudOp::Bitwise(BitwiseOp::And),
            PudOp::Bitwise(BitwiseOp::Or),
            PudOp::Bitwise(BitwiseOp::Not),
            PudOp::MajReduce { m: 3 },
            PudOp::MajReduce { m: 5 },
        ];
        for width in 1..=max_width.min(63) {
            v.push(PudOp::Add { width });
        }
        for width in 1..=max_width.min(32) {
            v.push(PudOp::Mul { width });
        }
        v
    }
}

fn require_width(width: usize, max: usize, what: &str) -> Result<(), PudError> {
    if width < 1 || width > max {
        return Err(PudError::MalformedCircuit(format!(
            "{what} width must be 1..={max}, got {width}"
        )));
    }
    Ok(())
}

/// Canonical liveness key: a signal and its negation share a last use
/// (the executor releases both polarities' rows together).
fn canonical(s: Signal) -> Signal {
    match s {
        Signal::NotInput(i) => Signal::Input(i),
        Signal::NotGate(g) => Signal::Gate(g),
        other => other,
    }
}

/// A [`PudOp`] compiled for execution: the synthesised circuit, the
/// per-gate death lists from last-use analysis, the exact scratch-row
/// high-water mark, and the command-cost summary. Plans are immutable
/// and bank-agnostic — compile once, share via `Arc` across every bank
/// and batch. (A `Custom` plan keeps the caller's circuit in `op` and
/// the executable copy in `circuit` — a few KB per plan, paid once at
/// compile time.)
#[derive(Clone, Debug)]
pub struct WorkloadPlan {
    pub op: PudOp,
    pub circuit: MajCircuit,
    /// Gate/NOT counts for the throughput model
    /// ([`crate::analysis::throughput::ThroughputModel::workload_ops`]).
    pub cost: CircuitCost,
    /// Exact peak simultaneous scratch rows the executor allocates
    /// (inputs + live wires + materialised negations).
    pub peak_rows: usize,
    /// Per-gate lists of canonical signals whose last consumer is that
    /// gate — the executor releases their rows right after it fires.
    deaths: Vec<Vec<Signal>>,
    /// Set only by [`WorkloadPlan::compile`] after the static verifier
    /// ([`crate::pud::verify`]) passed its output — the admission
    /// layers trust it and skip re-verification.
    verified: bool,
    /// Lazily-built canonical lowering ([`WorkloadPlan::lowered`]).
    /// Cloning a plan shares the already-computed lowering.
    lowered: OnceLock<Arc<crate::pud::verify::LoweredPlan>>,
}

impl WorkloadPlan {
    /// Compile an op: synthesise + validate the circuit, run last-use
    /// analysis and the allocation dry-run, price the gates — then run
    /// the static charge-state verifier on the result. The self-check
    /// pins `analyse` against the verifier's independent liveness and
    /// allocation replay on every compile; an error-severity diagnostic
    /// fails compilation as [`PudError::Verification`].
    pub fn compile(op: PudOp) -> Result<Self, PudError> {
        let circuit = op.circuit()?;
        if circuit.outputs.len() > 64 {
            return Err(PudError::MalformedCircuit(format!(
                "{} outputs do not fit the 64-bit value decode",
                circuit.outputs.len()
            )));
        }
        let (deaths, peak_rows) = analyse(&circuit);
        let mut plan = Self::assemble(op, circuit, deaths, peak_rows);
        let report = crate::pud::verify::verify_plan(&plan);
        if let Some(d) = report.errors().next() {
            return Err(d.clone().into());
        }
        plan.verified = true;
        Ok(plan)
    }

    /// Plan an arbitrary circuit (sugar for [`PudOp::Custom`]).
    pub fn from_circuit(circuit: MajCircuit) -> Result<Self, PudError> {
        Self::compile(PudOp::Custom(circuit))
    }

    /// Width-narrow a verified plan to declared per-operand ranges
    /// (see `pud::ranges` for the contract): run the bit-level range
    /// analysis, keep only gates observable at an output, substitute
    /// folded constants/aliases, replace provably-constant output bits
    /// with `Const` signals — then recompile the result through the
    /// same last-use analysis and charge-state verifier as
    /// [`WorkloadPlan::compile`]. The narrowed plan keeps the op,
    /// operand layout and output count, and is bit-identical to `self`
    /// for every operand inside `ranges`.
    ///
    /// Returns a clone of `self` when the analysis finds nothing to
    /// strip; refuses unverified plans (narrowing trusts the circuit).
    pub fn narrowed(
        &self,
        ranges: &[crate::pud::ranges::OperandRange],
    ) -> Result<Self, PudError> {
        if !self.verified {
            return Err(PudError::Verification {
                code: "P007",
                message: "narrowing requires a verified plan; compile it first".into(),
            });
        }
        let report = crate::pud::ranges::analyze_plan(self, ranges)?;
        if report.narrowed_gates() == self.circuit.gates.len() {
            return Ok(self.clone());
        }
        let circuit = report.narrowed;
        circuit.validate()?;
        let (deaths, peak_rows) = analyse(&circuit);
        let mut plan = Self::assemble(self.op.clone(), circuit, deaths, peak_rows);
        let verify = crate::pud::verify::verify_plan(&plan);
        if let Some(d) = verify.errors().next() {
            return Err(d.clone().into());
        }
        plan.verified = true;
        Ok(plan)
    }

    /// Assemble a plan from raw parts **without** compiling or
    /// verifying — the entry point for verifier tooling and mutation
    /// tests that need to represent ill-formed plans. The result is
    /// never marked verified, so every admission layer re-verifies it.
    pub fn assemble(
        op: PudOp,
        circuit: MajCircuit,
        deaths: Vec<Vec<Signal>>,
        peak_rows: usize,
    ) -> Self {
        let cost = circuit.cost();
        Self { op, circuit, cost, peak_rows, deaths, verified: false, lowered: OnceLock::new() }
    }

    /// Whether this plan came out of [`WorkloadPlan::compile`] with a
    /// clean verifier report (admission layers skip re-verification).
    pub fn is_verified(&self) -> bool {
        self.verified
    }

    /// The canonical backend-neutral lowering of this plan — the step
    /// stream every engine interprets, which is the same artifact the
    /// static verifier checks ([`crate::pud::verify::lower_plan_full`]).
    /// Computed on first use and cached for the plan's lifetime;
    /// clones of the plan share the cached lowering.
    pub fn lowered(&self) -> Result<Arc<crate::pud::verify::LoweredPlan>, PudError> {
        if let Some(l) = self.lowered.get() {
            return Ok(l.clone());
        }
        let l = Arc::new(crate::pud::verify::lower_plan_full(self).map_err(PudError::from)?);
        Ok(self.lowered.get_or_init(|| l).clone())
    }

    /// Structural fingerprint over everything execution depends on:
    /// the op's identity/arity/width, the circuit (inputs, gates,
    /// outputs), the death lists and the compiled peak. Two plans with
    /// equal fingerprints lower to the same step program, so batched
    /// engines group requests by it and the admission memo keys on it.
    pub fn fingerprint(&self) -> u64 {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.op.label().hash(&mut h);
        self.op.n_operands().hash(&mut h);
        self.op.operand_width().hash(&mut h);
        self.circuit.n_inputs.hash(&mut h);
        self.circuit.gates.len().hash(&mut h);
        for gate in &self.circuit.gates {
            gate.args.hash(&mut h);
        }
        self.circuit.outputs.hash(&mut h);
        self.deaths.hash(&mut h);
        self.peak_rows.hash(&mut h);
        h.finish()
    }

    /// Canonical signals dying at gate `gi`.
    pub fn deaths(&self, gi: usize) -> &[Signal] {
        &self.deaths[gi]
    }

    /// All death lists, indexed by gate (one list per gate).
    pub fn death_lists(&self) -> &[Vec<Signal>] {
        &self.deaths
    }

    /// Encode per-column operand values into the circuit's input
    /// bit-planes (operand-major, LSB first — the layout
    /// `ripple_adder`/`array_multiplier` consume).
    pub fn encode_operands(&self, operands: &[Vec<u64>]) -> Result<Vec<Vec<u8>>, PudError> {
        let n = self.op.n_operands();
        if operands.len() != n {
            return Err(PudError::ArityMismatch { expected: n, got: operands.len() });
        }
        let cols = operands.first().map(|v| v.len()).unwrap_or(0);
        for v in operands {
            if v.len() != cols {
                return Err(PudError::WidthMismatch { expected: cols, got: v.len() });
            }
        }
        let w = self.op.operand_width();
        let mut planes = Vec::with_capacity(self.circuit.n_inputs);
        for v in operands {
            for bit in 0..w {
                planes.push(v.iter().map(|&x| ((x >> bit) & 1) as u8).collect());
            }
        }
        Ok(planes)
    }

    /// Decode one column's output bit-planes into a value (LSB first).
    pub fn decode_output(&self, outputs: &[Vec<u8>], col: usize) -> u64 {
        outputs
            .iter()
            .enumerate()
            .fold(0u64, |acc, (bit, out)| acc | ((out[col] & 1) as u64) << bit)
    }

    /// Column-wise software golden model for broadcast operands: the
    /// expected output value of each of `cols` columns. Compute it
    /// once per served batch — it depends only on the plan and the
    /// operands, never on the bank. A 0-operand plan broadcasts its
    /// constant result to every column.
    pub fn golden_outputs(&self, operands: &[Vec<u64>], cols: usize) -> Result<Vec<u64>, PudError> {
        let n = self.op.n_operands();
        if operands.len() != n {
            return Err(PudError::ArityMismatch { expected: n, got: operands.len() });
        }
        for v in operands {
            if v.len() != cols {
                return Err(PudError::WidthMismatch { expected: cols, got: v.len() });
            }
        }
        if operands.is_empty() {
            return Ok(vec![self.golden(&[])?; cols]);
        }
        let mut vals = vec![0u64; n];
        (0..cols)
            .map(|c| {
                for (slot, v) in vals.iter_mut().zip(operands) {
                    *slot = v[c];
                }
                self.golden(&vals)
            })
            .collect()
    }

    /// Software golden model: the op on one column's operand values via
    /// [`MajCircuit::eval`].
    pub fn golden(&self, vals: &[u64]) -> Result<u64, PudError> {
        let n = self.op.n_operands();
        if vals.len() != n {
            return Err(PudError::ArityMismatch { expected: n, got: vals.len() });
        }
        let w = self.op.operand_width();
        let mut ins = Vec::with_capacity(self.circuit.n_inputs);
        for &v in vals {
            for bit in 0..w {
                ins.push((v >> bit) & 1 == 1);
            }
        }
        let out = self.circuit.try_eval(&ins)?;
        Ok(out
            .iter()
            .enumerate()
            .fold(0u64, |acc, (i, &b)| acc | (b as u64) << i))
    }
}

/// Last-use analysis + allocation dry-run: death lists and the exact
/// peak row count, mirroring `exec::run_plan`'s allocation discipline
/// (inputs up front, NOT rows materialised at first use, one result
/// row per gate, both polarities released at the canonical last use).
fn analyse(circuit: &MajCircuit) -> (Vec<Vec<Signal>>, usize) {
    let mut last_use: HashMap<Signal, usize> = HashMap::new();
    for (gi, gate) in circuit.gates.iter().enumerate() {
        for &s in &gate.args {
            last_use.insert(canonical(s), gi);
        }
    }
    for &s in &circuit.outputs {
        last_use.insert(canonical(s), usize::MAX); // outputs live forever
    }
    let mut deaths: Vec<Vec<Signal>> = vec![Vec::new(); circuit.gates.len()];
    for (&sig, &lu) in &last_use {
        if lu != usize::MAX {
            deaths[lu].push(sig);
        }
    }

    let mut live = circuit.n_inputs;
    let mut peak = live;
    let mut gate_live = vec![false; circuit.gates.len()];
    let mut not_live: HashSet<Signal> = HashSet::new();
    for (gi, gate) in circuit.gates.iter().enumerate() {
        for &s in &gate.args {
            if matches!(s, Signal::NotInput(_) | Signal::NotGate(_)) && not_live.insert(s) {
                live += 1;
                peak = peak.max(live);
            }
        }
        live += 1; // the gate's result row
        peak = peak.max(live);
        gate_live[gi] = true;
        for &sig in &deaths[gi] {
            match sig {
                Signal::Gate(g) => {
                    if gate_live[g] {
                        gate_live[g] = false;
                        live -= 1;
                    }
                    if not_live.remove(&Signal::NotGate(g)) {
                        live -= 1;
                    }
                }
                Signal::Input(i) => {
                    if not_live.remove(&Signal::NotInput(i)) {
                        live -= 1;
                    }
                }
                _ => {}
            }
        }
    }
    // Negated outputs materialise one more NOT row each.
    for &s in &circuit.outputs {
        if matches!(s, Signal::NotInput(_) | Signal::NotGate(_)) && not_live.insert(s) {
            live += 1;
            peak = peak.max(live);
        }
    }
    (deaths, peak)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::adder::eval_add;
    use crate::pud::multiplier::eval_mul;

    #[test]
    fn parse_roundtrips_labels() {
        for name in ["add8", "mul4", "and", "or", "not", "maj3", "maj5"] {
            let op = PudOp::parse(name).unwrap();
            assert_eq!(op.label(), name);
        }
        assert_eq!(PudOp::parse("xor"), None);
        assert_eq!(PudOp::parse("add"), None);
        assert_eq!(PudOp::parse("ADD8"), Some(PudOp::Add { width: 8 }));
    }

    #[test]
    fn parse_or_list_reports_the_vocabulary() {
        assert_eq!(PudOp::parse_or_list("maj5"), Ok(PudOp::MajReduce { m: 5 }));
        let err = PudOp::parse_or_list("xor").unwrap_err();
        assert!(err.contains("unknown op 'xor'"), "{err}");
        for item in ["add<W>", "mul<W>", "and", "or", "not", "maj3", "maj5"] {
            assert!(err.contains(item), "missing {item} in: {err}");
        }
    }

    #[test]
    fn golden_matches_reference_arithmetic() {
        let add = WorkloadPlan::compile(PudOp::Add { width: 8 }).unwrap();
        let mul = WorkloadPlan::compile(PudOp::Mul { width: 4 }).unwrap();
        for (a, b) in [(0u64, 0u64), (3, 5), (200, 255), (15, 15)] {
            assert_eq!(add.golden(&[a, b]).unwrap(), a + b);
            assert_eq!(add.golden(&[a, b]).unwrap(), eval_add(&add.circuit, 8, a, b));
            let (a4, b4) = (a & 15, b & 15);
            assert_eq!(mul.golden(&[a4, b4]).unwrap(), a4 * b4);
            assert_eq!(mul.golden(&[a4, b4]).unwrap(), eval_mul(&mul.circuit, 4, a4, b4));
        }
    }

    #[test]
    fn bitwise_and_majreduce_golden() {
        let and = WorkloadPlan::compile(PudOp::Bitwise(BitwiseOp::And)).unwrap();
        let or = WorkloadPlan::compile(PudOp::Bitwise(BitwiseOp::Or)).unwrap();
        let not = WorkloadPlan::compile(PudOp::Bitwise(BitwiseOp::Not)).unwrap();
        for (a, b) in [(0u64, 0u64), (0, 1), (1, 0), (1, 1)] {
            assert_eq!(and.golden(&[a, b]).unwrap(), a & b);
            assert_eq!(or.golden(&[a, b]).unwrap(), a | b);
        }
        assert_eq!(not.golden(&[0]).unwrap(), 1);
        assert_eq!(not.golden(&[1]).unwrap(), 0);
        let maj3 = WorkloadPlan::compile(PudOp::MajReduce { m: 3 }).unwrap();
        assert_eq!(maj3.golden(&[1, 1, 0]).unwrap(), 1);
        assert_eq!(maj3.golden(&[1, 0, 0]).unwrap(), 0);
        let maj5 = WorkloadPlan::compile(PudOp::MajReduce { m: 5 }).unwrap();
        assert_eq!(maj5.golden(&[1, 1, 1, 0, 0]).unwrap(), 1);
        assert_eq!(maj5.golden(&[1, 1, 0, 0, 0]).unwrap(), 0);
    }

    #[test]
    fn golden_outputs_broadcasts_per_column() {
        let plan = WorkloadPlan::compile(PudOp::Add { width: 2 }).unwrap();
        let g = plan.golden_outputs(&[vec![1, 2, 3], vec![3, 2, 1]], 3).unwrap();
        assert_eq!(g, vec![4, 4, 4]);
        assert!(plan.golden_outputs(&[vec![1], vec![1]], 3).is_err());
        // A 0-operand plan broadcasts its constant to every column.
        let mut c = MajCircuit::new(0);
        c.output(Signal::Const(true));
        let konst = WorkloadPlan::compile(PudOp::Custom(c)).unwrap();
        assert_eq!(konst.golden_outputs(&[], 4).unwrap(), vec![1, 1, 1, 1]);
    }

    #[test]
    fn encode_operands_validates_shape() {
        let plan = WorkloadPlan::compile(PudOp::Add { width: 4 }).unwrap();
        let planes = plan.encode_operands(&[vec![5, 10], vec![3, 12]]).unwrap();
        assert_eq!(planes.len(), 8); // 2 operands x 4 bit-planes
        assert_eq!(planes[0], vec![1, 0]); // a bit 0 of 5, 10
        assert_eq!(planes[4], vec![1, 0]); // b bit 0 of 3, 12
        assert_eq!(
            plan.encode_operands(&[vec![1]]),
            Err(PudError::ArityMismatch { expected: 2, got: 1 })
        );
        assert_eq!(
            plan.encode_operands(&[vec![1, 2], vec![1]]),
            Err(PudError::WidthMismatch { expected: 2, got: 1 })
        );
    }

    #[test]
    fn invalid_ops_are_rejected() {
        assert!(matches!(
            WorkloadPlan::compile(PudOp::Add { width: 0 }),
            Err(PudError::MalformedCircuit(_))
        ));
        assert!(matches!(
            WorkloadPlan::compile(PudOp::MajReduce { m: 7 }),
            Err(PudError::MalformedCircuit(_))
        ));
        // A dangling custom circuit is caught at compile time.
        let bad = MajCircuit { n_inputs: 1, gates: Vec::new(), outputs: vec![Signal::Gate(0)] };
        let err = WorkloadPlan::compile(PudOp::Custom(bad)).unwrap_err();
        assert!(err.to_string().contains("referenced before definition"), "{err}");
    }

    #[test]
    fn peak_rows_is_positive_and_bounded() {
        // The dry-run peak must cover inputs and at least one wire, and
        // stay well under naive all-live allocation.
        let plan = WorkloadPlan::compile(PudOp::Add { width: 8 }).unwrap();
        let naive = plan.circuit.n_inputs + plan.circuit.gates.len();
        assert!(plan.peak_rows > plan.circuit.n_inputs);
        assert!(plan.peak_rows < naive, "{} vs naive {naive}", plan.peak_rows);
        // Death lists cover every gate index.
        for gi in 0..plan.circuit.gates.len() {
            let _ = plan.deaths(gi);
        }
    }

    #[test]
    fn errors_render_usefully() {
        let e = PudError::ArityMismatch { expected: 2, got: 3 };
        assert!(e.to_string().contains("operand arity mismatch"));
        let e = PudError::RowBudgetExceeded { needed: 40, available: 8 };
        assert!(e.to_string().contains("40"));
        assert!(e.to_string().contains("8"));
    }
}
