//! Static charge-state verification of circuits and workload plans.
//!
//! PuDGhost-style corruption on real chips is systematic: specific
//! command interleavings open rows in charge states the sequence
//! designer never anticipated, and reliability collapses from there.
//! Our compiler ([`crate::pud::plan::WorkloadPlan::compile`]) computes
//! death lists and a peak-row dry-run, but nothing *proved* a plan was
//! charge-state safe before it touched a subarray — a hand-built
//! `Custom(MajCircuit)` could read a dead row, double-Frac a
//! calibration row, alias analog charge, or exit un-restored, and the
//! failure only surfaced as a golden-model mismatch at serve time.
//!
//! This module is the missing proof: an abstract interpreter that
//! lowers a plan to the exact command stream the executor would issue
//! ([`ChargeScript`]) and tracks every row through a four-state
//! machine — **Uninitialized → Packed ⇄ Fracd-analog → Dead** —
//! alongside independent (re-derived, not shared-code) liveness and
//! shape analyses. Violations surface as typed [`Diagnostic`]s with
//! stable `P###` codes (catalogued in [`DiagCode`] and the `pud`
//! module docs), each carrying the gate index, the abstract row, a
//! one-line fix hint and a machine-readable JSON rendering.
//!
//! Wiring:
//!
//! * [`WorkloadPlan::compile`] runs [`verify_plan`] on its own output
//!   and refuses to return a plan with error-severity diagnostics —
//!   the compiler's `analyse()` is pinned against this module's
//!   independent recomputation on every compile;
//! * [`crate::pud::exec::run_plan`], the compute engines and
//!   `RecalibService::serve_plan` call [`admit`] before touching DRAM,
//!   so an unverified hand-assembled plan is rejected at admission;
//! * `pudtune lint` verifies the built-in [`PudOp`] vocabulary and
//!   user-supplied circuit files ([`parse_circuit`]), exiting nonzero
//!   on any error-severity diagnostic (warnings too with
//!   `--deny-warnings`);
//! * the range analysis (`pud::ranges`) reports its findings through
//!   the same catalogue (P009–P012, all warnings).

use crate::pud::graph::{Gate, MajCircuit, Signal};
use crate::pud::plan::{PudError, PudOp, WorkloadPlan};
use std::collections::{HashMap, HashSet};
use std::fmt;
use std::sync::{Mutex, OnceLock};

/// Stable diagnostic codes. The numbering is part of the tool's
/// contract (CI, lint output parsers); never renumber, only append.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DiagCode {
    /// P001 — a row is read (or released again) after its death.
    UseAfterDeath,
    /// P002 — illegal charge operation on an analog row: a second
    /// Frac without an intervening SiMRA restore, or reading/copying/
    /// overwriting a row that still holds fractional charge.
    DoubleFrac,
    /// P003 — a row is consumed before anything was written to it.
    ReadUninitialized,
    /// P004 — the replayed scratch-row high-water mark overflows the
    /// budget, or disagrees with the plan's compiled `peak_rows`.
    RowBudgetOverflow,
    /// P005 — a gate's output (either polarity) is never consumed.
    DeadGate,
    /// P006 — the plan exits with rows still in the analog state.
    UnrestoredExit,
    /// P007 — the plan's death lists disagree with an independent
    /// last-use recomputation (or are structurally malformed).
    DeathListMismatch,
    /// P008 — gate arity, signal range, operand shape or output count
    /// is inconsistent with the op.
    ShapeMismatch,
    /// P009 — range analysis proves an output bit constant for every
    /// operand inside the declared ranges (`pud::ranges`).
    ConstantOutputBit,
    /// P010 — a gate is consumed syntactically but range analysis
    /// proves it unobservable at any output (folded constant/alias or
    /// feeding only folded logic). Disjoint from P005, which flags
    /// gates nothing consumes at all.
    DeadGateByDataflow,
    /// P011 — the value-interval refinement proves a carry/overflow
    /// output bit impossible where the bit lattice alone could not.
    RangeOverflowImpossibleCarry,
    /// P012 — the plan admits a strictly smaller narrowed variant
    /// under the declared operand ranges
    /// (`WorkloadPlan::narrowed`).
    NarrowingOpportunity,
}

/// Diagnostic severity. Errors block compilation and admission;
/// warnings are advisory — `pudtune lint` tolerates them unless
/// `--deny-warnings` is given (the built-in vocabulary has zero
/// diagnostics of either severity).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

impl DiagCode {
    /// Every code, in numeric order.
    pub const ALL: [DiagCode; 12] = [
        DiagCode::UseAfterDeath,
        DiagCode::DoubleFrac,
        DiagCode::ReadUninitialized,
        DiagCode::RowBudgetOverflow,
        DiagCode::DeadGate,
        DiagCode::UnrestoredExit,
        DiagCode::DeathListMismatch,
        DiagCode::ShapeMismatch,
        DiagCode::ConstantOutputBit,
        DiagCode::DeadGateByDataflow,
        DiagCode::RangeOverflowImpossibleCarry,
        DiagCode::NarrowingOpportunity,
    ];

    /// The stable `P###` code string.
    pub fn code(&self) -> &'static str {
        match self {
            DiagCode::UseAfterDeath => "P001",
            DiagCode::DoubleFrac => "P002",
            DiagCode::ReadUninitialized => "P003",
            DiagCode::RowBudgetOverflow => "P004",
            DiagCode::DeadGate => "P005",
            DiagCode::UnrestoredExit => "P006",
            DiagCode::DeathListMismatch => "P007",
            DiagCode::ShapeMismatch => "P008",
            DiagCode::ConstantOutputBit => "P009",
            DiagCode::DeadGateByDataflow => "P010",
            DiagCode::RangeOverflowImpossibleCarry => "P011",
            DiagCode::NarrowingOpportunity => "P012",
        }
    }

    /// One-line meaning (module docs, lint output).
    pub fn meaning(&self) -> &'static str {
        match self {
            DiagCode::UseAfterDeath => "use after death: a row is consumed after its release",
            DiagCode::DoubleFrac => {
                "double-Frac / analog aliasing: charge op on a row already holding analog charge"
            }
            DiagCode::ReadUninitialized => "read of a never-written row",
            DiagCode::RowBudgetOverflow => {
                "row-budget overflow or peak-row disagreement with the compiled plan"
            }
            DiagCode::DeadGate => "dead gate: a gate's output is never consumed",
            DiagCode::UnrestoredExit => "plan exits with analog rows un-restored",
            DiagCode::DeathListMismatch => {
                "death lists disagree with independent last-use analysis"
            }
            DiagCode::ShapeMismatch => "gate arity / signal range / operand shape mismatch",
            DiagCode::ConstantOutputBit => {
                "output bit is provably constant under the declared operand ranges"
            }
            DiagCode::DeadGateByDataflow => {
                "gate is consumed but unobservable at any output under the declared ranges"
            }
            DiagCode::RangeOverflowImpossibleCarry => {
                "carry/overflow bit is impossible by value-interval analysis"
            }
            DiagCode::NarrowingOpportunity => {
                "plan admits a strictly smaller width-narrowed variant for these ranges"
            }
        }
    }

    /// One-line fix hint attached to every diagnostic of this code.
    pub fn hint(&self) -> &'static str {
        match self {
            DiagCode::UseAfterDeath => {
                "move the signal's death entry to (or after) its true last consumer"
            }
            DiagCode::DoubleFrac => "restore the row with a SiMRA before charging or reusing it",
            DiagCode::ReadUninitialized => "write the row (input, constant or gate result) first",
            DiagCode::RowBudgetOverflow => {
                "shrink the circuit's live set or recompile to refresh peak_rows"
            }
            DiagCode::DeadGate => "drop the gate or route its output to a consumer/output",
            DiagCode::UnrestoredExit => "end every MAJX flow with its SiMRA restore",
            DiagCode::DeathListMismatch => "recompile the plan instead of editing death lists",
            DiagCode::ShapeMismatch => {
                "use 3- or 5-ary gates over in-range, already-defined signals"
            }
            DiagCode::ConstantOutputBit => {
                "serve a narrowed variant (WorkloadPlan::narrowed) or widen the declared ranges"
            }
            DiagCode::DeadGateByDataflow => {
                "narrow the plan to strip the gate, or widen the declared ranges"
            }
            DiagCode::RangeOverflowImpossibleCarry => {
                "serve a narrowed variant; the carry chain above this bit is unnecessary"
            }
            DiagCode::NarrowingOpportunity => {
                "register the narrowed variant in the PlanCache under its range class"
            }
        }
    }

    /// Default severity: the charge-state violations block
    /// compilation/admission; the dead-gate and range-analysis
    /// findings (P005, P009–P012) are advisory.
    pub fn severity(&self) -> Severity {
        match self {
            DiagCode::DeadGate
            | DiagCode::ConstantOutputBit
            | DiagCode::DeadGateByDataflow
            | DiagCode::RangeOverflowImpossibleCarry
            | DiagCode::NarrowingOpportunity => Severity::Warning,
            _ => Severity::Error,
        }
    }
}

/// One verification finding: a stable code plus where (gate index in
/// the circuit, abstract row in the replay) and a specific message.
/// The fix hint is derived from the code ([`DiagCode::hint`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub code: DiagCode,
    /// Gate index the violation is attributed to (`None` for
    /// setup/readout/exit findings).
    pub gate: Option<usize>,
    /// Abstract row in the lowered script (`None` for plan-level
    /// findings that concern a signal, not a physical row).
    pub row: Option<usize>,
    pub message: String,
}

impl Diagnostic {
    fn new(code: DiagCode, gate: Option<usize>, row: Option<usize>, message: String) -> Self {
        Self { code, gate, row, message }
    }

    pub fn severity(&self) -> Severity {
        self.code.severity()
    }

    pub fn hint(&self) -> &'static str {
        self.code.hint()
    }

    /// Machine-readable rendering, one JSON object per diagnostic.
    pub fn to_json(&self) -> String {
        let opt = |v: Option<usize>| v.map_or("null".into(), |x| x.to_string());
        format!(
            "{{\"code\":\"{}\",\"severity\":\"{}\",\"gate\":{},\"row\":{},\
             \"message\":\"{}\",\"hint\":\"{}\"}}",
            self.code.code(),
            match self.severity() {
                Severity::Error => "error",
                Severity::Warning => "warning",
            },
            opt(self.gate),
            opt(self.row),
            json_escape(&self.message),
            json_escape(self.hint()),
        )
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity() {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        write!(f, "{sev}[{}]", self.code.code())?;
        if let Some(g) = self.gate {
            write!(f, " gate {g}")?;
        }
        if let Some(r) = self.row {
            write!(f, " row {r}")?;
        }
        write!(f, ": {} (hint: {})", self.message, self.hint())
    }
}

impl std::error::Error for Diagnostic {}

impl From<Diagnostic> for PudError {
    fn from(d: Diagnostic) -> Self {
        PudError::Verification { code: d.code.code(), message: d.to_string() }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The outcome of verifying one plan/circuit: every diagnostic found
/// plus the replayed scratch-row high-water mark (0 when structural
/// errors prevented the replay).
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    pub diagnostics: Vec<Diagnostic>,
    /// Peak simultaneous scratch rows observed by the abstract replay
    /// — must equal the compiler's dry-run `peak_rows` on any plan the
    /// compiler produced.
    pub peak_rows: usize,
}

impl VerifyReport {
    /// No diagnostics of any severity.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Error-severity diagnostics (the ones that block admission).
    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity() == Severity::Error)
    }

    /// Whether any diagnostic carries `code`.
    pub fn has(&self, code: DiagCode) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }

    /// Machine-readable rendering of the whole report.
    pub fn to_json(&self) -> String {
        let items: Vec<String> = self.diagnostics.iter().map(|d| d.to_json()).collect();
        format!(
            "{{\"clean\":{},\"peak_rows\":{},\"diagnostics\":[{}]}}",
            self.is_clean(),
            self.peak_rows,
            items.join(",")
        )
    }
}

impl fmt::Display for VerifyReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_clean() {
            return write!(f, "clean (peak {} rows)", self.peak_rows);
        }
        for (i, d) in self.diagnostics.iter().enumerate() {
            if i > 0 {
                writeln!(f)?;
            }
            write!(f, "{d}")?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Abstract command stream (the lowering target)
// ---------------------------------------------------------------------------

/// Abstract row layout mirroring [`crate::dram::geometry::RowMap::standard`]:
/// the 8-row SiMRA group, the three calibration stores, the constant
/// rows, then the data region the replay allocator hands out.
pub const SIMRA_BASE: usize = 0;
/// Rows holding the pre-identified calibration bits.
pub const CALIB_STORE: [usize; 3] = [8, 9, 10];
/// All-zeros constant row.
pub const CONST0: usize = 11;
/// All-ones constant row.
pub const CONST1: usize = 12;
/// First scratch row the replay allocator hands out.
pub const DATA_BASE: usize = 16;

/// One abstract DRAM command over abstract rows. `gate` attributes the
/// command to the circuit gate whose MAJX flow issued it (`None` for
/// setup, input materialisation and output readout).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChargeOp {
    /// Column-interface write of fresh full-swing data.
    Write { row: usize, gate: Option<usize> },
    /// RowCopy `src → dst` (operand/calibration staging).
    Copy { src: usize, dst: usize, gate: Option<usize> },
    /// One Frac application burst on a calibration row (the burst's
    /// pulse count is a `FracConfig` runtime choice; a *second* burst
    /// without an intervening restore is the P002 violation).
    Frac { row: usize, gate: Option<usize> },
    /// SiMRA over the aligned group `base..base+8`; the hardware flow
    /// always restores every participating row to full swing —
    /// `restore: false` models a truncated command sequence.
    Simra { base: usize, restore: bool, gate: Option<usize> },
    /// Column-interface read.
    Read { row: usize, gate: Option<usize> },
    /// Scratch row released back to the allocator (death list).
    Release { row: usize, gate: Option<usize> },
}

impl ChargeOp {
    fn gate(&self) -> Option<usize> {
        match self {
            ChargeOp::Write { gate, .. }
            | ChargeOp::Copy { gate, .. }
            | ChargeOp::Frac { gate, .. }
            | ChargeOp::Simra { gate, .. }
            | ChargeOp::Read { gate, .. }
            | ChargeOp::Release { gate, .. } => *gate,
        }
    }
}

/// A plan lowered to the abstract command stream the executor would
/// issue, with the replay allocator's high-water mark.
#[derive(Clone, Debug)]
pub struct ChargeScript {
    pub ops: Vec<ChargeOp>,
    /// Peak simultaneous scratch rows during the lowering replay.
    pub peak_rows: usize,
}

/// One backend-neutral executor step — the typed, coarse view of the
/// same lowering [`ChargeScript`] records command by command. Engines
/// interpret this stream instead of re-deriving the
/// setup/Frac/SiMRA/readout order themselves; all rows are abstract
/// ([`SIMRA_BASE`]/[`CALIB_STORE`]/[`CONST0`]/[`CONST1`]/[`DATA_BASE`]
/// layout) and must be translated through the subarray's `RowMap`
/// before touching DRAM.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LoweredStep {
    /// Write input plane `input` into abstract data row `row`.
    WriteInput { input: usize, row: usize },
    /// Materialise a negation: read `src`, invert, write `dst`.
    Not { src: usize, dst: usize },
    /// One full MAJX flow for gate `gate`: stage the `operands` rows
    /// (plus calibration stores and, for MAJ3, the constant rows) into
    /// the SiMRA group, Frac the calibration rows, fire the restoring
    /// SiMRA, and write the per-column decision into data row `dst`.
    Majx { gate: usize, m: usize, operands: Vec<usize>, dst: usize },
    /// Scratch rows released after a gate's death list — a physical
    /// no-op at execution time (the abstract row ids already bake in
    /// the allocator's LIFO reuse order), kept so backends can audit
    /// per-step liveness and the verifier can replay releases.
    Release { rows: Vec<usize> },
    /// Read output plane `output` back from abstract data row `row`.
    ReadOutput { output: usize, row: usize },
}

/// The canonical backend-neutral lowering of a
/// [`WorkloadPlan`]: the typed step stream every
/// engine interprets ([`LoweredStep`]) plus the flat [`ChargeScript`]
/// the verifier's charge-state machine checks. Both views are emitted
/// by the same single pass ([`lower_plan_full`]), so the program that
/// executes is — by construction — the program that was verified.
#[derive(Clone, Debug)]
pub struct LoweredPlan {
    /// Executor steps in issue order.
    pub steps: Vec<LoweredStep>,
    /// The command-level view of the same lowering (verifier input).
    pub script: ChargeScript,
}

impl LoweredPlan {
    /// Peak simultaneous scratch rows during the lowering replay.
    pub fn peak_rows(&self) -> usize {
        self.script.peak_rows
    }
}

/// Replay of [`crate::pud::rowalloc::RowAlloc`]'s discipline (LIFO
/// free list, unbounded) so the abstract script reuses rows in exactly
/// the order the executor would.
struct ReplayAlloc {
    free: Vec<usize>,
    next: usize,
    live: usize,
    high: usize,
}

impl ReplayAlloc {
    fn new() -> Self {
        Self { free: Vec::new(), next: DATA_BASE, live: 0, high: 0 }
    }

    fn alloc(&mut self) -> usize {
        let row = self.free.pop().unwrap_or_else(|| {
            let r = self.next;
            self.next += 1;
            r
        });
        self.live += 1;
        self.high = self.high.max(self.live);
        row
    }

    fn release(&mut self, row: usize) {
        self.live -= 1;
        self.free.push(row);
    }
}

/// Lower a plan to its abstract command stream only (the verifier's
/// historical entry point). Equivalent to
/// [`lower_plan_full`]`(plan).map(|l| l.script)`.
pub fn lower_plan(plan: &WorkloadPlan) -> Result<ChargeScript, Diagnostic> {
    lower_plan_full(plan).map(|l| l.script)
}

/// Lower a plan to the canonical [`LoweredPlan`]: the typed executor
/// step stream and the abstract command stream, emitted together in
/// one pass that mirrors the execution order exactly — setup writes,
/// inputs materialised up front, NOT rows at first use, per-gate
/// stage/Frac/SiMRA/copy-out, death-list releases, output readout.
///
/// Fails (with a P007/P008 diagnostic) only when the circuit or death
/// lists are too malformed to walk — out-of-range references the
/// abstract machine cannot even name rows for.
pub fn lower_plan_full(plan: &WorkloadPlan) -> Result<LoweredPlan, Diagnostic> {
    let circuit = &plan.circuit;
    let n_gates = circuit.gates.len();
    if plan.death_lists().len() != n_gates {
        return Err(Diagnostic::new(
            DiagCode::DeathListMismatch,
            None,
            None,
            format!(
                "plan carries {} death lists for {n_gates} gates",
                plan.death_lists().len()
            ),
        ));
    }
    let in_range = |s: Signal, upto: usize| match s {
        Signal::Input(i) | Signal::NotInput(i) => i < circuit.n_inputs,
        Signal::Gate(g) | Signal::NotGate(g) => g < upto,
        Signal::Const(_) => true,
    };
    for (gi, gate) in circuit.gates.iter().enumerate() {
        for &s in &gate.args {
            if !in_range(s, gi) {
                return Err(Diagnostic::new(
                    DiagCode::ShapeMismatch,
                    Some(gi),
                    None,
                    format!("gate {gi} references out-of-range signal {s:?}"),
                ));
            }
        }
    }
    for &s in &circuit.outputs {
        if !in_range(s, n_gates) {
            return Err(Diagnostic::new(
                DiagCode::ShapeMismatch,
                None,
                None,
                format!("output references out-of-range signal {s:?}"),
            ));
        }
    }

    let mut ops = Vec::new();
    let mut steps = Vec::new();
    let mut alloc = ReplayAlloc::new();
    // setup_subarray: calibration stores + constants. These are issued
    // by `setup_subarray` itself, so they appear only in the command
    // stream, not as typed executor steps.
    for &r in &CALIB_STORE {
        ops.push(ChargeOp::Write { row: r, gate: None });
    }
    ops.push(ChargeOp::Write { row: CONST0, gate: None });
    ops.push(ChargeOp::Write { row: CONST1, gate: None });

    // Primary inputs.
    let mut input_rows = Vec::with_capacity(circuit.n_inputs);
    for i in 0..circuit.n_inputs {
        let r = alloc.alloc();
        ops.push(ChargeOp::Write { row: r, gate: None });
        steps.push(LoweredStep::WriteInput { input: i, row: r });
        input_rows.push(r);
    }
    // Gate result rows keep their id after release so a corrupt plan's
    // stale read still names the row it would hit.
    let mut gate_rows: Vec<Option<usize>> = vec![None; n_gates];
    let mut gate_released = vec![false; n_gates];
    let mut not_rows: HashMap<Signal, usize> = HashMap::new();

    // Resolve a signal to a readable row, materialising negations on
    // demand exactly like the executor's `row_of!`.
    macro_rules! row_of {
        ($sig:expr, $gate:expr) => {{
            let sig: Signal = $sig;
            match sig {
                Signal::Input(i) => input_rows[i],
                Signal::Gate(g) => gate_rows[g].expect("topological order checked above"),
                Signal::Const(false) => CONST0,
                Signal::Const(true) => CONST1,
                Signal::NotInput(_) | Signal::NotGate(_) => {
                    if let Some(&r) = not_rows.get(&sig) {
                        r
                    } else {
                        let src = match sig {
                            Signal::NotInput(i) => input_rows[i],
                            Signal::NotGate(g) => {
                                gate_rows[g].expect("topological order checked above")
                            }
                            _ => unreachable!(),
                        };
                        ops.push(ChargeOp::Read { row: src, gate: $gate });
                        let r = alloc.alloc();
                        ops.push(ChargeOp::Write { row: r, gate: $gate });
                        steps.push(LoweredStep::Not { src, dst: r });
                        not_rows.insert(sig, r);
                        r
                    }
                }
            }
        }};
    }

    for (gi, gate) in circuit.gates.iter().enumerate() {
        let m = gate.arity();
        let op_rows: Vec<usize> = gate.args.iter().map(|&s| row_of!(s, Some(gi))).collect();
        // ①' stage operands + calibration (+ constants for MAJ3) into
        // the aligned 8-row group.
        for (i, &r) in op_rows.iter().enumerate() {
            ops.push(ChargeOp::Copy { src: r, dst: SIMRA_BASE + i, gate: Some(gi) });
        }
        for (j, &store) in CALIB_STORE.iter().enumerate() {
            ops.push(ChargeOp::Copy { src: store, dst: SIMRA_BASE + m + j, gate: Some(gi) });
        }
        if m + 3 < 8 {
            ops.push(ChargeOp::Copy { src: CONST0, dst: SIMRA_BASE + m + 3, gate: Some(gi) });
            ops.push(ChargeOp::Copy { src: CONST1, dst: SIMRA_BASE + m + 4, gate: Some(gi) });
        }
        // ②' one Frac burst per calibration row.
        for j in 0..CALIB_STORE.len() {
            ops.push(ChargeOp::Frac { row: SIMRA_BASE + m + j, gate: Some(gi) });
        }
        // ③ SiMRA (restores the whole group to full swing).
        ops.push(ChargeOp::Simra { base: SIMRA_BASE, restore: true, gate: Some(gi) });
        // ④ copy the result out of the group.
        let r = alloc.alloc();
        ops.push(ChargeOp::Write { row: r, gate: Some(gi) });
        steps.push(LoweredStep::Majx { gate: gi, m, operands: op_rows, dst: r });
        gate_rows[gi] = Some(r);
        // Death-list releases (both polarities at the canonical death,
        // mirroring the executor's take()-guarded releases).
        let mut released = Vec::new();
        for &sig in plan.deaths(gi) {
            match sig {
                Signal::Gate(g) if g < n_gates => {
                    if let Some(row) = gate_rows[g] {
                        if !gate_released[g] {
                            gate_released[g] = true;
                            alloc.release(row);
                            ops.push(ChargeOp::Release { row, gate: Some(gi) });
                            released.push(row);
                        }
                    }
                    if let Some(row) = not_rows.remove(&Signal::NotGate(g)) {
                        alloc.release(row);
                        ops.push(ChargeOp::Release { row, gate: Some(gi) });
                        released.push(row);
                    }
                }
                Signal::Input(i) if i < circuit.n_inputs => {
                    if let Some(row) = not_rows.remove(&Signal::NotInput(i)) {
                        alloc.release(row);
                        ops.push(ChargeOp::Release { row, gate: Some(gi) });
                        released.push(row);
                    }
                }
                _ => {}
            }
        }
        if !released.is_empty() {
            steps.push(LoweredStep::Release { rows: released });
        }
    }

    // Output readout (negated outputs materialise one more NOT row).
    for (oi, &s) in circuit.outputs.iter().enumerate() {
        let r = row_of!(s, None);
        ops.push(ChargeOp::Read { row: r, gate: None });
        steps.push(LoweredStep::ReadOutput { output: oi, row: r });
    }

    Ok(LoweredPlan { steps, script: ChargeScript { ops, peak_rows: alloc.high } })
}

/// Abstract row state during script interpretation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RowState {
    Uninitialized,
    Packed,
    Analog,
    Dead,
}

/// Run the four-state abstract machine over a lowered script. Every
/// command checks its rows' states and transitions them; violations
/// become P001/P002/P003/P006 diagnostics. Pure state-machine pass —
/// no knowledge of the plan that produced the script, which is what
/// lets mutation tests corrupt scripts directly.
pub fn check_script(script: &ChargeScript) -> Vec<Diagnostic> {
    let max_row = script
        .ops
        .iter()
        .map(|op| match op {
            ChargeOp::Write { row, .. }
            | ChargeOp::Frac { row, .. }
            | ChargeOp::Read { row, .. }
            | ChargeOp::Release { row, .. } => *row,
            ChargeOp::Copy { src, dst, .. } => (*src).max(*dst),
            ChargeOp::Simra { base, .. } => base + 7,
        })
        .max()
        .unwrap_or(0);
    let mut state = vec![RowState::Uninitialized; max_row + 1];
    let mut diags = Vec::new();

    fn check_read(
        state: &[RowState],
        row: usize,
        gate: Option<usize>,
        what: &str,
        diags: &mut Vec<Diagnostic>,
    ) {
        match state[row] {
            RowState::Packed => {}
            RowState::Analog => diags.push(Diagnostic::new(
                DiagCode::DoubleFrac,
                gate,
                Some(row),
                format!("{what} of row {row} while it holds analog charge"),
            )),
            RowState::Dead => diags.push(Diagnostic::new(
                DiagCode::UseAfterDeath,
                gate,
                Some(row),
                format!("{what} of row {row} after its release"),
            )),
            RowState::Uninitialized => diags.push(Diagnostic::new(
                DiagCode::ReadUninitialized,
                gate,
                Some(row),
                format!("{what} of row {row} before anything was written to it"),
            )),
        }
    }

    for op in &script.ops {
        let gate = op.gate();
        match *op {
            ChargeOp::Write { row, .. } => {
                if state[row] == RowState::Analog {
                    diags.push(Diagnostic::new(
                        DiagCode::DoubleFrac,
                        gate,
                        Some(row),
                        format!("write over row {row} while it holds analog charge"),
                    ));
                }
                state[row] = RowState::Packed;
            }
            ChargeOp::Copy { src, dst, .. } => {
                check_read(&state, src, gate, "RowCopy source read", &mut diags);
                if state[dst] == RowState::Analog {
                    diags.push(Diagnostic::new(
                        DiagCode::DoubleFrac,
                        gate,
                        Some(dst),
                        format!("RowCopy over row {dst} while it holds analog charge"),
                    ));
                }
                state[dst] = RowState::Packed;
            }
            ChargeOp::Frac { row, .. } => match state[row] {
                RowState::Packed => state[row] = RowState::Analog,
                RowState::Analog => diags.push(Diagnostic::new(
                    DiagCode::DoubleFrac,
                    gate,
                    Some(row),
                    format!("second Frac burst on row {row} without a SiMRA restore"),
                )),
                RowState::Dead => diags.push(Diagnostic::new(
                    DiagCode::UseAfterDeath,
                    gate,
                    Some(row),
                    format!("Frac on row {row} after its release"),
                )),
                RowState::Uninitialized => diags.push(Diagnostic::new(
                    DiagCode::ReadUninitialized,
                    gate,
                    Some(row),
                    format!("Frac on row {row} before anything was written to it"),
                )),
            },
            ChargeOp::Simra { base, restore, .. } => {
                for row in base..base + 8 {
                    match state[row] {
                        RowState::Packed | RowState::Analog => {}
                        RowState::Dead => diags.push(Diagnostic::new(
                            DiagCode::UseAfterDeath,
                            gate,
                            Some(row),
                            format!("SiMRA opens row {row} after its release"),
                        )),
                        RowState::Uninitialized => diags.push(Diagnostic::new(
                            DiagCode::ReadUninitialized,
                            gate,
                            Some(row),
                            format!("SiMRA opens never-written row {row}"),
                        )),
                    }
                    if restore {
                        state[row] = RowState::Packed;
                    }
                }
            }
            ChargeOp::Read { row, .. } => {
                check_read(&state, row, gate, "column read", &mut diags);
            }
            ChargeOp::Release { row, .. } => {
                match state[row] {
                    RowState::Packed => {}
                    RowState::Analog => diags.push(Diagnostic::new(
                        DiagCode::UnrestoredExit,
                        gate,
                        Some(row),
                        format!("row {row} released while still analog"),
                    )),
                    RowState::Dead => diags.push(Diagnostic::new(
                        DiagCode::UseAfterDeath,
                        gate,
                        Some(row),
                        format!("double release of row {row}"),
                    )),
                    RowState::Uninitialized => diags.push(Diagnostic::new(
                        DiagCode::ReadUninitialized,
                        gate,
                        Some(row),
                        format!("release of never-written row {row}"),
                    )),
                }
                state[row] = RowState::Dead;
            }
        }
    }
    for (row, s) in state.iter().enumerate() {
        if *s == RowState::Analog {
            diags.push(Diagnostic::new(
                DiagCode::UnrestoredExit,
                None,
                Some(row),
                format!("plan exits with row {row} still analog"),
            ));
        }
    }
    diags
}

// ---------------------------------------------------------------------------
// Plan-level analyses (independent of the compiler's analyse())
// ---------------------------------------------------------------------------

/// Liveness key shared by both polarities of a signal (the executor
/// releases a row and its materialised negation together). Re-derived
/// here so the verifier never shares code with the compiler's pass.
fn canonical(s: Signal) -> Signal {
    match s {
        Signal::NotInput(i) => Signal::Input(i),
        Signal::NotGate(g) => Signal::Gate(g),
        other => other,
    }
}

/// Independent last-use recomputation: a single *reverse* scan (the
/// compiler scans forward and overwrites), outputs pinned live
/// forever. `None` = live at exit.
fn independent_last_use(circuit: &MajCircuit) -> HashMap<Signal, Option<usize>> {
    let mut last: HashMap<Signal, Option<usize>> = HashMap::new();
    for &s in &circuit.outputs {
        last.insert(canonical(s), None);
    }
    for (gi, gate) in circuit.gates.iter().enumerate().rev() {
        for &s in &gate.args {
            last.entry(canonical(s)).or_insert(Some(gi));
        }
    }
    last
}

/// Structural (P008) checks: op/operand shape, output count, gate
/// arities, signal ranges and topological order.
fn structural_diags(plan: &WorkloadPlan) -> Vec<Diagnostic> {
    let circuit = &plan.circuit;
    let mut diags = Vec::new();
    let expected = plan.op.n_operands() * plan.op.operand_width();
    if circuit.n_inputs != expected {
        diags.push(Diagnostic::new(
            DiagCode::ShapeMismatch,
            None,
            None,
            format!(
                "op {} encodes {expected} input bit-planes but the circuit declares {}",
                plan.op.label(),
                circuit.n_inputs
            ),
        ));
    }
    if circuit.outputs.len() > 64 {
        diags.push(Diagnostic::new(
            DiagCode::ShapeMismatch,
            None,
            None,
            format!("{} outputs do not fit the 64-bit value decode", circuit.outputs.len()),
        ));
    }
    let mut check = |s: Signal, gi: Option<usize>, upto: usize, diags: &mut Vec<Diagnostic>| {
        let bad = match s {
            Signal::Input(i) | Signal::NotInput(i) if i >= circuit.n_inputs => Some(format!(
                "input {i} out of range (circuit has {} inputs)",
                circuit.n_inputs
            )),
            Signal::Gate(g) | Signal::NotGate(g) if g >= upto => {
                Some(format!("gate {g} referenced before definition"))
            }
            _ => None,
        };
        if let Some(msg) = bad {
            diags.push(Diagnostic::new(DiagCode::ShapeMismatch, gi, None, msg));
        }
    };
    for (gi, gate) in circuit.gates.iter().enumerate() {
        if gate.arity() != 3 && gate.arity() != 5 {
            diags.push(Diagnostic::new(
                DiagCode::ShapeMismatch,
                Some(gi),
                None,
                format!("gate {gi} is {}-ary; majority gates are 3- or 5-ary", gate.arity()),
            ));
        }
        for &s in &gate.args {
            check(s, Some(gi), gi, &mut diags);
        }
    }
    for &s in &circuit.outputs {
        check(s, None, circuit.gates.len(), &mut diags);
    }
    diags
}

/// Death-list cross-checks: structural sanity of the entries (P007),
/// set-equality against the independent liveness (P007), use/readout
/// after a plan-declared death (P001) and dead gates (P005).
fn liveness_diags(plan: &WorkloadPlan) -> Vec<Diagnostic> {
    let circuit = &plan.circuit;
    let n_gates = circuit.gates.len();
    let mut diags = Vec::new();

    // Entry sanity: death lists hold canonical, in-range signals.
    let mut death_at: HashMap<Signal, usize> = HashMap::new();
    for (gi, list) in plan.death_lists().iter().enumerate() {
        for &sig in list {
            let ok = match sig {
                Signal::Gate(g) => g < n_gates,
                Signal::Input(i) => i < circuit.n_inputs,
                Signal::Const(_) => true,
                Signal::NotGate(_) | Signal::NotInput(_) => false,
            };
            if !ok {
                diags.push(Diagnostic::new(
                    DiagCode::DeathListMismatch,
                    Some(gi),
                    None,
                    format!("death list at gate {gi} holds non-canonical or out-of-range {sig:?}"),
                ));
            }
            if death_at.insert(sig, gi).is_some() {
                diags.push(Diagnostic::new(
                    DiagCode::DeathListMismatch,
                    Some(gi),
                    None,
                    format!("{sig:?} appears in more than one death list"),
                ));
            }
        }
    }

    // Independent recomputation vs the plan's lists, per gate, as sets.
    let last = independent_last_use(circuit);
    let mut expect: Vec<HashSet<Signal>> = vec![HashSet::new(); n_gates];
    for (&sig, &lu) in &last {
        if let Some(gi) = lu {
            expect[gi].insert(sig);
        }
    }
    for gi in 0..n_gates {
        let got: HashSet<Signal> = plan.deaths(gi).iter().copied().collect();
        if got != expect[gi] {
            let missing: Vec<Signal> = expect[gi].difference(&got).copied().collect();
            let extra: Vec<Signal> = got.difference(&expect[gi]).copied().collect();
            diags.push(Diagnostic::new(
                DiagCode::DeathListMismatch,
                Some(gi),
                None,
                format!(
                    "death list at gate {gi} disagrees with independent liveness \
                     (missing {missing:?}, extra {extra:?})"
                ),
            ));
        }
    }

    // P001: any consumer after the plan-declared death.
    for (gi, gate) in circuit.gates.iter().enumerate() {
        for &s in &gate.args {
            if let Some(&d) = death_at.get(&canonical(s)) {
                if d < gi {
                    diags.push(Diagnostic::new(
                        DiagCode::UseAfterDeath,
                        Some(gi),
                        None,
                        format!("gate {gi} reads {s:?}, released after gate {d}"),
                    ));
                }
            }
        }
    }
    for &s in &circuit.outputs {
        if let Some(&d) = death_at.get(&canonical(s)) {
            diags.push(Diagnostic::new(
                DiagCode::UseAfterDeath,
                None,
                None,
                format!("output {s:?} is released after gate {d}; outputs must live to exit"),
            ));
        }
    }

    // P005: gates whose output no one consumes.
    for g in 0..n_gates {
        if !last.contains_key(&Signal::Gate(g)) {
            diags.push(Diagnostic::new(
                DiagCode::DeadGate,
                Some(g),
                None,
                format!("gate {g}'s output is never consumed by a gate or output"),
            ));
        }
    }
    diags
}

/// Verify a compiled plan: structural shape, death lists against an
/// independent liveness recomputation, and the abstract charge-state
/// replay, with the replayed peak checked against the plan's compiled
/// `peak_rows`. See the module docs for the diagnostic catalogue.
pub fn verify_plan(plan: &WorkloadPlan) -> VerifyReport {
    verify_plan_with_budget(plan, None)
}

/// [`verify_plan`], additionally checking the replayed peak against a
/// scratch-row budget (e.g. `sub.rows - map.data_base`): exceeding it
/// is a P004 error before any subarray is touched.
pub fn verify_plan_with_budget(plan: &WorkloadPlan, budget: Option<usize>) -> VerifyReport {
    let mut diags = structural_diags(plan);
    if plan.death_lists().len() != plan.circuit.gates.len() {
        diags.push(Diagnostic::new(
            DiagCode::DeathListMismatch,
            None,
            None,
            format!(
                "plan carries {} death lists for {} gates",
                plan.death_lists().len(),
                plan.circuit.gates.len()
            ),
        ));
        return VerifyReport { diagnostics: diags, peak_rows: 0 };
    }
    if diags.iter().any(|d| d.severity() == Severity::Error) {
        // Out-of-range references: the lowering cannot even name rows.
        return VerifyReport { diagnostics: diags, peak_rows: 0 };
    }
    diags.extend(liveness_diags(plan));
    let mut peak_rows = 0;
    match lower_plan(plan) {
        Ok(script) => {
            diags.extend(check_script(&script));
            peak_rows = script.peak_rows;
            if peak_rows != plan.peak_rows {
                diags.push(Diagnostic::new(
                    DiagCode::RowBudgetOverflow,
                    None,
                    None,
                    format!(
                        "plan declares peak_rows {} but the replay reaches {peak_rows}",
                        plan.peak_rows
                    ),
                ));
            }
        }
        Err(d) => diags.push(d),
    }
    if let Some(b) = budget {
        let need = peak_rows.max(plan.peak_rows);
        if need > b {
            diags.push(Diagnostic::new(
                DiagCode::RowBudgetOverflow,
                None,
                None,
                format!("circuit needs {need} scratch rows, budget is {b}"),
            ));
        }
    }
    VerifyReport { diagnostics: diags, peak_rows }
}

/// Verify a raw circuit (no compiled plan): derives its own death
/// lists from the independent liveness pass, then runs the full plan
/// verification. This is the `pudtune lint` path for user-supplied
/// circuit files — shape violations surface as diagnostics, never as
/// compile errors.
pub fn verify_circuit(circuit: &MajCircuit) -> VerifyReport {
    verify_circuit_with_budget(circuit, None)
}

/// [`verify_circuit`] with a scratch-row budget (P004 on overflow).
pub fn verify_circuit_with_budget(circuit: &MajCircuit, budget: Option<usize>) -> VerifyReport {
    let mut deaths: Vec<Vec<Signal>> = vec![Vec::new(); circuit.gates.len()];
    for (&sig, &lu) in &independent_last_use(circuit) {
        if let Some(gi) = lu {
            deaths[gi].push(sig);
        }
    }
    // Probe the replay once for the true peak, so the assembled plan
    // carries a self-consistent `peak_rows` and any P004 the caller
    // sees is about the *budget*, not our own placeholder.
    let probe = WorkloadPlan::assemble(
        PudOp::Custom(circuit.clone()),
        circuit.clone(),
        deaths.clone(),
        0,
    );
    let peak = lower_plan(&probe).map(|s| s.peak_rows).unwrap_or(0);
    let plan =
        WorkloadPlan::assemble(PudOp::Custom(circuit.clone()), circuit.clone(), deaths, peak);
    verify_plan_with_budget(&plan, budget)
}

/// Bound on the admission memo below: if the process ever admits more
/// distinct hand-assembled plans than this, the memo is cleared
/// wholesale (re-verification is always safe, only slower).
const VERIFIED_MEMO_CAP: usize = 1024;

/// Fingerprints of hand-assembled plans that already passed full
/// verification — [`admit`]'s process-wide memo.
fn verified_memo() -> &'static Mutex<HashSet<u64>> {
    static MEMO: OnceLock<Mutex<HashSet<u64>>> = OnceLock::new();
    MEMO.get_or_init(|| Mutex::new(HashSet::new()))
}

/// Admission gate for the executor, compute engines and the serving
/// layer: a compiler-verified plan passes in O(1); anything else (a
/// hand-assembled plan) is fully verified once per process — admission
/// results are memoized by [`WorkloadPlan::fingerprint`], so a custom
/// plan served repeatedly through `serve_plan` pays full
/// re-verification only on its first serve. Only admissible plans are
/// memoized (warning-only reports included, matching the non-memoized
/// semantics); rejections are always re-derived so the caller gets the
/// full diagnostic every time.
pub fn admit(plan: &WorkloadPlan) -> Result<(), PudError> {
    if plan.is_verified() {
        return Ok(());
    }
    let fp = plan.fingerprint();
    if verified_memo().lock().expect("admission memo poisoned").contains(&fp) {
        return Ok(());
    }
    let report = verify_plan(plan);
    match report.errors().next() {
        Some(d) => Err(d.clone().into()),
        None => {
            let mut memo = verified_memo().lock().expect("admission memo poisoned");
            if memo.len() >= VERIFIED_MEMO_CAP {
                memo.clear();
            }
            memo.insert(fp);
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// Circuit text format (pudtune lint)
// ---------------------------------------------------------------------------

/// Parse the `pudtune lint` circuit file format:
///
/// ```text
/// # comment
/// inputs 2
/// gate i0 i1 0        # MAJ3 over input 0, input 1, const 0
/// gate i0 i1 g0 g0 1  # MAJ5; gN = gate N's output
/// output g1
/// output !g0          # negated signals: !iN / !gN
/// ```
///
/// The parser is deliberately permissive — wrong arities, out-of-range
/// and forward references all parse, so the *verifier* reports them as
/// P008 diagnostics instead of the parser hiding them.
pub fn parse_circuit(text: &str) -> Result<MajCircuit, String> {
    let mut circuit = MajCircuit::new(0);
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut toks = line.split_whitespace();
        let key = toks.next().unwrap();
        let parse_sig = |tok: &str| -> Result<Signal, String> {
            let (neg, body) = match tok.strip_prefix('!') {
                Some(rest) => (true, rest),
                None => (false, tok),
            };
            let sig = if let Some(n) = body.strip_prefix('i') {
                let i: usize =
                    n.parse().map_err(|_| format!("line {}: bad input '{tok}'", ln + 1))?;
                if neg { Signal::NotInput(i) } else { Signal::Input(i) }
            } else if let Some(n) = body.strip_prefix('g') {
                let g: usize =
                    n.parse().map_err(|_| format!("line {}: bad gate '{tok}'", ln + 1))?;
                if neg { Signal::NotGate(g) } else { Signal::Gate(g) }
            } else if body == "0" && !neg {
                Signal::Const(false)
            } else if body == "1" && !neg {
                Signal::Const(true)
            } else {
                return Err(format!("line {}: bad signal '{tok}'", ln + 1));
            };
            Ok(sig)
        };
        match key {
            "inputs" => {
                let n = toks
                    .next()
                    .ok_or_else(|| format!("line {}: inputs needs a count", ln + 1))?;
                circuit.n_inputs =
                    n.parse().map_err(|_| format!("line {}: bad count '{n}'", ln + 1))?;
            }
            "gate" => {
                let args: Result<Vec<Signal>, String> = toks.map(parse_sig).collect();
                circuit.gates.push(Gate { args: args? });
            }
            "output" => {
                let tok = toks
                    .next()
                    .ok_or_else(|| format!("line {}: output needs a signal", ln + 1))?;
                circuit.outputs.push(parse_sig(tok)?);
            }
            other => return Err(format!("line {}: unknown directive '{other}'", ln + 1)),
        }
    }
    Ok(circuit)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::plan::BitwiseOp;

    fn compiled(op: PudOp) -> WorkloadPlan {
        WorkloadPlan::compile(op).unwrap()
    }

    #[test]
    fn vocabulary_plans_verify_clean() {
        for op in PudOp::vocabulary(8) {
            let label = op.label();
            let plan = compiled(op);
            let report = verify_plan(&plan);
            assert!(report.is_clean(), "{label}: {report}");
            assert_eq!(report.peak_rows, plan.peak_rows, "{label}: replay peak diverged");
        }
    }

    #[test]
    fn codes_are_stable_and_documented() {
        let codes: Vec<&str> = DiagCode::ALL.iter().map(|c| c.code()).collect();
        assert_eq!(
            codes,
            vec![
                "P001", "P002", "P003", "P004", "P005", "P006", "P007", "P008", "P009", "P010",
                "P011", "P012"
            ]
        );
        for c in DiagCode::ALL {
            assert!(!c.meaning().is_empty());
            assert!(!c.hint().is_empty());
        }
    }

    #[test]
    fn diagnostics_render_json_and_display() {
        let d = Diagnostic::new(
            DiagCode::UseAfterDeath,
            Some(3),
            Some(17),
            "read of row 17 \"after\" death".into(),
        );
        let j = d.to_json();
        assert!(j.contains("\"code\":\"P001\""), "{j}");
        assert!(j.contains("\"gate\":3"), "{j}");
        assert!(j.contains("\"row\":17"), "{j}");
        assert!(j.contains("\\\"after\\\""), "escaping: {j}");
        assert!(d.to_string().contains("error[P001] gate 3 row 17"), "{d}");
        let report = VerifyReport { diagnostics: vec![d], peak_rows: 9 };
        let rj = report.to_json();
        assert!(rj.contains("\"clean\":false"), "{rj}");
        assert!(rj.contains("\"peak_rows\":9"), "{rj}");
        assert!(!report.is_clean());
        assert_eq!(report.errors().count(), 1);
    }

    #[test]
    fn early_death_is_use_after_death() {
        // add2: move Input(0)'s death to gate 0 — its real consumers
        // at later gates now read a released row.
        let good = compiled(PudOp::Add { width: 2 });
        let mut deaths: Vec<Vec<Signal>> =
            (0..good.circuit.gates.len()).map(|gi| good.deaths(gi).to_vec()).collect();
        let victim = Signal::Input(0);
        let from = deaths
            .iter()
            .position(|l| l.contains(&victim))
            .expect("input 0 dies somewhere");
        assert!(from > 0, "need an earlier gate to move the death to");
        deaths[from].retain(|&s| s != victim);
        deaths[0].push(victim);
        let plan =
            WorkloadPlan::assemble(good.op.clone(), good.circuit.clone(), deaths, good.peak_rows);
        let report = verify_plan(&plan);
        assert!(report.has(DiagCode::UseAfterDeath), "{report}");
        assert!(report.has(DiagCode::DeathListMismatch), "{report}");
        assert!(admit(&plan).is_err());
    }

    #[test]
    fn script_mutations_hit_the_state_machine() {
        let plan = compiled(PudOp::MajReduce { m: 3 });
        let script = lower_plan(&plan).unwrap();
        assert!(check_script(&script).is_empty());

        // Drop a SiMRA restore: the calibration slots stay analog, so
        // the next command over them is P002 or the exit is P006.
        let mut broken = script.clone();
        for op in broken.ops.iter_mut() {
            if let ChargeOp::Simra { restore, .. } = op {
                *restore = false;
            }
        }
        let diags = check_script(&broken);
        assert!(
            diags.iter().any(|d| matches!(d.code, DiagCode::DoubleFrac | DiagCode::UnrestoredExit)),
            "{diags:?}"
        );

        // Duplicate a Frac burst: P002 exactly.
        let mut doubled = script.clone();
        let fi = doubled
            .ops
            .iter()
            .position(|op| matches!(op, ChargeOp::Frac { .. }))
            .unwrap();
        let dup = doubled.ops[fi].clone();
        doubled.ops.insert(fi + 1, dup);
        assert!(check_script(&doubled).iter().any(|d| d.code == DiagCode::DoubleFrac));

        // Drop the first data-row write: its readers hit Uninitialized.
        let mut unwritten = script.clone();
        let wi = unwritten
            .ops
            .iter()
            .position(|op| matches!(op, ChargeOp::Write { row, .. } if *row >= DATA_BASE))
            .unwrap();
        unwritten.ops.remove(wi);
        assert!(check_script(&unwritten)
            .iter()
            .any(|d| d.code == DiagCode::ReadUninitialized));
    }

    #[test]
    fn budget_overflow_is_p004() {
        let plan = compiled(PudOp::Mul { width: 4 });
        let report = verify_plan_with_budget(&plan, Some(plan.peak_rows - 1));
        assert!(report.has(DiagCode::RowBudgetOverflow), "{report}");
        assert!(verify_plan_with_budget(&plan, Some(plan.peak_rows)).is_clean());
    }

    #[test]
    fn dead_gate_is_a_warning() {
        let mut c = MajCircuit::new(2);
        let g = c.push(Gate::maj3(Signal::Input(0), Signal::Input(1), Signal::Const(false)));
        c.push(Gate::maj3(Signal::Input(0), Signal::Input(1), Signal::Const(true)));
        c.output(g);
        let report = verify_circuit(&c);
        assert!(report.has(DiagCode::DeadGate), "{report}");
        assert_eq!(report.errors().count(), 0, "{report}");
        // A dead gate compiles (warning), but still fails lint.
        let plan = WorkloadPlan::from_circuit(c).unwrap();
        assert!(verify_plan(&plan).has(DiagCode::DeadGate));
        assert!(admit(&plan).is_ok());
    }

    #[test]
    fn shape_violations_are_p008() {
        // 4-ary gate.
        let mut c = MajCircuit::new(2);
        c.gates.push(Gate {
            args: vec![
                Signal::Input(0),
                Signal::Input(1),
                Signal::Const(false),
                Signal::Const(true),
            ],
        });
        c.outputs.push(Signal::Gate(0));
        assert!(verify_circuit(&c).has(DiagCode::ShapeMismatch));

        // Bumped input index (out of range).
        let mut plan = compiled(PudOp::Bitwise(BitwiseOp::And));
        plan.circuit.gates[0].args[0] = Signal::Input(7);
        assert!(verify_plan(&plan).has(DiagCode::ShapeMismatch));

        // Forward gate reference.
        let mut fwd = MajCircuit::new(1);
        fwd.gates.push(Gate {
            args: vec![Signal::Gate(5), Signal::Input(0), Signal::Const(false)],
        });
        fwd.outputs.push(Signal::Gate(0));
        assert!(verify_circuit(&fwd).has(DiagCode::ShapeMismatch));
    }

    #[test]
    fn lint_format_roundtrips() {
        let text = "
# MAJ3 with a spare negation
inputs 3
gate i0 i1 i2
gate !g0 0 1   # identity of the negation
output g1
";
        let c = parse_circuit(text).unwrap();
        assert_eq!(c.n_inputs, 3);
        assert_eq!(c.gates.len(), 2);
        assert_eq!(c.gates[1].args[0], Signal::NotGate(0));
        assert_eq!(c.outputs, vec![Signal::Gate(1)]);
        assert!(verify_circuit(&c).is_clean());

        assert!(parse_circuit("gate i0 iX 0").is_err());
        assert!(parse_circuit("widgets 3").is_err());
        // Malformed shapes parse; the verifier reports them.
        let four = parse_circuit("inputs 1\ngate i0 i0 0 1\noutput g0").unwrap();
        assert!(verify_circuit(&four).has(DiagCode::ShapeMismatch));
    }

    #[test]
    fn vocabulary_covers_every_op_family() {
        let v = PudOp::vocabulary(16);
        assert!(v.contains(&PudOp::Bitwise(BitwiseOp::And)));
        assert!(v.contains(&PudOp::Bitwise(BitwiseOp::Or)));
        assert!(v.contains(&PudOp::Bitwise(BitwiseOp::Not)));
        assert!(v.contains(&PudOp::MajReduce { m: 3 }));
        assert!(v.contains(&PudOp::MajReduce { m: 5 }));
        for w in 1..=16 {
            assert!(v.contains(&PudOp::Add { width: w }));
            assert!(v.contains(&PudOp::Mul { width: w }));
        }
    }
}
