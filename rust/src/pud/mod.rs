//! Processing-Using-DRAM operation library.
//!
//! Everything computable in the subarray is built from three primitives
//! (RowCopy / Frac / SiMRA, provided by `dram::subarray` + the
//! `controller` timing): the MAJX majority votes, boolean logic
//! (AND/OR via constant-biased MAJ3, NOT via inverted write-back),
//! full adders (MVDRAM construction), ripple-carry addition and
//! shift-and-add multiplication, plus a small majority-graph IR with a
//! row allocator so circuits schedule onto the subarray's row budget.
//!
//! ## Plan → lower → fuse → execute layering
//!
//! Workloads flow through one canonical pipeline, mirroring the
//! calibration stack's request/engine/service split:
//!
//! 1. **plan** — a [`plan::PudOp`] names the workload; compiling it
//!    into a [`plan::WorkloadPlan`] runs circuit synthesis, last-use
//!    analysis and command-cost pricing *once*, yielding a bank-
//!    agnostic, `Arc`-shareable artifact. Malformed shapes surface as
//!    typed [`plan::PudError`]s, not panics;
//! 2. **lower** — the plan lowers once into the canonical
//!    [`verify::LoweredPlan`]: a typed step stream
//!    ([`verify::LoweredStep`]) plus the flat abstract command script
//!    the static verifier's charge-state machine checks. Lowering and
//!    verification are **the same single pass**
//!    ([`verify::lower_plan_full`]), so the program that executes is —
//!    by construction — the program that was verified. The lowering is
//!    cached on the plan ([`plan::WorkloadPlan::lowered`]) and, for
//!    serving/CLI paths, in the process-wide
//!    [`crate::coordinator::plancache::PlanCache`] keyed by
//!    (op, geometry);
//! 3. **fuse** — [`crate::calib::engine::ComputeEngine::execute_batch`]
//!    groups requests by ([`plan::WorkloadPlan::fingerprint`],
//!    geometry) and walks each group's banks through the shared step
//!    stream **step-major** in one worker-pool dispatch per batch
//!    (per-bank RNG streams make the interleaving bit-invisible); the
//!    PJRT engine accounts unfusable step classes per step
//!    (`pjrt.compute.fallback`) and runs the same fused dispatch on
//!    its resident native fallback engine;
//! 4. **execute** — [`exec::run_plan`] / [`exec::run_lowered`]
//!    interpret the step stream against a subarray
//!    ([`exec::StepRunner`], the same interpreter the fused path
//!    drives per bank), and `RecalibService::serve_workload`
//!    ([`crate::coordinator::service`]) serves it on every registered
//!    subarray under its *current* calibration and drift state, so
//!    arithmetic serving and drift-scheduled recalibration share one
//!    lifecycle.
//!
//! * [`majx`] — MAJX execution flows, conventional and PUDTune;
//! * [`logic`] — AND / OR / NOT;
//! * [`fulladder`] — sum/carry from MAJ3 + MAJ5 (MVDRAM);
//! * [`adder`] — 8-bit (and general-width) ripple-carry addition;
//! * [`multiplier`] — 8-bit shift-and-add multiplication;
//! * [`graph`] — majority-graph IR + op/ACT cost accounting;
//! * [`plan`] — the `PudOp` workload vocabulary and one-time plan
//!   compilation (typed errors, death lists, peak-row precomputation);
//! * [`rowalloc`] — scratch-row allocation inside the subarray;
//! * [`exec`] — the lowered-step interpreter (single-bank and the
//!   per-bank core of fused batches);
//! * [`verify`] — the canonical lowering + static charge-state
//!   verifier (below);
//! * [`ranges`] — bit-level range analysis over the gate DAG and the
//!   width-narrowing transform ([`plan::WorkloadPlan::narrowed`]):
//!   declared operand ranges fold provably-constant bits, strip
//!   unobservable gates, and let the serving paths transparently pick
//!   a narrower (fewer gates, fewer steps) variant per range class.
//!
//! ## Diagnostics
//!
//! [`verify`] lowers every plan to the abstract command stream the
//! executor would issue and checks it against a four-state row machine
//! (Uninitialized → Packed ⇄ Fracd-analog → Dead), plus independent
//! liveness/shape analyses. Violations carry stable codes:
//!
//! | Code | Severity | Meaning | Fix hint |
//! |------|----------|---------|----------|
//! | `P001` | error | use after death: a row is consumed after its release | move the signal's death entry to (or after) its true last consumer |
//! | `P002` | error | double-Frac / analog aliasing: charge op on a row already holding analog charge | restore the row with a SiMRA before charging or reusing it |
//! | `P003` | error | read of a never-written row | write the row (input, constant or gate result) first |
//! | `P004` | error | row-budget overflow, or replayed peak disagrees with the compiled `peak_rows` | shrink the circuit's live set or recompile to refresh `peak_rows` |
//! | `P005` | warning | dead gate: a gate's output is never consumed | drop the gate or route its output to a consumer/output |
//! | `P006` | error | plan exits with analog rows un-restored | end every MAJX flow with its SiMRA restore |
//! | `P007` | error | death lists disagree with independent last-use analysis | recompile the plan instead of editing death lists |
//! | `P008` | error | gate arity / signal range / operand shape mismatch | use 3- or 5-ary gates over in-range, already-defined signals |
//! | `P009` | warning | output bit is provably constant under the declared operand ranges | serve a narrowed variant (`WorkloadPlan::narrowed`) or widen the declared ranges |
//! | `P010` | warning | gate is consumed but unobservable at any output under the declared ranges | narrow the plan to strip the gate, or widen the declared ranges |
//! | `P011` | warning | carry/overflow bit is impossible by value-interval analysis | serve a narrowed variant; the carry chain above this bit is unnecessary |
//! | `P012` | warning | plan admits a strictly smaller width-narrowed variant for these ranges | register the narrowed variant in the `PlanCache` under its range class |
//!
//! [`plan::WorkloadPlan::compile`] verifies its own output (errors fail
//! the compile as [`plan::PudError::Verification`]); the executor,
//! compute engines and `RecalibService::serve_plan` re-verify any plan
//! that did not come out of `compile` before admission; and `pudtune
//! lint` sweeps the whole built-in vocabulary plus user-supplied
//! circuit files, exiting nonzero on error-severity diagnostics
//! (warnings too under `--deny-warnings`). `pudtune analyze` runs the
//! [`ranges`] pass (P009–P012) over the vocabulary (or `--op`-selected
//! ops) under declared operand ranges, cross-checked by a concrete
//! soundness sweep.

pub mod adder;
pub mod exec;
pub mod fulladder;
pub mod graph;
pub mod logic;
pub mod majx;
pub mod multiplier;
pub mod plan;
pub mod ranges;
pub mod rowalloc;
pub mod verify;
