//! Processing-Using-DRAM operation library.
//!
//! Everything computable in the subarray is built from three primitives
//! (RowCopy / Frac / SiMRA, provided by `dram::subarray` + the
//! `controller` timing): the MAJX majority votes, boolean logic
//! (AND/OR via constant-biased MAJ3, NOT via inverted write-back),
//! full adders (MVDRAM construction), ripple-carry addition and
//! shift-and-add multiplication, plus a small majority-graph IR with a
//! row allocator so circuits schedule onto the subarray's row budget.
//!
//! * [`majx`] — MAJX execution flows, conventional and PUDTune;
//! * [`logic`] — AND / OR / NOT;
//! * [`fulladder`] — sum/carry from MAJ3 + MAJ5 (MVDRAM);
//! * [`adder`] — 8-bit (and general-width) ripple-carry addition;
//! * [`multiplier`] — 8-bit shift-and-add multiplication;
//! * [`graph`] — majority-graph IR + op/ACT cost accounting;
//! * [`rowalloc`] — scratch-row allocation inside the subarray;
//! * [`exec`] — graph execution against the golden model.

pub mod adder;
pub mod exec;
pub mod fulladder;
pub mod graph;
pub mod logic;
pub mod majx;
pub mod multiplier;
pub mod rowalloc;
