//! Processing-Using-DRAM operation library.
//!
//! Everything computable in the subarray is built from three primitives
//! (RowCopy / Frac / SiMRA, provided by `dram::subarray` + the
//! `controller` timing): the MAJX majority votes, boolean logic
//! (AND/OR via constant-biased MAJ3, NOT via inverted write-back),
//! full adders (MVDRAM construction), ripple-carry addition and
//! shift-and-add multiplication, plus a small majority-graph IR with a
//! row allocator so circuits schedule onto the subarray's row budget.
//!
//! ## Plan → engine → serve layering
//!
//! Workloads flow through three layers, mirroring the calibration
//! stack's request/engine/service split:
//!
//! 1. **plan** — a [`plan::PudOp`] names the workload; compiling it
//!    into a [`plan::WorkloadPlan`] runs circuit synthesis, last-use
//!    analysis and command-cost pricing *once*, yielding a bank-
//!    agnostic, `Arc`-shareable artifact. Malformed shapes surface as
//!    typed [`plan::PudError`]s, not panics;
//! 2. **engine** — [`crate::calib::engine::ComputeEngine`] executes
//!    batches of `ComputeRequest`s (plan + bank + calibration +
//!    error-free column mask) on a backend: the native engine fans
//!    across the worker pool via [`exec::run_plan`], the PJRT engine
//!    currently falls back per bank;
//! 3. **serve** — `RecalibService::serve_workload`
//!    ([`crate::coordinator::service`]) runs workloads on every
//!    registered subarray under its *current* calibration and drift
//!    state, so arithmetic serving and drift-scheduled recalibration
//!    share one lifecycle.
//!
//! * [`majx`] — MAJX execution flows, conventional and PUDTune;
//! * [`logic`] — AND / OR / NOT;
//! * [`fulladder`] — sum/carry from MAJ3 + MAJ5 (MVDRAM);
//! * [`adder`] — 8-bit (and general-width) ripple-carry addition;
//! * [`multiplier`] — 8-bit shift-and-add multiplication;
//! * [`graph`] — majority-graph IR + op/ACT cost accounting;
//! * [`plan`] — the `PudOp` workload vocabulary and one-time plan
//!   compilation (typed errors, death lists, peak-row precomputation);
//! * [`rowalloc`] — scratch-row allocation inside the subarray;
//! * [`exec`] — plan execution against the golden model.

pub mod adder;
pub mod exec;
pub mod fulladder;
pub mod graph;
pub mod logic;
pub mod majx;
pub mod multiplier;
pub mod plan;
pub mod rowalloc;
