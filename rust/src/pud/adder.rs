//! Ripple-carry addition from majority full adders (8-bit in Table I).

use crate::pud::fulladder::full_adder;
use crate::pud::graph::{CircuitCost, MajCircuit, Signal};

/// Build a `width`-bit ripple-carry adder.
///
/// Inputs: a[0..width] (LSB first) then b[0..width].
/// Outputs: sum[0..width] then carry-out.
pub fn ripple_adder(width: usize) -> MajCircuit {
    assert!(width >= 1);
    let mut c = MajCircuit::new(2 * width);
    let mut carry = Signal::Const(false);
    let mut sums = Vec::with_capacity(width);
    for i in 0..width {
        let (s, co) = full_adder(&mut c, Signal::Input(i), Signal::Input(width + i), carry);
        sums.push(s);
        carry = co;
    }
    for s in sums {
        c.output(s);
    }
    c.output(carry);
    c
}

/// Cost of the paper's 8-bit addition.
pub fn add8_cost() -> CircuitCost {
    ripple_adder(8).cost()
}

/// Reference: evaluate the adder on integers.
pub fn eval_add(c: &MajCircuit, width: usize, a: u64, b: u64) -> u64 {
    let mut ins = vec![false; 2 * width];
    for i in 0..width {
        ins[i] = (a >> i) & 1 == 1;
        ins[width + i] = (b >> i) & 1 == 1;
    }
    let out = c.eval(&ins);
    let mut v = 0u64;
    for (i, &bit) in out.iter().enumerate() {
        if bit {
            v |= 1 << i;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn adds_exhaustively_4bit() {
        let c = ripple_adder(4);
        for a in 0..16u64 {
            for b in 0..16u64 {
                assert_eq!(eval_add(&c, 4, a, b), a + b, "{a}+{b}");
            }
        }
    }

    #[test]
    fn adds_random_8bit() {
        let c = ripple_adder(8);
        proptest::check(
            "add8-matches-integer-addition",
            0xADD,
            proptest::DEFAULT_CASES,
            |r: &mut Rng| (r.below(256), r.below(256)),
            |&(a, b)| eval_add(&c, 8, a, b) == a + b,
        );
    }

    #[test]
    fn add8_cost_structure() {
        let cost = add8_cost();
        assert_eq!(cost.maj3, 8);
        assert_eq!(cost.maj5, 8);
        assert_eq!(cost.not_ops, 8);
    }
}
