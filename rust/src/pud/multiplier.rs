//! Shift-and-add multiplication from majority gates (8-bit in Table I):
//! partial products via AND (constant-biased MAJ3), accumulated with
//! ripple-carry rows of full adders.

use crate::pud::fulladder::full_adder;
use crate::pud::graph::{CircuitCost, MajCircuit, Signal};
use crate::pud::logic::and;

/// Build a `width x width -> 2*width` array multiplier.
///
/// Inputs: a[0..width] (LSB first) then b[0..width].
/// Outputs: product[0..2*width].
pub fn array_multiplier(width: usize) -> MajCircuit {
    assert!(width >= 1);
    let mut c = MajCircuit::new(2 * width);
    // Partial products pp[i][j] = a[j] & b[i].
    let mut pp = vec![vec![Signal::Const(false); width]; width];
    for (i, row) in pp.iter_mut().enumerate() {
        for (j, cell) in row.iter_mut().enumerate() {
            *cell = and(&mut c, Signal::Input(j), Signal::Input(width + i));
        }
    }
    // Accumulate rows: acc starts as pp[0] zero-extended.
    let mut acc: Vec<Signal> = Vec::with_capacity(2 * width);
    acc.extend_from_slice(&pp[0]);
    acc.resize(2 * width, Signal::Const(false));
    for (i, row) in pp.iter().enumerate().skip(1) {
        // Add row << i into acc with a ripple chain over `width` bits
        // plus carry propagation into the tail.
        let mut carry = Signal::Const(false);
        for j in 0..width {
            let (s, co) = full_adder(&mut c, acc[i + j], row[j], carry);
            acc[i + j] = s;
            carry = co;
        }
        // Propagate the final carry into the next accumulator bit.
        // Untouched accumulator bits are still constant 0, so the carry
        // drops straight in without a gate (saves ~w full adders per
        // row vs naive tail ripple).
        let mut pos = i + width;
        while pos < 2 * width && carry != Signal::Const(false) {
            if acc[pos] == Signal::Const(false) {
                acc[pos] = carry;
                carry = Signal::Const(false);
                break;
            }
            let (s, co) = full_adder(&mut c, acc[pos], carry, Signal::Const(false));
            acc[pos] = s;
            carry = co;
            pos += 1;
        }
    }
    for s in acc {
        c.output(s);
    }
    c
}

/// Cost of the paper's 8-bit multiplication.
pub fn mul8_cost() -> CircuitCost {
    array_multiplier(8).cost()
}

/// Reference: evaluate the multiplier on integers.
pub fn eval_mul(c: &MajCircuit, width: usize, a: u64, b: u64) -> u64 {
    let mut ins = vec![false; 2 * width];
    for i in 0..width {
        ins[i] = (a >> i) & 1 == 1;
        ins[width + i] = (b >> i) & 1 == 1;
    }
    let out = c.eval(&ins);
    let mut v = 0u64;
    for (i, &bit) in out.iter().enumerate() {
        if bit {
            v |= 1 << i;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn multiplies_exhaustively_4bit() {
        let c = array_multiplier(4);
        for a in 0..16u64 {
            for b in 0..16u64 {
                assert_eq!(eval_mul(&c, 4, a, b), a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    fn multiplies_random_8bit() {
        let c = array_multiplier(8);
        proptest::check(
            "mul8-matches-integer-multiplication",
            0x3A15,
            proptest::DEFAULT_CASES,
            |r: &mut Rng| (r.below(256), r.below(256)),
            |&(a, b)| eval_mul(&c, 8, a, b) == a * b,
        );
    }

    #[test]
    fn mul8_cost_structure() {
        let cost = mul8_cost();
        // 64 ANDs for partial products plus the adder army.
        assert_eq!(cost.maj3, 64 + cost.maj5);
        assert!(cost.maj5 >= 56, "maj5={}", cost.maj5);
        // Ratio vs a single MAJ5 ~ the paper's ADD:MUL throughput gap.
        let add = crate::pud::adder::add8_cost();
        let mul_majors = cost.maj3 + cost.maj5;
        let add_majors = add.maj3 + add.maj5;
        assert!(mul_majors / add_majors >= 7, "{mul_majors} vs {add_majors}");
    }
}
