//! Majority-graph IR.
//!
//! PUD computes by chaining MAJX operations (paper §I: "by constructing
//! majority-based computational graphs, PUD enables primitive operations
//! and complex calculations"). A [`MajCircuit`] is a DAG of MAJ3/MAJ5
//! gates over input wires, constants and negated signals; circuits are
//! built by `logic` / `fulladder` / `adder` / `multiplier`, evaluated
//! functionally for reference, costed for the throughput model, and
//! executed bit-serially on the subarray by `exec`.
//!
//! Validation is typed: the `try_*` builder/eval forms return
//! [`PudError`] so externally supplied circuits and inputs (e.g.
//! [`crate::pud::plan::PudOp::Custom`] workloads) fail as one bank's
//! error instead of a panic; the panicking `push`/`output`/`eval`
//! wrappers remain for circuit constructors whose shapes are correct
//! by construction.

use crate::pud::plan::PudError;

/// A signal consumed by a gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Signal {
    /// Primary input `i`.
    Input(usize),
    /// Output of gate `g` (must precede the consuming gate).
    Gate(usize),
    /// Constant 0/1 (the subarray's reserved constant rows).
    Const(bool),
    /// Negation of a gate output (computed via inverted write-back).
    NotGate(usize),
    /// Negation of a primary input.
    NotInput(usize),
}

/// A majority gate (arity 3 or 5).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Gate {
    pub args: Vec<Signal>,
}

impl Gate {
    pub fn maj3(a: Signal, b: Signal, c: Signal) -> Self {
        Self { args: vec![a, b, c] }
    }

    pub fn maj5(a: Signal, b: Signal, c: Signal, d: Signal, e: Signal) -> Self {
        Self { args: vec![a, b, c, d, e] }
    }

    pub fn arity(&self) -> usize {
        self.args.len()
    }
}

/// Cost summary of a circuit (consumed by `analysis::throughput`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CircuitCost {
    pub maj3: u32,
    pub maj5: u32,
    /// Distinct negations that must be materialised.
    pub not_ops: u32,
}

/// A majority DAG. Gates are stored in topological order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MajCircuit {
    pub n_inputs: usize,
    pub gates: Vec<Gate>,
    pub outputs: Vec<Signal>,
}

impl MajCircuit {
    pub fn new(n_inputs: usize) -> Self {
        Self { n_inputs, gates: Vec::new(), outputs: Vec::new() }
    }

    /// Append a gate; returns its signal. Typed-error form of
    /// [`Self::push`] for externally supplied shapes.
    pub fn try_push(&mut self, gate: Gate) -> Result<Signal, PudError> {
        for s in &gate.args {
            self.check_signal(*s, self.gates.len())?;
        }
        if gate.arity() != 3 && gate.arity() != 5 {
            return Err(PudError::MalformedCircuit(format!(
                "majority gates are 3- or 5-ary, got arity {}",
                gate.arity()
            )));
        }
        self.gates.push(gate);
        Ok(Signal::Gate(self.gates.len() - 1))
    }

    /// Append a gate; panics on an invalid shape (builder convenience
    /// for constructors that are correct by construction).
    pub fn push(&mut self, gate: Gate) -> Signal {
        self.try_push(gate).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Declare an output signal; typed-error form of [`Self::output`].
    pub fn try_output(&mut self, s: Signal) -> Result<(), PudError> {
        self.check_signal(s, self.gates.len())?;
        self.outputs.push(s);
        Ok(())
    }

    pub fn output(&mut self, s: Signal) {
        self.try_output(s).unwrap_or_else(|e| panic!("{e}"))
    }

    fn check_signal(&self, s: Signal, upto: usize) -> Result<(), PudError> {
        match s {
            Signal::Input(i) | Signal::NotInput(i) if i >= self.n_inputs => {
                Err(PudError::MalformedCircuit(format!(
                    "input {i} out of range (circuit has {} inputs)",
                    self.n_inputs
                )))
            }
            Signal::Gate(g) | Signal::NotGate(g) if g >= upto => Err(
                PudError::MalformedCircuit(format!("gate {g} referenced before definition")),
            ),
            _ => Ok(()),
        }
    }

    /// Re-validate a complete (possibly externally supplied) circuit:
    /// gate arities, topological references, output references.
    pub fn validate(&self) -> Result<(), PudError> {
        for (gi, gate) in self.gates.iter().enumerate() {
            if gate.arity() != 3 && gate.arity() != 5 {
                return Err(PudError::MalformedCircuit(format!(
                    "gate {gi} is {}-ary; majority gates are 3- or 5-ary",
                    gate.arity()
                )));
            }
            for &s in &gate.args {
                self.check_signal(s, gi)?;
            }
        }
        for &s in &self.outputs {
            self.check_signal(s, self.gates.len())?;
        }
        Ok(())
    }

    /// Functional evaluation (the logic-level reference); typed-error
    /// form of [`Self::eval`].
    pub fn try_eval(&self, inputs: &[bool]) -> Result<Vec<bool>, PudError> {
        if inputs.len() != self.n_inputs {
            return Err(PudError::ArityMismatch {
                expected: self.n_inputs,
                got: inputs.len(),
            });
        }
        let mut vals = Vec::with_capacity(self.gates.len());
        let get = |vals: &Vec<bool>, s: Signal| -> bool {
            match s {
                Signal::Input(i) => inputs[i],
                Signal::NotInput(i) => !inputs[i],
                Signal::Gate(g) => vals[g],
                Signal::NotGate(g) => !vals[g],
                Signal::Const(b) => b,
            }
        };
        for gate in &self.gates {
            let ones = gate.args.iter().filter(|&&s| get(&vals, s)).count();
            vals.push(ones * 2 > gate.arity());
        }
        Ok(self.outputs.iter().map(|&s| get(&vals, s)).collect())
    }

    /// Functional evaluation; panics on an input-arity mismatch.
    pub fn eval(&self, inputs: &[bool]) -> Vec<bool> {
        self.try_eval(inputs).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Cost: gate counts plus distinct negations.
    pub fn cost(&self) -> CircuitCost {
        let mut c = CircuitCost::default();
        let mut notted: Vec<Signal> = Vec::new();
        let mut signals = Vec::new();
        for g in &self.gates {
            match g.arity() {
                3 => c.maj3 += 1,
                5 => c.maj5 += 1,
                // Malformed arities are priced as zero; the verifier
                // surfaces them as P008 instead of a panic here.
                _ => {}
            }
            signals.extend(g.args.iter().copied());
        }
        signals.extend(self.outputs.iter().copied());
        for s in signals {
            if matches!(s, Signal::NotGate(_) | Signal::NotInput(_)) && !notted.contains(&s) {
                notted.push(s);
                c.not_ops += 1;
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maj3_truth_table() {
        let mut c = MajCircuit::new(3);
        let g = Gate::maj3(Signal::Input(0), Signal::Input(1), Signal::Input(2));
        let s = c.push(g);
        c.output(s);
        for v in 0..8u32 {
            let ins = [(v & 1) != 0, (v & 2) != 0, (v & 4) != 0];
            let expect = ins.iter().filter(|&&b| b).count() >= 2;
            assert_eq!(c.eval(&ins), vec![expect]);
        }
    }

    #[test]
    fn maj5_with_negation() {
        // MAJ5(a, a, ¬a, 0, 1) = a
        let mut c = MajCircuit::new(1);
        let g = c.push(Gate::maj5(
            Signal::Input(0),
            Signal::Input(0),
            Signal::NotInput(0),
            Signal::Const(false),
            Signal::Const(true),
        ));
        c.output(g);
        assert_eq!(c.eval(&[true]), vec![true]);
        assert_eq!(c.eval(&[false]), vec![false]);
    }

    #[test]
    fn cost_counts_distinct_nots() {
        let mut c = MajCircuit::new(2);
        let g0 = c.push(Gate::maj3(Signal::Input(0), Signal::Input(1), Signal::Const(false)));
        let Signal::Gate(i0) = g0 else { unreachable!() };
        let _g1 = c.push(Gate::maj5(
            Signal::Input(0),
            Signal::Input(1),
            Signal::NotGate(i0),
            Signal::NotGate(i0), // same negation reused
            Signal::Const(true),
        ));
        let cost = c.cost();
        assert_eq!(cost.maj3, 1);
        assert_eq!(cost.maj5, 1);
        assert_eq!(cost.not_ops, 1);
    }

    #[test]
    #[should_panic(expected = "referenced before definition")]
    fn forward_reference_rejected() {
        let mut c = MajCircuit::new(1);
        c.push(Gate::maj3(Signal::Gate(5), Signal::Input(0), Signal::Const(false)));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bad_input_rejected() {
        let mut c = MajCircuit::new(1);
        c.output(Signal::Input(3));
    }

    #[test]
    fn try_forms_return_typed_errors() {
        use crate::pud::plan::PudError;
        let mut c = MajCircuit::new(1);
        let err = c
            .try_push(Gate::maj3(Signal::Gate(5), Signal::Input(0), Signal::Const(false)))
            .unwrap_err();
        assert!(matches!(err, PudError::MalformedCircuit(_)));
        assert!(c.gates.is_empty(), "failed push must not mutate the circuit");
        assert!(c.try_output(Signal::Input(3)).is_err());
        let bad_arity = Gate { args: vec![Signal::Input(0), Signal::Const(true)] };
        assert!(c.try_push(bad_arity).is_err());

        let g = c.try_push(Gate::maj3(
            Signal::Input(0),
            Signal::Const(false),
            Signal::Const(true),
        ));
        assert_eq!(g, Ok(Signal::Gate(0)));
        c.try_output(Signal::Gate(0)).unwrap();
        assert_eq!(
            c.try_eval(&[true, false]),
            Err(PudError::ArityMismatch { expected: 1, got: 2 })
        );
        assert_eq!(c.try_eval(&[true]), Ok(vec![true]));
    }

    #[test]
    fn validate_catches_hand_built_corruption() {
        let mut c = MajCircuit::new(2);
        let g = c.push(Gate::maj3(Signal::Input(0), Signal::Input(1), Signal::Const(false)));
        c.output(g);
        assert!(c.validate().is_ok());
        // Corrupt the stored shape the way an external circuit could.
        c.gates[0].args[0] = Signal::Gate(9);
        assert!(c.validate().is_err());
        c.gates[0].args[0] = Signal::Input(0);
        c.outputs.push(Signal::NotGate(4));
        assert!(c.validate().is_err());
    }
}
