//! MAJX execution flows on the subarray (paper Fig. 1 / §III-D).
//!
//! Conventional and PUDTune MAJX share one flow; they differ only in
//! what the three non-operand rows hold (uniform neutral pattern vs
//! per-column calibration bits) and in the per-row Frac counts:
//!
//! 1. RowCopy the m operand rows and the 3 calibration rows (plus the
//!    constant rows for MAJ3) into the aligned 8-row SiMRA group;
//! 2. apply the configured number of Frac operations to each
//!    calibration row (step ②' of the paper);
//! 3. SiMRA — charge share + sense; the result lands in all 8 rows;
//! 4. read the result out.

use crate::calib::algorithm::Calibration;
use crate::calib::lattice::FracConfig;
use crate::config::system::Ddr4Timing;
use crate::controller::bender::{BenderProgram, RunResult};
use crate::dram::geometry::RowMap;
use crate::dram::subarray::Subarray;

/// Majority arity supported under 8-row SiMRA.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MajX {
    Maj3,
    Maj5,
}

impl MajX {
    pub fn m(&self) -> usize {
        match self {
            MajX::Maj3 => 3,
            MajX::Maj5 => 5,
        }
    }
}

/// Write the identified calibration bits and constants into the
/// subarray's reserved rows (done once per device, paper §III-A; the
/// bits come from the NV store on real systems).
pub fn setup_subarray(sub: &mut Subarray, map: &RowMap, calib: &Calibration) {
    for (i, &row) in map.calib_store.iter().enumerate() {
        let bits = calib.row_bits(i);
        sub.write_row(row, &bits);
    }
    sub.fill_row(map.const0, 0);
    sub.fill_row(map.const1, 1);
}

/// Execute one MAJX over `operand_rows` (data rows holding the m
/// operand bit-vectors). Returns the per-column majority decisions and
/// the command-level timing of the flow.
pub fn execute_majx(
    sub: &mut Subarray,
    map: &RowMap,
    x: MajX,
    operand_rows: &[usize],
    fc: &FracConfig,
    grade: &Ddr4Timing,
) -> (Vec<u8>, RunResult) {
    let m = x.m();
    assert_eq!(operand_rows.len(), m, "MAJ{m} takes {m} operand rows");
    let base = map.simra_base;
    let mut p = BenderProgram::new();
    // ①' operands into the group head.
    for (i, &r) in operand_rows.iter().enumerate() {
        p.row_copy(r, base + i);
    }
    // ①' calibration rows behind the operands.
    for (i, &store) in map.calib_store.iter().enumerate() {
        p.row_copy(store, base + m + i);
    }
    // Constant rows complete the 8-row group for MAJ3.
    if m + 3 < 8 {
        p.row_copy(map.const0, base + m + 3);
        p.row_copy(map.const1, base + m + 4);
    }
    // ②' per-row Frac applications.
    for (i, &n) in fc.fracs.iter().enumerate() {
        for _ in 0..n {
            p.frac(base + m + i);
        }
    }
    // ③ SiMRA (result restored into all 8 rows).
    p.simra(base);
    let mut run = p.run(sub, grade);
    let bits = run.reads.pop().expect("simra result");
    (bits, run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::algorithm::Calibration;
    use crate::calib::lattice::{FracConfig, OffsetLattice};
    use crate::config::device::DeviceConfig;

    fn quiet(cols: usize) -> Subarray {
        let mut cfg = DeviceConfig::default();
        cfg.sigma_sa = 1e-6;
        cfg.tail_weight = 0.0;
        cfg.sigma_noise = 1e-6;
        Subarray::with_geometry(&cfg, 64, cols, 3)
    }

    fn neutral_calib(sub: &Subarray, fc: &FracConfig) -> Calibration {
        Calibration::uniform(OffsetLattice::build(&sub.cfg, fc), sub.cols)
    }

    #[test]
    fn maj5_all_input_counts() {
        // On ideal columns the full flow computes MAJ5 for every
        // operand ones-count 0..=5.
        let fc = FracConfig::pudtune([2, 1, 0]);
        for ones in 0..=5usize {
            let mut sub = quiet(16);
            let map = RowMap::standard(sub.rows);
            let calib = neutral_calib(&sub, &fc);
            setup_subarray(&mut sub, &map, &calib);
            let rows: Vec<usize> = (0..5).map(|i| map.data_base + i).collect();
            for (i, &r) in rows.iter().enumerate() {
                sub.fill_row(r, (i < ones) as u8);
            }
            let (bits, run) =
                execute_majx(&mut sub, &map, MajX::Maj5, &rows, &fc, &Ddr4Timing::ddr4_2133());
            let expect = (ones >= 3) as u8;
            assert!(bits.iter().all(|&b| b == expect), "ones={ones}");
            assert!(run.elapsed_ns > 0.0);
        }
    }

    #[test]
    fn maj3_uses_constant_rows() {
        let fc = FracConfig::pudtune([2, 1, 0]);
        for ones in 0..=3usize {
            let mut sub = quiet(16);
            let map = RowMap::standard(sub.rows);
            let calib = neutral_calib(&sub, &fc);
            setup_subarray(&mut sub, &map, &calib);
            let rows: Vec<usize> = (0..3).map(|i| map.data_base + i).collect();
            for (i, &r) in rows.iter().enumerate() {
                sub.fill_row(r, (i < ones) as u8);
            }
            let (bits, _) =
                execute_majx(&mut sub, &map, MajX::Maj3, &rows, &fc, &Ddr4Timing::ddr4_2133());
            let expect = (ones >= 2) as u8;
            assert!(bits.iter().all(|&b| b == expect), "ones={ones}");
        }
    }

    #[test]
    fn baseline_flow_matches_conventional() {
        // B_{x,0,0}: neutral data = Frac'd 1 + const 0 + const 1.
        let fc = FracConfig::baseline(6);
        let mut sub = quiet(16);
        let map = RowMap::standard(sub.rows);
        let calib = neutral_calib(&sub, &fc);
        setup_subarray(&mut sub, &map, &calib);
        let rows: Vec<usize> = (0..5).map(|i| map.data_base + i).collect();
        for (i, &r) in rows.iter().enumerate() {
            sub.fill_row(r, (i < 2) as u8); // 2 ones -> majority 0
        }
        let (bits, _) =
            execute_majx(&mut sub, &map, MajX::Maj5, &rows, &fc, &Ddr4Timing::ddr4_2133());
        assert!(bits.iter().all(|&b| b == 0));
    }

    #[test]
    fn frac_count_hits_timing() {
        let mut sub = quiet(8);
        let map = RowMap::standard(sub.rows);
        let grade = Ddr4Timing::ddr4_2133();
        let rows: Vec<usize> = (0..5).map(|i| map.data_base + i).collect();
        let fc0 = FracConfig::pudtune([0, 0, 0]);
        let fc6 = FracConfig::pudtune([2, 2, 2]);
        let calib = neutral_calib(&sub, &fc0);
        setup_subarray(&mut sub, &map, &calib);
        let (_, r0) = execute_majx(&mut sub, &map, MajX::Maj5, &rows, &fc0, &grade);
        let (_, r6) = execute_majx(&mut sub, &map, MajX::Maj5, &rows, &fc6, &grade);
        assert!(r6.elapsed_ns > r0.elapsed_ns);
    }
}
