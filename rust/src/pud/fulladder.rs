//! Full adder from majority gates (the MVDRAM construction cited by the
//! paper): `cout = MAJ3(a, b, cin)`, `sum = MAJ5(a, b, cin, ¬cout, ¬cout)`.
//!
//! This is why MAJ5 reliability bottlenecks PUD arithmetic (paper
//! §II-C): every sum bit is a MAJ5.

use crate::pud::graph::{Gate, MajCircuit, Signal};
use crate::pud::logic::not;

/// Append a full adder; returns (sum, cout).
pub fn full_adder(c: &mut MajCircuit, a: Signal, b: Signal, cin: Signal) -> (Signal, Signal) {
    let cout = c.push(Gate::maj3(a, b, cin));
    let ncout = not(cout);
    let sum = c.push(Gate::maj5(a, b, cin, ncout, ncout));
    (sum, cout)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_adder_truth_table() {
        let mut c = MajCircuit::new(3);
        let (s, co) =
            full_adder(&mut c, Signal::Input(0), Signal::Input(1), Signal::Input(2));
        c.output(s);
        c.output(co);
        for v in 0..8u32 {
            let a = (v & 1) != 0;
            let b = (v & 2) != 0;
            let ci = (v & 4) != 0;
            let total = a as u32 + b as u32 + ci as u32;
            let out = c.eval(&[a, b, ci]);
            assert_eq!(out[0], total % 2 == 1, "sum for {a}{b}{ci}");
            assert_eq!(out[1], total >= 2, "carry for {a}{b}{ci}");
        }
    }

    #[test]
    fn full_adder_cost() {
        let mut c = MajCircuit::new(3);
        let (s, co) =
            full_adder(&mut c, Signal::Input(0), Signal::Input(1), Signal::Input(2));
        c.output(s);
        c.output(co);
        let cost = c.cost();
        assert_eq!(cost.maj3, 1);
        assert_eq!(cost.maj5, 1);
        assert_eq!(cost.not_ops, 1); // ¬cout materialised once
    }
}
