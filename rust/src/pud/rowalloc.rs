//! Scratch-row allocation inside a subarray's data region.
//!
//! Circuit execution needs a row per live wire; rows are recycled when
//! a wire's last consumer has fired (the executor computes last-use
//! positions). A free-list allocator with high-water-mark tracking.

/// Allocator over rows `[base, limit)`.
#[derive(Clone, Debug)]
pub struct RowAlloc {
    base: usize,
    limit: usize,
    free: Vec<usize>,
    next: usize,
    /// Peak simultaneous allocation (reported by examples/benches).
    pub high_water: usize,
    live: usize,
}

impl RowAlloc {
    pub fn new(base: usize, limit: usize) -> Self {
        assert!(base < limit);
        Self { base, limit, free: Vec::new(), next: base, high_water: 0, live: 0 }
    }

    /// Rows still available.
    pub fn available(&self) -> usize {
        (self.limit - self.next) + self.free.len()
    }

    pub fn live(&self) -> usize {
        self.live
    }

    /// Allocate a row; panics if the subarray is out of scratch rows
    /// (circuits must fit the row budget — checked by tests).
    pub fn alloc(&mut self) -> usize {
        let row = if let Some(r) = self.free.pop() {
            r
        } else {
            assert!(
                self.next < self.limit,
                "subarray out of scratch rows (base={}, limit={})",
                self.base,
                self.limit
            );
            let r = self.next;
            self.next += 1;
            r
        };
        self.live += 1;
        self.high_water = self.high_water.max(self.live);
        row
    }

    /// Release a row for reuse.
    pub fn release(&mut self, row: usize) {
        debug_assert!((self.base..self.limit).contains(&row));
        debug_assert!(!self.free.contains(&row), "double free of row {row}");
        self.live -= 1;
        self.free.push(row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocates_and_recycles() {
        let mut a = RowAlloc::new(16, 20);
        let r0 = a.alloc();
        let r1 = a.alloc();
        assert_ne!(r0, r1);
        assert_eq!(a.live(), 2);
        a.release(r0);
        let r2 = a.alloc();
        assert_eq!(r2, r0, "released rows are reused");
        assert_eq!(a.high_water, 2);
    }

    #[test]
    fn tracks_availability() {
        let mut a = RowAlloc::new(0, 4);
        assert_eq!(a.available(), 4);
        let _r = a.alloc();
        assert_eq!(a.available(), 3);
    }

    #[test]
    #[should_panic(expected = "out of scratch rows")]
    fn exhaustion_panics() {
        let mut a = RowAlloc::new(0, 2);
        a.alloc();
        a.alloc();
        a.alloc();
    }
}
