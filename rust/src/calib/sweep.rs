//! Frac-configuration sweeps (Fig. 5) and the one-off variation-model
//! fit (EXPERIMENTS.md §Model-Fit).
//!
//! Sweeps are expressed as request batches against the backend-agnostic
//! [`CalibEngine`] trait: one calibration request and one ECR request
//! per Frac configuration, submitted in two batched calls. The engine
//! owns the parallelism (the native backend fans the requests across
//! the worker pool); every sampling stream is address-derived
//! (`calib::algorithm` module docs), so the batched sweep is
//! bit-identical to the sequential one.

use anyhow::Result;

use crate::analysis::throughput::ThroughputModel;
use crate::calib::algorithm::{CalibParams, NativeEngine, DEFAULT_TILE_COLS};
use crate::calib::engine::{CalibEngine, CalibRequest, EcrRequest};
use crate::calib::lattice::FracConfig;
use crate::config::device::DeviceConfig;
use crate::config::system::SystemConfig;
use crate::coordinator::worker;
use crate::dram::subarray::Subarray;
use crate::util::stats::phi;

/// The Frac configurations evaluated by Fig. 5.
pub fn fig5_configs() -> Vec<FracConfig> {
    vec![
        FracConfig::baseline(0),
        FracConfig::baseline(1),
        FracConfig::baseline(2),
        FracConfig::baseline(3),
        FracConfig::baseline(4),
        FracConfig::baseline(6),
        FracConfig::pudtune([0, 0, 0]),
        FracConfig::pudtune([1, 0, 0]),
        FracConfig::pudtune([1, 1, 0]),
        FracConfig::pudtune([2, 1, 0]),
        FracConfig::pudtune([2, 1, 1]),
        FracConfig::pudtune([2, 2, 1]),
        FracConfig::pudtune([2, 2, 2]),
        FracConfig::pudtune([3, 2, 1]),
        FracConfig::pudtune([3, 3, 3]),
    ]
}

/// One sweep point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub config: FracConfig,
    pub ecr: f64,
    pub maj5_ops: f64,
}

/// Run the Fig. 5 sweep on one subarray: calibrate under each config
/// (baselines skip identification) and measure ECR + MAJ5 throughput,
/// submitted to the default native engine as request batches.
pub fn sweep_configs(
    cfg: &DeviceConfig,
    sys: &SystemConfig,
    sub: &Subarray,
    params: &CalibParams,
    ecr_samples: u32,
    configs: &[FracConfig],
) -> Vec<SweepPoint> {
    sweep_configs_threads(cfg, sys, sub, params, ecr_samples, configs, worker::default_threads())
}

/// [`sweep_configs`] with an explicit worker count (1 = sequential).
/// Results are identical for any `threads`.
pub fn sweep_configs_threads(
    cfg: &DeviceConfig,
    sys: &SystemConfig,
    sub: &Subarray,
    params: &CalibParams,
    ecr_samples: u32,
    configs: &[FracConfig],
    threads: usize,
) -> Vec<SweepPoint> {
    let engine = NativeEngine::with_parallelism(cfg.clone(), DEFAULT_TILE_COLS, threads);
    sweep_configs_with(&engine, sys, sub, params, ecr_samples, configs)
        .expect("the native engine is infallible")
}

/// The engine-generic sweep: one [`CalibRequest`] and one [`EcrRequest`]
/// per configuration, two batched calls total — the backend decides how
/// to execute them (worker-pool fan-out, fused executable calls, ...).
pub fn sweep_configs_with<E: CalibEngine>(
    engine: &E,
    sys: &SystemConfig,
    sub: &Subarray,
    params: &CalibParams,
    ecr_samples: u32,
    configs: &[FracConfig],
) -> Result<Vec<SweepPoint>> {
    let tput = ThroughputModel::new(sys);
    let creqs: Vec<CalibRequest> = configs
        .iter()
        .map(|fc| CalibRequest::from_subarray(sub, 0, *fc, *params))
        .collect();
    let calibs = engine.calibrate_batch(&creqs)?;
    let ereqs: Vec<EcrRequest> = calibs
        .iter()
        .map(|calib| EcrRequest::from_subarray(sub, 0, calib.clone(), 5, ecr_samples))
        .collect();
    let reports = engine.measure_ecr_batch(&ereqs)?;
    Ok(configs
        .iter()
        .zip(&reports)
        .map(|(fc, rep)| {
            let ecr = rep.ecr();
            let cost = tput.majx(5, fc);
            let maj5_ops = tput.ops_per_sec(&cost, 1.0 - ecr);
            SweepPoint { config: *fc, ecr, maj5_ops }
        })
        .collect())
}

/// Closed-form ECR estimate for the *baseline* configuration under a
/// pure-Gaussian core (used by the fit pre-pass to bracket sigma_sa
/// before the stochastic refinement):
///
/// error-free ⇔ −margin − off < δ + noise-margin < margin − off.
pub fn baseline_ecr_estimate(cfg: &DeviceConfig, frac_x: u32, noise_z: f64) -> f64 {
    let margin = cfg.majority_margin();
    let denom = cfg.simra_rows as f64 * cfg.cc_ff + cfg.cb_ff;
    let off = cfg.cc_ff * (cfg.frac_charge(1.0, frac_x) - 0.5) / denom;
    let e = margin - noise_z * cfg.sigma_noise;
    let core = phi((e - off) / cfg.sigma_sa) - phi((-e - off) / cfg.sigma_sa);
    let tail_sigma = cfg.sigma_sa * cfg.tail_ratio;
    let tail = phi((e - off) / tail_sigma) - phi((-e - off) / tail_sigma);
    1.0 - ((1.0 - cfg.tail_weight) * core + cfg.tail_weight * tail)
}

/// Fit `sigma_sa` so the simulated baseline ECR matches a target
/// (Table I: 46.6%), holding the other parameters fixed. Returns the
/// fitted config; see EXPERIMENTS.md §Model-Fit for the recorded run.
pub fn fit_sigma_sa(
    base_cfg: &DeviceConfig,
    sys: &SystemConfig,
    target_baseline_ecr: f64,
    seed: u64,
) -> DeviceConfig {
    let mut lo = 0.5 * base_cfg.sigma_sa;
    let mut hi = 2.0 * base_cfg.sigma_sa;
    let mut cfg = base_cfg.clone();
    for _ in 0..12 {
        let mid = 0.5 * (lo + hi);
        cfg.sigma_sa = mid;
        let eng = NativeEngine::new(cfg.clone());
        let sub = Subarray::new(&cfg, sys, seed);
        let base = FracConfig::baseline(3).uncalibrated(&cfg, sub.cols);
        let ecr = eng
            .measure_ecr_one(&EcrRequest::from_subarray(&sub, seed, base, 5, 2048))
            .expect("the native engine is infallible")
            .ecr();
        if ecr < target_baseline_ecr {
            lo = mid; // need more variation
        } else {
            hi = mid;
        }
    }
    cfg.sigma_sa = 0.5 * (lo + hi);
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimate_tracks_simulation() {
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = 4096;
        let eng = NativeEngine::new(cfg.clone());
        let sub = Subarray::new(&cfg, &sys, 3);
        let base = FracConfig::baseline(3).uncalibrated(&cfg, sub.cols);
        let sim = eng
            .measure_ecr_one(&EcrRequest::from_subarray(&sub, 3, base, 5, 2048))
            .unwrap()
            .ecr();
        let est = baseline_ecr_estimate(&cfg, 3, 3.0);
        assert!((sim - est).abs() < 0.12, "sim={sim} est={est}");
    }

    #[test]
    fn fit_hits_target() {
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = 2048;
        let fitted = fit_sigma_sa(&cfg, &sys, 0.466, 5);
        let eng = NativeEngine::new(fitted.clone());
        let sub = Subarray::new(&fitted, &sys, 17);
        let base = FracConfig::baseline(3).uncalibrated(&fitted, sub.cols);
        let ecr = eng
            .measure_ecr_one(&EcrRequest::from_subarray(&sub, 17, base, 5, 2048))
            .unwrap()
            .ecr();
        assert!((ecr - 0.466).abs() < 0.08, "ecr={ecr}");
    }

    #[test]
    fn parallel_sweep_matches_sequential() {
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = 512;
        let sub = Subarray::new(&cfg, &sys, 33);
        let configs = [
            FracConfig::baseline(3),
            FracConfig::pudtune([2, 1, 0]),
            FracConfig::pudtune([1, 1, 0]),
        ];
        let p = CalibParams::quick();
        let seq = sweep_configs_threads(&cfg, &sys, &sub, &p, 1024, &configs, 1);
        let par = sweep_configs_threads(&cfg, &sys, &sub, &p, 1024, &configs, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.config, b.config);
            assert_eq!(a.ecr.to_bits(), b.ecr.to_bits());
            assert_eq!(a.maj5_ops.to_bits(), b.maj5_ops.to_bits());
        }
    }

    #[test]
    fn sweep_prefers_t210() {
        // Fig. 5: T_{2,1,0} delivers the best ECR among the sweep.
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = 2048;
        let sub = Subarray::new(&cfg, &sys, 21);
        let configs = vec![
            FracConfig::baseline(3),
            FracConfig::pudtune([0, 0, 0]),
            FracConfig::pudtune([2, 1, 0]),
            FracConfig::pudtune([2, 2, 2]),
        ];
        let pts = sweep_configs(&cfg, &sys, &sub, &CalibParams::quick(), 2048, &configs);
        let best = pts
            .iter()
            .min_by(|a, b| a.ecr.partial_cmp(&b.ecr).unwrap())
            .unwrap();
        assert_eq!(best.config, FracConfig::pudtune([2, 1, 0]), "{pts:?}");
        // And every PUDTune config beats the baseline (paper: PUDTune
        // consistently outperforms across all configurations).
        let base_ecr = pts[0].ecr;
        for p in &pts[1..] {
            assert!(p.ecr < base_ecr, "{:?}", p);
        }
    }
}
