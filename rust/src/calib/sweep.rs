//! Frac-configuration sweeps (Fig. 5) and the one-off variation-model
//! fit (EXPERIMENTS.md §Model-Fit).

use crate::analysis::throughput::ThroughputModel;
use crate::calib::algorithm::{CalibParams, NativeEngine};
use crate::calib::lattice::FracConfig;
use crate::config::device::DeviceConfig;
use crate::config::system::SystemConfig;
use crate::dram::subarray::Subarray;
use crate::util::stats::phi;

/// The Frac configurations evaluated by Fig. 5.
pub fn fig5_configs() -> Vec<FracConfig> {
    vec![
        FracConfig::baseline(0),
        FracConfig::baseline(1),
        FracConfig::baseline(2),
        FracConfig::baseline(3),
        FracConfig::baseline(4),
        FracConfig::baseline(6),
        FracConfig::pudtune([0, 0, 0]),
        FracConfig::pudtune([1, 0, 0]),
        FracConfig::pudtune([1, 1, 0]),
        FracConfig::pudtune([2, 1, 0]),
        FracConfig::pudtune([2, 1, 1]),
        FracConfig::pudtune([2, 2, 1]),
        FracConfig::pudtune([2, 2, 2]),
        FracConfig::pudtune([3, 2, 1]),
        FracConfig::pudtune([3, 3, 3]),
    ]
}

/// One sweep point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub config: FracConfig,
    pub ecr: f64,
    pub maj5_ops: f64,
}

/// Run the Fig. 5 sweep on one subarray: calibrate under each config
/// (baselines skip identification) and measure ECR + MAJ5 throughput.
pub fn sweep_configs(
    cfg: &DeviceConfig,
    sys: &SystemConfig,
    sub: &mut Subarray,
    params: &CalibParams,
    ecr_samples: u32,
    configs: &[FracConfig],
) -> Vec<SweepPoint> {
    let mut eng = NativeEngine::new(cfg.clone());
    let tput = ThroughputModel::new(sys);
    configs
        .iter()
        .map(|fc| {
            let calib = eng.calibrate(sub, fc, params);
            let ecr = eng.measure_ecr(sub, &calib, 5, ecr_samples).ecr();
            let cost = tput.majx(5, fc);
            let maj5_ops = tput.ops_per_sec(&cost, 1.0 - ecr);
            SweepPoint { config: *fc, ecr, maj5_ops }
        })
        .collect()
}

/// Closed-form ECR estimate for the *baseline* configuration under a
/// pure-Gaussian core (used by the fit pre-pass to bracket sigma_sa
/// before the stochastic refinement):
///
/// error-free ⇔ −margin − off < δ + noise-margin < margin − off.
pub fn baseline_ecr_estimate(cfg: &DeviceConfig, frac_x: u32, noise_z: f64) -> f64 {
    let margin = cfg.majority_margin();
    let denom = cfg.simra_rows as f64 * cfg.cc_ff + cfg.cb_ff;
    let off = cfg.cc_ff * (cfg.frac_charge(1.0, frac_x) - 0.5) / denom;
    let e = margin - noise_z * cfg.sigma_noise;
    let core = phi((e - off) / cfg.sigma_sa) - phi((-e - off) / cfg.sigma_sa);
    let tail_sigma = cfg.sigma_sa * cfg.tail_ratio;
    let tail = phi((e - off) / tail_sigma) - phi((-e - off) / tail_sigma);
    1.0 - ((1.0 - cfg.tail_weight) * core + cfg.tail_weight * tail)
}

/// Fit `sigma_sa` so the simulated baseline ECR matches a target
/// (Table I: 46.6%), holding the other parameters fixed. Returns the
/// fitted config; see EXPERIMENTS.md §Model-Fit for the recorded run.
pub fn fit_sigma_sa(
    base_cfg: &DeviceConfig,
    sys: &SystemConfig,
    target_baseline_ecr: f64,
    seed: u64,
) -> DeviceConfig {
    let mut lo = 0.5 * base_cfg.sigma_sa;
    let mut hi = 2.0 * base_cfg.sigma_sa;
    let mut cfg = base_cfg.clone();
    for _ in 0..12 {
        let mid = 0.5 * (lo + hi);
        cfg.sigma_sa = mid;
        let mut eng = NativeEngine::new(cfg.clone());
        let mut sub = Subarray::new(&cfg, sys, seed);
        let base = FracConfig::baseline(3).uncalibrated(&cfg, sub.cols);
        let ecr = eng.measure_ecr(&mut sub, &base, 5, 2048).ecr();
        if ecr < target_baseline_ecr {
            lo = mid; // need more variation
        } else {
            hi = mid;
        }
    }
    cfg.sigma_sa = 0.5 * (lo + hi);
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimate_tracks_simulation() {
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = 4096;
        let mut eng = NativeEngine::new(cfg.clone());
        let mut sub = Subarray::new(&cfg, &sys, 3);
        let base = FracConfig::baseline(3).uncalibrated(&cfg, sub.cols);
        let sim = eng.measure_ecr(&mut sub, &base, 5, 2048).ecr();
        let est = baseline_ecr_estimate(&cfg, 3, 3.0);
        assert!((sim - est).abs() < 0.12, "sim={sim} est={est}");
    }

    #[test]
    fn fit_hits_target() {
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = 2048;
        let fitted = fit_sigma_sa(&cfg, &sys, 0.466, 5);
        let mut eng = NativeEngine::new(fitted.clone());
        let mut sub = Subarray::new(&fitted, &sys, 17);
        let base = FracConfig::baseline(3).uncalibrated(&fitted, sub.cols);
        let ecr = eng.measure_ecr(&mut sub, &base, 5, 2048).ecr();
        assert!((ecr - 0.466).abs() < 0.08, "ecr={ecr}");
    }

    #[test]
    fn sweep_prefers_t210() {
        // Fig. 5: T_{2,1,0} delivers the best ECR among the sweep.
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = 2048;
        let mut sub = Subarray::new(&cfg, &sys, 21);
        let configs = vec![
            FracConfig::baseline(3),
            FracConfig::pudtune([0, 0, 0]),
            FracConfig::pudtune([2, 1, 0]),
            FracConfig::pudtune([2, 2, 2]),
        ];
        let pts = sweep_configs(&cfg, &sys, &mut sub, &CalibParams::quick(), 2048, &configs);
        let best = pts
            .iter()
            .min_by(|a, b| a.ecr.partial_cmp(&b.ecr).unwrap())
            .unwrap();
        assert_eq!(best.config, FracConfig::pudtune([2, 1, 0]), "{pts:?}");
        // And every PUDTune config beats the baseline (paper: PUDTune
        // consistently outperforms across all configurations).
        let base_ecr = pts[0].ecr;
        for p in &pts[1..] {
            assert!(p.ecr < base_ecr, "{:?}", p);
        }
    }
}
