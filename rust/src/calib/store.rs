//! Non-volatile calibration store (paper §III-A: "by storing the bit
//! patterns used for calibration data generation in non-volatile
//! memory, it can be reused across different environments and system
//! reboots").
//!
//! Serialises identified calibration data per subarray — Frac
//! configuration plus per-column level indices — as JSON. Level indices
//! are run-length encoded: after calibration most columns sit at the
//! neutral level, so stores stay small.

use crate::calib::algorithm::Calibration;
use crate::calib::lattice::{ConfigKind, FracConfig, OffsetLattice};
use crate::config::device::DeviceConfig;
use crate::dram::geometry::SubarrayId;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// A persisted calibration store for (part of) a device.
#[derive(Clone, Debug, Default)]
pub struct CalibStore {
    /// Per-subarray entries.
    pub entries: BTreeMap<SubarrayId, StoredCalib>,
}

/// One subarray's stored calibration data.
#[derive(Clone, Debug, PartialEq)]
pub struct StoredCalib {
    pub config: FracConfig,
    pub levels: Vec<u8>,
}

impl CalibStore {
    pub fn insert(&mut self, id: SubarrayId, calib: &Calibration) {
        self.entries.insert(
            id,
            StoredCalib { config: calib.lattice.config, levels: calib.levels.clone() },
        );
    }

    /// Rehydrate one subarray's calibration against a device config.
    pub fn load(&self, id: SubarrayId, cfg: &DeviceConfig) -> Option<Calibration> {
        let e = self.entries.get(&id)?;
        Some(Calibration {
            lattice: OffsetLattice::build(cfg, &e.config),
            levels: e.levels.clone(),
        })
    }

    pub fn to_json(&self) -> Json {
        let mut subarrays = Vec::new();
        for (id, e) in &self.entries {
            let mut m = BTreeMap::new();
            m.insert("channel".into(), Json::Num(id.channel as f64));
            m.insert("bank".into(), Json::Num(id.bank as f64));
            m.insert("subarray".into(), Json::Num(id.subarray as f64));
            let kind = match e.config.kind {
                ConfigKind::Baseline => "baseline",
                ConfigKind::PudTune => "pudtune",
            };
            m.insert("kind".into(), Json::Str(kind.into()));
            m.insert(
                "fracs".into(),
                Json::from_f64_slice(&e.config.fracs.map(|x| x as f64)),
            );
            m.insert("levels_rle".into(), rle_encode(&e.levels));
            m.insert("cols".into(), Json::Num(e.levels.len() as f64));
            subarrays.push(Json::Obj(m));
        }
        let mut root = BTreeMap::new();
        root.insert("format".into(), Json::Str("pudtune-calib-v1".into()));
        root.insert("subarrays".into(), Json::Arr(subarrays));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        if j.get("format").as_str() != Some("pudtune-calib-v1") {
            return Err("unknown calibration store format".into());
        }
        let mut store = CalibStore::default();
        for e in j.get("subarrays").as_arr().ok_or("missing subarrays")? {
            let id = SubarrayId::new(
                e.get("channel").as_usize().ok_or("bad channel")?,
                e.get("bank").as_usize().ok_or("bad bank")?,
                e.get("subarray").as_usize().ok_or("bad subarray")?,
            );
            let fr = e.get("fracs").as_arr().ok_or("bad fracs")?;
            if fr.len() != 3 {
                return Err("fracs must have 3 entries".into());
            }
            let fracs = [
                fr[0].as_usize().ok_or("bad frac")? as u32,
                fr[1].as_usize().ok_or("bad frac")? as u32,
                fr[2].as_usize().ok_or("bad frac")? as u32,
            ];
            let config = match e.get("kind").as_str() {
                Some("baseline") => FracConfig { kind: ConfigKind::Baseline, fracs },
                Some("pudtune") => FracConfig { kind: ConfigKind::PudTune, fracs },
                _ => return Err("bad kind".into()),
            };
            let levels = rle_decode(e.get("levels_rle"))?;
            let cols = e.get("cols").as_usize().ok_or("bad cols")?;
            if levels.len() != cols {
                return Err(format!("RLE length {} != cols {cols}", levels.len()));
            }
            store.entries.insert(id, StoredCalib { config, levels });
        }
        Ok(store)
    }

    pub fn save_file(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
    }

    pub fn load_file(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_json(&json::parse(&text)?)
    }
}

/// Run-length encode level indices as [value, count, value, count, ...].
fn rle_encode(levels: &[u8]) -> Json {
    let mut out = Vec::new();
    let mut i = 0;
    while i < levels.len() {
        let v = levels[i];
        let mut n = 1usize;
        while i + n < levels.len() && levels[i + n] == v {
            n += 1;
        }
        out.push(Json::Num(v as f64));
        out.push(Json::Num(n as f64));
        i += n;
    }
    Json::Arr(out)
}

fn rle_decode(j: &Json) -> Result<Vec<u8>, String> {
    let arr = j.as_arr().ok_or("bad RLE array")?;
    if arr.len() % 2 != 0 {
        return Err("RLE array must have even length".into());
    }
    let mut out = Vec::new();
    for pair in arr.chunks(2) {
        let v = pair[0].as_usize().ok_or("bad RLE value")? as u8;
        let n = pair[1].as_usize().ok_or("bad RLE count")?;
        out.extend(std::iter::repeat(v).take(n));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::lattice::FracConfig;

    fn sample_calib(cfg: &DeviceConfig, cols: usize) -> Calibration {
        let fc = FracConfig::pudtune([2, 1, 0]);
        let mut c = Calibration::uniform(OffsetLattice::build(cfg, &fc), cols);
        for i in 0..cols {
            c.levels[i] = ((i * 7) % 8) as u8;
        }
        c
    }

    #[test]
    fn json_roundtrip() {
        let cfg = DeviceConfig::default();
        let mut store = CalibStore::default();
        store.insert(SubarrayId::new(0, 3, 1), &sample_calib(&cfg, 100));
        store.insert(SubarrayId::new(1, 0, 0), &sample_calib(&cfg, 64));
        let j = store.to_json();
        let back = CalibStore::from_json(&json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.entries, store.entries);
    }

    #[test]
    fn rehydrated_calibration_matches() {
        let cfg = DeviceConfig::default();
        let calib = sample_calib(&cfg, 32);
        let mut store = CalibStore::default();
        let id = SubarrayId::new(0, 0, 0);
        store.insert(id, &calib);
        let back = store.load(id, &cfg).unwrap();
        assert_eq!(back.levels, calib.levels);
        assert_eq!(back.lattice.config, calib.lattice.config);
        for c in 0..32 {
            assert!((back.q_extra(c) - calib.q_extra(c)).abs() < 1e-12);
        }
        assert!(store.load(SubarrayId::new(9, 9, 9), &cfg).is_none());
    }

    #[test]
    fn rle_is_compact_for_uniform_levels() {
        let levels = vec![4u8; 65536];
        let j = rle_encode(&levels);
        assert_eq!(j.as_arr().unwrap().len(), 2);
        assert_eq!(rle_decode(&j).unwrap(), levels);
    }

    #[test]
    fn file_roundtrip() {
        let cfg = DeviceConfig::default();
        let mut store = CalibStore::default();
        store.insert(SubarrayId::new(0, 0, 0), &sample_calib(&cfg, 16));
        let path = std::env::temp_dir().join("pudtune_store_test.json");
        store.save_file(&path).unwrap();
        let back = CalibStore::load_file(&path).unwrap();
        assert_eq!(back.entries, store.entries);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_format() {
        assert!(CalibStore::from_json(&json::parse(r#"{"format":"nope"}"#).unwrap()).is_err());
    }
}
