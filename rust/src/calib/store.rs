//! Non-volatile calibration store (paper §III-A: "by storing the bit
//! patterns used for calibration data generation in non-volatile
//! memory, it can be reused across different environments and system
//! reboots").
//!
//! ## Lifecycle
//!
//! The store is one stage of the full calibration lifecycle that the
//! recalibration service ([`crate::coordinator::service`]) closes:
//!
//! 1. **persist** — after Algorithm 1 identifies per-column levels,
//!    [`CalibStore::insert`] + [`CalibStore::save_file`] write them to
//!    non-volatile storage (JSON; level indices are run-length encoded,
//!    so post-calibration stores — where most columns sit at the
//!    neutral level — stay small);
//! 2. **load** — on startup [`CalibStore::load_file`] +
//!    [`CalibStore::load`] rehydrate `Calibration`s against the current
//!    [`DeviceConfig`]; decoding is *checked* (integral-value decode,
//!    level-range and geometry validation), so a corrupt or
//!    incompatible store surfaces as an error instead of silently
//!    truncated data;
//! 3. **validate** — a loaded calibration is a *candidate*: the service
//!    runs a cheap ECR spot-check battery and rejects entries whose
//!    error rate exceeds the drift policy's acceptance bound
//!    ([`crate::calib::drift::DriftPolicy`]);
//! 4. **drift → recalibrate** — accepted entries serve until a drift
//!    signal (temperature excursion, retention age, rolling served-ECR)
//!    schedules background recalibration, whose result is re-persisted
//!    through step 1.
//!
//! Loading distinguishes three cases: `Ok(Some(_))` (entry present and
//! decodable), `Ok(None)` (no entry for the subarray — calibrate from
//! scratch), and `Err(_)` (entry present but *incompatible* with the
//! current device — corrupt levels, wrong geometry — which callers must
//! treat as a hard fault, not a cache miss).
//!
//! ## Format versions
//!
//! Stores carry a versioned header: [`STORE_FORMAT_V2`] (written by
//! [`CalibStore::to_json`]) adds optional per-entry
//! calibration-environment metadata — die temperature and
//! retention-clock hours at identification time
//! ([`CalibStore::insert_with_env`] / [`CalibStore::stored_env`]) —
//! while [`STORE_FORMAT_V1`] files keep loading unchanged with no
//! metadata.

use crate::calib::algorithm::Calibration;
use crate::calib::lattice::{ConfigKind, FracConfig, OffsetLattice};
use crate::config::device::DeviceConfig;
use crate::dram::geometry::SubarrayId;
use crate::dram::temperature::Environment;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// The v1 store header: levels only, no calibration-environment
/// metadata. Still accepted on load (entries rehydrate with
/// [`StoredCalib::env`] = `None`).
pub const STORE_FORMAT_V1: &str = "pudtune-calib-v1";
/// The v2 store header written by [`CalibStore::to_json`]: adds
/// optional per-entry calibration-environment metadata (die
/// temperature and retention-clock hours at identification time), the
/// groundwork for acceptance policies that skip the load-time spot
/// check when conditions match exactly.
pub const STORE_FORMAT_V2: &str = "pudtune-calib-v2";

/// Maximum plausible stored per-row Frac count: `frac_charge` converges
/// geometrically, so anything beyond this is indistinguishable from
/// neutral and almost certainly store corruption.
pub const MAX_STORED_FRACS: u32 = 16;

/// Maximum plausible per-subarray column count in a store entry (the
/// paper's full geometry is 65,536; this leaves two orders of
/// magnitude of headroom). Bounds the RLE decode allocation so a
/// corrupt `cols` field errors out instead of attempting a huge `Vec`.
pub const MAX_STORED_COLS: usize = 1 << 24;

/// A persisted calibration store for (part of) a device.
#[derive(Clone, Debug, Default)]
pub struct CalibStore {
    /// Per-subarray entries.
    pub entries: BTreeMap<SubarrayId, StoredCalib>,
}

/// One subarray's stored calibration data.
#[derive(Clone, Debug, PartialEq)]
pub struct StoredCalib {
    pub config: FracConfig,
    pub levels: Vec<u8>,
    /// v2 metadata: the environment the levels were identified under
    /// (`None` for v1 entries and inserts without telemetry).
    pub env: Option<Environment>,
}

impl CalibStore {
    pub fn insert(&mut self, id: SubarrayId, calib: &Calibration) {
        self.entries.insert(
            id,
            StoredCalib {
                config: calib.lattice.config,
                levels: calib.levels.clone(),
                env: None,
            },
        );
    }

    /// [`Self::insert`] with v2 calibration-environment metadata.
    pub fn insert_with_env(&mut self, id: SubarrayId, calib: &Calibration, env: Environment) {
        self.entries.insert(
            id,
            StoredCalib {
                config: calib.lattice.config,
                levels: calib.levels.clone(),
                env: Some(env),
            },
        );
    }

    /// The calibration-environment metadata stored for `id`, if any
    /// (v1 entries and telemetry-free inserts have none).
    pub fn stored_env(&self, id: SubarrayId) -> Option<Environment> {
        self.entries.get(&id).and_then(|e| e.env)
    }

    /// Rehydrate one subarray's calibration against a device config.
    ///
    /// `Ok(None)` means the store has no entry for `id`; `Err` means an
    /// entry exists but is incompatible with the current device
    /// geometry (level indices outside the lattice the config builds,
    /// implausible Frac counts, non-8-row SiMRA) — a hard fault, not a
    /// cache miss.
    pub fn load(&self, id: SubarrayId, cfg: &DeviceConfig) -> Result<Option<Calibration>, String> {
        let Some(e) = self.entries.get(&id) else {
            return Ok(None);
        };
        if cfg.simra_rows != 8 {
            return Err(format!(
                "stored calibration assumes 8-row SiMRA (3 calibration rows); \
                 device has simra_rows = {}",
                cfg.simra_rows
            ));
        }
        if let Some(&f) = e.config.fracs.iter().find(|&&f| f > MAX_STORED_FRACS) {
            return Err(format!(
                "stored Frac count {f} exceeds the plausible maximum {MAX_STORED_FRACS}"
            ));
        }
        let lattice = OffsetLattice::build(cfg, &e.config);
        let max_level = lattice.len() as u8;
        if let Some(&lv) = e.levels.iter().find(|&&lv| lv >= max_level) {
            return Err(format!(
                "stored level index {lv} outside the {max_level}-level lattice of {}",
                e.config.label()
            ));
        }
        Ok(Some(Calibration { lattice, levels: e.levels.clone() }))
    }

    /// [`Self::load`] with a geometry check against the expected column
    /// count: an entry whose width disagrees with the subarray it is
    /// being rehydrated for is an error, not a candidate.
    pub fn load_expecting(
        &self,
        id: SubarrayId,
        cfg: &DeviceConfig,
        cols: usize,
    ) -> Result<Option<Calibration>, String> {
        match self.load(id, cfg)? {
            Some(c) if c.cols() != cols => Err(format!(
                "stored calibration covers {} columns, subarray has {cols}",
                c.cols()
            )),
            other => Ok(other),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut subarrays = Vec::new();
        for (id, e) in &self.entries {
            let mut m = BTreeMap::new();
            m.insert("channel".into(), Json::Num(id.channel as f64));
            m.insert("bank".into(), Json::Num(id.bank as f64));
            m.insert("subarray".into(), Json::Num(id.subarray as f64));
            let kind = match e.config.kind {
                ConfigKind::Baseline => "baseline",
                ConfigKind::PudTune => "pudtune",
            };
            m.insert("kind".into(), Json::Str(kind.into()));
            m.insert(
                "fracs".into(),
                Json::from_f64_slice(&e.config.fracs.map(|x| x as f64)),
            );
            m.insert("levels_rle".into(), rle_encode(&e.levels));
            m.insert("cols".into(), Json::Num(e.levels.len() as f64));
            if let Some(env) = e.env {
                let mut em = BTreeMap::new();
                em.insert("temp_c".into(), Json::Num(env.temp_c));
                em.insert("hours".into(), Json::Num(env.hours));
                m.insert("env".into(), Json::Obj(em));
            }
            subarrays.push(Json::Obj(m));
        }
        let mut root = BTreeMap::new();
        root.insert("format".into(), Json::Str(STORE_FORMAT_V2.into()));
        root.insert("subarrays".into(), Json::Arr(subarrays));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let v2 = match j.get("format").as_str() {
            Some(STORE_FORMAT_V1) => false,
            Some(STORE_FORMAT_V2) => true,
            _ => return Err("unknown calibration store format".into()),
        };
        let mut store = CalibStore::default();
        for e in j.get("subarrays").as_arr().ok_or("missing subarrays")? {
            // Identifiers and counts decode through the checked-integral
            // path: a fractional or out-of-range value is corruption,
            // not something to truncate into a different subarray.
            let id = SubarrayId::new(
                e.get("channel").as_exact_usize().ok_or("bad channel")?,
                e.get("bank").as_exact_usize().ok_or("bad bank")?,
                e.get("subarray").as_exact_usize().ok_or("bad subarray")?,
            );
            let fr = e.get("fracs").as_arr().ok_or("bad fracs")?;
            if fr.len() != 3 {
                return Err("fracs must have 3 entries".into());
            }
            let fracs = [
                fr[0].as_exact_u32().ok_or("bad frac")?,
                fr[1].as_exact_u32().ok_or("bad frac")?,
                fr[2].as_exact_u32().ok_or("bad frac")?,
            ];
            let config = match e.get("kind").as_str() {
                Some("baseline") => FracConfig { kind: ConfigKind::Baseline, fracs },
                Some("pudtune") => FracConfig { kind: ConfigKind::PudTune, fracs },
                _ => return Err("bad kind".into()),
            };
            let cols = e.get("cols").as_exact_usize().ok_or("bad cols")?;
            if cols > MAX_STORED_COLS {
                return Err(format!(
                    "stored cols {cols} exceeds the plausible maximum {MAX_STORED_COLS}"
                ));
            }
            let levels = rle_decode(e.get("levels_rle"), cols)?;
            if levels.len() != cols {
                return Err(format!("RLE length {} != cols {cols}", levels.len()));
            }
            // v2 metadata is optional per entry; v1 never carries it.
            let env = match e.get("env") {
                Json::Null => None,
                ej if v2 => {
                    let temp_c = ej.get("temp_c").as_f64().ok_or("bad env temp_c")?;
                    let hours = ej.get("hours").as_f64().ok_or("bad env hours")?;
                    if !temp_c.is_finite() || !hours.is_finite() {
                        return Err("non-finite env metadata".into());
                    }
                    Some(Environment { temp_c, hours })
                }
                _ => return Err("env metadata requires a v2 store header".into()),
            };
            store.entries.insert(id, StoredCalib { config, levels, env });
        }
        Ok(store)
    }

    pub fn save_file(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
    }

    pub fn load_file(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_json(&json::parse(&text)?)
    }
}

/// Run-length encode level indices as [value, count, value, count, ...].
fn rle_encode(levels: &[u8]) -> Json {
    let mut out = Vec::new();
    let mut i = 0;
    while i < levels.len() {
        let v = levels[i];
        let mut n = 1usize;
        while i + n < levels.len() && levels[i + n] == v {
            n += 1;
        }
        out.push(Json::Num(v as f64));
        out.push(Json::Num(n as f64));
        i += n;
    }
    Json::Arr(out)
}

/// Decode an RLE levels array, with every value and count going through
/// the checked-integral path. `max_len` bounds the decoded length so a
/// corrupt run count cannot balloon memory before the cols check.
fn rle_decode(j: &Json, max_len: usize) -> Result<Vec<u8>, String> {
    let arr = j.as_arr().ok_or("bad RLE array")?;
    if arr.len() % 2 != 0 {
        return Err("RLE array must have even length".into());
    }
    let mut out = Vec::new();
    for pair in arr.chunks(2) {
        let v = pair[0].as_exact_u8().ok_or("bad RLE value")?;
        let n = pair[1].as_exact_usize().ok_or("bad RLE count")?;
        if out.len() + n > max_len {
            return Err(format!("RLE decodes past the declared {max_len} columns"));
        }
        out.extend(std::iter::repeat(v).take(n));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::lattice::FracConfig;

    fn sample_calib(cfg: &DeviceConfig, cols: usize) -> Calibration {
        let fc = FracConfig::pudtune([2, 1, 0]);
        let mut c = Calibration::uniform(OffsetLattice::build(cfg, &fc), cols);
        for i in 0..cols {
            c.levels[i] = ((i * 7) % 8) as u8;
        }
        c
    }

    #[test]
    fn json_roundtrip() {
        let cfg = DeviceConfig::default();
        let mut store = CalibStore::default();
        store.insert(SubarrayId::new(0, 3, 1), &sample_calib(&cfg, 100));
        store.insert(SubarrayId::new(1, 0, 0), &sample_calib(&cfg, 64));
        let j = store.to_json();
        let back = CalibStore::from_json(&json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.entries, store.entries);
    }

    #[test]
    fn rehydrated_calibration_matches() {
        let cfg = DeviceConfig::default();
        let calib = sample_calib(&cfg, 32);
        let mut store = CalibStore::default();
        let id = SubarrayId::new(0, 0, 0);
        store.insert(id, &calib);
        let back = store.load(id, &cfg).unwrap().unwrap();
        assert_eq!(back.levels, calib.levels);
        assert_eq!(back.lattice.config, calib.lattice.config);
        for c in 0..32 {
            assert!((back.q_extra(c) - calib.q_extra(c)).abs() < 1e-12);
        }
        // Missing entries are a cache miss, not an error.
        assert!(store.load(SubarrayId::new(9, 9, 9), &cfg).unwrap().is_none());
    }

    #[test]
    fn load_rejects_out_of_range_levels() {
        let cfg = DeviceConfig::default();
        let mut store = CalibStore::default();
        let id = SubarrayId::new(0, 0, 0);
        store.entries.insert(
            id,
            StoredCalib {
                config: FracConfig::pudtune([2, 1, 0]),
                levels: vec![0, 3, 9, 1],
                env: None,
            },
        );
        let err = store.load(id, &cfg).unwrap_err();
        assert!(err.contains("level index 9"), "{err}");
    }

    #[test]
    fn load_rejects_implausible_fracs_and_geometry() {
        let cfg = DeviceConfig::default();
        let mut store = CalibStore::default();
        let id = SubarrayId::new(0, 0, 0);
        store.entries.insert(
            id,
            StoredCalib {
                config: FracConfig::pudtune([99, 1, 0]),
                levels: vec![0; 8],
                env: None,
            },
        );
        assert!(store.load(id, &cfg).unwrap_err().contains("Frac count 99"));

        let mut store = CalibStore::default();
        store.insert(id, &sample_calib(&cfg, 16));
        let mut bad_cfg = cfg.clone();
        bad_cfg.simra_rows = 16;
        assert!(store.load(id, &bad_cfg).unwrap_err().contains("8-row SiMRA"));
    }

    #[test]
    fn load_expecting_checks_column_count() {
        let cfg = DeviceConfig::default();
        let mut store = CalibStore::default();
        let id = SubarrayId::new(0, 0, 0);
        store.insert(id, &sample_calib(&cfg, 64));
        assert!(store.load_expecting(id, &cfg, 64).unwrap().is_some());
        let err = store.load_expecting(id, &cfg, 128).unwrap_err();
        assert!(err.contains("64 columns"), "{err}");
        // Missing stays a miss regardless of the expected width.
        assert!(store.load_expecting(SubarrayId::new(1, 1, 1), &cfg, 64).unwrap().is_none());
    }

    #[test]
    fn from_json_rejects_non_integral_and_out_of_range_numbers() {
        // Fractional bank id: would previously truncate 3.7 -> 3 and
        // silently rehydrate the wrong subarray.
        let frac_id = r#"{"format":"pudtune-calib-v1","subarrays":[
            {"channel":0,"bank":3.7,"subarray":0,"kind":"pudtune",
             "fracs":[2,1,0],"levels_rle":[4,4],"cols":4}]}"#;
        assert!(CalibStore::from_json(&json::parse(frac_id).unwrap())
            .unwrap_err()
            .contains("bad bank"));
        // RLE value 256 does not fit u8 (would previously wrap to 0).
        let wide_level = r#"{"format":"pudtune-calib-v1","subarrays":[
            {"channel":0,"bank":0,"subarray":0,"kind":"pudtune",
             "fracs":[2,1,0],"levels_rle":[256,4],"cols":4}]}"#;
        assert!(CalibStore::from_json(&json::parse(wide_level).unwrap())
            .unwrap_err()
            .contains("bad RLE value"));
        // Negative frac count.
        let neg_frac = r#"{"format":"pudtune-calib-v1","subarrays":[
            {"channel":0,"bank":0,"subarray":0,"kind":"pudtune",
             "fracs":[-2,1,0],"levels_rle":[4,4],"cols":4}]}"#;
        assert!(CalibStore::from_json(&json::parse(neg_frac).unwrap())
            .unwrap_err()
            .contains("bad frac"));
        // A run count overshooting the declared cols is rejected before
        // it can balloon memory.
        let runaway = r#"{"format":"pudtune-calib-v1","subarrays":[
            {"channel":0,"bank":0,"subarray":0,"kind":"pudtune",
             "fracs":[2,1,0],"levels_rle":[4,4000000],"cols":4}]}"#;
        assert!(CalibStore::from_json(&json::parse(runaway).unwrap())
            .unwrap_err()
            .contains("past the declared"));
        // ...and so is an implausibly huge cols declaration itself
        // (which would otherwise authorise the decode allocation).
        let huge = r#"{"format":"pudtune-calib-v1","subarrays":[
            {"channel":0,"bank":0,"subarray":0,"kind":"pudtune",
             "fracs":[2,1,0],"levels_rle":[4,900000000000000],"cols":900000000000000}]}"#;
        assert!(CalibStore::from_json(&json::parse(huge).unwrap())
            .unwrap_err()
            .contains("plausible maximum"));
    }

    #[test]
    fn v2_roundtrips_environment_metadata() {
        let cfg = DeviceConfig::default();
        let mut store = CalibStore::default();
        let with_env = SubarrayId::new(0, 0, 0);
        let without = SubarrayId::new(0, 1, 0);
        store.insert_with_env(
            with_env,
            &sample_calib(&cfg, 32),
            Environment { temp_c: 58.5, hours: 12.25 },
        );
        store.insert(without, &sample_calib(&cfg, 32));
        let j = store.to_json();
        assert_eq!(j.get("format").as_str(), Some(STORE_FORMAT_V2));
        let back = CalibStore::from_json(&json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.entries, store.entries);
        assert_eq!(back.stored_env(with_env), Some(Environment { temp_c: 58.5, hours: 12.25 }));
        assert_eq!(back.stored_env(without), None);
        // The metadata never affects the rehydrated calibration.
        assert!(back.load(with_env, &cfg).unwrap().is_some());
    }

    #[test]
    fn v1_stores_still_load() {
        let v1 = r#"{"format":"pudtune-calib-v1","subarrays":[
            {"channel":0,"bank":2,"subarray":0,"kind":"pudtune",
             "fracs":[2,1,0],"levels_rle":[4,8],"cols":8}]}"#;
        let store = CalibStore::from_json(&json::parse(v1).unwrap()).unwrap();
        let id = SubarrayId::new(0, 2, 0);
        assert_eq!(store.entries[&id].levels, vec![4; 8]);
        assert_eq!(store.stored_env(id), None);
        // A v1 header must not smuggle v2 metadata past validation.
        let mixed = r#"{"format":"pudtune-calib-v1","subarrays":[
            {"channel":0,"bank":0,"subarray":0,"kind":"pudtune",
             "fracs":[2,1,0],"levels_rle":[4,8],"cols":8,
             "env":{"temp_c":45.0,"hours":0.0}}]}"#;
        assert!(CalibStore::from_json(&json::parse(mixed).unwrap())
            .unwrap_err()
            .contains("v2 store header"));
    }

    #[test]
    fn v2_rejects_corrupt_environment_metadata() {
        let missing_field = r#"{"format":"pudtune-calib-v2","subarrays":[
            {"channel":0,"bank":0,"subarray":0,"kind":"pudtune",
             "fracs":[2,1,0],"levels_rle":[4,8],"cols":8,
             "env":{"temp_c":45.0}}]}"#;
        assert!(CalibStore::from_json(&json::parse(missing_field).unwrap())
            .unwrap_err()
            .contains("bad env hours"));
    }

    #[test]
    fn rle_is_compact_for_uniform_levels() {
        let levels = vec![4u8; 65536];
        let j = rle_encode(&levels);
        assert_eq!(j.as_arr().unwrap().len(), 2);
        assert_eq!(rle_decode(&j, levels.len()).unwrap(), levels);
    }

    #[test]
    fn file_roundtrip() {
        let cfg = DeviceConfig::default();
        let mut store = CalibStore::default();
        store.insert(SubarrayId::new(0, 0, 0), &sample_calib(&cfg, 16));
        let path = std::env::temp_dir().join("pudtune_store_test.json");
        store.save_file(&path).unwrap();
        let back = CalibStore::load_file(&path).unwrap();
        assert_eq!(back.entries, store.entries);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_format() {
        assert!(CalibStore::from_json(&json::parse(r#"{"format":"nope"}"#).unwrap()).is_err());
    }
}
