//! The bias metric of Algorithm 1.
//!
//! For each column the calibration loop records the MAJX outputs over a
//! batch of random input patterns and compares the observed '1'
//! proportion with the proportion expected from the true majorities:
//! `bias = mean(output) - mean(expected)`. A positive bias means the
//! column answers '1' too often — its SA threshold sits low — so the
//! calibration charge must *decrease* (decrement the lattice level),
//! and vice versa.

/// Per-column output accumulator for one sampling batch.
#[derive(Clone, Debug)]
pub struct BiasAccumulator {
    ones: Vec<u32>,
    expected_ones: Vec<u32>,
    errors: Vec<u32>,
    samples: u32,
}

/// A disjoint mutable column range of a [`BiasAccumulator`]: the unit
/// of work the tiled sampling kernel hands to each worker. Tiles
/// partition the accumulator, so parallel writers never alias.
pub struct BiasTileMut<'a> {
    /// First column of this tile (global index).
    pub start: usize,
    pub ones: &'a mut [u32],
    pub expected_ones: &'a mut [u32],
    pub errors: &'a mut [u32],
}

impl BiasTileMut<'_> {
    pub fn len(&self) -> usize {
        self.ones.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ones.is_empty()
    }
}

impl BiasAccumulator {
    pub fn new(cols: usize) -> Self {
        Self {
            ones: vec![0; cols],
            expected_ones: vec![0; cols],
            errors: vec![0; cols],
            samples: 0,
        }
    }

    pub fn cols(&self) -> usize {
        self.ones.len()
    }

    /// Zero all counts so the allocation can be reused across batches.
    pub fn reset(&mut self) {
        self.ones.fill(0);
        self.expected_ones.fill(0);
        self.errors.fill(0);
        self.samples = 0;
    }

    /// Split into tiles of (at most) `tile_cols` columns for parallel
    /// writers. Tiling is an execution detail: writers fill per-column
    /// totals directly, so the result is identical for any tile size.
    /// The caller records the batch size with [`Self::finish_batch`].
    pub fn tiles_mut(&mut self, tile_cols: usize) -> Vec<BiasTileMut<'_>> {
        let t = tile_cols.max(1);
        let mut tiles = Vec::with_capacity(self.ones.len().div_ceil(t));
        let mut start = 0;
        for ((ones, expected_ones), errors) in self
            .ones
            .chunks_mut(t)
            .zip(self.expected_ones.chunks_mut(t))
            .zip(self.errors.chunks_mut(t))
        {
            let len = ones.len();
            tiles.push(BiasTileMut { start, ones, expected_ones, errors });
            start += len;
        }
        tiles
    }

    /// Record the sample count of a batch whose per-column totals were
    /// written through [`Self::tiles_mut`].
    pub fn finish_batch(&mut self, samples: u32) {
        self.samples = samples;
    }

    /// Record one sample's outputs and expected majorities.
    pub fn record(&mut self, outputs: &[u8], expected: &[u8]) {
        debug_assert_eq!(outputs.len(), self.ones.len());
        debug_assert_eq!(expected.len(), self.ones.len());
        self.samples += 1;
        for c in 0..outputs.len() {
            self.ones[c] += outputs[c] as u32;
            self.expected_ones[c] += expected[c] as u32;
            self.errors[c] += (outputs[c] != expected[c]) as u32;
        }
    }

    pub fn samples(&self) -> u32 {
        self.samples
    }

    /// Per-column bias in [-1, 1].
    pub fn bias(&self, col: usize) -> f64 {
        if self.samples == 0 {
            return 0.0;
        }
        (self.ones[col] as f64 - self.expected_ones[col] as f64) / self.samples as f64
    }

    /// Per-column error count.
    pub fn errors(&self, col: usize) -> u32 {
        self.errors[col]
    }

    pub fn error_counts(&self) -> &[u32] {
        &self.errors
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_signs() {
        let mut acc = BiasAccumulator::new(3);
        // col 0: always over-reports 1; col 1: perfect; col 2: under.
        acc.record(&[1, 1, 0], &[0, 1, 1]);
        acc.record(&[1, 0, 0], &[0, 0, 1]);
        assert!(acc.bias(0) > 0.0);
        assert_eq!(acc.bias(1), 0.0);
        assert!(acc.bias(2) < 0.0);
        assert_eq!(acc.errors(0), 2);
        assert_eq!(acc.errors(1), 0);
        assert_eq!(acc.errors(2), 2);
        assert_eq!(acc.samples(), 2);
    }

    #[test]
    fn empty_accumulator_is_neutral() {
        let acc = BiasAccumulator::new(4);
        assert_eq!(acc.bias(2), 0.0);
        assert_eq!(acc.errors(2), 0);
    }

    #[test]
    fn tiles_partition_and_reset_clears() {
        let mut acc = BiasAccumulator::new(10);
        let tiles = acc.tiles_mut(4);
        assert_eq!(tiles.len(), 3);
        assert_eq!(
            tiles.iter().map(|t| (t.start, t.len())).collect::<Vec<_>>(),
            vec![(0, 4), (4, 4), (8, 2)]
        );
        for mut t in tiles {
            for j in 0..t.len() {
                t.ones[j] = (t.start + j) as u32;
                t.expected_ones[j] = 1;
                t.errors[j] = 2;
            }
        }
        acc.finish_batch(8);
        assert_eq!(acc.samples(), 8);
        assert_eq!(acc.errors(9), 2);
        assert!((acc.bias(9) - (9.0 - 1.0) / 8.0).abs() < 1e-12);
        acc.reset();
        assert_eq!(acc.samples(), 0);
        assert_eq!(acc.errors(9), 0);
        assert_eq!(acc.bias(9), 0.0);
    }
}
