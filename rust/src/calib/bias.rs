//! The bias metric of Algorithm 1.
//!
//! For each column the calibration loop records the MAJX outputs over a
//! batch of random input patterns and compares the observed '1'
//! proportion with the proportion expected from the true majorities:
//! `bias = mean(output) - mean(expected)`. A positive bias means the
//! column answers '1' too often — its SA threshold sits low — so the
//! calibration charge must *decrease* (decrement the lattice level),
//! and vice versa.

/// Per-column output accumulator for one sampling batch.
#[derive(Clone, Debug)]
pub struct BiasAccumulator {
    ones: Vec<u32>,
    expected_ones: Vec<u32>,
    errors: Vec<u32>,
    samples: u32,
}

impl BiasAccumulator {
    pub fn new(cols: usize) -> Self {
        Self {
            ones: vec![0; cols],
            expected_ones: vec![0; cols],
            errors: vec![0; cols],
            samples: 0,
        }
    }

    /// Record one sample's outputs and expected majorities.
    pub fn record(&mut self, outputs: &[u8], expected: &[u8]) {
        debug_assert_eq!(outputs.len(), self.ones.len());
        debug_assert_eq!(expected.len(), self.ones.len());
        self.samples += 1;
        for c in 0..outputs.len() {
            self.ones[c] += outputs[c] as u32;
            self.expected_ones[c] += expected[c] as u32;
            self.errors[c] += (outputs[c] != expected[c]) as u32;
        }
    }

    pub fn samples(&self) -> u32 {
        self.samples
    }

    /// Per-column bias in [-1, 1].
    pub fn bias(&self, col: usize) -> f64 {
        if self.samples == 0 {
            return 0.0;
        }
        (self.ones[col] as f64 - self.expected_ones[col] as f64) / self.samples as f64
    }

    /// Per-column error count.
    pub fn errors(&self, col: usize) -> u32 {
        self.errors[col]
    }

    pub fn error_counts(&self) -> &[u32] {
        &self.errors
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_signs() {
        let mut acc = BiasAccumulator::new(3);
        // col 0: always over-reports 1; col 1: perfect; col 2: under.
        acc.record(&[1, 1, 0], &[0, 1, 1]);
        acc.record(&[1, 0, 0], &[0, 0, 1]);
        assert!(acc.bias(0) > 0.0);
        assert_eq!(acc.bias(1), 0.0);
        assert!(acc.bias(2) < 0.0);
        assert_eq!(acc.errors(0), 2);
        assert_eq!(acc.errors(1), 0);
        assert_eq!(acc.errors(2), 2);
        assert_eq!(acc.samples(), 2);
    }

    #[test]
    fn empty_accumulator_is_neutral() {
        let acc = BiasAccumulator::new(4);
        assert_eq!(acc.bias(2), 0.0);
        assert_eq!(acc.errors(2), 0);
    }
}
