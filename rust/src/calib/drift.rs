//! Drift policy: when is a calibration still trustworthy?
//!
//! The paper stores calibration data in non-volatile memory so it
//! survives reboots (§III-A), but its own reliability study (Fig. 6)
//! and the SiMRA characterisation literature show the error-prone
//! column population is *condition-dependent*: temperature excursions
//! shift sense-amp thresholds, aging drifts them, and retention decay
//! erodes the stored analog levels. A serving system must therefore
//! treat a calibration as a cached artifact with an invalidation
//! policy, not a one-shot preprocessing step.
//!
//! This module is the policy half of that story — pure data and
//! decision logic, no engine access:
//!
//! * [`DriftPolicy`] — the thresholds an operator tunes: the load-time
//!   acceptance ECR bound, and the three drift signals' limits
//!   (temperature excursion, retention age, rolling served-batch ECR);
//! * [`DriftMonitor`] — one subarray's view: the environment its
//!   active calibration was identified/accepted under plus a rolling
//!   window of served-batch ECRs;
//! * [`DriftSignal`] — why recalibration was scheduled.
//!
//! The mechanism half — spot checks, queueing, background
//! recalibration — lives in [`crate::coordinator::service`].

use crate::dram::temperature::Environment;
use std::collections::VecDeque;
use std::fmt;

/// Operator-tunable drift thresholds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftPolicy {
    /// Load-time acceptance: a rehydrated calibration whose spot-check
    /// ECR exceeds this bound is rejected (recalibrate from scratch).
    pub accept_max_ecr: f64,
    /// Temperature excursion from the calibration temperature that
    /// schedules recalibration, °C.
    pub max_temp_delta_c: f64,
    /// Calibration age beyond which recalibration is scheduled, hours
    /// (retention decay and aging drift both accumulate with time).
    pub max_age_hours: f64,
    /// Rolling served-batch ECR beyond which recalibration is
    /// scheduled (the symptom-level signal: whatever the cause, the
    /// calibration is no longer holding).
    pub max_serve_ecr: f64,
    /// Served batches in the rolling ECR window; the ECR signal only
    /// fires once the window is full, so one noisy batch cannot
    /// trigger a recalibration storm.
    pub serve_window: usize,
    /// Environment-match fast-accept, temperature half: a rehydrated
    /// v2 entry whose stored identification temperature is within this
    /// many °C of the live die temperature (and whose age matches per
    /// [`Self::env_match_hours`]) is accepted **without** an ECR spot
    /// check. `0.0` disables the fast path (the default): skipping the
    /// spot check trades a measurement for trust in the stored
    /// metadata, so it is opt-in.
    pub env_match_temp_c: f64,
    /// Environment-match fast-accept, age half: maximum |stored −
    /// live| environment-clock delta, hours. Both halves must be
    /// non-zero and satisfied for the fast accept to apply.
    pub env_match_hours: f64,
}

impl Default for DriftPolicy {
    fn default() -> Self {
        Self {
            // PUDTune residual ECR is a few percent (Table I); 10%
            // leaves headroom for small-sample spot checks.
            accept_max_ecr: 0.10,
            // Fig. 6a heats to 100 °C from a 45 °C calibration; stay
            // well inside that span before re-tuning.
            max_temp_delta_c: 20.0,
            // Fig. 6b ages for one week.
            max_age_hours: 168.0,
            max_serve_ecr: 0.10,
            serve_window: 4,
            env_match_temp_c: 0.0,
            env_match_hours: 0.0,
        }
    }
}

impl DriftPolicy {
    /// Reject thresholds that can never fire or are not numbers.
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("accept_max_ecr", self.accept_max_ecr),
            ("max_temp_delta_c", self.max_temp_delta_c),
            ("max_age_hours", self.max_age_hours),
            ("max_serve_ecr", self.max_serve_ecr),
            ("env_match_temp_c", self.env_match_temp_c),
            ("env_match_hours", self.env_match_hours),
        ] {
            if v.is_nan() || v < 0.0 {
                return Err(format!("drift policy: {name} must be non-negative, got {v}"));
            }
        }
        if self.serve_window == 0 {
            return Err("drift policy: serve_window must be at least 1".into());
        }
        Ok(())
    }

    /// Environment-match fast-accept test: `Some((temp_delta_c,
    /// hours_delta))` when the fast path is enabled (both tolerances
    /// non-zero) and `stored` is within tolerance of `live` on both
    /// axes, else `None` (fall through to the ECR spot check).
    pub fn env_matches(&self, stored: &Environment, live: &Environment) -> Option<(f64, f64)> {
        if self.env_match_temp_c <= 0.0 || self.env_match_hours <= 0.0 {
            return None;
        }
        let dt = (stored.temp_c - live.temp_c).abs();
        let dh = (stored.hours - live.hours).abs();
        (dt <= self.env_match_temp_c && dh <= self.env_match_hours).then_some((dt, dh))
    }
}

/// Why a subarray's calibration was scheduled for recalibration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DriftSignal {
    /// Die temperature moved too far from the calibration temperature.
    TemperatureExcursion { delta_c: f64 },
    /// The calibration is too old (retention decay / aging drift).
    RetentionAge { hours: f64 },
    /// The rolling served-batch ECR exceeded the policy bound.
    EcrDegradation { rolling_ecr: f64 },
}

impl fmt::Display for DriftSignal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DriftSignal::TemperatureExcursion { delta_c } => {
                write!(f, "temperature excursion ({delta_c:+.1} C from calibration)")
            }
            DriftSignal::RetentionAge { hours } => {
                write!(f, "calibration age ({hours:.1} h)")
            }
            DriftSignal::EcrDegradation { rolling_ecr } => {
                write!(f, "served-batch ECR degradation ({:.2}%)", rolling_ecr * 100.0)
            }
        }
    }
}

/// One subarray's drift state: the environment its active calibration
/// holds for, and the recent served-batch error history.
#[derive(Clone, Debug)]
pub struct DriftMonitor {
    /// Temperature the active calibration was identified/accepted at.
    cal_temp_c: f64,
    /// Environment clock at identification/acceptance, hours.
    cal_hours: f64,
    /// Rolling ECRs of the most recent served batches.
    window: VecDeque<f64>,
    capacity: usize,
}

impl DriftMonitor {
    /// Monitor for a calibration just identified/accepted under `env`.
    pub fn new(env: &Environment, serve_window: usize) -> Self {
        Self {
            cal_temp_c: env.temp_c,
            cal_hours: env.hours,
            window: VecDeque::with_capacity(serve_window.max(1)),
            capacity: serve_window.max(1),
        }
    }

    /// Re-anchor after a successful recalibration: the new calibration
    /// holds for the *current* environment, and the served-ECR history
    /// of the old calibration no longer applies.
    pub fn rebase(&mut self, env: &Environment) {
        self.cal_temp_c = env.temp_c;
        self.cal_hours = env.hours;
        self.window.clear();
    }

    /// Record one served batch's ECR. Non-finite samples (a failed or
    /// degenerate measurement) are dropped: one NaN would poison the
    /// rolling mean forever — NaN propagates through the sum and never
    /// compares greater than the policy bound, silently disabling the
    /// ECR signal — mirroring the NaN/∞ guards `DriftState::advance`
    /// and `Subarray::advance_time` apply to their inputs.
    pub fn observe_ecr(&mut self, ecr: f64) {
        if !ecr.is_finite() {
            return;
        }
        if self.window.len() == self.capacity {
            self.window.pop_front();
        }
        self.window.push_back(ecr);
    }

    /// Mean ECR over the rolling window (`None` until a batch lands).
    pub fn rolling_ecr(&self) -> Option<f64> {
        if self.window.is_empty() {
            return None;
        }
        Some(self.window.iter().sum::<f64>() / self.window.len() as f64)
    }

    /// Age of the active calibration at `env`, hours.
    pub fn age_hours(&self, env: &Environment) -> f64 {
        env.hours - self.cal_hours
    }

    /// The environment the active calibration was identified/accepted
    /// under — what store-format v2 persists per entry
    /// ([`crate::calib::store::CalibStore::insert_with_env`]).
    pub fn calib_env(&self) -> Environment {
        Environment { temp_c: self.cal_temp_c, hours: self.cal_hours }
    }

    /// Evaluate the drift signals against a policy. Returns the first
    /// firing signal in fixed priority order — temperature excursion,
    /// then age, then rolling ECR — so repeated polls are stable.
    pub fn check(&self, policy: &DriftPolicy, env: &Environment) -> Option<DriftSignal> {
        let delta_c = env.temp_c - self.cal_temp_c;
        if delta_c.abs() > policy.max_temp_delta_c {
            return Some(DriftSignal::TemperatureExcursion { delta_c });
        }
        let hours = self.age_hours(env);
        if hours > policy.max_age_hours {
            return Some(DriftSignal::RetentionAge { hours });
        }
        if self.window.len() == self.capacity {
            // A full window always has a mean.
            let rolling_ecr = self.rolling_ecr().unwrap_or(0.0);
            if rolling_ecr > policy.max_serve_ecr {
                return Some(DriftSignal::EcrDegradation { rolling_ecr });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(temp_c: f64, hours: f64) -> Environment {
        Environment { temp_c, hours }
    }

    #[test]
    fn defaults_validate() {
        DriftPolicy::default().validate().unwrap();
    }

    #[test]
    fn validate_rejects_nonsense() {
        let p = DriftPolicy { max_temp_delta_c: f64::NAN, ..DriftPolicy::default() };
        assert!(p.validate().unwrap_err().contains("max_temp_delta_c"));
        let p = DriftPolicy { accept_max_ecr: -0.1, ..DriftPolicy::default() };
        assert!(p.validate().is_err());
        let p = DriftPolicy { serve_window: 0, ..DriftPolicy::default() };
        assert!(p.validate().unwrap_err().contains("serve_window"));
    }

    #[test]
    fn env_match_is_disabled_by_default_and_validated() {
        let p = DriftPolicy::default();
        // Even a bit-identical environment does not fast-match while
        // the tolerances are zero.
        assert_eq!(p.env_matches(&env(45.0, 0.0), &env(45.0, 0.0)), None);
        let p = DriftPolicy { env_match_temp_c: f64::NAN, ..DriftPolicy::default() };
        assert!(p.validate().unwrap_err().contains("env_match_temp_c"));
        let p = DriftPolicy { env_match_hours: -1.0, ..DriftPolicy::default() };
        assert!(p.validate().unwrap_err().contains("env_match_hours"));
    }

    #[test]
    fn env_match_requires_both_axes_within_tolerance() {
        let p = DriftPolicy {
            env_match_temp_c: 2.0,
            env_match_hours: 24.0,
            ..DriftPolicy::default()
        };
        let stored = env(45.0, 100.0);
        // In tolerance on both axes: matches, reporting the deltas.
        let (dt, dh) = p.env_matches(&stored, &env(46.5, 90.0)).unwrap();
        assert!((dt - 1.5).abs() < 1e-9 && (dh - 10.0).abs() < 1e-9);
        // Near-miss on either single axis: no match.
        assert_eq!(p.env_matches(&stored, &env(47.5, 100.0)), None);
        assert_eq!(p.env_matches(&stored, &env(45.0, 130.0)), None);
        // One zero tolerance disables the whole fast path.
        let half = DriftPolicy { env_match_hours: 0.0, ..p };
        assert_eq!(half.env_matches(&stored, &stored), None);
    }

    #[test]
    fn calib_env_tracks_anchor_and_rebase() {
        let mut m = DriftMonitor::new(&env(45.0, 2.0), 4);
        assert_eq!(m.calib_env(), env(45.0, 2.0));
        m.rebase(&env(60.0, 9.0));
        assert_eq!(m.calib_env(), env(60.0, 9.0));
    }

    #[test]
    fn quiet_monitor_raises_nothing() {
        let p = DriftPolicy::default();
        let m = DriftMonitor::new(&env(45.0, 0.0), p.serve_window);
        assert_eq!(m.check(&p, &env(45.0, 1.0)), None);
        assert_eq!(m.check(&p, &env(55.0, 24.0)), None);
    }

    #[test]
    fn temperature_excursion_fires_in_both_directions() {
        let p = DriftPolicy::default();
        let m = DriftMonitor::new(&env(45.0, 0.0), p.serve_window);
        match m.check(&p, &env(85.0, 0.0)) {
            Some(DriftSignal::TemperatureExcursion { delta_c }) => {
                assert!((delta_c - 40.0).abs() < 1e-9)
            }
            other => panic!("expected excursion, got {other:?}"),
        }
        assert!(matches!(
            m.check(&p, &env(10.0, 0.0)),
            Some(DriftSignal::TemperatureExcursion { .. })
        ));
    }

    #[test]
    fn age_fires_after_policy_bound() {
        let p = DriftPolicy { max_age_hours: 72.0, ..DriftPolicy::default() };
        let m = DriftMonitor::new(&env(45.0, 10.0), p.serve_window);
        assert_eq!(m.check(&p, &env(45.0, 80.0)), None);
        assert!(matches!(
            m.check(&p, &env(45.0, 83.0)),
            Some(DriftSignal::RetentionAge { .. })
        ));
    }

    #[test]
    fn rolling_ecr_needs_a_full_window() {
        let p = DriftPolicy { serve_window: 3, max_serve_ecr: 0.05, ..DriftPolicy::default() };
        let mut m = DriftMonitor::new(&env(45.0, 0.0), p.serve_window);
        m.observe_ecr(0.5);
        m.observe_ecr(0.5);
        // Two hot batches in a 3-window: not yet.
        assert_eq!(m.check(&p, &env(45.0, 0.0)), None);
        m.observe_ecr(0.5);
        match m.check(&p, &env(45.0, 0.0)) {
            Some(DriftSignal::EcrDegradation { rolling_ecr }) => {
                assert!((rolling_ecr - 0.5).abs() < 1e-9)
            }
            other => panic!("expected degradation, got {other:?}"),
        }
        // The window rolls: three clean batches clear the signal.
        m.observe_ecr(0.0);
        m.observe_ecr(0.0);
        m.observe_ecr(0.0);
        assert_eq!(m.check(&p, &env(45.0, 0.0)), None);
    }

    #[test]
    fn non_finite_ecr_samples_cannot_poison_the_window() {
        let p = DriftPolicy { serve_window: 3, max_serve_ecr: 0.05, ..DriftPolicy::default() };
        let mut m = DriftMonitor::new(&env(45.0, 0.0), p.serve_window);
        // Dropped outright: the window stays empty.
        m.observe_ecr(f64::NAN);
        m.observe_ecr(f64::INFINITY);
        m.observe_ecr(f64::NEG_INFINITY);
        assert_eq!(m.rolling_ecr(), None);
        // Interleaved bad samples neither fill nor skew the window:
        // three hot finite batches still fire the signal exactly.
        m.observe_ecr(0.5);
        m.observe_ecr(f64::NAN);
        m.observe_ecr(0.5);
        assert_eq!(m.check(&p, &env(45.0, 0.0)), None, "window not full yet");
        m.observe_ecr(0.5);
        match m.check(&p, &env(45.0, 0.0)) {
            Some(DriftSignal::EcrDegradation { rolling_ecr }) => {
                assert!((rolling_ecr - 0.5).abs() < 1e-9, "NaN must not skew the mean")
            }
            other => panic!("expected degradation, got {other:?}"),
        }
    }

    #[test]
    fn rebase_clears_history_and_reanchors() {
        let p = DriftPolicy::default();
        let mut m = DriftMonitor::new(&env(45.0, 0.0), p.serve_window);
        for _ in 0..p.serve_window {
            m.observe_ecr(0.9);
        }
        let hot = env(85.0, 200.0);
        assert!(m.check(&p, &hot).is_some());
        m.rebase(&hot);
        assert_eq!(m.check(&p, &hot), None);
        assert_eq!(m.rolling_ecr(), None);
        assert_eq!(m.age_hours(&hot), 0.0);
    }
}
