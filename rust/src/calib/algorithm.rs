//! Calibration-data identification (paper Algorithm 1) and ECR
//! measurement on the native golden model — as a column-tiled,
//! allocation-free batch kernel.
//!
//! ## Kernel architecture
//!
//! One sampling batch evaluates `samples` random MAJ-m patterns on every
//! column — the same arithmetic as `Subarray::simra` restricted to the
//! SiMRA group. The hot path is organised for throughput:
//!
//! * **per-(batch, column) RNG streams** — every column draws from
//!   `rng::stream(batch_seed, &[col])`, so the noise a column sees
//!   depends only on its logical address, never on execution order.
//!   Results are bit-identical for *any* tile size and worker count
//!   (the determinism suite in `rust/tests/determinism.rs` pins this).
//! * **uniform-space decisions** — instead of drawing a normal per
//!   sample, the per-column decision thresholds are folded into `m + 1`
//!   precomputed cutoffs `pcut[k] = Φ(−(a·k + b_c)/σ)`; a sample is
//!   then one word draw, a popcount and a compare (`u > pcut[k]`).
//!   Distributionally identical to adding N(0, σ) noise, ~6× cheaper.
//! * **scratch arena** — thresholds are computed once per environment
//!   (not per column per batch) and the cutoff table is reused across
//!   batches; the inner loop performs no allocation.
//! * **column tiles** — batches fan out over
//!   `coordinator::worker::parallel_map` in tiles of
//!   [`NativeEngine::tile_cols`] columns; tiling is an execution detail
//!   with no observable effect.
//!
//! The pre-tiling scalar loop is kept as
//! [`NativeEngine::sample_batch_reference`] for perf before/after
//! comparisons and the statistical-equivalence test. Mass experiments
//! use the PJRT path (`coordinator::engine`) which executes the same
//! graphs as AOT artifacts.
//!
//! ## Row-storage independence
//!
//! The sampling kernel never touches cell storage: `calibrate_columns`
//! and `measure_ecr_columns` read only the sense-amp bank and the
//! environment, and synthesize operand patterns arithmetically. The
//! subarray's hybrid bit-packed/analog row representation
//! (`dram::subarray`) is therefore invisible here by construction —
//! calibrating through the dense reference model's sense amps yields
//! bit-identical levels (pinned by a representation-independence unit
//! test below and by the storage parity suite).

use crate::analysis::ecr::EcrReport;
use crate::calib::bias::{BiasAccumulator, BiasTileMut};
use crate::calib::lattice::{ConfigKind, FracConfig, OffsetLattice};
use crate::config::device::DeviceConfig;
use crate::coordinator::worker;
use crate::dram::sense_amp::SenseAmps;
use crate::dram::subarray::Subarray;
use crate::dram::temperature::Environment;
use crate::util::rng::{derive_seed, stream, Rng};
use crate::util::stats::phi;

/// Identified calibration state for one subarray.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub lattice: OffsetLattice,
    /// Per-column lattice level index.
    pub levels: Vec<u8>,
}

impl Calibration {
    /// Uniform calibration at the lattice's neutral level (the
    /// starting point of Algorithm 1, and the whole story for the
    /// baseline configuration whose lattice has a single pattern).
    pub fn uniform(lattice: OffsetLattice, cols: usize) -> Self {
        let lv = lattice.neutral_level() as u8;
        Self { lattice, levels: vec![lv; cols] }
    }

    pub fn cols(&self) -> usize {
        self.levels.len()
    }

    /// Total calibration charge of one column (cell-equivalents).
    #[inline]
    pub fn q_extra(&self, col: usize) -> f64 {
        self.lattice.levels[self.levels[col] as usize].q_total
    }

    /// Bit pattern stored in calibration row `row` (0..3) — what gets
    /// written to the subarray's reserved rows / the NV store.
    pub fn row_bits(&self, row: usize) -> Vec<u8> {
        assert!(row < 3);
        self.levels
            .iter()
            .map(|&lv| self.lattice.levels[lv as usize].bits[row])
            .collect()
    }
}

impl FracConfig {
    /// The un-identified (uniform) calibration for this configuration —
    /// for the baseline this *is* the complete configuration.
    pub fn uncalibrated(&self, cfg: &DeviceConfig, cols: usize) -> Calibration {
        Calibration::uniform(OffsetLattice::build(cfg, self), cols)
    }
}

/// Parameters of Algorithm 1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalibParams {
    /// n_iterations (paper §IV-A: 20).
    pub iterations: u32,
    /// Random samples per iteration (paper §IV-A: 512).
    pub samples: u32,
    /// Bias threshold (Algorithm 1's `threshold`).
    pub tau: f64,
    /// Seed for the sampling streams.
    pub seed: u64,
}

impl CalibParams {
    /// The paper's §IV-A settings.
    pub fn paper() -> Self {
        Self { iterations: 20, samples: 512, tau: 0.02, seed: 0x1DE7 }
    }

    pub fn quick() -> Self {
        Self { iterations: 12, samples: 256, ..Self::paper() }
    }
}

/// Default battery depth of the recalibration service's load-time ECR
/// spot check: deep enough to flag a stale calibration (a drifted
/// column errs on a large fraction of boundary patterns), ~16x cheaper
/// than the paper's full 8,192-sample acceptance battery.
pub const SPOT_CHECK_SAMPLES: u32 = 512;

/// Constant-row charge opened alongside the calibration rows for MAJ-m
/// under 8-row SiMRA: MAJ5 opens none (5 operands + 3 calib), MAJ3
/// additionally opens a constant-0 and a constant-1 row.
pub fn const_q(m: usize) -> f64 {
    match m {
        5 => 0.0,
        3 => 1.0,
        _ => panic!("MAJ{m} not supported under 8-row SiMRA"),
    }
}

/// Stream-domain tags: calibration batches and ECR batteries must never
/// share per-column streams (see `util::rng` module docs).
const STREAM_CALIB: u64 = 0xCA11B;
const STREAM_ECR: u64 = 0xEC12;

/// Default master-seed tag of the ECR stream domain. ECR batteries
/// derive their sampling streams from `master ^ environment`, so a
/// measurement at a given (temperature, age) point replays the same
/// random patterns regardless of which engine or batch shape ran it —
/// [`crate::calib::engine::EcrRequest`] defaults to this tag, keeping
/// the trait path bit-identical to [`NativeEngine::measure_ecr`].
pub const ECR_MASTER_SEED: u64 = 0xECC;

/// Default column-tile width for the parallel sampling kernel. Tiling
/// never changes results; this only balances fan-out granularity
/// against scheduling overhead.
pub const DEFAULT_TILE_COLS: usize = 256;

/// Reusable buffers of the sampling kernel: per-column thresholds for
/// the current environment, and the per-(column, k) decision cutoffs of
/// the current calibration state. Lives on the engine so repeated
/// batches (20 Algorithm-1 iterations, ECR batteries) never reallocate.
#[derive(Clone, Debug, Default)]
struct SampleScratch {
    /// Effective SA threshold per column (refreshed per environment).
    thresholds: Vec<f64>,
    /// Per-level total calibration charge of the active lattice.
    q_total: Vec<f64>,
    /// `pcut[c * (m + 1) + k]` = probability that column `c` outputs 0
    /// given `k` operand ones — the uniform-space decision cutoff.
    pcut: Vec<f64>,
}

impl SampleScratch {
    /// Rebuild the cutoff table for (calibration state, operand count).
    /// `thresholds` must already reflect the subarray's environment.
    fn refresh_cutoffs(&mut self, cfg: &DeviceConfig, calib: &Calibration, m: usize) {
        let cq = const_q(m);
        let denom = cfg.simra_rows as f64 * cfg.cc_ff + cfg.cb_ff;
        let a = cfg.cc_ff / denom;
        let sigma = cfg.sigma_noise;
        self.q_total.clear();
        self.q_total.extend(calib.lattice.levels.iter().map(|l| l.q_total));
        let Self { thresholds, q_total, pcut } = self;
        pcut.clear();
        pcut.reserve(thresholds.len() * (m + 1));
        for (&lv, &thr) in calib.levels.iter().zip(thresholds.iter()) {
            let b = (cfg.cc_ff * (q_total[lv as usize] + cq) + cfg.cb_ff * cfg.v_pre)
                / denom
                - thr;
            for k in 0..=m {
                let d = a * k as f64 + b;
                // P(output 0) = P(d + N(0, σ) <= 0) = Φ(−d/σ).
                let z = if sigma > 0.0 {
                    -d / sigma
                } else if d > 0.0 {
                    f64::NEG_INFINITY
                } else {
                    f64::INFINITY
                };
                pcut.push(phi(z));
            }
        }
    }
}

/// Native (golden-model-equivalent) calibration + measurement engine.
#[derive(Clone, Debug)]
pub struct NativeEngine {
    pub cfg: DeviceConfig,
    /// Column-tile width of the parallel sampling kernel. Any value
    /// produces identical results (see module docs).
    pub tile_cols: usize,
    /// Worker threads for tile fan-out. Any value produces identical
    /// results; 1 disables fan-out entirely.
    pub threads: usize,
    scratch: SampleScratch,
}

impl NativeEngine {
    pub fn new(cfg: DeviceConfig) -> Self {
        Self::with_parallelism(cfg, DEFAULT_TILE_COLS, worker::default_threads())
    }

    /// Engine with explicit tile width / worker count (the determinism
    /// suite sweeps these; results never depend on them).
    pub fn with_parallelism(cfg: DeviceConfig, tile_cols: usize, threads: usize) -> Self {
        Self {
            cfg,
            tile_cols: tile_cols.max(1),
            threads: threads.max(1),
            scratch: SampleScratch::default(),
        }
    }

    /// Engine pinned to one worker thread — for callers that already
    /// parallelise at a coarser grain (configs, banks, subarrays).
    pub fn serial(cfg: DeviceConfig) -> Self {
        Self::with_parallelism(cfg, DEFAULT_TILE_COLS, 1)
    }

    /// Recompute per-column effective thresholds for a sense-amp bank
    /// under an environment (once per environment, not per batch).
    fn refresh_thresholds_columns(&mut self, sa: &SenseAmps, env: &Environment) {
        let Self { cfg, scratch, .. } = self;
        scratch.thresholds.clear();
        scratch
            .thresholds
            .extend((0..sa.cols()).map(|c| sa.threshold(cfg, env, c)));
    }

    /// [`Self::refresh_thresholds_columns`] for a full subarray.
    fn refresh_thresholds(&mut self, sub: &Subarray) {
        self.refresh_thresholds_columns(&sub.sa, &sub.env);
    }

    /// One sampling batch with prepared thresholds: `samples` random
    /// MAJ-m patterns per column, accumulated into `acc`.
    fn batch_prepared(
        &mut self,
        calib: &Calibration,
        m: usize,
        samples: u32,
        batch_seed: u64,
        acc: &mut BiasAccumulator,
    ) {
        // One u64 feeds both the operand pattern (bits 0..m) and the
        // 53-bit decision uniform (bits 11..64) — disjoint bit ranges
        // of a uniform word are independent.
        debug_assert!(m < 11, "operand bits must not overlap the uniform bits");
        self.scratch.refresh_cutoffs(&self.cfg, calib, m);
        let kdim = m + 1;
        assert_eq!(
            self.scratch.pcut.len(),
            acc.cols() * kdim,
            "calibration width must equal columns"
        );
        let pcut = &self.scratch.pcut;
        let mask = (1u64 << m) - 1;
        let maj_t = m.div_ceil(2) as u32;
        const U53: f64 = 1.0 / (1u64 << 53) as f64;
        acc.reset();
        let tiles = acc.tiles_mut(self.tile_cols);
        let kernel = |mut tile: BiasTileMut<'_>| {
            for j in 0..tile.len() {
                let c = tile.start + j;
                let cut = &pcut[c * kdim..(c + 1) * kdim];
                let mut rng = stream(batch_seed, &[c as u64]);
                let (mut ones, mut expected, mut errors) = (0u32, 0u32, 0u32);
                for _ in 0..samples {
                    let w = rng.next_u64();
                    let k = (w & mask).count_ones();
                    let u = ((w >> 11) as f64 + 0.5) * U53;
                    let out = (u > cut[k as usize]) as u32;
                    let exp = (k >= maj_t) as u32;
                    ones += out;
                    expected += exp;
                    errors += (out != exp) as u32;
                }
                tile.ones[j] = ones;
                tile.expected_ones[j] = expected;
                tile.errors[j] = errors;
            }
        };
        if self.threads > 1 && tiles.len() > 1 {
            worker::parallel_map(tiles, self.threads, kernel);
        } else {
            tiles.into_iter().for_each(kernel);
        }
        acc.finish_batch(samples);
    }

    /// One sampling batch into a reusable accumulator (the
    /// allocation-free entry point; see module docs for the stream
    /// contract on `batch_seed`).
    pub fn sample_batch_into(
        &mut self,
        sub: &Subarray,
        calib: &Calibration,
        m: usize,
        samples: u32,
        batch_seed: u64,
        acc: &mut BiasAccumulator,
    ) {
        assert_eq!(acc.cols(), sub.cols, "accumulator width must equal columns");
        self.refresh_thresholds(sub);
        self.batch_prepared(calib, m, samples, batch_seed, acc);
    }

    /// Convenience wrapper allocating a fresh accumulator.
    pub fn sample_batch(
        &mut self,
        sub: &Subarray,
        calib: &Calibration,
        m: usize,
        samples: u32,
        batch_seed: u64,
    ) -> BiasAccumulator {
        let mut acc = BiasAccumulator::new(sub.cols);
        self.sample_batch_into(sub, calib, m, samples, batch_seed, &mut acc);
        acc
    }

    /// The pre-tiling scalar reference: one shared sequential RNG
    /// stream, a per-sample Gaussian draw, thresholds re-derived per
    /// column per batch. Kept only as the perf/statistics baseline for
    /// benches and the equivalence test — not a production path.
    pub fn sample_batch_reference(
        &self,
        sub: &Subarray,
        calib: &Calibration,
        m: usize,
        samples: u32,
        rng: &mut Rng,
    ) -> BiasAccumulator {
        let cols = sub.cols;
        let rows = self.cfg.simra_rows;
        let maj_t = m.div_ceil(2) as u32;
        let cq = const_q(m);
        let mut acc = BiasAccumulator::new(cols);
        let mut out = vec![0u8; cols];
        let mut exp = vec![0u8; cols];
        let denom = rows as f64 * self.cfg.cc_ff + self.cfg.cb_ff;
        let a = self.cfg.cc_ff / denom;
        let base: Vec<f64> = (0..cols)
            .map(|c| {
                let b = (self.cfg.cc_ff * (calib.q_extra(c) + cq)
                    + self.cfg.cb_ff * self.cfg.v_pre)
                    / denom;
                b - sub.sa.threshold(&self.cfg, &sub.env, c)
            })
            .collect();
        let sigma = self.cfg.sigma_noise;
        for _ in 0..samples {
            for c in 0..cols {
                let word = rng.next_u64();
                let k = (word & ((1u64 << m) - 1)).count_ones();
                let d = a * k as f64 + base[c];
                out[c] = (d + rng.normal_ms(0.0, sigma) > 0.0) as u8;
                exp[c] = (k >= maj_t) as u8;
            }
            acc.record(&out, &exp);
        }
        acc
    }

    /// Algorithm 1 on a sense-amp bank + environment — the sampling
    /// loop never reads cell charges, so this is the complete
    /// calibration kernel (the engine-trait path enters here; the
    /// [`Self::calibrate`] wrapper serves `Subarray` callers).
    pub fn calibrate_columns(
        &mut self,
        sa: &SenseAmps,
        env: &Environment,
        fc: &FracConfig,
        params: &CalibParams,
    ) -> Calibration {
        let cols = sa.cols();
        let lattice = OffsetLattice::build(&self.cfg, fc);
        let mut calib = Calibration::uniform(lattice, cols);
        if fc.kind == ConfigKind::Baseline {
            // No per-column freedom to exploit.
            return calib;
        }
        let max_lv = (calib.lattice.len() - 1) as u8;
        self.refresh_thresholds_columns(sa, env);
        let mut acc = BiasAccumulator::new(cols);
        for iter in 0..params.iterations {
            let batch_seed = derive_seed(params.seed, &[STREAM_CALIB, iter as u64]);
            self.batch_prepared(&calib, 5, params.samples, batch_seed, &mut acc);
            for c in 0..cols {
                let bias = acc.bias(c);
                // Algorithm 1 lines 6-11: |bias| beyond the threshold
                // steps the level against the bias. Columns that still
                // show *any* errors are additionally nudged in the bias
                // direction — at 512 samples a sub-threshold bias of a
                // few flips is still a reliable direction signal, and
                // without the nudge columns converge to "just inside
                // the margin" levels that the 8,192-sample ECR test
                // still catches (see rust/tests/debug_calib.rs).
                if bias > params.tau || (acc.errors(c) > 0 && bias > 0.0) {
                    // Outputs '1' too often -> reduce calibration charge.
                    calib.levels[c] = calib.levels[c].saturating_sub(1);
                } else if bias < -params.tau || (acc.errors(c) > 0 && bias < 0.0) {
                    calib.levels[c] = (calib.levels[c] + 1).min(max_lv);
                }
            }
        }
        calib
    }

    /// Algorithm 1: iteratively identify per-column calibration data.
    pub fn calibrate(
        &mut self,
        sub: &Subarray,
        fc: &FracConfig,
        params: &CalibParams,
    ) -> Calibration {
        self.calibrate_columns(&sub.sa, &sub.env, fc, params)
    }

    /// ECR measurement on a sense-amp bank + environment: per-column
    /// error counts over `samples` random MAJ-m patterns. `master_seed`
    /// selects the stream domain ([`ECR_MASTER_SEED`] reproduces the
    /// [`Self::measure_ecr`] battery bit for bit); the environment is
    /// folded in, so each (temperature, age) point replays its own
    /// patterns.
    pub fn measure_ecr_columns(
        &mut self,
        sa: &SenseAmps,
        env: &Environment,
        calib: &Calibration,
        m: usize,
        samples: u32,
        master_seed: u64,
    ) -> EcrReport {
        let master = master_seed ^ env.temp_c.to_bits() ^ env.hours.to_bits();
        let batch_seed = derive_seed(master, &[STREAM_ECR, m as u64]);
        self.refresh_thresholds_columns(sa, env);
        let mut acc = BiasAccumulator::new(sa.cols());
        self.batch_prepared(calib, m, samples, batch_seed, &mut acc);
        EcrReport::from_error_counts(acc.error_counts().to_vec(), samples)
    }

    /// ECR measurement: per-column error counts over `samples` random
    /// MAJ-m patterns (paper §IV-A: 8,192 per bank).
    pub fn measure_ecr(
        &mut self,
        sub: &Subarray,
        calib: &Calibration,
        m: usize,
        samples: u32,
    ) -> EcrReport {
        self.measure_ecr_columns(&sub.sa, &sub.env, calib, m, samples, ECR_MASTER_SEED)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::SystemConfig;

    fn setup(cols: usize, seed: u64) -> (NativeEngine, Subarray) {
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = cols;
        let sub = Subarray::new(&cfg, &sys, seed);
        (NativeEngine::new(cfg), sub)
    }

    #[test]
    fn calibration_reduces_errors() {
        let (mut eng, sub) = setup(2048, 7);
        let base = FracConfig::baseline(3).uncalibrated(&eng.cfg, sub.cols);
        let tuned = eng.calibrate(&sub, &FracConfig::pudtune([2, 1, 0]), &CalibParams::paper());
        let ecr_b = eng.measure_ecr(&sub, &base, 5, 2048).ecr();
        let ecr_t = eng.measure_ecr(&sub, &tuned, 5, 2048).ecr();
        assert!(
            ecr_t < ecr_b / 3.0,
            "calibration should slash ECR: base={ecr_b:.3} tuned={ecr_t:.3}"
        );
    }

    #[test]
    fn baseline_ecr_is_high() {
        // §II-C: MAJ5 degrades to roughly 50% error-prone columns on
        // the baseline implementation.
        let (mut eng, sub) = setup(4096, 3);
        let base = FracConfig::baseline(3).uncalibrated(&eng.cfg, sub.cols);
        let ecr = eng.measure_ecr(&sub, &base, 5, 2048).ecr();
        assert!((0.30..0.65).contains(&ecr), "ecr={ecr}");
    }

    #[test]
    fn maj3_is_more_reliable_than_maj5() {
        // MAJ3's operand count is lower but margins are identical;
        // boundary patterns are rarer, so fewer columns *show* errors
        // at equal sample counts, never more errors than MAJ5 + noise.
        let (mut eng, sub) = setup(2048, 5);
        let base = FracConfig::baseline(3).uncalibrated(&eng.cfg, sub.cols);
        let e5 = eng.measure_ecr(&sub, &base, 5, 2048).ecr();
        let e3 = eng.measure_ecr(&sub, &base, 3, 2048).ecr();
        assert!(e3 <= e5 + 0.02, "e3={e3} e5={e5}");
    }

    #[test]
    fn calibration_is_deterministic() {
        let (mut eng, sub) = setup(512, 9);
        let p = CalibParams::quick();
        let a = eng.calibrate(&sub, &FracConfig::pudtune([2, 1, 0]), &p);
        let b = eng.calibrate(&sub, &FracConfig::pudtune([2, 1, 0]), &p);
        assert_eq!(a.levels, b.levels);
    }

    #[test]
    fn tiled_kernel_matches_reference_statistics() {
        // The per-(batch, column) streams + uniform-space decisions
        // must reproduce the shared-stream Gaussian reference kernel's
        // statistics: same device, both measured at 2,048 samples.
        let (mut eng, sub) = setup(4096, 13);
        let base = FracConfig::baseline(3).uncalibrated(&eng.cfg, sub.cols);
        let new_ecr = eng.measure_ecr(&sub, &base, 5, 2048).ecr();
        let mut rng = Rng::new(0x0EF5);
        let acc = eng.sample_batch_reference(&sub, &base, 5, 2048, &mut rng);
        let ref_ecr =
            EcrReport::from_error_counts(acc.error_counts().to_vec(), 2048).ecr();
        assert!(
            (new_ecr - ref_ecr).abs() < 0.04,
            "tiled={new_ecr:.4} reference={ref_ecr:.4}"
        );
    }

    #[test]
    fn calibrated_levels_track_offsets() {
        // Columns with strongly negative SA offset (threshold low ->
        // outputs 1 too often) should end below the neutral level;
        // strongly positive above it.
        let (mut eng, sub) = setup(4096, 11);
        let calib = eng.calibrate(&sub, &FracConfig::pudtune([2, 1, 0]), &CalibParams::paper());
        let neutral = calib.lattice.neutral_level() as i32;
        let mut low_ok = 0;
        let mut low_n = 0;
        let mut high_ok = 0;
        let mut high_n = 0;
        // Columns whose offset exceeds the majority margin *must* move
        // off the neutral level to become error-free; milder offsets may
        // legitimately stay (they are already within the margin).
        let must_move = sub.cfg.majority_margin() + 0.01;
        for c in 0..sub.cols {
            let off = sub.sa.variation.sa_offset[c] as f64;
            if off < -must_move {
                low_n += 1;
                if (calib.levels[c] as i32) < neutral {
                    low_ok += 1;
                }
            } else if off > must_move {
                high_n += 1;
                if (calib.levels[c] as i32) > neutral {
                    high_ok += 1;
                }
            }
        }
        assert!(low_n > 50 && high_n > 50, "not enough extreme columns");
        assert!(low_ok as f64 > 0.8 * low_n as f64, "{low_ok}/{low_n}");
        assert!(high_ok as f64 > 0.8 * high_n as f64, "{high_ok}/{high_n}");
    }

    #[test]
    fn calibration_is_storage_representation_independent() {
        // Algorithm 1 and the ECR battery read only (sense amps,
        // environment): running them against the hybrid subarray and
        // against the dense reference model built from the same seed
        // must agree bit for bit.
        use crate::dram::dense::DenseSubarray;
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = 512;
        let hyb = Subarray::new(&cfg, &sys, 0x5709);
        let den = DenseSubarray::new(&cfg, &sys, 0x5709);
        let fc = FracConfig::pudtune([2, 1, 0]);
        let p = CalibParams::quick();
        let mut eng = NativeEngine::new(cfg);
        let a = eng.calibrate(&hyb, &fc, &p);
        let b = eng.calibrate_columns(&den.sa, &den.env, &fc, &p);
        assert_eq!(a.levels, b.levels);
        let ra = eng.measure_ecr(&hyb, &a, 5, 2048);
        let rb = eng.measure_ecr_columns(&den.sa, &den.env, &b, 5, 2048, ECR_MASTER_SEED);
        assert_eq!(ra.error_counts, rb.error_counts);
    }

    #[test]
    fn row_bits_reflect_levels() {
        let cfg = DeviceConfig::default();
        let lat = OffsetLattice::build(&cfg, &FracConfig::pudtune([2, 1, 0]));
        let mut calib = Calibration::uniform(lat, 8);
        calib.levels = (0..8u8).collect();
        for r in 0..3 {
            let bits = calib.row_bits(r);
            for c in 0..8 {
                assert_eq!(bits[c], calib.lattice.levels[c].bits[r]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "not supported")]
    fn const_q_rejects_unknown_majx() {
        const_q(7);
    }
}
