//! Calibration-data identification (paper Algorithm 1) and ECR
//! measurement on the native golden model.
//!
//! The native engine evaluates the same arithmetic as the analog
//! subarray (`Subarray::simra`) but vectorised per column — random
//! operand count + calibration charge -> charge-share -> noisy compare —
//! which is what lets full calibration sweeps run in milliseconds while
//! staying bit-compatible with the golden model (see the consistency
//! test in `rust/tests/`). Mass experiments use the PJRT path
//! (`coordinator::engine`) which executes the same graphs as AOT
//! artifacts.

use crate::analysis::ecr::EcrReport;
use crate::calib::bias::BiasAccumulator;
use crate::calib::lattice::{ConfigKind, FracConfig, OffsetLattice};
use crate::config::device::DeviceConfig;
use crate::dram::subarray::Subarray;
use crate::util::rng::Rng;

/// Identified calibration state for one subarray.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub lattice: OffsetLattice,
    /// Per-column lattice level index.
    pub levels: Vec<u8>,
}

impl Calibration {
    /// Uniform calibration at the lattice's neutral level (the
    /// starting point of Algorithm 1, and the whole story for the
    /// baseline configuration whose lattice has a single pattern).
    pub fn uniform(lattice: OffsetLattice, cols: usize) -> Self {
        let lv = lattice.neutral_level() as u8;
        Self { lattice, levels: vec![lv; cols] }
    }

    pub fn cols(&self) -> usize {
        self.levels.len()
    }

    /// Total calibration charge of one column (cell-equivalents).
    #[inline]
    pub fn q_extra(&self, col: usize) -> f64 {
        self.lattice.levels[self.levels[col] as usize].q_total
    }

    /// Bit pattern stored in calibration row `row` (0..3) — what gets
    /// written to the subarray's reserved rows / the NV store.
    pub fn row_bits(&self, row: usize) -> Vec<u8> {
        assert!(row < 3);
        self.levels
            .iter()
            .map(|&lv| self.lattice.levels[lv as usize].bits[row])
            .collect()
    }
}

impl FracConfig {
    /// The un-identified (uniform) calibration for this configuration —
    /// for the baseline this *is* the complete configuration.
    pub fn uncalibrated(&self, cfg: &DeviceConfig, cols: usize) -> Calibration {
        Calibration::uniform(OffsetLattice::build(cfg, self), cols)
    }
}

/// Parameters of Algorithm 1.
#[derive(Clone, Copy, Debug)]
pub struct CalibParams {
    /// n_iterations (paper §IV-A: 20).
    pub iterations: u32,
    /// Random samples per iteration (paper §IV-A: 512).
    pub samples: u32,
    /// Bias threshold (Algorithm 1's `threshold`).
    pub tau: f64,
    /// Seed for the sampling streams.
    pub seed: u64,
}

impl CalibParams {
    /// The paper's §IV-A settings.
    pub fn paper() -> Self {
        Self { iterations: 20, samples: 512, tau: 0.02, seed: 0x1DE7 }
    }

    pub fn quick() -> Self {
        Self { iterations: 12, samples: 256, ..Self::paper() }
    }
}

/// Constant-row charge opened alongside the calibration rows for MAJ-m
/// under 8-row SiMRA: MAJ5 opens none (5 operands + 3 calib), MAJ3
/// additionally opens a constant-0 and a constant-1 row.
pub fn const_q(m: usize) -> f64 {
    match m {
        5 => 0.0,
        3 => 1.0,
        _ => panic!("MAJ{m} not supported under 8-row SiMRA"),
    }
}

/// Native (golden-model-equivalent) calibration + measurement engine.
#[derive(Clone, Debug)]
pub struct NativeEngine {
    pub cfg: DeviceConfig,
}

impl NativeEngine {
    pub fn new(cfg: DeviceConfig) -> Self {
        Self { cfg }
    }

    /// One sampling batch: `samples` random MAJ-m patterns per column.
    /// Identical math to `Subarray::simra` restricted to the SiMRA
    /// group, vectorised per column.
    pub fn sample_batch(
        &self,
        sub: &Subarray,
        calib: &Calibration,
        m: usize,
        samples: u32,
        rng: &mut Rng,
    ) -> BiasAccumulator {
        let cols = sub.cols;
        let rows = self.cfg.simra_rows;
        let maj_t = m.div_ceil(2) as u32;
        let cq = const_q(m);
        let mut acc = BiasAccumulator::new(cols);
        let mut out = vec![0u8; cols];
        let mut exp = vec![0u8; cols];
        // V(k, q) = a*k + b(q) — precompute the affine pieces so the
        // inner loop is one fused multiply-add per (column, sample).
        let denom = rows as f64 * self.cfg.cc_ff + self.cfg.cb_ff;
        let a = self.cfg.cc_ff / denom;
        let base: Vec<f64> = (0..cols)
            .map(|c| {
                let b = (self.cfg.cc_ff * (calib.q_extra(c) + cq)
                    + self.cfg.cb_ff * self.cfg.v_pre)
                    / denom;
                b - sub.sa.threshold(&self.cfg, &sub.env, c)
            })
            .collect();
        let sigma = self.cfg.sigma_noise;
        for _ in 0..samples {
            for c in 0..cols {
                let word = rng.next_u64();
                let k = (word & ((1u64 << m) - 1)).count_ones();
                let d = a * k as f64 + base[c];
                out[c] = (d + rng.normal_ms(0.0, sigma) > 0.0) as u8;
                exp[c] = (k >= maj_t) as u8;
            }
            acc.record(&out, &exp);
        }
        acc
    }

    /// Algorithm 1: iteratively identify per-column calibration data.
    pub fn calibrate(
        &mut self,
        sub: &mut Subarray,
        fc: &FracConfig,
        params: &CalibParams,
    ) -> Calibration {
        let lattice = OffsetLattice::build(&self.cfg, fc);
        let mut calib = Calibration::uniform(lattice, sub.cols);
        if fc.kind == ConfigKind::Baseline {
            // No per-column freedom to exploit.
            return calib;
        }
        let max_lv = (calib.lattice.len() - 1) as u8;
        let mut rng = Rng::new(params.seed);
        for _iter in 0..params.iterations {
            let acc = self.sample_batch(sub, &calib, 5, params.samples, &mut rng);
            for c in 0..sub.cols {
                let bias = acc.bias(c);
                // Algorithm 1 lines 6-11: |bias| beyond the threshold
                // steps the level against the bias. Columns that still
                // show *any* errors are additionally nudged in the bias
                // direction — at 512 samples a sub-threshold bias of a
                // few flips is still a reliable direction signal, and
                // without the nudge columns converge to "just inside
                // the margin" levels that the 8,192-sample ECR test
                // still catches (see rust/tests/debug_calib.rs).
                if bias > params.tau || (acc.errors(c) > 0 && bias > 0.0) {
                    // Outputs '1' too often -> reduce calibration charge.
                    calib.levels[c] = calib.levels[c].saturating_sub(1);
                } else if bias < -params.tau || (acc.errors(c) > 0 && bias < 0.0) {
                    calib.levels[c] = (calib.levels[c] + 1).min(max_lv);
                }
            }
        }
        calib
    }

    /// ECR measurement: per-column error counts over `samples` random
    /// MAJ-m patterns (paper §IV-A: 8,192 per bank).
    pub fn measure_ecr(
        &mut self,
        sub: &mut Subarray,
        calib: &Calibration,
        m: usize,
        samples: u32,
    ) -> EcrReport {
        let mut rng = Rng::new(0xECC ^ sub.env.temp_c.to_bits() ^ sub.env.hours.to_bits());
        let acc = self.sample_batch(sub, calib, m, samples, &mut rng);
        EcrReport::from_error_counts(acc.error_counts().to_vec(), samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::SystemConfig;

    fn setup(cols: usize, seed: u64) -> (NativeEngine, Subarray) {
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = cols;
        let sub = Subarray::new(&cfg, &sys, seed);
        (NativeEngine::new(cfg), sub)
    }

    #[test]
    fn calibration_reduces_errors() {
        let (mut eng, mut sub) = setup(2048, 7);
        let base = FracConfig::baseline(3).uncalibrated(&eng.cfg, sub.cols);
        let tuned = eng.calibrate(&mut sub, &FracConfig::pudtune([2, 1, 0]), &CalibParams::paper());
        let ecr_b = eng.measure_ecr(&mut sub, &base, 5, 2048).ecr();
        let ecr_t = eng.measure_ecr(&mut sub, &tuned, 5, 2048).ecr();
        assert!(
            ecr_t < ecr_b / 3.0,
            "calibration should slash ECR: base={ecr_b:.3} tuned={ecr_t:.3}"
        );
    }

    #[test]
    fn baseline_ecr_is_high() {
        // §II-C: MAJ5 degrades to roughly 50% error-prone columns on
        // the baseline implementation.
        let (mut eng, mut sub) = setup(4096, 3);
        let base = FracConfig::baseline(3).uncalibrated(&eng.cfg, sub.cols);
        let ecr = eng.measure_ecr(&mut sub, &base, 5, 2048).ecr();
        assert!((0.30..0.65).contains(&ecr), "ecr={ecr}");
    }

    #[test]
    fn maj3_is_more_reliable_than_maj5() {
        // MAJ3's operand count is lower but margins are identical;
        // boundary patterns are rarer, so fewer columns *show* errors
        // at equal sample counts, never more errors than MAJ5 + noise.
        let (mut eng, mut sub) = setup(2048, 5);
        let base = FracConfig::baseline(3).uncalibrated(&eng.cfg, sub.cols);
        let e5 = eng.measure_ecr(&mut sub, &base, 5, 2048).ecr();
        let e3 = eng.measure_ecr(&mut sub, &base, 3, 2048).ecr();
        assert!(e3 <= e5 + 0.02, "e3={e3} e5={e5}");
    }

    #[test]
    fn calibration_is_deterministic() {
        let (mut eng, mut sub) = setup(512, 9);
        let p = CalibParams::quick();
        let a = eng.calibrate(&mut sub, &FracConfig::pudtune([2, 1, 0]), &p);
        let b = eng.calibrate(&mut sub, &FracConfig::pudtune([2, 1, 0]), &p);
        assert_eq!(a.levels, b.levels);
    }

    #[test]
    fn calibrated_levels_track_offsets() {
        // Columns with strongly negative SA offset (threshold low ->
        // outputs 1 too often) should end below the neutral level;
        // strongly positive above it.
        let (mut eng, mut sub) = setup(4096, 11);
        let calib = eng.calibrate(&mut sub, &FracConfig::pudtune([2, 1, 0]), &CalibParams::paper());
        let neutral = calib.lattice.neutral_level() as i32;
        let mut low_ok = 0;
        let mut low_n = 0;
        let mut high_ok = 0;
        let mut high_n = 0;
        // Columns whose offset exceeds the majority margin *must* move
        // off the neutral level to become error-free; milder offsets may
        // legitimately stay (they are already within the margin).
        let must_move = sub.cfg.majority_margin() + 0.01;
        for c in 0..sub.cols {
            let off = sub.sa.variation.sa_offset[c] as f64;
            if off < -must_move {
                low_n += 1;
                if (calib.levels[c] as i32) < neutral {
                    low_ok += 1;
                }
            } else if off > must_move {
                high_n += 1;
                if (calib.levels[c] as i32) > neutral {
                    high_ok += 1;
                }
            }
        }
        assert!(low_n > 50 && high_n > 50, "not enough extreme columns");
        assert!(low_ok as f64 > 0.8 * low_n as f64, "{low_ok}/{low_n}");
        assert!(high_ok as f64 > 0.8 * high_n as f64, "{high_ok}/{high_n}");
    }

    #[test]
    fn row_bits_reflect_levels() {
        let cfg = DeviceConfig::default();
        let lat = OffsetLattice::build(&cfg, &FracConfig::pudtune([2, 1, 0]));
        let mut calib = Calibration::uniform(lat, 8);
        calib.levels = (0..8u8).collect();
        for r in 0..3 {
            let bits = calib.row_bits(r);
            for c in 0..8 {
                assert_eq!(bits[c], calib.lattice.levels[c].bits[r]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "not supported")]
    fn const_q_rejects_unknown_majx() {
        const_q(7);
    }
}
