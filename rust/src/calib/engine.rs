//! The backend-agnostic calibration *and compute* engine API.
//!
//! The paper's pipeline — offset-search calibration (Algorithm 1,
//! §IV-A) followed by mass ECR measurement — used to be implemented
//! twice with diverging signatures: the native column-tiled kernel
//! (`calib::algorithm`) and the PJRT AOT path (`coordinator::engine`).
//! This module is the single seam between *what* a calibration workload
//! is and *which backend* executes it:
//!
//! * **Request types** — [`CalibRequest`] and [`EcrRequest`] describe
//!   one bank's job in backend-neutral terms (a [`ColumnBank`]: the
//!   sense-amp variation field + environment + seed; cell charges never
//!   matter to the sampling hot loop). [`BankBatch`] materialises the
//!   per-bank requests of a whole device from one seed.
//! * **[`CalibEngine`]** — the trait every backend implements. It is
//!   **batch-first**: `calibrate_batch` / `measure_ecr_batch` take
//!   slices of requests so backends can exploit whole-device shape —
//!   the native engine fans requests across the scoped worker pool,
//!   the PJRT engine stacks multiple banks' `[cols]`-shaped thresholds
//!   into **one executable invocation** (see
//!   `coordinator::engine`). Single-item calls ([`CalibEngine::calibrate_one`],
//!   [`CalibEngine::measure_ecr_one`]) are default-method sugar over
//!   the batch entry points.
//! * **[`AnyEngine`]** — the runtime-selected backend
//!   ([`AnyEngine::auto`] opens the PJRT runtime when AOT artifacts are
//!   present and falls back to the native kernel otherwise), so service
//!   code is written once against the trait.
//! * **[`ComputeEngine`]** — the same batch-first shape for *serving
//!   arithmetic*: a [`ComputeRequest`] pairs a compiled, bank-agnostic
//!   [`WorkloadPlan`] with one bank (geometry + seed + environment),
//!   its current [`Calibration`] and an optional error-free column
//!   mask; `execute_batch` runs the whole slice **batch-fused**:
//!   requests are grouped by (plan fingerprint, geometry) and every
//!   group's banks walk the plan's canonical lowering
//!   ([`WorkloadPlan::lowered`]) step-major in one worker-pool
//!   dispatch — bit-identical to the per-request
//!   [`crate::pud::exec::run_plan`] loop (PJRT: per-step native
//!   fallback until circuit-execution artifacts exist, counted by
//!   `pjrt.compute.fallback`). Malformed requests surface as typed
//!   [`PudError`]s, and [`execute_isolated`] degrades a faulty bank to
//!   one error slot exactly like [`calibrate_isolated`].
//!
//! ## Determinism contract
//!
//! The native implementation delegates to the column-tiled kernel and
//! inherits its bit-identical guarantee: results never depend on tile
//! size, worker count, or batch shape — `calibrate_batch(&[a, b])`
//! equals `[calibrate_one(&a), calibrate_one(&b)]` bit for bit, and a
//! request built from a `Subarray` reproduces the inherent
//! `NativeEngine::calibrate` / `measure_ecr` results exactly
//! (`rust/tests/determinism.rs` and `rust/tests/engine_api.rs` pin
//! both). The PJRT fused path draws different (but equally valid)
//! streams per batch shape; cross-backend agreement is statistical and
//! pinned by `rust/tests/cross_validation.rs`.

use anyhow::Result;

use crate::analysis::ecr::EcrReport;
use crate::calib::algorithm::{CalibParams, Calibration, NativeEngine, ECR_MASTER_SEED};
use crate::calib::lattice::FracConfig;
use crate::config::device::DeviceConfig;
use crate::config::system::Ddr4Timing;
use crate::coordinator::engine::{ColumnBank, PjrtEngine};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::worker;
use crate::dram::geometry::RowMap;
use crate::dram::subarray::Subarray;
use crate::dram::temperature::Environment;
use crate::pud::exec::{run_plan, StepRunner};
use crate::pud::majx::setup_subarray;
use crate::pud::plan::{PudError, WorkloadPlan};
use crate::pud::ranges::{OperandRange, RangeClass};
use crate::pud::verify::LoweredPlan;
use crate::runtime::Runtime;
use crate::util::rng::derive_seed;
use std::borrow::Cow;
use std::sync::Arc;

/// One bank's calibration job (Algorithm 1 under one Frac config).
#[derive(Clone, Debug)]
pub struct CalibRequest {
    /// The bank to calibrate: variation field + environment + seed.
    pub bank: ColumnBank,
    /// Frac configuration to identify calibration data for.
    pub config: FracConfig,
    /// Algorithm-1 parameters (iterations, samples, tau, seed).
    pub params: CalibParams,
}

impl CalibRequest {
    pub fn new(bank: ColumnBank, config: FracConfig, params: CalibParams) -> Self {
        Self { bank, config, params }
    }

    /// Request against an existing subarray's sense amps + environment
    /// (`bank_seed` is the seed the subarray was built from; it selects
    /// the PJRT stream domain and is ignored by the native kernel).
    pub fn from_subarray(
        sub: &Subarray,
        bank_seed: u64,
        config: FracConfig,
        params: CalibParams,
    ) -> Self {
        Self::new(ColumnBank::from_subarray(sub, bank_seed), config, params)
    }

    pub fn cols(&self) -> usize {
        self.bank.cols()
    }
}

/// One bank's ECR measurement job (`samples` random MAJ-m patterns).
#[derive(Clone, Debug)]
pub struct EcrRequest {
    pub bank: ColumnBank,
    /// Calibration state to measure under.
    pub calib: Calibration,
    /// Operand count (5 or 3 under 8-row SiMRA).
    pub m: usize,
    /// Battery depth (paper §IV-A: 8,192). The PJRT path runs its
    /// artifact's baked `total_samples` instead; the returned report
    /// carries the depth actually measured.
    pub samples: u32,
    /// Master-seed tag of the sampling stream domain. The default
    /// ([`ECR_MASTER_SEED`]) reproduces `NativeEngine::measure_ecr`
    /// bit for bit; distinct tags give independent batteries.
    pub seed: u64,
}

impl EcrRequest {
    pub fn new(bank: ColumnBank, calib: Calibration, m: usize, samples: u32) -> Self {
        Self { bank, calib, m, samples, seed: ECR_MASTER_SEED }
    }

    /// Same request on a distinct stream domain.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Request against an existing subarray's sense amps + environment.
    pub fn from_subarray(
        sub: &Subarray,
        bank_seed: u64,
        calib: Calibration,
        m: usize,
        samples: u32,
    ) -> Self {
        Self::new(ColumnBank::from_subarray(sub, bank_seed), calib, m, samples)
    }

    pub fn cols(&self) -> usize {
        self.bank.cols()
    }
}

/// The banks of (part of) a device, described by seeds — the unit the
/// coordinator hands to an engine in one batched call.
#[derive(Clone, Debug)]
pub struct BankBatch {
    pub cfg: DeviceConfig,
    /// Columns per bank.
    pub cols: usize,
    /// One variation-field seed per bank.
    pub seeds: Vec<u64>,
}

impl BankBatch {
    /// Per-bank seeds derived from one device seed — the same
    /// derivation the native and PJRT experiment paths have always
    /// used, so batched runs see identical variation fields.
    pub fn from_device_seed(cfg: DeviceConfig, cols: usize, device_seed: u64, banks: usize) -> Self {
        let seeds = (0..banks)
            .map(|b| derive_seed(device_seed, &[0, b as u64, 0]))
            .collect();
        Self { cfg, cols, seeds }
    }

    /// Batch over explicit per-bank seeds.
    pub fn with_seeds(cfg: DeviceConfig, cols: usize, seeds: Vec<u64>) -> Self {
        Self { cfg, cols, seeds }
    }

    pub fn len(&self) -> usize {
        self.seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seeds.is_empty()
    }

    /// Materialise the banks (variation fields drawn from the seeds).
    pub fn banks(&self) -> Vec<ColumnBank> {
        self.seeds
            .iter()
            .map(|&s| ColumnBank::new(&self.cfg, self.cols, s))
            .collect()
    }

    /// One calibration request per bank, all under the same config.
    /// Draws the variation fields afresh — when issuing several phases
    /// over the same batch, materialise [`Self::banks`] once and use
    /// [`Self::calib_requests_for`] instead.
    pub fn calib_requests(&self, config: FracConfig, params: CalibParams) -> Vec<CalibRequest> {
        Self::calib_requests_for(&self.banks(), config, params)
    }

    /// [`Self::calib_requests`] over already-materialised banks.
    pub fn calib_requests_for(
        banks: &[ColumnBank],
        config: FracConfig,
        params: CalibParams,
    ) -> Vec<CalibRequest> {
        banks
            .iter()
            .map(|bank| CalibRequest::new(bank.clone(), config, params))
            .collect()
    }

    /// One ECR request per bank (`calibs` pairs with the banks; pass
    /// the output of [`CalibEngine::calibrate_batch`]). Draws the
    /// variation fields afresh — see [`Self::ecr_requests_for`].
    pub fn ecr_requests(
        &self,
        calibs: &[Calibration],
        m: usize,
        samples: u32,
    ) -> Vec<EcrRequest> {
        assert_eq!(calibs.len(), self.len(), "one calibration per bank");
        Self::ecr_requests_for(&self.banks(), calibs, m, samples)
    }

    /// [`Self::ecr_requests`] over already-materialised banks.
    pub fn ecr_requests_for(
        banks: &[ColumnBank],
        calibs: &[Calibration],
        m: usize,
        samples: u32,
    ) -> Vec<EcrRequest> {
        assert_eq!(calibs.len(), banks.len(), "one calibration per bank");
        banks
            .iter()
            .zip(calibs)
            .map(|(bank, calib)| EcrRequest::new(bank.clone(), calib.clone(), m, samples))
            .collect()
    }
}

/// One bank's arithmetic-workload job: a compiled plan plus everything
/// needed to materialise the bank (geometry + variation seed +
/// environment), the calibration to run under, per-column operand
/// values, and an optional error-free column mask (from an ECR
/// battery) restricting which columns' outputs are trusted/reported.
#[derive(Clone, Debug)]
pub struct ComputeRequest {
    /// The compiled workload (shared across banks/batches via `Arc`).
    pub plan: Arc<WorkloadPlan>,
    /// Subarray geometry to execute on.
    pub rows: usize,
    pub cols: usize,
    /// Variation-field seed (same derivation as `Subarray`).
    pub seed: u64,
    /// Calibration state to execute under (its lattice fixes the Frac
    /// configuration of every MAJX flow).
    pub calib: Calibration,
    /// Environment override (die temperature + retention clock);
    /// `None` executes at the nominal calibration temperature. The
    /// variation field is re-drawn from `seed`, so accumulated
    /// Brownian aging drift is *not* carried — the serving lifecycle
    /// handles aging by recalibrating, not by replaying the walk.
    pub env: Option<Environment>,
    /// Command timing grade for the latency account.
    pub grade: Ddr4Timing,
    /// Per-column operand values, `plan.op.n_operands()` vectors of
    /// `cols` values each.
    pub operands: Vec<Vec<u64>>,
    /// Error-free column mask (`None` = trust every column).
    pub mask: Option<Vec<bool>>,
    /// Redundant-execution factor: the workload runs on this many
    /// independently seeded spare banks and the per-column outputs are
    /// combined by bitwise majority vote (`1` = single run, the
    /// default; `0` is treated as `1`). Latency is accounted as the
    /// sum of all replica runs — redundancy is never free.
    pub replicas: usize,
    /// Declared per-operand value ranges (`None` = full width). When
    /// set, operands are validated against them
    /// ([`PudError::RangeViolation`]) and the engine transparently
    /// substitutes the width-narrowed plan variant for the ranges'
    /// [`RangeClass`] from the process-wide `PlanCache` — bit-identical
    /// outputs for in-range operands, fewer gates and steps.
    pub declared_ranges: Option<Vec<OperandRange>>,
}

impl ComputeRequest {
    pub fn new(
        plan: Arc<WorkloadPlan>,
        rows: usize,
        cols: usize,
        seed: u64,
        calib: Calibration,
        operands: Vec<Vec<u64>>,
    ) -> Self {
        Self {
            plan,
            rows,
            cols,
            seed,
            calib,
            env: None,
            grade: Ddr4Timing::ddr4_2133(),
            operands,
            mask: None,
            replicas: 1,
            declared_ranges: None,
        }
    }

    /// Request against an existing subarray's geometry + environment
    /// (`seed` is the seed the subarray was built from).
    pub fn from_subarray(
        sub: &Subarray,
        seed: u64,
        plan: Arc<WorkloadPlan>,
        calib: Calibration,
        operands: Vec<Vec<u64>>,
    ) -> Self {
        Self {
            env: Some(sub.env),
            ..Self::new(plan, sub.rows, sub.cols, seed, calib, operands)
        }
    }

    /// Restrict execution reporting to an error-free column mask.
    pub fn with_mask(mut self, mask: Vec<bool>) -> Self {
        self.mask = Some(mask);
        self
    }

    /// Run on `n` independently seeded replicas with per-column
    /// bitwise majority vote (see [`Self::replicas`]).
    pub fn with_replicas(mut self, n: usize) -> Self {
        self.replicas = n;
        self
    }

    /// Declare per-operand value ranges (see
    /// [`Self::declared_ranges`]): operands outside them are rejected,
    /// and the engine may serve a width-narrowed plan variant.
    pub fn with_ranges(mut self, ranges: Vec<OperandRange>) -> Self {
        self.declared_ranges = Some(ranges);
        self
    }

    /// Validate the operands against the declared ranges (no-op when
    /// none are declared): the narrowed variant is only bit-identical
    /// inside them, so a violation is a typed rejection, never a wrong
    /// answer.
    pub fn validate_ranges(&self) -> Result<(), PudError> {
        let Some(ranges) = &self.declared_ranges else { return Ok(()) };
        if ranges.len() != self.plan.op.n_operands() {
            return Err(PudError::ArityMismatch {
                expected: self.plan.op.n_operands(),
                got: ranges.len(),
            });
        }
        for (i, (r, vals)) in ranges.iter().zip(&self.operands).enumerate() {
            if let Some(&v) = vals.iter().find(|v| !r.contains(**v)) {
                return Err(PudError::RangeViolation { operand: i, value: v, lo: r.lo, hi: r.hi });
            }
        }
        Ok(())
    }

    /// Software golden model of this request: the expected per-column
    /// output values via [`crate::pud::graph::MajCircuit::eval`].
    pub fn golden_outputs(&self) -> Result<Vec<u64>, PudError> {
        self.plan.golden_outputs(&self.operands, self.cols)
    }
}

/// One bank's executed workload batch.
#[derive(Clone, Debug)]
pub struct ComputeResult {
    /// Decoded per-column output values (every column; only masked
    /// columns are trusted).
    pub outputs: Vec<u64>,
    /// The mask execution reported under (all-true when the request
    /// carried none).
    pub mask: Vec<bool>,
    /// DRAM command latency of the run, ns (summed over replicas when
    /// the request asked for redundant execution).
    pub elapsed_ns: f64,
    /// Peak simultaneous scratch rows (max over replicas).
    pub peak_rows: usize,
    /// Fault-injection bit flips the run(s) absorbed (summed over
    /// replicas; 0 unless the device config enables `dram::faults`).
    pub fault_flips: u64,
}

impl ComputeResult {
    /// Error-free columns the workload served.
    pub fn active_cols(&self) -> usize {
        self.mask.iter().filter(|&&m| m).count()
    }

    /// A masked column's output (`None` off-mask or out of range).
    pub fn output(&self, col: usize) -> Option<u64> {
        match self.mask.get(col) {
            Some(true) => self.outputs.get(col).copied(),
            _ => None,
        }
    }

    /// Masked columns whose outputs equal the golden-model values —
    /// the serving-quality figure every caller reports.
    pub fn golden_correct(&self, golden: &[u64]) -> usize {
        self.outputs
            .iter()
            .zip(golden)
            .zip(&self.mask)
            .filter(|((o, g), &m)| m && o == g)
            .count()
    }
}

/// An arithmetic-serving backend, mirroring [`CalibEngine`]'s
/// batch-first shape: `execute_batch` is the primitive, `execute_one`
/// is sugar.
pub trait ComputeEngine {
    /// Short backend tag for logs/reports ("native", ...).
    fn compute_backend(&self) -> &'static str;

    /// Run every request, results in request order.
    fn execute_batch(&self, reqs: &[ComputeRequest]) -> Result<Vec<ComputeResult>>;

    /// Single-bank sugar over [`Self::execute_batch`].
    fn execute_one(&self, req: &ComputeRequest) -> Result<ComputeResult> {
        let mut out = self.execute_batch(std::slice::from_ref(req))?;
        anyhow::ensure!(out.len() == 1, "engine returned {} results for 1 request", out.len());
        Ok(out.pop().unwrap())
    }
}

impl<E: ComputeEngine + ?Sized> ComputeEngine for &E {
    fn compute_backend(&self) -> &'static str {
        (**self).compute_backend()
    }

    fn execute_batch(&self, reqs: &[ComputeRequest]) -> Result<Vec<ComputeResult>> {
        (**self).execute_batch(reqs)
    }
}

/// A calibration + measurement backend.
///
/// Batch methods are the primitive: implementations are free to
/// exploit the whole request slice (worker-pool fan-out, stacking
/// banks into one executable call). The `_one` forms are sugar.
pub trait CalibEngine {
    /// Short backend tag for logs/reports ("native", "pjrt", ...).
    fn backend(&self) -> &'static str;

    /// Algorithm 1 for every request, results in request order.
    fn calibrate_batch(&self, reqs: &[CalibRequest]) -> Result<Vec<Calibration>>;

    /// ECR battery for every request, results in request order.
    fn measure_ecr_batch(&self, reqs: &[EcrRequest]) -> Result<Vec<EcrReport>>;

    /// Single-bank sugar over [`Self::calibrate_batch`].
    fn calibrate_one(&self, req: &CalibRequest) -> Result<Calibration> {
        let mut out = self.calibrate_batch(std::slice::from_ref(req))?;
        anyhow::ensure!(out.len() == 1, "engine returned {} results for 1 request", out.len());
        Ok(out.pop().unwrap())
    }

    /// Single-bank sugar over [`Self::measure_ecr_batch`].
    fn measure_ecr_one(&self, req: &EcrRequest) -> Result<EcrReport> {
        let mut out = self.measure_ecr_batch(std::slice::from_ref(req))?;
        anyhow::ensure!(out.len() == 1, "engine returned {} results for 1 request", out.len());
        Ok(out.pop().unwrap())
    }
}

/// Engines pass through shared references, so generic consumers (e.g.
/// `DeviceCoordinator<E>`) can borrow an engine owned elsewhere.
impl<E: CalibEngine + ?Sized> CalibEngine for &E {
    fn backend(&self) -> &'static str {
        (**self).backend()
    }

    fn calibrate_batch(&self, reqs: &[CalibRequest]) -> Result<Vec<Calibration>> {
        (**self).calibrate_batch(reqs)
    }

    fn measure_ecr_batch(&self, reqs: &[EcrRequest]) -> Result<Vec<EcrReport>> {
        (**self).measure_ecr_batch(reqs)
    }
}

impl NativeEngine {
    /// Split the worker budget across `jobs` concurrent per-request
    /// kernels: request-grain fan-out uses up to `threads` workers and
    /// any leftover budget goes to tile fan-out inside each kernel, so
    /// small batches still saturate the pool without oversubscribing.
    fn inner_threads(&self, jobs: usize) -> usize {
        (self.threads / jobs.max(1)).max(1)
    }
}

/// The native column-tiled kernel behind the trait.
///
/// A single request keeps the engine's own tile fan-out (`threads`
/// workers across column tiles); multiple requests fan across the pool
/// at bank grain, with the pool split across the per-request kernels
/// when the batch is smaller than the pool. Execution shape never
/// changes results (address-derived streams; see `calib::algorithm`).
impl CalibEngine for NativeEngine {
    fn backend(&self) -> &'static str {
        "native"
    }

    fn calibrate_batch(&self, reqs: &[CalibRequest]) -> Result<Vec<Calibration>> {
        if reqs.len() == 1 {
            let r = &reqs[0];
            let mut eng = self.clone();
            return Ok(vec![eng.calibrate_columns(&r.bank.sa, &r.bank.env, &r.config, &r.params)]);
        }
        let inner = self.inner_threads(reqs.len());
        Ok(worker::parallel_map((0..reqs.len()).collect(), self.threads, |i| {
            let r = &reqs[i];
            let mut eng = NativeEngine::with_parallelism(self.cfg.clone(), self.tile_cols, inner);
            eng.calibrate_columns(&r.bank.sa, &r.bank.env, &r.config, &r.params)
        }))
    }

    fn measure_ecr_batch(&self, reqs: &[EcrRequest]) -> Result<Vec<EcrReport>> {
        if reqs.len() == 1 {
            let r = &reqs[0];
            let mut eng = self.clone();
            return Ok(vec![eng.measure_ecr_columns(
                &r.bank.sa, &r.bank.env, &r.calib, r.m, r.samples, r.seed,
            )]);
        }
        let inner = self.inner_threads(reqs.len());
        Ok(worker::parallel_map((0..reqs.len()).collect(), self.threads, |i| {
            let r = &reqs[i];
            let mut eng = NativeEngine::with_parallelism(self.cfg.clone(), self.tile_cols, inner);
            eng.measure_ecr_columns(&r.bank.sa, &r.bank.env, &r.calib, r.m, r.samples, r.seed)
        }))
    }
}

/// Stream tag of the spare banks redundant execution runs on: replica
/// `i > 0` of a request executes on the variation field drawn from
/// `derive_seed(req.seed, &[SPARE_STREAM, i])`, so every replica sees
/// independent variation *and* an independent fault field — which is
/// what lets the majority vote outvote a faulty column.
pub const SPARE_STREAM: u64 = 0x5AFE;

impl NativeEngine {
    /// One workload run on a freshly materialised golden-model
    /// subarray seeded from `seed`. Returns the decoded per-column
    /// outputs, DRAM latency, peak scratch rows and fault flips.
    fn execute_single(
        &self,
        req: &ComputeRequest,
        seed: u64,
    ) -> Result<(Vec<u64>, f64, usize, u64), PudError> {
        let inputs = req.plan.encode_operands(&req.operands)?;
        let mut sub = Subarray::with_geometry(&self.cfg, req.rows, req.cols, seed);
        if let Some(env) = req.env {
            sub.env = env;
        }
        let map = RowMap::standard(req.rows);
        let fc = req.calib.lattice.config;
        let run = run_plan(&mut sub, &map, &req.calib, &fc, &req.grade, &req.plan, &inputs)?;
        let outputs = (0..req.cols)
            .map(|c| req.plan.decode_output(&run.outputs, c))
            .collect();
        Ok((outputs, run.elapsed_ns, run.peak_rows, sub.fault_flips()))
    }

    /// Execute one compute request on a freshly materialised
    /// golden-model subarray (variation field from the request seed,
    /// environment from the request). All validation happens before
    /// any DRAM state is touched, so a malformed request is a clean
    /// per-bank `Err`. `req.replicas > 1` runs the workload on that
    /// many independently seeded spare banks and combines the outputs
    /// by per-column bitwise majority vote ([`SPARE_STREAM`]).
    fn execute_request(&self, req: &ComputeRequest) -> Result<ComputeResult, PudError> {
        // Admission: reject unverified (hand-assembled) plans before
        // any replica touches a subarray. Compiled plans pass in O(1).
        crate::pud::verify::admit(&req.plan)?;
        for v in &req.operands {
            if v.len() != req.cols {
                return Err(PudError::WidthMismatch { expected: req.cols, got: v.len() });
            }
        }
        if req.calib.cols() != req.cols {
            return Err(PudError::WidthMismatch {
                expected: req.cols,
                got: req.calib.cols(),
            });
        }
        if let Some(mask) = &req.mask {
            if mask.len() != req.cols {
                return Err(PudError::WidthMismatch { expected: req.cols, got: mask.len() });
            }
        }
        if req.rows < 32 {
            // `RowMap::standard` needs the reserved-row layout.
            return Err(PudError::RowBudgetExceeded { needed: 32, available: req.rows });
        }
        let runs = req.replicas.max(1);
        let mut all = Vec::with_capacity(runs);
        let mut elapsed_ns = 0.0;
        let mut peak_rows = 0usize;
        let mut fault_flips = 0u64;
        for i in 0..runs {
            let seed = if i == 0 {
                req.seed
            } else {
                derive_seed(req.seed, &[SPARE_STREAM, i as u64])
            };
            let (outputs, e, p, f) = self.execute_single(req, seed)?;
            elapsed_ns += e;
            peak_rows = peak_rows.max(p);
            fault_flips += f;
            all.push(outputs);
        }
        let outputs = combine_replicas(all, req.cols);
        let mask = req.mask.clone().unwrap_or_else(|| vec![true; req.cols]);
        Ok(ComputeResult { outputs, mask, elapsed_ns, peak_rows, fault_flips })
    }

    /// Validate one request exactly like the per-request path (same
    /// checks, in the same order, producing the same error values) and
    /// prepare what fused execution needs up front: the encoded input
    /// bit-planes and the plan's canonical lowering.
    fn prepare_request(
        &self,
        req: &ComputeRequest,
    ) -> Result<(Vec<Vec<u8>>, Arc<LoweredPlan>), PudError> {
        crate::pud::verify::admit(&req.plan)?;
        for v in &req.operands {
            if v.len() != req.cols {
                return Err(PudError::WidthMismatch { expected: req.cols, got: v.len() });
            }
        }
        if req.calib.cols() != req.cols {
            return Err(PudError::WidthMismatch {
                expected: req.cols,
                got: req.calib.cols(),
            });
        }
        if let Some(mask) = &req.mask {
            if mask.len() != req.cols {
                return Err(PudError::WidthMismatch { expected: req.cols, got: mask.len() });
            }
        }
        if req.rows < 32 {
            // `RowMap::standard` needs the reserved-row layout.
            return Err(PudError::RowBudgetExceeded { needed: 32, available: req.rows });
        }
        let inputs = req.plan.encode_operands(&req.operands)?;
        if inputs.len() != req.plan.circuit.n_inputs {
            return Err(PudError::ArityMismatch {
                expected: req.plan.circuit.n_inputs,
                got: inputs.len(),
            });
        }
        let available = req.rows.saturating_sub(RowMap::standard(req.rows).data_base);
        if available == 0 || req.plan.peak_rows > available {
            return Err(PudError::RowBudgetExceeded {
                needed: req.plan.peak_rows.max(1),
                available,
            });
        }
        let lowered = req.plan.lowered()?;
        Ok((inputs, lowered))
    }

    /// Execute validated, grouped requests as fused dispatches: every
    /// group shares one lowered step program, its (request, replica)
    /// instances are cut into at most `threads` contiguous chunks, and
    /// a single worker-pool dispatch drives every chunk of every group
    /// concurrently. Within a chunk the banks advance **step-major**
    /// (step outer, banks inner) through the shared stream. Per-bank
    /// RNG streams make the interleaving invisible: each subarray sees
    /// exactly the operation sequence the per-request path would
    /// issue, so results are bit-identical to the per-request loop.
    fn execute_fused(
        &self,
        reqs: &[ComputeRequest],
        prepared: &[(Vec<Vec<u8>>, Arc<LoweredPlan>)],
        groups: &[Vec<usize>],
    ) -> Vec<ComputeResult> {
        let mut chunks: Vec<FusedChunk> = Vec::new();
        for members in groups {
            let mut instances = Vec::new();
            for &ri in members {
                let runs = reqs[ri].replicas.max(1);
                for i in 0..runs {
                    let seed = if i == 0 {
                        reqs[ri].seed
                    } else {
                        derive_seed(reqs[ri].seed, &[SPARE_STREAM, i as u64])
                    };
                    instances.push(FusedInstance { req: ri, seed });
                }
            }
            // Contiguous cuts: chunk-major flattening preserves the
            // group's global instance order.
            let n = instances.len();
            let n_chunks = self.threads.max(1).min(n.max(1));
            let mut it = instances.into_iter();
            for k in 0..n_chunks {
                let take = (n * (k + 1)) / n_chunks - (n * k) / n_chunks;
                let part: Vec<FusedInstance> = it.by_ref().take(take).collect();
                if !part.is_empty() {
                    chunks.push(FusedChunk { lowered_of: members[0], instances: part });
                }
            }
        }
        let chunk_results: Vec<Vec<(Vec<u64>, f64, usize, u64)>> =
            worker::parallel_map(chunks, self.threads, |chunk| {
                self.run_chunk(reqs, prepared, &chunk)
            });
        // Stitch instances back into per-request results, replicas
        // combined in replica order (bit-identical f64 summation).
        let mut inst_results = chunk_results.into_iter().flatten();
        let mut results: Vec<Option<ComputeResult>> = (0..reqs.len()).map(|_| None).collect();
        for members in groups {
            for &ri in members {
                let req = &reqs[ri];
                let runs = req.replicas.max(1);
                let mut all = Vec::with_capacity(runs);
                let mut elapsed_ns = 0.0;
                let mut peak_rows = 0usize;
                let mut fault_flips = 0u64;
                for _ in 0..runs {
                    let (outputs, e, p, f) =
                        inst_results.next().expect("one result per instance");
                    elapsed_ns += e;
                    peak_rows = peak_rows.max(p);
                    fault_flips += f;
                    all.push(outputs);
                }
                let outputs = combine_replicas(all, req.cols);
                let mask = req.mask.clone().unwrap_or_else(|| vec![true; req.cols]);
                results[ri] =
                    Some(ComputeResult { outputs, mask, elapsed_ns, peak_rows, fault_flips });
            }
        }
        results.into_iter().map(|r| r.expect("every request executed")).collect()
    }

    /// Walk one chunk of banks through its shared lowered step stream
    /// step-major: materialise and set up every bank, then advance all
    /// of them one [`crate::pud::verify::LoweredStep`] at a time.
    fn run_chunk(
        &self,
        reqs: &[ComputeRequest],
        prepared: &[(Vec<Vec<u8>>, Arc<LoweredPlan>)],
        chunk: &FusedChunk,
    ) -> Vec<(Vec<u64>, f64, usize, u64)> {
        let lowered = &prepared[chunk.lowered_of].1;
        let mut states: Vec<(Subarray, RowMap, FracConfig, StepRunner)> = chunk
            .instances
            .iter()
            .map(|inst| {
                let req = &reqs[inst.req];
                let mut sub = Subarray::with_geometry(&self.cfg, req.rows, req.cols, inst.seed);
                if let Some(env) = req.env {
                    sub.env = env;
                }
                let map = RowMap::standard(req.rows);
                let fc = req.calib.lattice.config;
                setup_subarray(&mut sub, &map, &req.calib);
                (sub, map, fc, StepRunner::new(req.cols))
            })
            .collect();
        for step in &lowered.steps {
            for (inst, (sub, map, fc, runner)) in chunk.instances.iter().zip(states.iter_mut()) {
                let req = &reqs[inst.req];
                runner.apply(sub, map, fc, &req.grade, &prepared[inst.req].0, step);
            }
        }
        chunk
            .instances
            .iter()
            .zip(states)
            .map(|(inst, (sub, _, _, runner))| {
                let req = &reqs[inst.req];
                let run = runner.finish(&sub, lowered.peak_rows());
                let outputs =
                    (0..req.cols).map(|c| req.plan.decode_output(&run.outputs, c)).collect();
                (outputs, run.elapsed_ns, run.peak_rows, sub.fault_flips())
            })
            .collect()
    }
}

/// One (request, replica) execution instance inside a fused group:
/// which request it serves and which seed its bank's variation/fault
/// field is drawn from.
struct FusedInstance {
    req: usize,
    seed: u64,
}

/// A contiguous slice of a fused group's instances, executed by one
/// worker. All instances share the lowering of request `lowered_of`
/// (equal plan fingerprints lower to the same step program).
struct FusedChunk {
    lowered_of: usize,
    instances: Vec<FusedInstance>,
}

/// Resolve each request's declared operand ranges: validate the
/// operands against them ([`ComputeRequest::validate_ranges`]) and,
/// for verified plans whose range class is strictly narrower than the
/// compiled width, substitute the width-narrowed plan variant from the
/// process-wide [`PlanCache`](crate::coordinator::plancache::PlanCache)
/// (bit-identical outputs for in-range operands). Requests without
/// declared ranges — and unverified plans, which must keep reaching
/// the admission layer untouched — pass through unchanged; the
/// borrowed slice is returned as-is when nothing substitutes.
fn narrow_requests(reqs: &[ComputeRequest]) -> Result<Cow<'_, [ComputeRequest]>, PudError> {
    for req in reqs {
        req.validate_ranges()?;
    }
    let wants_narrow = |req: &ComputeRequest| {
        req.declared_ranges.as_ref().is_some_and(|ranges| {
            req.plan.is_verified() && RangeClass::of(ranges).narrows(&req.plan.op)
        })
    };
    if !reqs.iter().any(wants_narrow) {
        return Ok(Cow::Borrowed(reqs));
    }
    let cache = crate::coordinator::plancache::PlanCache::global();
    let mut owned = reqs.to_vec();
    for req in &mut owned {
        if !wants_narrow(req) {
            continue;
        }
        let ranges = req.declared_ranges.as_ref().expect("wants_narrow checked");
        let class = RangeClass::of(ranges);
        let compiled = cache.get_or_narrow(&req.plan, 0, &class, None)?;
        req.plan = compiled.plan.clone();
    }
    Ok(Cow::Owned(owned))
}

/// Combine replica outputs: identity for a single replica, per-column
/// bitwise majority vote across replicas otherwise.
fn combine_replicas(mut all: Vec<Vec<u64>>, cols: usize) -> Vec<u64> {
    let runs = all.len();
    if runs == 1 {
        return all.pop().expect("one replica ran");
    }
    (0..cols)
        .map(|c| {
            let mut v = 0u64;
            for bit in 0..u64::BITS {
                let votes = all.iter().filter(|o| (o[c] >> bit) & 1 != 0).count();
                if votes * 2 > runs {
                    v |= 1u64 << bit;
                }
            }
            v
        })
        .collect()
}

/// The golden-model executor behind the compute trait: one request
/// runs inline; larger batches are **batch-fused**. Requests are
/// grouped by (plan fingerprint, geometry), each group shares one
/// canonical lowering, and a single worker-pool dispatch walks every
/// group's (request, replica) banks through the shared step program
/// step-major. Validation runs up front in request order, so a
/// malformed request fails the batch with the same first error the
/// per-request loop would surface — and results stay bit-identical to
/// that loop (pinned by `rust/tests/fused_exec.rs`).
impl ComputeEngine for NativeEngine {
    fn compute_backend(&self) -> &'static str {
        "native"
    }

    fn execute_batch(&self, reqs: &[ComputeRequest]) -> Result<Vec<ComputeResult>> {
        // Declared-range handling first: operand validation, then the
        // transparent narrowed-variant substitution (`narrow_requests`).
        let reqs = narrow_requests(reqs).map_err(anyhow::Error::from)?;
        let reqs: &[ComputeRequest] = &reqs;
        if reqs.len() <= 1 {
            return reqs
                .iter()
                .map(|r| self.execute_request(r).map_err(anyhow::Error::from))
                .collect();
        }
        let mut prepared = Vec::with_capacity(reqs.len());
        for req in reqs {
            prepared.push(self.prepare_request(req).map_err(anyhow::Error::from)?);
        }
        // Group request indices by (plan fingerprint, geometry): group
        // order follows first appearance, members stay in batch order.
        let mut keys: Vec<(u64, usize, usize)> = Vec::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            let key = (req.plan.fingerprint(), req.rows, req.cols);
            match keys.iter().position(|k| *k == key) {
                Some(g) => groups[g].push(i),
                None => {
                    keys.push(key);
                    groups.push(vec![i]);
                }
            }
        }
        Ok(self.execute_fused(reqs, &prepared, &groups))
    }
}

/// One calibration's arithmetic battery: the per-arity ECR reports a
/// majority circuit's reliability decomposes into. A column serves a
/// circuit only if *every* constituent arity is error-free on it, so
/// workload masks come from [`ArithBattery::arith`], never from a
/// single-arity report.
#[derive(Clone, Debug)]
pub struct ArithBattery {
    /// MAJ5 battery (sum bits, the reliability bottleneck).
    pub maj5: EcrReport,
    /// MAJ3 battery (carries / boolean logic).
    pub maj3: EcrReport,
}

impl ArithBattery {
    /// The arithmetic-usable battery: columns error-free under both
    /// arities (paper Table I's ADD/MUL column population).
    pub fn arith(&self) -> EcrReport {
        self.maj5.intersect(&self.maj3)
    }
}

/// Measure the arithmetic batteries of several calibrations of one
/// subarray in a single batched ECR phase (2 requests per calibration,
/// which the PJRT backend may fuse per arity) — the shared mask
/// derivation behind `pudtune run`, the workload benches and the
/// examples.
pub fn measure_arith_batteries<E: CalibEngine>(
    engine: &E,
    sub: &Subarray,
    seed: u64,
    calibs: &[&Calibration],
    samples: u32,
) -> Result<Vec<ArithBattery>> {
    let mut reqs = Vec::with_capacity(2 * calibs.len());
    for calib in calibs {
        reqs.push(EcrRequest::from_subarray(sub, seed, (*calib).clone(), 5, samples));
        reqs.push(EcrRequest::from_subarray(sub, seed, (*calib).clone(), 3, samples));
    }
    let mut reports = engine.measure_ecr_batch(&reqs)?.into_iter();
    Ok(calibs
        .iter()
        .map(|_| ArithBattery {
            maj5: reports.next().expect("engine returned one report per request"),
            maj3: reports.next().expect("engine returned one report per request"),
        })
        .collect())
}

/// Run a calibration batch with **per-bank fault isolation**: the
/// batched call is attempted first (keeping worker-pool fan-out / PJRT
/// fusion on the fast path); if it errors or panics, every request is
/// retried individually across the worker pool with panics captured,
/// so one bad bank degrades to one `Err` slot instead of failing the
/// whole batch — or aborting the process. This is the execution
/// primitive of the recalibration service
/// ([`crate::coordinator::service`]); the shared pattern lives in
/// [`worker::isolate_batch`].
pub fn calibrate_isolated<E: CalibEngine + Sync>(
    engine: &E,
    reqs: &[CalibRequest],
    threads: usize,
) -> Vec<Result<Calibration, String>> {
    worker::isolate_batch(
        reqs,
        threads,
        |rs| engine.calibrate_batch(rs),
        |r| engine.calibrate_one(r).map_err(|e| format!("{e:#}")),
    )
}

/// [`calibrate_isolated`] for ECR measurement batches.
pub fn measure_ecr_isolated<E: CalibEngine + Sync>(
    engine: &E,
    reqs: &[EcrRequest],
    threads: usize,
) -> Vec<Result<EcrReport, String>> {
    worker::isolate_batch(
        reqs,
        threads,
        |rs| engine.measure_ecr_batch(rs),
        |r| engine.measure_ecr_one(r).map_err(|e| format!("{e:#}")),
    )
}

/// [`calibrate_isolated`] for compute batches: one malformed or
/// panicking workload request degrades to one `Err` slot while the
/// rest of the banks keep serving.
pub fn execute_isolated<E: ComputeEngine + Sync>(
    engine: &E,
    reqs: &[ComputeRequest],
    threads: usize,
) -> Vec<Result<ComputeResult, String>> {
    worker::isolate_batch(
        reqs,
        threads,
        |rs| engine.execute_batch(rs),
        |r| engine.execute_one(r).map_err(|e| format!("{e:#}")),
    )
}

/// Runtime-selected backend: one concrete type service code can hold
/// while staying backend-agnostic.
pub enum AnyEngine {
    Native(NativeEngine),
    Pjrt(PjrtEngine),
}

impl AnyEngine {
    /// The native golden-model engine (always available).
    pub fn native(cfg: DeviceConfig) -> Self {
        AnyEngine::Native(NativeEngine::new(cfg))
    }

    /// The PJRT engine over an opened runtime.
    pub fn pjrt(rt: Arc<Runtime>, cfg: DeviceConfig) -> Self {
        AnyEngine::Pjrt(PjrtEngine::new(rt, cfg))
    }

    /// Open the PJRT runtime, falling back to native with a notice
    /// when the AOT artifacts are unavailable (offline checkouts, the
    /// vendored `xla` stub).
    pub fn auto(cfg: DeviceConfig) -> Self {
        match Runtime::open_default() {
            Ok(rt) => Self::pjrt(Arc::new(rt), cfg),
            Err(e) => {
                eprintln!("note: PJRT artifacts unavailable ({e}); using native engine");
                Self::native(cfg)
            }
        }
    }

    /// Execution metrics (PJRT backend only).
    pub fn metrics(&self) -> Option<&Metrics> {
        match self {
            AnyEngine::Pjrt(e) => Some(e.metrics.as_ref()),
            AnyEngine::Native(_) => None,
        }
    }
}

impl CalibEngine for AnyEngine {
    fn backend(&self) -> &'static str {
        match self {
            AnyEngine::Native(e) => e.backend(),
            AnyEngine::Pjrt(e) => e.backend(),
        }
    }

    fn calibrate_batch(&self, reqs: &[CalibRequest]) -> Result<Vec<Calibration>> {
        match self {
            AnyEngine::Native(e) => e.calibrate_batch(reqs),
            AnyEngine::Pjrt(e) => e.calibrate_batch(reqs),
        }
    }

    fn measure_ecr_batch(&self, reqs: &[EcrRequest]) -> Result<Vec<EcrReport>> {
        match self {
            AnyEngine::Native(e) => e.measure_ecr_batch(reqs),
            AnyEngine::Pjrt(e) => e.measure_ecr_batch(reqs),
        }
    }
}

impl ComputeEngine for AnyEngine {
    fn compute_backend(&self) -> &'static str {
        match self {
            AnyEngine::Native(e) => e.compute_backend(),
            AnyEngine::Pjrt(e) => e.compute_backend(),
        }
    }

    fn execute_batch(&self, reqs: &[ComputeRequest]) -> Result<Vec<ComputeResult>> {
        match self {
            AnyEngine::Native(e) => e.execute_batch(reqs),
            AnyEngine::Pjrt(e) => e.execute_batch(reqs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::lattice::FracConfig;

    fn cfg() -> DeviceConfig {
        DeviceConfig::default()
    }

    #[test]
    fn batch_matches_singles_bit_for_bit() {
        let cfg = cfg();
        let eng = NativeEngine::new(cfg.clone());
        let batch = BankBatch::from_device_seed(cfg, 512, 0xBB, 3);
        let reqs = batch.calib_requests(FracConfig::pudtune([2, 1, 0]), CalibParams::quick());
        let batched = eng.calibrate_batch(&reqs).unwrap();
        for (r, b) in reqs.iter().zip(&batched) {
            assert_eq!(eng.calibrate_one(r).unwrap().levels, b.levels);
        }
        let ereqs = batch.ecr_requests(&batched, 5, 1024);
        let reports = eng.measure_ecr_batch(&ereqs).unwrap();
        for (r, rep) in ereqs.iter().zip(&reports) {
            assert_eq!(eng.measure_ecr_one(r).unwrap().error_counts, rep.error_counts);
        }
    }

    #[test]
    fn trait_path_matches_inherent_subarray_path() {
        use crate::config::system::SystemConfig;
        let cfg = cfg();
        let mut sys = SystemConfig::small();
        sys.cols = 512;
        let sub = Subarray::new(&cfg, &sys, 0x5EED);
        let fc = FracConfig::pudtune([2, 1, 0]);
        let p = CalibParams::quick();
        let mut inherent = NativeEngine::new(cfg.clone());
        let a = inherent.calibrate(&sub, &fc, &p);
        let ra = inherent.measure_ecr(&sub, &a, 5, 1024);

        let eng = NativeEngine::new(cfg);
        let b = eng.calibrate_one(&CalibRequest::from_subarray(&sub, 0x5EED, fc, p)).unwrap();
        let rb = eng
            .measure_ecr_one(&EcrRequest::from_subarray(&sub, 0x5EED, b.clone(), 5, 1024))
            .unwrap();
        assert_eq!(a.levels, b.levels);
        assert_eq!(ra.error_counts, rb.error_counts);
    }

    #[test]
    fn bank_batch_seeds_match_legacy_derivation() {
        let batch = BankBatch::from_device_seed(cfg(), 64, 42, 4);
        for (b, &s) in batch.seeds.iter().enumerate() {
            assert_eq!(s, derive_seed(42, &[0, b as u64, 0]));
        }
        assert_eq!(batch.len(), 4);
        assert!(!batch.is_empty());
        assert_eq!(batch.banks().len(), 4);
    }

    #[test]
    fn ecr_request_default_seed_is_the_inherent_battery() {
        let bank = ColumnBank::new(&cfg(), 64, 1);
        let calib = FracConfig::baseline(3).uncalibrated(&cfg(), 64);
        let req = EcrRequest::new(bank, calib, 5, 256);
        assert_eq!(req.seed, ECR_MASTER_SEED);
        assert_eq!(req.with_seed(7).seed, 7);
    }

    /// Engine that panics whenever a batch touches one poisoned bank —
    /// the fault-injection rig for the isolation helpers.
    struct PanickingEngine {
        inner: NativeEngine,
        poison_seed: u64,
    }

    impl CalibEngine for PanickingEngine {
        fn backend(&self) -> &'static str {
            "panicking"
        }

        fn calibrate_batch(&self, reqs: &[CalibRequest]) -> Result<Vec<Calibration>> {
            for r in reqs {
                assert_ne!(r.bank.seed, self.poison_seed, "injected engine fault");
            }
            self.inner.calibrate_batch(reqs)
        }

        fn measure_ecr_batch(&self, reqs: &[EcrRequest]) -> Result<Vec<EcrReport>> {
            for r in reqs {
                assert_ne!(r.bank.seed, self.poison_seed, "injected engine fault");
            }
            self.inner.measure_ecr_batch(reqs)
        }
    }

    #[test]
    fn isolated_calibration_degrades_exactly_one_bank() {
        let cfg = cfg();
        let batch = BankBatch::from_device_seed(cfg.clone(), 256, 0xFA11, 3);
        let reqs = batch.calib_requests(FracConfig::pudtune([2, 1, 0]), CalibParams::quick());
        let poison_seed = reqs[1].bank.seed;
        let eng = PanickingEngine { inner: NativeEngine::new(cfg.clone()), poison_seed };
        let out = calibrate_isolated(&eng, &reqs, 4);
        assert_eq!(out.len(), 3);
        assert!(out[1].is_err(), "poisoned bank must surface as an error");
        // The healthy banks match the clean engine bit for bit.
        let clean = NativeEngine::new(cfg);
        for i in [0usize, 2] {
            let got = out[i].as_ref().expect("healthy bank");
            assert_eq!(got.levels, clean.calibrate_one(&reqs[i]).unwrap().levels);
        }
    }

    fn quiet_cfg() -> DeviceConfig {
        DeviceConfig {
            sigma_sa: 1e-6,
            tail_weight: 0.0,
            sigma_noise: 1e-6,
            ..DeviceConfig::default()
        }
    }

    fn add_request(cfg: &DeviceConfig, cols: usize, seed: u64) -> ComputeRequest {
        use crate::pud::plan::PudOp;
        let plan = Arc::new(WorkloadPlan::compile(PudOp::Add { width: 4 }).unwrap());
        let fc = FracConfig::pudtune([2, 1, 0]);
        let calib = fc.uncalibrated(cfg, cols);
        let a: Vec<u64> = (0..cols as u64).map(|c| c % 16).collect();
        let b: Vec<u64> = (0..cols as u64).map(|c| (c * 3 + 1) % 16).collect();
        ComputeRequest::new(plan, 96, cols, seed, calib, vec![a, b])
    }

    #[test]
    fn compute_batch_matches_golden_and_singles() {
        let cfg = quiet_cfg();
        let eng = NativeEngine::new(cfg.clone());
        let reqs: Vec<ComputeRequest> =
            (0..3).map(|i| add_request(&cfg, 16, 0xADD + i)).collect();
        let batched = eng.execute_batch(&reqs).unwrap();
        assert_eq!(batched.len(), 3);
        for (req, res) in reqs.iter().zip(&batched) {
            // Quiet device: every column equals the software model.
            assert_eq!(res.outputs, req.golden_outputs().unwrap());
            assert_eq!(res.active_cols(), 16);
            assert!(res.elapsed_ns > 0.0);
            assert_eq!(res.peak_rows, req.plan.peak_rows);
            // Batch shape never changes results.
            assert_eq!(eng.execute_one(req).unwrap().outputs, res.outputs);
        }
    }

    #[test]
    fn compute_mask_restricts_reporting() {
        let cfg = quiet_cfg();
        let eng = NativeEngine::new(cfg.clone());
        let mut mask = vec![true; 16];
        mask[3] = false;
        let req = add_request(&cfg, 16, 7).with_mask(mask);
        let res = eng.execute_one(&req).unwrap();
        assert_eq!(res.active_cols(), 15);
        assert_eq!(res.output(3), None);
        assert_eq!(res.output(4), Some(req.golden_outputs().unwrap()[4]));
        assert_eq!(res.output(99), None);
    }

    #[test]
    fn replicas_are_transparent_on_a_quiet_device() {
        let cfg = quiet_cfg();
        let eng = NativeEngine::new(cfg.clone());
        let req = add_request(&cfg, 16, 0x3E9);
        let single = eng.execute_one(&req).unwrap();
        let voted = eng.execute_one(&req.clone().with_replicas(3)).unwrap();
        assert_eq!(voted.outputs, single.outputs);
        assert_eq!(voted.outputs, req.golden_outputs().unwrap());
        assert_eq!(single.fault_flips, 0);
        assert_eq!(voted.fault_flips, 0);
        // Redundancy is accounted: three runs cost three latencies.
        assert!((voted.elapsed_ns - 3.0 * single.elapsed_ns).abs() < 1e-3);
        assert_eq!(voted.peak_rows, single.peak_rows);
        // replicas = 0 is treated as a single run.
        let zero = eng.execute_one(&req.clone().with_replicas(0)).unwrap();
        assert_eq!(zero.outputs, single.outputs);
    }

    #[test]
    fn majority_vote_outvotes_fault_campaign_corruption() {
        use crate::dram::faults::standard_campaign;
        let cfg = standard_campaign(&DeviceConfig::default());
        let eng = NativeEngine::new(cfg.clone());
        let req = add_request(&cfg, 256, 0xFA57);
        let golden = req.golden_outputs().unwrap();
        let single = eng.execute_one(&req).unwrap();
        assert!(single.fault_flips > 0, "campaign must inject flips");
        let single_ok = single.golden_correct(&golden);
        assert!(single_ok < 256, "campaign must corrupt an unprotected run");
        let voted = eng.execute_one(&req.clone().with_replicas(3)).unwrap();
        // Flips accumulate across replicas (the base replica's flips
        // are a subset), and the vote repairs almost every column —
        // a column only survives corruption when independently drawn
        // fault fields corrupt the same bits in two of three replicas.
        assert!(voted.fault_flips >= single.fault_flips);
        let voted_ok = voted.golden_correct(&golden);
        assert!(voted_ok >= single_ok, "vote must not lose columns: {voted_ok} < {single_ok}");
        assert!(voted_ok >= 248, "vote must repair almost every column; got {voted_ok}");
    }

    #[test]
    fn malformed_compute_request_degrades_one_bank() {
        let cfg = quiet_cfg();
        let eng = NativeEngine::new(cfg.clone());
        let mut reqs: Vec<ComputeRequest> =
            (0..3).map(|i| add_request(&cfg, 16, 0xBAD + i)).collect();
        reqs[1].operands.pop(); // arity violation on one bank only
        let err = eng.execute_batch(&reqs).unwrap_err();
        assert!(err.to_string().contains("arity"), "{err:#}");
        let isolated = execute_isolated(&eng, &reqs, 2);
        assert!(isolated[0].is_ok());
        assert!(isolated[1].as_ref().unwrap_err().contains("arity"));
        assert!(isolated[2].is_ok());
    }

    #[test]
    fn isolated_helpers_use_the_batched_fast_path_when_healthy() {
        let cfg = cfg();
        let eng = NativeEngine::new(cfg.clone());
        let batch = BankBatch::from_device_seed(cfg, 128, 0x150, 2);
        let reqs = batch.calib_requests(FracConfig::pudtune([2, 1, 0]), CalibParams::quick());
        let isolated = calibrate_isolated(&eng, &reqs, 2);
        let batched = eng.calibrate_batch(&reqs).unwrap();
        for (a, b) in isolated.iter().zip(&batched) {
            assert_eq!(a.as_ref().unwrap().levels, b.levels);
        }
        let calibs: Vec<Calibration> = isolated.into_iter().map(|r| r.unwrap()).collect();
        let ereqs = batch.ecr_requests(&calibs, 5, 512);
        let reports = measure_ecr_isolated(&eng, &ereqs, 2);
        let direct = eng.measure_ecr_batch(&ereqs).unwrap();
        for (a, b) in reports.iter().zip(&direct) {
            assert_eq!(a.as_ref().unwrap().error_counts, b.error_counts);
        }
    }
}
