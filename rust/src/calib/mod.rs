//! PUDTune calibration — the paper's contribution.
//!
//! * [`lattice`] — the multi-level offset lattice: Frac-count
//!   configurations `T_{x,y,z}` turn 3 stored bits per column into
//!   2^3 analog offsets (paper §III-C/D, Fig. 3);
//! * [`bias`] — the bias metric of Algorithm 1, with disjoint column
//!   tiles for parallel accumulation;
//! * [`algorithm`] — calibration-data identification (Algorithm 1) and
//!   ECR measurement as a column-tiled, allocation-free batch kernel:
//!   per-(batch, column) RNG streams make results bit-identical across
//!   tile sizes and worker counts, per-environment threshold caching
//!   and uniform-space decision cutoffs keep the inner loop to one
//!   word draw + popcount + compare per sample (module docs there
//!   spell out the stream contract);
//! * [`engine`] — the backend-agnostic [`engine::CalibEngine`] trait:
//!   batch-first request/response types executed by the native kernel,
//!   the PJRT AOT path, or whatever backend comes next — the API the
//!   coordinator, sweeps, CLI and examples are written against;
//! * [`store`] — non-volatile persistence of identified calibration
//!   data (paper §III-A: stored bit patterns are reusable across
//!   reboots), as JSON, with checked decoding and geometry validation;
//! * [`drift`] — the drift policy that decides when a persisted or
//!   serving calibration is no longer trustworthy (temperature
//!   excursion, retention age, rolling served-batch ECR) — the policy
//!   half of the recalibration service in
//!   [`crate::coordinator::service`];
//! * [`sweep`] — Frac-configuration sweeps (Fig. 5), batched through
//!   the engine trait, and the one-off variation-model fit against
//!   Table I's baseline.

pub mod algorithm;
pub mod bias;
pub mod drift;
pub mod engine;
pub mod lattice;
pub mod store;
pub mod sweep;
