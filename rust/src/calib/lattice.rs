//! The multi-level offset lattice (paper §III-C/D, Fig. 3).
//!
//! A MAJX's three non-operand rows hold per-column calibration bits;
//! the configuration `T_{x,y,z}` applies x, y, z Frac operations to the
//! three rows. A stored bit b after f Fracs holds charge
//! `q_f(b) = 0.5 + (b - 0.5) r^f`, so a column's 3 bits select one of
//! 2^3 total charges Q — an analog offset `ΔV = Cc (Q - 1.5) / (8 Cc + Cb)`
//! on the shared bitline. Distinct per-row Frac counts (T_{2,1,0}) give
//! a lattice that is simultaneously fine-grained (small steps from the
//! heavily-Frac'd rows) and wide-range (full swing from the 0-Frac row).
//!
//! The baseline `B_{x,0,0}` is the degenerate case: fixed pattern
//! (Frac^x(1), const 0, const 1) with no per-column freedom.

use crate::config::device::DeviceConfig;

/// How the three non-operand rows are used.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfigKind {
    /// Conventional neutral rows: Frac^x(1), constant 0, constant 1.
    Baseline,
    /// Per-column calibration bits in all three rows (PUDTune).
    PudTune,
}

/// A Frac-count configuration for the three non-operand rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FracConfig {
    pub kind: ConfigKind,
    /// Frac operations applied to rows 0, 1, 2 after each copy-in.
    pub fracs: [u32; 3],
}

impl FracConfig {
    /// The paper's baseline `B_{x,0,0}`.
    pub fn baseline(x: u32) -> Self {
        Self { kind: ConfigKind::Baseline, fracs: [x, 0, 0] }
    }

    /// A PUDTune configuration `T_{x,y,z}`.
    pub fn pudtune(fracs: [u32; 3]) -> Self {
        Self { kind: ConfigKind::PudTune, fracs }
    }

    /// Total Frac operations per MAJX execution (drives latency).
    pub fn total_fracs(&self) -> u32 {
        self.fracs.iter().sum()
    }

    /// Paper-style label ("B_{3,0,0}", "T_{2,1,0}").
    pub fn label(&self) -> String {
        let tag = match self.kind {
            ConfigKind::Baseline => "B",
            ConfigKind::PudTune => "T",
        };
        format!("{}_{{{},{},{}}}", tag, self.fracs[0], self.fracs[1], self.fracs[2])
    }
}

/// One lattice level: a bit-triple and its analog consequences.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatticeLevel {
    pub bits: [u8; 3],
    /// Total calibration charge Q of the three rows, cell-equivalents.
    pub q_total: f64,
    /// Offset relative to the ideal neutral charge (Q - 1.5), expressed
    /// as bitline voltage, V_DD units.
    pub offset_v: f64,
}

/// The sorted offset lattice of a configuration.
#[derive(Clone, Debug)]
pub struct OffsetLattice {
    pub config: FracConfig,
    /// Levels sorted ascending by `q_total`. For `Baseline` all levels
    /// are the single fixed pattern (so level arithmetic is a no-op).
    pub levels: Vec<LatticeLevel>,
}

/// Ideal (perfectly neutral) calibration charge: 1.5 cell-equivalents.
pub const IDEAL_Q: f64 = 1.5;

impl OffsetLattice {
    pub fn build(cfg: &DeviceConfig, fc: &FracConfig) -> Self {
        let rows = cfg.simra_rows;
        let denom = rows as f64 * cfg.cc_ff + cfg.cb_ff;
        let mut levels = Vec::with_capacity(8);
        match fc.kind {
            ConfigKind::Baseline => {
                // Fixed pattern: Frac^x(1), const 0, const 1.
                let q = cfg.frac_charge(1.0, fc.fracs[0]) + 0.0 + 1.0;
                let lv = LatticeLevel {
                    bits: [1, 0, 1],
                    q_total: q,
                    offset_v: cfg.cc_ff * (q - IDEAL_Q) / denom,
                };
                levels = vec![lv; 8];
            }
            ConfigKind::PudTune => {
                for combo in 0..8u8 {
                    let bits = [combo & 1, (combo >> 1) & 1, (combo >> 2) & 1];
                    let q: f64 = (0..3)
                        .map(|i| cfg.frac_charge(bits[i] as f64, fc.fracs[i]))
                        .sum();
                    levels.push(LatticeLevel {
                        bits,
                        q_total: q,
                        offset_v: cfg.cc_ff * (q - IDEAL_Q) / denom,
                    });
                }
                levels.sort_by(|a, b| a.q_total.partial_cmp(&b.q_total).unwrap());
            }
        }
        Self { config: *fc, levels }
    }

    pub fn len(&self) -> usize {
        self.levels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.levels.is_empty()
    }

    /// Index of the level closest to the ideal neutral charge —
    /// the calibration starting point.
    pub fn neutral_level(&self) -> usize {
        let mut best = 0;
        let mut bestd = f64::INFINITY;
        for (i, lv) in self.levels.iter().enumerate() {
            let d = (lv.q_total - IDEAL_Q).abs();
            if d < bestd {
                bestd = d;
                best = i;
            }
        }
        best
    }

    /// The bit-triples in level order, as f32 — the `bits_table` input
    /// of the AOT graphs (`python/compile/model.py`).
    pub fn bits_table_f32(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.levels.len() * 3);
        for lv in &self.levels {
            for i in 0..3 {
                v.push(lv.bits[i] as f32);
            }
        }
        v
    }

    /// Span of the lattice: (min offset, max offset), V_DD units.
    pub fn range(&self) -> (f64, f64) {
        (self.levels[0].offset_v, self.levels[self.levels.len() - 1].offset_v)
    }

    /// Largest gap between adjacent distinct offsets (granularity).
    pub fn max_gap(&self) -> f64 {
        let mut gap: f64 = 0.0;
        for w in self.levels.windows(2) {
            gap = gap.max(w[1].offset_v - w[0].offset_v);
        }
        gap
    }

    /// Distinct offset count (duplicates collapse, e.g. T_{0,0,0}).
    pub fn distinct_levels(&self) -> usize {
        let mut offs: Vec<f64> = self.levels.iter().map(|l| l.offset_v).collect();
        offs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        offs.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        offs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DeviceConfig {
        DeviceConfig::default()
    }

    #[test]
    fn t210_is_fine_and_wide() {
        // Fig. 3c: distinct per-row Frac counts give 8 distinct levels
        // covering a wide range with small gaps.
        let l = OffsetLattice::build(&cfg(), &FracConfig::pudtune([2, 1, 0]));
        assert_eq!(l.distinct_levels(), 8);
        let (lo, hi) = l.range();
        assert!(hi > 0.055 && lo < -0.055, "range ({lo}, {hi})");
        assert!(l.max_gap() < 0.03, "gap {}", l.max_gap());
        // Monotone non-decreasing by construction.
        for w in l.levels.windows(2) {
            assert!(w[1].q_total >= w[0].q_total);
        }
    }

    #[test]
    fn t000_is_coarse() {
        // Fig. 3a: no Fracs -> only 4 distinct levels, coarse steps.
        let l = OffsetLattice::build(&cfg(), &FracConfig::pudtune([0, 0, 0]));
        assert_eq!(l.distinct_levels(), 4);
        let wide = OffsetLattice::build(&cfg(), &FracConfig::pudtune([2, 1, 0]));
        assert!(l.max_gap() > wide.max_gap());
        // Same full range as any config containing a 0-Frac row... wider.
        assert!(l.range().1 > wide.range().1);
    }

    #[test]
    fn t222_is_fine_but_narrow() {
        // Fig. 3b: uniform Fracs -> fine granularity, narrow range.
        let l = OffsetLattice::build(&cfg(), &FracConfig::pudtune([2, 2, 2]));
        let t210 = OffsetLattice::build(&cfg(), &FracConfig::pudtune([2, 1, 0]));
        let t000 = OffsetLattice::build(&cfg(), &FracConfig::pudtune([0, 0, 0]));
        // Narrower range than both (Fig. 3b)...
        assert!(l.range().1 < 0.7 * t210.range().1, "narrow vs T210");
        assert!(l.range().1 < 0.5 * t000.range().1, "narrow vs T000");
        // ...with finer absolute steps than the no-Frac lattice.
        assert!(l.max_gap() < 0.5 * t000.max_gap());
        assert_eq!(l.distinct_levels(), 4); // ±3d, ±1d collapse
    }

    #[test]
    fn baseline_has_single_fixed_level() {
        let l = OffsetLattice::build(&cfg(), &FracConfig::baseline(3));
        assert_eq!(l.distinct_levels(), 1);
        assert_eq!(l.levels[0].bits, [1, 0, 1]);
        // Small positive systematic offset: Frac^3(1) has not fully
        // converged to neutral.
        assert!(l.levels[0].offset_v > 0.0 && l.levels[0].offset_v < 0.01);
        // Deeper Frac'ing converges toward zero offset.
        let l6 = OffsetLattice::build(&cfg(), &FracConfig::baseline(6));
        assert!(l6.levels[0].offset_v < l.levels[0].offset_v);
    }

    #[test]
    fn neutral_level_is_nearest_to_ideal() {
        let l = OffsetLattice::build(&cfg(), &FracConfig::pudtune([2, 1, 0]));
        let n = l.neutral_level();
        for lv in &l.levels {
            assert!((l.levels[n].q_total - IDEAL_Q).abs() <= (lv.q_total - IDEAL_Q).abs() + 1e-12);
        }
    }

    #[test]
    fn offsets_match_margin_scale() {
        // The coarse T_{0,0,0} step (one full bit flip on a 0-Frac row)
        // equals 2x the majority margin: 1 cell-equivalent / divider.
        let c = cfg();
        let l = OffsetLattice::build(&c, &FracConfig::pudtune([0, 0, 0]));
        let m = c.majority_margin();
        let step = l.levels[1].offset_v - l.levels[0].offset_v;
        assert!((step - 2.0 * m).abs() < 1e-9, "step={step} margin={m}");
    }

    #[test]
    fn labels_and_totals() {
        assert_eq!(FracConfig::baseline(3).label(), "B_{3,0,0}");
        assert_eq!(FracConfig::pudtune([2, 1, 0]).label(), "T_{2,1,0}");
        assert_eq!(FracConfig::pudtune([2, 1, 0]).total_fracs(), 3);
        assert_eq!(FracConfig::baseline(3).total_fracs(), 3);
    }

    #[test]
    fn bits_table_matches_levels() {
        let l = OffsetLattice::build(&cfg(), &FracConfig::pudtune([2, 1, 0]));
        let t = l.bits_table_f32();
        assert_eq!(t.len(), 24);
        for (i, lv) in l.levels.iter().enumerate() {
            for j in 0..3 {
                assert_eq!(t[i * 3 + j], lv.bits[j] as f32);
            }
        }
    }
}
