//! Tiny timing harness for `rust/benches/*` (criterion is not in the
//! offline vendor set). Measures wall-clock over repeated runs, reports
//! mean / std / min, prints in a stable machine-grepable format, and
//! (via [`BenchSuite`]) emits machine-readable JSON so the repo's perf
//! trajectory can be tracked across PRs (`BENCH_calib.json`).

use std::time::Instant;

use super::json::Json;
use super::stats::Summary;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    /// Machine-readable form (name/iters/mean/std/min).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_s".to_string(), Json::Num(self.mean_s));
        m.insert("std_s".to_string(), Json::Num(self.std_s));
        m.insert("min_s".to_string(), Json::Num(self.min_s));
        Json::Obj(m)
    }

    pub fn print(&self) {
        println!(
            "bench {:<40} iters={:<3} mean={} std={} min={}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
        );
    }
}

/// Time `f` for `iters` measured iterations after `warmup` unmeasured ones.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        s.push(t.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean(),
        std_s: s.std(),
        min_s: s.min(),
    };
    r.print();
    r
}

/// Auto-calibrating variant: picks an iteration count so the measured
/// phase takes roughly `budget_s` seconds (at least 3 iterations).
pub fn bench_budget<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    let t = Instant::now();
    f(); // warmup + probe
    let probe = t.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / probe) as u32).clamp(3, 1000);
    bench(name, 0, iters, f)
}

/// Collects bench results (plus derived scalars like speedups) and
/// writes them as one JSON document — the machine-readable record the
/// perf acceptance criteria are checked against.
#[derive(Debug, Default)]
pub struct BenchSuite {
    results: Vec<BenchResult>,
    derived: Vec<(String, f64)>,
}

impl BenchSuite {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run and record a fixed-iteration case (see [`bench`]).
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: u32, iters: u32, f: F) -> BenchResult {
        let r = bench(name, warmup, iters, f);
        self.results.push(r.clone());
        r
    }

    /// Run and record an auto-calibrated case (see [`bench_budget`]).
    pub fn bench_budget<F: FnMut()>(&mut self, name: &str, budget_s: f64, f: F) -> BenchResult {
        let r = bench_budget(name, budget_s, f);
        self.results.push(r.clone());
        r
    }

    /// Record an externally produced result.
    pub fn record(&mut self, r: BenchResult) {
        self.results.push(r);
    }

    /// Record a derived scalar (e.g. a before/after speedup).
    pub fn derive(&mut self, name: &str, value: f64) {
        println!("derived {name:<38} {value:.3}");
        self.derived.push((name.to_string(), value));
    }

    pub fn to_json(&self) -> Json {
        let mut root = std::collections::BTreeMap::new();
        root.insert(
            "benches".to_string(),
            Json::Arr(self.results.iter().map(BenchResult::to_json).collect()),
        );
        let mut derived = std::collections::BTreeMap::new();
        for (k, v) in &self.derived {
            derived.insert(k.clone(), Json::Num(*v));
        }
        root.insert("derived".to_string(), Json::Obj(derived));
        Json::Obj(root)
    }

    /// Write the suite as pretty JSON (e.g. `BENCH_calib.json`).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty() + "\n")
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0u64;
        let r = bench("noop", 1, 5, || n += 1);
        assert_eq!(r.iters, 5);
        assert_eq!(n, 6);
        assert!(r.min_s <= r.mean_s + 1e-12);
    }

    #[test]
    fn suite_emits_json() {
        let mut suite = BenchSuite::new();
        suite.bench("case-a", 0, 3, || {
            std::hint::black_box(1 + 1);
        });
        suite.derive("speedup", 4.5);
        let j = suite.to_json();
        let cases = j.get("benches").as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").as_str(), Some("case-a"));
        assert!(cases[0].get("mean_s").as_f64().is_some());
        assert!(cases[0].get("min_s").as_f64().is_some());
        assert_eq!(j.get("derived").get("speedup").as_f64(), Some(4.5));
        // Round-trips through the parser (what a CI checker would do).
        let back = crate::util::json::parse(&j.to_pretty()).unwrap();
        assert_eq!(back.get("derived").get("speedup").as_f64(), Some(4.5));
    }

    #[test]
    fn time_formats() {
        assert_eq!(fmt_time(2.0), "2.000s");
        assert_eq!(fmt_time(2e-3), "2.000ms");
        assert_eq!(fmt_time(2e-6), "2.000us");
        assert_eq!(fmt_time(2e-9), "2.0ns");
    }
}
