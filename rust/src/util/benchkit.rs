//! Tiny timing harness for `rust/benches/*` (criterion is not in the
//! offline vendor set). Measures wall-clock over repeated runs, reports
//! mean / std / min, and prints in a stable machine-grepable format.

use std::time::Instant;

use super::stats::Summary;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<40} iters={:<3} mean={} std={} min={}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
        );
    }
}

/// Time `f` for `iters` measured iterations after `warmup` unmeasured ones.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        s.push(t.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean(),
        std_s: s.std(),
        min_s: s.min(),
    };
    r.print();
    r
}

/// Auto-calibrating variant: picks an iteration count so the measured
/// phase takes roughly `budget_s` seconds (at least 3 iterations).
pub fn bench_budget<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    let t = Instant::now();
    f(); // warmup + probe
    let probe = t.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / probe) as u32).clamp(3, 1000);
    bench(name, 0, iters, f)
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0u64;
        let r = bench("noop", 1, 5, || n += 1);
        assert_eq!(r.iters, 5);
        assert_eq!(n, 6);
        assert!(r.min_s <= r.mean_s + 1e-12);
    }

    #[test]
    fn time_formats() {
        assert_eq!(fmt_time(2.0), "2.000s");
        assert_eq!(fmt_time(2e-3), "2.000ms");
        assert_eq!(fmt_time(2e-6), "2.000us");
        assert_eq!(fmt_time(2e-9), "2.0ns");
    }
}
