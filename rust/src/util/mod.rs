//! Std-only utility layer.
//!
//! The offline vendor set ships only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (`rand`, `serde`, `criterion`,
//! `clap`, `proptest`) are unavailable. This module provides the small,
//! deterministic subset the simulator needs:
//!
//! * [`rng`] — SplitMix64 seeding + xoshiro256++ streams, Box-Muller
//!   normals, mixture sampling (replaces `rand`/`rand_distr`);
//! * [`stats`] — summaries, quantiles, confidence intervals;
//! * [`json`] — a minimal JSON writer/parser for `artifacts/manifest.json`,
//!   calibration stores and experiment reports (replaces `serde_json`);
//! * [`table`] — ASCII table / series renderers for paper-style output;
//! * [`benchkit`] — timing harness used by `rust/benches/*` (replaces
//!   `criterion`);
//! * [`proptest`] — a tiny property-testing harness (shrinkless, seeded).

pub mod benchkit;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
