//! ASCII renderers for paper-style tables and figures.
//!
//! Every bench/experiment prints its result through these so that
//! `cargo bench` output lines up visually with the paper's Table I and
//! Figs. 3/5/6.

/// A simple column-aligned table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let sep: String = w
            .iter()
            .map(|&x| "-".repeat(x + 2))
            .collect::<Vec<_>>()
            .join("+");
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .zip(w)
                .map(|(c, &x)| format!(" {:<width$} ", c, width = x))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &w));
            out.push('\n');
        }
        out
    }
}

/// Horizontal bar chart for figure-style series (one bar per label).
pub fn bar_chart(title: &str, series: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = series.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-30);
    let lw = series.iter().map(|(l, _)| l.chars().count()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (label, v) in series {
        let n = ((v / max) * width as f64).round().max(0.0) as usize;
        out.push_str(&format!(
            "  {:<lw$} |{:<width$}| {:.4} {}\n",
            label,
            "#".repeat(n),
            v,
            unit,
            lw = lw,
            width = width
        ));
    }
    out
}

/// Format a throughput value with engineering units (OPS). The TOPS
/// threshold sits at 0.5e12 so paper-style values like "0.89 TOPS"
/// render in the same unit as the paper.
pub fn fmt_ops(v: f64) -> String {
    if v >= 0.5e12 {
        format!("{:.2} TOPS", v / 1e12)
    } else if v >= 1e9 {
        format!("{:.1} GOPS", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.1} MOPS", v / 1e6)
    } else {
        format!("{:.0} OPS", v)
    }
}

/// Format a ratio like the paper ("1.81x").
pub fn fmt_ratio(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["Method", "ECR"]);
        t.row(&["Baseline".into(), "46.6%".into()]);
        t.row(&["PUDTune".into(), "3.3%".into()]);
        let s = t.render();
        assert!(s.contains("Baseline"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn ops_units() {
        assert_eq!(fmt_ops(0.89e12), "0.89 TOPS");
        assert_eq!(fmt_ops(0.4e12), "400.0 GOPS");
        assert_eq!(fmt_ops(50.2e9), "50.2 GOPS");
        assert_eq!(fmt_ops(5.0e6), "5.0 MOPS");
    }

    #[test]
    fn bars_render() {
        let s = bar_chart("t", &[("a".into(), 1.0), ("b".into(), 0.5)], "u", 10);
        assert!(s.contains("##########"));
        assert!(s.contains("#####"));
    }
}
