//! Minimal JSON reader/writer (std-only `serde_json` replacement).
//!
//! Scope: everything the repo actually serialises — `artifacts/manifest.json`
//! and `artifacts/physics.json` from the Python build step, calibration
//! stores (`calib::store`), and experiment reports. Supports the full JSON
//! grammar except for exotic number forms; numbers are kept as `f64`
//! (plus an integer fast path on write).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object keys are kept sorted (BTreeMap) for stable round-trips.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as usize) } else { None })
    }

    /// Checked integral decode: `Some` only when the number is finite,
    /// non-negative, **exactly** integral and within the f64-exact
    /// integer range — unlike [`Self::as_usize`], which truncates
    /// fractional values. Use for persisted identifiers and counts
    /// where silent truncation would corrupt data.
    pub fn as_exact_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if x.is_finite() && *x >= 0.0 && x.fract() == 0.0 && *x < 9e15 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// [`Self::as_exact_u64`] narrowed to `usize`.
    pub fn as_exact_usize(&self) -> Option<usize> {
        self.as_exact_u64().and_then(|x| usize::try_from(x).ok())
    }

    /// [`Self::as_exact_u64`] narrowed to `u32`; `None` on overflow.
    pub fn as_exact_u32(&self) -> Option<u32> {
        self.as_exact_u64().and_then(|x| u32::try_from(x).ok())
    }

    /// [`Self::as_exact_u64`] narrowed to `u8`; `None` on overflow.
    pub fn as_exact_u8(&self) -> Option<u8> {
        self.as_exact_u64().and_then(|x| u8::try_from(x).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_u8_slice(xs: &[u8]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Serialise to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialise with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    e.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let start = self.i;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "hi\n", "d": true}, "e": null}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").idx(1).as_f64(), Some(2.5));
        assert_eq!(v.get("b").get("c").as_str(), Some("hi\n"));
        assert_eq!(v.get("b").get("d").as_bool(), Some(true));
        assert_eq!(v.get("e"), &Json::Null);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = parse(r#"{"x":[{"y":[]},{}],"z":1e-3}"#).unwrap();
        let v2 = parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v2);
        assert!((v.get("z").as_f64().unwrap() - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{}extra").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn integers_stay_integral() {
        let v = Json::Num(65536.0);
        assert_eq!(v.to_string(), "65536");
    }

    #[test]
    fn exact_decoders_reject_non_integral_and_out_of_range() {
        assert_eq!(Json::Num(7.0).as_exact_u64(), Some(7));
        assert_eq!(Json::Num(7.0).as_exact_u32(), Some(7));
        assert_eq!(Json::Num(255.0).as_exact_u8(), Some(255));
        assert_eq!(Json::Num(0.0).as_exact_usize(), Some(0));
        // Non-integral, negative and non-finite values are rejected
        // (as_usize would silently truncate the first two).
        assert_eq!(Json::Num(7.5).as_exact_u64(), None);
        assert_eq!(Json::Num(-1.0).as_exact_u64(), None);
        assert_eq!(Json::Num(f64::NAN).as_exact_u64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_exact_u64(), None);
        assert_eq!(Json::Num(1e16).as_exact_u64(), None);
        // Range narrowing.
        assert_eq!(Json::Num(256.0).as_exact_u8(), None);
        assert_eq!(Json::Num(4.3e9).as_exact_u32(), None);
        // Non-numbers.
        assert_eq!(Json::Str("7".into()).as_exact_u64(), None);
        assert_eq!(Json::Null.as_exact_u8(), None);
    }
}
