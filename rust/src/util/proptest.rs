//! Minimal property-testing harness (the `proptest` crate is not in the
//! offline vendor set).
//!
//! Runs a property over `cases` generated inputs from a seeded [`Rng`];
//! on failure it reports the case index and seed so the exact input can
//! be replayed deterministically (no shrinking — inputs are printed via
//! the generator's Debug output instead).

use super::rng::Rng;

/// Number of cases per property (kept modest: the whole suite runs on
/// one core).
pub const DEFAULT_CASES: u32 = 64;

/// Run `prop` over `cases` inputs drawn by `gen`. Panics with the seed
/// and case index on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: u32,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  input = {input:?}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` for a
/// richer failure message.
pub fn check_res<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: u32,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\n  input = {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 1, 32, |r| (r.range(-100, 100), r.range(-100, 100)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics() {
        check("always-false", 1, 4, |r| r.next_u32(), |_| false);
    }
}
