//! Summary statistics for measurements and reports.

/// Running mean/variance (Welford) plus extrema.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }

    /// 95% normal-approximation confidence half-width of the mean.
    pub fn ci95(&self) -> f64 {
        1.96 * self.sem()
    }
}

/// Quantile of a sample (linear interpolation); `q` in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Standard normal CDF (Abramowitz-Stegun 7.1.26 via erf approximation).
/// Used by the closed-form model-fit pre-pass (calib::sweep).
pub fn phi(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Error function, |err| < 1.5e-7.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let s = Summary::from_slice(&xs);
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn quantiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn phi_reference_values() {
        assert!((phi(0.0) - 0.5).abs() < 1e-7);
        assert!((phi(1.96) - 0.975).abs() < 1e-3);
        assert!((phi(-1.0) - 0.158655).abs() < 1e-4);
    }
}
