//! Deterministic pseudo-random number generation.
//!
//! Every stochastic quantity in the simulator — process-variation fields,
//! per-operation noise, random test patterns — is drawn from seeded,
//! splittable streams so that experiments are exactly reproducible and
//! the Rust native simulator can be cross-validated against fixed
//! vectors. xoshiro256++ for the stream, SplitMix64 for seeding
//! (standard constructions; see Blackman & Vigna).
//!
//! ## Stream splitting
//!
//! Parallel code must never share one sequential stream: the draw order
//! would then depend on scheduling, and results on thread count. The
//! contract used throughout the crate is *address-based splitting*: a
//! work item identified by a path of indices (iteration, column, ...)
//! draws from [`stream`]`(seed, path)` — a stream that depends only on
//! the logical address, never on execution order. The batch sampling
//! kernel (`calib::algorithm`) derives one stream per (batch, column),
//! which is what makes calibration output bit-identical across tile
//! sizes and worker counts.

/// SplitMix64: used to expand a single `u64` seed into stream state and
/// to derive hierarchical sub-seeds (device -> bank -> subarray -> ...).
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self(seed)
    }

    #[inline]
    pub fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Derive a child seed from a parent seed and a path of indices.
/// Used to give every (channel, bank, subarray, column) its own
/// independent, order-insensitive stream.
pub fn derive_seed(parent: u64, path: &[u64]) -> u64 {
    let mut s = SplitMix64::new(parent ^ 0xA076_1D64_78BD_642F);
    let mut acc = s.next();
    for &p in path {
        let mut m = SplitMix64::new(acc ^ p.wrapping_mul(0xE703_7ED1_A0B4_28DB));
        acc = m.next();
    }
    acc
}

/// The canonical splittable sub-stream for a logical work address:
/// `stream(seed, &[domain, iteration, column])` is an independent,
/// order-insensitive stream per address (see module docs). Cheap enough
/// to create per column per batch (~7 SplitMix64 rounds).
#[inline]
pub fn stream(seed: u64, path: &[u64]) -> Rng {
    Rng::new(derive_seed(seed, path))
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller normal.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next(), sm.next(), sm.next(), sm.next()], spare: None }
    }

    /// Order-sensitive digest of the full generator state (stream
    /// position *and* the cached Box-Muller spare). Two generators with
    /// equal fingerprints produce identical future draws — the storage
    /// parity suite uses this to prove the hybrid and dense golden
    /// models consume their noise streams in lockstep.
    pub fn fingerprint(&self) -> u64 {
        let spare = match self.spare {
            Some(z) => z.to_bits(),
            // Any constant that a stored f64 bit pattern cannot alias
            // in practice would do; what matters is Some(z) != None.
            None => 0x5EED_0000_0000_0001,
        };
        derive_seed(
            self.s[0]
                ^ self.s[1].rotate_left(13)
                ^ self.s[2].rotate_left(29)
                ^ self.s[3].rotate_left(43),
            &[spare],
        )
    }

    /// Child RNG for a sub-component: an independent stream derived from
    /// the current state and an index path, without advancing `self`.
    pub fn child(&self, path: &[u64]) -> Rng {
        let fingerprint = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47);
        Rng::new(derive_seed(fingerprint, path))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection method.
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// A random bit (p = 1/2), branch-free.
    #[inline]
    pub fn bit(&mut self) -> u8 {
        (self.next_u64() >> 63) as u8
    }

    /// Standard normal via Acklam's inverse-CDF approximation on a
    /// 53-bit uniform (|relative error| < 1.2e-9): ~2.5x faster than
    /// Box-Muller on the sampling hot path (no sin/cos/ln per draw)
    /// while preserving tail behaviour well past 5 sigma — which the
    /// error-free-column measurement depends on (EXPERIMENTS.md §Perf).
    pub fn normal(&mut self) -> f64 {
        // Uniform in (0, 1), never exactly 0 or 1.
        let u = ((self.next_u64() >> 11) as f64 + 0.5) * (1.0 / (1u64 << 53) as f64);
        inverse_normal_cdf(u)
    }

    /// Box-Muller normal (the pre-optimisation reference; kept for the
    /// distribution-agreement test).
    pub fn normal_box_muller(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * v).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// Normal with the given mean / std-dev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Two-component Gaussian scale mixture: with probability
    /// `tail_weight` the draw uses `sd * tail_ratio`. Models the
    /// heavy-tailed sense-amplifier offset distribution (DESIGN.md §3).
    pub fn mixture_normal(&mut self, sd: f64, tail_weight: f64, tail_ratio: f64) -> f64 {
        let scale = if self.bool(tail_weight) { sd * tail_ratio } else { sd };
        self.normal() * scale
    }

    /// Fill a slice with standard normals scaled by `sd`.
    pub fn fill_normal(&mut self, out: &mut [f32], sd: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * sd;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Acklam's rational approximation of the inverse standard-normal CDF.
/// |relative error| < 1.15e-9 over the full open interval.
pub fn inverse_normal_cdf(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_seed_is_path_sensitive() {
        let s = derive_seed(7, &[1, 2, 3]);
        assert_ne!(s, derive_seed(7, &[1, 2, 4]));
        assert_ne!(s, derive_seed(7, &[1, 3, 2]));
        assert_ne!(s, derive_seed(8, &[1, 2, 3]));
        assert_eq!(s, derive_seed(7, &[1, 2, 3]));
    }

    #[test]
    fn streams_are_independent_and_reproducible() {
        // Same address -> same stream; any address change -> a
        // different stream (the per-(batch, column) splitting contract).
        let mut a = stream(9, &[1, 2, 3]);
        let mut b = stream(9, &[1, 2, 3]);
        let mut c = stream(9, &[1, 3, 2]);
        let mut d = stream(8, &[1, 2, 3]);
        let mut collide = 0;
        for _ in 0..64 {
            let x = a.next_u64();
            assert_eq!(x, b.next_u64());
            collide += (x == c.next_u64()) as u32 + (x == d.next_u64()) as u32;
        }
        assert_eq!(collide, 0);
    }

    #[test]
    fn fingerprint_tracks_stream_position() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        assert_eq!(a.fingerprint(), b.fingerprint());
        a.next_u64();
        assert_ne!(a.fingerprint(), b.fingerprint());
        b.next_u64();
        assert_eq!(a.fingerprint(), b.fingerprint());
        // The Box-Muller spare is part of the observable state.
        a.normal_box_muller();
        b.normal_box_muller();
        assert_eq!(a.fingerprint(), b.fingerprint());
        a.normal_box_muller(); // consumes a's spare only
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.below(17);
            assert!(n < 17);
            let i = r.range(-5, 5);
            assert!((-5..5).contains(&i));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sum2 += z * z;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn mixture_has_heavier_tails() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let thresh = 3.0 * 0.04;
        let mut plain = 0;
        let mut mixed = 0;
        for _ in 0..n {
            if r.normal_ms(0.0, 0.04).abs() > thresh {
                plain += 1;
            }
            if r.mixture_normal(0.04, 0.15, 2.5).abs() > thresh {
                mixed += 1;
            }
        }
        assert!(mixed > plain * 5, "plain={plain} mixed={mixed}");
    }

    #[test]
    fn inverse_cdf_matches_reference_points() {
        // Known quantiles of the standard normal.
        for (p, z) in [
            (0.5, 0.0),
            (0.975, 1.959964),
            (0.841344746, 1.0),
            (0.0013498980, -3.0),
            (1.0 - 2.866515719e-7, 5.0),
        ] {
            let got = inverse_normal_cdf(p);
            assert!((got - z).abs() < 2e-4, "p={p}: got {got}, want {z}");
        }
    }

    #[test]
    fn fast_normal_matches_box_muller_distribution() {
        // Moments and tail frequencies of the inverse-CDF sampler must
        // match the Box-Muller reference (the pre-optimisation
        // implementation) closely — the ECR measurement depends on
        // accurate >3-sigma behaviour.
        let n = 400_000;
        let mut fast = Rng::new(77);
        let mut refr = Rng::new(78);
        let (mut t_fast, mut t_ref) = (0u32, 0u32);
        let (mut s_fast, mut s_ref) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let a = fast.normal();
            let b = refr.normal_box_muller();
            s_fast += a * a;
            s_ref += b * b;
            t_fast += (a.abs() > 3.0) as u32;
            t_ref += (b.abs() > 3.0) as u32;
        }
        let var_ratio = s_fast / s_ref;
        assert!((var_ratio - 1.0).abs() < 0.02, "var ratio {var_ratio}");
        // P(|z|>3) = 0.27%; expect ~1080 events each, agree within 20%.
        assert!(t_fast > 800 && t_fast < 1400, "tail fast {t_fast}");
        let ratio = t_fast as f64 / t_ref.max(1) as f64;
        assert!((0.8..1.25).contains(&ratio), "tail ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
