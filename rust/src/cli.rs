//! Minimal CLI argument parser (std-only `clap` replacement).
//!
//! Grammar: `pudtune <subcommand> [--flag] [--key value|--key=value] ...`

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Parse raw arguments (without argv[0]). Flags listed in
/// `boolean_flags` consume no value.
pub fn parse(raw: &[String], boolean_flags: &[&str]) -> Result<Args, String> {
    let mut a = Args::default();
    let mut i = 0;
    while i < raw.len() {
        let tok = &raw[i];
        if let Some(name) = tok.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                a.options.insert(k.to_string(), v.to_string());
            } else if boolean_flags.contains(&name) {
                a.flags.push(name.to_string());
            } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                a.options.insert(name.to_string(), raw[i + 1].clone());
                i += 1;
            } else {
                return Err(format!("option --{name} expects a value"));
            }
        } else if a.subcommand.is_none() {
            a.subcommand = Some(tok.clone());
        } else {
            a.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(a)
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number '{v}'")),
        }
    }

    /// Optional float: `Ok(None)` when absent, parse error when
    /// malformed — for options with no meaningful default (e.g.
    /// threshold overrides layered on a policy struct).
    pub fn f64_opt(&self, name: &str) -> Result<Option<f64>, String> {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) => {
                v.parse().map(Some).map_err(|_| format!("--{name}: bad number '{v}'"))
            }
        }
    }

    /// Comma-separated list option (`--op add8,mul8`); empty when the
    /// option is absent.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.options
            .get(name)
            .map(|v| {
                v.split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Parse a `--fracs x,y,z` style triple.
    pub fn fracs(&self, name: &str, default: [u32; 3]) -> Result<[u32; 3], String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => {
                let parts: Vec<&str> = v.split(',').collect();
                if parts.len() != 3 {
                    return Err(format!("--{name}: expected x,y,z"));
                }
                let mut out = [0u32; 3];
                for (i, p) in parts.iter().enumerate() {
                    out[i] = p.trim().parse().map_err(|_| format!("--{name}: bad '{p}'"))?;
                }
                Ok(out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse(&v(&["table1", "--banks", "8", "--cols=1024", "--native"]), &["native"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.usize("banks", 0).unwrap(), 8);
        assert_eq!(a.usize("cols", 0).unwrap(), 1024);
        assert!(a.flag("native"));
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn fracs_triple() {
        let a = parse(&v(&["fig5", "--fracs", "2,1,0"]), &[]).unwrap();
        assert_eq!(a.fracs("fracs", [0, 0, 0]).unwrap(), [2, 1, 0]);
        assert_eq!(a.fracs("other", [3, 3, 3]).unwrap(), [3, 3, 3]);
    }

    #[test]
    fn optional_floats() {
        let a = parse(&v(&["serve", "--drift-temp", "12.5"]), &[]).unwrap();
        assert_eq!(a.f64_opt("drift-temp").unwrap(), Some(12.5));
        assert_eq!(a.f64_opt("drift-age").unwrap(), None);
        let bad = parse(&v(&["serve", "--drift-temp", "warm"]), &[]).unwrap();
        assert!(bad.f64_opt("drift-temp").is_err());
    }

    #[test]
    fn list_option() {
        let a = parse(&v(&["run", "--op", "add8, mul8,"]), &[]).unwrap();
        assert_eq!(a.list("op"), vec!["add8".to_string(), "mul8".to_string()]);
        assert!(a.list("missing").is_empty());
    }

    #[test]
    fn boolean_flags_still_take_values_in_equals_form() {
        // `lint --ranges` is a boolean, but `analyze --ranges=0:15,0:15`
        // must still parse as an option: the `=` form always wins.
        let a = parse(&v(&["lint", "--ranges"]), &["ranges"]).unwrap();
        assert!(a.flag("ranges"));
        assert_eq!(a.str("ranges"), None);
        let b = parse(&v(&["analyze", "--ranges=0:15,0:15"]), &["ranges"]).unwrap();
        assert!(!b.flag("ranges"));
        assert_eq!(b.str("ranges"), Some("0:15,0:15"));
        assert_eq!(b.list("ranges"), vec!["0:15".to_string(), "0:15".to_string()]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&v(&["x", "--key"]), &[]).is_err());
        let a = parse(&v(&["x", "--num", "abc"]), &[]).unwrap();
        assert!(a.usize("num", 0).is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse(&v(&["trace", "maj5", "--fracs=1,1,1"]), &[]).unwrap();
        assert_eq!(a.positional, vec!["maj5"]);
    }
}
