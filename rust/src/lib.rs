//! # PUDTune — Processing-Using-DRAM calibration, reproduced end to end
//!
//! A full-system reproduction of *PUDTune: Multi-Level Charging for
//! High-Precision Calibration in Processing-Using-DRAM* (Kubo et al.,
//! 2025) on a simulated DDR4 substrate, structured as a three-layer
//! Rust + JAX + Pallas stack (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the coordinator: an analog charge-sharing DRAM
//!   simulator (`dram`), a command-level DDR4 controller model
//!   (`controller`), the PUD operation library (`pud`), the PUDTune
//!   calibration engine (`calib`), throughput/ECR analytics (`analysis`),
//!   a PJRT runtime that executes AOT-compiled JAX artifacts (`runtime`)
//!   and a bank-parallel experiment coordinator (`coordinator`).
//! * **L2/L1 (build time)** — `python/compile/`: JAX sampling graphs
//!   calling Pallas kernels, lowered once to `artifacts/*.hlo.txt`.
//!   Python never runs on the request path.
//!
//! ## Storage
//!
//! The golden model (`dram::subarray`) stores rows in a **hybrid
//! bit-packed / analog representation**: full-swing rows are packed 64
//! columns per `u64` word (RowCopy between them is a word-wise copy,
//! SiMRA over an all-packed group counts charge with bit-sliced
//! word-parallel popcounts), and only `Frac`'d rows carry per-cell
//! `f32` levels — a subarray at rest is ~20-30x smaller than one `f32`
//! per cell. The representation is observably invisible: the dense
//! reference implementation is kept as `dram::dense::DenseSubarray`
//! (compiled under the default-on `reference-model` feature), and
//! `rust/tests/storage_parity.rs` proves bit-identical read-outs,
//! operation counts and noise-stream positions across both. Strip the
//! reference model from production builds with `--no-default-features`.
//!
//! ## Parallelism & determinism
//!
//! The native sampling hot path is a **column-tiled batch kernel**
//! (`calib::algorithm`): every (batch, column) draws from its own
//! stream derived with `util::rng::derive_seed`, batches fan out in
//! column tiles over the scoped worker pool (`coordinator::worker`),
//! and sweeps/banks/temperature points parallelise at a coarser grain
//! on the same pool. Because streams are address-derived, **every
//! result is bit-identical for any tile size and worker count** — the
//! determinism suite (`rust/tests/determinism.rs`) pins this contract.
//!
//! ## Quickstart
//!
//! All calibration work goes through the backend-agnostic
//! [`calib::engine::CalibEngine`] trait: describe banks as requests,
//! submit them in batches, and let the engine decide how to execute —
//! the native kernel fans a batch across the worker pool; the PJRT
//! backend stacks the banks' thresholds into one executable call.
//!
//! ```no_run
//! use pudtune::prelude::*;
//!
//! // Pick a backend at runtime: PJRT when AOT artifacts are present,
//! // the native column-tiled kernel otherwise. Everything below is
//! // written against the `CalibEngine` trait, so either works.
//! let cfg = DeviceConfig::default();
//! let engine = AnyEngine::auto(cfg.clone());
//!
//! // Four 1024-column banks with seeded process variation, calibrated
//! // for T_{2,1,0} in one batched call (Algorithm 1 per bank).
//! let banks = BankBatch::from_device_seed(cfg.clone(), 1024, 7 /* seed */, 4);
//! let tune = FracConfig::pudtune([2, 1, 0]);
//! let calibs = engine
//!     .calibrate_batch(&banks.calib_requests(tune, CalibParams::paper()))
//!     .unwrap();
//!
//! // Measure the calibrated MAJ5 error-prone column ratio, again one
//! // batched call (paper §IV-A: 8,192 random patterns per bank).
//! let reports = engine
//!     .measure_ecr_batch(&banks.ecr_requests(&calibs, 5, 8192))
//!     .unwrap();
//! let base = FracConfig::baseline(3).uncalibrated(&cfg, 1024);
//! for (bank, tuned) in banks.banks().into_iter().zip(&reports) {
//!     let req = EcrRequest::new(bank, base.clone(), 5, 8192);
//!     let baseline = engine.measure_ecr_one(&req).unwrap();
//!     assert!(tuned.ecr() < baseline.ecr());
//! }
//!
//! // Whole-device orchestration (Table I's pipeline) is one call on
//! // the engine-generic coordinator:
//! let sys = SystemConfig::small();
//! let coord = DeviceCoordinator::new(cfg.clone(), sys, engine);
//! let outcomes = coord
//!     .run_banks(7, 4, &FracConfig::baseline(3), &tune, &CalibParams::paper(), 8192)
//!     .unwrap();
//! println!("{}", BankSummary::from_outcomes(&outcomes));
//! ```
//!
//! Arithmetic workloads flow through the same batch-first shape:
//! compile a [`pud::plan::PudOp`] into a [`pud::plan::WorkloadPlan`]
//! once, then submit [`calib::engine::ComputeRequest`]s to any
//! [`calib::engine::ComputeEngine`] (or serve them with drift-aware
//! recalibration through `RecalibService::serve_workload`).
//!
//! Every plan is **statically verified** before it touches a subarray:
//! [`pud::verify`] lowers it to the abstract command stream the
//! executor would issue and checks a four-state charge machine
//! (Uninitialized → Packed ⇄ Fracd-analog → Dead) plus independent
//! liveness and shape analyses, reporting violations as stable
//! `P001`–`P012` diagnostics (catalogued in the [`pud`] module docs).
//! `WorkloadPlan::compile` self-checks its output, the engines and
//! `RecalibService` reject unverified custom plans at admission, and
//! `pudtune lint` sweeps the whole built-in op vocabulary — plus
//! user-supplied circuit files — exiting nonzero on any error-severity
//! diagnostic (`--deny-warnings` promotes the advisory ones).
//!
//! On top of verification sits a **bit-level range analysis**
//! ([`pud::ranges`]): declared per-operand value ranges flow through
//! the MAJ/NOT dataflow as a ternary bit lattice plus a value
//! interval, proving output bits constant and gates unobservable —
//! and [`pud::plan::WorkloadPlan::narrowed`] rewrites the plan to the
//! minimal safe width. The serving layer picks narrowed variants
//! transparently: `ComputeRequest::with_ranges` and
//! `RecalibService::serve_workload` resolve them through the
//! process-wide plan cache keyed by (op, geometry, range class).
//! `pudtune analyze` runs the analysis over the vocabulary and
//! cross-checks every claim against the executable circuit.
//!
//! The `pudtune` binary exposes every experiment in the paper
//! (`pudtune table1`, `pudtune fig5`, `pudtune run --op add8`,
//! `pudtune lint`, `pudtune analyze`, ...); `rust/benches/`
//! regenerates each table and figure.

pub mod analysis;
pub mod calib;
pub mod cli;
pub mod config;
pub mod controller;
pub mod coordinator;
pub mod dram;
pub mod experiments;
pub mod pud;
pub mod runtime;
pub mod util;

/// Convenience re-exports for the common experiment workflow, so
/// service-style callers need no deep module paths: the engine trait
/// and its request types, both backends, the coordinator, the
/// non-volatile calibration store and the drift-aware recalibration
/// service built on top of it.
pub mod prelude {
    pub use crate::analysis::ecr::EcrReport;
    pub use crate::analysis::throughput::{ThroughputModel, ThroughputReport};
    pub use crate::calib::algorithm::{CalibParams, Calibration, NativeEngine};
    pub use crate::calib::drift::{DriftMonitor, DriftPolicy, DriftSignal};
    pub use crate::calib::engine::{
        AnyEngine, BankBatch, CalibEngine, CalibRequest, ComputeEngine, ComputeRequest,
        ComputeResult, EcrRequest,
    };
    pub use crate::calib::lattice::{FracConfig, OffsetLattice};
    pub use crate::calib::store::CalibStore;
    pub use crate::config::device::DeviceConfig;
    pub use crate::config::system::SystemConfig;
    pub use crate::coordinator::engine::{
        BankOutcome, BankSummary, ColumnBank, DeviceCoordinator, PjrtEngine,
    };
    pub use crate::coordinator::service::{
        EntryState, LoadOutcome, Quarantine, QuarantineDelta, RecalibService, ScrubOutcome,
        ServeOutcome, ServiceConfig, ServiceServer, WorkloadOutcome,
    };
    pub use crate::dram::device::Device;
    pub use crate::dram::faults::{standard_campaign, FaultField};
    pub use crate::dram::geometry::SubarrayId;
    pub use crate::dram::subarray::{OpCounts, RowStorage, Subarray};
    pub use crate::pud::majx::MajX;
    pub use crate::pud::plan::{BitwiseOp, PudError, PudOp, WorkloadPlan};
    pub use crate::pud::ranges::{analyze_plan, OperandRange, RangeClass, RangeReport};
    pub use crate::pud::verify::{
        verify_circuit, verify_plan, DiagCode, Diagnostic, VerifyReport,
    };
    pub use crate::util::rng::Rng;
}
