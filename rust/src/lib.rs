//! # PUDTune — Processing-Using-DRAM calibration, reproduced end to end
//!
//! A full-system reproduction of *PUDTune: Multi-Level Charging for
//! High-Precision Calibration in Processing-Using-DRAM* (Kubo et al.,
//! 2025) on a simulated DDR4 substrate, structured as a three-layer
//! Rust + JAX + Pallas stack (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the coordinator: an analog charge-sharing DRAM
//!   simulator (`dram`), a command-level DDR4 controller model
//!   (`controller`), the PUD operation library (`pud`), the PUDTune
//!   calibration engine (`calib`), throughput/ECR analytics (`analysis`),
//!   a PJRT runtime that executes AOT-compiled JAX artifacts (`runtime`)
//!   and a bank-parallel experiment coordinator (`coordinator`).
//! * **L2/L1 (build time)** — `python/compile/`: JAX sampling graphs
//!   calling Pallas kernels, lowered once to `artifacts/*.hlo.txt`.
//!   Python never runs on the request path.
//!
//! ## Parallelism & determinism
//!
//! The native sampling hot path is a **column-tiled batch kernel**
//! (`calib::algorithm`): every (batch, column) draws from its own
//! stream derived with `util::rng::derive_seed`, batches fan out in
//! column tiles over the scoped worker pool (`coordinator::worker`),
//! and sweeps/banks/temperature points parallelise at a coarser grain
//! on the same pool. Because streams are address-derived, **every
//! result is bit-identical for any tile size and worker count** — the
//! determinism suite (`rust/tests/determinism.rs`) pins this contract.
//!
//! ## Quickstart
//!
//! ```no_run
//! use pudtune::prelude::*;
//!
//! // A 1024-column subarray with seeded process variation.
//! let cfg = DeviceConfig::default();
//! let sys = SystemConfig::small();
//! let sub = Subarray::new(&cfg, &sys, 7 /* seed */);
//!
//! // Baseline B_{3,0,0} vs calibrated T_{2,1,0} error-prone ratio.
//! let base = FracConfig::baseline(3);
//! let tune = FracConfig::pudtune([2, 1, 0]);
//! let mut engine = NativeEngine::new(cfg.clone());
//! let calib = engine.calibrate(&sub, &tune, &CalibParams::paper());
//! let base_cal = base.uncalibrated(&cfg, sub.cols);
//! let ecr_base = engine.measure_ecr(&sub, &base_cal, 5, 8192);
//! let ecr_tune = engine.measure_ecr(&sub, &calib, 5, 8192);
//! assert!(ecr_tune.ecr() < ecr_base.ecr());
//! ```
//!
//! The `pudtune` binary exposes every experiment in the paper
//! (`pudtune table1`, `pudtune fig5`, ...); `rust/benches/` regenerates
//! each table and figure.

pub mod analysis;
pub mod calib;
pub mod cli;
pub mod config;
pub mod controller;
pub mod coordinator;
pub mod dram;
pub mod experiments;
pub mod pud;
pub mod runtime;
pub mod util;

/// Convenience re-exports for the common experiment workflow.
pub mod prelude {
    pub use crate::analysis::ecr::EcrReport;
    pub use crate::analysis::throughput::{ThroughputModel, ThroughputReport};
    pub use crate::calib::algorithm::{CalibParams, Calibration, NativeEngine};
    pub use crate::calib::lattice::{FracConfig, OffsetLattice};
    pub use crate::config::device::DeviceConfig;
    pub use crate::config::system::SystemConfig;
    pub use crate::dram::subarray::Subarray;
    pub use crate::dram::device::Device;
    pub use crate::pud::majx::MajX;
    pub use crate::util::rng::Rng;
}
