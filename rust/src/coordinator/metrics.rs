//! Lightweight counters and phase timers for the coordinator.
//!
//! ## Metric names
//!
//! PJRT engine (`coordinator::engine`):
//!
//! * `pjrt.step.calls` / `pjrt.ecr.calls` — executable invocations;
//! * `pjrt.step.banks_fused` / `pjrt.ecr.banks_fused` — banks served
//!   by fused multi-bank calls;
//! * `pjrt.batch.unfused` — fusable batches that fell back to per-bank
//!   calls because no artifact matched the stacked width;
//! * `pjrt.compute.fallback` — **lowered steps** in served compute
//!   requests whose step class has no fused lowering
//!   (`coordinator::engine::unfusable_steps`) and would fall back to
//!   bank-serial execution — zero for the whole built-in `PudOp`
//!   vocabulary (pinned by the CI bench smoke);
//! * `pjrt.step` / `pjrt.ecr` / `pjrt.compute` (timers) — seconds
//!   inside the runtime (or its native fallback).
//!
//! Compiled-plan cache (`coordinator::plancache`, reported by
//! `RecalibService::serve_workload` and the CLI):
//!
//! * `plan.cache.hit` — lookups answered from the cache (no compile,
//!   no lowering, no re-verification);
//! * `plan.cache.miss` — lookups that compiled + lowered a fresh plan
//!   and inserted it;
//! * `plan.cache.evicted` — entries evicted by the LRU capacity bound;
//! * `plan.narrow.served` — serves that picked a width-narrowed plan
//!   variant (`pud::ranges`): the operand values' covering bit-lengths
//!   were strictly narrower than the compiled width, so the serve ran
//!   the `PlanCache`'s (op, geometry, range-class) variant instead.
//!
//! Recalibration service (`coordinator::service`):
//!
//! * `serve.batches` — served workload batches measured successfully;
//! * `serve.bank_failures` — served batches degraded by a per-bank
//!   engine fault (the batch itself still completes);
//! * `recalib.accepted_on_load` / `recalib.accepted_on_env` /
//!   `recalib.rejected_on_load` — store rehydration outcomes: accepted
//!   by spot check, accepted by the environment-match fast path (no
//!   spot check spent), or rejected (spot-check failures AND
//!   incompatible/corrupt entries);
//! * `recalib.scheduled` — background recalibrations scheduled by a
//!   drift signal; `recalib.rescheduled` — retries of earlier faults;
//!   `recalib.requested` — operator-forced recalibrations
//!   (`RecalibService::request_recalibration`);
//! * `recalib.completed` / `recalib.failed` — background
//!   recalibration outcomes (worker threads and `run_pending` alike);
//! * `recalib.background` — jobs executed by `ServiceServer` worker
//!   threads (as opposed to explicit `run_pending` calls);
//! * `service.spot_check` / `service.serve` / `service.recalibrate`
//!   (timers) — seconds per lifecycle phase.
//!
//! Admission control and server lifecycle (`ServiceServer`, the
//! threaded serve → admit → shard → worker → drain loop):
//!
//! * `admission.accepted` — serve requests admitted past the in-flight
//!   bound; `admission.rejected` — typed `PudError::Overloaded`
//!   rejections (the bound was full); `admission.rejected_draining` —
//!   typed `PudError::Draining` rejections after drain began;
//! * `serve.concurrent` — high-water mark of simultaneously admitted
//!   serve requests (a max-gauge, never exceeds the configured bound);
//! * `drain.pending_jobs` — recalibration jobs (queued + running) at
//!   the moment drain began, all finished before drain returns;
//! * `drain.abandoned_jobs` — queued jobs a fast `shutdown` dropped
//!   (they re-queue from drift state on the next boot's polls);
//! * `drain.persisted_entries` — calibrations persisted into the final
//!   store snapshot;
//! * `drain.seconds` (timer) — wall time from drain/shutdown start to
//!   workers joined and store snapshot taken.
//!
//! Arithmetic serving (`RecalibService::serve_workload` /
//! `serve_plan`):
//!
//! * `compute.batches` — workload batches executed successfully (one
//!   per bank per serve call);
//! * `compute.bank_failures` — batches degraded by a per-bank fault
//!   (malformed request, engine panic); the other banks still serve;
//! * `compute.columns_served` — error-free (masked) columns that
//!   produced a trusted output, summed over batches — the Eq. 1
//!   numerator of effective workload throughput;
//! * `compute.golden_mismatch` — masked columns whose output diverged
//!   from the software golden model (`MajCircuit::eval`) — expected to
//!   stay near zero, the serving-quality alarm;
//! * `compute.serve` (timer) — seconds executing workload batches.
//!
//! Fault countermeasures (`RecalibService` quarantine / scrub,
//! `dram::faults` injection):
//!
//! * `fault.flips` — injected SiMRA bit flips observed by executed
//!   batches (serve and scrub; summed over redundant replicas) — zero
//!   on a healthy device;
//! * `quarantine.observed_mismatches` — masked columns a served
//!   workload caught diverging from the golden model while quarantine
//!   was enabled (each is a strike toward quarantining that column);
//! * `quarantine.entered` / `quarantine.released` — columns crossing
//!   the hysteresis thresholds (strikes in, consecutive clean scrub
//!   passes out);
//! * `scrub.passes` — scrub replays of the last served workload;
//! * `scrub.dirty_cols` — columns a scrub pass caught mismatching the
//!   golden model (full-width, mask ignored);
//! * `scrub.bank_failures` — scrub replays degraded by a per-bank
//!   engine fault (no quarantine state changes on that bank);
//! * `service.scrub` (timer) — seconds inside scrub replays.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe metric registry.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    timers: Mutex<BTreeMap<String, f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, key: &str, v: u64) {
        *self.counters.lock().unwrap().entry(key.to_string()).or_insert(0) += v;
    }

    pub fn incr(&self, key: &str) {
        self.add(key, 1);
    }

    pub fn counter(&self, key: &str) -> u64 {
        self.counters.lock().unwrap().get(key).copied().unwrap_or(0)
    }

    /// Record a high-water mark: `key` keeps the maximum value ever
    /// observed (e.g. `serve.concurrent`, the peak number of
    /// simultaneously admitted serve requests).
    pub fn gauge_max(&self, key: &str, v: u64) {
        let mut counters = self.counters.lock().unwrap();
        let slot = counters.entry(key.to_string()).or_insert(0);
        *slot = (*slot).max(v);
    }

    /// Time a closure under `key` (accumulating seconds).
    pub fn time<R>(&self, key: &str, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        *self
            .timers
            .lock()
            .unwrap()
            .entry(key.to_string())
            .or_insert(0.0) += t.elapsed().as_secs_f64();
        r
    }

    pub fn seconds(&self, key: &str) -> f64 {
        self.timers.lock().unwrap().get(key).copied().unwrap_or(0.0)
    }

    /// Render all metrics as aligned text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("  {k:<40} {v}\n"));
        }
        for (k, v) in self.timers.lock().unwrap().iter() {
            out.push_str(&format!("  {k:<40} {v:.3}s\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("pjrt.calls");
        m.add("pjrt.calls", 2);
        assert_eq!(m.counter("pjrt.calls"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauge_keeps_the_high_water_mark() {
        let m = Metrics::new();
        m.gauge_max("serve.concurrent", 3);
        m.gauge_max("serve.concurrent", 7);
        m.gauge_max("serve.concurrent", 2);
        assert_eq!(m.counter("serve.concurrent"), 7);
    }

    #[test]
    fn timers_accumulate() {
        let m = Metrics::new();
        let x = m.time("phase", || 21 * 2);
        assert_eq!(x, 42);
        m.time("phase", || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(m.seconds("phase") >= 0.005);
        assert!(m.render().contains("phase"));
    }
}
