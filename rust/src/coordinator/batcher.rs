//! Generic micro-batching queue.
//!
//! The e2e GEMV example serves request streams through the PUD pipeline;
//! PJRT executables amortise best over batched inputs, so requests are
//! collected until a batch fills (or the queue is flushed) — the same
//! dynamic-batching shape a serving router uses.

/// A batch-accumulating queue with a fixed batch size.
#[derive(Debug)]
pub struct Batcher<T> {
    batch_size: usize,
    pending: Vec<T>,
    pub batches_emitted: u64,
    pub items_seen: u64,
}

impl<T> Batcher<T> {
    pub fn new(batch_size: usize) -> Self {
        assert!(batch_size > 0);
        Self { batch_size, pending: Vec::new(), batches_emitted: 0, items_seen: 0 }
    }

    /// Push an item; returns a full batch when one completes.
    pub fn push(&mut self, item: T) -> Option<Vec<T>> {
        self.items_seen += 1;
        self.pending.push(item);
        if self.pending.len() >= self.batch_size {
            self.batches_emitted += 1;
            Some(std::mem::take(&mut self.pending))
        } else {
            None
        }
    }

    /// Flush the remainder (end of stream).
    pub fn flush(&mut self) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            None
        } else {
            self.batches_emitted += 1;
            Some(std::mem::take(&mut self.pending))
        }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Mean batch occupancy so far (efficiency metric).
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches_emitted == 0 {
            0.0
        } else {
            self.items_seen as f64 / (self.batches_emitted as f64 * self.batch_size as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_full_batches() {
        let mut b = Batcher::new(3);
        assert!(b.push(1).is_none());
        assert!(b.push(2).is_none());
        assert_eq!(b.push(3), Some(vec![1, 2, 3]));
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flush_drains_remainder() {
        let mut b = Batcher::new(4);
        b.push("a");
        b.push("b");
        assert_eq!(b.flush(), Some(vec!["a", "b"]));
        assert_eq!(b.flush(), None);
    }

    #[test]
    fn occupancy_accounts_partial_batches() {
        let mut b = Batcher::new(4);
        for i in 0..6 {
            b.push(i);
        }
        b.flush();
        assert_eq!(b.batches_emitted, 2);
        assert!((b.mean_occupancy() - 0.75).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn zero_batch_size_rejected() {
        Batcher::<u8>::new(0);
    }
}
