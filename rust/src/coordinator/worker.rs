//! Scoped worker pool (std-only replacement for rayon's parallel map).
//!
//! The pool is **panic-contained**: every job runs under
//! `catch_unwind`, so one panicking closure can never poison the
//! slot/result mutexes or abort the process — it degrades to one
//! [`JobError::Panicked`] slot. [`try_parallel_map`] surfaces the
//! per-slot `Result`s to callers that want to fail one item and keep
//! the rest (the recalibration service's per-bank isolation);
//! [`parallel_map`] keeps the infallible signature by re-raising the
//! first failure as a panic *on the calling thread*.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

/// Why one worker job produced no result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// The job closure panicked; carries the panic payload rendered as
    /// text (non-string payloads become a placeholder).
    Panicked(String),
    /// The job never ran or never stored a result (a worker thread
    /// died before reaching it) — should be unobservable in practice.
    Missing,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Panicked(msg) => write!(f, "worker job panicked: {msg}"),
            JobError::Missing => write!(f, "worker job produced no result"),
        }
    }
}

impl std::error::Error for JobError {}

/// Render a `catch_unwind` payload as text.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Lock a mutex, recovering the guard even if a previous holder
/// panicked (jobs are panic-contained, so poisoning should not occur;
/// this makes the pool robust to it anyway).
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Apply `f` to every item on up to `threads` worker threads, returning
/// per-slot `Result`s in input order: a panicking job yields
/// `Err(JobError::Panicked)` for its slot only, and every other job
/// still completes. `f` must be `Sync` (shared by reference); items are
/// distributed by an atomic cursor so uneven job costs balance
/// naturally.
pub fn try_parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<Result<R, JobError>>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items
            .into_iter()
            .map(|t| {
                catch_unwind(AssertUnwindSafe(|| f(t)))
                    .map_err(|p| JobError::Panicked(panic_message(p)))
            })
            .collect();
    }
    use std::sync::atomic::{AtomicUsize, Ordering};
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<Result<R, JobError>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let Some(item) = lock_unpoisoned(&slots[i]).take() else {
                    continue;
                };
                let r = catch_unwind(AssertUnwindSafe(|| f(item)))
                    .map_err(|p| JobError::Panicked(panic_message(p)));
                *lock_unpoisoned(&results[i]) = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| lock_unpoisoned(&m).take().unwrap_or(Err(JobError::Missing)))
        .collect()
}

/// Infallible parallel map: like [`try_parallel_map`] but re-raises the
/// first job failure as a panic on the *calling* thread (after every
/// other job has completed) — use when a job panic is a programming
/// error rather than a per-item fault to isolate.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    try_parallel_map(items, threads, f)
        .into_iter()
        .map(|r| r.unwrap_or_else(|e| panic!("{e}")))
        .collect()
}

/// The isolation pattern shared by the engine helpers
/// (`calibrate_isolated`, `measure_ecr_isolated`, `execute_isolated`
/// in `calib::engine`): try the batched call first — keeping
/// worker-pool fan-out / PJRT fusion on the fast path, with panics
/// contained — and on any error, panic, or short result retry every
/// request individually across the pool, so one bad item degrades to
/// one `Err` slot instead of failing (or aborting) the whole batch.
pub fn isolate_batch<Q: Sync, R: Send>(
    reqs: &[Q],
    threads: usize,
    batch: impl FnOnce(&[Q]) -> anyhow::Result<Vec<R>>,
    one: impl Fn(&Q) -> Result<R, String> + Sync,
) -> Vec<Result<R, String>> {
    if reqs.is_empty() {
        return Vec::new();
    }
    match catch_unwind(AssertUnwindSafe(|| batch(reqs))) {
        Ok(Ok(v)) if v.len() == reqs.len() => return v.into_iter().map(Ok).collect(),
        _ => {}
    }
    try_parallel_map((0..reqs.len()).collect(), threads, |i| one(&reqs[i]))
        .into_iter()
        .map(|slot| match slot {
            Ok(inner) => inner,
            Err(job) => Err(job.to_string()),
        })
        .collect()
}

/// Run one closure with panic containment — the single-job form of
/// [`try_parallel_map`], used by the service's background worker
/// threads so a panicking job body can never kill (or leak the
/// bookkeeping of) a long-lived worker.
pub fn run_contained<R>(f: impl FnOnce() -> R) -> Result<R, JobError> {
    catch_unwind(AssertUnwindSafe(f)).map_err(|p| JobError::Panicked(panic_message(p)))
}

/// Default worker count: available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect(), 4, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 8, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        // Jobs with wildly different costs still all complete.
        let out = parallel_map((0..32).collect(), 4, |x: u64| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc)
        });
        assert_eq!(out.len(), 32);
        assert_eq!(out[31].0, 31);
    }

    #[test]
    fn panicking_job_degrades_one_slot() {
        let out = try_parallel_map((0..16).collect(), 4, |x: i32| {
            if x == 7 {
                panic!("injected failure on item 7");
            }
            x * 10
        });
        assert_eq!(out.len(), 16);
        for (i, r) in out.iter().enumerate() {
            if i == 7 {
                match r {
                    Err(JobError::Panicked(msg)) => {
                        assert!(msg.contains("injected failure"), "{msg}")
                    }
                    other => panic!("slot 7 should have panicked: {other:?}"),
                }
            } else {
                assert_eq!(*r, Ok(i as i32 * 10));
            }
        }
    }

    #[test]
    fn panicking_job_single_thread_degrades_one_slot() {
        let out = try_parallel_map(vec![1, 2, 3], 1, |x: i32| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
        assert_eq!(out[0], Ok(1));
        assert!(matches!(out[1], Err(JobError::Panicked(_))));
        assert_eq!(out[2], Ok(3));
    }

    #[test]
    #[should_panic(expected = "worker job panicked")]
    fn infallible_map_reraises_on_caller() {
        // The failure surfaces as a normal panic on the calling thread
        // (catchable), never as a poisoned-mutex process abort.
        let _ = parallel_map((0..8).collect(), 4, |x: i32| {
            if x == 3 {
                panic!("bad bank");
            }
            x
        });
    }

    #[test]
    fn isolate_batch_uses_the_fast_path_then_degrades_per_item() {
        // Healthy batch: one call, results pass through.
        let reqs = vec![1u32, 2, 3];
        let out = isolate_batch(
            &reqs,
            2,
            |rs| Ok(rs.iter().map(|x| x * 10).collect()),
            |_| unreachable!("fast path must satisfy a healthy batch"),
        );
        assert_eq!(out, vec![Ok(10), Ok(20), Ok(30)]);
        // Batched call panics: every item retried, one bad item
        // degrades to one error slot.
        let out = isolate_batch(
            &reqs,
            2,
            |_| panic!("injected batch fault"),
            |&x| if x == 2 { Err("bad item".into()) } else { Ok(x * 10) },
        );
        assert_eq!(out[0], Ok(10));
        assert_eq!(out[1], Err("bad item".to_string()));
        assert_eq!(out[2], Ok(30));
        // Short batched result is treated as a fault, not truncated.
        let out = isolate_batch(&reqs, 2, |_| Ok(vec![7u32]), |&x| Ok(x));
        assert_eq!(out, vec![Ok(1), Ok(2), Ok(3)]);
        let empty: Vec<Result<u32, String>> =
            isolate_batch(&[] as &[u32], 2, |_| Ok(Vec::new()), |&x| Ok(x));
        assert!(empty.is_empty());
    }

    #[test]
    fn run_contained_returns_or_reports() {
        assert_eq!(run_contained(|| 41 + 1), Ok(42));
        match run_contained(|| -> i32 { panic!("contained boom") }) {
            Err(JobError::Panicked(msg)) => assert!(msg.contains("contained boom")),
            other => panic!("expected a contained panic, got {other:?}"),
        }
    }

    #[test]
    fn job_error_renders() {
        let e = JobError::Panicked("xyz".into());
        assert!(e.to_string().contains("xyz"));
        assert!(JobError::Missing.to_string().contains("no result"));
    }
}
