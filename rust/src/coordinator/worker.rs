//! Scoped worker pool (std-only replacement for rayon's parallel map).

/// Apply `f` to every item on up to `threads` worker threads, returning
/// results in input order. `f` must be `Sync` (shared by reference);
/// items are distributed by an atomic cursor so uneven job costs
/// balance naturally.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.into_iter().map(f).collect();
    }
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().unwrap();
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed"))
        .collect()
}

/// Default worker count: available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect(), 4, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 8, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        // Jobs with wildly different costs still all complete.
        let out = parallel_map((0..32).collect(), 4, |x: u64| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc)
        });
        assert_eq!(out.len(), 32);
        assert_eq!(out[31].0, 31);
    }
}
