//! Drift-aware recalibration service: the runtime loop that closes the
//! paper's §III-A persistence story.
//!
//! The paper stores identified calibration bit patterns in non-volatile
//! memory "so it can be reused across different environments and system
//! reboots" — but reuse is only safe while conditions hold. This
//! service treats each subarray's calibration as a **cached artifact
//! with drift-driven invalidation**:
//!
//! 1. **rehydrate** — [`RecalibService::load_store`] loads every
//!    registered subarray's entry from a [`CalibStore`] (checked
//!    decode + geometry validation), then runs one *batched* cheap ECR
//!    spot check ([`crate::calib::algorithm::SPOT_CHECK_SAMPLES`]) and
//!    accepts or rejects each candidate against
//!    [`DriftPolicy::accept_max_ecr`];
//! 2. **serve** — [`RecalibService::serve`] measures workload batches
//!    from the current calibrations (accepted ones; stale or
//!    uncalibrated entries keep serving their best-known levels so the
//!    serving path never stalls) and feeds each batch's ECR into the
//!    per-subarray [`DriftMonitor`];
//! 3. **monitor** — [`RecalibService::poll_drift`] evaluates the drift
//!    signals (temperature excursion from `dram::temperature`,
//!    retention age from the `dram::retention` clock, rolling
//!    served-batch ECR) and schedules background recalibration for
//!    drifted entries;
//! 4. **recalibrate** — [`RecalibService::run_pending`] drains the
//!    queue through the engine with per-bank fault isolation
//!    ([`crate::calib::engine::calibrate_isolated`]): the batch fans
//!    across the worker pool, a panicking or failing bank degrades to
//!    one error slot, and every success re-anchors its monitor;
//!    [`RecalibService::snapshot_store`] re-persists the result.
//!
//! Serving and recalibration are decoupled: `serve` never waits on the
//! queue, and a recalibration failure leaves the previous calibration
//! serving. All engine work goes through the batch-first
//! [`CalibEngine`] trait, so the service is backend-agnostic.
//!
//! ## Serving arithmetic
//!
//! With an engine that also implements
//! [`crate::calib::engine::ComputeEngine`], the service serves real
//! workloads, not just measurement batteries:
//! [`RecalibService::serve_workload`] compiles a
//! [`crate::pud::plan::PudOp`] once and executes it on every
//! registered subarray under its **current** calibration and the
//! arithmetic-usable column mask (MAJ5 ∧ MAJ3 error-free — circuits
//! chain both arities) from its most recent battery (spot check or
//! served batch), with the same per-bank fault isolation
//! ([`crate::calib::engine::execute_isolated`]) — so drift-scheduled
//! recalibration and arithmetic serving share one lifecycle: a stale
//! bank keeps serving its last-good levels and mask until background
//! recalibration lands, and each outcome reports how many masked
//! columns matched the software golden model.
//!
//! ## Fault countermeasures
//!
//! Calibration cancels *smooth* error sources; PuDGhost-style faults
//! ([`crate::dram::faults`]) are invisible to every ECR battery (the
//! sampling kernel runs on sense amps alone, no cell array) and only
//! surface as golden mismatches on served workloads. Three opt-in
//! countermeasures (all off by default) close that gap:
//!
//! * **quarantine with hysteresis** ([`Quarantine`],
//!   `ServiceConfig::quarantine_strikes` /
//!   `quarantine_clean_passes`) — a column leaves the
//!   arithmetic-usable mask after K observed golden mismatches and
//!   re-enters only after M consecutive clean scrub passes, so
//!   intermittent columns cannot flap back in;
//! * **redundant execution** (`ServiceConfig::redundancy`) — served
//!   workloads run on N independently seeded spare banks with
//!   per-column bitwise majority vote
//!   ([`crate::calib::engine::SPARE_STREAM`]); latency is accounted as
//!   the sum of the replica runs;
//! * **scrub passes** (`ServiceConfig::scrub_every`,
//!   [`RecalibService::scrub`]) — every Nth maintenance poll replays
//!   the last served workload *unmasked* and compares every column to
//!   the golden model: mismatching columns strike toward quarantine,
//!   clean quarantined columns count toward release. Because a scrub
//!   replays the exact serving workload, it detects precisely the
//!   corruption serving would see — unlike a one-shot spot check,
//!   which duty-cycled faults evade.
//!
//! Costs and effects are reported via the `fault.*` / `quarantine.*` /
//! `scrub.*` metrics ([`crate::coordinator::metrics`]) and measured by
//! the `BENCH_reliability.json` bench case; `rust/tests/fault_campaign.rs`
//! pins that a protected service reaches zero steady-state mismatches
//! under the standard corruption campaign while an unprotected one
//! keeps mismatching.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use crate::analysis::ecr::EcrReport;
use crate::calib::algorithm::{CalibParams, Calibration, SPOT_CHECK_SAMPLES};
use crate::calib::drift::{DriftMonitor, DriftPolicy, DriftSignal};
use crate::calib::engine::{
    calibrate_isolated, execute_isolated, measure_ecr_isolated, CalibEngine, CalibRequest,
    ComputeEngine, ComputeRequest, ComputeResult, EcrRequest,
};
use crate::calib::lattice::FracConfig;
use crate::calib::store::CalibStore;
use crate::config::device::DeviceConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::worker;
use crate::dram::geometry::SubarrayId;
use crate::dram::subarray::Subarray;
use crate::pud::plan::{PudError, PudOp, WorkloadPlan};
use crate::util::rng::derive_seed;

/// Stream-domain tag of served workload batteries (each serve call
/// draws fresh patterns from its epoch).
const SERVE_STREAM: u64 = 0x5E12F;
/// Stream-domain tag of the load-time acceptance spot check.
const SPOT_CHECK_STREAM: u64 = 0x57CC;

/// Service-level configuration: what to calibrate for and how to judge
/// drift.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Frac configuration served and recalibrated (paper: T_{2,1,0}).
    pub config: FracConfig,
    /// Algorithm-1 parameters for (re)calibration.
    pub params: CalibParams,
    /// Drift thresholds.
    pub policy: DriftPolicy,
    /// Operand count of served MAJX workloads.
    pub serve_m: usize,
    /// Battery depth of one served workload batch.
    pub serve_samples: u32,
    /// Battery depth of the load-time acceptance spot check.
    pub spot_check_samples: u32,
    /// Golden mismatches before a column is quarantined out of the
    /// arithmetic mask (`0` disables quarantine — the default).
    pub quarantine_strikes: usize,
    /// Consecutive clean scrub passes before a quarantined column
    /// re-enters the mask (hysteresis; ignored while quarantine is
    /// disabled).
    pub quarantine_clean_passes: usize,
    /// Redundant-execution factor for served workloads (`1` = single
    /// run, the default; `N > 1` majority-votes N replica runs).
    pub redundancy: usize,
    /// Run a scrub pass every N maintenance polls (`0` disables scrub
    /// — the default). See [`RecalibService::scrub`].
    pub scrub_every: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            config: FracConfig::pudtune([2, 1, 0]),
            params: CalibParams::paper(),
            policy: DriftPolicy::default(),
            serve_m: 5,
            serve_samples: 2048,
            spot_check_samples: SPOT_CHECK_SAMPLES,
            quarantine_strikes: 0,
            quarantine_clean_passes: 2,
            redundancy: 1,
            scrub_every: 0,
        }
    }
}

/// Per-column quarantine state with hysteresis: a column is expelled
/// from the arithmetic mask after `strikes_to_enter` observed golden
/// mismatches (served batches and scrub passes both strike) and
/// readmitted only after `clean_to_release` *consecutive* clean scrub
/// passes — a dirty scrub resets the clean counter, so duty-cycled
/// intermittent columns cannot flap back into service.
/// `strikes_to_enter == 0` disables the whole mechanism.
#[derive(Clone, Debug)]
pub struct Quarantine {
    strikes_to_enter: usize,
    clean_to_release: usize,
    /// Cumulative mismatch strikes per column (not reset by clean
    /// serves: intermittent faults must not launder their history).
    strikes: Vec<u32>,
    /// Columns currently quarantined out of the mask.
    out: Vec<bool>,
    /// Consecutive clean scrub passes per quarantined column.
    clean: Vec<u32>,
}

/// One quarantine update's bookkeeping (fed into the `quarantine.*` /
/// `scrub.*` metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QuarantineDelta {
    /// Columns newly quarantined by this observation.
    pub entered: usize,
    /// Quarantined columns released back into the mask.
    pub released: usize,
    /// Columns observed mismatching in this observation.
    pub dirty: usize,
}

impl Quarantine {
    pub fn new(cols: usize, strikes_to_enter: usize, clean_to_release: usize) -> Self {
        Self {
            strikes_to_enter,
            clean_to_release: clean_to_release.max(1),
            strikes: vec![0; cols],
            out: vec![false; cols],
            clean: vec![0; cols],
        }
    }

    /// Whether the mechanism is active at all.
    pub fn enabled(&self) -> bool {
        self.strikes_to_enter > 0
    }

    /// Columns currently quarantined.
    pub fn quarantined_cols(&self) -> usize {
        self.out.iter().filter(|&&q| q).count()
    }

    /// Whether column `c` is currently quarantined.
    pub fn is_quarantined(&self, c: usize) -> bool {
        self.out.get(c).copied().unwrap_or(false)
    }

    /// Remove quarantined columns from an arithmetic mask.
    pub fn apply(&self, mask: &mut [bool]) {
        if !self.enabled() {
            return;
        }
        for (m, &q) in mask.iter_mut().zip(&self.out) {
            if q {
                *m = false;
            }
        }
    }

    /// Record one served batch's per-column golden mismatches
    /// (`bad[c]` = column `c` was served and mismatched). Serving only
    /// strikes toward entry; release requires scrub evidence.
    pub fn observe_serve(&mut self, bad: &[bool]) -> QuarantineDelta {
        let mut delta = QuarantineDelta::default();
        if !self.enabled() {
            return delta;
        }
        for (c, &b) in bad.iter().enumerate() {
            if !b {
                continue;
            }
            delta.dirty += 1;
            if !self.out[c] {
                self.strikes[c] += 1;
                if self.strikes[c] as usize >= self.strikes_to_enter {
                    self.out[c] = true;
                    self.clean[c] = 0;
                    delta.entered += 1;
                }
            }
        }
        delta
    }

    /// Record one *unmasked* scrub pass: dirty columns strike toward
    /// (or stay in) quarantine, clean quarantined columns count toward
    /// hysteresis release.
    pub fn observe_scrub(&mut self, bad: &[bool]) -> QuarantineDelta {
        let mut delta = QuarantineDelta::default();
        if !self.enabled() {
            return delta;
        }
        for (c, &b) in bad.iter().enumerate() {
            if self.out[c] {
                if b {
                    delta.dirty += 1;
                    self.clean[c] = 0;
                } else {
                    self.clean[c] += 1;
                    if self.clean[c] as usize >= self.clean_to_release {
                        self.out[c] = false;
                        self.strikes[c] = 0;
                        self.clean[c] = 0;
                        delta.released += 1;
                    }
                }
            } else if b {
                delta.dirty += 1;
                self.strikes[c] += 1;
                if self.strikes[c] as usize >= self.strikes_to_enter {
                    self.out[c] = true;
                    self.clean[c] = 0;
                    delta.entered += 1;
                }
            }
        }
        delta
    }
}

/// Where a subarray's active calibration currently stands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntryState {
    /// Spot-checked (or freshly identified) and trusted.
    Accepted,
    /// Drift detected; still serving the old levels until background
    /// recalibration replaces them.
    Stale,
    /// No trusted calibration yet (missing/rejected store entry or
    /// failed recalibration): serving the uniform neutral levels.
    Uncalibrated,
}

/// Result of rehydrating one subarray from the store.
#[derive(Clone, Debug)]
pub enum LoadOutcome {
    /// Entry decoded and passed the spot check.
    Accepted { spot_ecr: f64 },
    /// Entry decoded but its spot-check ECR exceeded the policy bound.
    Rejected { spot_ecr: f64 },
    /// The store has no entry for this subarray.
    Missing,
    /// The entry exists but is unusable (geometry mismatch, corrupt
    /// levels, or a failed spot-check measurement).
    Incompatible(String),
}

/// One subarray's result from a served workload batch.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    pub id: SubarrayId,
    /// Entry state at serve time (stale entries still serve).
    pub state: EntryState,
    /// The measured battery, or the per-bank failure that degraded it.
    pub report: Result<EcrReport, String>,
}

/// One subarray's result from a served arithmetic workload batch
/// ([`RecalibService::serve_workload`]).
#[derive(Clone, Debug)]
pub struct WorkloadOutcome {
    pub id: SubarrayId,
    /// Entry state at serve time (stale entries still serve).
    pub state: EntryState,
    /// The executed batch, or the per-bank failure that degraded it.
    pub result: Result<ComputeResult, String>,
    /// Masked (error-free) columns whose outputs matched the software
    /// golden model.
    pub golden_correct: usize,
    /// Masked columns the workload was served on.
    pub active_cols: usize,
}

/// One subarray's result from a scrub pass ([`RecalibService::scrub`]).
#[derive(Clone, Debug)]
pub struct ScrubOutcome {
    pub id: SubarrayId,
    /// The replayed batch's per-bank failure, if any (a failed replay
    /// changes no quarantine state).
    pub result: Result<(), String>,
    /// Quarantine transitions this pass caused on the subarray.
    pub delta: QuarantineDelta,
}

struct Entry {
    sub: Subarray,
    seed: u64,
    calib: Calibration,
    state: EntryState,
    monitor: DriftMonitor,
    /// Whether the entry currently sits in the recalibration queue.
    queued: bool,
    /// Arithmetic-usable column mask (MAJ5 ∧ MAJ3 error-free) from the
    /// most recent battery measured under the *current* calibration
    /// (spot check or served batch); `None` until one lands, and
    /// cleared when recalibration swaps the levels.
    mask: Option<Vec<bool>>,
    /// Per-column fault quarantine (disabled unless the service config
    /// sets `quarantine_strikes`). Survives recalibration: faults are
    /// a property of the column, not of the levels.
    quarantine: Quarantine,
}

/// The drift-aware recalibration service (module docs for the loop).
pub struct RecalibService<E> {
    pub cfg: DeviceConfig,
    svc: ServiceConfig,
    engine: E,
    threads: usize,
    entries: BTreeMap<SubarrayId, Entry>,
    /// FIFO of subarrays awaiting background recalibration.
    queue: VecDeque<SubarrayId>,
    /// Bumped per serve call: every batch draws fresh patterns.
    serve_epoch: u64,
    /// Maintenance polls so far (drives the scrub cadence).
    polls: u64,
    /// Set when the scrub cadence fires; cleared by [`Self::scrub`].
    scrub_pending: bool,
    /// The last served workload — what a scrub pass replays unmasked,
    /// so scrub detection sees exactly the corruption serving sees.
    last_workload: Option<(Arc<WorkloadPlan>, Vec<Vec<u64>>)>,
    pub metrics: Arc<Metrics>,
}

impl<E: CalibEngine + Sync> RecalibService<E> {
    pub fn new(cfg: DeviceConfig, svc: ServiceConfig, engine: E) -> Result<Self, String> {
        cfg.validate()?;
        svc.policy.validate()?;
        Ok(Self {
            cfg,
            svc,
            engine,
            threads: worker::default_threads(),
            entries: BTreeMap::new(),
            queue: VecDeque::new(),
            serve_epoch: 0,
            polls: 0,
            scrub_pending: false,
            last_workload: None,
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// Register one subarray, manufactured from the device seed along
    /// its address path (the same derivation the experiment paths
    /// use). Starts `Uncalibrated` (serving neutral levels) and queued
    /// for calibration; [`Self::load_store`] may satisfy it first.
    pub fn register(&mut self, id: SubarrayId, rows: usize, cols: usize, device_seed: u64) {
        let seed = derive_seed(device_seed, &id.seed_path());
        let sub = Subarray::with_geometry(&self.cfg, rows, cols, seed);
        let calib = self.svc.config.uncalibrated(&self.cfg, cols);
        let monitor = DriftMonitor::new(&sub.env, self.svc.policy.serve_window);
        let quarantine = Quarantine::new(
            cols,
            self.svc.quarantine_strikes,
            self.svc.quarantine_clean_passes,
        );
        self.entries.insert(
            id,
            Entry {
                sub,
                seed,
                calib,
                state: EntryState::Uncalibrated,
                monitor,
                queued: false,
                mask: None,
                quarantine,
            },
        );
        self.enqueue(id);
    }

    fn enqueue(&mut self, id: SubarrayId) {
        if let Some(e) = self.entries.get_mut(&id) {
            if !e.queued {
                e.queued = true;
                self.queue.push_back(id);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn ids(&self) -> Vec<SubarrayId> {
        self.entries.keys().copied().collect()
    }

    pub fn state(&self, id: SubarrayId) -> Option<EntryState> {
        self.entries.get(&id).map(|e| e.state)
    }

    /// The calibration currently serving for `id`.
    pub fn calibration(&self, id: SubarrayId) -> Option<&Calibration> {
        self.entries.get(&id).map(|e| &e.calib)
    }

    /// Subarrays awaiting background recalibration.
    pub fn pending(&self) -> usize {
        self.entries.values().filter(|e| e.queued).count()
    }

    /// One subarray's quarantine state (`None` for unknown ids).
    pub fn quarantine(&self, id: SubarrayId) -> Option<&Quarantine> {
        self.entries.get(&id).map(|e| &e.quarantine)
    }

    /// Whether the scrub cadence has fired since the last scrub pass.
    pub fn scrub_pending(&self) -> bool {
        self.scrub_pending
    }

    /// Rehydrate every registered subarray from a store: checked
    /// decode, then ONE batched ECR spot check over all decodable
    /// candidates, then per-entry accept/reject. Rejections and
    /// incompatibilities count into `recalib.rejected_on_load` and
    /// leave the entry queued for recalibration.
    pub fn load_store(&mut self, store: &CalibStore) -> Vec<(SubarrayId, LoadOutcome)> {
        let mut outcomes: Vec<(SubarrayId, LoadOutcome)> = Vec::new();
        let mut candidates: Vec<(SubarrayId, Calibration)> = Vec::new();
        for (&id, entry) in &self.entries {
            match store.load_expecting(id, &self.cfg, entry.sub.cols) {
                Ok(Some(calib)) => {
                    // v2 env-metadata gate: levels identified at a die
                    // temperature the drift policy would already have
                    // flagged are rejected before spending a spot
                    // check on them. v1 entries (no env) skip the gate
                    // and rely on the spot check alone.
                    if let Some(env) = store.stored_env(id) {
                        let delta = (env.temp_c - entry.sub.env.temp_c).abs();
                        if delta > self.svc.policy.max_temp_delta_c {
                            self.metrics.incr("recalib.rejected_on_load");
                            outcomes.push((
                                id,
                                LoadOutcome::Incompatible(format!(
                                    "stored calibration env is {delta:.1} C from the \
                                     current die temperature (policy allows {:.1} C)",
                                    self.svc.policy.max_temp_delta_c
                                )),
                            ));
                            continue;
                        }
                    }
                    candidates.push((id, calib));
                }
                Ok(None) => outcomes.push((id, LoadOutcome::Missing)),
                Err(e) => {
                    self.metrics.incr("recalib.rejected_on_load");
                    outcomes.push((id, LoadOutcome::Incompatible(e)));
                }
            }
        }
        // One batched spot check for every candidate: both MAJ
        // arities, so an accepted entry starts with a trustworthy
        // arithmetic-usable mask, not just a MAJ-`serve_m` one.
        let other_m = 8 - self.svc.serve_m;
        let mut reqs = Vec::with_capacity(2 * candidates.len());
        for (id, calib) in &candidates {
            let entry = &self.entries[id];
            for m in [self.svc.serve_m, other_m] {
                reqs.push(
                    EcrRequest::from_subarray(
                        &entry.sub,
                        entry.seed,
                        calib.clone(),
                        m,
                        self.svc.spot_check_samples,
                    )
                    .with_seed(SPOT_CHECK_STREAM),
                );
            }
        }
        let mut reports = self
            .metrics
            .time("service.spot_check", || {
                measure_ecr_isolated(&self.engine, &reqs, self.threads)
            })
            .into_iter();
        for (id, calib) in candidates {
            let primary = reports.next().expect("one primary spot check per candidate");
            let secondary = reports.next().expect("one secondary spot check per candidate");
            let outcome = match (primary, secondary) {
                (Ok(rep), Ok(sec)) => {
                    let spot_ecr = rep.ecr();
                    if spot_ecr <= self.svc.policy.accept_max_ecr {
                        let window = self.svc.policy.serve_window;
                        let entry = self.entries.get_mut(&id).expect("candidate is registered");
                        entry.calib = calib;
                        entry.state = EntryState::Accepted;
                        entry.monitor = DriftMonitor::new(&entry.sub.env, window);
                        entry.queued = false; // drop any pending cold-start job
                        entry.mask = Some(rep.intersect(&sec).error_free_mask());
                        self.metrics.incr("recalib.accepted_on_load");
                        LoadOutcome::Accepted { spot_ecr }
                    } else {
                        self.metrics.incr("recalib.rejected_on_load");
                        LoadOutcome::Rejected { spot_ecr }
                    }
                }
                (Err(e), _) | (_, Err(e)) => {
                    self.metrics.incr("recalib.rejected_on_load");
                    LoadOutcome::Incompatible(format!("spot check failed: {e}"))
                }
            };
            outcomes.push((id, outcome));
        }
        outcomes.sort_by_key(|(id, _)| *id);
        outcomes
    }

    /// Serve one workload batch on every subarray (one batched engine
    /// call, per-bank fault isolation): measures `serve_samples`
    /// random patterns at *both* MAJ arities under each entry's
    /// current calibration, feeds the primary (MAJ-`serve_m`) ECR into
    /// the drift monitors, refreshes the entry's arithmetic-usable
    /// mask (MAJ5 ∧ MAJ3 error-free — what [`Self::serve_plan`]
    /// restricts compute to), and never touches the recalibration
    /// queue — a stale entry keeps serving its old levels until
    /// background recalibration lands.
    pub fn serve(&mut self) -> Vec<ServeOutcome> {
        self.serve_epoch += 1;
        let seed = derive_seed(SERVE_STREAM, &[self.serve_epoch]);
        let other_m = 8 - self.svc.serve_m;
        let ids: Vec<SubarrayId> = self.entries.keys().copied().collect();
        let mut reqs = Vec::with_capacity(2 * ids.len());
        for id in &ids {
            let entry = &self.entries[id];
            for m in [self.svc.serve_m, other_m] {
                reqs.push(
                    EcrRequest::from_subarray(
                        &entry.sub,
                        entry.seed,
                        entry.calib.clone(),
                        m,
                        self.svc.serve_samples,
                    )
                    .with_seed(seed),
                );
            }
        }
        let mut reports = self
            .metrics
            .time("service.serve", || {
                measure_ecr_isolated(&self.engine, &reqs, self.threads)
            })
            .into_iter();
        ids.into_iter()
            .map(|id| {
                let primary = reports.next().expect("one primary report per entry");
                let secondary = reports.next().expect("one secondary report per entry");
                let entry = self.entries.get_mut(&id).expect("serving a registered entry");
                match (&primary, secondary) {
                    (Ok(rep), Ok(sec)) => {
                        entry.monitor.observe_ecr(rep.ecr());
                        entry.mask = Some(rep.intersect(&sec).error_free_mask());
                        self.metrics.incr("serve.batches");
                    }
                    (Ok(rep), Err(_)) => {
                        // The primary battery still monitors drift; the
                        // mask keeps its last trusted value.
                        entry.monitor.observe_ecr(rep.ecr());
                        self.metrics.incr("serve.batches");
                        self.metrics.incr("serve.bank_failures");
                    }
                    (Err(_), _) => self.metrics.incr("serve.bank_failures"),
                }
                ServeOutcome { id, state: entry.state, report: primary }
            })
            .collect()
    }

    /// Evaluate drift for every accepted entry and schedule background
    /// recalibration for the drifted ones (metric `recalib.scheduled`).
    /// Entries whose earlier recalibration failed (stale/uncalibrated,
    /// no longer queued) are re-queued here too (`recalib.rescheduled`),
    /// so faults retry on the next maintenance pass. Returns the fresh
    /// drift signals.
    pub fn poll_drift(&mut self) -> Vec<(SubarrayId, DriftSignal)> {
        self.polls += 1;
        if self.svc.scrub_every > 0 && self.polls % self.svc.scrub_every as u64 == 0 {
            // Scrubbing needs a compute-capable engine; the poll only
            // raises the flag, [`Self::maintain`] (or an explicit
            // [`Self::scrub`]) runs the pass.
            self.scrub_pending = true;
        }
        let mut signals = Vec::new();
        let mut to_queue = Vec::new();
        for (&id, entry) in &mut self.entries {
            match entry.state {
                EntryState::Accepted => {
                    if let Some(sig) = entry.monitor.check(&self.svc.policy, &entry.sub.env) {
                        entry.state = EntryState::Stale;
                        self.metrics.incr("recalib.scheduled");
                        signals.push((id, sig));
                        to_queue.push(id);
                    }
                }
                EntryState::Stale | EntryState::Uncalibrated => {
                    if !entry.queued {
                        self.metrics.incr("recalib.rescheduled");
                        to_queue.push(id);
                    }
                }
            }
        }
        for id in to_queue {
            self.enqueue(id);
        }
        signals
    }

    /// Drain up to `max_jobs` queued recalibrations through the engine
    /// (one isolated batch: worker-pool fan-out, a panicking bank
    /// degrades to one error). Successes swap in the new calibration
    /// and re-anchor their drift monitor; failures keep the previous
    /// levels serving and are retried on the next [`Self::poll_drift`].
    pub fn run_pending(&mut self, max_jobs: usize) -> Vec<(SubarrayId, Result<(), String>)> {
        let mut ids = Vec::new();
        while ids.len() < max_jobs {
            let Some(id) = self.queue.pop_front() else {
                break;
            };
            let Some(entry) = self.entries.get_mut(&id) else {
                continue;
            };
            // Skip stale queue entries (e.g. accepted by a later
            // `load_store` after being queued at registration).
            if entry.queued {
                entry.queued = false;
                ids.push(id);
            }
        }
        if ids.is_empty() {
            return Vec::new();
        }
        let reqs: Vec<CalibRequest> = ids
            .iter()
            .map(|id| {
                let entry = &self.entries[id];
                CalibRequest::from_subarray(
                    &entry.sub,
                    entry.seed,
                    self.svc.config,
                    self.svc.params,
                )
            })
            .collect();
        let results = self.metrics.time("service.recalibrate", || {
            calibrate_isolated(&self.engine, &reqs, self.threads)
        });
        ids.into_iter()
            .zip(results)
            .map(|(id, result)| {
                let entry = self.entries.get_mut(&id).expect("recalibrating a registered entry");
                let outcome = match result {
                    Ok(calib) => {
                        entry.calib = calib;
                        entry.state = EntryState::Accepted;
                        entry.monitor.rebase(&entry.sub.env);
                        // The old mask measured the old levels; the
                        // next battery under the new calibration
                        // re-establishes it.
                        entry.mask = None;
                        self.metrics.incr("recalib.completed");
                        Ok(())
                    }
                    Err(e) => {
                        self.metrics.incr("recalib.failed");
                        Err(e)
                    }
                };
                (id, outcome)
            })
            .collect()
    }

    /// Snapshot the current calibrations into a persistable store —
    /// the write-back half of the lifecycle. Stale entries are
    /// included too: they are the last-known-good identification, and
    /// a shutdown between drift detection and repair should not erase
    /// them (the load-time spot check re-validates every entry on the
    /// next boot anyway). Only `Uncalibrated` entries — serving the
    /// uniform neutral levels — carry nothing worth persisting.
    pub fn snapshot_store(&self) -> CalibStore {
        let mut store = CalibStore::default();
        for (&id, entry) in &self.entries {
            if entry.state != EntryState::Uncalibrated {
                // v2 metadata: the environment the levels were
                // identified/accepted under.
                store.insert_with_env(id, &entry.calib, entry.monitor.calib_env());
            }
        }
        store
    }

    /// Set one subarray's die temperature (scenario driver / telemetry
    /// ingest). Returns false for unknown ids.
    pub fn set_temperature(&mut self, id: SubarrayId, temp_c: f64) -> bool {
        match self.entries.get_mut(&id) {
            Some(e) => {
                e.sub.set_temperature(temp_c);
                true
            }
            None => false,
        }
    }

    /// Advance simulated wall-clock time on every subarray (retention
    /// decay + aging drift).
    pub fn advance_time(&mut self, dt_hours: f64) {
        for entry in self.entries.values_mut() {
            entry.sub.advance_time(dt_hours);
        }
    }
}

/// Arithmetic serving (engines that also execute workloads).
impl<E: CalibEngine + ComputeEngine + Sync> RecalibService<E> {
    /// Compile `op` once and serve it on every registered subarray —
    /// see [`Self::serve_plan`]. An invalid op is a request-level
    /// error; per-bank faults live inside the returned outcomes.
    pub fn serve_workload(
        &mut self,
        op: PudOp,
        operands: &[Vec<u64>],
    ) -> Result<Vec<WorkloadOutcome>, PudError> {
        let plan = Arc::new(WorkloadPlan::compile(op)?);
        self.serve_plan(&plan, operands)
    }

    /// Serve one compiled workload batch on every subarray (one
    /// batched engine call, per-bank fault isolation): each bank
    /// executes under its *current* calibration and the error-free
    /// column mask from its most recent battery, stale entries
    /// included — arithmetic never waits on the recalibration queue.
    /// `operands` are per-column values broadcast to every bank; a
    /// bank whose geometry disagrees degrades to one `Err` outcome.
    /// Each outcome counts how many masked columns matched the
    /// software golden model (`compute.golden_mismatch` tracks the
    /// shortfall). A plan that did not come out of
    /// `WorkloadPlan::compile` is statically verified first and a
    /// charge-state violation rejects the whole request before any
    /// bank executes (`PudError::Verification`).
    pub fn serve_plan(
        &mut self,
        plan: &Arc<WorkloadPlan>,
        operands: &[Vec<u64>],
    ) -> Result<Vec<WorkloadOutcome>, PudError> {
        crate::pud::verify::admit(plan)?;
        self.last_workload = Some((plan.clone(), operands.to_vec()));
        let redundancy = self.svc.redundancy.max(1);
        let ids: Vec<SubarrayId> = self.entries.keys().copied().collect();
        let reqs: Vec<ComputeRequest> = ids
            .iter()
            .map(|id| {
                let entry = &self.entries[id];
                let mut req = ComputeRequest::from_subarray(
                    &entry.sub,
                    entry.seed,
                    plan.clone(),
                    entry.calib.clone(),
                    operands.to_vec(),
                );
                // Battery mask ∧ quarantine: a column serves only when
                // both the ECR battery and the fault history trust it.
                let quarantined = entry.quarantine.quarantined_cols() > 0;
                if entry.mask.is_some() || quarantined {
                    let mut mask =
                        entry.mask.clone().unwrap_or_else(|| vec![true; entry.sub.cols]);
                    entry.quarantine.apply(&mut mask);
                    req = req.with_mask(mask);
                }
                if redundancy > 1 {
                    req = req.with_replicas(redundancy);
                }
                req
            })
            .collect();
        let results = self.metrics.time("compute.serve", || {
            execute_isolated(&self.engine, &reqs, self.threads)
        });
        // The golden model depends only on the plan and the broadcast
        // operands — evaluate the circuit once, not once per bank. A
        // 0-operand plan computes one constant; a bank that executed
        // successfully at a different width re-broadcasts it below.
        let shared_cols = operands.first().map(|v| v.len()).unwrap_or(1);
        let golden = plan.golden_outputs(operands, shared_cols);
        let outcomes = ids
            .into_iter()
            .zip(results)
            .map(|(id, result)| {
                let entry = self.entries.get_mut(&id).expect("serving a registered entry");
                let state = entry.state;
                let (golden_correct, active_cols) = match (&result, &golden) {
                    (Ok(res), Ok(golden)) => {
                        self.metrics.incr("compute.batches");
                        self.metrics.add("fault.flips", res.fault_flips);
                        let active = res.active_cols();
                        self.metrics.add("compute.columns_served", active as u64);
                        let correct = if golden.len() == res.outputs.len() {
                            res.golden_correct(golden)
                        } else {
                            // Only reachable for 0-operand plans (any
                            // width mismatch fails execution): compare
                            // every column to the broadcast constant.
                            let constant = vec![golden[0]; res.outputs.len()];
                            res.golden_correct(&constant)
                        };
                        if correct < active {
                            self.metrics
                                .add("compute.golden_mismatch", (active - correct) as u64);
                        }
                        if entry.quarantine.enabled() && golden.len() == res.outputs.len() {
                            let bad: Vec<bool> = (0..res.outputs.len())
                                .map(|c| {
                                    matches!(res.mask.get(c), Some(true))
                                        && res.outputs[c] != golden[c]
                                })
                                .collect();
                            let delta = entry.quarantine.observe_serve(&bad);
                            self.metrics
                                .add("quarantine.observed_mismatches", delta.dirty as u64);
                            self.metrics.add("quarantine.entered", delta.entered as u64);
                        }
                        (correct, active)
                    }
                    _ => {
                        self.metrics.incr("compute.bank_failures");
                        (0, 0)
                    }
                };
                WorkloadOutcome { id, state, result, golden_correct, active_cols }
            })
            .collect();
        Ok(outcomes)
    }

    /// Replay the last served workload **unmasked** on every subarray
    /// and feed each column's golden verdict into its quarantine:
    /// mismatching columns strike toward (or stay in) quarantine,
    /// clean quarantined columns count toward hysteresis release. A
    /// scrub replays exactly what serving runs, so it observes exactly
    /// the corruption serving would absorb — including duty-cycled
    /// intermittent columns that a one-shot spot check misses. No-op
    /// (empty result) before the first served workload.
    pub fn scrub(&mut self) -> Vec<ScrubOutcome> {
        self.scrub_pending = false;
        let Some((plan, operands)) = self.last_workload.clone() else {
            return Vec::new();
        };
        let ids: Vec<SubarrayId> = self.entries.keys().copied().collect();
        let reqs: Vec<ComputeRequest> = ids
            .iter()
            .map(|id| {
                let entry = &self.entries[id];
                ComputeRequest::from_subarray(
                    &entry.sub,
                    entry.seed,
                    plan.clone(),
                    entry.calib.clone(),
                    operands.clone(),
                )
            })
            .collect();
        let results = self.metrics.time("service.scrub", || {
            execute_isolated(&self.engine, &reqs, self.threads)
        });
        self.metrics.incr("scrub.passes");
        let shared_cols = operands.first().map(|v| v.len()).unwrap_or(1);
        let golden = plan.golden_outputs(&operands, shared_cols);
        ids.into_iter()
            .zip(results)
            .map(|(id, result)| {
                let entry = self.entries.get_mut(&id).expect("scrubbing a registered entry");
                let (result, delta) = match (result, &golden) {
                    (Ok(res), Ok(golden)) if golden.len() == res.outputs.len() => {
                        let bad: Vec<bool> = (0..res.outputs.len())
                            .map(|c| res.outputs[c] != golden[c])
                            .collect();
                        let delta = entry.quarantine.observe_scrub(&bad);
                        self.metrics.add("fault.flips", res.fault_flips);
                        self.metrics.add("scrub.dirty_cols", delta.dirty as u64);
                        self.metrics.add("quarantine.entered", delta.entered as u64);
                        self.metrics.add("quarantine.released", delta.released as u64);
                        (Ok(()), delta)
                    }
                    (Ok(_), Ok(_)) => (
                        Err("scrub golden width mismatch".to_string()),
                        QuarantineDelta::default(),
                    ),
                    (Ok(_), Err(e)) => (Err(format!("{e}")), QuarantineDelta::default()),
                    (Err(e), _) => {
                        self.metrics.incr("scrub.bank_failures");
                        (Err(e), QuarantineDelta::default())
                    }
                };
                ScrubOutcome { id, result, delta }
            })
            .collect()
    }

    /// One maintenance tick: evaluate drift signals
    /// ([`Self::poll_drift`]) and, when the scrub cadence
    /// (`ServiceConfig::scrub_every`) fires, run the scrub pass.
    pub fn maintain(&mut self) -> (Vec<(SubarrayId, DriftSignal)>, Vec<ScrubOutcome>) {
        let signals = self.poll_drift();
        let scrubbed = if self.scrub_pending { self.scrub() } else { Vec::new() };
        (signals, scrubbed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::algorithm::NativeEngine;

    fn service(banks: usize, cols: usize) -> RecalibService<NativeEngine> {
        let cfg = DeviceConfig::default();
        let svc = ServiceConfig { serve_samples: 512, ..ServiceConfig::default() };
        let mut s = RecalibService::new(cfg.clone(), svc, NativeEngine::new(cfg)).unwrap();
        for b in 0..banks {
            s.register(SubarrayId::new(0, b, 0), 32, cols, 0x5EED);
        }
        s
    }

    #[test]
    fn cold_start_calibrates_and_persists() {
        let mut s = service(2, 512);
        assert_eq!(s.pending(), 2);
        assert!(s.ids().iter().all(|&id| s.state(id) == Some(EntryState::Uncalibrated)));
        let done = s.run_pending(usize::MAX);
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|(_, r)| r.is_ok()));
        assert!(s.ids().iter().all(|&id| s.state(id) == Some(EntryState::Accepted)));
        assert_eq!(s.pending(), 0);
        assert_eq!(s.snapshot_store().entries.len(), 2);
        assert_eq!(s.metrics.counter("recalib.completed"), 2);
    }

    #[test]
    fn load_accepts_good_entries_and_skips_their_cold_start() {
        let mut warm = service(2, 512);
        warm.run_pending(usize::MAX);
        let store = warm.snapshot_store();

        // "Reboot": a fresh service over the same manufactured device.
        let mut s = service(2, 512);
        let outcomes = s.load_store(&store);
        for (id, o) in &outcomes {
            assert!(matches!(o, LoadOutcome::Accepted { .. }), "{id:?}: {o:?}");
        }
        assert_eq!(s.metrics.counter("recalib.accepted_on_load"), 2);
        assert_eq!(s.metrics.counter("recalib.rejected_on_load"), 0);
        assert_eq!(s.pending(), 0);
        // The loaded levels are bit-identical to the persisted ones.
        for &id in &s.ids() {
            assert_eq!(
                s.calibration(id).unwrap().levels,
                warm.calibration(id).unwrap().levels
            );
        }
        // The stale queue entries from registration are skipped.
        assert!(s.run_pending(usize::MAX).is_empty());
    }

    #[test]
    fn load_rejects_tampered_entries() {
        let mut warm = service(1, 512);
        warm.run_pending(usize::MAX);
        let mut store = warm.snapshot_store();
        let id = SubarrayId::new(0, 0, 0);
        // Pin every column to the lowest lattice level: a maximally
        // wrong calibration that the spot check must catch.
        store.entries.get_mut(&id).unwrap().levels = vec![0; 512];

        let mut s = service(1, 512);
        let outcomes = s.load_store(&store);
        assert!(matches!(outcomes[0].1, LoadOutcome::Rejected { spot_ecr } if spot_ecr > 0.5));
        assert_eq!(s.metrics.counter("recalib.rejected_on_load"), 1);
        assert_eq!(s.state(id), Some(EntryState::Uncalibrated));
        // Still queued from registration: recalibration repairs it.
        assert_eq!(s.pending(), 1);
        s.run_pending(usize::MAX);
        assert_eq!(s.state(id), Some(EntryState::Accepted));
    }

    #[test]
    fn geometry_mismatch_is_incompatible_not_a_miss() {
        let mut warm = service(1, 512);
        warm.run_pending(usize::MAX);
        let store = warm.snapshot_store();
        let mut s = service(1, 256);
        let outcomes = s.load_store(&store);
        assert!(matches!(&outcomes[0].1, LoadOutcome::Incompatible(e) if e.contains("512")));
        assert_eq!(s.metrics.counter("recalib.rejected_on_load"), 1);
    }

    #[test]
    fn serve_feeds_monitors_without_touching_the_queue() {
        let mut s = service(1, 512);
        s.run_pending(usize::MAX);
        let out = s.serve();
        assert_eq!(out.len(), 1);
        assert!(out[0].report.is_ok());
        assert_eq!(out[0].state, EntryState::Accepted);
        assert_eq!(s.metrics.counter("serve.batches"), 1);
        assert_eq!(s.pending(), 0);
        // A quiet environment raises no drift signals.
        assert!(s.poll_drift().is_empty());
    }

    #[test]
    fn temperature_excursion_schedules_background_recalibration() {
        let mut s = service(2, 512);
        s.run_pending(usize::MAX);
        let hot = SubarrayId::new(0, 1, 0);
        assert!(s.set_temperature(hot, 85.0));
        let signals = s.poll_drift();
        assert_eq!(signals.len(), 1);
        assert_eq!(signals[0].0, hot);
        assert!(matches!(signals[0].1, DriftSignal::TemperatureExcursion { .. }));
        assert_eq!(s.state(hot), Some(EntryState::Stale));
        assert_eq!(s.metrics.counter("recalib.scheduled"), 1);
        // A shutdown now must not lose the stale bank's last-known-good
        // entry: snapshots persist everything except Uncalibrated.
        assert_eq!(s.snapshot_store().entries.len(), 2);
        // Stale entries keep serving while queued.
        assert!(s.serve()[1].report.is_ok());
        let done = s.run_pending(usize::MAX);
        assert_eq!(done.len(), 1);
        assert!(done[0].1.is_ok());
        assert_eq!(s.state(hot), Some(EntryState::Accepted));
        // Re-anchored at the hot temperature: no further signal.
        assert!(s.poll_drift().is_empty());
    }

    #[test]
    fn unknown_id_set_temperature_is_reported() {
        let mut s = service(1, 128);
        assert!(!s.set_temperature(SubarrayId::new(7, 7, 7), 60.0));
    }

    #[test]
    fn serve_workload_runs_under_current_masks() {
        use crate::pud::plan::PudOp;
        let cols = 64;
        let mut s = service(2, cols);
        s.run_pending(usize::MAX);
        // A served battery establishes each bank's error-free mask.
        s.serve();
        // width 2: the add2 plan needs ~10 scratch rows, well inside
        // the 16 the test geometry's data region provides.
        let a: Vec<u64> = (0..cols as u64).map(|c| c % 4).collect();
        let b: Vec<u64> = (0..cols as u64).map(|c| (c * 5 + 2) % 4).collect();
        let out = s
            .serve_workload(PudOp::Add { width: 2 }, &[a.clone(), b.clone()])
            .unwrap();
        assert_eq!(out.len(), 2);
        for o in &out {
            let res = o.result.as_ref().expect("served");
            assert_eq!(o.state, EntryState::Accepted);
            // The battery-derived mask restricts reporting.
            assert!(res.mask.len() == cols && o.active_cols <= cols);
            assert!(o.golden_correct <= o.active_cols);
            assert!(res.elapsed_ns > 0.0);
        }
        assert_eq!(s.metrics.counter("compute.batches"), 2);
        assert_eq!(s.metrics.counter("compute.bank_failures"), 0);
        // An invalid op fails the request, not the banks.
        assert!(s.serve_workload(PudOp::Add { width: 0 }, &[a, b]).is_err());
        assert_eq!(s.metrics.counter("compute.bank_failures"), 0);
    }

    #[test]
    fn quarantine_hysteresis_enters_and_releases() {
        let mut q = Quarantine::new(4, 2, 2);
        assert!(q.enabled());
        let bad = vec![false, true, false, true];
        assert_eq!(
            q.observe_serve(&bad),
            QuarantineDelta { entered: 0, released: 0, dirty: 2 }
        );
        // The second strike quarantines both dirty columns.
        assert_eq!(q.observe_serve(&bad).entered, 2);
        assert_eq!(q.quarantined_cols(), 2);
        assert!(q.is_quarantined(1) && q.is_quarantined(3));
        let mut mask = vec![true; 4];
        q.apply(&mut mask);
        assert_eq!(mask, vec![true, false, true, false]);
        // One clean scrub is not enough to release (hysteresis)...
        let clean = vec![false; 4];
        assert_eq!(q.observe_scrub(&clean).released, 0);
        // ...a dirty scrub resets column 1's progress while column 3
        // reaches two consecutive clean passes and is released.
        let dirty1 = vec![false, true, false, false];
        assert_eq!(
            q.observe_scrub(&dirty1),
            QuarantineDelta { entered: 0, released: 1, dirty: 1 }
        );
        assert!(q.is_quarantined(1) && !q.is_quarantined(3));
        // Column 1 needs two fresh consecutive clean passes.
        assert_eq!(q.observe_scrub(&clean).released, 0);
        assert_eq!(q.observe_scrub(&clean).released, 1);
        assert_eq!(q.quarantined_cols(), 0);
        // Release clears the strike history: one new mismatch does not
        // re-quarantine.
        assert_eq!(q.observe_serve(&bad).entered, 0);
    }

    #[test]
    fn disabled_quarantine_is_inert() {
        let mut q = Quarantine::new(4, 0, 2);
        assert!(!q.enabled());
        let bad = vec![true; 4];
        for _ in 0..5 {
            assert_eq!(q.observe_serve(&bad), QuarantineDelta::default());
            assert_eq!(q.observe_scrub(&bad), QuarantineDelta::default());
        }
        assert_eq!(q.quarantined_cols(), 0);
        let mut mask = vec![true; 4];
        q.apply(&mut mask);
        assert_eq!(mask, vec![true; 4]);
    }

    #[test]
    fn scrub_observations_strike_toward_quarantine() {
        let mut q = Quarantine::new(2, 2, 1);
        let bad = vec![true, false];
        assert_eq!(q.observe_scrub(&bad).entered, 0);
        assert_eq!(q.observe_scrub(&bad).entered, 1);
        assert!(q.is_quarantined(0));
        // clean_to_release is clamped to at least one pass.
        assert_eq!(q.observe_scrub(&[false, false]).released, 1);
    }

    #[test]
    fn scrub_cadence_fires_through_maintenance_polls() {
        use crate::pud::plan::PudOp;
        let cols = 32;
        let cfg = DeviceConfig::default();
        let svc = ServiceConfig {
            serve_samples: 256,
            quarantine_strikes: 2,
            scrub_every: 2,
            ..ServiceConfig::default()
        };
        let mut s = RecalibService::new(cfg.clone(), svc, NativeEngine::new(cfg)).unwrap();
        s.register(SubarrayId::new(0, 0, 0), 32, cols, 0x5EED);
        s.run_pending(usize::MAX);
        // Poll 1: cadence not due yet.
        let (_, sc) = s.maintain();
        assert!(sc.is_empty() && !s.scrub_pending());
        // Poll 2: due, but nothing served yet — the pass is empty and
        // the flag still clears.
        let (_, sc) = s.maintain();
        assert!(sc.is_empty() && !s.scrub_pending());
        assert_eq!(s.metrics.counter("scrub.passes"), 0);
        // Serve a workload, then the next due poll scrubs it.
        let a: Vec<u64> = (0..cols as u64).map(|c| c % 4).collect();
        let b: Vec<u64> = (0..cols as u64).map(|c| (c * 5 + 2) % 4).collect();
        s.serve_workload(PudOp::Add { width: 2 }, &[a, b]).unwrap();
        let (_, sc) = s.maintain(); // poll 3: not due
        assert!(sc.is_empty());
        let (_, sc) = s.maintain(); // poll 4: due
        assert_eq!(sc.len(), 1);
        assert!(sc[0].result.is_ok());
        assert_eq!(s.metrics.counter("scrub.passes"), 1);
    }

    #[test]
    fn snapshot_persists_calibration_environment_metadata() {
        let mut s = service(1, 128);
        s.run_pending(usize::MAX);
        let id = SubarrayId::new(0, 0, 0);
        // An excursion past the policy bound schedules recalibration;
        // the repaired entry re-anchors its monitor at the hot
        // temperature, which is what the v2 store must record.
        s.set_temperature(id, 85.0);
        assert_eq!(s.poll_drift().len(), 1);
        s.run_pending(usize::MAX);
        let store = s.snapshot_store();
        let env = store.stored_env(id).expect("v2 entries carry an environment");
        assert_eq!(env.temp_c, 85.0);
    }
}
