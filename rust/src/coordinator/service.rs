//! Drift-aware recalibration service: the runtime loop that closes the
//! paper's §III-A persistence story.
//!
//! The paper stores identified calibration bit patterns in non-volatile
//! memory "so it can be reused across different environments and system
//! reboots" — but reuse is only safe while conditions hold. This
//! service treats each subarray's calibration as a **cached artifact
//! with drift-driven invalidation**:
//!
//! 1. **rehydrate** — [`RecalibService::load_store`] loads every
//!    registered subarray's entry from a [`CalibStore`] (checked
//!    decode + geometry validation), then runs one *batched* cheap ECR
//!    spot check ([`crate::calib::algorithm::SPOT_CHECK_SAMPLES`]) and
//!    accepts or rejects each candidate against
//!    [`DriftPolicy::accept_max_ecr`];
//! 2. **serve** — [`RecalibService::serve`] measures workload batches
//!    from the current calibrations (accepted ones; stale or
//!    uncalibrated entries keep serving their best-known levels so the
//!    serving path never stalls) and feeds each batch's ECR into the
//!    per-subarray [`DriftMonitor`];
//! 3. **monitor** — [`RecalibService::poll_drift`] evaluates the drift
//!    signals (temperature excursion from `dram::temperature`,
//!    retention age from the `dram::retention` clock, rolling
//!    served-batch ECR) and schedules background recalibration for
//!    drifted entries;
//! 4. **recalibrate** — [`RecalibService::run_pending`] drains the
//!    queue through the engine with per-bank fault isolation
//!    ([`crate::calib::engine::calibrate_isolated`]): the batch fans
//!    across the worker pool, a panicking or failing bank degrades to
//!    one error slot, and every success re-anchors its monitor;
//!    [`RecalibService::snapshot_store`] re-persists the result.
//!
//! Serving and recalibration are decoupled: `serve` never waits on the
//! queue, and a recalibration failure leaves the previous calibration
//! serving. All engine work goes through the batch-first
//! [`CalibEngine`] trait, so the service is backend-agnostic.
//!
//! ## Serving arithmetic
//!
//! With an engine that also implements
//! [`crate::calib::engine::ComputeEngine`], the service serves real
//! workloads, not just measurement batteries:
//! [`RecalibService::serve_workload`] compiles a
//! [`crate::pud::plan::PudOp`] once and executes it on every
//! registered subarray under its **current** calibration and the
//! arithmetic-usable column mask (MAJ5 ∧ MAJ3 error-free — circuits
//! chain both arities) from its most recent battery (spot check or
//! served batch), with the same per-bank fault isolation
//! ([`crate::calib::engine::execute_isolated`]) — so drift-scheduled
//! recalibration and arithmetic serving share one lifecycle: a stale
//! bank keeps serving its last-good levels and mask until background
//! recalibration lands, and each outcome reports how many masked
//! columns matched the software golden model.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use crate::analysis::ecr::EcrReport;
use crate::calib::algorithm::{CalibParams, Calibration, SPOT_CHECK_SAMPLES};
use crate::calib::drift::{DriftMonitor, DriftPolicy, DriftSignal};
use crate::calib::engine::{
    calibrate_isolated, execute_isolated, measure_ecr_isolated, CalibEngine, CalibRequest,
    ComputeEngine, ComputeRequest, ComputeResult, EcrRequest,
};
use crate::calib::lattice::FracConfig;
use crate::calib::store::CalibStore;
use crate::config::device::DeviceConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::worker;
use crate::dram::geometry::SubarrayId;
use crate::dram::subarray::Subarray;
use crate::pud::plan::{PudError, PudOp, WorkloadPlan};
use crate::util::rng::derive_seed;

/// Stream-domain tag of served workload batteries (each serve call
/// draws fresh patterns from its epoch).
const SERVE_STREAM: u64 = 0x5E12F;
/// Stream-domain tag of the load-time acceptance spot check.
const SPOT_CHECK_STREAM: u64 = 0x57CC;

/// Service-level configuration: what to calibrate for and how to judge
/// drift.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Frac configuration served and recalibrated (paper: T_{2,1,0}).
    pub config: FracConfig,
    /// Algorithm-1 parameters for (re)calibration.
    pub params: CalibParams,
    /// Drift thresholds.
    pub policy: DriftPolicy,
    /// Operand count of served MAJX workloads.
    pub serve_m: usize,
    /// Battery depth of one served workload batch.
    pub serve_samples: u32,
    /// Battery depth of the load-time acceptance spot check.
    pub spot_check_samples: u32,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            config: FracConfig::pudtune([2, 1, 0]),
            params: CalibParams::paper(),
            policy: DriftPolicy::default(),
            serve_m: 5,
            serve_samples: 2048,
            spot_check_samples: SPOT_CHECK_SAMPLES,
        }
    }
}

/// Where a subarray's active calibration currently stands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntryState {
    /// Spot-checked (or freshly identified) and trusted.
    Accepted,
    /// Drift detected; still serving the old levels until background
    /// recalibration replaces them.
    Stale,
    /// No trusted calibration yet (missing/rejected store entry or
    /// failed recalibration): serving the uniform neutral levels.
    Uncalibrated,
}

/// Result of rehydrating one subarray from the store.
#[derive(Clone, Debug)]
pub enum LoadOutcome {
    /// Entry decoded and passed the spot check.
    Accepted { spot_ecr: f64 },
    /// Entry decoded but its spot-check ECR exceeded the policy bound.
    Rejected { spot_ecr: f64 },
    /// The store has no entry for this subarray.
    Missing,
    /// The entry exists but is unusable (geometry mismatch, corrupt
    /// levels, or a failed spot-check measurement).
    Incompatible(String),
}

/// One subarray's result from a served workload batch.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    pub id: SubarrayId,
    /// Entry state at serve time (stale entries still serve).
    pub state: EntryState,
    /// The measured battery, or the per-bank failure that degraded it.
    pub report: Result<EcrReport, String>,
}

/// One subarray's result from a served arithmetic workload batch
/// ([`RecalibService::serve_workload`]).
#[derive(Clone, Debug)]
pub struct WorkloadOutcome {
    pub id: SubarrayId,
    /// Entry state at serve time (stale entries still serve).
    pub state: EntryState,
    /// The executed batch, or the per-bank failure that degraded it.
    pub result: Result<ComputeResult, String>,
    /// Masked (error-free) columns whose outputs matched the software
    /// golden model.
    pub golden_correct: usize,
    /// Masked columns the workload was served on.
    pub active_cols: usize,
}

struct Entry {
    sub: Subarray,
    seed: u64,
    calib: Calibration,
    state: EntryState,
    monitor: DriftMonitor,
    /// Whether the entry currently sits in the recalibration queue.
    queued: bool,
    /// Arithmetic-usable column mask (MAJ5 ∧ MAJ3 error-free) from the
    /// most recent battery measured under the *current* calibration
    /// (spot check or served batch); `None` until one lands, and
    /// cleared when recalibration swaps the levels.
    mask: Option<Vec<bool>>,
}

/// The drift-aware recalibration service (module docs for the loop).
pub struct RecalibService<E> {
    pub cfg: DeviceConfig,
    svc: ServiceConfig,
    engine: E,
    threads: usize,
    entries: BTreeMap<SubarrayId, Entry>,
    /// FIFO of subarrays awaiting background recalibration.
    queue: VecDeque<SubarrayId>,
    /// Bumped per serve call: every batch draws fresh patterns.
    serve_epoch: u64,
    pub metrics: Arc<Metrics>,
}

impl<E: CalibEngine + Sync> RecalibService<E> {
    pub fn new(cfg: DeviceConfig, svc: ServiceConfig, engine: E) -> Result<Self, String> {
        cfg.validate()?;
        svc.policy.validate()?;
        Ok(Self {
            cfg,
            svc,
            engine,
            threads: worker::default_threads(),
            entries: BTreeMap::new(),
            queue: VecDeque::new(),
            serve_epoch: 0,
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// Register one subarray, manufactured from the device seed along
    /// its address path (the same derivation the experiment paths
    /// use). Starts `Uncalibrated` (serving neutral levels) and queued
    /// for calibration; [`Self::load_store`] may satisfy it first.
    pub fn register(&mut self, id: SubarrayId, rows: usize, cols: usize, device_seed: u64) {
        let seed = derive_seed(device_seed, &id.seed_path());
        let sub = Subarray::with_geometry(&self.cfg, rows, cols, seed);
        let calib = self.svc.config.uncalibrated(&self.cfg, cols);
        let monitor = DriftMonitor::new(&sub.env, self.svc.policy.serve_window);
        self.entries.insert(
            id,
            Entry {
                sub,
                seed,
                calib,
                state: EntryState::Uncalibrated,
                monitor,
                queued: false,
                mask: None,
            },
        );
        self.enqueue(id);
    }

    fn enqueue(&mut self, id: SubarrayId) {
        if let Some(e) = self.entries.get_mut(&id) {
            if !e.queued {
                e.queued = true;
                self.queue.push_back(id);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn ids(&self) -> Vec<SubarrayId> {
        self.entries.keys().copied().collect()
    }

    pub fn state(&self, id: SubarrayId) -> Option<EntryState> {
        self.entries.get(&id).map(|e| e.state)
    }

    /// The calibration currently serving for `id`.
    pub fn calibration(&self, id: SubarrayId) -> Option<&Calibration> {
        self.entries.get(&id).map(|e| &e.calib)
    }

    /// Subarrays awaiting background recalibration.
    pub fn pending(&self) -> usize {
        self.entries.values().filter(|e| e.queued).count()
    }

    /// Rehydrate every registered subarray from a store: checked
    /// decode, then ONE batched ECR spot check over all decodable
    /// candidates, then per-entry accept/reject. Rejections and
    /// incompatibilities count into `recalib.rejected_on_load` and
    /// leave the entry queued for recalibration.
    pub fn load_store(&mut self, store: &CalibStore) -> Vec<(SubarrayId, LoadOutcome)> {
        let mut outcomes: Vec<(SubarrayId, LoadOutcome)> = Vec::new();
        let mut candidates: Vec<(SubarrayId, Calibration)> = Vec::new();
        for (&id, entry) in &self.entries {
            match store.load_expecting(id, &self.cfg, entry.sub.cols) {
                Ok(Some(calib)) => candidates.push((id, calib)),
                Ok(None) => outcomes.push((id, LoadOutcome::Missing)),
                Err(e) => {
                    self.metrics.incr("recalib.rejected_on_load");
                    outcomes.push((id, LoadOutcome::Incompatible(e)));
                }
            }
        }
        // One batched spot check for every candidate: both MAJ
        // arities, so an accepted entry starts with a trustworthy
        // arithmetic-usable mask, not just a MAJ-`serve_m` one.
        let other_m = 8 - self.svc.serve_m;
        let mut reqs = Vec::with_capacity(2 * candidates.len());
        for (id, calib) in &candidates {
            let entry = &self.entries[id];
            for m in [self.svc.serve_m, other_m] {
                reqs.push(
                    EcrRequest::from_subarray(
                        &entry.sub,
                        entry.seed,
                        calib.clone(),
                        m,
                        self.svc.spot_check_samples,
                    )
                    .with_seed(SPOT_CHECK_STREAM),
                );
            }
        }
        let mut reports = self
            .metrics
            .time("service.spot_check", || {
                measure_ecr_isolated(&self.engine, &reqs, self.threads)
            })
            .into_iter();
        for (id, calib) in candidates {
            let primary = reports.next().expect("one primary spot check per candidate");
            let secondary = reports.next().expect("one secondary spot check per candidate");
            let outcome = match (primary, secondary) {
                (Ok(rep), Ok(sec)) => {
                    let spot_ecr = rep.ecr();
                    if spot_ecr <= self.svc.policy.accept_max_ecr {
                        let window = self.svc.policy.serve_window;
                        let entry = self.entries.get_mut(&id).expect("candidate is registered");
                        entry.calib = calib;
                        entry.state = EntryState::Accepted;
                        entry.monitor = DriftMonitor::new(&entry.sub.env, window);
                        entry.queued = false; // drop any pending cold-start job
                        entry.mask = Some(rep.intersect(&sec).error_free_mask());
                        self.metrics.incr("recalib.accepted_on_load");
                        LoadOutcome::Accepted { spot_ecr }
                    } else {
                        self.metrics.incr("recalib.rejected_on_load");
                        LoadOutcome::Rejected { spot_ecr }
                    }
                }
                (Err(e), _) | (_, Err(e)) => {
                    self.metrics.incr("recalib.rejected_on_load");
                    LoadOutcome::Incompatible(format!("spot check failed: {e}"))
                }
            };
            outcomes.push((id, outcome));
        }
        outcomes.sort_by_key(|(id, _)| *id);
        outcomes
    }

    /// Serve one workload batch on every subarray (one batched engine
    /// call, per-bank fault isolation): measures `serve_samples`
    /// random patterns at *both* MAJ arities under each entry's
    /// current calibration, feeds the primary (MAJ-`serve_m`) ECR into
    /// the drift monitors, refreshes the entry's arithmetic-usable
    /// mask (MAJ5 ∧ MAJ3 error-free — what [`Self::serve_plan`]
    /// restricts compute to), and never touches the recalibration
    /// queue — a stale entry keeps serving its old levels until
    /// background recalibration lands.
    pub fn serve(&mut self) -> Vec<ServeOutcome> {
        self.serve_epoch += 1;
        let seed = derive_seed(SERVE_STREAM, &[self.serve_epoch]);
        let other_m = 8 - self.svc.serve_m;
        let ids: Vec<SubarrayId> = self.entries.keys().copied().collect();
        let mut reqs = Vec::with_capacity(2 * ids.len());
        for id in &ids {
            let entry = &self.entries[id];
            for m in [self.svc.serve_m, other_m] {
                reqs.push(
                    EcrRequest::from_subarray(
                        &entry.sub,
                        entry.seed,
                        entry.calib.clone(),
                        m,
                        self.svc.serve_samples,
                    )
                    .with_seed(seed),
                );
            }
        }
        let mut reports = self
            .metrics
            .time("service.serve", || {
                measure_ecr_isolated(&self.engine, &reqs, self.threads)
            })
            .into_iter();
        ids.into_iter()
            .map(|id| {
                let primary = reports.next().expect("one primary report per entry");
                let secondary = reports.next().expect("one secondary report per entry");
                let entry = self.entries.get_mut(&id).expect("serving a registered entry");
                match (&primary, secondary) {
                    (Ok(rep), Ok(sec)) => {
                        entry.monitor.observe_ecr(rep.ecr());
                        entry.mask = Some(rep.intersect(&sec).error_free_mask());
                        self.metrics.incr("serve.batches");
                    }
                    (Ok(rep), Err(_)) => {
                        // The primary battery still monitors drift; the
                        // mask keeps its last trusted value.
                        entry.monitor.observe_ecr(rep.ecr());
                        self.metrics.incr("serve.batches");
                        self.metrics.incr("serve.bank_failures");
                    }
                    (Err(_), _) => self.metrics.incr("serve.bank_failures"),
                }
                ServeOutcome { id, state: entry.state, report: primary }
            })
            .collect()
    }

    /// Evaluate drift for every accepted entry and schedule background
    /// recalibration for the drifted ones (metric `recalib.scheduled`).
    /// Entries whose earlier recalibration failed (stale/uncalibrated,
    /// no longer queued) are re-queued here too (`recalib.rescheduled`),
    /// so faults retry on the next maintenance pass. Returns the fresh
    /// drift signals.
    pub fn poll_drift(&mut self) -> Vec<(SubarrayId, DriftSignal)> {
        let mut signals = Vec::new();
        let mut to_queue = Vec::new();
        for (&id, entry) in &mut self.entries {
            match entry.state {
                EntryState::Accepted => {
                    if let Some(sig) = entry.monitor.check(&self.svc.policy, &entry.sub.env) {
                        entry.state = EntryState::Stale;
                        self.metrics.incr("recalib.scheduled");
                        signals.push((id, sig));
                        to_queue.push(id);
                    }
                }
                EntryState::Stale | EntryState::Uncalibrated => {
                    if !entry.queued {
                        self.metrics.incr("recalib.rescheduled");
                        to_queue.push(id);
                    }
                }
            }
        }
        for id in to_queue {
            self.enqueue(id);
        }
        signals
    }

    /// Drain up to `max_jobs` queued recalibrations through the engine
    /// (one isolated batch: worker-pool fan-out, a panicking bank
    /// degrades to one error). Successes swap in the new calibration
    /// and re-anchor their drift monitor; failures keep the previous
    /// levels serving and are retried on the next [`Self::poll_drift`].
    pub fn run_pending(&mut self, max_jobs: usize) -> Vec<(SubarrayId, Result<(), String>)> {
        let mut ids = Vec::new();
        while ids.len() < max_jobs {
            let Some(id) = self.queue.pop_front() else {
                break;
            };
            let Some(entry) = self.entries.get_mut(&id) else {
                continue;
            };
            // Skip stale queue entries (e.g. accepted by a later
            // `load_store` after being queued at registration).
            if entry.queued {
                entry.queued = false;
                ids.push(id);
            }
        }
        if ids.is_empty() {
            return Vec::new();
        }
        let reqs: Vec<CalibRequest> = ids
            .iter()
            .map(|id| {
                let entry = &self.entries[id];
                CalibRequest::from_subarray(
                    &entry.sub,
                    entry.seed,
                    self.svc.config,
                    self.svc.params,
                )
            })
            .collect();
        let results = self.metrics.time("service.recalibrate", || {
            calibrate_isolated(&self.engine, &reqs, self.threads)
        });
        ids.into_iter()
            .zip(results)
            .map(|(id, result)| {
                let entry = self.entries.get_mut(&id).expect("recalibrating a registered entry");
                let outcome = match result {
                    Ok(calib) => {
                        entry.calib = calib;
                        entry.state = EntryState::Accepted;
                        entry.monitor.rebase(&entry.sub.env);
                        // The old mask measured the old levels; the
                        // next battery under the new calibration
                        // re-establishes it.
                        entry.mask = None;
                        self.metrics.incr("recalib.completed");
                        Ok(())
                    }
                    Err(e) => {
                        self.metrics.incr("recalib.failed");
                        Err(e)
                    }
                };
                (id, outcome)
            })
            .collect()
    }

    /// Snapshot the current calibrations into a persistable store —
    /// the write-back half of the lifecycle. Stale entries are
    /// included too: they are the last-known-good identification, and
    /// a shutdown between drift detection and repair should not erase
    /// them (the load-time spot check re-validates every entry on the
    /// next boot anyway). Only `Uncalibrated` entries — serving the
    /// uniform neutral levels — carry nothing worth persisting.
    pub fn snapshot_store(&self) -> CalibStore {
        let mut store = CalibStore::default();
        for (&id, entry) in &self.entries {
            if entry.state != EntryState::Uncalibrated {
                // v2 metadata: the environment the levels were
                // identified/accepted under.
                store.insert_with_env(id, &entry.calib, entry.monitor.calib_env());
            }
        }
        store
    }

    /// Set one subarray's die temperature (scenario driver / telemetry
    /// ingest). Returns false for unknown ids.
    pub fn set_temperature(&mut self, id: SubarrayId, temp_c: f64) -> bool {
        match self.entries.get_mut(&id) {
            Some(e) => {
                e.sub.set_temperature(temp_c);
                true
            }
            None => false,
        }
    }

    /// Advance simulated wall-clock time on every subarray (retention
    /// decay + aging drift).
    pub fn advance_time(&mut self, dt_hours: f64) {
        for entry in self.entries.values_mut() {
            entry.sub.advance_time(dt_hours);
        }
    }
}

/// Arithmetic serving (engines that also execute workloads).
impl<E: CalibEngine + ComputeEngine + Sync> RecalibService<E> {
    /// Compile `op` once and serve it on every registered subarray —
    /// see [`Self::serve_plan`]. An invalid op is a request-level
    /// error; per-bank faults live inside the returned outcomes.
    pub fn serve_workload(
        &mut self,
        op: PudOp,
        operands: &[Vec<u64>],
    ) -> Result<Vec<WorkloadOutcome>, PudError> {
        let plan = Arc::new(WorkloadPlan::compile(op)?);
        Ok(self.serve_plan(&plan, operands))
    }

    /// Serve one compiled workload batch on every subarray (one
    /// batched engine call, per-bank fault isolation): each bank
    /// executes under its *current* calibration and the error-free
    /// column mask from its most recent battery, stale entries
    /// included — arithmetic never waits on the recalibration queue.
    /// `operands` are per-column values broadcast to every bank; a
    /// bank whose geometry disagrees degrades to one `Err` outcome.
    /// Each outcome counts how many masked columns matched the
    /// software golden model (`compute.golden_mismatch` tracks the
    /// shortfall).
    pub fn serve_plan(
        &mut self,
        plan: &Arc<WorkloadPlan>,
        operands: &[Vec<u64>],
    ) -> Vec<WorkloadOutcome> {
        let ids: Vec<SubarrayId> = self.entries.keys().copied().collect();
        let reqs: Vec<ComputeRequest> = ids
            .iter()
            .map(|id| {
                let entry = &self.entries[id];
                let mut req = ComputeRequest::from_subarray(
                    &entry.sub,
                    entry.seed,
                    plan.clone(),
                    entry.calib.clone(),
                    operands.to_vec(),
                );
                if let Some(mask) = &entry.mask {
                    req = req.with_mask(mask.clone());
                }
                req
            })
            .collect();
        let results = self.metrics.time("compute.serve", || {
            execute_isolated(&self.engine, &reqs, self.threads)
        });
        // The golden model depends only on the plan and the broadcast
        // operands — evaluate the circuit once, not once per bank. A
        // 0-operand plan computes one constant; a bank that executed
        // successfully at a different width re-broadcasts it below.
        let shared_cols = operands.first().map(|v| v.len()).unwrap_or(1);
        let golden = plan.golden_outputs(operands, shared_cols);
        ids.into_iter()
            .zip(results)
            .map(|(id, result)| {
                let state = self.entries[&id].state;
                let (golden_correct, active_cols) = match (&result, &golden) {
                    (Ok(res), Ok(golden)) => {
                        self.metrics.incr("compute.batches");
                        let active = res.active_cols();
                        self.metrics.add("compute.columns_served", active as u64);
                        let correct = if golden.len() == res.outputs.len() {
                            res.golden_correct(golden)
                        } else {
                            // Only reachable for 0-operand plans (any
                            // width mismatch fails execution): compare
                            // every column to the broadcast constant.
                            let constant = vec![golden[0]; res.outputs.len()];
                            res.golden_correct(&constant)
                        };
                        if correct < active {
                            self.metrics
                                .add("compute.golden_mismatch", (active - correct) as u64);
                        }
                        (correct, active)
                    }
                    _ => {
                        self.metrics.incr("compute.bank_failures");
                        (0, 0)
                    }
                };
                WorkloadOutcome { id, state, result, golden_correct, active_cols }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::algorithm::NativeEngine;

    fn service(banks: usize, cols: usize) -> RecalibService<NativeEngine> {
        let cfg = DeviceConfig::default();
        let svc = ServiceConfig { serve_samples: 512, ..ServiceConfig::default() };
        let mut s = RecalibService::new(cfg.clone(), svc, NativeEngine::new(cfg)).unwrap();
        for b in 0..banks {
            s.register(SubarrayId::new(0, b, 0), 32, cols, 0x5EED);
        }
        s
    }

    #[test]
    fn cold_start_calibrates_and_persists() {
        let mut s = service(2, 512);
        assert_eq!(s.pending(), 2);
        assert!(s.ids().iter().all(|&id| s.state(id) == Some(EntryState::Uncalibrated)));
        let done = s.run_pending(usize::MAX);
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|(_, r)| r.is_ok()));
        assert!(s.ids().iter().all(|&id| s.state(id) == Some(EntryState::Accepted)));
        assert_eq!(s.pending(), 0);
        assert_eq!(s.snapshot_store().entries.len(), 2);
        assert_eq!(s.metrics.counter("recalib.completed"), 2);
    }

    #[test]
    fn load_accepts_good_entries_and_skips_their_cold_start() {
        let mut warm = service(2, 512);
        warm.run_pending(usize::MAX);
        let store = warm.snapshot_store();

        // "Reboot": a fresh service over the same manufactured device.
        let mut s = service(2, 512);
        let outcomes = s.load_store(&store);
        for (id, o) in &outcomes {
            assert!(matches!(o, LoadOutcome::Accepted { .. }), "{id:?}: {o:?}");
        }
        assert_eq!(s.metrics.counter("recalib.accepted_on_load"), 2);
        assert_eq!(s.metrics.counter("recalib.rejected_on_load"), 0);
        assert_eq!(s.pending(), 0);
        // The loaded levels are bit-identical to the persisted ones.
        for &id in &s.ids() {
            assert_eq!(
                s.calibration(id).unwrap().levels,
                warm.calibration(id).unwrap().levels
            );
        }
        // The stale queue entries from registration are skipped.
        assert!(s.run_pending(usize::MAX).is_empty());
    }

    #[test]
    fn load_rejects_tampered_entries() {
        let mut warm = service(1, 512);
        warm.run_pending(usize::MAX);
        let mut store = warm.snapshot_store();
        let id = SubarrayId::new(0, 0, 0);
        // Pin every column to the lowest lattice level: a maximally
        // wrong calibration that the spot check must catch.
        store.entries.get_mut(&id).unwrap().levels = vec![0; 512];

        let mut s = service(1, 512);
        let outcomes = s.load_store(&store);
        assert!(matches!(outcomes[0].1, LoadOutcome::Rejected { spot_ecr } if spot_ecr > 0.5));
        assert_eq!(s.metrics.counter("recalib.rejected_on_load"), 1);
        assert_eq!(s.state(id), Some(EntryState::Uncalibrated));
        // Still queued from registration: recalibration repairs it.
        assert_eq!(s.pending(), 1);
        s.run_pending(usize::MAX);
        assert_eq!(s.state(id), Some(EntryState::Accepted));
    }

    #[test]
    fn geometry_mismatch_is_incompatible_not_a_miss() {
        let mut warm = service(1, 512);
        warm.run_pending(usize::MAX);
        let store = warm.snapshot_store();
        let mut s = service(1, 256);
        let outcomes = s.load_store(&store);
        assert!(matches!(&outcomes[0].1, LoadOutcome::Incompatible(e) if e.contains("512")));
        assert_eq!(s.metrics.counter("recalib.rejected_on_load"), 1);
    }

    #[test]
    fn serve_feeds_monitors_without_touching_the_queue() {
        let mut s = service(1, 512);
        s.run_pending(usize::MAX);
        let out = s.serve();
        assert_eq!(out.len(), 1);
        assert!(out[0].report.is_ok());
        assert_eq!(out[0].state, EntryState::Accepted);
        assert_eq!(s.metrics.counter("serve.batches"), 1);
        assert_eq!(s.pending(), 0);
        // A quiet environment raises no drift signals.
        assert!(s.poll_drift().is_empty());
    }

    #[test]
    fn temperature_excursion_schedules_background_recalibration() {
        let mut s = service(2, 512);
        s.run_pending(usize::MAX);
        let hot = SubarrayId::new(0, 1, 0);
        assert!(s.set_temperature(hot, 85.0));
        let signals = s.poll_drift();
        assert_eq!(signals.len(), 1);
        assert_eq!(signals[0].0, hot);
        assert!(matches!(signals[0].1, DriftSignal::TemperatureExcursion { .. }));
        assert_eq!(s.state(hot), Some(EntryState::Stale));
        assert_eq!(s.metrics.counter("recalib.scheduled"), 1);
        // A shutdown now must not lose the stale bank's last-known-good
        // entry: snapshots persist everything except Uncalibrated.
        assert_eq!(s.snapshot_store().entries.len(), 2);
        // Stale entries keep serving while queued.
        assert!(s.serve()[1].report.is_ok());
        let done = s.run_pending(usize::MAX);
        assert_eq!(done.len(), 1);
        assert!(done[0].1.is_ok());
        assert_eq!(s.state(hot), Some(EntryState::Accepted));
        // Re-anchored at the hot temperature: no further signal.
        assert!(s.poll_drift().is_empty());
    }

    #[test]
    fn unknown_id_set_temperature_is_reported() {
        let mut s = service(1, 128);
        assert!(!s.set_temperature(SubarrayId::new(7, 7, 7), 60.0));
    }

    #[test]
    fn serve_workload_runs_under_current_masks() {
        use crate::pud::plan::PudOp;
        let cols = 64;
        let mut s = service(2, cols);
        s.run_pending(usize::MAX);
        // A served battery establishes each bank's error-free mask.
        s.serve();
        // width 2: the add2 plan needs ~10 scratch rows, well inside
        // the 16 the test geometry's data region provides.
        let a: Vec<u64> = (0..cols as u64).map(|c| c % 4).collect();
        let b: Vec<u64> = (0..cols as u64).map(|c| (c * 5 + 2) % 4).collect();
        let out = s
            .serve_workload(PudOp::Add { width: 2 }, &[a.clone(), b.clone()])
            .unwrap();
        assert_eq!(out.len(), 2);
        for o in &out {
            let res = o.result.as_ref().expect("served");
            assert_eq!(o.state, EntryState::Accepted);
            // The battery-derived mask restricts reporting.
            assert!(res.mask.len() == cols && o.active_cols <= cols);
            assert!(o.golden_correct <= o.active_cols);
            assert!(res.elapsed_ns > 0.0);
        }
        assert_eq!(s.metrics.counter("compute.batches"), 2);
        assert_eq!(s.metrics.counter("compute.bank_failures"), 0);
        // An invalid op fails the request, not the banks.
        assert!(s.serve_workload(PudOp::Add { width: 0 }, &[a, b]).is_err());
        assert_eq!(s.metrics.counter("compute.bank_failures"), 0);
    }

    #[test]
    fn snapshot_persists_calibration_environment_metadata() {
        let mut s = service(1, 128);
        s.run_pending(usize::MAX);
        let id = SubarrayId::new(0, 0, 0);
        // An excursion past the policy bound schedules recalibration;
        // the repaired entry re-anchors its monitor at the hot
        // temperature, which is what the v2 store must record.
        s.set_temperature(id, 85.0);
        assert_eq!(s.poll_drift().len(), 1);
        s.run_pending(usize::MAX);
        let store = s.snapshot_store();
        let env = store.stored_env(id).expect("v2 entries carry an environment");
        assert_eq!(env.temp_c, 85.0);
    }
}
